"""Pooling Pallas kernel vs oracle: 2x2/3x3 windows, strides 1..3."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import prng
from compile.kernels import maxpool_int
from compile.kernels import ref


class TestPoolBasic:
    def test_pool2x2_known(self):
        x = np.arange(16, dtype=np.int16).reshape(4, 4, 1)
        out = np.asarray(maxpool_int(jnp.asarray(x), k=2, stride=2))
        assert np.array_equal(out[:, :, 0], [[5, 7], [13, 15]])

    def test_pool3x3_overlapping(self):
        """AlexNet-style overlapping pool: k=3, stride=2."""
        x = prng.image_tensor(5, (9, 9, 3))
        out = np.asarray(maxpool_int(jnp.asarray(x), k=3, stride=2))
        assert out.shape == (4, 4, 3)
        assert np.array_equal(out, ref.maxpool_ref(x, 3, 2))

    def test_negative_values(self):
        """All-negative inputs: max must not clamp to zero."""
        x = np.full((6, 6, 2), -100, np.int16)
        x[1, 1, 0] = -7
        out = np.asarray(maxpool_int(jnp.asarray(x), k=2, stride=2))
        assert out[0, 0, 0] == -7
        assert out[0, 0, 1] == -100

    def test_int16_min_padding_not_leaked(self):
        """Channel padding uses INT16_MIN sentinels; they must never win."""
        x = np.full((5, 5, 17), -32767, np.int16)  # 17 ch -> padded to 32
        out = np.asarray(maxpool_int(jnp.asarray(x), k=2, stride=2))
        assert (out == -32767).all()


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.integers(3, 40),
    w=st.integers(3, 40),
    c=st.integers(1, 40),
    k=st.sampled_from([2, 3]),
    stride=st.integers(1, 3),
)
def test_pool_matches_oracle(seed, h, w, c, k, stride):
    if h < k or w < k:
        return
    x = prng.image_tensor(seed, (h, w, c), lo=-3000, hi=3000)
    got = np.asarray(maxpool_int(jnp.asarray(x), k=k, stride=stride))
    want = ref.maxpool_ref(x, k, stride)
    assert np.array_equal(got, want)
