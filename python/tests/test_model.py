"""L2 model graph tests: kernel decomposition, padding, net forwards."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import prng
from compile.model import conv_any, make_net_fn, layer_params
from compile.kernels import ref
from compile.nets import ZOO, net_shapes, conv_out_hw


@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.sampled_from([3, 5, 7, 11]),
    stride=st.sampled_from([1, 2, 4]),
    c=st.integers(1, 6),
    m=st.integers(1, 12),
    extra=st.integers(0, 9),
)
def test_kernel_decomposition_matches_direct(seed, k, stride, c, m, extra):
    """K>3 decomposed into shifted 3x3 passes == direct KxK oracle.

    This is the invariant that makes the fixed 3x3 CU array able to run
    arbitrary kernel sizes (paper §1: 'image, feature and kernel
    decompositions')."""
    h = w = k + extra + (stride - 1)
    x = prng.image_tensor(seed, (h, w, c))
    wt = prng.weight_tensor(seed + 1, (k, k, c, m))
    b = prng.bias_tensor(seed + 2, m)
    got = np.asarray(conv_any(jnp.asarray(x), jnp.asarray(wt), jnp.asarray(b),
                              stride=stride, shift=9, relu=True))
    want = ref.conv_ref(x, wt, b, stride=stride, shift=9, relu=True)
    assert np.array_equal(got, want)


def _net_oracle(net, x):
    """Run the whole net through the numpy oracle."""
    for l in net.layers:
        if l.kind == "pool":
            x = ref.maxpool_ref(x, l.k, l.stride)
        else:
            w, b = layer_params(l)
            x = ref.conv_ref(ref.pad_hw(x, l.pad), w, b, stride=l.stride,
                             shift=l.shift, relu=l.relu)
    return x


@pytest.mark.parametrize("name", ["quicknet", "facenet"])
def test_net_forward_matches_oracle(name):
    net = ZOO[name]()
    x = prng.image_tensor(123, (net.in_h, net.in_w, net.in_c))
    got = np.asarray(make_net_fn(net)(jnp.asarray(x))[0])
    want = _net_oracle(net, x)
    assert np.array_equal(got, want)


def test_net_shapes_match_eval_shape():
    """Static shape calculator agrees with jax tracing for every net."""
    import jax
    for name, mk in ZOO.items():
        net = mk()
        want = net_shapes(net)[-1][1:]
        fn = make_net_fn(net)
        aval = jax.eval_shape(fn, jnp.zeros((net.in_h, net.in_w, net.in_c),
                                            jnp.int16))[0]
        assert tuple(aval.shape) == tuple(want), name


def test_alexnet_table1_shapes():
    """The zoo must reproduce the layer shapes of the paper's Table 1."""
    net = ZOO["alexnet"]()
    shapes = {n: (h, w, c) for n, h, w, c in net_shapes(net)}
    assert shapes["input"] == (227, 227, 3)
    assert shapes["conv1"] == (55, 55, 96)
    assert shapes["conv2"] == (27, 27, 256)
    assert shapes["conv3"] == (13, 13, 384)
    assert shapes["conv4"] == (13, 13, 384)
    assert shapes["conv5"] == (13, 13, 256)


def test_facenet_signal_not_dead():
    """The synthetic quantization schedule must preserve signal (no
    all-zero collapse through the stack)."""
    net = ZOO["facenet"]()
    x = prng.image_tensor(7, (64, 64, 1))
    out = np.asarray(make_net_fn(net)(jnp.asarray(x))[0])
    assert out.std() > 5.0


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_conv_out_hw_consistency(seed):
    """conv_out_hw matches the oracle's actual output shape."""
    rng = prng.XorShift32(seed)
    k = [3, 5, 7][rng.next_u32() % 3]
    stride = [1, 2][rng.next_u32() % 2]
    pad = rng.next_u32() % 3
    h = k + rng.next_u32() % 12
    w = k + rng.next_u32() % 12
    x = prng.image_tensor(seed, (h, w, 2))
    wt = prng.weight_tensor(seed + 1, (k, k, 2, 3))
    b = prng.bias_tensor(seed + 2, 3)
    want_h, want_w = conv_out_hw(h, w, k, stride, pad)
    if want_h < 1 or want_w < 1:
        return
    out = ref.conv_ref(ref.pad_hw(x, pad), wt, b, stride=stride, shift=8,
                       relu=False)
    assert out.shape == (want_h, want_w, 3)
