"""Requantization kernel: the bit-exactness contract itself."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import requantize, requant_scalar
from compile.kernels.ref import requant_ref


class TestRequantKnown:
    def test_round_half_up(self):
        # 3/2 -> 2 (half rounds up), -3/2 -> -1 (toward +inf)
        acc = np.array([3, -3, 2, -2, 1, -1], np.int32)
        out = np.asarray(requantize(jnp.asarray(acc), shift=1))
        assert out.tolist() == [2, -1, 1, -1, 1, 0]

    def test_shift_zero_passthrough(self):
        acc = np.array([123, -456, 32767, -32768], np.int32)
        out = np.asarray(requantize(jnp.asarray(acc), shift=0))
        assert out.tolist() == [123, -456, 32767, -32768]

    def test_saturation_both_rails(self):
        acc = np.array([1 << 30, -(1 << 30), 32768 << 4, -(32769 << 4)], np.int32)
        out = np.asarray(requantize(jnp.asarray(acc), shift=4))
        assert out.tolist() == [32767, -32768, 32767, -32768]

    def test_relu(self):
        acc = np.array([-1000, -1, 0, 1, 1000], np.int32)
        out = np.asarray(requantize(jnp.asarray(acc), shift=0, relu=True))
        assert out.tolist() == [0, 0, 0, 1, 1000]

    def test_rounding_add_can_wrap(self):
        """acc near INT32_MAX: the rounding add wraps (hardware register
        semantics) — all three implementations must agree."""
        acc = np.array([2**31 - 1, 2**31 - 64, -(2**31)], np.int32)
        out = np.asarray(requantize(jnp.asarray(acc), shift=8))
        want = requant_ref(acc.astype(np.int64), 8)
        scal = [requant_scalar(int(a), 8) for a in acc]
        assert out.tolist() == want.tolist() == scal


@settings(max_examples=60, deadline=None)
@given(
    accs=st.lists(st.integers(-(2**31), 2**31 - 1), min_size=1, max_size=64),
    shift=st.integers(0, 24),
    relu=st.booleans(),
)
def test_requant_three_way_agreement(accs, shift, relu):
    acc = np.array(accs, np.int32)
    kern = np.asarray(requantize(jnp.asarray(acc), shift=shift, relu=relu))
    orac = requant_ref(acc.astype(np.int64), shift, relu)
    scal = np.array([requant_scalar(int(a), shift, relu) for a in accs], np.int16)
    assert np.array_equal(kern, orac)
    assert np.array_equal(kern, scal)
