"""Grouped convolution + kernel decomposition edge cases (the AlexNet
conv2/4/5 path) — L2 vs the numpy oracle."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import prng
from compile.model import conv_grouped, layer_params, apply_layer
from compile.kernels import ref
from compile.nets import ZOO


def _grouped_oracle(x, w, b, stride, shift, relu, groups):
    cg = x.shape[2] // groups
    mg = w.shape[3] // groups
    outs = [
        ref.conv_ref(x[:, :, g * cg:(g + 1) * cg],
                     w[:, :, :, g * mg:(g + 1) * mg],
                     b[g * mg:(g + 1) * mg],
                     stride=stride, shift=shift, relu=relu)
        for g in range(groups)
    ]
    return np.concatenate(outs, axis=2)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    groups=st.sampled_from([1, 2, 4]),
    k=st.sampled_from([3, 5]),
    cg=st.integers(1, 4),
    mg=st.integers(1, 8),
    extra=st.integers(0, 8),
)
def test_grouped_conv_matches_oracle(seed, groups, k, cg, mg, extra):
    cin, cout = groups * cg, groups * mg
    h = w_dim = k + extra
    x = prng.image_tensor(seed, (h, w_dim, cin))
    w = prng.weight_tensor(seed + 1, (k, k, cg, cout))
    b = prng.bias_tensor(seed + 2, cout)
    got = np.asarray(conv_grouped(jnp.asarray(x), jnp.asarray(w),
                                  jnp.asarray(b), stride=1, shift=9,
                                  relu=True, groups=groups))
    want = _grouped_oracle(x, w, b, 1, 9, True, groups)
    assert np.array_equal(got, want)


def test_alexnet_conv2_layer_exact():
    """The real AlexNet conv2 (k5, pad2, groups=2, 96->256 ch)."""
    net = ZOO["alexnet"]()
    conv2 = net.layers[2]
    assert conv2.name == "conv2" and conv2.groups == 2
    x = prng.image_tensor(5, (27, 27, 96))
    got = np.asarray(apply_layer(jnp.asarray(x), conv2))
    w, b = layer_params(conv2)
    want = _grouped_oracle(ref.pad_hw(x, conv2.pad), w, b, conv2.stride,
                           conv2.shift, conv2.relu, conv2.groups)
    assert got.shape == (27, 27, 256)
    assert np.array_equal(got, want)


def test_grouped_weight_shape():
    net = ZOO["alexnet"]()
    conv2 = net.layers[2]
    w, b = layer_params(conv2)
    assert w.shape == (5, 5, 48, 256)  # cin/groups = 48
    assert b.shape == (256,)


@pytest.mark.parametrize("k,stride", [(7, 1), (7, 2), (9, 3), (11, 4)])
def test_large_kernel_decomposition(k, stride):
    """Kernel sizes beyond AlexNet's (future-work coverage)."""
    from compile.model import conv_any
    h = k + 2 * stride + 1
    x = prng.image_tensor(k, (h, h, 2))
    w = prng.weight_tensor(k + 1, (k, k, 2, 5))
    b = prng.bias_tensor(k + 2, 5)
    got = np.asarray(conv_any(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                              stride=stride, shift=10, relu=False))
    want = ref.conv_ref(x, w, b, stride=stride, shift=10, relu=False)
    assert np.array_equal(got, want)


def test_1x1_kernel_via_padding():
    """K=1 pads to a 3x3 with zero ring — must equal the 1x1 oracle."""
    from compile.model import conv_any
    x = prng.image_tensor(31, (6, 6, 3))
    w = prng.weight_tensor(32, (1, 1, 3, 4))
    b = prng.bias_tensor(33, 4)
    got = np.asarray(conv_any(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b),
                              stride=1, shift=6, relu=True))
    want = ref.conv_ref(x, w, b, stride=1, shift=6, relu=True)
    assert got.shape == (6, 6, 4)
    assert np.array_equal(got, want)
