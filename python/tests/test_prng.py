"""xorshift32 contract tests — pinned vectors shared with rust/util/rng.rs.

If these values change, the Rust side (util::rng tests pin the SAME
vectors) and every baked artifact weight changes with them."""

import numpy as np

from compile import prng

# Pinned: XorShift32(1).next_u32() x 5 — mirrored in rust/src/util/rng.rs
PINNED_SEED1 = [270369, 67634689, 2647435461, 307599695, 2398689233]
# Pinned: XorShift32(0) must remap seed 0 -> golden ratio constant
PINNED_SEED0_FIRST = 1359758873


def test_pinned_vectors():
    r = prng.XorShift32(1)
    assert [r.next_u32() for _ in range(5)] == PINNED_SEED1


def test_zero_seed_remap():
    assert prng.XorShift32(0).next_u32() == PINNED_SEED0_FIRST
    assert prng.XorShift32(0).state != 0


def test_ranges():
    r = prng.XorShift32(99)
    vals = [r.next_i16_in(-128, 127) for _ in range(1000)]
    assert min(vals) >= -128 and max(vals) <= 127
    assert min(vals) < -100 and max(vals) > 100  # actually spans the range


def test_weight_tensor_deterministic():
    a = prng.weight_tensor(7, (3, 3, 2, 4))
    b = prng.weight_tensor(7, (3, 3, 2, 4))
    assert np.array_equal(a, b)
    c = prng.weight_tensor(8, (3, 3, 2, 4))
    assert not np.array_equal(a, c)


def test_image_tensor_pixel_range():
    img = prng.image_tensor(3, (16, 16, 3))
    assert img.min() >= 0 and img.max() <= 255 and img.dtype == np.int16
