"""AOT path tests: HLO text must be loadable by the rust side's parser
(no elided constants, tuple-rooted, parameter dtypes as expected)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import prng
from compile.aot import to_hlo_text, tile_conv_fn, TILE_SEEDS

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_constants_not_elided():
    """print_large_constants must be on: an elided 'constant({...})' would
    silently zero the baked weights on the rust side."""
    ws, bs = TILE_SEEDS["conv_s1"]
    fn = tile_conv_fn(3, 1, 8, 16, 10, True, ws, bs)
    lowered = jax.jit(fn).lower(jnp.zeros((10, 10, 8), jnp.int16))
    text = to_hlo_text(lowered)
    assert "constant({...})" not in text
    assert "s16[3,3,8,16]" in text  # the weight constant, fully printed


def test_root_is_tuple():
    """rust unwraps with to_tuple1(); the root must be a 1-tuple."""
    ws, bs = TILE_SEEDS["conv_s1"]
    fn = tile_conv_fn(3, 1, 8, 16, 10, True, ws, bs)
    lowered = jax.jit(fn).lower(jnp.zeros((10, 10, 8), jnp.int16))
    text = to_hlo_text(lowered)
    root_lines = [l for l in text.splitlines() if "ROOT" in l and "tuple" in l]
    assert root_lines, "entry root must be a tuple"


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["version"] == 1
    names = set()
    for a in man["artifacts"]:
        assert os.path.exists(os.path.join(ART, a["file"])), a["file"]
        assert a["name"] not in names, "duplicate artifact name"
        names.add(a["name"])
        assert a["input"]["dtype"] == "int16"
        assert a["output"]["dtype"] == "int16"
    # the contract set the rust runtime expects
    for required in ("conv3x3_s1_tile", "facenet_fwd", "alexnet_fwd",
                     "quicknet_fwd"):
        assert required in names


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_artifact_constants_present_on_disk():
    """Spot-check: the alexnet artifact must contain the conv2 weight
    tensor fully printed (it is ~600k values; elision would shrink the
    file by >10x)."""
    path = os.path.join(ART, "alexnet_fwd.hlo.txt")
    assert os.path.getsize(path) > 4 * 1024 * 1024
    with open(path) as f:
        head = f.read(1 << 20)
    assert "constant({...})" not in head
