"""L1 conv3x3 Pallas kernel vs the pure-numpy oracle — the CORE
correctness signal of the compile path. Hypothesis sweeps shapes,
strides, channel counts, shifts, and value ranges."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import prng
from compile.kernels import conv3x3_acc, conv3x3_int
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _case(seed, h, w, c, m, lo=-128, hi=127):
    x = prng.image_tensor(seed, (h, w, c))
    wt = prng.weight_tensor(seed + 1, (3, 3, c, m), lo, hi)
    b = prng.bias_tensor(seed + 2, m)
    return x, wt, b


class TestConvBasic:
    def test_identity_kernel(self):
        """A center-tap delta filter must reproduce the input (shift 0)."""
        x = prng.image_tensor(1, (10, 10, 1))
        w = np.zeros((3, 3, 1, 1), np.int16)
        w[1, 1, 0, 0] = 1
        b = np.zeros(1, np.int32)
        out = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), stride=1, shift=0,
                                     relu=False))
        assert np.array_equal(out[:, :, 0], x[1:-1, 1:-1, 0])

    def test_bias_only(self):
        x = np.zeros((8, 8, 2), np.int16)
        w = np.zeros((3, 3, 2, 4), np.int16)
        b = np.array([5, -7, 100, 0], np.int32)
        out = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), stride=1, shift=0,
                                     relu=False))
        assert np.array_equal(out[0, 0], b.astype(np.int16))

    def test_relu_clamps_negative(self):
        x = np.ones((6, 6, 1), np.int16)
        w = np.full((3, 3, 1, 1), -1, np.int16)
        b = np.zeros(1, np.int32)
        out = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), stride=1, shift=0,
                                     relu=True))
        assert (out == 0).all()

    def test_saturation(self):
        """Large accumulators must saturate to int16, not wrap."""
        x = np.full((5, 5, 4), 255, np.int16)
        w = np.full((3, 3, 4, 1), 127, np.int16)
        b = np.zeros(1, np.int32)
        out = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), stride=1, shift=0,
                                     relu=False))
        assert (out == 32767).all()
        w = -w
        out = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), stride=1, shift=0,
                                     relu=False))
        assert (out == -32768).all()

    def test_nonsquare_and_nondivisible(self):
        """H_out not a multiple of the 8-row stripe, M not 16-wide."""
        x, w, b = _case(10, 13, 21, 3, 5)
        got = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), stride=1, shift=8,
                                     relu=True))
        want = ref.conv_ref(x, w, b, stride=1, shift=8, relu=True)
        assert np.array_equal(got, want)

    def test_min_size(self):
        """Smallest legal input: 3x3 -> 1x1."""
        x, w, b = _case(11, 3, 3, 2, 1)
        got = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(w),
                                     jnp.asarray(b), stride=1, shift=4,
                                     relu=False))
        want = ref.conv_ref(x, w, b, stride=1, shift=4, relu=False)
        assert got.shape == (1, 1, 1)
        assert np.array_equal(got, want)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.integers(3, 40),
    w=st.integers(3, 40),
    c=st.integers(1, 24),
    m=st.integers(1, 40),
    stride=st.sampled_from([1, 2, 4]),
    shift=st.integers(0, 16),
    relu=st.booleans(),
)
def test_conv_matches_oracle(seed, h, w, c, m, stride, shift, relu):
    if h < 3 or w < 3 or (h - 3) // stride < 0:
        return
    x = prng.image_tensor(seed, (h, w, c), lo=-256, hi=255)
    wt = prng.weight_tensor(seed ^ 0xABCD, (3, 3, c, m))
    b = prng.bias_tensor(seed ^ 0x1234, m)
    got = np.asarray(conv3x3_int(jnp.asarray(x), jnp.asarray(wt),
                                 jnp.asarray(b), stride=stride, shift=shift,
                                 relu=relu))
    want = ref.conv_ref(x, wt, b, stride=stride, shift=shift, relu=relu)
    assert np.array_equal(got, want)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    h=st.integers(3, 24),
    w=st.integers(3, 24),
    c=st.integers(1, 8),
    m=st.integers(1, 20),
    stride=st.sampled_from([1, 2]),
)
def test_acc_matches_oracle(seed, h, w, c, m, stride):
    """Raw int32 partial path (decomposition building block)."""
    x = prng.image_tensor(seed, (h, w, c), lo=-300, hi=300)
    wt = prng.weight_tensor(seed + 7, (3, 3, c, m), -300, 300)
    got = np.asarray(conv3x3_acc(jnp.asarray(x), jnp.asarray(wt), stride=stride))
    want = ref.conv_acc_ref(x, wt, stride)
    assert np.array_equal(got.astype(np.int64), want)


def test_extreme_values_wrap_exactly():
    """int32 accumulator overflow must wrap identically to the oracle's
    explicit two's-complement model (C = 64 of max-magnitude products)."""
    x = np.full((6, 6, 64), 32767, np.int16)
    w = np.full((3, 3, 64, 1), 32767, np.int16)  # 9*64*2^30 >> int32
    got = np.asarray(conv3x3_acc(jnp.asarray(x), jnp.asarray(w), stride=1))
    want = ref.conv_acc_ref(x, w, 1)
    assert np.array_equal(got.astype(np.int64), want)
