"""AOT compile path: lower L2 graphs to HLO *text* artifacts for Rust.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` 0.1.6 crate binds) rejects;
the text parser reassigns ids and round-trips cleanly.

Run once via ``make artifacts``; the Rust binary is self-contained
afterwards. Also writes ``artifacts/manifest.json`` — the contract the
Rust runtime reads (shapes, dtypes, seeds, layer params).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import prng
from .kernels import ref
from .model import conv_any, make_net_fn, layer_params
from .kernels import maxpool_int
from .nets import ZOO, net_shapes

# Standalone-tile weight seeds (recorded in the manifest; Rust regenerates).
TILE_SEEDS = {"conv_s1": (3000, 3001), "conv_s2": (3002, 3003),
              "alexnet_c1": (9000, 9001)}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the baked weight tensors ARE the model —
    # the default elides them to "constant({...})" which the rust-side text
    # parser would reject (or worse, zero-fill).
    return comp.as_hlo_text(print_large_constants=True)


def lower_and_write(fn, example, out_dir: str, name: str) -> dict:
    t0 = time.time()
    lowered = jax.jit(fn).lower(example)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    out_aval = jax.eval_shape(fn, example)[0]
    print(f"  {name}: {example.shape}{example.dtype} -> "
          f"{out_aval.shape}{out_aval.dtype}  "
          f"({len(text)//1024} KiB, {time.time()-t0:.1f}s)")
    return {
        "name": name,
        "file": f"{name}.hlo.txt",
        "input": {"shape": list(example.shape), "dtype": str(example.dtype)},
        "output": {"shape": list(out_aval.shape), "dtype": str(out_aval.dtype)},
    }


def tile_conv_fn(k: int, stride: int, cin: int, cout: int, shift: int,
                 relu: bool, wseed: int, bseed: int):
    from .nets import B_HI, B_LO, W_HI, W_LO
    w = jnp.asarray(prng.weight_tensor(wseed, (k, k, cin, cout), W_LO, W_HI))
    b = jnp.asarray(prng.bias_tensor(bseed, cout, B_LO, B_HI))

    def fn(x):
        return (conv_any(x, w, b, stride=stride, shift=shift, relu=relu),)

    return fn


def build_all(out_dir: str, nets: list[str], selfcheck: bool) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"version": 1, "artifacts": []}

    # --- standalone CU-tile kernels (runtime microbench + golden refs) ----
    print("tiles:")
    ws, bs = TILE_SEEDS["conv_s1"]
    ent = lower_and_write(
        tile_conv_fn(3, 1, 8, 16, 10, True, ws, bs),
        jnp.zeros((66, 66, 8), jnp.int16), out_dir, "conv3x3_s1_tile")
    ent.update(kind="conv", k=3, stride=1, pad=0, cin=8, cout=16, shift=10,
               relu=True, wseed=ws, bseed=bs)
    manifest["artifacts"].append(ent)

    ws, bs = TILE_SEEDS["conv_s2"]
    ent = lower_and_write(
        tile_conv_fn(3, 2, 8, 16, 10, True, ws, bs),
        jnp.zeros((67, 67, 8), jnp.int16), out_dir, "conv3x3_s2_tile")
    ent.update(kind="conv", k=3, stride=2, pad=0, cin=8, cout=16, shift=10,
               relu=True, wseed=ws, bseed=bs)
    manifest["artifacts"].append(ent)

    # AlexNet conv1 on one image-decomposition tile: 11x11/s4 via kernel
    # decomposition (Fig. 6's 1/9 tile: 83x83x3 -> 19x19x96).
    ws, bs = TILE_SEEDS["alexnet_c1"]
    ent = lower_and_write(
        tile_conv_fn(11, 4, 3, 96, 12, True, ws, bs),
        jnp.zeros((83, 83, 3), jnp.int16), out_dir, "alexnet_conv1_tile")
    ent.update(kind="conv", k=11, stride=4, pad=0, cin=3, cout=96, shift=12,
               relu=True, wseed=ws, bseed=bs)
    manifest["artifacts"].append(ent)

    def pool_fn(k, stride):
        return lambda x: (maxpool_int(x, k=k, stride=stride),)

    ent = lower_and_write(pool_fn(3, 2), jnp.zeros((55, 55, 16), jnp.int16),
                          out_dir, "pool3x3_s2_tile")
    ent.update(kind="pool", k=3, stride=2)
    manifest["artifacts"].append(ent)

    ent = lower_and_write(pool_fn(2, 2), jnp.zeros((54, 54, 16), jnp.int16),
                          out_dir, "pool2x2_s2_tile")
    ent.update(kind="pool", k=2, stride=2)
    manifest["artifacts"].append(ent)

    # --- whole-net forwards (weights baked as HLO constants) --------------
    print("nets:")
    for net_name in nets:
        net = ZOO[net_name]()
        fn = make_net_fn(net)
        example = jnp.zeros((net.in_h, net.in_w, net.in_c), jnp.int16)
        ent = lower_and_write(fn, example, out_dir, f"{net_name}_fwd")
        ent.update(kind="net", net=net_name,
                   shapes=[list(s) for s in net_shapes(net)])
        manifest["artifacts"].append(ent)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts")

    if selfcheck:
        run_selfcheck()


def run_selfcheck() -> None:
    """Cheap end-of-build check: tile kernel vs the pure-numpy oracle."""
    from .nets import B_HI, B_LO, W_HI, W_LO
    ws, bs = TILE_SEEDS["conv_s1"]
    x = prng.image_tensor(42, (66, 66, 8))
    w = prng.weight_tensor(ws, (3, 3, 8, 16), W_LO, W_HI)
    b = prng.bias_tensor(bs, 16, B_LO, B_HI)
    got = np.asarray(tile_conv_fn(3, 1, 8, 16, 10, True, ws, bs)(jnp.asarray(x))[0])
    want = ref.conv_ref(x, w, b, stride=1, shift=10, relu=True)
    assert np.array_equal(got, want), "selfcheck FAILED: kernel != oracle"
    print("selfcheck: conv3x3_s1_tile == numpy oracle (bit-exact)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--nets", default="quicknet,facenet,alexnet",
                    help="comma-separated net names to AOT (vgg16 is large)")
    ap.add_argument("--no-selfcheck", action="store_true")
    args = ap.parse_args()
    build_all(args.out_dir, [n for n in args.nets.split(",") if n],
              not args.no_selfcheck)


if __name__ == "__main__":
    main()
