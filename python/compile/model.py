"""L2 — quantized CNN compute graphs composed from the L1 Pallas kernels.

This is the *build-time* model definition: ``aot.py`` jit-lowers these
functions once to HLO text and the Rust runtime executes the artifacts;
Python never runs on the request path.

Kernel decomposition (paper §1/§5): the CU array is a fixed 3x3
primitive, so K>3 convolutions are decomposed into ceil(K/3)^2 shifted
3x3 sub-kernels whose int32 partial sums accumulate in the accumulation
buffer — ``conv_any`` implements exactly the schedule the compiler
(``rust/src/compiler/kernel_decomp.rs``) emits for the chip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import prng
from .kernels import conv3x3_acc, conv3x3_int, maxpool_int, requantize
from .nets import ConvSpec, NetSpec, PoolSpec


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def pad_hw(x: jax.Array, pad: int) -> jax.Array:
    if pad == 0:
        return x
    return jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))


def conv_grouped(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int,
                 shift: int, relu: bool, groups: int) -> jax.Array:
    """Grouped convolution (original AlexNet conv2/4/5): each group is an
    independent conv over a channel slice — exactly how the compiler maps
    groups onto feature-decomposition passes."""
    if groups == 1:
        return conv_any(x, w, b, stride=stride, shift=shift, relu=relu)
    cin = x.shape[2]
    cout = w.shape[3]
    assert cin % groups == 0 and cout % groups == 0
    cg, mg = cin // groups, cout // groups
    assert w.shape[2] == cg, f"grouped weight cin {w.shape[2]} != {cg}"
    outs = []
    for g in range(groups):
        outs.append(conv_any(
            x[:, :, g * cg:(g + 1) * cg],
            w[:, :, :, g * mg:(g + 1) * mg],
            b[g * mg:(g + 1) * mg],
            stride=stride, shift=shift, relu=relu))
    return jnp.concatenate(outs, axis=2)


def conv_any(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int,
             shift: int, relu: bool) -> jax.Array:
    """KxK conv via the 3x3 CU primitive (direct for K=3, decomposed else).

    For K>3 the filter is zero-padded to Kp = 3*ceil(K/3) and split into a
    (Kp/3 x Kp/3) grid of 3x3 sub-kernels. Sub-kernel (p, q) sees the
    input shifted by (3p, 3q); all partials accumulate in wrapping int32
    (order-independent), then bias + requantize once — identical to the
    hardware pass schedule.
    """
    k = w.shape[0]
    if k == 3:
        return conv3x3_int(x, w, b, stride=stride, shift=shift, relu=relu)
    kp = _ceil_to(k, 3)
    h, wid, _ = x.shape
    ho = (h - k) // stride + 1
    wo = (wid - k) // stride + 1
    # Pad the filter to Kp and the input so every shifted 3x3 pass sees a
    # full window (the zero filter taps contribute nothing).
    w_p = jnp.pad(w, ((0, kp - k), (0, kp - k), (0, 0), (0, 0)))
    x_p = jnp.pad(x, ((0, kp - k), (0, kp - k), (0, 0)))
    acc = None
    for p in range(kp // 3):
        for q in range(kp // 3):
            sub = w_p[3 * p:3 * p + 3, 3 * q:3 * q + 3]
            xs = x_p[3 * p:, 3 * q:, :]
            part = conv3x3_acc(xs, sub, stride=stride)[:ho, :wo, :]
            acc = part if acc is None else acc + part
    acc = acc + b.astype(jnp.int32)
    return requantize(acc, shift=shift, relu=relu)


def layer_params(l: ConvSpec) -> tuple[np.ndarray, np.ndarray]:
    """Regenerate the layer's deterministic weights (shared with Rust)."""
    from .nets import B_HI, B_LO, W_HI, W_LO
    w = prng.weight_tensor(l.wseed, (l.k, l.k, l.cin // l.groups, l.cout),
                           W_LO, W_HI)
    b = prng.bias_tensor(l.bseed, l.cout, B_LO, B_HI)
    return w, b


def apply_layer(x: jax.Array, l, params=None) -> jax.Array:
    if isinstance(l, PoolSpec) or getattr(l, "kind", None) == "pool":
        return maxpool_int(x, k=l.k, stride=l.stride)
    w, b = params if params is not None else layer_params(l)
    x = pad_hw(x, l.pad)
    return conv_grouped(x, jnp.asarray(w), jnp.asarray(b), stride=l.stride,
                        shift=l.shift, relu=l.relu, groups=l.groups)


def net_forward(net: NetSpec, x: jax.Array) -> jax.Array:
    """Full quantized forward pass; weights baked as HLO constants."""
    for l in net.layers:
        x = apply_layer(x, l)
    return x


def make_net_fn(net: NetSpec):
    """A jit-able fn(image int16 (H,W,C)) -> int16 feature map, with the
    weight constants closed over (they become HLO constants on lowering,
    mirroring the chip's 'weights pre-stored in DRAM' model)."""
    params = [layer_params(l) if l.kind == "conv" else None
              for l in net.layers]

    def fwd(x):
        for l, p in zip(net.layers, params):
            x = apply_layer(x, l, p)
        return (x,)  # 1-tuple: lowered with return_tuple=True for rust

    return fwd
