"""Deterministic xorshift32 PRNG shared bit-for-bit with the Rust side.

The accelerator reproduction needs *identical* synthetic weights on the
Python (L1/L2 compile path) and Rust (L3 simulator) sides so that the
cycle simulator's output can be compared bit-exactly against the
PJRT-executed HLO artifact. numpy/jax RNGs are not stable contracts
across versions, so we pin a tiny xorshift32 implemented identically in
``rust/src/util/rng.rs``.
"""

from __future__ import annotations

import numpy as np


class XorShift32:
    """xorshift32 (Marsaglia) — mirrors ``kn_stream::util::rng::XorShift32``."""

    def __init__(self, seed: int):
        seed &= 0xFFFFFFFF
        if seed == 0:
            seed = 0x9E3779B9
        self.state = seed

    def next_u32(self) -> int:
        x = self.state
        x ^= (x << 13) & 0xFFFFFFFF
        x ^= x >> 17
        x ^= (x << 5) & 0xFFFFFFFF
        self.state = x
        return x

    def next_i16_in(self, lo: int, hi: int) -> int:
        """Uniform int in [lo, hi] via modulo (bias irrelevant for synthetic weights)."""
        span = hi - lo + 1
        return lo + self.next_u32() % span


def weight_tensor(seed: int, shape: tuple[int, ...], lo: int = -128, hi: int = 127) -> np.ndarray:
    """Deterministic int16 weight tensor; generation order is C-contiguous."""
    rng = XorShift32(seed)
    n = int(np.prod(shape))
    flat = np.empty(n, dtype=np.int16)
    for i in range(n):
        flat[i] = rng.next_i16_in(lo, hi)
    return flat.reshape(shape)


def bias_tensor(seed: int, n: int, lo: int = -1024, hi: int = 1023) -> np.ndarray:
    rng = XorShift32(seed)
    out = np.empty(n, dtype=np.int32)
    for i in range(n):
        out[i] = rng.next_i16_in(lo, hi)
    return out


def image_tensor(seed: int, shape: tuple[int, ...], lo: int = 0, hi: int = 255) -> np.ndarray:
    """Deterministic int16 activation/image tensor (8-bit pixel range by default)."""
    rng = XorShift32(seed)
    n = int(np.prod(shape))
    flat = np.empty(n, dtype=np.int16)
    for i in range(n):
        flat[i] = rng.next_i16_in(lo, hi)
    return flat.reshape(shape)
