"""L1 Pallas kernels — the accelerator's compute hot-spot.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls; see DESIGN.md §Deviations). The BlockSpec / grid structure
mirrors the chip's dataflow: 8-row streaming stripes (column buffer),
16-wide output-feature tiles (the 16-CU engine array), channel-serial
int32 accumulation (the accumulation buffer), and a fused 16-bit
requantization output stage.
"""

from .conv3x3 import conv3x3_int, conv3x3_acc, STRIPE_ROWS, CU_FEATURES
from .pool import maxpool_int
from .quant import requantize, requant_scalar

__all__ = [
    "conv3x3_int",
    "conv3x3_acc",
    "maxpool_int",
    "requantize",
    "requant_scalar",
    "STRIPE_ROWS",
    "CU_FEATURES",
]
