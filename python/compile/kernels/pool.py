"""Reconfigurable streaming max-pooling Pallas kernel (paper §4.3).

The chip's pooling module reads rows of one output feature from a
scratchpad, muxes the valid rows for the configured conv stride, and
reduces a 2x2 or 3x3 window with a four-input comparator plus a feedback
register. Functionally that is a running max over the window taps; here
each tap is a shifted strided view of the feature-tile block and the
feedback register is the running ``jnp.maximum`` accumulator.

Grid: one step per 16-feature tile (the scratchpad holds one output
feature group at a time).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv3x3 import CU_FEATURES, _ceil_to

_I16_MIN = -32768


def _pool_kernel(x_ref, o_ref, *, k: int, stride: int, h_out: int, w_out: int):
    x = x_ref[...]  # (H, W, 16) int16
    acc = jnp.full((h_out, w_out, CU_FEATURES), _I16_MIN, jnp.int16)
    for i in range(k):
        for j in range(k):
            tap = jax.lax.slice(
                x,
                (i, j, 0),
                (i + (h_out - 1) * stride + 1, j + (w_out - 1) * stride + 1,
                 CU_FEATURES),
                (stride, stride, 1),
            )
            acc = jnp.maximum(acc, tap)  # comparator + feedback register
    o_ref[...] = acc


def maxpool_int(x: jax.Array, *, k: int = 2, stride: int = 2) -> jax.Array:
    """Max-pool (H, W, C) int16 with window ``k`` in {2, 3} and ``stride``."""
    assert k in (2, 3), "the pooling module supports 2x2 and 3x3 windows"
    assert x.dtype == jnp.int16
    h, w, c = x.shape
    h_out = (h - k) // stride + 1
    w_out = (w - k) // stride + 1
    assert h_out >= 1 and w_out >= 1
    c_p = _ceil_to(c, CU_FEATURES)
    rows_needed = (h_out - 1) * stride + k
    cols_needed = (w_out - 1) * stride + k
    x_p = jnp.pad(x, ((0, 0), (0, 0), (0, c_p - c)),
                  constant_values=_I16_MIN)[:rows_needed, :cols_needed, :]
    out = pl.pallas_call(
        functools.partial(_pool_kernel, k=k, stride=stride, h_out=h_out,
                          w_out=w_out),
        grid=(c_p // CU_FEATURES,),
        in_specs=[pl.BlockSpec((rows_needed, cols_needed, CU_FEATURES),
                               lambda f: (0, 0, f))],
        out_specs=pl.BlockSpec((h_out, w_out, CU_FEATURES),
                               lambda f: (0, 0, f)),
        out_shape=jax.ShapeDtypeStruct((h_out, w_out, c_p), jnp.int16),
        interpret=True,
    )(x_p)
    return out[:, :, :c]
