"""16-bit fixed-point requantization — the ACC BUF output stage (L1).

``q = sat16(round_half_up(acc * 2^-shift))`` with round-half-up
implemented as an int32 wrapping add of ``2^(shift-1)`` followed by an
arithmetic right shift — exactly what the accelerator's output stage
does in silicon and what ``rust/src/fixed`` mirrors bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _requant_kernel(a_ref, o_ref, *, shift: int, relu: bool):
    acc = a_ref[...]
    if shift > 0:
        acc = acc + jnp.int32(1 << (shift - 1))
        acc = jnp.right_shift(acc, shift)
    acc = jnp.clip(acc, -32768, 32767)
    if relu:
        acc = jnp.maximum(acc, 0)
    o_ref[...] = acc.astype(jnp.int16)


def requantize(acc: jax.Array, *, shift: int, relu: bool = False) -> jax.Array:
    """Requantize an int32 accumulator tensor of any shape to int16."""
    assert acc.dtype == jnp.int32
    assert 0 <= shift < 31
    flat = acc.reshape(-1)
    out = pl.pallas_call(
        functools.partial(_requant_kernel, shift=shift, relu=relu),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.int16),
        interpret=True,
    )(flat)
    return out.reshape(acc.shape)


def requant_scalar(acc: int, shift: int, relu: bool = False) -> int:
    """Pure-python mirror (for tests / documentation of the contract)."""
    acc = ((acc + 0x8000_0000) & 0xFFFF_FFFF) - 0x8000_0000  # wrap to int32
    if shift > 0:
        acc = ((acc + (1 << (shift - 1)) + 0x8000_0000) & 0xFFFF_FFFF) - 0x8000_0000
        acc >>= shift  # python's >> on negatives floors == arithmetic shift
    acc = max(-32768, min(32767, acc))
    if relu:
        acc = max(0, acc)
    return acc
