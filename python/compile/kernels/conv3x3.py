"""Streaming 3x3 convolution Pallas kernel — the CU engine array (L1).

Hardware mapping (DESIGN.md §Hardware-Adaptation):

- The chip streams the input feature map through a **column buffer** that
  presents 3x3 windows to the CU array without re-reading SRAM. Here the
  nine window taps are nine shifted strided views of an 8-output-row
  *stripe* held in VMEM — same reuse, no im2col blow-up.
- The **16 CUs** share one input window and produce 16 output features
  per cycle; the grid's feature axis tiles the output features by
  ``CU_FEATURES = 16`` and each grid step multiplies the stripe against
  a ``(3,3,C,16)`` filter block (input-stationary reuse).
- The **accumulation buffer** sums channel partials in int32 and applies
  the fused bias + requantize + ReLU output stage; ``conv3x3_acc``
  exposes the raw int32 partial path used by feature/kernel
  decomposition (the compiler replays it per sub-kernel / channel group).

Numerics contract (mirrored bit-exactly by ``rust/src/fixed``):
int16 activations x int16 weights -> wrapping int32 accumulate
(+ int32 bias) -> round-half-up arithmetic shift by ``shift`` ->
saturate to int16 -> optional ReLU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

STRIPE_ROWS = 8  # the chip streams 8 pixels/cycle from a 16 B SRAM word
CU_FEATURES = 16  # 16 convolution units in the engine array


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, stride: int, w_out: int,
                 shift: int | None, relu: bool):
    """One grid step: one 8-row output stripe x one 16-feature CU tile."""
    r = pl.program_id(0)
    rows_needed = (STRIPE_ROWS - 1) * stride + 3
    row0 = r * STRIPE_ROWS * stride
    # Column-buffer fill: the stripe of input rows feeding this output stripe.
    xs = x_ref[pl.dslice(row0, rows_needed), :, :]
    w = w_ref[...].astype(jnp.int32)  # (3, 3, C, 16)
    acc = jnp.zeros((STRIPE_ROWS, w_out, CU_FEATURES), jnp.int32)
    # Nine taps of the column buffer == nine shifted strided views.
    for i in range(3):
        for j in range(3):
            win = jax.lax.slice(
                xs,
                (i, j, 0),
                (i + (STRIPE_ROWS - 1) * stride + 1,
                 j + (w_out - 1) * stride + 1,
                 xs.shape[2]),
                (stride, stride, 1),
            ).astype(jnp.int32)  # (8, w_out, C)
            acc = acc + jnp.matmul(win, w[i, j])  # (8, w_out, 16)
    if b_ref is not None:
        acc = acc + b_ref[...].astype(jnp.int32)
    if shift is None:
        o_ref[...] = acc
        return
    # Fused ACC BUF output stage: round-half-up shift, saturate, ReLU.
    if shift > 0:
        acc = acc + jnp.int32(1 << (shift - 1))
        acc = jnp.right_shift(acc, shift)
    acc = jnp.clip(acc, -32768, 32767)
    if relu:
        acc = jnp.maximum(acc, 0)
    o_ref[...] = acc.astype(jnp.int16)


def _run(x: jax.Array, w: jax.Array, b: jax.Array | None, *, stride: int,
         shift: int | None, relu: bool) -> jax.Array:
    """Pad to stripe/CU granularity, launch the grid, crop the result."""
    h, wid, c = x.shape
    kh, kw, wc, m = w.shape
    assert (kh, kw) == (3, 3), "the CU primitive is 3x3; larger K uses kernel decomposition"
    assert wc == c, f"channel mismatch {wc} != {c}"
    assert x.dtype == jnp.int16 and w.dtype == jnp.int16
    h_out = (h - 3) // stride + 1
    w_out = (wid - 3) // stride + 1
    assert h_out >= 1 and w_out >= 1, f"input {h}x{wid} too small for 3x3/s{stride}"

    # Stripe-pad output rows to a multiple of 8 (zero rows below the image
    # feed the final partial stripe, cropped after the launch).
    h_out_p = _ceil_to(h_out, STRIPE_ROWS)
    rows_in_needed = (h_out_p - 1) * stride + 3
    m_p = _ceil_to(m, CU_FEATURES)
    if rows_in_needed >= h:
        x_p = jnp.pad(x, ((0, rows_in_needed - h), (0, 0), (0, 0)))
    else:
        # Stride leaves trailing rows no output depends on — drop them.
        x_p = x[:rows_in_needed]
    w_p = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, m_p - m)))
    if b is not None:
        assert b.dtype == jnp.int32 and b.shape == (m,)
        b_p = jnp.pad(b, ((0, m_p - m),))

    grid = (h_out_p // STRIPE_ROWS, m_p // CU_FEATURES)
    out_dtype = jnp.int32 if shift is None else jnp.int16
    in_specs = [
        # Full input each step: the kernel slices its own stripe (the chip's
        # column buffer addresses SRAM rows the same way).
        pl.BlockSpec(x_p.shape, lambda r, f: (0, 0, 0)),
        pl.BlockSpec((3, 3, c, CU_FEATURES), lambda r, f: (0, 0, 0, f)),
    ]
    args = [x_p, w_p]
    if b is not None:
        in_specs.append(pl.BlockSpec((CU_FEATURES,), lambda r, f: (f,)))
        args.append(b_p)
        kern = functools.partial(_conv_kernel, stride=stride, w_out=w_out,
                                 shift=shift, relu=relu)
    else:
        def kern(x_ref, w_ref, o_ref):
            _conv_kernel(x_ref, w_ref, None, o_ref, stride=stride,
                         w_out=w_out, shift=shift, relu=relu)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((STRIPE_ROWS, w_out, CU_FEATURES),
                               lambda r, f: (r, 0, f)),
        out_shape=jax.ShapeDtypeStruct((h_out_p, w_out, m_p), out_dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*args)
    return out[:h_out, :, :m]


def conv3x3_int(x: jax.Array, w: jax.Array, b: jax.Array, *, stride: int = 1,
                shift: int = 8, relu: bool = True) -> jax.Array:
    """Fused 3x3 conv: int16 in -> int16 out with bias+requant+ReLU.

    ``x``: (H, W, C) int16, already padded by the caller (valid conv).
    ``w``: (3, 3, C, M) int16. ``b``: (M,) int32.
    """
    return _run(x, w, b, stride=stride, shift=shift, relu=relu)


def conv3x3_acc(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    """Raw int32 partial-sum path (no bias/requant) for decomposition.

    The compiler accumulates several of these (kernel decomposition taps,
    feature-decomposition channel groups) in the accumulation buffer and
    requantizes once at the end — wrapping int32 addition makes the
    result independent of accumulation order.
    """
    return _run(x, w, None, stride=stride, shift=None, relu=False)
