"""Pure-numpy oracle for the L1 kernels (independent implementation).

Deliberately written a *different* way from the Pallas kernels — im2col
patch extraction + int64 math with explicit wrap-to-int32 — so that an
agreement between kernel and oracle is meaningful. Used by the pytest /
hypothesis suites and by ``aot.py --selfcheck``.
"""

from __future__ import annotations

import numpy as np


def wrap32(a: np.ndarray) -> np.ndarray:
    """Wrap int64 values to int32 two's-complement (the ACC BUF register)."""
    return ((a.astype(np.int64) + 0x8000_0000) % 0x1_0000_0000) - 0x8000_0000


def requant_ref(acc: np.ndarray, shift: int, relu: bool = False) -> np.ndarray:
    """round-half-up shift + saturate + optional ReLU, via floor division."""
    acc = wrap32(acc)
    if shift > 0:
        acc = wrap32(acc + (1 << (shift - 1)))
        acc = np.floor_divide(acc, 1 << shift)  # == arithmetic right shift
    acc = np.clip(acc, -32768, 32767)
    if relu:
        acc = np.maximum(acc, 0)
    return acc.astype(np.int16)


def conv_acc_ref(x: np.ndarray, w: np.ndarray, stride: int = 1) -> np.ndarray:
    """Valid KxK conv, int64 accumulate wrapped to int32 at the end.

    x: (H, W, C) int, w: (K, K, C, M) int. Returns (Ho, Wo, M) int64 whose
    values equal the wrapping-int32 accumulator of the hardware (wrap32
    of the true sum equals the sum of wrapped partials — two's complement
    addition is associative modulo 2^32).
    """
    kh, kw, c, m = w.shape
    h, wid, xc = x.shape
    assert xc == c
    ho = (h - kh) // stride + 1
    wo = (wid - kw) // stride + 1
    # im2col: gather patches, one big integer matmul.
    patches = np.empty((ho, wo, kh * kw * c), dtype=np.int64)
    for i in range(kh):
        for j in range(kw):
            tap = x[i:i + (ho - 1) * stride + 1:stride,
                    j:j + (wo - 1) * stride + 1:stride, :]
            patches[:, :, (i * kw + j) * c:(i * kw + j + 1) * c] = tap
    wmat = w.astype(np.int64).transpose(0, 1, 2, 3).reshape(kh * kw * c, m)
    return wrap32(patches.reshape(ho * wo, -1) @ wmat).reshape(ho, wo, m)


def conv_ref(x: np.ndarray, w: np.ndarray, b: np.ndarray, *, stride: int = 1,
             shift: int = 8, relu: bool = True) -> np.ndarray:
    """Full fused conv oracle matching ``conv3x3_int`` (any K)."""
    acc = conv_acc_ref(x, w, stride) + b.astype(np.int64)
    return requant_ref(acc, shift, relu)


def maxpool_ref(x: np.ndarray, k: int = 2, stride: int = 2) -> np.ndarray:
    h, w, c = x.shape
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    out = np.full((ho, wo, c), -32768, dtype=np.int16)
    for i in range(ho):
        for j in range(wo):
            win = x[i * stride:i * stride + k, j * stride:j * stride + k, :]
            out[i, j, :] = win.reshape(-1, c).max(axis=0)
    return out


def pad_hw(x: np.ndarray, pad: int) -> np.ndarray:
    """Zero-pad H and W (the DMA writes a zero apron around each tile)."""
    if pad == 0:
        return x
    return np.pad(x, ((pad, pad), (pad, pad), (0, 0)))
