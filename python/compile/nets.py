"""Network zoo shared with the Rust side (``rust/src/model/zoo.rs``).

Layer specs, weight seeds, and quantization shifts are the contract:
both sides regenerate identical synthetic weights from the xorshift32
seeds, so the Rust cycle simulator and the AOT HLO artifacts must agree
bit-for-bit. Any edit here must be mirrored in ``zoo.rs`` (the
integration tests catch drift).
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ConvSpec:
    name: str
    k: int          # kernel size (KxK); K>3 is run via kernel decomposition
    stride: int
    pad: int
    cin: int
    cout: int
    shift: int      # requantization right-shift (power-of-two scale)
    relu: bool
    wseed: int
    bseed: int
    groups: int = 1   # grouped convolution (original AlexNet conv2/4/5)
    kind: str = field(default="conv", init=False)


@dataclass(frozen=True)
class PoolSpec:
    name: str
    k: int          # 2 or 3
    stride: int
    kind: str = field(default="pool", init=False)


@dataclass(frozen=True)
class NetSpec:
    name: str
    in_h: int
    in_w: int
    in_c: int
    layers: tuple


# Weight magnitudes: |w| <= 127, biases |b| <= 1023, pixels 0..255 —
# together with the per-layer shifts this keeps typical activations in
# a few-hundred range (no saturation on synthetic data) while the
# contract itself is wrap/saturate-exact either way.
W_LO, W_HI = -128, 127
B_LO, B_HI = -1024, 1023


def quicknet() -> NetSpec:
    """Tiny net for the quickstart example: one conv + one pool."""
    base = 5000
    return NetSpec(
        "quicknet", 18, 18, 4,
        (
            ConvSpec("conv1", 3, 1, 0, 4, 16, 9, True, base, base + 1),
            PoolSpec("pool1", 2, 2),
        ),
    )


def facenet() -> NetSpec:
    """Small face-detection CNN (the Fig. 8 FPGA demo workload).

    64x64 grayscale -> 4x4x16 score map; detection = per-cell score
    thresholding on channel 0 (see examples/face_detection.rs).
    """
    base = 7000
    return NetSpec(
        "facenet", 64, 64, 1,
        (
            ConvSpec("conv1", 3, 1, 1, 1, 8, 8, True, base + 0, base + 1),
            PoolSpec("pool1", 2, 2),
            ConvSpec("conv2", 3, 1, 1, 8, 16, 9, True, base + 2, base + 3),
            PoolSpec("pool2", 2, 2),
            ConvSpec("conv3", 3, 1, 1, 16, 32, 10, True, base + 4, base + 5),
            PoolSpec("pool3", 2, 2),
            ConvSpec("conv4", 3, 1, 0, 32, 16, 10, True, base + 6, base + 7),
            ConvSpec("score", 3, 1, 0, 16, 16, 10, False, base + 8, base + 9),
        ),
    )


def alexnet_convstack() -> NetSpec:
    """AlexNet CONV+POOL stack (Table 1 of the paper; FC layers excluded
    per the paper's scope). 227x227x3 -> 6x6x256."""
    base = 9000
    return NetSpec(
        "alexnet", 227, 227, 3,
        (
            ConvSpec("conv1", 11, 4, 0, 3, 96, 11, True, base + 0, base + 1),
            PoolSpec("pool1", 3, 2),
            ConvSpec("conv2", 5, 1, 2, 96, 256, 12, True, base + 2, base + 3, groups=2),
            PoolSpec("pool2", 3, 2),
            ConvSpec("conv3", 3, 1, 1, 256, 384, 12, True, base + 4, base + 5),
            ConvSpec("conv4", 3, 1, 1, 384, 384, 12, True, base + 6, base + 7, groups=2),
            ConvSpec("conv5", 3, 1, 1, 384, 256, 12, True, base + 8, base + 9, groups=2),
            PoolSpec("pool5", 3, 2),
        ),
    )


def vgg16_convstack() -> NetSpec:
    """VGG-16 conv stack (all-3x3 — the shape the streaming CU array is
    natively built for). Used by the decomposition and throughput sweeps."""
    base = 11000
    layers = []
    cfg = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]
    cin = 3
    seed = base
    for bi, (cout, reps) in enumerate(cfg, start=1):
        for ri in range(1, reps + 1):
            layers.append(ConvSpec(f"conv{bi}_{ri}", 3, 1, 1, cin, cout, 8 if cin == 3 else 11,
                                   True, seed, seed + 1))
            seed += 2
            cin = cout
        layers.append(PoolSpec(f"pool{bi}", 2, 2))
    return NetSpec("vgg16", 224, 224, 3, tuple(layers))


ZOO = {
    "quicknet": quicknet,
    "facenet": facenet,
    "alexnet": alexnet_convstack,
    "vgg16": vgg16_convstack,
}


def conv_out_hw(h: int, w: int, k: int, stride: int, pad: int) -> tuple[int, int]:
    return (h + 2 * pad - k) // stride + 1, (w + 2 * pad - k) // stride + 1


def net_shapes(net: NetSpec) -> list[tuple[str, int, int, int]]:
    """(layer name, H, W, C) of every layer *output*, input first."""
    shapes = [("input", net.in_h, net.in_w, net.in_c)]
    h, w, c = net.in_h, net.in_w, net.in_c
    for l in net.layers:
        if l.kind == "conv":
            h, w = conv_out_hw(h, w, l.k, l.stride, l.pad)
            c = l.cout
        else:
            h, w = (h - l.k) // l.stride + 1, (w - l.k) // l.stride + 1
        shapes.append((l.name, h, w, c))
    return shapes
