//! Regenerates **Fig. 6** (image & feature decomposition of AlexNet
//! conv1): SRAM footprints with and without decomposition, the paper's
//! canonical ÷9/÷2 plan, the solver's plan, and the DRAM-traffic cost
//! of decomposing ("at the cost of slower computation").
//!
//! `cargo bench --bench bench_fig6_decomposition`

use kn_stream::compiler::decompose::{plan_conv, plan_fixed_grid};
use kn_stream::compiler::NetRunner;
use kn_stream::model::{zoo, LayerSpec, NetSpec, Tensor};
use kn_stream::util::bench::{JsonReport, Table};
use kn_stream::SRAM_BYTES;

fn main() {
    let net = zoo::alexnet();
    let LayerSpec::Conv(c1) = &net.layers[0] else { unreachable!() };
    let (h, w) = (227usize, 227usize);

    // ---- SRAM footprint table (the Fig. 6 numbers) -------------------------
    let naive_in = h * w * c1.cin * 2;
    let naive_out = 55 * 55 * c1.cout * 2;
    let mut t = Table::new(
        "Fig. 6 — AlexNet conv1 SRAM footprint vs decomposition",
        &["plan", "tiles", "feat split", "in tile", "out tile", "fits 128KB?"],
    );
    t.row(&[
        "undecomposed".into(),
        "1".into(),
        "1".into(),
        format!("{:.0}KB", naive_in as f64 / 1e3),
        format!("{:.0}KB", naive_out as f64 / 1e3),
        "NO (309KB input alone)".into(),
    ]);
    let grids = [(3, 3, 2, "paper ÷9, ÷2"), (2, 2, 4, "2x2, ÷4"), (4, 4, 1, "4x4, ÷1")];
    for (gy, gx, fs, label) in grids {
        let (tiles, in_b, out_b) = plan_fixed_grid(c1, h, w, gy, gx, fs);
        let fits = in_b + out_b <= SRAM_BYTES;
        t.row(&[
            label.into(),
            format!("{}", tiles.len()),
            format!("{fs}"),
            format!("{:.0}KB", in_b as f64 / 1e3),
            format!("{:.0}KB", out_b as f64 / 1e3),
            if fits { "yes".into() } else { "NO".into() },
        ]);
    }
    let solver = plan_conv(c1, h, w).unwrap();
    t.row(&[
        "solver optimum".into(),
        format!("{}", solver.tiles.len()),
        format!("(16-wide x{})", solver.m_tiles),
        format!("{:.0}KB", solver.in_tile_bytes as f64 / 1e3),
        format!("{:.0}KB", solver.out_tile_bytes as f64 / 1e3),
        "yes".into(),
    ]);
    t.print();
    println!("paper: input 309KB -> 34KB (÷9), output 581KB -> 33KB (÷9 image x ÷2 feature)");

    // ---- decomposition cost: DRAM traffic & cycles vs grid ------------------
    let mut t = Table::new(
        "Decomposition cost on conv1 (measured on the simulator)",
        &["grid", "cycles", "DRAM read MB", "DRAM write MB", "halo overhead"],
    );
    let ideal_read = (h * w * c1.cin * 2) as f64 / 1e6;
    for force in [None, Some(2), Some(3), Some(4), Some(5)] {
        // single-layer net; to force a grid we shrink ACC_TILE via tiles:
        // easiest honest knob: run the solver plan (None) vs fixed grids by
        // constructing a plan-equivalent via plan_fixed_grid is codegen-
        // internal, so measure the solver plan and report fixed grids
        // analytically from tile halos.
        match force {
            None => {
                let single = NetSpec {
                    name: "conv1".into(),
                    in_h: h,
                    in_w: w,
                    in_c: c1.cin,
                    layers: vec![net.layers[0].clone()],
                };
                let runner = NetRunner::new(&single).unwrap();
                let frame = Tensor::random_image(3, h, w, c1.cin);
                let (_, stats) = runner.run_frame(&frame).unwrap();
                t.row(&[
                    format!("solver ({}x{})", solver.gy, solver.gx),
                    format!("{}", stats.cycles),
                    format!("{:.2}", stats.dram_read_bytes as f64 / 1e6),
                    format!("{:.2}", stats.dram_write_bytes as f64 / 1e6),
                    format!(
                        "{:.2}x vs ideal {:.2}MB",
                        stats.dram_read_bytes as f64 / 1e6 / ideal_read,
                        ideal_read
                    ),
                ]);
            }
            Some(g) => {
                let (tiles, _, _) = plan_fixed_grid(c1, h, w, g, g, 2);
                let read_px: usize =
                    tiles.iter().map(|tl| tl.ih * tl.iw * c1.cin).sum::<usize>() * solver.m_tiles;
                t.row(&[
                    format!("{g}x{g} (analytic)"),
                    "-".into(),
                    format!("{:.2}", (read_px * 2) as f64 / 1e6),
                    "-".into(),
                    format!("{:.2}x", (read_px * 2) as f64 / 1e6 / ideal_read),
                ]);
            }
        }
    }
    t.print();
    let mut report = JsonReport::new("fig6");
    report
        .text("bench", "fig6_decomposition")
        .num("solver_tiles", solver.tiles.len() as f64)
        .num("solver_in_tile_bytes", solver.in_tile_bytes as f64)
        .num("solver_sram_bytes", solver.sram_bytes as f64);
    report.write().expect("write BENCH_fig6.json");
    println!(
        "\nTakeaway (paper §5): decomposition turns an un-runnable 309KB working set \
         into <128KB tiles; the price is halo re-reads and per-feature-tile input \
         re-streaming — DRAM traffic grows with the grid, which is why the solver \
         prefers the coarsest grid that fits."
    );
}
