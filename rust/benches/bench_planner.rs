//! Decomposition-planner bench: predicted DRAM traffic, cross-tile
//! dependency counts and tile-granular overlap for every plan policy,
//! across the zoo at several SRAM budgets (the paper's Fig. 6 trade,
//! produced by the analytic planner instead of a fixed heuristic) —
//! plus the parallel weight-emission compile-time sweep.
//!
//! `cargo bench --bench bench_planner` → `BENCH_planner.json`
//!
//! The acceptance row: on at least one zoo graph, `dag-aware` must
//! reduce predicted DRAM traffic or cross-tile dependency count vs
//! `heuristic` (it does, massively, wherever feature decomposition
//! forces channel re-streaming); outputs stay bit-identical, which the
//! measured section re-verifies against the heuristic compile.

use kn_stream::analysis::analyze;
use kn_stream::compiler::{
    compile_graph_threads, compile_graph_with_options, CompileOptions, NetRunner,
};
use kn_stream::energy::OperatingPoint;
use kn_stream::model::{zoo, Tensor};
use kn_stream::planner::{plan_graph_budget, plan_graph_objective, PlanObjective, PlanPolicy};
use kn_stream::util::bench::{bench_once, JsonReport, Table};
use kn_stream::util::json::{obj, s, Json};
use kn_stream::SRAM_BYTES;

/// Nets whose planning analytics we sweep (everything), and the subset
/// small enough to execute per policy in a bench run.
const ANALYTIC_NETS: &[&str] =
    &["quicknet", "facenet", "edgenet", "widenet", "gapnet", "alexnet", "vgg16"];
const EXEC_NETS: &[&str] = &["facenet", "edgenet", "widenet", "gapnet"];
const BUDGETS: &[usize] = &[64 * 1024, 128 * 1024, 256 * 1024];
/// Nets for the objective trade-off sweep (planning-only, so mobilenet
/// and its fused dw/pw pairs ride along at no execution cost).
const OBJ_NETS: &[&str] = &["facenet", "edgenet", "widenet", "gapnet", "mobilenet"];
const OBJ_FREQS_MHZ: &[f64] = &[20.0, 100.0, 250.0, 500.0];

fn main() {
    let mut report = JsonReport::new("planner");
    report.text("bench", "planner");

    // ---- analytic sweep: traffic + deps per net × budget × policy --------
    let mut t = Table::new(
        "planner sweep — predicted DRAM MB / dep edges (per policy)",
        &["net", "SRAM", "heuristic", "min-traffic", "dag-aware"],
    );
    let mut dag_beats_heuristic = 0u32;
    for name in ANALYTIC_NETS {
        let graph = zoo::graph_by_name(name).unwrap();
        for &budget in BUDGETS {
            let mut cells: Vec<String> = vec![name.to_string(), format!("{}K", budget / 1024)];
            let mut heur: Option<(u64, u64)> = None;
            for policy in PlanPolicy::ALL {
                match plan_graph_budget(&graph, policy, budget) {
                    Ok(gp) => {
                        let tt = gp.total_traffic();
                        let total = tt.read_bytes + tt.write_bytes;
                        cells.push(format!("{:.2}MB/{}e", total as f64 / 1e6, gp.dep_edges));
                        if policy == PlanPolicy::Heuristic {
                            heur = Some((total, gp.dep_edges));
                        }
                        if policy == PlanPolicy::DagAware && budget == SRAM_BYTES {
                            if let Some((ht, hd)) = heur {
                                if total < ht || gp.dep_edges < hd {
                                    dag_beats_heuristic += 1;
                                }
                            }
                        }
                        report.push_row(
                            "plans",
                            obj(vec![
                                ("net", s(name)),
                                ("budget_kb", Json::Num((budget / 1024) as f64)),
                                ("policy", s(policy.name())),
                                ("pred_read_bytes", Json::Num(tt.read_bytes as f64)),
                                ("pred_write_bytes", Json::Num(tt.write_bytes as f64)),
                                ("dep_edges", Json::Num(gp.dep_edges as f64)),
                                (
                                    "est_critical_path_cycles",
                                    Json::Num(gp.est_critical_path_cycles as f64),
                                ),
                            ]),
                        );
                    }
                    Err(_) => cells.push("infeasible".into()),
                }
            }
            t.row(&cells);
        }
    }
    t.print();
    report.num("dag_beats_heuristic_nets", dag_beats_heuristic as f64);

    // ---- objectives: latency/energy trade at DVFS points -----------------
    let mut t = Table::new(
        "objective trade at 128K (full candidate search) — per DVFS point",
        &["net", "objective", "MHz", "cycles", "lat ms", "energy mJ", "DRAM MB"],
    );
    for name in OBJ_NETS {
        let graph = zoo::graph_by_name(name).unwrap();
        for &freq in OBJ_FREQS_MHZ {
            let op = OperatingPoint::for_freq(freq);
            let objectives = [
                PlanObjective::MinTraffic,
                PlanObjective::MinLatency { op },
                PlanObjective::MinEnergy { slo_ms: 0.0, op },
                PlanObjective::MinEdp { op },
            ];
            for objective in objectives {
                let gp = plan_graph_objective(&graph, PlanPolicy::MinTraffic, objective).unwrap();
                let tt = gp.total_traffic();
                let dram_bytes = (tt.read_bytes + tt.write_bytes) as f64;
                t.row(&[
                    name.to_string(),
                    objective.name().to_string(),
                    format!("{freq:.0}"),
                    format!("{}", gp.predicted_cycles()),
                    format!("{:.3}", gp.latency_ms(op)),
                    format!("{:.3}", gp.energy_j(op) * 1e3),
                    format!("{:.3}", dram_bytes / 1e6),
                ]);
                report.push_row(
                    "objective",
                    obj(vec![
                        ("net", s(name)),
                        ("objective", s(objective.name())),
                        ("freq_mhz", Json::Num(freq)),
                        ("cycles", Json::Num(gp.predicted_cycles() as f64)),
                        ("latency_ms", Json::Num(gp.latency_ms(op))),
                        ("energy_mj", Json::Num(gp.energy_j(op) * 1e3)),
                        ("pred_dram_bytes", Json::Num(dram_bytes)),
                    ]),
                );
            }
        }
    }
    t.print();

    // ---- measured: execute each policy, verify bit-exactness -------------
    let mut t = Table::new(
        "measured at 128K — DRAM MB (predicted == measured), cycles, overlap",
        &["net", "policy", "DRAM MB", "cycles", "overlap enters", "bit-exact"],
    );
    for name in EXEC_NETS {
        let graph = zoo::graph_by_name(name).unwrap();
        let frame = Tensor::random_image(7, graph.in_h, graph.in_w, graph.in_c);
        let mut baseline: Option<Tensor> = None;
        for policy in PlanPolicy::ALL {
            let runner = NetRunner::from_graph_with_policy(&graph, policy).unwrap();
            let (out, stats) = runner.run_frame(&frame).unwrap();
            let exact = match &baseline {
                None => {
                    baseline = Some(out);
                    true
                }
                Some(b) => *b == out,
            };
            assert!(exact, "{name}/{}: outputs diverged across policies", policy.name());
            // tile-granular overlap: segment enters while a segment of a
            // *different* node is still in flight (4 tile workers)
            let (_, _, trace) = runner.run_frame_parallel_traced(&frame, 4).unwrap();
            let mut in_flight: Vec<(usize, usize)> = Vec::new(); // (seg, node)
            let mut overlap_enters = 0u64;
            for ev in &trace {
                if ev.enter {
                    if in_flight.iter().any(|&(_, n)| n != ev.node) {
                        overlap_enters += 1;
                    }
                    in_flight.push((ev.seg, ev.node));
                } else {
                    in_flight.retain(|&(sg, _)| sg != ev.seg);
                }
            }
            let dram_mb = (stats.dram_read_bytes + stats.dram_write_bytes) as f64 / 1e6;
            t.row(&[
                name.to_string(),
                policy.name().to_string(),
                format!("{dram_mb:.3}"),
                format!("{}", stats.cycles),
                format!("{overlap_enters}"),
                "yes".into(),
            ]);
            report.push_row(
                "measured",
                obj(vec![
                    ("net", s(name)),
                    ("policy", s(policy.name())),
                    ("dram_read_bytes", Json::Num(stats.dram_read_bytes as f64)),
                    ("dram_write_bytes", Json::Num(stats.dram_write_bytes as f64)),
                    ("cycles", Json::Num(stats.cycles as f64)),
                    ("overlap_enters", Json::Num(overlap_enters as f64)),
                ]),
            );
        }
    }
    t.print();

    // ---- compile-time: parallel weight-image emission --------------------
    let mut t = Table::new(
        "vgg16 compile time — weight-image emission threads",
        &["threads", "wall"],
    );
    let vgg = zoo::graph_by_name("vgg16").unwrap();
    for threads in [1usize, 2, 4, 8] {
        let r = bench_once(&format!("compile_vgg16_t{threads}"), || {
            compile_graph_threads(&vgg, threads).unwrap().dram_px
        });
        t.row(&[format!("{threads}"), format!("{:.0}ms", r.mean.as_secs_f64() * 1e3)]);
        report.push_row(
            "compile",
            obj(vec![
                ("threads", Json::Num(threads as f64)),
                ("wall_ms", Json::Num(r.mean.as_secs_f64() * 1e3)),
            ]),
        );
    }
    t.print();

    // ---- static analysis: full-schedule lint cost per net ----------------
    let mut t = Table::new(
        "schedule lint at 128K dag-aware — analyzer wall time",
        &["net", "segs", "hazards", "lint ms"],
    );
    let opts = CompileOptions { verify: false, ..Default::default() };
    for name in EXEC_NETS {
        let graph = zoo::graph_by_name(name).unwrap();
        let gp = plan_graph_budget(&graph, PlanPolicy::DagAware, SRAM_BYTES).unwrap();
        let net = compile_graph_with_options(&graph, Some(&gp.plans), &opts).unwrap();
        let mut hazards = 0u64;
        let mut segs = 0usize;
        let r = bench_once(&format!("lint_{name}"), || {
            let a = analyze(&net).unwrap();
            assert!(a.is_clean(), "{name}: {}", a.report());
            hazards = a.hazards_checked;
            segs = a.segments;
            hazards
        });
        let lint_ms = r.mean.as_secs_f64() * 1e3;
        t.row(&[
            name.to_string(),
            format!("{segs}"),
            format!("{hazards}"),
            format!("{lint_ms:.2}"),
        ]);
        report.push_row(
            "lint",
            obj(vec![
                ("net", s(name)),
                ("segments", Json::Num(segs as f64)),
                ("hazards_checked", Json::Num(hazards as f64)),
                ("lint_ms", Json::Num(lint_ms)),
            ]),
        );
    }
    t.print();

    assert!(
        dag_beats_heuristic >= 1,
        "acceptance: dag-aware must reduce traffic or dep edges on >= 1 zoo graph"
    );
    report.write().expect("write BENCH_planner.json");
    println!(
        "\nTakeaway: the analytic planner turns the fixed \"fewest tiles\" heuristic into a\n\
         measured trade — min-traffic plans cut DRAM re-streaming wherever feature\n\
         decomposition forced channel reloads, and the DAG-aware pass aligns producer/\n\
         consumer split axes so consumer tiles wait on fewer producer tiles ({} nets\n\
         improved at the chip budget).",
        dag_beats_heuristic
    );
}
