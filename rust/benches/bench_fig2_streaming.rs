//! Regenerates **Fig. 2** (streaming architecture): the column buffer
//! turns row-streamed SRAM reads into one valid 3×3 window per cycle
//! after an 2-row fill — vs a naive window fetcher that re-reads the
//! 3×3 neighbourhood from SRAM for every output pixel.
//!
//! `cargo bench --bench bench_fig2_streaming`

use kn_stream::model::Tensor;
use kn_stream::sim::colbuf::ColumnBuffer;
use kn_stream::sim::sram::WORD_PX;
use kn_stream::util::bench::{bench, fmt_dur, JsonReport, Table};

fn main() {
    // ---- continuity: valid windows per streamed pixel ----------------------
    let mut t = Table::new(
        "Fig. 2b — streaming continuity (single channel, W x H tile)",
        &["tile", "pixels in", "fill px", "valid windows", "valid/cycle after fill",
          "SRAM words (col buf)", "SRAM words (naive)", "saving"],
    );
    for (h, w) in [(16usize, 16usize), (32, 32), (55, 55), (112, 112)] {
        let tensor = Tensor::random_image(1, h, w, 1);
        let mut cb = ColumnBuffer::new(w);
        let mut valid = 0u64;
        let mut fill_px = 0u64;
        for y in 0..h {
            for x in 0..w {
                if cb.push_px(tensor.at(y, x, 0)).is_some() {
                    valid += 1;
                } else if valid == 0 {
                    fill_px += 1;
                }
            }
        }
        let expect = ((h - 2) * (w - 2)) as u64;
        assert_eq!(valid, expect);
        // column buffer: every pixel read once = h*w/8 words
        let stream_words = (h * w).div_ceil(WORD_PX) as u64;
        // naive: 9 reads per output window, word-granular
        let naive_words = expect * 9 / WORD_PX as u64;
        let after_fill_rate = valid as f64 / (h * w) as f64 / ((h - 2) as f64 / h as f64);
        t.row(&[
            format!("{h}x{w}"),
            format!("{}", h * w),
            format!("{fill_px}"),
            format!("{valid}"),
            format!("{:.2}", after_fill_rate.min(1.0)),
            format!("{stream_words}"),
            format!("{naive_words}"),
            format!("{:.1}x", naive_words as f64 / stream_words as f64),
        ]);
    }
    t.print();

    // ---- host-side throughput of the streaming model -----------------------
    let tensor = Tensor::random_image(2, 64, 64, 1);
    let r = bench("column buffer 64x64 stream", || {
        let mut cb = ColumnBuffer::new(64);
        let mut acc = 0i64;
        for y in 0..64 {
            for x in 0..64 {
                if let Some(win) = cb.push_px(tensor.at(y, x, 0)) {
                    acc += win[4] as i64;
                }
            }
        }
        acc
    });
    println!(
        "\nhost microbench: 64x64 stream in {} ({:.1} Mpx/s simulated)",
        fmt_dur(r.mean),
        4096.0 / r.mean.as_secs_f64() / 1e6
    );
    let mut report = JsonReport::new("fig2");
    report
        .text("bench", "fig2_streaming")
        .num("colbuf_64x64_wall_ns", r.mean.as_nanos() as f64)
        .num("colbuf_mpx_per_sec", 4096.0 / r.mean.as_secs_f64() / 1e6);
    report.write().expect("write BENCH_fig2.json");
    println!(
        "Takeaway (paper Fig. 2): after the 2-row fill the pipeline yields one valid \
         window per streamed pixel — no pauses — while SRAM traffic drops ~9x vs \
         re-fetching windows."
    );
}
