//! Regenerates **Table 1** (AlexNet operations and storage summary),
//! cross-checks the static cost model against the *measured* simulator
//! event counts per layer, and times the hot path (the tap-major conv
//! kernel) per layer — emitting `BENCH_hotpath.json` with GOPS,
//! sim-cycles and wall-ns so the perf trajectory is tracked PR over PR.
//!
//! `cargo bench --bench bench_table1_alexnet`

use std::time::Instant;

use kn_stream::compiler::NetRunner;
use kn_stream::model::{zoo, LayerSpec, NetSpec, Tensor};
use kn_stream::planner::PlanPolicy;
use kn_stream::sim::SimStats;
use kn_stream::util::bench::{fmt_dur, JsonReport, Table};
use kn_stream::util::json::{num, obj, s};
use kn_stream::util::stats::eng;

/// Run a single layer as a one-layer net; returns the measured sim
/// stats and the best-of-3 host wall time for one frame.
fn measure_layer(
    net: &NetSpec,
    idx: usize,
    in_shape: (usize, usize, usize),
) -> (SimStats, std::time::Duration) {
    let single = NetSpec {
        name: format!("{}@{}", net.name, idx),
        in_h: in_shape.0,
        in_w: in_shape.1,
        in_c: in_shape.2,
        layers: vec![net.layers[idx].clone()],
    };
    let runner = NetRunner::new(&single).expect("plan");
    let frame = Tensor::random_image(9, in_shape.0, in_shape.1, in_shape.2);
    let mut best = std::time::Duration::MAX;
    let mut stats = SimStats::default();
    for _ in 0..3 {
        let t0 = Instant::now();
        let (_, st) = runner.run_frame(&frame).expect("run");
        best = best.min(t0.elapsed());
        stats = st;
    }
    (stats, best)
}

fn main() {
    let net = zoo::alexnet();
    let mut t = Table::new(
        "Table 1 — AlexNet operations and storage summary (paper values in §5)",
        &["layer", "input", "output", "ops (model)", "MACs (sim)", "pad ovh",
          "in mem", "out mem", "total", "host wall", "host GOPS"],
    );
    let mut report = JsonReport::new("hotpath");
    report.text("bench", "table1_alexnet").text("net", "alexnet");
    let mut shape = net.in_shape();
    let (mut total_ops, mut total_in, mut total_out) = (0u64, 0usize, 0usize);
    let (mut total_wall_ns, mut total_cycles, mut total_macs) = (0u128, 0u64, 0u64);
    for (i, l) in net.layers.iter().enumerate() {
        let out = l.out_shape(shape);
        if let LayerSpec::Conv(c) = l {
            let ops = c.ops(out.0, out.1);
            let (stats, wall) = measure_layer(&net, i, shape);
            let sim_macs = stats.macs;
            let host_gops = stats.ops() as f64 / wall.as_secs_f64() / 1e9;
            total_ops += ops;
            total_in += shape.0 * shape.1 * shape.2 * 2;
            total_out += out.0 * out.1 * out.2 * 2;
            total_wall_ns += wall.as_nanos();
            total_cycles += stats.cycles;
            total_macs += sim_macs;
            t.row(&[
                c.name.clone(),
                format!("{}x{}x{}", shape.0, shape.1, shape.2),
                format!("{}x{}x{}", out.0, out.1, out.2),
                eng(ops as f64),
                eng(sim_macs as f64),
                format!("{:.2}x", sim_macs as f64 / (ops / 2) as f64),
                format!("{:.0}KB", (shape.0 * shape.1 * shape.2 * 2) as f64 / 1e3),
                format!("{:.0}KB", (out.0 * out.1 * out.2 * 2) as f64 / 1e3),
                format!(
                    "{:.0}KB",
                    ((shape.0 * shape.1 * shape.2 + out.0 * out.1 * out.2) * 2) as f64 / 1e3
                ),
                fmt_dur(wall),
                format!("{host_gops:.2}"),
            ]);
            report.push_row(
                "layers",
                obj(vec![
                    ("name", s(&c.name)),
                    ("wall_ns", num(wall.as_nanos() as f64)),
                    ("sim_cycles", num(stats.cycles as f64)),
                    ("macs", num(sim_macs as f64)),
                    ("gops_host", num(host_gops)),
                    ("sram_words", num((stats.sram_reads + stats.sram_writes) as f64)),
                    ("dram_bytes", num((stats.dram_read_bytes + stats.dram_write_bytes) as f64)),
                ]),
            );
        }
        shape = out;
    }
    t.row(&[
        "Total".into(),
        "".into(),
        "".into(),
        eng(total_ops as f64),
        "".into(),
        "".into(),
        format!("{:.1}MB", total_in as f64 / 1e6),
        format!("{:.1}MB", total_out as f64 / 1e6),
        format!("{:.1}MB", (total_in + total_out) as f64 / 1e6),
    ]);
    t.print();
    println!(
        "\npaper row check: conv1 211M / conv2 448M / conv3 299M / conv4 224M / conv5 150M, \
         total 1.3G ops; 0.8MB in + 1.3MB out = 2.1MB.\n\
         'pad ovh' = simulator MACs / model MACs — the 3x3-array padding cost of kernel \
         decomposition (K=11 -> 144/121, K=5 -> 36/25) plus 16-feature rounding."
    );

    // ---- MobileNet-class per-node utilization (depthwise fast path) --------
    // Heuristic = packed dw lowering (16 channel planes per scan),
    // MinTraffic = fused DwPw on top of it. The per-node lane
    // utilization column is the acceptance metric for the fast path.
    let g = zoo::graph_by_name("mobilenet").unwrap();
    let frame = Tensor::random_image(9, g.in_h, g.in_w, g.in_c);
    let mut mt = Table::new(
        "mobilenet per-node (dw fast path): packed vs fused",
        &["node", "policy", "cycles", "MACs", "lane util", "DRAM KB"],
    );
    for policy in [PlanPolicy::Heuristic, PlanPolicy::MinTraffic] {
        let runner = NetRunner::from_graph_with_policy(&g, policy).expect("plan mobilenet");
        let (_, per_node) = runner.run_frame_node_stats(&frame).expect("run mobilenet");
        for (node, st) in g.nodes.iter().zip(&per_node) {
            if st.cycles == 0 {
                continue; // fused-away dw node: all work attributed to its pw consumer
            }
            let dram = (st.dram_read_bytes + st.dram_write_bytes) as f64 / 1e3;
            mt.row(&[
                node.op.name().to_string(),
                policy.name().into(),
                format!("{}", st.cycles),
                eng(st.macs as f64),
                format!("{:.3}", st.lane_utilization()),
                format!("{dram:.1}"),
            ]);
            report.push_row(
                "mobilenet_nodes",
                obj(vec![
                    ("node", s(node.op.name())),
                    ("policy", s(policy.name())),
                    ("sim_cycles", num(st.cycles as f64)),
                    ("macs", num(st.macs as f64)),
                    ("lane_utilization", num(st.lane_utilization())),
                    ("dram_bytes", num((st.dram_read_bytes + st.dram_write_bytes) as f64)),
                ]),
            );
        }
    }
    mt.print();

    // ---- machine-readable hot-path artifact (tracked by CI) ----------------
    let total_wall_s = total_wall_ns as f64 / 1e9;
    report
        .num("total_wall_ns", total_wall_ns as f64)
        .num("total_sim_cycles", total_cycles as f64)
        .num("total_macs", total_macs as f64)
        .num("gops", 2.0 * total_macs as f64 / total_wall_s / 1e9)
        .num("sim_cycles_per_wall_ns", total_cycles as f64 / total_wall_ns as f64)
        .num("frames_per_sec", 1.0 / total_wall_s);
    report.write().expect("write BENCH_hotpath.json");
    println!(
        "hot path: {} conv-layer sim in {:.1} ms host wall = {:.2} effective host GOPS",
        net.name,
        total_wall_s * 1e3,
        2.0 * total_macs as f64 / total_wall_s / 1e9
    );
}
