//! Regenerates **Table 1** (AlexNet operations and storage summary) and
//! cross-checks the static cost model against the *measured* simulator
//! event counts per layer.
//!
//! `cargo bench --bench bench_table1_alexnet`

use kn_stream::compiler::NetRunner;
use kn_stream::model::{zoo, LayerSpec, NetSpec, Tensor};
use kn_stream::util::bench::Table;
use kn_stream::util::stats::eng;

/// Run a single layer as a one-layer net to get measured sim stats.
fn measure_layer(net: &NetSpec, idx: usize, in_shape: (usize, usize, usize)) -> u64 {
    let single = NetSpec {
        name: format!("{}@{}", net.name, idx),
        in_h: in_shape.0,
        in_w: in_shape.1,
        in_c: in_shape.2,
        layers: vec![net.layers[idx].clone()],
    };
    let runner = NetRunner::new(&single).expect("plan");
    let frame = Tensor::random_image(9, in_shape.0, in_shape.1, in_shape.2);
    let (_, stats) = runner.run_frame(&frame).expect("run");
    stats.macs
}

fn main() {
    let net = zoo::alexnet();
    let mut t = Table::new(
        "Table 1 — AlexNet operations and storage summary (paper values in §5)",
        &["layer", "input", "output", "ops (model)", "MACs (sim)", "pad ovh",
          "in mem", "out mem", "total"],
    );
    let mut shape = net.in_shape();
    let (mut total_ops, mut total_in, mut total_out) = (0u64, 0usize, 0usize);
    for (i, l) in net.layers.iter().enumerate() {
        let out = l.out_shape(shape);
        if let LayerSpec::Conv(c) = l {
            let ops = c.ops(out.0, out.1);
            let sim_macs = measure_layer(&net, i, shape);
            total_ops += ops;
            total_in += shape.0 * shape.1 * shape.2 * 2;
            total_out += out.0 * out.1 * out.2 * 2;
            t.row(&[
                c.name.clone(),
                format!("{}x{}x{}", shape.0, shape.1, shape.2),
                format!("{}x{}x{}", out.0, out.1, out.2),
                eng(ops as f64),
                eng(sim_macs as f64),
                format!("{:.2}x", sim_macs as f64 / (ops / 2) as f64),
                format!("{:.0}KB", (shape.0 * shape.1 * shape.2 * 2) as f64 / 1e3),
                format!("{:.0}KB", (out.0 * out.1 * out.2 * 2) as f64 / 1e3),
                format!(
                    "{:.0}KB",
                    ((shape.0 * shape.1 * shape.2 + out.0 * out.1 * out.2) * 2) as f64 / 1e3
                ),
            ]);
        }
        shape = out;
    }
    t.row(&[
        "Total".into(),
        "".into(),
        "".into(),
        eng(total_ops as f64),
        "".into(),
        "".into(),
        format!("{:.1}MB", total_in as f64 / 1e6),
        format!("{:.1}MB", total_out as f64 / 1e6),
        format!("{:.1}MB", (total_in + total_out) as f64 / 1e6),
    ]);
    t.print();
    println!(
        "\npaper row check: conv1 211M / conv2 448M / conv3 299M / conv4 224M / conv5 150M, \
         total 1.3G ops; 0.8MB in + 1.3MB out = 2.1MB.\n\
         'pad ovh' = simulator MACs / model MACs — the 3x3-array padding cost of kernel \
         decomposition (K=11 -> 144/121, K=5 -> 36/25) plus 16-feature rounding."
    );
}
