//! Regenerates **Fig. 7** (layout area breakdown): 57 % SRAM / 35 % CU
//! array / 8 % column buffer of a 1.84 mm² 65 nm core — plus what-if
//! scalings (the ablation the area model enables).
//!
//! `cargo bench --bench bench_fig7_area`

use kn_stream::energy::AreaModel;
use kn_stream::util::bench::{JsonReport, Table};
use kn_stream::{NUM_CU, SRAM_BYTES};

fn main() {
    let m = AreaModel::default();
    let rpt = m.paper_config();
    let (s, c, b) = rpt.shares();

    let mut t = Table::new(
        "Fig. 7 — area breakdown (TSMC 65 nm, core 2.3 mm x 0.8 mm)",
        &["block", "mm²", "share", "paper"],
    );
    t.row(&[
        "SRAM buffer bank".into(),
        format!("{:.3}", rpt.sram_mm2),
        format!("{:.0}%", s * 100.0),
        "57%".into(),
    ]);
    t.row(&[
        "CU engine array".into(),
        format!("{:.3}", rpt.cu_array_mm2),
        format!("{:.0}%", c * 100.0),
        "35%".into(),
    ]);
    t.row(&[
        "column buffer".into(),
        format!("{:.3}", rpt.colbuf_mm2),
        format!("{:.0}%", b * 100.0),
        "8%".into(),
    ]);
    t.row(&[
        "core total".into(),
        format!("{:.3}", rpt.total_mm2()),
        "100%".into(),
        "1.84 mm²".into(),
    ]);
    t.print();
    println!("gate count: {:.2} M (paper: 0.3 M)\n", m.gate_count(&rpt) / 1e6);

    // ---- what-if scalings ---------------------------------------------------
    let mut t = Table::new(
        "What-if configurations (area model ablation)",
        &["config", "SRAM mm²", "CU mm²", "colbuf mm²", "total mm²", "SRAM share"],
    );
    for (label, sram, ncu, row) in [
        ("paper (128KB, 16 CU)", SRAM_BYTES, NUM_CU, 256usize),
        ("64KB SRAM", SRAM_BYTES / 2, NUM_CU, 256),
        ("256KB SRAM", SRAM_BYTES * 2, NUM_CU, 256),
        ("32 CUs", SRAM_BYTES, 32, 256),
        ("8 CUs", SRAM_BYTES, 8, 256),
        ("512-px rows", SRAM_BYTES, NUM_CU, 512),
    ] {
        let r = m.report_for(sram, ncu, row);
        t.row(&[
            label.into(),
            format!("{:.3}", r.sram_mm2),
            format!("{:.3}", r.cu_array_mm2),
            format!("{:.3}", r.colbuf_mm2),
            format!("{:.3}", r.total_mm2()),
            format!("{:.0}%", r.shares().0 * 100.0),
        ]);
    }
    t.print();
    let mut report = JsonReport::new("fig7");
    report
        .text("bench", "fig7_area")
        .num("core_mm2", rpt.total_mm2())
        .num("sram_share", s)
        .num("cu_share", c)
        .num("colbuf_share", b)
        .num("gate_count_m", m.gate_count(&rpt) / 1e6);
    report.write().expect("write BENCH_fig7.json");
    println!(
        "\nTakeaway (paper Fig. 7): memory dominates — even at 128 KB the buffer bank \
         is ~57% of the core, which is why §5's decomposition (not more SRAM) is the \
         scaling story."
    );
}
