//! Regenerates **Table 2** (performance summary): the V/f surface with
//! peak throughput, power and energy efficiency, plus measured
//! *effective* numbers for AlexNet and facenet at both corners.
//!
//! `cargo bench --bench bench_table2_perf`

use kn_stream::compiler::NetRunner;
use kn_stream::energy::{AreaModel, EnergyModel, OperatingPoint};
use kn_stream::model::{zoo, Tensor};
use kn_stream::planner::PlanPolicy;
use kn_stream::util::bench::{JsonReport, Table};
use kn_stream::util::json::{num, obj, s};

fn main() {
    let energy = EnergyModel::default();
    let area = AreaModel::default();
    let rpt = area.paper_config();

    // ---- the fixed rows of Table 2 ----------------------------------------
    println!("Technology        : 65 nm CMOS (modeled — see DESIGN.md substitution)");
    println!("Supply voltage    : 0.6 – 1.0 V");
    println!("Clock rate        : 20 – 500 MHz");
    let core = rpt.total_mm2();
    println!("Core area         : {core:.2} mm² (paper: 2.3 mm x 0.8 mm = 1.84 mm²)");
    println!("Gate count        : {:.2} M (paper: 0.3 M)", area.gate_count(&rpt) / 1e6);
    println!("CU engines        : {} ({} PEs each)", kn_stream::NUM_CU, kn_stream::PES_PER_CU);
    println!("On-chip SRAM      : {} KB single-port", kn_stream::SRAM_BYTES / 1024);
    println!("Precision         : 16-bit fixed point");

    // ---- V/f sweep ---------------------------------------------------------
    let mut t = Table::new(
        "Table 2 — peak throughput / power / efficiency across DVFS",
        &["f (MHz)", "VDD (V)", "peak GOPS", "power (mW)", "TOPS/W", "paper"],
    );
    for (f, paper) in [
        (20.0, "7 mW, 5.8 GOPS, 0.8 TOPS/W"),
        (50.0, ""),
        (100.0, ""),
        (200.0, ""),
        (350.0, ""),
        (500.0, "425 mW, 144 GOPS, 0.3 TOPS/W"),
    ] {
        let op = OperatingPoint::for_freq(f);
        t.row(&[
            format!("{f:.0}"),
            format!("{:.2}", op.vdd),
            format!("{:.1}", energy.peak_ops(op) / 1e9),
            format!("{:.1}", energy.peak_power_w(op) * 1e3),
            format!("{:.2}", energy.peak_tops_per_w(op)),
            paper.into(),
        ]);
    }
    t.print();

    // ---- measured effective numbers on real workloads ----------------------
    let mut t = Table::new(
        "Measured (simulated) effective performance per workload",
        &["net", "corner", "cycles/frame", "latency", "fps", "eff GOPS", "util",
          "lane util", "mJ/frame"],
    );
    let mut report = JsonReport::new("table2");
    report.text("bench", "table2_perf");
    for name in ["facenet", "alexnet", "mobilenet"] {
        // mobilenet is a graph net (dw/pw layers, GAP); the planner's
        // dag-aware policy exercises the fused DwPw lowering here.
        let (runner, in_h, in_w, in_c) = if name == "mobilenet" {
            let g = zoo::graph_by_name(name).unwrap();
            let r = NetRunner::from_graph_with_policy(&g, PlanPolicy::DagAware).expect("compile");
            (r, g.in_h, g.in_w, g.in_c)
        } else {
            let net = zoo::by_name(name).unwrap();
            (NetRunner::new(&net).expect("compile"), net.in_h, net.in_w, net.in_c)
        };
        let frame = Tensor::random_image(5, in_h, in_w, in_c);
        let (_, stats) = runner.run_frame(&frame).expect("run");
        for f in [500.0, 20.0] {
            let op = OperatingPoint::for_freq(f);
            let secs = stats.cycles as f64 * op.cycle_s();
            let e = energy.energy(&stats, op);
            t.row(&[
                name.into(),
                format!("{:.0}MHz", f),
                format!("{}", stats.cycles),
                format!("{:.2} ms", secs * 1e3),
                format!("{:.1}", 1.0 / secs),
                format!("{:.1}", stats.ops() as f64 / secs / 1e9),
                format!("{:.2}", stats.utilization()),
                format!("{:.2}", stats.lane_utilization()),
                format!("{:.2}", e.total_j() * 1e3),
            ]);
            report.push_row(
                "workloads",
                obj(vec![
                    ("net", s(name)),
                    ("freq_mhz", num(f)),
                    ("cycles_per_frame", num(stats.cycles as f64)),
                    ("device_fps", num(1.0 / secs)),
                    ("eff_gops", num(stats.ops() as f64 / secs / 1e9)),
                    ("utilization", num(stats.utilization())),
                    ("lane_utilization", num(stats.lane_utilization())),
                    ("mj_per_frame", num(e.total_j() * 1e3)),
                ]),
            );
        }
    }
    t.print();
    report.write().expect("write BENCH_table2.json");
    println!(
        "\nShape check vs paper: peak 144 GOPS / 5.8 GOPS and 0.3 / 0.8 TOPS/W corners \
         reproduced; effective AlexNet throughput lands at ~40-45% utilization — \
         stride-4 conv1 is SRAM-stream-bound and K=11/K=5 pay 3x3-padding, the costs \
         §5 attributes to decomposition."
    );
}
