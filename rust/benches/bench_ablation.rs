//! Ablations of the design choices DESIGN.md calls out: DMA/compute
//! overlap (double buffering), DRAM bandwidth sensitivity, and DVFS —
//! the knobs behind the paper's "maximize local data reuse within
//! limited bandwidth" claim.
//!
//! `cargo bench --bench bench_ablation`

use kn_stream::compiler::NetRunner;
use kn_stream::model::{zoo, Tensor};
use kn_stream::sim::SimConfig;
use kn_stream::util::bench::Table;

fn run(net_name: &str, cfg: SimConfig) -> kn_stream::sim::SimStats {
    let net = zoo::by_name(net_name).unwrap();
    let runner = NetRunner::with_config(&net, cfg).unwrap();
    let frame = Tensor::random_image(7, net.in_h, net.in_w, net.in_c);
    runner.run_frame(&frame).unwrap().1
}

fn main() {
    // ---- DMA overlap (double buffering) ------------------------------------
    let mut t = Table::new(
        "Ablation: DMA/compute overlap (double buffering)",
        &["net", "overlap", "cycles", "dma stalls", "slowdown"],
    );
    for net in ["facenet", "alexnet"] {
        let on = run(net, SimConfig { overlap_dma: true, ..SimConfig::default() });
        let off = run(net, SimConfig { overlap_dma: false, ..SimConfig::default() });
        for (label, s) in [("yes", &on), ("no (serialized)", &off)] {
            t.row(&[
                net.into(),
                label.into(),
                format!("{}", s.cycles),
                format!("{}", s.dma_stall_cycles),
                format!("{:.2}x", s.cycles as f64 / on.cycles as f64),
            ]);
        }
    }
    t.print();

    // ---- DRAM bandwidth sensitivity ----------------------------------------
    let mut t = Table::new(
        "Ablation: off-chip bandwidth (bytes/cycle) — why reuse matters",
        &["net", "B/cycle", "cycles", "eff GOPS @500MHz", "vs 3.2 B/c"],
    );
    for net in ["facenet", "alexnet"] {
        let base = run(
            net,
            SimConfig { dram_bytes_per_cycle: 3.2, overlap_dma: false, ..SimConfig::default() },
        );
        for bw in [0.8, 1.6, 3.2, 6.4, 12.8] {
            let s = run(
                net,
                SimConfig { dram_bytes_per_cycle: bw, overlap_dma: false, ..SimConfig::default() },
            );
            let gops = s.ops() as f64 / (s.cycles as f64 / 500e6) / 1e9;
            t.row(&[
                net.into(),
                format!("{bw}"),
                format!("{}", s.cycles),
                format!("{gops:.1}"),
                format!("{:.2}x", s.cycles as f64 / base.cycles as f64),
            ]);
        }
    }
    t.print();
    println!(
        "\nTakeaway: with overlap on, the decomposition schedule hides nearly all DMA \
         behind compute (stall column); serialized DMA shows the raw bandwidth \
         sensitivity the on-chip reuse exists to suppress."
    );
}
