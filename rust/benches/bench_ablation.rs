//! Ablations of the design choices DESIGN.md calls out: DMA/compute
//! overlap (double buffering), DRAM bandwidth sensitivity, and DVFS —
//! the knobs behind the paper's "maximize local data reuse within
//! limited bandwidth" claim.
//!
//! `cargo bench --bench bench_ablation`

use kn_stream::compiler::NetRunner;
use kn_stream::model::{zoo, Tensor};
use kn_stream::sim::SimConfig;
use kn_stream::util::bench::{fmt_dur, JsonReport, Table};
use kn_stream::util::json::{num, obj, s};

fn run(net_name: &str, cfg: SimConfig) -> kn_stream::sim::SimStats {
    let net = zoo::by_name(net_name).unwrap();
    let runner = NetRunner::with_config(&net, cfg).unwrap();
    let frame = Tensor::random_image(7, net.in_h, net.in_w, net.in_c);
    runner.run_frame(&frame).unwrap().1
}

fn main() {
    let mut report = JsonReport::new("ablation");
    report.text("bench", "ablation");
    // ---- DMA overlap (double buffering) ------------------------------------
    let mut t = Table::new(
        "Ablation: DMA/compute overlap (double buffering)",
        &["net", "overlap", "cycles", "dma stalls", "slowdown"],
    );
    for net in ["facenet", "alexnet"] {
        let on = run(net, SimConfig { overlap_dma: true, ..SimConfig::default() });
        let off = run(net, SimConfig { overlap_dma: false, ..SimConfig::default() });
        for (label, s) in [("yes", &on), ("no (serialized)", &off)] {
            t.row(&[
                net.into(),
                label.into(),
                format!("{}", s.cycles),
                format!("{}", s.dma_stall_cycles),
                format!("{:.2}x", s.cycles as f64 / on.cycles as f64),
            ]);
        }
    }
    t.print();

    // ---- DRAM bandwidth sensitivity ----------------------------------------
    let mut t = Table::new(
        "Ablation: off-chip bandwidth (bytes/cycle) — why reuse matters",
        &["net", "B/cycle", "cycles", "eff GOPS @500MHz", "vs 3.2 B/c"],
    );
    for net in ["facenet", "alexnet"] {
        let base = run(
            net,
            SimConfig { dram_bytes_per_cycle: 3.2, overlap_dma: false, ..SimConfig::default() },
        );
        for bw in [0.8, 1.6, 3.2, 6.4, 12.8] {
            let s = run(
                net,
                SimConfig { dram_bytes_per_cycle: bw, overlap_dma: false, ..SimConfig::default() },
            );
            let gops = s.ops() as f64 / (s.cycles as f64 / 500e6) / 1e9;
            t.row(&[
                net.into(),
                format!("{bw}"),
                format!("{}", s.cycles),
                format!("{gops:.1}"),
                format!("{:.2}x", s.cycles as f64 / base.cycles as f64),
            ]);
        }
    }
    t.print();

    // ---- host segment-DAG parallelism (run_frame_parallel) -----------------
    let mut t = Table::new(
        "Ablation: host-side segment-DAG execution (bit-identical output/stats)",
        &["net", "tile threads", "wall/frame", "speedup"],
    );
    for net_name in ["facenet", "alexnet", "edgenet", "widenet"] {
        let net = zoo::graph_by_name(net_name).unwrap();
        let runner = NetRunner::from_graph(&net).unwrap();
        let frame = Tensor::random_image(7, net.in_h, net.in_w, net.in_c);
        let mut base = None;
        for workers in [1usize, 2, 4, 8] {
            // warm the pools, then take best-of-3
            let _ = runner.run_frame_parallel(&frame, workers).unwrap();
            let mut best = std::time::Duration::MAX;
            for _ in 0..3 {
                let t0 = std::time::Instant::now();
                let _ = runner.run_frame_parallel(&frame, workers).unwrap();
                best = best.min(t0.elapsed());
            }
            let base_s = *base.get_or_insert(best.as_secs_f64());
            t.row(&[
                net_name.into(),
                format!("{workers}"),
                fmt_dur(best),
                format!("{:.2}x", base_s / best.as_secs_f64()),
            ]);
            report.push_row(
                "tile_parallel",
                obj(vec![
                    ("net", s(net_name)),
                    ("tile_workers", num(workers as f64)),
                    ("wall_ns", num(best.as_nanos() as f64)),
                    ("speedup", num(base_s / best.as_secs_f64())),
                ]),
            );
        }
    }
    t.print();
    report.write().expect("write BENCH_ablation.json");
    println!(
        "\nTakeaway: with overlap on, the decomposition schedule hides nearly all DMA \
         behind compute (stall column); serialized DMA shows the raw bandwidth \
         sensitivity the on-chip reuse exists to suppress. Host tile threads speed \
         up the wall clock without touching device-side numbers."
    );
}
