//! End-to-end serving bench (the Fig. 8 system): coordinator + simulated
//! accelerator streaming synthetic camera frames, vs the PJRT CPU
//! baseline executing the same AOT artifact.
//!
//! `cargo bench --bench bench_e2e_serving`

use std::sync::Arc;

use kn_stream::coordinator::{AdmissionMode, AdmissionPolicy, Coordinator, CoordinatorConfig};
use kn_stream::energy::{dvfs, EnergyModel, OperatingPoint};
use kn_stream::model::{zoo, Tensor};
use kn_stream::obs::Obs;
use kn_stream::runtime::Golden;
use kn_stream::util::bench::{bench_once, JsonReport, Table};
use kn_stream::util::json::{num, obj, s};

fn main() {
    let energy = EnergyModel::default();
    let frames_n = 32;
    let mut report = JsonReport::new("e2e");
    report.text("bench", "e2e_serving").num("frames_per_config", frames_n as f64);

    let mut t = Table::new(
        "End-to-end serving (coordinator + simulated accelerator)",
        &["net", "f (MHz)", "workers", "tile thr", "device fps", "p50", "p99",
          "mJ/frame", "host sim fps"],
    );
    for net_name in ["quicknet", "facenet", "edgenet", "widenet", "mobilenet"] {
        let net = zoo::graph_by_name(net_name).unwrap();
        // (freq, chip workers, host tile threads per frame)
        for (freq, workers, tile_workers) in
            [(500.0, 1usize, 1usize), (20.0, 1, 1), (500.0, 4, 1), (500.0, 1, 4)]
        {
            let op = OperatingPoint::for_freq(freq);
            let coord = Coordinator::start_graph(
                &net,
                CoordinatorConfig {
                    workers,
                    queue_depth: 4,
                    tile_workers,
                    op,
                    ..Default::default()
                },
            )
            .unwrap();
            let frames: Vec<Tensor> = (0..frames_n)
                .map(|i| Tensor::random_image(i as u32, net.in_h, net.in_w, net.in_c))
                .collect();
            let m = coord.run_stream(frames).expect("coordinator running");
            let e = energy.energy(&m.totals, op);
            t.row(&[
                net_name.into(),
                format!("{freq:.0}"),
                format!("{workers}"),
                format!("{tile_workers}"),
                format!("{:.1}", m.device_fps() * workers as f64),
                format!("{:.2}ms", m.dev_lat_us.quantile(0.5) / 1e3),
                format!("{:.2}ms", m.dev_lat_us.quantile(0.99) / 1e3),
                format!("{:.3}", e.total_j() / m.frames as f64 * 1e3),
                format!("{:.1}", m.wall_fps()),
            ]);
            report.push_row(
                "configs",
                obj(vec![
                    ("net", s(net_name)),
                    ("freq_mhz", num(freq)),
                    ("workers", num(workers as f64)),
                    ("tile_workers", num(tile_workers as f64)),
                    ("device_fps", num(m.device_fps() * workers as f64)),
                    ("frames_per_sec", num(m.wall_fps())),
                    ("gops_device", num(m.device_ops_per_s() / 1e9)),
                    ("p99_device_ms", num(m.dev_lat_us.quantile(0.99) / 1e3)),
                    ("mj_per_frame", num(e.total_j() / m.frames as f64 * 1e3)),
                ]),
            );
            coord.stop();
        }
    }
    t.print();

    // ---- Mixed-traffic registry: one worker pool, heterogeneous nets ------
    // The paper's target deployment: several smart-vision workloads
    // sharing one accelerator. 4:2:1 mix over three different
    // topologies (residual / branch+concat / linear), pooled simulators
    // shared across runners, admission policy on (generous budget —
    // the interesting number here is throughput under mixing).
    let nets = zoo::graphs_by_names("edgenet,widenet,facenet").unwrap();
    let mixed_n = 56usize;
    let tagged = zoo::mix_stream(&nets, &[4, 2, 1], mixed_n);
    let op = OperatingPoint::for_freq(500.0);
    let coord = Coordinator::start_registry(
        nets,
        CoordinatorConfig {
            workers: 4,
            queue_depth: 8,
            tile_workers: 1,
            op,
            admission: AdmissionPolicy {
                max_dram_bytes: 64 << 20,
                mode: AdmissionMode::Block,
            },
            ..Default::default()
        },
    )
    .unwrap();
    let rep = coord.run_mix(tagged).expect("coordinator running");
    let mut mt = Table::new(
        "Mixed traffic: 3-net registry, shared 4-worker pool (mix 4:2:1)",
        &["net", "frames", "errors", "device fps", "p99", "q-wait mean", "host share fps"],
    );
    for (name, nm) in &rep.per_net {
        mt.row(&[
            name.to_string(),
            format!("{}", nm.frames),
            format!("{}", nm.errors),
            format!("{:.1}", nm.device_fps()),
            format!("{:.2}ms", nm.dev_lat_us.quantile(0.99) / 1e3),
            format!("{:.0}µs", nm.queue_wait_us.mean()),
            format!("{:.1}", nm.wall_fps()),
        ]);
        report.push_row(
            "mixed",
            obj(vec![
                ("net", s(name)),
                ("frames", num(nm.frames as f64)),
                ("errors", num(nm.errors as f64)),
                ("device_fps", num(nm.device_fps())),
                ("p99_device_ms", num(nm.dev_lat_us.quantile(0.99) / 1e3)),
                ("queue_wait_mean_us", num(nm.queue_wait_us.mean())),
                ("queue_wait_max_us", num(nm.queue_wait_us.max())),
            ]),
        );
    }
    mt.print();
    report
        .num("mixed_frames_total", rep.aggregate.frames as f64)
        .num("mixed_errors_total", rep.aggregate.errors as f64)
        .num("mixed_wall_fps", rep.aggregate.wall_fps())
        .num("mixed_queue_wait_mean_us", rep.aggregate.queue_wait_us.mean());
    assert_eq!(
        rep.accounted(),
        mixed_n as u64,
        "every mixed-traffic frame must be accounted"
    );
    coord.stop();

    // ---- Cross-frame pipelining: depth sweep (latency vs throughput) -----
    // One worker, 4 tile threads, rolling window of `depth` frames: the
    // frame-boundary idle gap the per-frame DAG left on the tile
    // workers closes as depth grows, so host throughput (wall fps)
    // rises while per-frame wall latency rises with it (a frame shares
    // its tile workers with its window). Per-frame outputs and
    // SimStats are bit-identical at every depth (the pipeline test
    // battery proves it); this sweep records the latency/throughput
    // trade the knob buys.
    let net = zoo::graph_by_name("facenet").unwrap();
    let mut pt = Table::new(
        "Cross-frame pipelining depth sweep (facenet, 1 worker, 4 tile threads)",
        &["depth", "host fps", "wall p50", "wall p99", "window mean/max", "q-wait mean"],
    );
    for depth in [1usize, 2, 4] {
        let coord = Coordinator::start_graph(
            &net,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 8,
                tile_workers: 4,
                pipeline_depth: depth,
                op: OperatingPoint::for_freq(500.0),
                ..Default::default()
            },
        )
        .unwrap();
        let frames: Vec<Tensor> = (0..frames_n)
            .map(|i| Tensor::random_image(i as u32, net.in_h, net.in_w, net.in_c))
            .collect();
        let m = coord.run_stream(frames).expect("coordinator running");
        assert_eq!(m.frames + m.errors, frames_n as u64, "depth {depth}: all accounted");
        pt.row(&[
            format!("{depth}"),
            format!("{:.1}", m.wall_fps()),
            format!("{:.2}ms", m.wall_lat_us.quantile(0.5) / 1e3),
            format!("{:.2}ms", m.wall_lat_us.quantile(0.99) / 1e3),
            format!("{:.1}/{:.0}", m.window.mean(), m.window.max()),
            format!("{:.0}µs", m.queue_wait_us.mean()),
        ]);
        report.push_row(
            "pipeline",
            obj(vec![
                ("net", s("facenet")),
                ("depth", num(depth as f64)),
                ("wall_fps", num(m.wall_fps())),
                ("wall_p50_ms", num(m.wall_lat_us.quantile(0.5) / 1e3)),
                ("wall_p99_ms", num(m.wall_lat_us.quantile(0.99) / 1e3)),
                ("window_mean", num(m.window.mean())),
                ("window_max", num(m.window.max())),
                ("frames", num(m.frames as f64)),
                ("errors", num(m.errors as f64)),
            ]),
        );
        coord.stop();
    }
    pt.print();

    // ---- Chip-sharded fleet: data-parallel scaling sweep ------------------
    // N chip fault domains, each a private pool + queue + worker,
    // frames routed least-loaded. Host throughput should scale with
    // chips until the submitter becomes the bottleneck; outputs stay
    // bit-exact per the fault battery, so the interesting numbers are
    // wall fps and the accounting columns staying clean.
    let net = zoo::graph_by_name("edgenet").unwrap();
    let mut ct = Table::new(
        "Chip-sharded serving sweep (edgenet, 1 worker/chip)",
        &["chips", "host fps", "device fps/chip", "frames", "errors", "retries"],
    );
    for chips in [1usize, 2, 4, 8] {
        let coord = Coordinator::start_graph(
            &net,
            CoordinatorConfig {
                workers: 1,
                chips,
                queue_depth: 4,
                op: OperatingPoint::for_freq(500.0),
                ..Default::default()
            },
        )
        .unwrap();
        let frames: Vec<Tensor> = (0..frames_n)
            .map(|i| Tensor::random_image(i as u32, net.in_h, net.in_w, net.in_c))
            .collect();
        let m = coord.run_stream(frames).expect("coordinator running");
        assert_eq!(m.frames + m.errors, frames_n as u64, "chips {chips}: all accounted");
        ct.row(&[
            format!("{chips}"),
            format!("{:.1}", m.wall_fps()),
            format!("{:.1}", m.device_fps()),
            format!("{}", m.frames),
            format!("{}", m.errors),
            format!("{}", m.retries),
        ]);
        report.push_row(
            "chips",
            obj(vec![
                ("net", s("edgenet")),
                ("chips", num(chips as f64)),
                ("wall_fps", num(m.wall_fps())),
                ("device_fps", num(m.device_fps())),
                ("frames", num(m.frames as f64)),
                ("errors", num(m.errors as f64)),
                ("retries", num(m.retries as f64)),
            ]),
        );
        coord.stop();
    }
    ct.print();

    // ---- Chip-kill recovery: throughput before / during / after ----------
    // One 4-chip coordinator serving three consecutive batches; chip 1
    // is killed between batch 1 and 2. The fleet must keep serving on
    // 3 chips (shrunken but nonzero fps, zero errors), and the plan-
    // driven run records the failovers the mid-stream death forced.
    let mut kt = Table::new(
        "Chip-kill recovery (edgenet, 4 chips, kill chip 1 after batch 1)",
        &["phase", "chips alive", "host fps", "frames", "errors", "failovers"],
    );
    let coord = Coordinator::start_graph(
        &net,
        CoordinatorConfig {
            workers: 1,
            chips: 4,
            queue_depth: 4,
            op: OperatingPoint::for_freq(500.0),
            ..Default::default()
        },
    )
    .unwrap();
    for (phase, kill_before) in [("before", false), ("during", true), ("after", false)] {
        if kill_before {
            coord.kill_chip(1).expect("fleet running");
        }
        let frames: Vec<Tensor> = (0..frames_n)
            .map(|i| Tensor::random_image(i as u32, net.in_h, net.in_w, net.in_c))
            .collect();
        let m = coord.run_stream(frames).expect("fleet keeps serving");
        let alive = coord.chip_health().iter().filter(|h| !h.is_dead()).count();
        assert_eq!(m.frames + m.errors, frames_n as u64, "{phase}: all accounted");
        kt.row(&[
            phase.into(),
            format!("{alive}"),
            format!("{:.1}", m.wall_fps()),
            format!("{}", m.frames),
            format!("{}", m.errors),
            format!("{}", m.failovers),
        ]);
        report.push_row(
            "chip_kill",
            obj(vec![
                ("phase", s(phase)),
                ("chips_alive", num(alive as f64)),
                ("wall_fps", num(m.wall_fps())),
                ("frames", num(m.frames as f64)),
                ("errors", num(m.errors as f64)),
                ("failovers", num(m.failovers as f64)),
            ]),
        );
    }
    coord.stop();
    // plan-driven mid-stream death: chip 0 dies at its 4th dequeue
    let coord = Coordinator::start_graph(
        &net,
        CoordinatorConfig {
            workers: 1,
            chips: 4,
            queue_depth: 4,
            op: OperatingPoint::for_freq(500.0),
            fault_plan: kn_stream::coordinator::FaultPlan::none()
                .with(0, 3, kn_stream::coordinator::FaultKind::ChipDeath),
            ..Default::default()
        },
    )
    .unwrap();
    let frames: Vec<Tensor> = (0..frames_n)
        .map(|i| Tensor::random_image(i as u32, net.in_h, net.in_w, net.in_c))
        .collect();
    let m = coord.run_stream(frames).expect("fleet keeps serving");
    assert_eq!(m.frames + m.errors, frames_n as u64, "planned death: all accounted");
    kt.row(&[
        "planned-death".into(),
        format!("{}", coord.chip_health().iter().filter(|h| !h.is_dead()).count()),
        format!("{:.1}", m.wall_fps()),
        format!("{}", m.frames),
        format!("{}", m.errors),
        format!("{}", m.failovers),
    ]);
    report.push_row(
        "chip_kill",
        obj(vec![
            ("phase", s("planned-death")),
            ("chips_alive", num(3.0)),
            ("wall_fps", num(m.wall_fps())),
            ("frames", num(m.frames as f64)),
            ("errors", num(m.errors as f64)),
            ("failovers", num(m.failovers as f64)),
        ]),
    );
    coord.stop();
    kt.print();

    // ---- Tracing overhead: off vs on, same seed, bit-exact outputs -------
    // The observability contract: span tracing must not change a single
    // output bit or stats counter, and its wall-clock cost must stay
    // small (the hot path adds two timestamped pushes per segment).
    let run_pass = |obs: Arc<Obs>| {
        let coord = Coordinator::start_graph(
            &net,
            CoordinatorConfig {
                workers: 1,
                queue_depth: 8,
                tile_workers: 2,
                pipeline_depth: 2,
                op: OperatingPoint::for_freq(500.0),
                obs,
                ..Default::default()
            },
        )
        .unwrap();
        let frames: Vec<Tensor> = (0..frames_n)
            .map(|i| Tensor::random_image(i as u32, net.in_h, net.in_w, net.in_c))
            .collect();
        let t0 = std::time::Instant::now();
        let pendings: Vec<_> = frames.iter().map(|f| coord.submit(f.clone()).unwrap()).collect();
        let outs: Vec<_> = pendings
            .into_iter()
            .map(|p| p.recv().expect("delivered").ok().expect("served"))
            .collect();
        let wall = t0.elapsed().as_secs_f64();
        coord.stop();
        (wall, outs)
    };
    let obs = Obs::with(true, true);
    let (wall_off, outs_off) = run_pass(Obs::none());
    let (wall_on, outs_on) = run_pass(obs.clone());
    for (i, (a, b)) in outs_off.iter().zip(&outs_on).enumerate() {
        assert_eq!(a.output, b.output, "frame {i}: tracing must not change outputs");
        assert_eq!(a.stats, b.stats, "frame {i}: tracing must not change stats");
    }
    let spans = obs.trace.as_ref().unwrap().spans().len();
    assert!(spans > 0, "traced pass recorded spans");
    let overhead = wall_on / wall_off;
    assert!(overhead < 10.0, "tracing overhead {overhead:.2}x is out of hand");
    let mut ot = Table::new(
        "Tracing overhead (edgenet, off vs on, same seed, outputs bit-exact)",
        &["tracing", "wall s", "host fps", "spans", "overhead"],
    );
    ot.row(&[
        "off".into(),
        format!("{wall_off:.3}"),
        format!("{:.1}", frames_n as f64 / wall_off),
        "0".into(),
        "1.00x".into(),
    ]);
    ot.row(&[
        "on".into(),
        format!("{wall_on:.3}"),
        format!("{:.1}", frames_n as f64 / wall_on),
        format!("{spans}"),
        format!("{overhead:.2}x"),
    ]);
    ot.print();
    for (mode, wall, nspans) in [("off", wall_off, 0usize), ("on", wall_on, spans)] {
        report.push_row(
            "trace_overhead",
            obj(vec![
                ("net", s("edgenet")),
                ("tracing", s(mode)),
                ("wall_s", num(wall)),
                ("wall_fps", num(frames_n as f64 / wall)),
                ("spans", num(nspans as f64)),
                ("overhead_x", num(wall / wall_off)),
                ("bit_exact", num(1.0)),
            ]),
        );
    }

    report.write().expect("write BENCH_e2e.json");

    // ---- PJRT CPU baseline (the "reference platform") -----------------------
    match Golden::load_default() {
        Ok(mut golden) => {
            let mut t = Table::new(
                "Baseline: same AOT artifact on the PJRT CPU client",
                &["artifact", "first run (compile+exec)", "steady-state", "vs device @500MHz"],
            );
            for (art, net_name) in [("facenet_fwd", "facenet"), ("alexnet_fwd", "alexnet")] {
                let net = zoo::by_name(net_name).unwrap();
                let frame = Tensor::random_image(3, net.in_h, net.in_w, net.in_c);
                let cold = bench_once(art, || golden.run(art, &frame).unwrap());
                // steady state: average of 5
                let t0 = std::time::Instant::now();
                for _ in 0..5 {
                    let _ = golden.run(art, &frame).unwrap();
                }
                let steady = t0.elapsed() / 5;
                // device time at 500 MHz from one sim run
                let runner = kn_stream::compiler::NetRunner::new(&net).unwrap();
                let (_, stats) = runner.run_frame(&frame).unwrap();
                let dev = stats.cycles as f64 * dvfs::PEAK.cycle_s();
                t.row(&[
                    art.into(),
                    format!("{:.1} ms", cold.mean.as_secs_f64() * 1e3),
                    format!("{:.2} ms", steady.as_secs_f64() * 1e3),
                    format!("{:.2}x device time", steady.as_secs_f64() / dev),
                ]);
            }
            t.print();
            println!(
                "\nNote: the PJRT row is a *numerical* baseline (same bits), not a fair \
                 perf baseline — it runs on a desktop-class CPU, the device model is a \
                 7..425 mW accelerator."
            );
        }
        Err(e) => println!("PJRT baseline skipped: {e}"),
    }
}
