//! Static schedule analysis: an ISA linter and segment-DAG race
//! detector over compiled command streams.
//!
//! The compiler *promises* a long list of invariants — every DMA stays
//! inside the allocated DRAM image, every SRAM access fits the 128 KB
//! bank, stores land only in canvas valid regions (the zero apron that
//! implements conv padding must stay zero), loads never read canvas
//! bytes no store produced, `PASS_DW` field encodings match the staging
//! planes they address, and the segment dependency DAG covers every
//! cross-segment data hazard. Codegen asserts some of this where it is
//! authored, with `debug_assert!`s that vanish in release builds.
//!
//! This module re-derives all of it **from the artifact**: it decodes
//! the encoded word stream back to commands (flagging encode/decode
//! drift), interprets each segment symbolically over DRAM/SRAM address
//! intervals, and recomputes every pairwise read/write intersection
//! between segments — independently of codegen's region bookkeeping —
//! checking each RAW/WAR/WAW conflict against reachability in the
//! declared DAG. Anything off-contract becomes a typed [`Diagnostic`]
//! naming the defect class, the segment, and the offending commands.
//!
//! The independence is the point: the analyzer shares *constants* with
//! the compiler (canvas layout geometry, `SRAM_BYTES`, `ACC_TILE_PX`)
//! but none of its region/dep code, so a bug in either side surfaces as
//! a disagreement instead of being trusted twice. The mutation harness
//! in `tests/integration_analysis.rs` seeds one defect per class and
//! asserts the analyzer kills all of them.

use std::collections::VecDeque;

use crate::compiler::CompiledNet;
use crate::isa::{Cmd, ConvCfg, DmaDesc, PASS_DW, PASS_FIRST, PASS_LAST};
use crate::model::graph::{Graph, NodeOp, NodeRef};
use crate::sim::accbuf::ACC_TILE_PX;
use crate::sim::dma::SegClock;
use crate::sim::fastconv::{dw_scan_timing, scan_timing};
use crate::sim::sram::WORD_PX;
use crate::{NUM_CU, PES_PER_CU, SRAM_BYTES};

/// SRAM capacity in pixels (1 px = 2 bytes).
const SRAM_CAP_PX: u64 = (SRAM_BYTES / 2) as u64;

/// Flavor of a cross-segment data conflict.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HazardKind {
    /// Read-after-write: the later segment reads what the earlier wrote.
    Raw,
    /// Write-after-read: the later segment overwrites what the earlier reads.
    War,
    /// Write-after-write: both segments write the same bytes.
    Waw,
}

impl HazardKind {
    pub fn name(self) -> &'static str {
        match self {
            HazardKind::Raw => "RAW",
            HazardKind::War => "WAR",
            HazardKind::Waw => "WAW",
        }
    }
}

/// Defect classes the analyzer reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiagKind {
    /// Encoded words fail to decode, or decode to different commands
    /// than the in-memory program (encode/decode drift).
    DecodeDrift,
    /// A command touches SRAM beyond the configured capacity.
    SramOob,
    /// A compute pass's output overlaps its own input region, two
    /// operands of a pass alias, or compute output lands on the
    /// segment's DMA-staged input allocation.
    SramOverlap,
    /// The segment's touched SRAM high-water mark exceeds capacity.
    SramFootprint,
    /// Weight shadow-bank misuse: `Conv` with nothing staged, staging
    /// past depth 2, a stale block left at segment end, or a staged
    /// block whose length mismatches the pass that consumes it.
    WeightStage,
    /// A DMA access falls outside the allocated DRAM image.
    DramOob,
    /// A store lands outside every canvas valid region: in the zero
    /// apron/margin, the input canvas, or the weight/bias blocks.
    BadStore,
    /// A load reads valid canvas bytes that no store ever writes.
    UninitRead,
    /// `PASS_DW`/lane field inconsistency: `mn` or depthwise `cn`
    /// outside `1..=16`, or `dpp`/`dpl` smaller than the plane extents
    /// the pass writes.
    DwField,
    /// Conv/pool geometry violates the datapath contract: output tile
    /// past the ACC BUF partial plane, tap window outside the input
    /// tile, a conv pass with no `SetConv` in effect, stride 0.
    ConvShape,
    /// Segment bookkeeping broken: ranges overlap or escape the
    /// program, a segment does not end on its `Sync` barrier, or
    /// non-prologue commands sit between segments.
    SegmentForm,
    /// A dependency edge points at the segment itself or forward:
    /// the declared segment order is not topological.
    NonTopological,
    /// A cross-segment hazard with no covering dependency path.
    UncoveredHazard(HazardKind),
    /// The planner's predicted per-node cycle table disagrees with the
    /// exact cycle count replayed from the decoded command stream
    /// ([`lint_timing`]) — the timing claims drifted from the artifact.
    TimingDrift,
}

/// One analyzer finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub kind: DiagKind,
    /// Segment the finding is anchored to (`None` = whole-program).
    pub segment: Option<usize>,
    /// Offending command indices into the analyzed program.
    pub cmds: Vec<usize>,
    pub detail: String,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{:?}]", self.kind)?;
        if let Some(s) = self.segment {
            write!(f, " seg {s}")?;
        }
        if !self.cmds.is_empty() {
            write!(f, " cmd {:?}", self.cmds)?;
        }
        write!(f, ": {}", self.detail)
    }
}

/// Analyzer verdict over one compiled net.
#[derive(Debug, Default)]
pub struct Analysis {
    pub diagnostics: Vec<Diagnostic>,
    /// Cross-segment interval conflicts the race detector examined
    /// (covered hazards included) — a coverage meter, not a defect
    /// count.
    pub hazards_checked: u64,
    pub segments: usize,
}

impl Analysis {
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// All diagnostics, one per line.
    pub fn report(&self) -> String {
        self.diagnostics.iter().map(|d| format!("  {d}\n")).collect()
    }

    pub fn has_kind(&self, kind: DiagKind) -> bool {
        self.diagnostics.iter().any(|d| d.kind == kind)
    }
}

/// Analyze a compiled net end to end: encodes the program to its wire
/// form and lints the words (so encode/decode drift is always checked).
pub fn analyze(net: &CompiledNet) -> anyhow::Result<Analysis> {
    analyze_words(net, &Cmd::encode_program(&net.program))
}

/// Analyze a compiled net against an explicit word stream (the form a
/// command FIFO would consume). Errors only on analysis-infrastructure
/// failure (an invalid graph); schedule defects come back as
/// diagnostics.
pub fn analyze_words(net: &CompiledNet, words: &[u16]) -> anyhow::Result<Analysis> {
    let mut diags: Vec<Diagnostic> = Vec::new();

    // ---- 1. decode the wire form; flag drift against the in-memory program
    let prog: Vec<Cmd> = match Cmd::decode_program(words) {
        Ok(decoded) => {
            if decoded != net.program {
                let at = decoded
                    .iter()
                    .zip(&net.program)
                    .position(|(a, b)| a != b)
                    .unwrap_or_else(|| decoded.len().min(net.program.len()));
                diags.push(Diagnostic {
                    kind: DiagKind::DecodeDrift,
                    segment: None,
                    cmds: vec![at],
                    detail: format!(
                        "decoded program diverges from the in-memory program at command {at}: \
                         {:?} vs {:?} ({} vs {} commands)",
                        decoded.get(at),
                        net.program.get(at),
                        decoded.len(),
                        net.program.len()
                    ),
                });
            }
            decoded
        }
        Err(e) => {
            diags.push(Diagnostic {
                kind: DiagKind::DecodeDrift,
                segment: None,
                cmds: vec![e.cmd],
                detail: format!("word stream does not decode: {e}"),
            });
            // Fall back to the in-memory program so the remaining
            // checks still run.
            net.program.clone()
        }
    };

    // ---- 2. re-derive the DRAM canvas layout from the graph alone
    let canvases = canvas_layouts(&net.graph)?;
    let weights_base = canvases.last().map_or(0, |cv| (cv.base + cv.len_px()) as u64);
    let dram_px = net.dram_px as u64;

    check_segment_form(net, &prog, &mut diags);

    // ---- 3. per-segment symbolic interpretation
    let mut seg_access: Vec<SegAccess> = Vec::with_capacity(net.segments.len());
    let mut canvas_loads: Vec<(usize, usize, Vec<Iv>)> = Vec::new();
    for (si, seg) in net.segments.iter().enumerate() {
        seg_access.push(analyze_segment(
            si,
            seg,
            &prog,
            &canvases,
            weights_base,
            dram_px,
            &mut canvas_loads,
            &mut diags,
        ));
    }

    // ---- 4. uninitialized-read detection (halo-aware)
    let all_writes = merge_ivs(seg_access.iter().flat_map(|a| a.dram_w.iter().copied()).collect());
    check_uninit_reads(&canvas_loads, &canvases, &all_writes, &mut diags);

    // ---- 5. race detection over the segment DAG
    let hazards_checked = check_races(net, &prog, &seg_access, &mut diags);

    Ok(Analysis { diagnostics: diags, hazards_checked, segments: net.segments.len() })
}

// ---------------------------------------------------------------------------
// interval arithmetic (half-open pixel ranges)

/// Half-open pixel interval `[start, end)`.
type Iv = (u64, u64);

/// Sort and coalesce (touching intervals merge; empties drop).
fn merge_ivs(mut v: Vec<Iv>) -> Vec<Iv> {
    v.retain(|iv| iv.0 < iv.1);
    v.sort_unstable();
    let mut out: Vec<Iv> = Vec::with_capacity(v.len());
    for iv in v {
        match out.last_mut() {
            Some(last) if iv.0 <= last.1 => last.1 = last.1.max(iv.1),
            _ => out.push(iv),
        }
    }
    out
}

/// First overlap between two merged interval sets, if any.
fn sets_overlap(a: &[Iv], b: &[Iv]) -> Option<Iv> {
    let (first_a, last_a) = (a.first()?, a.last()?);
    let (first_b, last_b) = (b.first()?, b.last()?);
    if last_a.1 <= first_b.0 || last_b.1 <= first_a.0 {
        return None; // disjoint bounding boxes — the common case
    }
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        let lo = a[i].0.max(b[j].0);
        let hi = a[i].1.min(b[j].1);
        if lo < hi {
            return Some((lo, hi));
        }
        if a[i].1 <= b[j].1 {
            i += 1;
        } else {
            j += 1;
        }
    }
    None
}

/// First pixel of `iv` not covered by the merged set, if any.
fn first_uncovered(iv: Iv, set: &[Iv]) -> Option<u64> {
    let mut at = iv.0;
    // First interval that could cover `at`.
    let mut idx = set.partition_point(|s| s.1 <= at);
    while at < iv.1 {
        match set.get(idx) {
            Some(&(lo, hi)) if lo <= at => {
                at = hi;
                idx += 1;
            }
            _ => return Some(at),
        }
    }
    None
}

// ---------------------------------------------------------------------------
// canvas layout re-derivation (independent of codegen's `Canvas`)

/// One DRAM activation canvas: planar (c, ch, cw) with `pad` zero
/// border top/left and a `margin` extension bottom/right; the valid
/// region of channel `k` is rows `pad..pad+h` × cols `pad..pad+w`.
struct CanvasLayout {
    base: usize,
    h: usize,
    w: usize,
    c: usize,
    pad: usize,
    ch: usize,
    cw: usize,
}

impl CanvasLayout {
    fn len_px(&self) -> usize {
        self.c * self.ch * self.cw
    }
}

/// Recompute the canvas layout the compiler promises: per-canvas pad is
/// the largest consumer conv pad, the margin absorbs kernel-
/// decomposition overshoot (`Kp − K`), and bases are allocated
/// sequentially from DRAM 0 in canvas order (input first, then one
/// canvas per node).
fn canvas_layouts(graph: &Graph) -> anyhow::Result<Vec<CanvasLayout>> {
    let shapes = graph.validate()?;
    let n_canvas = graph.nodes.len() + 1;
    let mut pad = vec![0usize; n_canvas];
    let mut need = vec![0usize; n_canvas];
    for node in &graph.nodes {
        if let NodeOp::Conv(c) = &node.op {
            let kp = 3 * c.k.div_ceil(3);
            let j = canvas_of(node.inputs[0]);
            pad[j] = pad[j].max(c.pad);
            need[j] = need[j].max(c.pad + kp - c.k);
        }
    }
    let mut out = Vec::with_capacity(n_canvas);
    let mut base = 0usize;
    for (j, (pad, need)) in pad.into_iter().zip(need).enumerate() {
        let r = if j == 0 { NodeRef::Input } else { NodeRef::Node(j - 1) };
        let (h, w, c) = graph.shape_of(r, &shapes);
        let margin = need.saturating_sub(pad);
        let (ch, cw) = (h + 2 * pad + margin, w + 2 * pad + margin);
        let cv = CanvasLayout { base, h, w, c, pad, ch, cw };
        base += cv.len_px();
        out.push(cv);
    }
    Ok(out)
}

/// Canvas index of a node input (0 = graph input, node *i* → *i + 1*).
fn canvas_of(r: NodeRef) -> usize {
    match r {
        NodeRef::Input => 0,
        NodeRef::Node(i) => i + 1,
    }
}

/// Index of the canvas containing DRAM pixel `px` (caller guarantees
/// `px < weights_base`).
fn canvas_at(canvases: &[CanvasLayout], px: u64) -> usize {
    canvases.partition_point(|cv| (cv.base as u64) <= px).saturating_sub(1)
}

// ---------------------------------------------------------------------------
// per-command access derivation

/// DRAM row intervals a DMA descriptor touches on the DRAM side.
fn dma_dram_rows(d: &DmaDesc) -> Vec<Iv> {
    (0..u64::from(d.rows))
        .map(|r| {
            let a = u64::from(d.dram_px) + r * u64::from(d.dram_pitch);
            (a, a + u64::from(d.row_px))
        })
        .collect()
}

/// SRAM row intervals a DMA descriptor touches on the SRAM side.
fn dma_sram_rows(d: &DmaDesc) -> Vec<Iv> {
    (0..u64::from(d.rows))
        .map(|r| {
            let a = u64::from(d.sram_px) + r * u64::from(d.sram_pitch);
            (a, a + u64::from(d.row_px))
        })
        .collect()
}

/// DRAM intervals a command reads (weight/bias fetches included).
fn dram_reads(cmd: &Cmd) -> Vec<Iv> {
    match cmd {
        Cmd::LoadImage(d) => dma_dram_rows(d),
        Cmd::LoadWeights(w) => {
            let a = u64::from(w.dram_px);
            vec![(a, a + u64::from(w.cn) * (PES_PER_CU * NUM_CU) as u64)]
        }
        Cmd::LoadBias(b) => {
            let a = u64::from(b.dram_px);
            vec![(a, a + 2 * NUM_CU as u64)]
        }
        _ => Vec::new(),
    }
}

/// DRAM intervals a command writes.
fn dram_writes(cmd: &Cmd) -> Vec<Iv> {
    match cmd {
        Cmd::Store(d) => dma_dram_rows(d),
        _ => Vec::new(),
    }
}

// ---------------------------------------------------------------------------
// per-segment symbolic interpreter

/// Merged DRAM read/write footprints of one segment.
#[derive(Default)]
struct SegAccess {
    dram_r: Vec<Iv>,
    dram_w: Vec<Iv>,
}

fn diag(
    diags: &mut Vec<Diagnostic>,
    kind: DiagKind,
    segment: Option<usize>,
    cmds: Vec<usize>,
    detail: String,
) {
    diags.push(Diagnostic { kind, segment, cmds, detail });
}

/// Check SRAM intervals against capacity; returns the highest pixel
/// touched (for the footprint high-water mark).
fn check_sram(
    ivs: &[Iv],
    si: usize,
    ci: usize,
    what: &str,
    diags: &mut Vec<Diagnostic>,
) -> u64 {
    let mut top = 0u64;
    for &(lo, hi) in ivs {
        top = top.max(hi);
        if hi > SRAM_CAP_PX || lo >= SRAM_CAP_PX {
            diag(
                diags,
                DiagKind::SramOob,
                Some(si),
                vec![ci],
                format!("{what} touches SRAM px [{lo}, {hi}) past the {SRAM_CAP_PX} px bank"),
            );
            break; // one report per command
        }
    }
    top
}

/// Check DRAM intervals against the allocated image size.
fn check_dram(
    ivs: &[Iv],
    dram_px: u64,
    si: usize,
    ci: usize,
    what: &str,
    diags: &mut Vec<Diagnostic>,
) {
    for &(lo, hi) in ivs {
        if hi > dram_px || lo >= dram_px {
            diag(
                diags,
                DiagKind::DramOob,
                Some(si),
                vec![ci],
                format!("{what} touches DRAM px [{lo}, {hi}) past the {dram_px} px image"),
            );
            break;
        }
    }
}

/// Interpret one segment: weight-stage discipline, SRAM bounds and
/// aliasing, conv/pool geometry, `PASS_DW` fields, store legality.
/// Returns the segment's merged DRAM footprints and appends every
/// `LoadImage` canvas read to `canvas_loads` for the later
/// uninitialized-read pass.
#[allow(clippy::too_many_arguments)]
fn analyze_segment(
    si: usize,
    seg: &crate::compiler::Segment,
    prog: &[Cmd],
    canvases: &[CanvasLayout],
    weights_base: u64,
    dram_px: u64,
    canvas_loads: &mut Vec<(usize, usize, Vec<Iv>)>,
    diags: &mut Vec<Diagnostic>,
) -> SegAccess {
    if seg.start >= seg.end || seg.end > prog.len() {
        // Already reported by `check_segment_form`; nothing to interpret.
        return SegAccess::default();
    }

    let mut cfg: Option<ConvCfg> = seg.cfg;
    // (command index, staged channel count) — FIFO, depth 2.
    let mut wstage: VecDeque<(usize, u16)> = VecDeque::new();
    let mut dma_in_w: Vec<Iv> = Vec::new(); // SRAM written by LoadImage
    let mut comp_w: Vec<Iv> = Vec::new(); // SRAM written by compute passes
    let mut sram_top = 0u64;
    let mut dram_r: Vec<Iv> = Vec::new();
    let mut dram_w: Vec<Iv> = Vec::new();

    for ci in seg.start..seg.end {
        match &prog[ci] {
            Cmd::Nop | Cmd::Sync => {}
            Cmd::Halt => diag(
                diags,
                DiagKind::SegmentForm,
                Some(si),
                vec![ci],
                "Halt inside a segment".into(),
            ),
            Cmd::SetConv(c) => cfg = Some(*c),
            Cmd::LoadImage(d) => {
                let dr = dma_dram_rows(d);
                check_dram(&dr, dram_px, si, ci, "LoadImage", diags);
                let sw = dma_sram_rows(d);
                sram_top = sram_top.max(check_sram(&sw, si, ci, "LoadImage", diags));
                canvas_loads.push((si, ci, dr.clone()));
                dram_r.extend(dr);
                dma_in_w.extend(sw);
            }
            Cmd::Store(d) => {
                let sr = dma_sram_rows(d);
                sram_top = sram_top.max(check_sram(&sr, si, ci, "Store", diags));
                let dw = dma_dram_rows(d);
                check_dram(&dw, dram_px, si, ci, "Store", diags);
                check_store_rows(&dw, canvases, weights_base, dram_px, si, ci, diags);
                dram_w.extend(dw);
            }
            Cmd::LoadWeights(w) => {
                let r = dram_reads(&prog[ci]);
                check_dram(&r, dram_px, si, ci, "LoadWeights", diags);
                dram_r.extend(r);
                wstage.push_back((ci, w.cn));
                if wstage.len() > 2 {
                    diag(
                        diags,
                        DiagKind::WeightStage,
                        Some(si),
                        vec![ci],
                        format!("weight shadow bank over-filled to depth {}", wstage.len()),
                    );
                }
            }
            Cmd::LoadBias(_) => {
                let r = dram_reads(&prog[ci]);
                check_dram(&r, dram_px, si, ci, "LoadBias", diags);
                dram_r.extend(r);
            }
            Cmd::Conv(p) => {
                let staged = wstage.pop_front();
                let Some(c) = cfg else {
                    diag(
                        diags,
                        DiagKind::ConvShape,
                        Some(si),
                        vec![ci],
                        "conv pass with no SetConv in effect".into(),
                    );
                    continue;
                };
                if c.stride == 0 {
                    diag(
                        diags,
                        DiagKind::ConvShape,
                        Some(si),
                        vec![ci],
                        "conv stride 0".into(),
                    );
                    continue;
                }
                let st = u64::from(c.stride);
                let (ih, iw) = (u64::from(p.ih), u64::from(p.iw));
                let (oh, ow) = (u64::from(p.oh), u64::from(p.ow));
                let is_dw = p.flags & PASS_DW != 0;
                let last = p.flags & PASS_LAST != 0;

                if oh == 0 || ow == 0 {
                    diag(
                        diags,
                        DiagKind::ConvShape,
                        Some(si),
                        vec![ci],
                        format!("empty output tile {oh}x{ow}"),
                    );
                    continue;
                }
                if oh * ow > ACC_TILE_PX as u64 {
                    diag(
                        diags,
                        DiagKind::ConvShape,
                        Some(si),
                        vec![ci],
                        format!(
                            "output tile {oh}x{ow} overflows the {ACC_TILE_PX} px ACC BUF plane"
                        ),
                    );
                }
                if u64::from(p.dy) + (oh - 1) * st + 3 > ih
                    || u64::from(p.dx) + (ow - 1) * st + 3 > iw
                {
                    diag(
                        diags,
                        DiagKind::ConvShape,
                        Some(si),
                        vec![ci],
                        format!(
                            "tap window (dy={}, dx={}, stride {st}) overruns the {ih}x{iw} \
                             input tile for a {oh}x{ow} output",
                            p.dy, p.dx
                        ),
                    );
                }
                if p.mn == 0 || p.mn > NUM_CU as u16 {
                    diag(
                        diags,
                        DiagKind::DwField,
                        Some(si),
                        vec![ci],
                        format!("mn {} outside 1..={NUM_CU}", p.mn),
                    );
                }
                match staged {
                    None => diag(
                        diags,
                        DiagKind::WeightStage,
                        Some(si),
                        vec![ci],
                        "conv pass with an empty weight shadow bank".into(),
                    ),
                    Some((load_ci, cn_load)) => {
                        let want = if is_dw { 1 } else { p.cn };
                        if cn_load != want {
                            diag(
                                diags,
                                DiagKind::WeightStage,
                                Some(si),
                                vec![load_ci, ci],
                                format!(
                                    "staged weight block is {cn_load}*144 px but the pass \
                                     consumes {want}*144"
                                ),
                            );
                        }
                    }
                }

                // Input hull: lane/channel planes src + k*ih*iw, k in 0..cn.
                let src = u64::from(p.src_px);
                let read = (src, src + u64::from(p.cn) * ih * iw);
                sram_top = sram_top.max(check_sram(&[read], si, ci, "Conv input", diags));

                let mut write: Option<Iv> = None;
                if is_dw {
                    if p.cn == 0 || p.cn > NUM_CU as u16 {
                        diag(
                            diags,
                            DiagKind::DwField,
                            Some(si),
                            vec![ci],
                            format!("depthwise cn {} outside 1..={NUM_CU}", p.cn),
                        );
                    } else if last {
                        let dpp = if p.dpp == 0 { ow } else { u64::from(p.dpp) };
                        let dpl = if p.dpl == 0 { oh * ow } else { u64::from(p.dpl) };
                        if dpp < ow {
                            diag(
                                diags,
                                DiagKind::DwField,
                                Some(si),
                                vec![ci],
                                format!("dpp {dpp} shorter than the {ow} px output row"),
                            );
                        }
                        if dpl < (oh - 1) * dpp + ow {
                            diag(
                                diags,
                                DiagKind::DwField,
                                Some(si),
                                vec![ci],
                                format!(
                                    "dpl {dpl} too small for {oh} rows of pitch {dpp} \
                                     (plane extent {})",
                                    (oh - 1) * dpp + ow
                                ),
                            );
                        }
                        let dst = u64::from(p.dst_px);
                        write = Some((dst, dst + u64::from(p.cn - 1) * dpl + (oh - 1) * dpp + ow));
                    }
                } else if last {
                    let dst = u64::from(p.dst_px);
                    write = Some((dst, dst + NUM_CU as u64 * oh * ow));
                }
                if let Some(w) = write {
                    sram_top = sram_top.max(check_sram(&[w], si, ci, "Conv output", diags));
                    if let Some(ov) = sets_overlap(&[read], &[w]) {
                        diag(
                            diags,
                            DiagKind::SramOverlap,
                            Some(si),
                            vec![ci],
                            format!(
                                "conv output [{}, {}) overlaps its input tile at px {}",
                                w.0, w.1, ov.0
                            ),
                        );
                    }
                    comp_w.push(w);
                }
            }
            Cmd::Pool(p) => {
                let (ih, iw, c) = (u64::from(p.ih), u64::from(p.iw), u64::from(p.c));
                let (k, st) = (u64::from(p.k), u64::from(p.stride));
                if k == 0 || st == 0 || k > ih || k > iw {
                    diag(
                        diags,
                        DiagKind::ConvShape,
                        Some(si),
                        vec![ci],
                        format!("pool window {k} stride {st} illegal for a {ih}x{iw} tile"),
                    );
                    continue;
                }
                let (oh, ow) = ((ih - k) / st + 1, (iw - k) / st + 1);
                let src = u64::from(p.src_px);
                let dst = u64::from(p.dst_px);
                let read = (src, src + c * ih * iw);
                let write = (dst, dst + c * oh * ow);
                sram_top = sram_top.max(check_sram(&[read], si, ci, "Pool input", diags));
                sram_top = sram_top.max(check_sram(&[write], si, ci, "Pool output", diags));
                if sets_overlap(&[read], &[write]).is_some() {
                    diag(
                        diags,
                        DiagKind::SramOverlap,
                        Some(si),
                        vec![ci],
                        "pool output overlaps its input region".into(),
                    );
                }
                comp_w.push(write);
            }
            Cmd::Add(a) => {
                let n = u64::from(a.n_px);
                let ra = (u64::from(a.src_a_px), u64::from(a.src_a_px) + n);
                let rb = (u64::from(a.src_b_px), u64::from(a.src_b_px) + n);
                let w = (u64::from(a.dst_px), u64::from(a.dst_px) + n);
                sram_top = sram_top.max(check_sram(&[ra], si, ci, "Add operand a", diags));
                sram_top = sram_top.max(check_sram(&[rb], si, ci, "Add operand b", diags));
                sram_top = sram_top.max(check_sram(&[w], si, ci, "Add output", diags));
                if sets_overlap(&merge_ivs(vec![ra, rb]), &[w]).is_some() {
                    diag(
                        diags,
                        DiagKind::SramOverlap,
                        Some(si),
                        vec![ci],
                        "add output overlaps an input operand".into(),
                    );
                }
                comp_w.push(w);
            }
        }
    }

    if !wstage.is_empty() {
        let cmds: Vec<usize> = wstage.iter().map(|&(ci, _)| ci).collect();
        diag(
            diags,
            DiagKind::WeightStage,
            Some(si),
            cmds,
            format!("{} stale weight block(s) staged at segment end", wstage.len()),
        );
    }
    let staged_in = merge_ivs(dma_in_w);
    let computed = merge_ivs(comp_w);
    if let Some(ov) = sets_overlap(&staged_in, &computed) {
        diag(
            diags,
            DiagKind::SramOverlap,
            Some(si),
            Vec::new(),
            format!(
                "compute output overlaps the DMA-staged input allocation at SRAM px \
                 [{}, {})",
                ov.0, ov.1
            ),
        );
    }
    if sram_top > SRAM_CAP_PX {
        diag(
            diags,
            DiagKind::SramFootprint,
            Some(si),
            Vec::new(),
            format!(
                "segment footprint reaches SRAM px {sram_top} ({} bytes) past the \
                 {SRAM_BYTES}-byte bank",
                sram_top * 2
            ),
        );
    }

    SegAccess { dram_r: merge_ivs(dram_r), dram_w: merge_ivs(dram_w) }
}

/// Every store row must land wholly inside one canvas valid region:
/// the zero apron/margin, the input canvas, and the weight blocks must
/// never be written.
#[allow(clippy::too_many_arguments)]
fn check_store_rows(
    rows: &[Iv],
    canvases: &[CanvasLayout],
    weights_base: u64,
    dram_px: u64,
    si: usize,
    ci: usize,
    diags: &mut Vec<Diagnostic>,
) {
    for &(lo, hi) in rows {
        if hi > dram_px || lo >= dram_px {
            return; // DramOob already reported; classification is moot
        }
        if lo >= weights_base {
            diag(
                diags,
                DiagKind::BadStore,
                Some(si),
                vec![ci],
                format!("store row [{lo}, {hi}) lands in the weight/bias region"),
            );
            return;
        }
        let j = canvas_at(canvases, lo);
        let cv = &canvases[j];
        let (base, cwu) = (cv.base as u64, cv.cw as u64);
        let plane = (cv.ch * cv.cw) as u64;
        let off = lo - base;
        let (k, rem) = (off / plane, off % plane);
        let (y, x) = (rem / cwu, rem % cwu);
        let valid = hi <= base + cv.len_px() as u64
            && k < cv.c as u64
            && (cv.pad as u64..(cv.pad + cv.h) as u64).contains(&y)
            && x >= cv.pad as u64
            && x + (hi - lo) <= (cv.pad + cv.w) as u64;
        if j == 0 {
            diag(
                diags,
                DiagKind::BadStore,
                Some(si),
                vec![ci],
                format!("store row [{lo}, {hi}) overwrites the input canvas"),
            );
            return;
        }
        if !valid {
            diag(
                diags,
                DiagKind::BadStore,
                Some(si),
                vec![ci],
                format!(
                    "store row [{lo}, {hi}) escapes canvas {j}'s valid region \
                     (ch {k}, y {y}, x {x}; the zero apron must stay zero)"
                ),
            );
            return;
        }
    }
}

/// Clip a canvas read interval to the valid-region bytes it covers and
/// report the first pixel no store ever writes. The zero apron/margin
/// and the input canvas are exempt (padding halos legally read zeros;
/// the runtime writes the input frame).
fn check_uninit_reads(
    canvas_loads: &[(usize, usize, Vec<Iv>)],
    canvases: &[CanvasLayout],
    writes: &[Iv],
    diags: &mut Vec<Diagnostic>,
) {
    let weights_base = canvases.last().map_or(0, |cv| (cv.base + cv.len_px()) as u64);
    for (si, ci, rows) in canvas_loads {
        'rows: for &(lo, hi) in rows {
            if lo >= weights_base {
                continue;
            }
            let j = canvas_at(canvases, lo);
            if j == 0 {
                continue;
            }
            let cv = &canvases[j];
            let (base, cwu) = (cv.base as u64, cv.cw as u64);
            let plane = (cv.ch * cv.cw) as u64;
            let end = hi.min(base + cv.len_px() as u64);
            // Walk the canvas rows the interval spans; intersect each
            // with that row's valid columns.
            let mut a = lo;
            while a < end {
                let off = a - base;
                let (k, rem) = (off / plane, off % plane);
                let (y, x) = (rem / cwu, rem % cwu);
                let row_end = a + (cwu - x); // canvas-row boundary
                let b = end.min(row_end);
                let row0 = a - x; // DRAM px of this canvas row's col 0
                if k < cv.c as u64 && (cv.pad as u64..(cv.pad + cv.h) as u64).contains(&y) {
                    let vlo = (row0 + cv.pad as u64).max(a);
                    let vhi = (row0 + (cv.pad + cv.w) as u64).min(b);
                    if vlo < vhi {
                        if let Some(px) = first_uncovered((vlo, vhi), writes) {
                            diag(
                                diags,
                                DiagKind::UninitRead,
                                Some(*si),
                                vec![*ci],
                                format!(
                                    "reads canvas {j} px {px} (ch {k}, y {y}) that no \
                                     store ever writes"
                                ),
                            );
                            break 'rows; // one report per command
                        }
                    }
                }
                a = b;
            }
        }
    }
}

/// Segment bookkeeping: ranges must tile the program in order, every
/// inter-segment gap may hold only `SetConv` prologues, each segment
/// must end on its `Sync` barrier, and the tail is the single `Halt`.
fn check_segment_form(net: &CompiledNet, prog: &[Cmd], diags: &mut Vec<Diagnostic>) {
    let mut at = 0usize;
    for (si, seg) in net.segments.iter().enumerate() {
        if seg.start < at || seg.start >= seg.end || seg.end > prog.len() {
            diag(
                diags,
                DiagKind::SegmentForm,
                Some(si),
                Vec::new(),
                format!(
                    "segment range [{}, {}) overlaps its predecessor or escapes the \
                     {}-command program",
                    seg.start,
                    seg.end,
                    prog.len()
                ),
            );
            at = at.max(seg.end.min(prog.len()));
            continue;
        }
        for (ci, c) in prog.iter().enumerate().take(seg.start).skip(at) {
            if !matches!(c, Cmd::SetConv(_)) {
                diag(
                    diags,
                    DiagKind::SegmentForm,
                    None,
                    vec![ci],
                    format!("non-prologue command {c:?} between segments"),
                );
            }
        }
        if !matches!(prog[seg.end - 1], Cmd::Sync) {
            diag(
                diags,
                DiagKind::SegmentForm,
                Some(si),
                vec![seg.end - 1],
                "segment does not end on its Sync barrier".into(),
            );
        }
        at = seg.end;
    }
    let tail = &prog[at.min(prog.len())..];
    if tail != [Cmd::Halt] {
        diag(
            diags,
            DiagKind::SegmentForm,
            None,
            Vec::new(),
            format!("program tail after the last segment is {tail:?}, expected a single Halt"),
        );
    }
}

// ---------------------------------------------------------------------------
// timing replay: exact per-segment cycles from the decoded stream

/// Exact cycle count of one segment, replayed from the decoded command
/// stream through the same charge rules the simulator applies (via
/// [`SegClock`]): overlappable DMA on a serialized channel, the
/// two-deep weight stage with stall-to-fetch, `scan_timing`/
/// `dw_scan_timing` per conv pass, `oh·ow·k` per pool channel, and the
/// `Sync` drain. Commands whose geometry is illegal (reported elsewhere
/// as `ConvShape`) contribute what they legally can.
pub fn segment_cycles(seg: &crate::compiler::Segment, prog: &[Cmd]) -> u64 {
    segment_replay(seg, prog).cyc
}

/// Exact phase split of one segment's clock, from the same replay as
/// [`segment_cycles`]. The three phases partition `cycles` by
/// construction — `SegClock` charges every clock advance to exactly one
/// of compute, inbound-load stall, or outbound store drain — so
/// `load_stall + compute + store_stall == cycles` always, and `cycles`
/// equals the measured per-segment `SimStats.cycles` delta (PR 9's
/// exactness gate). The trace sink uses this split to render DMA-load /
/// compute / store sub-spans under each segment span.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SegPhases {
    /// Total segment cycles (== the sum of the three phases).
    pub cycles: u64,
    /// Datapath compute cycles.
    pub compute: u64,
    /// Non-hidden inbound DMA stall (weights/image/bias fetch).
    pub load_stall: u64,
    /// Non-hidden outbound store drain at `Sync` barriers.
    pub store_stall: u64,
}

/// Replay one segment and return its exact phase split.
pub fn segment_phases(seg: &crate::compiler::Segment, prog: &[Cmd]) -> SegPhases {
    let clk = segment_replay(seg, prog);
    SegPhases {
        cycles: clk.cyc,
        compute: clk.compute_cycles,
        load_stall: clk.load_stall_cycles,
        store_stall: clk.store_stall_cycles,
    }
}

/// Phase split of every segment of a compiled net, in segment order.
pub fn net_phases(net: &CompiledNet) -> Vec<SegPhases> {
    net.segments.iter().map(|seg| segment_phases(seg, &net.program)).collect()
}

fn segment_replay(seg: &crate::compiler::Segment, prog: &[Cmd]) -> SegClock {
    let mut clk = SegClock::new();
    let mut cfg = seg.cfg;
    for cmd in &prog[seg.start..seg.end.min(prog.len())] {
        match cmd {
            Cmd::Nop | Cmd::Halt => {}
            Cmd::Sync => clk.sync(),
            Cmd::SetConv(c) => cfg = Some(*c),
            Cmd::LoadImage(d) => {
                clk.dma(u64::from(d.rows) * u64::from(d.row_px) * 2);
            }
            Cmd::Store(d) => {
                clk.store(u64::from(d.rows) * u64::from(d.row_px) * 2);
            }
            Cmd::LoadWeights(w) => {
                clk.load_weights(u64::from(w.cn) * (PES_PER_CU * NUM_CU) as u64);
            }
            Cmd::LoadBias(_) => clk.dma(2 * 2 * NUM_CU as u64),
            Cmd::Conv(p) => {
                let st = cfg.map_or(1, |c| c.stride as usize).max(1);
                let (ih, iw) = (p.ih as usize, p.iw as usize);
                let (oh, ow) = (p.oh as usize, p.ow as usize);
                if p.flags & PASS_FIRST != 0 {
                    clk.compute((oh * ow / WORD_PX) as u64 + 1);
                }
                clk.pop_weights();
                if p.flags & PASS_DW != 0 {
                    let cn = (p.cn as usize).clamp(1, NUM_CU);
                    let t = dw_scan_timing(ih, iw, oh, ow, st, cn);
                    clk.compute(t.fill_cycles + t.scan_cycles);
                    if p.flags & PASS_LAST != 0 {
                        clk.compute((oh * ow * cn).div_ceil(WORD_PX) as u64);
                    }
                } else {
                    let t = scan_timing(ih, iw, oh, ow, st);
                    clk.compute(u64::from(p.cn) * (t.fill_cycles + t.scan_cycles));
                    if p.flags & PASS_LAST != 0 {
                        clk.compute((oh * ow * NUM_CU).div_ceil(WORD_PX) as u64);
                    }
                }
            }
            Cmd::Pool(p) => {
                let (ih, iw) = (p.ih as usize, p.iw as usize);
                let (k, st) = (p.k as usize, p.stride as usize);
                if k == 0 || st == 0 || k > ih || k > iw {
                    continue;
                }
                let (oh, ow) = ((ih - k) / st + 1, (iw - k) / st + 1);
                clk.compute((p.c as usize * oh * ow * k) as u64);
            }
            Cmd::Add(a) => clk.compute(3 * u64::from(a.n_px).div_ceil(WORD_PX as u64)),
        }
    }
    clk
}

/// Per-node exact cycle totals derived from the artifact alone: every
/// segment replayed through [`segment_cycles`], summed onto the graph
/// node that owns it. Every segment ends on a `Sync` barrier, so the
/// per-segment deltas are translation-invariant and the per-node sums
/// equal the measured `SimStats` attribution.
pub fn derived_node_cycles(net: &CompiledNet) -> Vec<u64> {
    let mut per_node = vec![0u64; net.graph.nodes.len()];
    for seg in &net.segments {
        per_node[seg.node] += segment_cycles(seg, &net.program);
    }
    per_node
}

/// Timing lint: check a planner-predicted per-node cycle table (e.g.
/// `GraphPlan::node_cycles`) against the exact totals replayed from the
/// compiled command stream. Any disagreement is a [`DiagKind::TimingDrift`]
/// diagnostic — the planner's timing claims no longer describe the
/// artifact it planned.
pub fn lint_timing(net: &CompiledNet, predicted: &[u64]) -> Vec<Diagnostic> {
    let derived = derived_node_cycles(net);
    let mut diags = Vec::new();
    if predicted.len() != derived.len() {
        diag(
            &mut diags,
            DiagKind::TimingDrift,
            None,
            Vec::new(),
            format!(
                "predicted cycle table has {} entries for a {}-node graph",
                predicted.len(),
                derived.len()
            ),
        );
        return diags;
    }
    for (i, (&p, &d)) in predicted.iter().zip(&derived).enumerate() {
        if p != d {
            diag(
                &mut diags,
                DiagKind::TimingDrift,
                None,
                Vec::new(),
                format!(
                    "node {i}: planner predicts {p} cycles but the decoded command \
                     stream replays to {d}"
                ),
            );
        }
    }
    diags
}

// ---------------------------------------------------------------------------
// race detection over the segment DAG

/// Recompute every pairwise DRAM read/write intersection between
/// segments and require a dependency path for each RAW/WAR/WAW
/// conflict. Returns the number of conflicts examined.
fn check_races(
    net: &CompiledNet,
    prog: &[Cmd],
    acc: &[SegAccess],
    diags: &mut Vec<Diagnostic>,
) -> u64 {
    let n = net.segments.len();
    let wlen = n.div_ceil(64);

    // Ancestor bitsets: anc[j] holds every segment with a dependency
    // path into j. Built in declared order, so it is also the
    // topology check — an edge pointing at itself or forward cannot
    // contribute and is reported.
    let mut anc: Vec<Vec<u64>> = Vec::with_capacity(n);
    for (j, seg) in net.segments.iter().enumerate() {
        let mut cur = vec![0u64; wlen];
        for &d in &seg.deps {
            if d >= j {
                diag(
                    diags,
                    DiagKind::NonTopological,
                    Some(j),
                    Vec::new(),
                    format!("dep edge {j} -> {d} points forward; segment order is not topological"),
                );
                continue;
            }
            cur[d / 64] |= 1 << (d % 64);
            for (w, s) in cur.iter_mut().zip(&anc[d]) {
                *w |= s;
            }
        }
        anc.push(cur);
    }

    let mut hazards = 0u64;
    for j in 1..n {
        for i in 0..j {
            let covered = (anc[j][i / 64] >> (i % 64)) & 1 == 1;
            for kind in [HazardKind::Raw, HazardKind::Waw, HazardKind::War] {
                let (a, b) = match kind {
                    HazardKind::Raw => (&acc[i].dram_w, &acc[j].dram_r),
                    HazardKind::Waw => (&acc[i].dram_w, &acc[j].dram_w),
                    HazardKind::War => (&acc[i].dram_r, &acc[j].dram_w),
                };
                let Some(ov) = sets_overlap(a, b) else { continue };
                hazards += 1;
                if !covered {
                    let (ca, cb) = offending_cmds(prog, net, i, j, ov, kind);
                    diag(
                        diags,
                        DiagKind::UncoveredHazard(kind),
                        Some(j),
                        vec![ca, cb],
                        format!(
                            "{} hazard between segments {i} and {j} on DRAM px [{}, {}) \
                             has no covering dependency path",
                            kind.name(),
                            ov.0,
                            ov.1
                        ),
                    );
                }
            }
        }
    }
    hazards
}

/// Name one offending command on each side of a hazard: the first
/// command in each segment whose relevant DRAM access intersects the
/// conflicting interval.
fn offending_cmds(
    prog: &[Cmd],
    net: &CompiledNet,
    i: usize,
    j: usize,
    ov: Iv,
    kind: HazardKind,
) -> (usize, usize) {
    let pick = |si: usize, want_write: bool| -> usize {
        let seg = &net.segments[si];
        for ci in seg.start..seg.end.min(prog.len()) {
            let ivs = if want_write { dram_writes(&prog[ci]) } else { dram_reads(&prog[ci]) };
            if ivs.iter().any(|iv| iv.0 < ov.1 && ov.0 < iv.1) {
                return ci;
            }
        }
        seg.start
    };
    match kind {
        HazardKind::Raw => (pick(i, true), pick(j, false)),
        HazardKind::War => (pick(i, false), pick(j, true)),
        HazardKind::Waw => (pick(i, true), pick(j, true)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_merge_coalesces_and_drops_empties() {
        let m = merge_ivs(vec![(5, 9), (0, 3), (3, 5), (7, 7), (20, 25)]);
        assert_eq!(m, vec![(0, 9), (20, 25)]);
    }

    #[test]
    fn interval_overlap_finds_first_intersection() {
        let a = vec![(0u64, 10u64), (20, 30)];
        let b = vec![(10u64, 15u64), (28, 40)];
        assert_eq!(sets_overlap(&a, &b), Some((28, 30)));
        assert_eq!(sets_overlap(&a, &[(10, 20)]), None);
        assert_eq!(sets_overlap(&a, &[]), None);
    }

    #[test]
    fn first_uncovered_walks_the_merged_set() {
        let set = vec![(0u64, 10u64), (12, 20)];
        assert_eq!(first_uncovered((2, 9), &set), None);
        assert_eq!(first_uncovered((2, 12), &set), Some(10));
        assert_eq!(first_uncovered((15, 25), &set), Some(20));
        assert_eq!(first_uncovered((30, 31), &set), Some(30));
    }

    #[test]
    fn analyzer_passes_a_trivial_compile() {
        let graph = crate::model::zoo::graph_by_name("quicknet").unwrap();
        let net = crate::compiler::compile_graph(&graph).unwrap();
        let a = analyze(&net).unwrap();
        assert!(a.is_clean(), "quicknet should lint clean:\n{}", a.report());
        assert!(a.hazards_checked > 0, "a multi-node net must exercise the race detector");
    }

    #[test]
    fn timing_replay_agrees_with_the_planner_and_kills_corruption() {
        let graph = crate::model::zoo::graph_by_name("quicknet").unwrap();
        let gp =
            crate::planner::plan_graph(&graph, crate::planner::PlanPolicy::MinTraffic).unwrap();
        let net = crate::compiler::compile_graph_with_plans(&graph, &gp.plans).unwrap();
        let clean = lint_timing(&net, &gp.node_cycles);
        assert!(clean.is_empty(), "planner vs replay drift:\n{clean:?}");
        let mut bad = gp.node_cycles.clone();
        bad[0] += 1;
        assert!(lint_timing(&net, &bad).iter().any(|d| d.kind == DiagKind::TimingDrift));
        assert!(lint_timing(&net, &bad[1..]).iter().any(|d| d.kind == DiagKind::TimingDrift));
    }
}
