//! Image / feature / channel decomposition solver (paper §5, Fig. 6).
//!
//! Fits an arbitrary CONV layer into the fixed on-chip resources:
//!
//! * **SRAM budget** (128 KB): `input tile (channel group, planar)` +
//!   `output staging (one 16-feature group)` + weight staging must fit.
//! * **ACC BUF**: output tile ≤ 1024 pixels (int32 partial plane,
//!   16 features wide).
//!
//! Decomposition axes, in the paper's terms:
//! * *image decomposition*: split the output plane into a `gy × gx`
//!   grid of tiles, re-loading each tile's input window (with halo)
//!   from DRAM — trades DRAM traffic for SRAM footprint;
//! * *feature decomposition*: output features computed in groups of 16
//!   (the engine width) — `fsplit` counts the groups per DRAM round;
//! * *channel decomposition*: input channels loaded in groups when one
//!   channel set alone exceeds SRAM; partial sums persist in the ACC
//!   BUF across groups.
//!
//! The solver prefers the fewest image tiles (halo overhead), then the
//! fewest channel groups (input re-streaming), and reports the SRAM
//! footprint of the chosen plan (the Fig. 6 numbers).

use crate::model::ConvSpec;
use crate::sim::accbuf::ACC_TILE_PX;
use crate::{NUM_CU, SRAM_BYTES};

/// One spatial tile of a layer's output plane.
#[derive(Clone, Debug, PartialEq)]
pub struct Tile {
    /// Output-plane origin and size.
    pub oy0: usize,
    pub ox0: usize,
    pub oh: usize,
    pub ow: usize,
    /// Padded-input-canvas origin and size of the window this tile reads
    /// (includes halo; the canvas bakes the conv padding).
    pub iy0: usize,
    pub ix0: usize,
    pub ih: usize,
    pub iw: usize,
}

/// The decomposition plan for one CONV layer.
#[derive(Clone, Debug)]
pub struct Plan {
    /// Image-decomposition grid.
    pub gy: usize,
    pub gx: usize,
    pub tiles: Vec<Tile>,
    /// Input channels per load group (per conv group).
    pub c_per_group: usize,
    /// Number of channel load groups (per conv group).
    pub c_groups: usize,
    /// 16-feature engine tiles per conv group.
    pub m_tiles: usize,
    /// Peak SRAM bytes: input tile + output staging.
    pub sram_bytes: usize,
    /// Largest input-tile bytes (the Fig. 6 "input SRAM" number).
    pub in_tile_bytes: usize,
    /// Output staging bytes (one 16-feature group of one tile).
    pub out_tile_bytes: usize,
    /// Depthwise fast-path schedule: `c_per_group` (≤ 16) channel
    /// *planes* packed across the engine width per pass, `m_tiles` = 1.
    pub dw: bool,
    /// Pointwise node only: fuse with its depthwise producer — the dw
    /// output streams through SRAM staging instead of a DRAM
    /// round-trip. Requires this plan's grid to equal the producer's.
    pub fuse_dw: bool,
}

/// A conv is depthwise-eligible when every channel is its own group:
/// the packed schedule runs 16 channel planes per pass instead of one.
pub fn dw_eligible(spec: &ConvSpec) -> bool {
    spec.groups == spec.cin && spec.cout == spec.cin
}

/// Errors a plan request can hit.
#[derive(Debug, thiserror::Error, PartialEq)]
pub enum PlanError {
    #[error("layer cannot fit: single pixel tile still exceeds resources")]
    Unsatisfiable,
}

fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// Split `n` into `parts` nearly-equal spans (first ones larger).
pub fn split_even(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let base = n / parts;
    let rem = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut at = 0;
    for i in 0..parts {
        let len = base + usize::from(i < rem);
        out.push((at, len));
        at += len;
    }
    out
}

/// Build the tile list for a given grid over the output plane.
/// `kp` = padded kernel span (3·⌈K/3⌉), `canvas` dims are the padded
/// input canvas (H + 2·pad).
pub(crate) fn tiles_for_grid(
    (oh, ow): (usize, usize),
    (gy, gx): (usize, usize),
    stride: usize,
    kp: usize,
) -> Vec<Tile> {
    let mut tiles = Vec::with_capacity(gy * gx);
    for (oy0, th) in split_even(oh, gy) {
        for (ox0, tw) in split_even(ow, gx) {
            if th == 0 || tw == 0 {
                continue;
            }
            // input window: rows oy0*s .. (oy0+th-1)*s + kp
            let iy0 = oy0 * stride;
            let ix0 = ox0 * stride;
            let ih = (th - 1) * stride + kp;
            let iw = (tw - 1) * stride + kp;
            tiles.push(Tile { oy0, ox0, oh: th, ow: tw, iy0, ix0, ih, iw });
        }
    }
    tiles
}

/// SRAM cost of a candidate: input tile (one channel group, planar,
/// padded kernel halo) + output staging (16 features, int16) + weight
/// staging for one pass.
fn candidate_sram(tile: &Tile, c_per_group: usize) -> (usize, usize, usize) {
    let in_bytes = tile.ih * tile.iw * c_per_group * 2;
    let out_bytes = tile.oh * tile.ow * NUM_CU * 2;
    let w_bytes = c_per_group * 9 * NUM_CU * 2;
    (in_bytes, out_bytes, w_bytes)
}

/// Depthwise variant: the weight stage holds a single 9×16 block per
/// pass (one 3×3 filter per lane), regardless of how many channel
/// planes are resident.
fn candidate_sram_dw(tile: &Tile, c_per_group: usize) -> (usize, usize, usize) {
    let in_bytes = tile.ih * tile.iw * c_per_group * 2;
    let out_bytes = tile.oh * tile.ow * NUM_CU * 2;
    let w_bytes = 9 * NUM_CU * 2;
    (in_bytes, out_bytes, w_bytes)
}

/// Materialize the full [`Plan`] for an explicitly chosen grid and
/// channel grouping — the planner's candidate enumerator picks
/// `(gy, gx, c_per_group)` analytically and builds the executable plan
/// through this. No feasibility is enforced here; the enumerator (and
/// `codegen`'s emission-time checks) gate that.
pub fn plan_with_grid(
    spec: &ConvSpec,
    h: usize,
    w: usize,
    gy: usize,
    gx: usize,
    c_per_group: usize,
) -> Plan {
    let (oh, ow) = (
        (h + 2 * spec.pad - spec.k) / spec.stride + 1,
        (w + 2 * spec.pad - spec.k) / spec.stride + 1,
    );
    let kp = 3 * ceil_div(spec.k, 3);
    let tiles = tiles_for_grid((oh, ow), (gy, gx), spec.stride, kp);
    let worst = tiles.iter().max_by_key(|t| t.ih * t.iw).expect("grid produces tiles").clone();
    if dw_eligible(spec) {
        let cpg = c_per_group.min(NUM_CU).min(spec.cin);
        let (ib, ob, wb) = candidate_sram_dw(&worst, cpg);
        return Plan {
            gy,
            gx,
            tiles,
            c_per_group: cpg,
            c_groups: ceil_div(spec.cin, cpg),
            m_tiles: 1,
            sram_bytes: ib + ob + wb,
            in_tile_bytes: ib,
            out_tile_bytes: ob,
            dw: true,
            fuse_dw: false,
        };
    }
    let cg_in = spec.cin / spec.groups;
    let (ib, ob, wb) = candidate_sram(&worst, c_per_group);
    Plan {
        gy,
        gx,
        tiles,
        c_per_group,
        c_groups: ceil_div(cg_in, c_per_group),
        m_tiles: ceil_div(spec.cout / spec.groups, NUM_CU),
        sram_bytes: ib + ob + wb,
        in_tile_bytes: ib,
        out_tile_bytes: ob,
        dw: false,
        fuse_dw: false,
    }
}

/// Solve the decomposition for `spec` with input plane (h, w) (pre-pad)
/// against the chip's 128 KB buffer bank.
pub fn plan_conv(spec: &ConvSpec, h: usize, w: usize) -> Result<Plan, PlanError> {
    plan_conv_budget(spec, h, w, SRAM_BYTES)
}

/// [`plan_conv`] against an explicit SRAM budget — the planner's
/// what-if sweeps (Fig. 6 at 64/256 KB) go through this; the chip
/// itself always plans at [`SRAM_BYTES`].
pub fn plan_conv_budget(
    spec: &ConvSpec,
    h: usize,
    w: usize,
    sram_budget: usize,
) -> Result<Plan, PlanError> {
    let (oh, ow) = (
        (h + 2 * spec.pad - spec.k) / spec.stride + 1,
        (w + 2 * spec.pad - spec.k) / spec.stride + 1,
    );
    let kp = 3 * ceil_div(spec.k, 3);
    let dw = dw_eligible(spec);
    // depthwise packs channel planes across lanes; others group cin/groups
    let cg_in = if dw { spec.cin.min(NUM_CU) } else { spec.cin / spec.groups };
    // grid search: smallest tile count first, square-ish grids preferred
    for tiles_target in 1..=oh * ow {
        let mut grids: Vec<(usize, usize)> = Vec::new();
        for gy in 1..=tiles_target.min(oh) {
            if tiles_target % gy == 0 {
                let gx = tiles_target / gy;
                if gx <= ow {
                    grids.push((gy, gx));
                }
            }
        }
        // prefer square-ish
        grids.sort_by_key(|&(gy, gx)| (gy as i64 - gx as i64).abs());
        for (gy, gx) in grids {
            let tiles = tiles_for_grid((oh, ow), (gy, gx), spec.stride, kp);
            if tiles.is_empty() {
                continue;
            }
            // ACC BUF constraint on the largest tile
            let max_px = tiles.iter().map(|t| t.oh * t.ow).max().unwrap();
            if max_px > ACC_TILE_PX {
                continue;
            }
            // channel grouping: largest c_per_group that fits SRAM
            let worst = tiles
                .iter()
                .max_by_key(|t| t.ih * t.iw)
                .unwrap()
                .clone();
            let mut c_per_group = cg_in;
            loop {
                let (ib, ob, wb) = if dw {
                    candidate_sram_dw(&worst, c_per_group)
                } else {
                    candidate_sram(&worst, c_per_group)
                };
                if ib + ob + wb <= sram_budget {
                    let plan = Plan {
                        gy,
                        gx,
                        tiles,
                        c_per_group,
                        c_groups: if dw {
                            ceil_div(spec.cin, c_per_group)
                        } else {
                            ceil_div(cg_in, c_per_group)
                        },
                        m_tiles: if dw { 1 } else { ceil_div(spec.cout / spec.groups, NUM_CU) },
                        sram_bytes: ib + ob + wb,
                        in_tile_bytes: ib,
                        out_tile_bytes: ob,
                        dw,
                        fuse_dw: false,
                    };
                    return Ok(plan);
                }
                if c_per_group == 1 {
                    break; // this grid can't fit even one channel
                }
                c_per_group = ceil_div(c_per_group, 2);
            }
        }
    }
    Err(PlanError::Unsatisfiable)
}

/// The paper's canonical Fig. 6 plan for a layer: force a `g × g` image
/// grid and report footprints (used by the Fig. 6 bench to reproduce
/// the 309 KB → 34 KB / 581 KB → 33 KB numbers).
pub fn plan_fixed_grid(
    spec: &ConvSpec,
    h: usize,
    w: usize,
    gy: usize,
    gx: usize,
    fsplit: usize,
) -> (Vec<Tile>, usize, usize) {
    let (oh, ow) = (
        (h + 2 * spec.pad - spec.k) / spec.stride + 1,
        (w + 2 * spec.pad - spec.k) / spec.stride + 1,
    );
    let kp = 3 * ceil_div(spec.k, 3);
    let tiles = tiles_for_grid((oh, ow), (gy, gx), spec.stride, kp);
    let worst = tiles.iter().max_by_key(|t| t.ih * t.iw).unwrap();
    let in_bytes = worst.ih * worst.iw * spec.cin * 2;
    let out_bytes = worst.oh * worst.ow * (spec.cout / fsplit) * 2;
    (tiles, in_bytes, out_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::model::LayerSpec;
    use crate::util::prop::check;

    fn conv_of(net: &str, layer: &str) -> (ConvSpec, usize, usize) {
        let net = zoo::by_name(net).unwrap();
        let mut shape = net.in_shape();
        for l in &net.layers {
            if l.name() == layer {
                if let LayerSpec::Conv(c) = l {
                    return (c.clone(), shape.0, shape.1);
                }
            }
            shape = l.out_shape(shape);
        }
        panic!("layer not found");
    }

    #[test]
    fn tiles_cover_output_exactly_once() {
        check("tiles partition the output plane", 60, |g| {
            let oh = g.usize_in(1, 60);
            let ow = g.usize_in(1, 60);
            let gy = g.usize_in(1, oh.min(6));
            let gx = g.usize_in(1, ow.min(6));
            let stride = g.usize_in(1, 4);
            let kp = 3 * g.usize_in(1, 4);
            let tiles = tiles_for_grid((oh, ow), (gy, gx), stride, kp);
            let mut cover = vec![0u8; oh * ow];
            for t in &tiles {
                for y in t.oy0..t.oy0 + t.oh {
                    for x in t.ox0..t.ox0 + t.ow {
                        cover[y * ow + x] += 1;
                    }
                }
            }
            if cover.iter().all(|&c| c == 1) {
                Ok(())
            } else {
                let bad = cover.iter().filter(|&&c| c != 1).count();
                Err(format!("{oh}x{ow} grid {gy}x{gx}: coverage {bad:?}"))
            }
        });
    }

    #[test]
    fn tile_input_windows_reach_only_valid_canvas() {
        check("input windows in canvas bounds", 60, |g| {
            let k = *g.choose(&[1usize, 3, 5, 7, 11]);
            let stride = *g.choose(&[1usize, 2, 4]);
            let pad = g.usize_in(0, 3);
            let h = k + stride * g.usize_in(0, 40);
            let w = k + stride * g.usize_in(0, 40);
            let oh = (h + 2 * pad - k) / stride + 1;
            let ow = (w + 2 * pad - k) / stride + 1;
            let kp = 3 * k.div_ceil(3);
            let gy = g.usize_in(1, oh.min(4));
            let gx = g.usize_in(1, ow.min(4));
            let canvas_h = h + 2 * pad + (kp - k);
            let canvas_w = w + 2 * pad + (kp - k);
            for t in tiles_for_grid((oh, ow), (gy, gx), stride, kp) {
                if t.iy0 + t.ih > canvas_h || t.ix0 + t.iw > canvas_w {
                    return Err(format!(
                        "tile {t:?} exceeds canvas {canvas_h}x{canvas_w} (k={k} s={stride} p={pad})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn alexnet_conv1_fits_with_image_decomposition() {
        let (c1, h, w) = conv_of("alexnet", "conv1");
        let plan = plan_conv(&c1, h, w).unwrap();
        assert!(plan.gy * plan.gx > 1, "conv1 must image-decompose (309 KB input)");
        assert!(plan.sram_bytes <= SRAM_BYTES);
        assert!(plan.tiles.iter().all(|t| t.oh * t.ow <= ACC_TILE_PX));
    }

    #[test]
    fn fig6_canonical_9_and_2() {
        // Paper Fig. 6: image ÷ 9 (3x3 grid), features ÷ 2 →
        // input tile ≈ 34 KB, output tile ≈ 33 KB (KB = 1000 B).
        let (c1, h, w) = conv_of("alexnet", "conv1");
        let (tiles, in_b, out_b) = plan_fixed_grid(&c1, h, w, 3, 3, 2);
        assert_eq!(tiles.len(), 9);
        // halo makes our input tile a bit larger than the paper's naive
        // /9; both land in the same few-tens-of-KB class.
        assert!(in_b as f64 / 1000.0 < 45.0, "in={in_b}");
        assert!((out_b as f64 / 1000.0 - 33.0).abs() < 3.0, "out={out_b}");
    }

    #[test]
    fn every_zoo_conv_layer_has_a_plan() {
        for name in zoo::ALL {
            let net = zoo::by_name(name).unwrap();
            let mut shape = net.in_shape();
            for l in &net.layers {
                if let LayerSpec::Conv(c) = l {
                    let plan = plan_conv(c, shape.0, shape.1)
                        .unwrap_or_else(|e| panic!("{name}/{}: {e}", c.name));
                    assert!(plan.sram_bytes <= SRAM_BYTES, "{name}/{}", c.name);
                }
                shape = l.out_shape(shape);
            }
        }
    }

    #[test]
    fn plan_with_grid_reproduces_solver_choice() {
        let (c1, h, w) = conv_of("alexnet", "conv1");
        let plan = plan_conv(&c1, h, w).unwrap();
        let again = plan_with_grid(&c1, h, w, plan.gy, plan.gx, plan.c_per_group);
        assert_eq!(again.tiles, plan.tiles);
        assert_eq!(again.sram_bytes, plan.sram_bytes);
        assert_eq!((again.c_groups, again.m_tiles), (plan.c_groups, plan.m_tiles));
    }

    #[test]
    fn smaller_budget_forces_finer_plans() {
        let (c1, h, w) = conv_of("alexnet", "conv1");
        let full = plan_conv_budget(&c1, h, w, SRAM_BYTES).unwrap();
        let half = plan_conv_budget(&c1, h, w, SRAM_BYTES / 2).unwrap();
        assert!(half.sram_bytes <= SRAM_BYTES / 2);
        assert!(
            half.tiles.len() >= full.tiles.len(),
            "tighter budget cannot coarsen the grid: {} < {}",
            half.tiles.len(),
            full.tiles.len()
        );
    }

    #[test]
    fn split_even_properties() {
        check("split_even partitions", 50, |g| {
            let n = g.usize_in(1, 200);
            let parts = g.usize_in(1, n.min(17));
            let spans = split_even(n, parts);
            let total: usize = spans.iter().map(|s| s.1).sum();
            if total != n {
                return Err(format!("sum {total} != {n}"));
            }
            let mut at = 0;
            for (start, len) in &spans {
                if *start != at {
                    return Err(format!("gap at {start}"));
                }
                at += len;
            }
            Ok(())
        });
    }
}
