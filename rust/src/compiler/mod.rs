//! Layer → decomposition plan → ISA command stream (the paper's §5
//! contribution, as a compiler).
//!
//! * [`decompose`] — the image/feature/channel decomposition solver.
//! * [`kernel_decomp`] — K×K → 3×3 tap enumeration (fixed CU array).
//! * [`codegen`] — plan → command program + DRAM image.
//! * [`NetRunner`] — convenience: compile once, run frames on a fresh or
//!   reused simulator, extract outputs (what the coordinator uses).

pub mod codegen;
pub mod decompose;
pub mod kernel_decomp;

pub use codegen::{compile_net, CompiledNet};
pub use decompose::{plan_conv, Plan, PlanError};

use crate::model::{NetSpec, Tensor};
use crate::sim::{Accelerator, SimConfig, SimStats};

/// Compile-once / run-many harness around the simulator.
pub struct NetRunner {
    pub compiled: CompiledNet,
    cfg: SimConfig,
}

impl NetRunner {
    pub fn new(net: &NetSpec) -> anyhow::Result<Self> {
        Self::with_config(net, SimConfig::default())
    }

    pub fn with_config(net: &NetSpec, mut cfg: SimConfig) -> anyhow::Result<Self> {
        let compiled = compile_net(net).map_err(|e| anyhow::anyhow!("{e}"))?;
        cfg.dram_px = compiled.dram_px;
        Ok(Self { compiled, cfg })
    }

    /// Run one frame through a fresh accelerator instance; returns the
    /// output tensor and the run's statistics.
    pub fn run_frame(&self, frame: &Tensor) -> anyhow::Result<(Tensor, SimStats)> {
        let net = &self.compiled.net;
        anyhow::ensure!(
            frame.shape() == net.in_shape(),
            "frame shape {:?} != net input {:?}",
            frame.shape(),
            net.in_shape()
        );
        let mut accel = Accelerator::new(self.cfg.clone());
        accel.dram.data[..self.compiled.dram_init.len()]
            .copy_from_slice(&self.compiled.dram_init);
        // write the frame into the input canvas (HWC -> padded planar)
        let cv = &self.compiled.input;
        for ch in 0..frame.c {
            for y in 0..frame.h {
                for x in 0..frame.w {
                    accel.dram.data[cv.px(ch, y, x)] = frame.at(y, x, ch);
                }
            }
        }
        accel.run_program(&self.compiled.program)?;
        // extract the output canvas (planar -> HWC)
        let ov = &self.compiled.output;
        let mut out = Tensor::zeros(ov.h, ov.w, ov.c);
        for ch in 0..ov.c {
            for y in 0..ov.h {
                for x in 0..ov.w {
                    out.set(y, x, ch, accel.dram.data[ov.px(ch, y, x)]);
                }
            }
        }
        Ok((out, accel.stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::run_net_ref;
    use crate::model::zoo;

    #[test]
    fn quicknet_sim_matches_reference_bit_exactly() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(42, net.in_h, net.in_w, net.in_c);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got, want, "simulator output != reference");
        assert!(stats.macs > 0 && stats.cycles > 0);
    }

    #[test]
    fn facenet_sim_matches_reference_bit_exactly() {
        let net = zoo::facenet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(7, 64, 64, 1);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got, want, "simulator output != reference");
        // sanity: sim performs at least the net's real MACs (padding taps
        // and 16-feature rounding only add)
        let static_macs: u64 = net.total_ops() / 2;
        assert!(stats.macs >= static_macs, "sim must do at least the real MACs");
    }

    #[test]
    fn wrong_frame_shape_rejected() {
        let runner = NetRunner::new(&zoo::quicknet()).unwrap();
        assert!(runner.run_frame(&Tensor::zeros(4, 4, 1)).is_err());
    }
}
