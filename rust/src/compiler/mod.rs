//! Graph IR → decomposition plan → ISA command stream (the paper's §5
//! contribution, as a compiler) → segment-DAG execution.
//!
//! * [`decompose`] — the image/feature/channel decomposition solver.
//! * [`kernel_decomp`] — K×K → 3×3 tap enumeration (fixed CU array).
//! * [`codegen`] — graph → command program + DRAM image + the segment
//!   DAG (independently executable work units annotated with their
//!   producer→consumer dependencies).
//! * [`NetRunner`] — compile-once / run-many harness: pooled, reusable
//!   simulator instances (no per-frame SRAM/DRAM reallocation; the
//!   [`AccelPool`] can be shared across runners so one serving registry
//!   of heterogeneous nets recycles a single instance pool), a
//!   sequential path ([`NetRunner::run_frame`]) and a parallel path
//!   ([`NetRunner::run_frame_parallel`]) that executes the segment DAG
//!   over a worker pool with a ready-queue — a segment becomes runnable
//!   the moment its producers have stored, with **no layer barriers**,
//!   so fast tiles of one node overlap slow tiles of another and
//!   branch/residual topologies parallelize across branches.

pub mod codegen;
pub mod decompose;
pub mod kernel_decomp;

pub use codegen::{compile_graph, compile_net, CompiledNet, Segment};
pub use decompose::{plan_conv, Plan, PlanError};

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

use crate::model::{Graph, NetSpec, Tensor};
use crate::sim::accel::{SharedDram, StoreLog};
use crate::sim::{Accelerator, SimConfig, SimStats};

/// One scheduler event of a traced parallel run: a worker entered
/// (`enter == true`) or finished a segment. Events are globally ordered
/// (the trace lock serializes them), so "segment A started before
/// segment B finished" is a positional check — the overlap property the
/// DAG scheduler exists to create.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegTrace {
    pub seg: usize,
    pub node: usize,
    pub enter: bool,
}

/// Ready-queue state shared by the DAG workers.
struct Sched {
    queue: VecDeque<usize>,
    indeg: Vec<usize>,
    remaining: usize,
    /// Set when a worker panicked mid-segment: siblings must exit so
    /// the thread scope can join them and propagate the panic instead
    /// of deadlocking on a `remaining` count that will never drain.
    poisoned: bool,
}

/// Armed for the duration of one segment's execution; if the segment
/// panics, `Drop` runs during unwind and poisons the scheduler so the
/// other workers wake up and bail out.
struct PoisonGuard<'a> {
    sched: &'a Mutex<Sched>,
    cv: &'a Condvar,
    armed: bool,
}

impl Drop for PoisonGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // Avoid unwrap inside Drop: if the mutex itself is poisoned
            // the sibling workers' own `lock().unwrap()` already
            // propagates the panic.
            if let Ok(mut st) = self.sched.lock() {
                st.poisoned = true;
            }
            self.cv.notify_all();
        }
    }
}

/// Reusable simulator state: DRAM-less [`Accelerator`] instances plus
/// frame DRAM images, recycled across frames. Every [`NetRunner`] owns
/// one by default; a serving registry hands the *same* `Arc<AccelPool>`
/// to all its runners ([`NetRunner::share_pool`]) so heterogeneous nets
/// recycle one set of simulator instances instead of each net holding
/// a private idle pool — the instances are net-agnostic because the
/// frame image is attached only for the duration of one run.
#[derive(Default)]
pub struct AccelPool {
    /// DRAM-less instances (`cfg.dram_px == 0`), reusable by any runner
    /// whose timing knobs match.
    accels: Mutex<Vec<Accelerator>>,
    /// Frame DRAM images; handed out zeroed and exactly sized.
    drams: Mutex<Vec<Vec<i16>>>,
}

impl AccelPool {
    /// Pop a pooled instance whose timing config matches `cfg`, or
    /// build a fresh DRAM-less one. `dram_px` is ignored in the match:
    /// pooled instances never own DRAM — the runner attaches a frame
    /// image per run.
    fn take_accel(&self, cfg: &SimConfig) -> Accelerator {
        let mut pool = self.accels.lock().unwrap();
        let found = pool.iter().position(|a| {
            a.cfg.dram_latency == cfg.dram_latency
                && a.cfg.dram_bytes_per_cycle.to_bits() == cfg.dram_bytes_per_cycle.to_bits()
                && a.cfg.overlap_dma == cfg.overlap_dma
        });
        match found {
            Some(i) => pool.swap_remove(i),
            None => {
                drop(pool);
                Accelerator::new(SimConfig { dram_px: 0, ..cfg.clone() })
            }
        }
    }

    fn put_accel(&self, a: Accelerator) {
        self.accels.lock().unwrap().push(a);
    }

    /// A zero-filled DRAM image of exactly `px` pixels. Zeroing (not
    /// just resizing) is what makes cross-net reuse safe: another net's
    /// canvas layout must not leak into this frame's image.
    fn take_dram(&self, px: usize) -> Vec<i16> {
        let mut d = self.drams.lock().unwrap().pop().unwrap_or_default();
        d.clear();
        d.resize(px, 0);
        d
    }

    fn put_dram(&self, d: Vec<i16>) {
        self.drams.lock().unwrap().push(d);
    }
}

/// Compile-once / run-many harness around the simulator.
pub struct NetRunner {
    pub compiled: CompiledNet,
    cfg: SimConfig,
    /// Forward edges of the segment DAG: `dependents[i]` are the
    /// segments unblocked (in part) by segment `i` completing.
    dependents: Vec<Vec<usize>>,
    /// Initial dependency count per segment.
    indeg: Vec<usize>,
    /// Total commands covered by segments (the rest — `SetConv`s and
    /// the `Halt` — are accounted to the parallel totals directly).
    covered: usize,
    /// Reusable simulator instances + frame DRAM images — private by
    /// default, shared across runners in a registry.
    pool: Arc<AccelPool>,
}

impl NetRunner {
    pub fn new(net: &NetSpec) -> anyhow::Result<Self> {
        Self::with_config(net, SimConfig::default())
    }

    pub fn with_config(net: &NetSpec, cfg: SimConfig) -> anyhow::Result<Self> {
        Self::from_graph_with_config(&Graph::from_net(net), cfg)
    }

    pub fn from_graph(graph: &Graph) -> anyhow::Result<Self> {
        Self::from_graph_with_config(graph, SimConfig::default())
    }

    pub fn from_graph_with_config(graph: &Graph, mut cfg: SimConfig) -> anyhow::Result<Self> {
        let compiled = compile_graph(graph)?;
        cfg.dram_px = compiled.dram_px;
        let n = compiled.segments.len();
        let mut dependents = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (i, s) in compiled.segments.iter().enumerate() {
            indeg[i] = s.deps.len();
            for &d in &s.deps {
                dependents[d].push(i);
            }
        }
        let covered: usize = compiled.segments.iter().map(|s| s.end - s.start).sum();
        Ok(Self {
            compiled,
            cfg,
            dependents,
            indeg,
            covered,
            pool: Arc::new(AccelPool::default()),
        })
    }

    /// Replace this runner's private [`AccelPool`] with a shared one.
    /// A registry calls this on every runner it compiles, before any
    /// frame runs, so heterogeneous nets draw simulator instances and
    /// DRAM images from one pool.
    pub fn share_pool(&mut self, pool: Arc<AccelPool>) {
        self.pool = pool;
    }

    /// Bytes of DRAM image one in-flight frame of this net occupies
    /// (weights + all canvases) — the unit the serving registry's
    /// admission policy budgets.
    pub fn dram_frame_bytes(&self) -> usize {
        self.compiled.dram_px * std::mem::size_of::<i16>()
    }

    /// Write the frame and initial image into a DRAM backing store.
    fn init_dram(&self, dram: &mut [i16], frame: &Tensor) {
        dram[..self.compiled.dram_init.len()].copy_from_slice(&self.compiled.dram_init);
        // frame into the input canvas (HWC -> padded planar)
        let cv = &self.compiled.input;
        for ch in 0..frame.c {
            for y in 0..frame.h {
                for x in 0..frame.w {
                    dram[cv.px(ch, y, x)] = frame.at(y, x, ch);
                }
            }
        }
    }

    /// Extract the output canvas (planar -> HWC).
    fn extract_output(&self, dram: &[i16]) -> Tensor {
        let ov = &self.compiled.output;
        let mut out = Tensor::zeros(ov.h, ov.w, ov.c);
        for ch in 0..ov.c {
            for y in 0..ov.h {
                for x in 0..ov.w {
                    out.set(y, x, ch, dram[ov.px(ch, y, x)]);
                }
            }
        }
        out
    }

    fn check_frame(&self, frame: &Tensor) -> anyhow::Result<()> {
        anyhow::ensure!(
            frame.shape() == self.compiled.graph.in_shape(),
            "frame shape {:?} != net input {:?}",
            frame.shape(),
            self.compiled.graph.in_shape()
        );
        Ok(())
    }

    /// Run one frame through a pooled simulator instance; returns the
    /// output tensor and the run's statistics.
    pub fn run_frame(&self, frame: &Tensor) -> anyhow::Result<(Tensor, SimStats)> {
        self.check_frame(frame)?;
        let mut accel = self.pool.take_accel(&self.cfg);
        accel.reset_counters();
        let mut dram = self.pool.take_dram(self.compiled.dram_px);
        self.init_dram(&mut dram, frame);
        // Attach the frame image as the instance's DRAM for this run —
        // pooled instances are DRAM-less, which is what lets runners of
        // different nets (different DRAM footprints) share one pool.
        std::mem::swap(&mut accel.dram.data, &mut dram);
        // On error the instance is dropped (mid-program state is not
        // worth recycling); on success it returns to the pool.
        accel.run_program(&self.compiled.program)?;
        std::mem::swap(&mut accel.dram.data, &mut dram);
        let out = self.extract_output(&dram);
        let stats = accel.stats.clone();
        self.pool.put_accel(accel);
        self.pool.put_dram(dram);
        Ok((out, stats))
    }

    /// Run one frame with the segment DAG executed by up to `workers`
    /// simulator instances over a shared ready-queue: a segment is
    /// enqueued the moment its dependency count reaches zero, so
    /// consumer tiles start as soon as *their* producer tiles have
    /// stored — no per-node barrier, and independent branches run
    /// concurrently. Output **and** aggregated [`SimStats`] are
    /// bit-identical to [`run_frame`]: every counter delta is
    /// translation-invariant across the per-segment `Sync` barriers, so
    /// summing per-worker stats reproduces the sequential totals
    /// exactly, in any execution order the DAG admits.
    pub fn run_frame_parallel(
        &self,
        frame: &Tensor,
        workers: usize,
    ) -> anyhow::Result<(Tensor, SimStats)> {
        self.run_frame_dag(frame, workers, None)
    }

    /// [`NetRunner::run_frame_parallel`] with a scheduler trace — used
    /// by tests to prove cross-node overlap and by `--dump-graph`
    /// debugging.
    pub fn run_frame_parallel_traced(
        &self,
        frame: &Tensor,
        workers: usize,
    ) -> anyhow::Result<(Tensor, SimStats, Vec<SegTrace>)> {
        let trace = Mutex::new(Vec::new());
        let (out, stats) = self.run_frame_dag(frame, workers, Some(&trace))?;
        Ok((out, stats, trace.into_inner().unwrap()))
    }

    fn run_frame_dag(
        &self,
        frame: &Tensor,
        workers: usize,
        trace: Option<&Mutex<Vec<SegTrace>>>,
    ) -> anyhow::Result<(Tensor, SimStats)> {
        if workers <= 1 || self.compiled.segments.len() <= 1 {
            return self.run_frame(frame);
        }
        self.check_frame(frame)?;
        let mut dram = self.pool.take_dram(self.compiled.dram_px);
        self.init_dram(&mut dram, frame);

        let segments = &self.compiled.segments;
        let program = &self.compiled.program;
        let nworkers = workers.min(segments.len());
        let mut accels: Vec<Accelerator> = (0..nworkers)
            .map(|_| {
                let mut a = self.pool.take_accel(&self.cfg);
                a.reset_counters();
                a
            })
            .collect();

        let mut queue = VecDeque::new();
        for (i, &d) in self.indeg.iter().enumerate() {
            if d == 0 {
                queue.push_back(i);
            }
        }
        let sched = Mutex::new(Sched {
            queue,
            indeg: self.indeg.clone(),
            remaining: segments.len(),
            poisoned: false,
        });
        let cv = Condvar::new();
        // All conflicting pixel accesses through this handle are ordered
        // by the segment DAG: a consumer is enqueued only after its
        // producers published, under the scheduler mutex (release/
        // acquire = happens-before); unordered accesses are disjoint.
        let dram_cell = SharedDram::new(&mut dram);

        std::thread::scope(|scope| {
            let sched = &sched;
            let cv = &cv;
            let dram_cell = &dram_cell;
            let dependents = &self.dependents;
            let handles: Vec<_> = accels
                .iter_mut()
                .map(|accel| {
                    scope.spawn(move || {
                        let mut wlog = StoreLog::new();
                        loop {
                            let idx = {
                                let mut st = sched.lock().unwrap();
                                loop {
                                    if st.poisoned {
                                        return;
                                    }
                                    if let Some(i) = st.queue.pop_front() {
                                        break i;
                                    }
                                    if st.remaining == 0 {
                                        return;
                                    }
                                    st = cv.wait(st).unwrap();
                                }
                            };
                            let mut guard = PoisonGuard { sched, cv, armed: true };
                            let seg = &segments[idx];
                            if let Some(t) = trace {
                                t.lock().unwrap().push(SegTrace {
                                    seg: idx,
                                    node: seg.node,
                                    enter: true,
                                });
                            }
                            if let Some(cfg) = seg.cfg {
                                accel.set_conv_cfg(cfg);
                            }
                            for cmd in &program[seg.start..seg.end] {
                                accel.exec_shared(*cmd, dram_cell, &mut wlog);
                            }
                            for (dst, row) in wlog.drain(..) {
                                dram_cell.write(dst, &row);
                            }
                            if let Some(t) = trace {
                                t.lock().unwrap().push(SegTrace {
                                    seg: idx,
                                    node: seg.node,
                                    enter: false,
                                });
                            }
                            let mut st = sched.lock().unwrap();
                            st.remaining -= 1;
                            for &d in &dependents[idx] {
                                st.indeg[d] -= 1;
                                if st.indeg[d] == 0 {
                                    st.queue.push_back(d);
                                }
                            }
                            drop(st);
                            guard.armed = false;
                            cv.notify_all();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("tile worker panicked");
            }
        });

        // Merge per-worker stats; the SetConv/Halt commands living
        // outside the segments cost no cycles but are counted by the
        // sequential stream, so count them here too.
        let mut totals = SimStats {
            commands: (program.len() - self.covered) as u64,
            ..SimStats::default()
        };
        for mut a in accels {
            a.sync_stats();
            totals.add(&a.stats);
            a.reset_counters();
            self.pool.put_accel(a);
        }

        let out = self.extract_output(&dram);
        self.pool.put_dram(dram);
        Ok((out, totals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::{run_graph_ref, run_net_ref};
    use crate::model::zoo;

    #[test]
    fn quicknet_sim_matches_reference_bit_exactly() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(42, net.in_h, net.in_w, net.in_c);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got, want, "simulator output != reference");
        assert!(stats.macs > 0 && stats.cycles > 0);
    }

    #[test]
    fn facenet_sim_matches_reference_bit_exactly() {
        let net = zoo::facenet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(7, 64, 64, 1);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got, want, "simulator output != reference");
        // sanity: sim performs at least the net's real MACs (padding taps
        // and 16-feature rounding only add)
        let static_macs: u64 = net.total_ops() / 2;
        assert!(stats.macs >= static_macs, "sim must do at least the real MACs");
    }

    #[test]
    fn graph_nets_match_reference_bit_exactly() {
        for name in ["edgenet", "widenet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let runner = NetRunner::from_graph(&graph).unwrap();
            let frame = Tensor::random_image(3, graph.in_h, graph.in_w, graph.in_c);
            let (got, stats) = runner.run_frame(&frame).unwrap();
            assert_eq!(got, run_graph_ref(&graph, &frame), "{name}");
            assert!(stats.macs > 0);
        }
    }

    #[test]
    fn wrong_frame_shape_rejected() {
        let runner = NetRunner::new(&zoo::quicknet()).unwrap();
        assert!(runner.run_frame(&Tensor::zeros(4, 4, 1)).is_err());
        assert!(runner.run_frame_parallel(&Tensor::zeros(4, 4, 1), 4).is_err());
    }

    /// Pooled instance reuse must not leak state between frames: the
    /// same frame run twice gives identical output and stats, and two
    /// different frames stay independent.
    #[test]
    fn pooled_reuse_is_stateless_across_frames() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let f1 = Tensor::random_image(1, net.in_h, net.in_w, net.in_c);
        let f2 = Tensor::random_image(2, net.in_h, net.in_w, net.in_c);
        let (o1a, s1a) = runner.run_frame(&f1).unwrap();
        let (o2, _) = runner.run_frame(&f2).unwrap();
        let (o1b, s1b) = runner.run_frame(&f1).unwrap();
        assert_eq!(o1a, o1b, "reused instance changed the result");
        assert_eq!(s1a, s1b, "reused instance changed the stats");
        assert_eq!(o2, run_net_ref(&net, &f2));
    }

    /// Sharing one [`AccelPool`] across heterogeneous runners must not
    /// change results: pooled instances are DRAM-less and frame images
    /// are handed out zeroed, so nothing of one net's layout can leak
    /// into another's frame. Interleaves nets so instances and images
    /// actually hop between them.
    #[test]
    fn shared_pool_across_nets_is_bit_exact() {
        let pool = Arc::new(AccelPool::default());
        let mut runners = Vec::new();
        for name in ["quicknet", "edgenet", "widenet"] {
            let g = zoo::graph_by_name(name).unwrap();
            let mut r = NetRunner::from_graph(&g).unwrap();
            r.share_pool(Arc::clone(&pool));
            assert!(r.dram_frame_bytes() > 0);
            runners.push((g, r));
        }
        for s in 0..2u32 {
            for (g, r) in &runners {
                let f = Tensor::random_image(s, g.in_h, g.in_w, g.in_c);
                let want = run_graph_ref(g, &f);
                let (seq, _) = r.run_frame(&f).unwrap();
                assert_eq!(seq, want, "{} seed {s} sequential", g.name);
                let (par, _) = r.run_frame_parallel(&f, 3).unwrap();
                assert_eq!(par, want, "{} seed {s} parallel", g.name);
            }
        }
    }

    /// The tentpole invariant: DAG-parallel execution is bit-identical
    /// to the sequential run — output AND aggregated SimStats — for
    /// linear and graph topologies alike.
    #[test]
    fn parallel_dag_matches_sequential_bit_exactly() {
        for name in ["quicknet", "facenet", "edgenet", "widenet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let runner = NetRunner::from_graph(&graph).unwrap();
            let frame = Tensor::random_image(9, graph.in_h, graph.in_w, graph.in_c);
            let (seq, seq_stats) = runner.run_frame(&frame).unwrap();
            for workers in [2usize, 4] {
                let (par, par_stats) = runner.run_frame_parallel(&frame, workers).unwrap();
                assert_eq!(par, seq, "{name} workers={workers} output");
                assert_eq!(par_stats, seq_stats, "{name} workers={workers} stats");
            }
        }
    }
}
