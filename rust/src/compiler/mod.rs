//! Graph IR → decomposition plan → ISA command stream (the paper's §5
//! contribution, as a compiler) → segment-DAG execution.
//!
//! * [`decompose`] — the image/feature/channel decomposition solver.
//! * [`kernel_decomp`] — K×K → 3×3 tap enumeration (fixed CU array).
//! * [`codegen`] — graph → command program + DRAM image + the segment
//!   DAG (independently executable work units annotated with their
//!   producer→consumer dependencies).
//! * [`NetRunner`] — compile-once / run-many harness: pooled, reusable
//!   simulator instances (no per-frame SRAM/DRAM reallocation; the
//!   [`AccelPool`] can be shared across runners so one serving registry
//!   of heterogeneous nets recycles a single instance pool), a
//!   sequential path ([`NetRunner::run_frame`]) and a parallel path
//!   ([`NetRunner::run_frame_parallel`]) that executes the segment DAG
//!   over a worker pool with a ready-queue — a segment becomes runnable
//!   the moment its producers have stored, with **no layer barriers**,
//!   so fast tiles of one node overlap slow tiles of another and
//!   branch/residual topologies parallelize across branches.

pub mod codegen;
pub mod decompose;
pub mod kernel_decomp;

pub use codegen::{
    compile_graph, compile_graph_threads, compile_graph_with_options, compile_graph_with_plans,
    compile_net, CompileOptions, CompiledNet, Segment,
};
pub use decompose::{plan_conv, plan_conv_budget, plan_with_grid, Plan, PlanError};

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::model::{Graph, NetSpec, Tensor};
use crate::sim::accel::{SharedDram, StoreLog};
use crate::sim::{Accelerator, SimConfig, SimStats};
use crate::util::sync::{into_inner_recover, lock_recover};

/// One scheduler event of a traced parallel run: a worker entered
/// (`enter == true`) or finished a segment of frame `frame` (index
/// into the submitted window; always 0 for single-frame runs). Events
/// are globally ordered (the trace lock serializes them), so "segment
/// A started before segment B finished" is a positional check — within
/// one frame that is the branch-overlap property of the DAG scheduler,
/// across frames it is the cross-frame overlap the pipelined window
/// exists to create.
///
/// Each event also carries the tile worker that ran the segment and a
/// wall-clock timestamp (nanoseconds since the [`TraceTarget`] epoch);
/// exit events additionally carry the segment's measured `SimStats`
/// delta (`cycles`, `dma_stall_cycles`). The observability layer
/// (`crate::obs`) pairs enter/exit events into per-track spans.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SegTrace {
    pub frame: usize,
    pub seg: usize,
    pub node: usize,
    pub enter: bool,
    /// Tile worker (DAG executor index) that ran the segment.
    pub worker: usize,
    /// Nanoseconds since the trace epoch at which the event occurred.
    pub t_ns: u64,
    /// Measured segment cycles (exit events only; 0 on enter).
    pub cycles: u64,
    /// Measured non-hidden DMA stall cycles (exit events only).
    pub dma_stall_cycles: u64,
}

/// Collector handed to the traced run paths: an epoch for timestamping
/// plus the shared event vector. The epoch can be shared with an
/// observability sink (`obs::TraceSink`) so events from many runs land
/// on one timeline. All locking is poison-tolerant (`lock_recover`): a
/// panicked tile worker must not cascade into every other worker that
/// merely wants to record what it ran — the trace is precisely the
/// artifact you want intact *after* a crash.
pub struct TraceTarget {
    epoch: Instant,
    events: Mutex<Vec<SegTrace>>,
}

impl Default for TraceTarget {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceTarget {
    pub fn new() -> Self {
        Self::with_epoch(Instant::now())
    }

    /// A target whose timestamps are relative to `epoch` (share one
    /// epoch across runs to get one coherent timeline).
    pub fn with_epoch(epoch: Instant) -> Self {
        Self { epoch, events: Mutex::new(Vec::new()) }
    }

    /// Nanoseconds since the epoch, saturating (monotonic clock).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    fn push(&self, e: SegTrace) {
        lock_recover(&self.events).push(e);
    }

    /// Consume the target, returning the recorded events (poison-safe).
    pub fn take(self) -> Vec<SegTrace> {
        into_inner_recover(self.events)
    }
}

/// Scheduler state of one in-flight frame — one slot of the rolling
/// pipeline window. The slot owns a full per-frame DRAM image
/// (weights + canvases); when its frame drains, the worker that
/// completed the last segment extracts the output and re-arms the
/// slot with the next admitted frame.
struct SlotState {
    /// Index (into the submitted window) of the frame this slot runs.
    frame: usize,
    /// Remaining-dependency count per segment, this frame's DAG copy.
    indeg: Vec<usize>,
    /// Segments of this frame not yet completed.
    remaining: usize,
    /// Sum of this frame's completed segment deltas. Every segment
    /// ends on `Sync`, so deltas are translation-invariant and the
    /// per-frame sum reproduces the sequential frame bit-for-bit.
    stats: SimStats,
}

/// Ready-queue state shared by the DAG workers: a rolling window of up
/// to `depth` in-flight frames, each with its own DAG copy, keyed into
/// one FIFO as `(slot, segment)`. Frame N+1's zero-indegree segments
/// sit in the queue the moment slot N+1 is armed, so they start on
/// idle workers while frame N's tail segments drain — the cross-frame
/// pipelining the paper's streaming design uses to keep the datapath
/// fed across frame boundaries.
struct Sched {
    queue: VecDeque<(usize, usize)>,
    /// One entry per window slot; `None` while the completing worker
    /// holds the slot outside the lock (extract + re-arm).
    slots: Vec<Option<SlotState>>,
    /// Next frame of the window not yet admitted to a slot.
    next_frame: usize,
    /// Frames fully completed (output extracted by their last worker).
    done: usize,
    total: usize,
    /// Set when a worker panicked mid-segment: siblings must exit so
    /// the thread scope can join them and propagate the panic instead
    /// of deadlocking on counts that will never drain.
    poisoned: bool,
}

/// Armed for the duration of one segment's execution; if the segment
/// panics, `Drop` runs during unwind and poisons the scheduler so the
/// other workers wake up and bail out.
struct PoisonGuard<'a> {
    sched: &'a Mutex<Sched>,
    cv: &'a Condvar,
    armed: bool,
}

impl Drop for PoisonGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            // Avoid unwrap inside Drop: if the mutex itself is poisoned
            // the sibling workers' own `lock().unwrap()` already
            // propagates the panic.
            if let Ok(mut st) = self.sched.lock() {
                st.poisoned = true;
            }
            self.cv.notify_all();
        }
    }
}

/// Reusable simulator state: DRAM-less [`Accelerator`] instances plus
/// frame DRAM images, recycled across frames. Every [`NetRunner`] owns
/// one by default; a serving registry hands the *same* `Arc<AccelPool>`
/// to all its runners ([`NetRunner::share_pool`]) so heterogeneous nets
/// recycle one set of simulator instances instead of each net holding
/// a private idle pool — the instances are net-agnostic because the
/// frame image is attached only for the duration of one run.
#[derive(Default)]
pub struct AccelPool {
    /// DRAM-less instances (`cfg.dram_px == 0`), reusable by any runner
    /// whose timing knobs match.
    accels: Mutex<Vec<Accelerator>>,
    /// Frame DRAM images; handed out zeroed and exactly sized.
    drams: Mutex<Vec<Vec<i16>>>,
}

impl AccelPool {
    /// Pop a pooled instance whose timing config matches `cfg`, or
    /// build a fresh DRAM-less one. `dram_px` is ignored in the match:
    /// pooled instances never own DRAM — the runner attaches a frame
    /// image per run.
    fn take_accel(&self, cfg: &SimConfig) -> Accelerator {
        let mut pool = self.accels.lock().unwrap();
        let found = pool.iter().position(|a| {
            a.cfg.dram_latency == cfg.dram_latency
                && a.cfg.dram_bytes_per_cycle.to_bits() == cfg.dram_bytes_per_cycle.to_bits()
                && a.cfg.overlap_dma == cfg.overlap_dma
        });
        match found {
            Some(i) => pool.swap_remove(i),
            None => {
                drop(pool);
                Accelerator::new(SimConfig { dram_px: 0, ..cfg.clone() })
            }
        }
    }

    fn put_accel(&self, a: Accelerator) {
        self.accels.lock().unwrap().push(a);
    }

    /// A zero-filled DRAM image of exactly `px` pixels. Zeroing (not
    /// just resizing) is what makes cross-net reuse safe: another net's
    /// canvas layout must not leak into this frame's image.
    fn take_dram(&self, px: usize) -> Vec<i16> {
        let mut d = self.drams.lock().unwrap().pop().unwrap_or_default();
        d.clear();
        d.resize(px, 0);
        d
    }

    fn put_dram(&self, d: Vec<i16>) {
        self.drams.lock().unwrap().push(d);
    }
}

/// Compile-once / run-many harness around the simulator.
pub struct NetRunner {
    pub compiled: CompiledNet,
    cfg: SimConfig,
    /// Forward edges of the segment DAG: `dependents[i]` are the
    /// segments unblocked (in part) by segment `i` completing.
    dependents: Vec<Vec<usize>>,
    /// Initial dependency count per segment.
    indeg: Vec<usize>,
    /// Total commands covered by segments (the rest — `SetConv`s and
    /// the `Halt` — are accounted to the parallel totals directly).
    covered: usize,
    /// Reusable simulator instances + frame DRAM images — private by
    /// default, shared across runners in a registry.
    pool: Arc<AccelPool>,
}

impl NetRunner {
    pub fn new(net: &NetSpec) -> anyhow::Result<Self> {
        Self::with_config(net, SimConfig::default())
    }

    pub fn with_config(net: &NetSpec, cfg: SimConfig) -> anyhow::Result<Self> {
        Self::from_graph_with_config(&Graph::from_net(net), cfg)
    }

    pub fn from_graph(graph: &Graph) -> anyhow::Result<Self> {
        Self::from_graph_with_config(graph, SimConfig::default())
    }

    pub fn from_graph_with_config(graph: &Graph, cfg: SimConfig) -> anyhow::Result<Self> {
        Self::from_compiled(compile_graph(graph)?, cfg)
    }

    /// Compile with a planner policy (`planner::PlanPolicy`): the
    /// decomposition plans come from `planner::plan_graph` instead of
    /// the per-node heuristic. `Heuristic` is byte-identical to
    /// [`NetRunner::from_graph`].
    pub fn from_graph_with_policy(
        graph: &Graph,
        policy: crate::planner::PlanPolicy,
    ) -> anyhow::Result<Self> {
        Self::from_graph_with_config_policy(graph, SimConfig::default(), policy)
    }

    /// [`NetRunner::from_graph_with_policy`] with an explicit plan
    /// objective, default sim config.
    pub fn from_graph_with_policy_objective(
        graph: &Graph,
        policy: crate::planner::PlanPolicy,
        objective: crate::planner::PlanObjective,
    ) -> anyhow::Result<Self> {
        Self::from_graph_with_config_policy_objective(
            graph,
            SimConfig::default(),
            policy,
            objective,
        )
    }

    /// [`NetRunner::from_graph_with_policy`] with explicit sim config.
    pub fn from_graph_with_config_policy(
        graph: &Graph,
        cfg: SimConfig,
        policy: crate::planner::PlanPolicy,
    ) -> anyhow::Result<Self> {
        Self::from_graph_with_config_policy_objective(
            graph,
            cfg,
            policy,
            crate::planner::PlanObjective::MinTraffic,
        )
    }

    /// [`NetRunner::from_graph_with_config_policy`] with an explicit
    /// plan objective (what a searching policy minimizes: traffic,
    /// latency, energy under an SLO, or EDP at an operating point).
    /// `Heuristic` ignores the objective — it never scores plans.
    pub fn from_graph_with_config_policy_objective(
        graph: &Graph,
        cfg: SimConfig,
        policy: crate::planner::PlanPolicy,
        objective: crate::planner::PlanObjective,
    ) -> anyhow::Result<Self> {
        let compiled = match policy {
            crate::planner::PlanPolicy::Heuristic => compile_graph(graph)?,
            _ => {
                let gp = crate::planner::plan_graph_objective(graph, policy, objective)?;
                codegen::compile_graph_with_plans(graph, &gp.plans)?
            }
        };
        Self::from_compiled(compiled, cfg)
    }

    /// Build a runner around an already-compiled net (e.g. one produced
    /// by [`compile_graph_with_plans`] with planner-chosen plans).
    pub fn from_compiled(compiled: CompiledNet, mut cfg: SimConfig) -> anyhow::Result<Self> {
        cfg.dram_px = compiled.dram_px;
        let n = compiled.segments.len();
        let mut dependents = vec![Vec::new(); n];
        let mut indeg = vec![0usize; n];
        for (i, s) in compiled.segments.iter().enumerate() {
            indeg[i] = s.deps.len();
            for &d in &s.deps {
                dependents[d].push(i);
            }
        }
        let covered: usize = compiled.segments.iter().map(|s| s.end - s.start).sum();
        Ok(Self {
            compiled,
            cfg,
            dependents,
            indeg,
            covered,
            pool: Arc::new(AccelPool::default()),
        })
    }

    /// Replace this runner's private [`AccelPool`] with a shared one.
    /// A registry calls this on every runner it compiles, before any
    /// frame runs, so heterogeneous nets draw simulator instances and
    /// DRAM images from one pool.
    pub fn share_pool(&mut self, pool: Arc<AccelPool>) {
        self.pool = pool;
    }

    /// Bytes of DRAM image one in-flight frame of this net occupies
    /// (weights + all canvases) — the unit the serving registry's
    /// admission policy budgets.
    pub fn dram_frame_bytes(&self) -> usize {
        self.compiled.dram_px * std::mem::size_of::<i16>()
    }

    /// Write the frame and initial image into a DRAM backing store.
    fn init_dram(&self, dram: &mut [i16], frame: &Tensor) {
        self.init_dram_shared(&SharedDram::new(dram), frame);
    }

    /// Extract the output canvas (planar -> HWC).
    fn extract_output(&self, dram: &mut [i16]) -> Tensor {
        self.extract_output_shared(&SharedDram::new(dram))
    }

    /// The one implementation of "frame image → DRAM" (full-image
    /// rewrite + HWC → padded-planar input), through a [`SharedDram`]
    /// handle so the pipelined scheduler can re-arm a drained slot in
    /// place. Caller must hold exclusive logical ownership of the
    /// backing store (for a slot: previous frame fully completed,
    /// nothing enqueued); the full-image rewrite also re-zeroes the
    /// activation canvases, so nothing of the previous frame can leak
    /// into this one.
    fn init_dram_shared(&self, dram: &SharedDram, frame: &Tensor) {
        dram.write(0, &self.compiled.dram_init);
        let cv = &self.compiled.input;
        let mut row = vec![0i16; frame.w];
        for ch in 0..frame.c {
            for y in 0..frame.h {
                for (x, px) in row.iter_mut().enumerate() {
                    *px = frame.at(y, x, ch);
                }
                dram.write(cv.px(ch, y, 0), &row);
            }
        }
    }

    /// The one implementation of "DRAM → output tensor" (padded planar
    /// → HWC), same exclusive-ownership contract as
    /// [`Self::init_dram_shared`].
    fn extract_output_shared(&self, dram: &SharedDram) -> Tensor {
        let ov = &self.compiled.output;
        let mut out = Tensor::zeros(ov.h, ov.w, ov.c);
        let mut row = vec![0i16; ov.w];
        for ch in 0..ov.c {
            for y in 0..ov.h {
                dram.read_into(ov.px(ch, y, 0), &mut row);
                for (x, px) in row.iter().enumerate() {
                    out.set(y, x, ch, *px);
                }
            }
        }
        out
    }

    /// Check that `frame` matches this net's input shape. Public so the
    /// coordinator can pre-validate a pipelined window: one malformed
    /// frame gets its own delivered error instead of poisoning the
    /// window it rode in with.
    pub fn check_frame(&self, frame: &Tensor) -> anyhow::Result<()> {
        anyhow::ensure!(
            frame.shape() == self.compiled.graph.in_shape(),
            "frame shape {:?} != net input {:?}",
            frame.shape(),
            self.compiled.graph.in_shape()
        );
        Ok(())
    }

    /// Run one frame through a pooled simulator instance; returns the
    /// output tensor and the run's statistics.
    pub fn run_frame(&self, frame: &Tensor) -> anyhow::Result<(Tensor, SimStats)> {
        self.run_frame_on(&self.pool, frame)
    }

    /// [`Self::run_frame`] drawing instances and DRAM images from an
    /// explicit pool instead of the runner's own. The chip-sharded
    /// coordinator compiles each net once and serves it on every chip's
    /// *private* pool — a chip is a fault domain precisely because no
    /// simulator state crosses this argument.
    pub fn run_frame_on(
        &self,
        pool: &AccelPool,
        frame: &Tensor,
    ) -> anyhow::Result<(Tensor, SimStats)> {
        self.check_frame(frame)?;
        let mut accel = pool.take_accel(&self.cfg);
        accel.reset_counters();
        let mut dram = pool.take_dram(self.compiled.dram_px);
        self.init_dram(&mut dram, frame);
        // Attach the frame image as the instance's DRAM for this run —
        // pooled instances are DRAM-less, which is what lets runners of
        // different nets (different DRAM footprints) share one pool.
        std::mem::swap(&mut accel.dram.data, &mut dram);
        // On error the instance is dropped (mid-program state is not
        // worth recycling); on success it returns to the pool.
        accel.run_program(&self.compiled.program)?;
        std::mem::swap(&mut accel.dram.data, &mut dram);
        let out = self.extract_output(&mut dram);
        let stats = accel.stats.clone();
        pool.put_accel(accel);
        pool.put_dram(dram);
        Ok((out, stats))
    }

    /// Run one frame sequentially, attributing [`SimStats`] deltas to
    /// the graph node whose segment produced them — the measured side
    /// of the planner's predicted-vs-measured tables. Executes the
    /// segments in emission (topological) order through the shared-DRAM
    /// path, exactly like a one-worker DAG run: output and summed stats
    /// match [`NetRunner::run_frame`] (per-segment deltas are
    /// translation-invariant across the `Sync` barriers); only the
    /// `SetConv`/`Halt` command count lives outside any node.
    pub fn run_frame_node_stats(&self, frame: &Tensor) -> anyhow::Result<(Tensor, Vec<SimStats>)> {
        self.check_frame(frame)?;
        let mut accel = self.pool.take_accel(&self.cfg);
        let mut dram = self.pool.take_dram(self.compiled.dram_px);
        self.init_dram(&mut dram, frame);
        let mut per_node = vec![SimStats::default(); self.compiled.graph.nodes.len()];
        {
            let cell = SharedDram::new(&mut dram);
            let mut wlog = StoreLog::new();
            for seg in &self.compiled.segments {
                accel.reset_counters();
                if let Some(cfg) = seg.cfg {
                    accel.set_conv_cfg(cfg);
                }
                for cmd in &self.compiled.program[seg.start..seg.end] {
                    accel.exec_shared(*cmd, &cell, &mut wlog);
                }
                for (dst, row) in wlog.drain(..) {
                    cell.write(dst, &row);
                }
                accel.sync_stats();
                per_node[seg.node].add(&accel.stats);
            }
        }
        let out = self.extract_output(&mut dram);
        accel.reset_counters();
        self.pool.put_accel(accel);
        self.pool.put_dram(dram);
        Ok((out, per_node))
    }

    /// Run one frame with the segment DAG executed by up to `workers`
    /// simulator instances over a shared ready-queue: a segment is
    /// enqueued the moment its dependency count reaches zero, so
    /// consumer tiles start as soon as *their* producer tiles have
    /// stored — no per-node barrier, and independent branches run
    /// concurrently. Output **and** aggregated [`SimStats`] are
    /// bit-identical to [`run_frame`]: every counter delta is
    /// translation-invariant across the per-segment `Sync` barriers, so
    /// summing per-segment stats reproduces the sequential totals
    /// exactly, in any execution order the DAG admits.
    ///
    /// This is [`NetRunner::run_frames_pipelined`] with a window of one.
    pub fn run_frame_parallel(
        &self,
        frame: &Tensor,
        workers: usize,
    ) -> anyhow::Result<(Tensor, SimStats)> {
        let mut v = self.run_window(&self.pool, &[frame], workers, 1, None)?;
        Ok(v.pop().expect("one frame in, one result out"))
    }

    /// [`NetRunner::run_frame_parallel`] with a scheduler trace — used
    /// by tests to prove cross-node overlap and by `--dump-graph`
    /// debugging. Trace events carry `frame == 0`.
    pub fn run_frame_parallel_traced(
        &self,
        frame: &Tensor,
        workers: usize,
    ) -> anyhow::Result<(Tensor, SimStats, Vec<SegTrace>)> {
        let trace = TraceTarget::new();
        let mut v = self.run_window(&self.pool, &[frame], workers, 1, Some(&trace))?;
        let (out, stats) = v.pop().expect("one frame in, one result out");
        Ok((out, stats, trace.take()))
    }

    /// Run a stream of frames through the **cross-frame pipelined**
    /// scheduler: a rolling window of up to `depth` in-flight frames,
    /// each owning a private DRAM image (weights + canvases), all
    /// feeding one `(frame, segment)` ready-queue executed by up to
    /// `workers` simulator instances. Frame N+1's early segments start
    /// on idle workers as soon as slot N+1 is armed, while frame N's
    /// tail segments drain — the frame-boundary stall of the per-frame
    /// DAG disappears, which is exactly the streaming behaviour the
    /// paper's image/feature decomposition exists to sustain.
    ///
    /// Results come back in submission order. Per-frame output **and**
    /// per-frame [`SimStats`] are bit-identical to running each frame
    /// through [`run_frame`](Self::run_frame) alone: segment stat
    /// deltas are translation-invariant (every segment ends on `Sync`)
    /// and are attributed to the frame that ran them, so neither
    /// pipelining depth, worker count, nor completion interleaving can
    /// perturb a frame's numbers.
    pub fn run_frames_pipelined(
        &self,
        frames: &[Tensor],
        workers: usize,
        depth: usize,
    ) -> anyhow::Result<Vec<(Tensor, SimStats)>> {
        let refs: Vec<&Tensor> = frames.iter().collect();
        self.run_window(&self.pool, &refs, workers, depth, None)
    }

    /// Refs-taking variant of [`Self::run_frames_pipelined`] for
    /// callers that already own the frames scattered across other
    /// structures (the coordinator's window jobs) and must not
    /// deep-copy every image per window.
    pub fn run_frames_pipelined_ref(
        &self,
        frames: &[&Tensor],
        workers: usize,
        depth: usize,
    ) -> anyhow::Result<Vec<(Tensor, SimStats)>> {
        self.run_window(&self.pool, frames, workers, depth, None)
    }

    /// [`Self::run_frames_pipelined_ref`] on an explicit pool — the
    /// window-serving path of the chip-sharded coordinator, where each
    /// chip executes windows against its own [`AccelPool`].
    pub fn run_frames_pipelined_ref_on(
        &self,
        pool: &AccelPool,
        frames: &[&Tensor],
        workers: usize,
        depth: usize,
    ) -> anyhow::Result<Vec<(Tensor, SimStats)>> {
        self.run_window(pool, frames, workers, depth, None)
    }

    /// [`NetRunner::run_frames_pipelined`] with a scheduler trace whose
    /// events carry the frame index — the instrument that proves
    /// cross-frame segment overlap (a frame-N+1 `enter` positioned
    /// before frame-N's last exit).
    pub fn run_frames_pipelined_traced(
        &self,
        frames: &[Tensor],
        workers: usize,
        depth: usize,
    ) -> anyhow::Result<(Vec<(Tensor, SimStats)>, Vec<SegTrace>)> {
        let trace = TraceTarget::new();
        let refs: Vec<&Tensor> = frames.iter().collect();
        let outs = self.run_window(&self.pool, &refs, workers, depth, Some(&trace))?;
        Ok((outs, trace.take()))
    }

    /// Refs-taking traced run against the runner's own pool, recording
    /// into a caller-owned [`TraceTarget`] (so many runs can share one
    /// epoch/timeline). Used by the observability layer.
    pub fn run_frames_pipelined_ref_traced(
        &self,
        frames: &[&Tensor],
        workers: usize,
        depth: usize,
        trace: &TraceTarget,
    ) -> anyhow::Result<Vec<(Tensor, SimStats)>> {
        self.run_window(&self.pool, frames, workers, depth, Some(trace))
    }

    /// [`Self::run_frames_pipelined_ref_traced`] on an explicit pool —
    /// the traced window-serving path of the chip-sharded coordinator.
    pub fn run_frames_pipelined_ref_traced_on(
        &self,
        pool: &AccelPool,
        frames: &[&Tensor],
        workers: usize,
        depth: usize,
        trace: &TraceTarget,
    ) -> anyhow::Result<Vec<(Tensor, SimStats)>> {
        self.run_window(pool, frames, workers, depth, Some(trace))
    }

    /// The scheduler core: execute a rolling window of per-frame
    /// segment DAGs. `depth` bounds the in-flight frames (window
    /// slots); each slot owns one pooled DRAM image, re-armed in place
    /// when its frame completes. With `workers <= 1` (or a single
    /// segment) the window degenerates to the sequential per-frame
    /// path, which is the reference behaviour by definition.
    fn run_window(
        &self,
        pool: &AccelPool,
        frames: &[&Tensor],
        workers: usize,
        depth: usize,
        trace: Option<&TraceTarget>,
    ) -> anyhow::Result<Vec<(Tensor, SimStats)>> {
        for f in frames {
            self.check_frame(f)?;
        }
        if frames.is_empty() {
            return Ok(Vec::new());
        }
        let nseg = self.compiled.segments.len();
        if workers <= 1 || nseg <= 1 {
            return frames.iter().map(|f| self.run_frame_on(pool, f)).collect();
        }

        let segments = &self.compiled.segments;
        let program = &self.compiled.program;
        // SetConv/Halt live outside the segments; the sequential stream
        // counts them once per frame, so each frame's stats do too.
        let uncovered = (program.len() - self.covered) as u64;

        // One DRAM image per window slot, pre-armed with the first
        // `nslots` frames of the window.
        let nslots = depth.clamp(1, frames.len());
        let mut slot_drams: Vec<Vec<i16>> = (0..nslots)
            .map(|s| {
                let mut d = pool.take_dram(self.compiled.dram_px);
                self.init_dram(&mut d, frames[s]);
                d
            })
            .collect();

        let nworkers = workers.min(nseg * nslots);
        let mut accels: Vec<Accelerator> = (0..nworkers)
            .map(|_| {
                let mut a = pool.take_accel(&self.cfg);
                a.reset_counters();
                a
            })
            .collect();

        let mut queue = VecDeque::new();
        let mut slots = Vec::with_capacity(nslots);
        for s in 0..nslots {
            for (i, &d) in self.indeg.iter().enumerate() {
                if d == 0 {
                    queue.push_back((s, i));
                }
            }
            slots.push(Some(SlotState {
                frame: s,
                indeg: self.indeg.clone(),
                remaining: nseg,
                stats: SimStats::default(),
            }));
        }
        let sched = Mutex::new(Sched {
            queue,
            slots,
            next_frame: nslots,
            done: 0,
            total: frames.len(),
            poisoned: false,
        });
        let cv = Condvar::new();
        // All conflicting pixel accesses through these handles are
        // ordered by the per-frame segment DAG: a consumer is enqueued
        // only after its producers published, under the scheduler mutex
        // (release/acquire = happens-before); unordered accesses are
        // disjoint, and distinct slots are distinct allocations.
        let dram_cells: Vec<SharedDram> =
            slot_drams.iter_mut().map(|d| SharedDram::new(d)).collect();
        let results: Mutex<Vec<Option<(Tensor, SimStats)>>> =
            Mutex::new((0..frames.len()).map(|_| None).collect());

        std::thread::scope(|scope| {
            let sched = &sched;
            let cv = &cv;
            let dram_cells = &dram_cells;
            let results = &results;
            let dependents = &self.dependents;
            let handles: Vec<_> = accels
                .iter_mut()
                .enumerate()
                .map(|(wid, accel)| {
                    scope.spawn(move || {
                        let mut wlog = StoreLog::new();
                        loop {
                            let (slot, idx, frame_id) = {
                                let mut st = sched.lock().unwrap();
                                loop {
                                    if st.poisoned {
                                        return;
                                    }
                                    if let Some((s, i)) = st.queue.pop_front() {
                                        let f = st.slots[s]
                                            .as_ref()
                                            .expect("queued slot is armed")
                                            .frame;
                                        break (s, i, f);
                                    }
                                    if st.done == st.total {
                                        return;
                                    }
                                    st = cv.wait(st).unwrap();
                                }
                            };
                            let mut guard = PoisonGuard { sched, cv, armed: true };
                            let seg = &segments[idx];
                            let dram_cell = &dram_cells[slot];
                            if let Some(t) = trace {
                                t.push(SegTrace {
                                    frame: frame_id,
                                    seg: idx,
                                    node: seg.node,
                                    enter: true,
                                    worker: wid,
                                    t_ns: t.now_ns(),
                                    cycles: 0,
                                    dma_stall_cycles: 0,
                                });
                            }
                            // Per-segment counter reset: the delta this
                            // segment charges is attributed to *its*
                            // frame, which is what keeps per-frame stats
                            // exact under any cross-frame interleaving.
                            accel.reset_counters();
                            if let Some(cfg) = seg.cfg {
                                accel.set_conv_cfg(cfg);
                            }
                            for cmd in &program[seg.start..seg.end] {
                                accel.exec_shared(*cmd, dram_cell, &mut wlog);
                            }
                            for (dst, row) in wlog.drain(..) {
                                dram_cell.write(dst, &row);
                            }
                            accel.sync_stats();
                            let delta = accel.stats.clone();
                            if let Some(t) = trace {
                                t.push(SegTrace {
                                    frame: frame_id,
                                    seg: idx,
                                    node: seg.node,
                                    enter: false,
                                    worker: wid,
                                    t_ns: t.now_ns(),
                                    cycles: delta.cycles,
                                    dma_stall_cycles: delta.dma_stall_cycles,
                                });
                            }

                            let mut st = sched.lock().unwrap();
                            let mut ready: Vec<usize> = Vec::new();
                            let slot_done = {
                                let s = st.slots[slot]
                                    .as_mut()
                                    .expect("slot stays armed while its segment runs");
                                s.stats.add(&delta);
                                for &d in &dependents[idx] {
                                    s.indeg[d] -= 1;
                                    if s.indeg[d] == 0 {
                                        ready.push(d);
                                    }
                                }
                                s.remaining -= 1;
                                s.remaining == 0
                            };
                            for d in ready {
                                st.queue.push_back((slot, d));
                            }
                            if slot_done {
                                // This worker drains the slot outside the
                                // lock (it owns the slot exclusively: the
                                // frame has no segments left anywhere),
                                // then re-arms it with the next frame.
                                let fin =
                                    st.slots[slot].take().expect("completing slot is armed");
                                let next = (st.next_frame < st.total).then(|| {
                                    st.next_frame += 1;
                                    st.next_frame - 1
                                });
                                drop(st);
                                let mut stats = fin.stats;
                                stats.commands += uncovered;
                                let out = self.extract_output_shared(dram_cell);
                                results.lock().unwrap()[fin.frame] = Some((out, stats));
                                if let Some(f) = next {
                                    self.init_dram_shared(dram_cell, frames[f]);
                                }
                                let mut st = sched.lock().unwrap();
                                if let Some(f) = next {
                                    for (i, &d) in self.indeg.iter().enumerate() {
                                        if d == 0 {
                                            st.queue.push_back((slot, i));
                                        }
                                    }
                                    st.slots[slot] = Some(SlotState {
                                        frame: f,
                                        indeg: self.indeg.clone(),
                                        remaining: nseg,
                                        stats: SimStats::default(),
                                    });
                                }
                                st.done += 1;
                                drop(st);
                            } else {
                                drop(st);
                            }
                            guard.armed = false;
                            cv.notify_all();
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().expect("tile worker panicked");
            }
        });

        drop(dram_cells);
        for mut a in accels {
            a.reset_counters();
            pool.put_accel(a);
        }
        for d in slot_drams {
            pool.put_dram(d);
        }
        let results = results.into_inner().unwrap();
        Ok(results
            .into_iter()
            .map(|r| r.expect("every frame of the window completed"))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::{run_graph_ref, run_net_ref};
    use crate::model::zoo;

    #[test]
    fn quicknet_sim_matches_reference_bit_exactly() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(42, net.in_h, net.in_w, net.in_c);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got, want, "simulator output != reference");
        assert!(stats.macs > 0 && stats.cycles > 0);
    }

    #[test]
    fn facenet_sim_matches_reference_bit_exactly() {
        let net = zoo::facenet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(7, 64, 64, 1);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got, want, "simulator output != reference");
        // sanity: sim performs at least the net's real MACs (padding taps
        // and 16-feature rounding only add)
        let static_macs: u64 = net.total_ops() / 2;
        assert!(stats.macs >= static_macs, "sim must do at least the real MACs");
    }

    #[test]
    fn graph_nets_match_reference_bit_exactly() {
        for name in ["edgenet", "widenet", "gapnet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let runner = NetRunner::from_graph(&graph).unwrap();
            let frame = Tensor::random_image(3, graph.in_h, graph.in_w, graph.in_c);
            let (got, stats) = runner.run_frame(&frame).unwrap();
            assert_eq!(got, run_graph_ref(&graph, &frame), "{name}");
            assert!(stats.macs > 0);
        }
    }

    #[test]
    fn wrong_frame_shape_rejected() {
        let runner = NetRunner::new(&zoo::quicknet()).unwrap();
        assert!(runner.run_frame(&Tensor::zeros(4, 4, 1)).is_err());
        assert!(runner.run_frame_parallel(&Tensor::zeros(4, 4, 1), 4).is_err());
    }

    /// Pooled instance reuse must not leak state between frames: the
    /// same frame run twice gives identical output and stats, and two
    /// different frames stay independent.
    #[test]
    fn pooled_reuse_is_stateless_across_frames() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let f1 = Tensor::random_image(1, net.in_h, net.in_w, net.in_c);
        let f2 = Tensor::random_image(2, net.in_h, net.in_w, net.in_c);
        let (o1a, s1a) = runner.run_frame(&f1).unwrap();
        let (o2, _) = runner.run_frame(&f2).unwrap();
        let (o1b, s1b) = runner.run_frame(&f1).unwrap();
        assert_eq!(o1a, o1b, "reused instance changed the result");
        assert_eq!(s1a, s1b, "reused instance changed the stats");
        assert_eq!(o2, run_net_ref(&net, &f2));
    }

    /// Sharing one [`AccelPool`] across heterogeneous runners must not
    /// change results: pooled instances are DRAM-less and frame images
    /// are handed out zeroed, so nothing of one net's layout can leak
    /// into another's frame. Interleaves nets so instances and images
    /// actually hop between them.
    #[test]
    fn shared_pool_across_nets_is_bit_exact() {
        let pool = Arc::new(AccelPool::default());
        let mut runners = Vec::new();
        for name in ["quicknet", "edgenet", "widenet"] {
            let g = zoo::graph_by_name(name).unwrap();
            let mut r = NetRunner::from_graph(&g).unwrap();
            r.share_pool(Arc::clone(&pool));
            assert!(r.dram_frame_bytes() > 0);
            runners.push((g, r));
        }
        for s in 0..2u32 {
            for (g, r) in &runners {
                let f = Tensor::random_image(s, g.in_h, g.in_w, g.in_c);
                let want = run_graph_ref(g, &f);
                let (seq, _) = r.run_frame(&f).unwrap();
                assert_eq!(seq, want, "{} seed {s} sequential", g.name);
                let (par, _) = r.run_frame_parallel(&f, 3).unwrap();
                assert_eq!(par, want, "{} seed {s} parallel", g.name);
            }
        }
    }

    /// The chip-sharded serving contract: one compiled runner executed
    /// against several *distinct* pools (one per chip) yields
    /// bit-identical outputs and stats on every pool, sequential and
    /// pipelined alike — a chip is a pure fault domain, not a source of
    /// numerical divergence.
    #[test]
    fn distinct_pools_are_bit_exact_fault_domains() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let frames: Vec<Tensor> = (0..3)
            .map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c))
            .collect();
        let refs: Vec<&Tensor> = frames.iter().collect();
        let want: Vec<_> = frames.iter().map(|f| runner.run_frame(f).unwrap()).collect();
        for chip in 0..2 {
            let pool = AccelPool::default();
            for (f, (wo, ws)) in frames.iter().zip(&want) {
                let (o, s) = runner.run_frame_on(&pool, f).unwrap();
                assert_eq!(&o, wo, "chip {chip} sequential output");
                assert_eq!(&s, ws, "chip {chip} sequential stats");
            }
            let piped = runner.run_frames_pipelined_ref_on(&pool, &refs, 3, 2).unwrap();
            for (i, ((o, s), (wo, ws))) in piped.iter().zip(&want).enumerate() {
                assert_eq!(o, wo, "chip {chip} pipelined frame {i} output");
                assert_eq!(s, ws, "chip {chip} pipelined frame {i} stats");
            }
        }
    }

    /// Per-node stat attribution must reconstruct the frame run
    /// exactly: same output, and counters summing to the aggregate
    /// (minus the SetConv/Halt commands that live outside segments).
    #[test]
    fn node_stats_sum_to_frame_stats() {
        for name in ["quicknet", "edgenet", "widenet", "gapnet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let runner = NetRunner::from_graph(&graph).unwrap();
            let frame = Tensor::random_image(5, graph.in_h, graph.in_w, graph.in_c);
            let (seq, stats) = runner.run_frame(&frame).unwrap();
            let (out, per_node) = runner.run_frame_node_stats(&frame).unwrap();
            assert_eq!(out, seq, "{name} output");
            assert_eq!(per_node.len(), graph.nodes.len());
            let mut sum = SimStats::default();
            for s in &per_node {
                sum.add(s);
            }
            assert_eq!(sum.dram_read_bytes, stats.dram_read_bytes, "{name} reads");
            assert_eq!(sum.dram_write_bytes, stats.dram_write_bytes, "{name} writes");
            assert_eq!(sum.macs, stats.macs, "{name} macs");
            assert_eq!(sum.cycles, stats.cycles, "{name} cycles");
            assert_eq!(sum.sram_reads, stats.sram_reads, "{name} sram reads");
            assert_eq!(sum.sram_writes, stats.sram_writes, "{name} sram writes");
        }
    }

    /// The tentpole invariant: DAG-parallel execution is bit-identical
    /// to the sequential run — output AND aggregated SimStats — for
    /// linear and graph topologies alike.
    #[test]
    fn parallel_dag_matches_sequential_bit_exactly() {
        for name in ["quicknet", "facenet", "edgenet", "widenet", "gapnet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let runner = NetRunner::from_graph(&graph).unwrap();
            let frame = Tensor::random_image(9, graph.in_h, graph.in_w, graph.in_c);
            let (seq, seq_stats) = runner.run_frame(&frame).unwrap();
            for workers in [2usize, 4] {
                let (par, par_stats) = runner.run_frame_parallel(&frame, workers).unwrap();
                assert_eq!(par, seq, "{name} workers={workers} output");
                assert_eq!(par_stats, seq_stats, "{name} workers={workers} stats");
            }
        }
    }

    /// The pipelined window must be a per-frame no-op: every frame's
    /// output AND SimStats equal its own sequential run, for any depth
    /// and worker count, with per-frame slot images recycled in place.
    #[test]
    fn pipelined_window_is_bit_exact_per_frame() {
        for name in ["quicknet", "edgenet", "widenet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let runner = NetRunner::from_graph(&graph).unwrap();
            let frames: Vec<Tensor> = (0..4)
                .map(|s| Tensor::random_image(s, graph.in_h, graph.in_w, graph.in_c))
                .collect();
            let seq: Vec<(Tensor, SimStats)> =
                frames.iter().map(|f| runner.run_frame(f).unwrap()).collect();
            for (workers, depth) in [(2usize, 2usize), (4, 3), (3, 8)] {
                let got = runner.run_frames_pipelined(&frames, workers, depth).unwrap();
                assert_eq!(got.len(), frames.len());
                for (i, ((go, gs), (so, ss))) in got.iter().zip(&seq).enumerate() {
                    assert_eq!(go, so, "{name} frame {i} w={workers} d={depth} output");
                    assert_eq!(gs, ss, "{name} frame {i} w={workers} d={depth} stats");
                }
            }
        }
    }

    /// Trace events carry the frame index: a single-frame traced run is
    /// all frame 0; a depth-2 window sees both frames, each segment
    /// entered and exited exactly once per frame.
    #[test]
    fn traces_carry_frame_ids() {
        let graph = zoo::graph_by_name("widenet").unwrap();
        let runner = NetRunner::from_graph(&graph).unwrap();
        let frames: Vec<Tensor> = (0..2)
            .map(|s| Tensor::random_image(s, graph.in_h, graph.in_w, graph.in_c))
            .collect();
        let (_, _, t1) = runner.run_frame_parallel_traced(&frames[0], 2).unwrap();
        assert!(!t1.is_empty() && t1.iter().all(|e| e.frame == 0));
        let (_, t2) = runner.run_frames_pipelined_traced(&frames, 2, 2).unwrap();
        let nseg = runner.compiled.segments.len();
        assert_eq!(t2.len(), 2 * 2 * nseg);
        for f in 0..2 {
            for s in 0..nseg {
                let enters =
                    t2.iter().filter(|e| e.frame == f && e.seg == s && e.enter).count();
                let exits =
                    t2.iter().filter(|e| e.frame == f && e.seg == s && !e.enter).count();
                assert_eq!((enters, exits), (1, 1), "frame {f} seg {s}");
            }
        }
    }

    /// An empty window and an oversized depth are both fine; a bad
    /// frame anywhere in the window is rejected up front.
    #[test]
    fn pipelined_window_edge_cases() {
        let graph = zoo::graph_by_name("quicknet").unwrap();
        let runner = NetRunner::from_graph(&graph).unwrap();
        assert!(runner.run_frames_pipelined(&[], 4, 2).unwrap().is_empty());
        let good = Tensor::random_image(0, graph.in_h, graph.in_w, graph.in_c);
        let bad = Tensor::zeros(3, 3, 1);
        assert!(runner
            .run_frames_pipelined(&[good, bad], 4, 2)
            .unwrap_err()
            .to_string()
            .contains("shape"));
    }
}
