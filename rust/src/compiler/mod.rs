//! Layer → decomposition plan → ISA command stream (the paper's §5
//! contribution, as a compiler).
//!
//! * [`decompose`] — the image/feature/channel decomposition solver.
//! * [`kernel_decomp`] — K×K → 3×3 tap enumeration (fixed CU array).
//! * [`codegen`] — plan → command program + DRAM image (+ the segment
//!   map of independently executable work units).
//! * [`NetRunner`] — compile-once / run-many harness: pooled, reusable
//!   simulator instances (no per-frame SRAM/DRAM reallocation), a
//!   sequential path ([`NetRunner::run_frame`]) and a parallel path
//!   ([`NetRunner::run_frame_parallel`]) that executes a layer's
//!   decomposed tiles/feature-groups concurrently.

pub mod codegen;
pub mod decompose;
pub mod kernel_decomp;

pub use codegen::{compile_net, CompiledNet, Segment};
pub use decompose::{plan_conv, Plan, PlanError};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::model::{NetSpec, Tensor};
use crate::sim::accel::StoreLog;
use crate::sim::{Accelerator, SimConfig, SimStats};

/// Compile-once / run-many harness around the simulator.
pub struct NetRunner {
    pub compiled: CompiledNet,
    cfg: SimConfig,
    /// Segments grouped by layer (indexed `[layer]`), precomputed once —
    /// the parallel path consumes this per frame.
    layer_segments: Vec<Vec<Segment>>,
    /// Reusable full simulators (sequential path).
    pool: Mutex<Vec<Accelerator>>,
    /// Reusable DRAM-less simulators: parallel tile workers execute
    /// against a shared frame DRAM image instead of owning one.
    worker_pool: Mutex<Vec<Accelerator>>,
    /// Reusable shared frame DRAM images (parallel path).
    dram_pool: Mutex<Vec<Vec<i16>>>,
}

impl NetRunner {
    pub fn new(net: &NetSpec) -> anyhow::Result<Self> {
        Self::with_config(net, SimConfig::default())
    }

    pub fn with_config(net: &NetSpec, mut cfg: SimConfig) -> anyhow::Result<Self> {
        let compiled = compile_net(net).map_err(|e| anyhow::anyhow!("{e}"))?;
        cfg.dram_px = compiled.dram_px;
        let mut layer_segments = vec![Vec::new(); net.layers.len()];
        for s in &compiled.segments {
            layer_segments[s.layer].push(*s);
        }
        Ok(Self {
            compiled,
            cfg,
            layer_segments,
            pool: Mutex::new(Vec::new()),
            worker_pool: Mutex::new(Vec::new()),
            dram_pool: Mutex::new(Vec::new()),
        })
    }

    fn take_full(&self) -> Accelerator {
        match self.pool.lock().unwrap().pop() {
            Some(a) => a,
            None => Accelerator::new(self.cfg.clone()),
        }
    }

    fn take_worker(&self) -> Accelerator {
        match self.worker_pool.lock().unwrap().pop() {
            Some(a) => a,
            None => Accelerator::new(SimConfig { dram_px: 0, ..self.cfg.clone() }),
        }
    }

    /// Write the frame and initial image into a DRAM backing store.
    fn init_dram(&self, dram: &mut [i16], frame: &Tensor) {
        dram[..self.compiled.dram_init.len()].copy_from_slice(&self.compiled.dram_init);
        // frame into the input canvas (HWC -> padded planar)
        let cv = &self.compiled.input;
        for ch in 0..frame.c {
            for y in 0..frame.h {
                for x in 0..frame.w {
                    dram[cv.px(ch, y, x)] = frame.at(y, x, ch);
                }
            }
        }
    }

    /// Extract the output canvas (planar -> HWC).
    fn extract_output(&self, dram: &[i16]) -> Tensor {
        let ov = &self.compiled.output;
        let mut out = Tensor::zeros(ov.h, ov.w, ov.c);
        for ch in 0..ov.c {
            for y in 0..ov.h {
                for x in 0..ov.w {
                    out.set(y, x, ch, dram[ov.px(ch, y, x)]);
                }
            }
        }
        out
    }

    /// Run one frame through a pooled simulator instance; returns the
    /// output tensor and the run's statistics.
    pub fn run_frame(&self, frame: &Tensor) -> anyhow::Result<(Tensor, SimStats)> {
        let net = &self.compiled.net;
        anyhow::ensure!(
            frame.shape() == net.in_shape(),
            "frame shape {:?} != net input {:?}",
            frame.shape(),
            net.in_shape()
        );
        let mut accel = self.take_full();
        accel.reset_counters();
        self.init_dram(&mut accel.dram.data, frame);
        // On error the instance is dropped (mid-program state is not
        // worth recycling); on success it returns to the pool.
        accel.run_program(&self.compiled.program)?;
        let out = self.extract_output(&accel.dram.data);
        let stats = accel.stats.clone();
        self.pool.lock().unwrap().push(accel);
        Ok((out, stats))
    }

    /// Run one frame with each layer's decomposed tiles/feature-groups
    /// executed concurrently by up to `workers` simulator instances
    /// (scoped threads, shared read-only frame DRAM, deferred disjoint
    /// stores). Output **and** aggregated [`SimStats`] are bit-identical
    /// to [`run_frame`]: segments are independent by construction, and
    /// every counter delta is translation-invariant across the
    /// per-segment `Sync` barriers, so summing per-worker stats
    /// reproduces the sequential totals exactly.
    pub fn run_frame_parallel(
        &self,
        frame: &Tensor,
        workers: usize,
    ) -> anyhow::Result<(Tensor, SimStats)> {
        if workers <= 1 || self.compiled.segments.len() <= 1 {
            return self.run_frame(frame);
        }
        let net = &self.compiled.net;
        anyhow::ensure!(
            frame.shape() == net.in_shape(),
            "frame shape {:?} != net input {:?}",
            frame.shape(),
            net.in_shape()
        );
        let mut dram = self.dram_pool.lock().unwrap().pop().unwrap_or_default();
        dram.resize(self.compiled.dram_px, 0);
        self.init_dram(&mut dram, frame);

        let nworkers = workers.min(self.compiled.segments.len());
        let mut accels: Vec<Accelerator> = (0..nworkers)
            .map(|_| {
                let mut a = self.take_worker();
                a.reset_counters();
                a
            })
            .collect();

        let program = &self.compiled.program;
        let mut covered = 0usize;
        for (li, segs) in self.layer_segments.iter().enumerate() {
            if segs.is_empty() {
                continue;
            }
            covered += segs.iter().map(|s| s.end - s.start).sum::<usize>();
            if let Some(cfg) = self.compiled.layer_cfgs[li] {
                for a in &mut accels {
                    a.set_conv_cfg(cfg);
                }
            }
            // Fan the layer's segments out over the workers; barrier at
            // the end of the scope, then apply the deferred stores.
            let next = AtomicUsize::new(0);
            let dram_view: &[i16] = &dram;
            let logs: Vec<StoreLog> = std::thread::scope(|scope| {
                let next = &next;
                let handles: Vec<_> = accels
                    .iter_mut()
                    .map(|accel| {
                        scope.spawn(move || {
                            let mut wlog = StoreLog::new();
                            loop {
                                let i = next.fetch_add(1, Ordering::Relaxed);
                                let Some(seg) = segs.get(i) else { break };
                                for cmd in &program[seg.start..seg.end] {
                                    accel.exec_shared(*cmd, dram_view, &mut wlog);
                                }
                            }
                            wlog
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("tile worker panicked")).collect()
            });
            for log in logs {
                for (dst, row) in log {
                    dram[dst..dst + row.len()].copy_from_slice(&row);
                }
            }
        }

        // Merge per-worker stats; the SetConv/Halt commands living
        // outside the segments cost no cycles but are counted by the
        // sequential stream, so count them here too.
        let mut totals =
            SimStats { commands: (program.len() - covered) as u64, ..SimStats::default() };
        for mut a in accels {
            a.sync_stats();
            totals.add(&a.stats);
            a.reset_counters();
            self.worker_pool.lock().unwrap().push(a);
        }

        let out = self.extract_output(&dram);
        self.dram_pool.lock().unwrap().push(dram);
        Ok((out, totals))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::run_net_ref;
    use crate::model::zoo;

    #[test]
    fn quicknet_sim_matches_reference_bit_exactly() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(42, net.in_h, net.in_w, net.in_c);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got, want, "simulator output != reference");
        assert!(stats.macs > 0 && stats.cycles > 0);
    }

    #[test]
    fn facenet_sim_matches_reference_bit_exactly() {
        let net = zoo::facenet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(7, 64, 64, 1);
        let (got, stats) = runner.run_frame(&frame).unwrap();
        let want = run_net_ref(&net, &frame);
        assert_eq!(got, want, "simulator output != reference");
        // sanity: sim performs at least the net's real MACs (padding taps
        // and 16-feature rounding only add)
        let static_macs: u64 = net.total_ops() / 2;
        assert!(stats.macs >= static_macs, "sim must do at least the real MACs");
    }

    #[test]
    fn wrong_frame_shape_rejected() {
        let runner = NetRunner::new(&zoo::quicknet()).unwrap();
        assert!(runner.run_frame(&Tensor::zeros(4, 4, 1)).is_err());
        assert!(runner.run_frame_parallel(&Tensor::zeros(4, 4, 1), 4).is_err());
    }

    /// Pooled instance reuse must not leak state between frames: the
    /// same frame run twice gives identical output and stats, and two
    /// different frames stay independent.
    #[test]
    fn pooled_reuse_is_stateless_across_frames() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let f1 = Tensor::random_image(1, net.in_h, net.in_w, net.in_c);
        let f2 = Tensor::random_image(2, net.in_h, net.in_w, net.in_c);
        let (o1a, s1a) = runner.run_frame(&f1).unwrap();
        let (o2, _) = runner.run_frame(&f2).unwrap();
        let (o1b, s1b) = runner.run_frame(&f1).unwrap();
        assert_eq!(o1a, o1b, "reused instance changed the result");
        assert_eq!(s1a, s1b, "reused instance changed the stats");
        assert_eq!(o2, run_net_ref(&net, &f2));
    }

    /// The tentpole invariant: parallel tile execution is bit-identical
    /// to the sequential run — output AND aggregated SimStats.
    #[test]
    fn parallel_tiles_match_sequential_bit_exactly() {
        for name in ["quicknet", "facenet"] {
            let net = zoo::by_name(name).unwrap();
            let runner = NetRunner::new(&net).unwrap();
            let frame = Tensor::random_image(9, net.in_h, net.in_w, net.in_c);
            let (seq, seq_stats) = runner.run_frame(&frame).unwrap();
            assert_eq!(seq, run_net_ref(&net, &frame), "{name} sequential");
            for workers in [2usize, 4] {
                let (par, par_stats) = runner.run_frame_parallel(&frame, workers).unwrap();
                assert_eq!(par, seq, "{name} workers={workers} output");
                assert_eq!(par_stats, seq_stats, "{name} workers={workers} stats");
            }
        }
    }
}
