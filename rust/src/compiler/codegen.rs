//! Command-stream code generation: decomposition plan → ISA program +
//! DRAM image (weights, biases, activation canvases).
//!
//! ## DRAM layout
//!
//! Activations live in **padded planar canvases**: layer *i*'s output
//! canvas is (C, Hc, Wc) planar with a `pad_next` zero border on all
//! sides plus a `margin` zero skirt on bottom/right for the next
//! layer's kernel-decomposition overshoot (Kp − K). Because DRAM is
//! zero-initialised and the apron is never written, conv padding comes
//! for free and tile loads are simple 2-D DMA reads.
//!
//! Weights/biases are laid out in exactly the blocks `LoadWeights` /
//! `LoadBias` consume (CU staging order `[ch][tap9][feat16]`), one block
//! per (layer, conv-group, feature-tile, tap, channel-group).

use std::collections::HashMap;

use super::decompose::{plan_conv, Plan, PlanError};
use super::kernel_decomp::{tap_weights, taps};
use crate::isa::{BiasLoad, Cmd, ConvCfg, ConvPass, DmaDesc, PoolPass, WeightLoad, PASS_FIRST, PASS_LAST};
use crate::model::{ConvSpec, LayerSpec, NetSpec};
use crate::{NUM_CU, SRAM_BYTES};

/// A padded planar activation canvas in DRAM.
#[derive(Clone, Debug)]
pub struct Canvas {
    pub base_px: usize,
    /// Valid (unpadded) dims.
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Zero border on top/left (= consumer's conv pad).
    pub pad: usize,
    /// Extra zero skirt on bottom/right (consumer's Kp − K).
    pub margin: usize,
    /// Full canvas dims.
    pub ch: usize,
    pub cw: usize,
}

impl Canvas {
    fn layout(base_px: usize, h: usize, w: usize, c: usize, pad: usize, margin: usize) -> Self {
        let ch = h + 2 * pad + margin;
        let cw = w + 2 * pad + margin;
        Self { base_px, h, w, c, pad, margin, ch, cw }
    }
    pub fn len_px(&self) -> usize {
        self.c * self.ch * self.cw
    }
    /// DRAM pixel address of valid-region (y, x) of channel `ch_idx`.
    pub fn px(&self, ch_idx: usize, y: usize, x: usize) -> usize {
        self.base_px + (ch_idx * self.ch + y + self.pad) * self.cw + x + self.pad
    }
    /// Address of a *canvas-space* coordinate (tile windows use this:
    /// tile iy0/ix0 are relative to the padded canvas origin).
    pub fn px_canvas(&self, ch_idx: usize, cy: usize, cx: usize) -> usize {
        self.base_px + (ch_idx * self.ch + cy) * self.cw + cx
    }
}

/// One independently executable span of the command program: all passes
/// of one decomposed work unit (a conv image-tile with its feature
/// groups, or a pool channel chunk). Segments of the same layer read
/// only the previous layer's canvas and write disjoint regions of their
/// own output canvas, so the runner may execute them concurrently;
/// between layers sits a barrier. Every segment ends on a `Sync`, which
/// makes its stat deltas translation-invariant — the parallel runner
/// relies on both properties.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// Index of the layer this segment belongs to.
    pub layer: usize,
    /// Command range `[start, end)` into `CompiledNet::program`.
    pub start: usize,
    pub end: usize,
}

/// Everything the runtime needs to run one network on the accelerator.
pub struct CompiledNet {
    pub net: NetSpec,
    pub program: Vec<Cmd>,
    /// Initial DRAM image (weights + zeroed canvases). Length = DRAM px.
    pub dram_init: Vec<i16>,
    /// Input canvas (frame goes here) and final output canvas.
    pub input: Canvas,
    pub output: Canvas,
    /// Per conv layer: the decomposition plan (reporting / benches).
    pub plans: Vec<(String, Plan)>,
    /// Total DRAM pixels used.
    pub dram_px: usize,
    /// Independently schedulable command spans (parallel tile execution).
    pub segments: Vec<Segment>,
    /// Per layer: the conv datapath config its segments assume (`None`
    /// for pool layers). The parallel runner applies this in lieu of
    /// the single `SetConv` command emitted outside the segments.
    pub layer_cfgs: Vec<Option<ConvCfg>>,
}

/// What the next layer needs from the current output canvas.
fn consumer_needs(layers: &[LayerSpec], idx: usize) -> (usize, usize) {
    match layers.get(idx + 1) {
        Some(LayerSpec::Conv(c)) => {
            let kp = 3 * c.k.div_ceil(3);
            (c.pad, kp - c.k)
        }
        _ => (0, 0),
    }
}

struct Emitter {
    program: Vec<Cmd>,
    dram: Vec<i16>,
    segments: Vec<Segment>,
    /// weight-block offset cache: (layer, group, mtile, tap, cgroup)
    wcache: HashMap<(usize, usize, usize, usize, usize), (usize, usize)>,
    bcache: HashMap<(usize, usize, usize), usize>,
}

impl Emitter {
    fn alloc_dram(&mut self, len: usize) -> usize {
        let base = self.dram.len();
        self.dram.resize(base + len, 0);
        base
    }
    fn push(&mut self, c: Cmd) {
        self.program.push(c);
    }
}

/// Compile a network into a command program + DRAM image.
pub fn compile_net(net: &NetSpec) -> Result<CompiledNet, PlanError> {
    let mut em = Emitter {
        program: Vec::new(),
        dram: Vec::new(),
        segments: Vec::new(),
        wcache: HashMap::new(),
        bcache: HashMap::new(),
    };

    // ---- canvases --------------------------------------------------------
    let (pad0, margin0) = match &net.layers[0] {
        LayerSpec::Conv(c) => (c.pad, 3 * c.k.div_ceil(3) - c.k),
        _ => (0, 0),
    };
    let in_canvas = {
        let base = em.alloc_dram(0);
        let cv = Canvas::layout(base, net.in_h, net.in_w, net.in_c, pad0, margin0);
        em.alloc_dram(cv.len_px());
        cv
    };
    let mut canvases = vec![in_canvas.clone()];
    let mut shape = net.in_shape();
    for (i, l) in net.layers.iter().enumerate() {
        shape = l.out_shape(shape);
        let (pad, margin) = consumer_needs(&net.layers, i);
        let base = em.alloc_dram(0);
        let cv = Canvas::layout(base, shape.0, shape.1, shape.2, pad, margin);
        em.alloc_dram(cv.len_px());
        canvases.push(cv);
    }

    // ---- per-layer programs ----------------------------------------------
    let mut plans = Vec::new();
    let mut shape = net.in_shape();
    for (li, l) in net.layers.iter().enumerate() {
        let (src, dst) = (canvases[li].clone(), canvases[li + 1].clone());
        match l {
            LayerSpec::Conv(c) => {
                let plan = plan_conv(c, shape.0, shape.1)?;
                emit_conv(&mut em, li, c, &plan, &src, &dst);
                plans.push((c.name.clone(), plan));
            }
            LayerSpec::Pool(p) => {
                emit_pool(&mut em, li, p, &src, &dst);
            }
        }
        shape = l.out_shape(shape);
    }
    em.push(Cmd::Halt);

    let layer_cfgs = net
        .layers
        .iter()
        .map(|l| match l {
            LayerSpec::Conv(c) => {
                Some(ConvCfg { stride: c.stride as u8, shift: c.shift, relu: c.relu })
            }
            LayerSpec::Pool(_) => None,
        })
        .collect();
    let dram_px = em.dram.len();
    Ok(CompiledNet {
        net: net.clone(),
        program: em.program,
        dram_init: em.dram,
        input: canvases[0].clone(),
        output: canvases[canvases.len() - 1].clone(),
        plans,
        dram_px,
        segments: em.segments,
        layer_cfgs,
    })
}

/// Emit one conv layer.
fn emit_conv(em: &mut Emitter, li: usize, c: &ConvSpec, plan: &Plan, src: &Canvas, dst: &Canvas) {
    let weights = c.weights();
    let biases = c.biases();
    let cg = c.cin / c.groups; // channels per conv group
    let mg = c.cout / c.groups; // features per conv group
    let tap_list = taps(c.k);
    em.push(Cmd::SetConv(ConvCfg { stride: c.stride as u8, shift: c.shift, relu: c.relu }));

    // SRAM layout per tile: [input tile (c_per_group planar)] [out staging 16]
    let in_tile_px_max =
        plan.tiles.iter().map(|t| t.ih * t.iw).max().unwrap() * plan.c_per_group;

    for tile in &plan.tiles {
        // Everything one tile needs — channel loads, weight/bias
        // prefetches, all conv passes and the output stores — forms one
        // self-contained, independently executable segment.
        let seg_start = em.program.len();
        let in_px = tile.ih * tile.iw;
        let sram_in = 0u32;
        let sram_out = in_tile_px_max as u32;
        debug_assert!(
            (in_tile_px_max + tile.oh * tile.ow * NUM_CU) * 2 <= SRAM_BYTES,
            "plan exceeded SRAM"
        );
        // track which channel slice currently resides in SRAM
        let mut loaded: Option<(usize, usize)> = None; // (group, cgroup)
        for g in 0..c.groups {
            for mt in 0..plan.m_tiles {
                // bias block
                let bkey = (li, g, mt);
                let boff = match em.bcache.get(&bkey) {
                    Some(&o) => o,
                    None => {
                        let o = em.alloc_dram(2 * NUM_CU);
                        for f in 0..NUM_CU {
                            let m = mt * NUM_CU + f;
                            let v = if m < mg { biases[g * mg + m] } else { 0 };
                            em.dram[o + 2 * f] = (v as u32 & 0xFFFF) as u16 as i16;
                            em.dram[o + 2 * f + 1] = ((v as u32) >> 16) as u16 as i16;
                        }
                        em.bcache.insert(bkey, o);
                        o
                    }
                };
                em.push(Cmd::LoadBias(BiasLoad { dram_px: boff as u32 }));

                // Collect this feature-group's pass list, then emit it
                // software-pipelined: the LoadWeights for pass i+1 is
                // issued before Conv(i), so the shadow bank (depth 2)
                // lets the prefetch DMA hide behind Conv(i)'s compute —
                // exactly the §4.2 "pre-fetch controller" behaviour.
                struct PassDesc {
                    cgi: usize,
                    cn: usize,
                    woff: usize,
                    dy: u8,
                    dx: u8,
                }
                let mut passes: Vec<PassDesc> = Vec::new();
                for cgi in 0..plan.c_groups {
                    let c0 = cgi * plan.c_per_group;
                    let cn = plan.c_per_group.min(cg - c0);
                    for (ti, tp) in tap_list.iter().enumerate() {
                        let wkey = (li, g, mt, ti, cgi);
                        let (woff, _wlen) = match em.wcache.get(&wkey) {
                            Some(&v) => v,
                            None => {
                                let blk = tap_weights(
                                    &weights,
                                    c.k,
                                    cg,
                                    c.cout,
                                    *tp,
                                    c0,
                                    cn,
                                    g * mg + mt * NUM_CU,
                                );
                                let o = em.alloc_dram(blk.len());
                                em.dram[o..o + blk.len()].copy_from_slice(&blk);
                                em.wcache.insert(wkey, (o, blk.len()));
                                (o, blk.len())
                            }
                        };
                        passes.push(PassDesc { cgi, cn, woff, dy: tp.dy, dx: tp.dx });
                    }
                }
                let total_passes = passes.len();
                // prime the shadow bank with pass 0's weights
                em.push(Cmd::LoadWeights(WeightLoad {
                    dram_px: passes[0].woff as u32,
                    cn: passes[0].cn as u16,
                }));
                for (pass, pd) in passes.iter().enumerate() {
                    // (re)load the input channel slice if not resident
                    if loaded != Some((g, pd.cgi)) {
                        let c0 = pd.cgi * plan.c_per_group;
                        for ci in 0..pd.cn {
                            let ch = g * cg + c0 + ci;
                            em.push(Cmd::LoadImage(DmaDesc {
                                dram_px: src.px_canvas(ch, tile.iy0, tile.ix0) as u32,
                                sram_px: sram_in + (ci * in_px) as u32,
                                row_px: tile.iw as u32,
                                rows: tile.ih as u16,
                                dram_pitch: src.cw as u32,
                                sram_pitch: tile.iw as u32,
                            }));
                        }
                        em.push(Cmd::Sync);
                        loaded = Some((g, pd.cgi));
                    }
                    // prefetch the NEXT pass's weights before this Conv
                    if let Some(next) = passes.get(pass + 1) {
                        em.push(Cmd::LoadWeights(WeightLoad {
                            dram_px: next.woff as u32,
                            cn: next.cn as u16,
                        }));
                    }
                    let mut flags = 0u8;
                    if pass == 0 {
                        flags |= PASS_FIRST;
                    }
                    if pass + 1 == total_passes {
                        flags |= PASS_LAST;
                    }
                    em.push(Cmd::Conv(ConvPass {
                        src_px: sram_in,
                        acc_px: 0,
                        dst_px: sram_out,
                        ih: tile.ih as u16,
                        iw: tile.iw as u16,
                        ctot: pd.cn as u16,
                        c0: 0,
                        cn: pd.cn as u16,
                        oh: tile.oh as u16,
                        ow: tile.ow as u16,
                        dy: pd.dy,
                        dx: pd.dx,
                        flags,
                    }));
                }
                // store the 16-feature group to the output canvas
                for f in 0..NUM_CU {
                    let m = mt * NUM_CU + f;
                    if m >= mg {
                        break;
                    }
                    let gm = g * mg + m;
                    em.push(Cmd::Store(DmaDesc {
                        dram_px: dst.px(gm, tile.oy0, tile.ox0) as u32,
                        sram_px: sram_out + (f * tile.oh * tile.ow) as u32,
                        row_px: tile.ow as u32,
                        rows: tile.oh as u16,
                        dram_pitch: dst.cw as u32,
                        sram_pitch: tile.ow as u32,
                    }));
                }
                em.push(Cmd::Sync);
            }
        }
        em.segments.push(Segment { layer: li, start: seg_start, end: em.program.len() });
    }
}

/// Emit one pool layer: channel-chunked SRAM-resident pooling.
fn emit_pool(em: &mut Emitter, li: usize, p: &crate::model::PoolSpec, src: &Canvas, dst: &Canvas) {
    let (ih, iw, c) = (src.h, src.w, src.c);
    let oh = (ih - p.k) / p.stride + 1;
    let ow = (iw - p.k) / p.stride + 1;
    // channels per chunk limited by SRAM: (ih*iw + oh*ow) * 2 bytes each
    let per_ch = (ih * iw + oh * ow) * 2;
    let cc_max = (SRAM_BYTES / per_ch).max(1).min(c);
    let mut ch0 = 0;
    while ch0 < c {
        // One channel chunk = one independently executable segment.
        let seg_start = em.program.len();
        let cc = cc_max.min(c - ch0);
        let sram_in = 0u32;
        let sram_out = (cc * ih * iw) as u32;
        for ci in 0..cc {
            em.push(Cmd::LoadImage(DmaDesc {
                dram_px: src.px(ch0 + ci, 0, 0) as u32,
                sram_px: sram_in + (ci * ih * iw) as u32,
                row_px: iw as u32,
                rows: ih as u16,
                dram_pitch: src.cw as u32,
                sram_pitch: iw as u32,
            }));
        }
        em.push(Cmd::Sync);
        em.push(Cmd::Pool(PoolPass {
            src_px: sram_in,
            dst_px: sram_out,
            ih: ih as u16,
            iw: iw as u16,
            c: cc as u16,
            k: p.k as u8,
            stride: p.stride as u8,
        }));
        for ci in 0..cc {
            em.push(Cmd::Store(DmaDesc {
                dram_px: dst.px(ch0 + ci, 0, 0) as u32,
                sram_px: sram_out + (ci * oh * ow) as u32,
                row_px: ow as u32,
                rows: oh as u16,
                dram_pitch: dst.cw as u32,
                sram_pitch: ow as u32,
            }));
        }
        em.push(Cmd::Sync);
        em.segments.push(Segment { layer: li, start: seg_start, end: em.program.len() });
        ch0 += cc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    /// Segments must exactly cover the program minus the per-conv-layer
    /// `SetConv` and the final `Halt`, without overlap, in layer order,
    /// and each must end on the `Sync` barrier the parallel runner's
    /// translation-invariance argument depends on.
    #[test]
    fn segments_partition_the_program() {
        // (vgg16 omitted: compiling its full weight image is bench-scale)
        for name in ["quicknet", "facenet", "alexnet"] {
            let net = zoo::by_name(name).unwrap();
            let compiled = compile_net(&net).unwrap();
            let mut covered = 0usize;
            let mut at = 0usize;
            let mut last_layer = 0usize;
            for s in &compiled.segments {
                assert!(s.start < s.end && s.end <= compiled.program.len(), "{name}: {s:?}");
                assert!(s.start >= at, "{name}: overlapping segments at {s:?}");
                assert!(s.layer >= last_layer, "{name}: segments out of layer order");
                assert_eq!(
                    compiled.program[s.end - 1],
                    Cmd::Sync,
                    "{name}: segment {s:?} must end on a Sync barrier"
                );
                // commands skipped between segments are layer prologues
                for cmd in &compiled.program[at..s.start] {
                    assert!(matches!(cmd, Cmd::SetConv(_)), "{name}: uncovered {cmd:?}");
                }
                covered += s.end - s.start;
                at = s.end;
                last_layer = s.layer;
            }
            // tail: only the Halt remains
            assert_eq!(&compiled.program[at..], &[Cmd::Halt], "{name}");
            let n_conv = compiled.layer_cfgs.iter().filter(|c| c.is_some()).count();
            assert_eq!(covered + n_conv + 1, compiled.program.len(), "{name}");
            assert_eq!(compiled.layer_cfgs.len(), net.layers.len(), "{name}");
        }
    }

    /// facenet's early layers exceed the 1024-px ACC BUF tile, so the
    /// plan must decompose them into multiple parallel segments.
    #[test]
    fn facenet_has_parallel_width() {
        let compiled = compile_net(&zoo::facenet()).unwrap();
        let first_layer: Vec<_> =
            compiled.segments.iter().filter(|s| s.layer == 0).collect();
        assert!(first_layer.len() >= 4, "expected >=4 tiles, got {}", first_layer.len());
    }
}
