//! Command-stream code generation: graph IR → decomposition plans →
//! ISA program + DRAM image (weights, biases, activation canvases) +
//! the dependency-annotated segment DAG the parallel runner schedules.
//!
//! ## DRAM layout
//!
//! Activations live in **padded planar canvases**: one canvas per graph
//! node output (plus the input), (C, Hc, Wc) planar with a zero border
//! sized for the node's *consumers* — `pad` = the largest conv pad among
//! them, plus a `margin` zero skirt on bottom/right for kernel-
//! decomposition overshoot (Kp − K). Because DRAM is zero-initialised
//! and the apron is never written, conv padding comes for free and tile
//! loads are simple 2-D DMA reads. A consumer whose own pad is smaller
//! than the canvas pad simply offsets its reads by the difference.
//!
//! Weights/biases are laid out in exactly the blocks `LoadWeights` /
//! `LoadBias` consume (CU staging order `[ch][tap9][feat16]`), one block
//! per (node, conv-group, feature-tile, tap, channel-group).
//!
//! ## Segments and the dependency DAG
//!
//! Every decomposed work unit (conv image-tile, pool/add channel chunk,
//! concat input copy) is one [`Segment`]: an independently executable
//! command span ending on a `Sync`. During emission the compiler records
//! the canvas-space region each segment reads and writes; afterwards it
//! derives `deps` — the producer segments whose written region
//! intersects a read region. Where the decomposition makes output tiles
//! disjoint this yields *tile-granular* edges (a consumer tile waits
//! only for the producer tiles under its halo); where it doesn't, the
//! edges degrade gracefully to node granularity. The runner executes
//! the DAG with no other barriers.

use std::collections::HashMap;

use super::decompose::{dw_eligible, plan_conv, Plan};
use super::kernel_decomp::{dw_tap_weights, tap_weights, taps, Tap};
use crate::isa::{
    AddPass, BiasLoad, Cmd, ConvCfg, ConvPass, DmaDesc, PoolPass, WeightLoad, PASS_DW,
    PASS_FIRST, PASS_LAST,
};
use crate::model::graph::{Graph, NodeOp, NodeRef};
use crate::model::{AddSpec, ConcatSpec, ConvSpec, NetSpec, PoolSpec};
use crate::sim::accbuf::ACC_TILE_PX;
use crate::{NUM_CU, SRAM_BYTES};

/// A padded planar activation canvas in DRAM.
#[derive(Clone, Debug)]
pub struct Canvas {
    pub base_px: usize,
    /// Valid (unpadded) dims.
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Zero border on top/left (= the largest consumer conv pad).
    pub pad: usize,
    /// Extra zero skirt on bottom/right (consumer Kp − K overshoot).
    pub margin: usize,
    /// Full canvas dims.
    pub ch: usize,
    pub cw: usize,
}

impl Canvas {
    fn layout(base_px: usize, h: usize, w: usize, c: usize, pad: usize, margin: usize) -> Self {
        let ch = h + 2 * pad + margin;
        let cw = w + 2 * pad + margin;
        Self { base_px, h, w, c, pad, margin, ch, cw }
    }
    pub fn len_px(&self) -> usize {
        self.c * self.ch * self.cw
    }
    /// DRAM pixel address of valid-region (y, x) of channel `ch_idx`.
    pub fn px(&self, ch_idx: usize, y: usize, x: usize) -> usize {
        self.base_px + (ch_idx * self.ch + y + self.pad) * self.cw + x + self.pad
    }
    /// Address of a *canvas-space* coordinate (tile windows use this:
    /// tile iy0/ix0 are relative to the padded canvas origin).
    pub fn px_canvas(&self, ch_idx: usize, cy: usize, cx: usize) -> usize {
        self.base_px + (ch_idx * self.ch + cy) * self.cw + cx
    }
}

/// One independently executable span of the command program: all passes
/// of one decomposed work unit (a conv image-tile with its feature
/// groups, a pool/add channel chunk, or one concat input copy). A
/// segment becomes runnable when every segment in `deps` has completed;
/// segments of the same node write disjoint regions of its output
/// canvas, so no further ordering exists. Every segment ends on a
/// `Sync`, which makes its stat deltas translation-invariant — the
/// parallel runner relies on both properties.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Segment {
    /// Index of the graph node this segment belongs to.
    pub node: usize,
    /// Command range `[start, end)` into `CompiledNet::program`.
    pub start: usize,
    pub end: usize,
    /// Conv datapath config the span's passes assume (`None` for
    /// pool/add/concat). The DAG runner applies it before execution in
    /// lieu of the single `SetConv` emitted outside the segments.
    pub cfg: Option<ConvCfg>,
    /// Producer segments (indices into `CompiledNet::segments`) that
    /// must complete first. Always earlier indices (the emission order
    /// is topological).
    pub deps: Vec<usize>,
}

/// Everything the runtime needs to run one network on the accelerator.
pub struct CompiledNet {
    pub graph: Graph,
    pub program: Vec<Cmd>,
    /// Initial DRAM image (weights + zeroed canvases). Length = DRAM px.
    pub dram_init: Vec<i16>,
    /// Input canvas (frame goes here) and final output canvas.
    pub input: Canvas,
    pub output: Canvas,
    /// Per conv node: the decomposition plan (reporting / benches).
    pub plans: Vec<(String, Plan)>,
    /// Total DRAM pixels used.
    pub dram_px: usize,
    /// Independently schedulable command spans with their dependency
    /// edges (the segment DAG).
    pub segments: Vec<Segment>,
}

impl CompiledNet {
    /// The segment DAG in Graphviz DOT, for `kn-stream plan
    /// --dump-graph` and scheduler debugging.
    pub fn segments_dot(&self) -> String {
        let mut out = String::from(
            "digraph segments {\n  rankdir=LR;\n  node [shape=box fontname=\"monospace\"];\n",
        );
        for (i, s) in self.segments.iter().enumerate() {
            let name = self.graph.nodes[s.node].name();
            out.push_str(&format!(
                "  s{i} [label=\"{name} #{i}\\ncmds [{}..{})\"];\n",
                s.start, s.end
            ));
        }
        for (i, s) in self.segments.iter().enumerate() {
            for &d in &s.deps {
                out.push_str(&format!("  s{d} -> s{i};\n"));
            }
        }
        out.push_str("}\n");
        out
    }
}

/// Canvas-space rectangle a segment touches: channel, row and column
/// ranges, all half-open. Reads include the zero apron (halo), writes
/// cover only valid pixels; intersection of a read with an earlier
/// write is exactly a scheduling dependency.
#[derive(Clone, Copy, Debug)]
struct Region {
    canvas: usize,
    c0: usize,
    c1: usize,
    y0: usize,
    y1: usize,
    x0: usize,
    x1: usize,
}

impl Region {
    fn overlaps(&self, o: &Region) -> bool {
        self.canvas == o.canvas
            && self.c0 < o.c1
            && o.c0 < self.c1
            && self.y0 < o.y1
            && o.y0 < self.y1
            && self.x0 < o.x1
            && o.x0 < self.x1
    }
}

/// What a segment reads and writes (parallel to `Emitter::segments`).
struct SegMeta {
    reads: Vec<Region>,
    write: Region,
}

struct Emitter {
    program: Vec<Cmd>,
    dram: Vec<i16>,
    segments: Vec<Segment>,
    seg_meta: Vec<SegMeta>,
    /// weight-block offset cache: (node, group, mtile, tap, cgroup)
    wcache: HashMap<(usize, usize, usize, usize, usize), (usize, usize)>,
    bcache: HashMap<(usize, usize, usize), usize>,
}

impl Emitter {
    fn alloc_dram(&mut self, len: usize) -> usize {
        let base = self.dram.len();
        self.dram.resize(base + len, 0);
        base
    }
    fn push(&mut self, c: Cmd) {
        self.program.push(c);
    }
    /// Close the segment opened at command index `start`.
    fn end_segment(
        &mut self,
        node: usize,
        start: usize,
        cfg: Option<ConvCfg>,
        reads: Vec<Region>,
        write: Region,
    ) {
        self.segments.push(Segment { node, start, end: self.program.len(), cfg, deps: Vec::new() });
        self.seg_meta.push(SegMeta { reads, write });
    }
}

/// Canvas index of a node input: 0 is the graph input, node *i* writes
/// canvas *i + 1*.
fn canvas_of(r: NodeRef) -> usize {
    match r {
        NodeRef::Input => 0,
        NodeRef::Node(i) => i + 1,
    }
}

/// Compile a linear layer stack (converted to the graph IR underneath).
pub fn compile_net(net: &NetSpec) -> anyhow::Result<CompiledNet> {
    compile_graph(&Graph::from_net(net))
}

/// Knobs for `compile_graph*`.
#[derive(Clone, Debug)]
pub struct CompileOptions {
    /// Weight-emission thread count (1 = fully sequential).
    pub emit_threads: usize,
    /// Run the static schedule analyzer ([`crate::analysis::analyze`])
    /// on the compiled artifact and fail compilation on any diagnostic.
    /// Defaults **on** under `debug_assertions` — every test compile is
    /// verified — and off in release, where callers opt in explicitly
    /// (the `lint` CLI always analyzes).
    pub verify: bool,
    /// What the planner minimizes when a searching
    /// [`crate::planner::PlanPolicy`] chooses the decomposition
    /// (ignored by the emitter itself; read by
    /// [`crate::compiler::NetRunner`] and the CLI when they plan
    /// before compiling). Default: DRAM traffic.
    pub objective: crate::planner::PlanObjective,
}

impl Default for CompileOptions {
    fn default() -> Self {
        Self {
            emit_threads: default_emit_threads(),
            verify: cfg!(debug_assertions),
            objective: crate::planner::PlanObjective::MinTraffic,
        }
    }
}

/// Compile a graph into a command program + DRAM image + segment DAG,
/// with the historical per-node heuristic decomposition.
pub fn compile_graph(graph: &Graph) -> anyhow::Result<CompiledNet> {
    compile_graph_with_options(graph, None, &CompileOptions::default())
}

/// [`compile_graph`] with explicit [`CompileOptions`] and optional
/// planner-chosen per-node plans.
pub fn compile_graph_with_options(
    graph: &Graph,
    plans: Option<&[Option<Plan>]>,
    opts: &CompileOptions,
) -> anyhow::Result<CompiledNet> {
    compile_graph_opts(graph, plans, opts.emit_threads, opts.verify)
}

/// [`compile_graph`] with per-conv-node decomposition plans chosen by
/// the planner (`planner::plan_graph`). `plans` is indexed like
/// `graph.nodes`; a `None` entry for a conv node falls back to the
/// heuristic solver. Every supplied plan is re-checked against the
/// ACC-BUF/SRAM contracts before emission.
pub fn compile_graph_with_plans(
    graph: &Graph,
    plans: &[Option<Plan>],
) -> anyhow::Result<CompiledNet> {
    compile_graph_with_options(graph, Some(plans), &CompileOptions::default())
}

/// [`compile_graph`] with an explicit weight-emission thread count
/// (1 = fully sequential). The emitted program AND DRAM image are
/// byte-identical at any thread count — block offsets are assigned
/// sequentially and block contents depend only on the layer weights.
pub fn compile_graph_threads(graph: &Graph, emit_threads: usize) -> anyhow::Result<CompiledNet> {
    compile_graph_with_options(graph, None, &CompileOptions { emit_threads, ..Default::default() })
}

/// Default weight-emission parallelism: the host's cores, capped —
/// the fill is memory-bound beyond a few threads.
pub fn default_emit_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
}

/// A plan arriving from outside the heuristic solver must still honor
/// the emitter's resource contracts; checked here with real errors so
/// a planner bug cannot surface as a mid-emission debug panic.
fn check_plan(c: &ConvSpec, h: usize, w: usize, plan: &Plan) -> anyhow::Result<()> {
    let oh = (h + 2 * c.pad - c.k) / c.stride + 1;
    let ow = (w + 2 * c.pad - c.k) / c.stride + 1;
    anyhow::ensure!(!plan.tiles.is_empty(), "conv {}: plan has no tiles", c.name);
    // exact disjoint cover of the output plane (a pixel-count check
    // alone would let overlapping tiles double-write one region and
    // silently leave another unwritten)
    let mut cover = vec![false; oh * ow];
    for t in &plan.tiles {
        anyhow::ensure!(
            t.oh >= 1 && t.ow >= 1 && t.oy0 + t.oh <= oh && t.ox0 + t.ow <= ow,
            "conv {}: tile {t:?} outside the {oh}x{ow} output plane",
            c.name
        );
        for y in t.oy0..t.oy0 + t.oh {
            for x in t.ox0..t.ox0 + t.ow {
                anyhow::ensure!(
                    !std::mem::replace(&mut cover[y * ow + x], true),
                    "conv {}: plan tiles overlap at ({y}, {x})",
                    c.name
                );
            }
        }
    }
    anyhow::ensure!(
        cover.iter().all(|&px| px),
        "conv {}: plan tiles do not cover the whole output plane",
        c.name
    );
    let max_out = plan.tiles.iter().map(|t| t.oh * t.ow).max().unwrap();
    anyhow::ensure!(
        max_out <= ACC_TILE_PX,
        "conv {}: tile of {max_out} px exceeds the {ACC_TILE_PX}-px ACC BUF",
        c.name
    );
    if plan.dw {
        anyhow::ensure!(
            dw_eligible(c),
            "conv {}: depthwise plan for a non-depthwise layer",
            c.name
        );
        let lanes = c.cin.min(NUM_CU);
        anyhow::ensure!(
            plan.c_per_group >= 1 && plan.c_per_group <= lanes,
            "conv {}: dw c_per_group {} outside 1..={lanes}",
            c.name,
            plan.c_per_group
        );
        anyhow::ensure!(
            plan.c_groups == c.cin.div_ceil(plan.c_per_group) && plan.m_tiles == 1,
            "conv {}: inconsistent depthwise channel grouping",
            c.name
        );
    } else {
        let cg = c.cin / c.groups;
        anyhow::ensure!(
            plan.c_per_group >= 1 && plan.c_per_group <= cg,
            "conv {}: c_per_group {} outside 1..={cg}",
            c.name,
            plan.c_per_group
        );
        anyhow::ensure!(
            plan.c_groups == cg.div_ceil(plan.c_per_group)
                && plan.m_tiles == (c.cout / c.groups).div_ceil(NUM_CU),
            "conv {}: inconsistent channel/feature grouping",
            c.name
        );
    }
    let in_max = plan.tiles.iter().map(|t| t.ih * t.iw).max().unwrap() * plan.c_per_group;
    anyhow::ensure!(
        (in_max + max_out * NUM_CU) * 2 <= SRAM_BYTES,
        "conv {}: SRAM staging {} B exceeds the bank",
        c.name,
        (in_max + max_out * NUM_CU) * 2
    );
    Ok(())
}

fn compile_graph_opts(
    graph: &Graph,
    plans_in: Option<&[Option<Plan>]>,
    emit_threads: usize,
    verify: bool,
) -> anyhow::Result<CompiledNet> {
    let shapes = graph.validate()?;
    let n_canvas = graph.nodes.len() + 1;

    // ---- canvas padding: what each producer's consumers need -------------
    let mut pad = vec![0usize; n_canvas];
    let mut need = vec![0usize; n_canvas]; // max (pad + Kp − K) over conv consumers
    for node in &graph.nodes {
        if let NodeOp::Conv(c) = &node.op {
            let kp = 3 * c.k.div_ceil(3);
            let j = canvas_of(node.inputs[0]);
            pad[j] = pad[j].max(c.pad);
            need[j] = need[j].max(c.pad + kp - c.k);
        }
    }

    let mut em = Emitter {
        program: Vec::new(),
        dram: Vec::new(),
        segments: Vec::new(),
        seg_meta: Vec::new(),
        wcache: HashMap::new(),
        bcache: HashMap::new(),
    };

    // ---- canvases --------------------------------------------------------
    let mut canvases: Vec<Canvas> = Vec::with_capacity(n_canvas);
    for j in 0..n_canvas {
        let r = if j == 0 { NodeRef::Input } else { NodeRef::Node(j - 1) };
        let (h, w, c) = graph.shape_of(r, &shapes);
        let margin = need[j].saturating_sub(pad[j]);
        let base = em.alloc_dram(0);
        let cv = Canvas::layout(base, h, w, c, pad[j], margin);
        em.alloc_dram(cv.len_px());
        canvases.push(cv);
    }

    // ---- fused depthwise→pointwise pairs ---------------------------------
    // A pointwise plan carrying `fuse_dw` absorbs its depthwise producer:
    // the dw node emits nothing and its output canvas is never written —
    // the dw results stream through SRAM staging inside the pw segments.
    // Every legality condition is re-checked with real errors so a
    // planner bug cannot mis-emit.
    let mut fused_dw_of: Vec<Option<usize>> = vec![None; graph.nodes.len()];
    let mut fused_away = vec![false; graph.nodes.len()];
    if let Some(plans) = plans_in {
        for (ni, node) in graph.nodes.iter().enumerate() {
            let NodeOp::Conv(pw) = &node.op else { continue };
            let Some(Some(plan)) = plans.get(ni) else { continue };
            if !plan.fuse_dw {
                continue;
            }
            anyhow::ensure!(
                pw.k == 1 && pw.stride == 1 && pw.pad == 0 && pw.groups == 1,
                "conv {}: fuse_dw on a non-1x1-pointwise layer",
                pw.name
            );
            let Some(NodeRef::Node(di)) = node.inputs.first().copied() else {
                anyhow::bail!("conv {}: fuse_dw input is the graph input", pw.name);
            };
            let NodeOp::Conv(dw) = &graph.nodes[di].op else {
                anyhow::bail!("conv {}: fuse_dw input is not a conv", pw.name);
            };
            anyhow::ensure!(
                dw_eligible(dw),
                "conv {}: fuse_dw producer {} is not depthwise",
                pw.name,
                dw.name
            );
            let consumers = graph
                .nodes
                .iter()
                .flat_map(|n| &n.inputs)
                .filter(|r| matches!(r, NodeRef::Node(i) if *i == di))
                .count();
            anyhow::ensure!(
                consumers == 1 && graph.output != NodeRef::Node(di),
                "conv {}: fused producer {} has other consumers",
                pw.name,
                dw.name
            );
            let dwp = plans
                .get(di)
                .cloned()
                .flatten()
                .ok_or_else(|| anyhow::anyhow!("conv {}: fused producer has no plan", pw.name))?;
            anyhow::ensure!(
                dwp.dw && dwp.gy == plan.gy && dwp.gx == plan.gx,
                "conv {}: fused producer plan is not a matching depthwise grid",
                pw.name
            );
            fused_dw_of[ni] = Some(di);
            fused_away[di] = true;
        }
    }

    // ---- per-node programs -----------------------------------------------
    let mut plans = Vec::new();
    for (ni, node) in graph.nodes.iter().enumerate() {
        let dst = canvases[ni + 1].clone();
        let srcs: Vec<(usize, Canvas)> = node
            .inputs
            .iter()
            .map(|r| (canvas_of(*r), canvases[canvas_of(*r)].clone()))
            .collect();
        match &node.op {
            NodeOp::Conv(c) => {
                let (h, w, _) = graph.shape_of(node.inputs[0], &shapes);
                let plan = match plans_in.and_then(|p| p.get(ni).cloned().flatten()) {
                    Some(p) => {
                        check_plan(c, h, w, &p)?;
                        p
                    }
                    None => plan_conv(c, h, w)
                        .map_err(|e| anyhow::anyhow!("conv {}: {e}", c.name))?,
                };
                if fused_away[ni] {
                    // emitted inside the consuming pointwise node's segments
                } else if let Some(di) = fused_dw_of[ni] {
                    let NodeOp::Conv(dw) = &graph.nodes[di].op else { unreachable!() };
                    let dwplan = plans_in
                        .and_then(|p| p.get(di).cloned().flatten())
                        .expect("checked in the fusion pass");
                    let dsrc_idx = canvas_of(graph.nodes[di].inputs[0]);
                    let dsrc = canvases[dsrc_idx].clone();
                    emit_fused_dwpw(
                        &mut em,
                        (di, dw),
                        &dwplan,
                        (ni, c),
                        &plan,
                        dsrc_idx,
                        &dsrc,
                        (ni + 1, &dst),
                        emit_threads,
                    )?;
                } else if plan.dw {
                    emit_conv_dw(&mut em, ni, c, &plan, srcs[0].0, &srcs[0].1, (ni + 1, &dst))?;
                } else {
                    emit_conv(
                        &mut em,
                        ni,
                        c,
                        &plan,
                        srcs[0].0,
                        &srcs[0].1,
                        (ni + 1, &dst),
                        emit_threads,
                    )?;
                }
                plans.push((c.name.clone(), plan));
            }
            NodeOp::Pool(p) => emit_pool(&mut em, ni, p, srcs[0].0, &srcs[0].1, (ni + 1, &dst))?,
            NodeOp::Add(a) => emit_add(&mut em, ni, a, &srcs, (ni + 1, &dst))?,
            NodeOp::Concat(c) => emit_concat(&mut em, ni, c, &srcs, (ni + 1, &dst))?,
        }
    }
    em.push(Cmd::Halt);

    // ---- dependency edges: read/write region intersection ----------------
    let mut writers: Vec<Vec<usize>> = vec![Vec::new(); n_canvas];
    for (si, m) in em.seg_meta.iter().enumerate() {
        writers[m.write.canvas].push(si);
    }
    for si in 0..em.segments.len() {
        let mut deps: Vec<usize> = Vec::new();
        for r in &em.seg_meta[si].reads {
            for &wi in &writers[r.canvas] {
                if wi != si && r.overlaps(&em.seg_meta[wi].write) && !deps.contains(&wi) {
                    deps.push(wi);
                }
            }
        }
        deps.sort_unstable();
        // Promoted from a debug_assert: a non-topological edge would
        // deadlock or misorder the DAG runner, so release builds must
        // refuse it too.
        anyhow::ensure!(
            deps.iter().all(|&d| d < si),
            "graph {}: segment {si} has a non-topological dependency edge ({deps:?})",
            graph.name
        );
        em.segments[si].deps = deps;
    }

    let dram_px = em.dram.len();
    let output = canvases[canvas_of(graph.output)].clone();
    let compiled = CompiledNet {
        graph: graph.clone(),
        program: em.program,
        dram_init: em.dram,
        input: canvases[0].clone(),
        output,
        plans,
        dram_px,
        segments: em.segments,
    };
    if verify {
        let analysis = crate::analysis::analyze(&compiled)?;
        anyhow::ensure!(
            analysis.is_clean(),
            "graph {}: static schedule analyzer found {} defect(s):\n{}",
            graph.name,
            analysis.diagnostics.len(),
            analysis.report()
        );
    }
    Ok(compiled)
}

/// Fill the weight/bias image blocks of one conv node. Offsets are
/// allocated sequentially in the historical lazy order — (group,
/// feature-tile): bias, then (channel-group, tap) weights — so the
/// DRAM layout is identical to what on-demand emission produced;
/// block *contents* are then computed in parallel across the
/// independent `(node, tap, cgroup)` blocks (the vgg16-scale compile-
/// time item) and are a pure function of the layer weights, so the
/// image is byte-identical at any `emit_threads`.
#[allow(clippy::too_many_arguments)]
fn prefill_conv_blocks(em: &mut Emitter, ni: usize, c: &ConvSpec, plan: &Plan, threads: usize) {
    struct WJob {
        off: usize,
        tap: Tap,
        c0: usize,
        cn: usize,
        m0: usize,
    }
    let weights = c.weights();
    let biases = c.biases();
    let cg = c.cin / c.groups;
    let mg = c.cout / c.groups;
    let tap_list = taps(c.k);
    let mut wjobs: Vec<WJob> = Vec::new();
    // Every (g, mt, ti, cgi) key is visited exactly once per node, so
    // each block is allocated fresh, in the historical order.
    for g in 0..c.groups {
        for mt in 0..plan.m_tiles {
            let o = em.alloc_dram(2 * NUM_CU);
            for f in 0..NUM_CU {
                let m = mt * NUM_CU + f;
                let v = if m < mg { biases[g * mg + m] } else { 0 };
                em.dram[o + 2 * f] = (v as u32 & 0xFFFF) as u16 as i16;
                em.dram[o + 2 * f + 1] = ((v as u32) >> 16) as u16 as i16;
            }
            em.bcache.insert((ni, g, mt), o);
            for cgi in 0..plan.c_groups {
                let c0 = cgi * plan.c_per_group;
                let cn = plan.c_per_group.min(cg - c0);
                for (ti, tp) in tap_list.iter().enumerate() {
                    let len = cn * 9 * NUM_CU;
                    let off = em.alloc_dram(len);
                    em.wcache.insert((ni, g, mt, ti, cgi), (off, len));
                    wjobs.push(WJob { off, tap: *tp, c0, cn, m0: g * mg + mt * NUM_CU });
                }
            }
        }
    }
    let fill = |j: &WJob| tap_weights(&weights, c.k, cg, c.cout, j.tap, j.c0, j.cn, j.m0);
    if threads <= 1 || wjobs.len() < 4 {
        for job in &wjobs {
            let blk = fill(job);
            em.dram[job.off..job.off + blk.len()].copy_from_slice(&blk);
        }
        return;
    }
    let chunk = wjobs.len().div_ceil(threads.min(wjobs.len()));
    let parts: Vec<Vec<(usize, Vec<i16>)>> = std::thread::scope(|scope| {
        let fill = &fill;
        let handles: Vec<_> = wjobs
            .chunks(chunk)
            .map(|jobs| {
                scope.spawn(move || jobs.iter().map(|j| (j.off, fill(j))).collect::<Vec<_>>())
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("weight emitter panicked")).collect()
    });
    for part in parts {
        for (off, blk) in part {
            em.dram[off..off + blk.len()].copy_from_slice(&blk);
        }
    }
}

/// Emit one conv node. `src.pad` may exceed the conv's own pad when a
/// sibling consumer needs a wider apron; reads shift by the difference.
#[allow(clippy::too_many_arguments)]
fn emit_conv(
    em: &mut Emitter,
    ni: usize,
    c: &ConvSpec,
    plan: &Plan,
    src_idx: usize,
    src: &Canvas,
    (dst_idx, dst): (usize, &Canvas),
    emit_threads: usize,
) -> anyhow::Result<()> {
    prefill_conv_blocks(em, ni, c, plan, emit_threads);
    let cg = c.cin / c.groups; // channels per conv group
    let mg = c.cout / c.groups; // features per conv group
    let tap_list = taps(c.k);
    let cfg = ConvCfg { stride: c.stride as u8, shift: c.shift, relu: c.relu };
    // canvas-space offset of this consumer's padded coordinate frame
    let off = src.pad - c.pad;
    em.push(Cmd::SetConv(cfg));

    // SRAM layout per tile: [input tile (c_per_group planar)] [out staging 16]
    let in_tile_px_max =
        plan.tiles.iter().map(|t| t.ih * t.iw).max().unwrap() * plan.c_per_group;

    for tile in &plan.tiles {
        // Everything one tile needs — channel loads, weight/bias
        // prefetches, all conv passes and the output stores — forms one
        // self-contained, independently executable segment.
        let seg_start = em.program.len();
        let in_px = tile.ih * tile.iw;
        let sram_in = 0u32;
        let sram_out = in_tile_px_max as u32;
        // Promoted from a debug_assert: an over-budget tile would
        // silently corrupt SRAM in release builds.
        anyhow::ensure!(
            (in_tile_px_max + tile.oh * tile.ow * NUM_CU) * 2 <= SRAM_BYTES,
            "conv {}: tile staging exceeds the {SRAM_BYTES}-byte SRAM bank",
            c.name
        );
        // track which channel slice currently resides in SRAM
        let mut loaded: Option<(usize, usize)> = None; // (group, cgroup)
        for g in 0..c.groups {
            for mt in 0..plan.m_tiles {
                // bias block (prefilled)
                let boff = em.bcache[&(ni, g, mt)];
                em.push(Cmd::LoadBias(BiasLoad { dram_px: boff as u32 }));

                // Collect this feature-group's pass list, then emit it
                // software-pipelined: the LoadWeights for pass i+1 is
                // issued before Conv(i), so the shadow bank (depth 2)
                // lets the prefetch DMA hide behind Conv(i)'s compute —
                // exactly the §4.2 "pre-fetch controller" behaviour.
                struct PassDesc {
                    cgi: usize,
                    cn: usize,
                    woff: usize,
                    dy: u8,
                    dx: u8,
                }
                let mut passes: Vec<PassDesc> = Vec::new();
                for cgi in 0..plan.c_groups {
                    let c0 = cgi * plan.c_per_group;
                    let cn = plan.c_per_group.min(cg - c0);
                    for (ti, tp) in tap_list.iter().enumerate() {
                        // prefilled by prefill_conv_blocks
                        let (woff, _wlen) = em.wcache[&(ni, g, mt, ti, cgi)];
                        passes.push(PassDesc { cgi, cn, woff, dy: tp.dy, dx: tp.dx });
                    }
                }
                let total_passes = passes.len();
                // real output features this engine tile computes
                let mn = (mg - mt * NUM_CU).min(NUM_CU) as u16;
                // prime the shadow bank with pass 0's weights
                em.push(Cmd::LoadWeights(WeightLoad {
                    dram_px: passes[0].woff as u32,
                    cn: passes[0].cn as u16,
                }));
                for (pass, pd) in passes.iter().enumerate() {
                    // (re)load the input channel slice if not resident
                    if loaded != Some((g, pd.cgi)) {
                        let c0 = pd.cgi * plan.c_per_group;
                        for ci in 0..pd.cn {
                            let ch = g * cg + c0 + ci;
                            em.push(Cmd::LoadImage(DmaDesc {
                                dram_px: src.px_canvas(ch, off + tile.iy0, off + tile.ix0)
                                    as u32,
                                sram_px: sram_in + (ci * in_px) as u32,
                                row_px: tile.iw as u32,
                                rows: tile.ih as u16,
                                dram_pitch: src.cw as u32,
                                sram_pitch: tile.iw as u32,
                            }));
                        }
                        em.push(Cmd::Sync);
                        loaded = Some((g, pd.cgi));
                    }
                    // prefetch the NEXT pass's weights before this Conv
                    if let Some(next) = passes.get(pass + 1) {
                        em.push(Cmd::LoadWeights(WeightLoad {
                            dram_px: next.woff as u32,
                            cn: next.cn as u16,
                        }));
                    }
                    let mut flags = 0u8;
                    if pass == 0 {
                        flags |= PASS_FIRST;
                    }
                    if pass + 1 == total_passes {
                        flags |= PASS_LAST;
                    }
                    em.push(Cmd::Conv(ConvPass {
                        src_px: sram_in,
                        acc_px: 0,
                        dst_px: sram_out,
                        ih: tile.ih as u16,
                        iw: tile.iw as u16,
                        ctot: pd.cn as u16,
                        c0: 0,
                        cn: pd.cn as u16,
                        oh: tile.oh as u16,
                        ow: tile.ow as u16,
                        dy: pd.dy,
                        dx: pd.dx,
                        flags,
                        mn,
                        dpp: 0,
                        dpl: 0,
                    }));
                }
                // store the 16-feature group to the output canvas
                for f in 0..NUM_CU {
                    let m = mt * NUM_CU + f;
                    if m >= mg {
                        break;
                    }
                    let gm = g * mg + m;
                    em.push(Cmd::Store(DmaDesc {
                        dram_px: dst.px(gm, tile.oy0, tile.ox0) as u32,
                        sram_px: sram_out + (f * tile.oh * tile.ow) as u32,
                        row_px: tile.ow as u32,
                        rows: tile.oh as u16,
                        dram_pitch: dst.cw as u32,
                        sram_pitch: tile.ow as u32,
                    }));
                }
                em.push(Cmd::Sync);
            }
        }
        em.end_segment(
            ni,
            seg_start,
            Some(cfg),
            vec![Region {
                canvas: src_idx,
                c0: 0,
                c1: c.cin,
                y0: off + tile.iy0,
                y1: off + tile.iy0 + tile.ih,
                x0: off + tile.ix0,
                x1: off + tile.ix0 + tile.iw,
            }],
            Region {
                canvas: dst_idx,
                c0: 0,
                c1: c.cout,
                y0: dst.pad + tile.oy0,
                y1: dst.pad + tile.oy0 + tile.oh,
                x0: dst.pad + tile.ox0,
                x1: dst.pad + tile.ox0 + tile.ow,
            },
        );
    }
    Ok(())
}

/// Fill the weight/bias blocks of one *depthwise* conv node: per
/// 16-channel lane group, one bias block (lane f = channel `c0 + f`)
/// and one 9×16 block per tap. Blocks are tiny (144 px), so the fill is
/// sequential — trivially byte-identical at any `emit_threads`.
fn prefill_conv_blocks_dw(em: &mut Emitter, ni: usize, c: &ConvSpec, plan: &Plan) {
    let weights = c.weights(); // (K, K, 1, cin) C-order
    let biases = c.biases();
    let tap_list = taps(c.k);
    for cgi in 0..plan.c_groups {
        let c0 = cgi * plan.c_per_group;
        let cn = plan.c_per_group.min(c.cin - c0);
        let o = em.alloc_dram(2 * NUM_CU);
        for f in 0..NUM_CU {
            let v = if f < cn { biases[c0 + f] } else { 0 };
            em.dram[o + 2 * f] = (v as u32 & 0xFFFF) as u16 as i16;
            em.dram[o + 2 * f + 1] = ((v as u32) >> 16) as u16 as i16;
        }
        em.bcache.insert((ni, cgi, 0), o);
        for (ti, tp) in tap_list.iter().enumerate() {
            let len = 9 * NUM_CU;
            let off = em.alloc_dram(len);
            em.wcache.insert((ni, cgi, 0, ti, 0), (off, len));
            let blk = dw_tap_weights(&weights, c.k, c.cin, *tp, c0, cn);
            em.dram[off..off + len].copy_from_slice(&blk);
        }
    }
}

/// Emit one depthwise conv node on the packed fast path: each pass
/// scans `c_per_group` ≤ 16 independent channel planes, one per engine
/// lane, instead of broadcasting one channel across 16 feature columns.
fn emit_conv_dw(
    em: &mut Emitter,
    ni: usize,
    c: &ConvSpec,
    plan: &Plan,
    src_idx: usize,
    src: &Canvas,
    (dst_idx, dst): (usize, &Canvas),
) -> anyhow::Result<()> {
    prefill_conv_blocks_dw(em, ni, c, plan);
    let tap_list = taps(c.k);
    let cfg = ConvCfg { stride: c.stride as u8, shift: c.shift, relu: c.relu };
    let off = src.pad - c.pad;
    em.push(Cmd::SetConv(cfg));

    // SRAM per tile: [input (c_per_group planes)] [out staging 16 planes]
    let in_tile_px_max =
        plan.tiles.iter().map(|t| t.ih * t.iw).max().unwrap() * plan.c_per_group;

    for tile in &plan.tiles {
        let seg_start = em.program.len();
        let in_px = tile.ih * tile.iw;
        let sram_in = 0u32;
        let sram_out = in_tile_px_max as u32;
        // Promoted from a debug_assert (same rationale as emit_conv).
        anyhow::ensure!(
            (in_tile_px_max + tile.oh * tile.ow * NUM_CU) * 2 <= SRAM_BYTES,
            "dw conv {}: tile staging exceeds the {SRAM_BYTES}-byte SRAM bank",
            c.name
        );
        for cgi in 0..plan.c_groups {
            let c0 = cgi * plan.c_per_group;
            let cn = plan.c_per_group.min(c.cin - c0);
            em.push(Cmd::LoadBias(BiasLoad { dram_px: em.bcache[&(ni, cgi, 0)] as u32 }));
            for ci in 0..cn {
                em.push(Cmd::LoadImage(DmaDesc {
                    dram_px: src.px_canvas(c0 + ci, off + tile.iy0, off + tile.ix0) as u32,
                    sram_px: sram_in + (ci * in_px) as u32,
                    row_px: tile.iw as u32,
                    rows: tile.ih as u16,
                    dram_pitch: src.cw as u32,
                    sram_pitch: tile.iw as u32,
                }));
            }
            em.push(Cmd::Sync);
            for (ti, tp) in tap_list.iter().enumerate() {
                let (woff, _) = em.wcache[&(ni, cgi, 0, ti, 0)];
                em.push(Cmd::LoadWeights(WeightLoad { dram_px: woff as u32, cn: 1 }));
                let mut flags = PASS_DW;
                if ti == 0 {
                    flags |= PASS_FIRST;
                }
                if ti + 1 == tap_list.len() {
                    flags |= PASS_LAST;
                }
                em.push(Cmd::Conv(ConvPass {
                    src_px: sram_in,
                    acc_px: 0,
                    dst_px: sram_out,
                    ih: tile.ih as u16,
                    iw: tile.iw as u16,
                    ctot: cn as u16,
                    c0: 0,
                    cn: cn as u16,
                    oh: tile.oh as u16,
                    ow: tile.ow as u16,
                    dy: tp.dy,
                    dx: tp.dx,
                    flags,
                    mn: cn as u16,
                    dpp: 0,
                    dpl: 0,
                }));
            }
            // store the cn finished channel planes
            for m in 0..cn {
                em.push(Cmd::Store(DmaDesc {
                    dram_px: dst.px(c0 + m, tile.oy0, tile.ox0) as u32,
                    sram_px: sram_out + (m * tile.oh * tile.ow) as u32,
                    row_px: tile.ow as u32,
                    rows: tile.oh as u16,
                    dram_pitch: dst.cw as u32,
                    sram_pitch: tile.ow as u32,
                }));
            }
            em.push(Cmd::Sync);
        }
        em.end_segment(
            ni,
            seg_start,
            Some(cfg),
            vec![Region {
                canvas: src_idx,
                c0: 0,
                c1: c.cin,
                y0: off + tile.iy0,
                y1: off + tile.iy0 + tile.ih,
                x0: off + tile.ix0,
                x1: off + tile.ix0 + tile.iw,
            }],
            Region {
                canvas: dst_idx,
                c0: 0,
                c1: c.cout,
                y0: dst.pad + tile.oy0,
                y1: dst.pad + tile.oy0 + tile.oh,
                x0: dst.pad + tile.ox0,
                x1: dst.pad + tile.ox0 + tile.ow,
            },
        );
    }
    Ok(())
}

/// Emit a fused depthwise→1×1-pointwise pair as one node program
/// attributed to the pointwise node. Per tile: the depthwise phase
/// writes all `C` finished channel planes into SRAM *staging* (via the
/// pass's `dpp`/`dpl` strided store), then the pointwise phase runs
/// normal 1×1 passes straight from staging — the dw→pw intermediate
/// never round-trips through DRAM.
///
/// Staging planes are `pt.ih × pt.iw` = `(oh+2) × (ow+2)` — exactly the
/// input window a k=1 conv pass scans (kernel decomposition pads 1×1 to
/// 3×3). The 2-px margin is never zeroed: every margin pixel only ever
/// multiplies a zero-padded weight, which contributes exactly 0 in the
/// wrapping arithmetic.
#[allow(clippy::too_many_arguments)]
fn emit_fused_dwpw(
    em: &mut Emitter,
    (di, dw): (usize, &ConvSpec),
    dwplan: &Plan,
    (ni, pw): (usize, &ConvSpec),
    pwplan: &Plan,
    src_idx: usize,
    src: &Canvas,
    (dst_idx, dst): (usize, &Canvas),
    emit_threads: usize,
) -> anyhow::Result<()> {
    prefill_conv_blocks_dw(em, di, dw, dwplan);
    prefill_conv_blocks(em, ni, pw, pwplan, emit_threads);
    let c_mid = dw.cout; // dw output channels = pw input channels
    let dw_taps = taps(dw.k);
    let dw_cfg = ConvCfg { stride: dw.stride as u8, shift: dw.shift, relu: dw.relu };
    let pw_cfg = ConvCfg { stride: 1, shift: pw.shift, relu: pw.relu };
    let off = src.pad - dw.pad;
    anyhow::ensure!(
        dwplan.tiles.len() == pwplan.tiles.len(),
        "fused {}+{}: tile counts disagree",
        dw.name,
        pw.name
    );
    em.push(Cmd::SetConv(dw_cfg));

    // worst-tile SRAM: [dw input group][staging C planes][pw out 16 planes]
    let in_px_max =
        dwplan.tiles.iter().map(|t| t.ih * t.iw).max().unwrap() * dwplan.c_per_group;
    let s_max = pwplan.tiles.iter().map(|t| t.ih * t.iw).max().unwrap();
    let out_px_max = pwplan.tiles.iter().map(|t| t.oh * t.ow).max().unwrap();
    let sram_need = (in_px_max + c_mid * s_max + out_px_max * NUM_CU) * 2;
    anyhow::ensure!(
        sram_need <= SRAM_BYTES,
        "fused {}+{}: SRAM staging {sram_need} B exceeds the bank",
        dw.name,
        pw.name
    );

    for (dt, pt) in dwplan.tiles.iter().zip(&pwplan.tiles) {
        anyhow::ensure!(
            (dt.oy0, dt.ox0, dt.oh, dt.ow) == (pt.oy0, pt.ox0, pt.oh, pt.ow),
            "fused {}+{}: tile grids disagree",
            dw.name,
            pw.name
        );
        let seg_start = em.program.len();
        em.push(Cmd::SetConv(dw_cfg));
        let in_px = dt.ih * dt.iw;
        let s_px = pt.ih * pt.iw; // one staging plane
        let sram_in = 0u32;
        let sram_stage = in_px_max as u32;
        let sram_out = sram_stage + (c_mid * s_px) as u32;

        // ---- phase 1: depthwise into SRAM staging ----
        for cgi in 0..dwplan.c_groups {
            let c0 = cgi * dwplan.c_per_group;
            let cn = dwplan.c_per_group.min(c_mid - c0);
            em.push(Cmd::LoadBias(BiasLoad { dram_px: em.bcache[&(di, cgi, 0)] as u32 }));
            for ci in 0..cn {
                em.push(Cmd::LoadImage(DmaDesc {
                    dram_px: src.px_canvas(c0 + ci, off + dt.iy0, off + dt.ix0) as u32,
                    sram_px: sram_in + (ci * in_px) as u32,
                    row_px: dt.iw as u32,
                    rows: dt.ih as u16,
                    dram_pitch: src.cw as u32,
                    sram_pitch: dt.iw as u32,
                }));
            }
            em.push(Cmd::Sync);
            for (ti, tp) in dw_taps.iter().enumerate() {
                let (woff, _) = em.wcache[&(di, cgi, 0, ti, 0)];
                em.push(Cmd::LoadWeights(WeightLoad { dram_px: woff as u32, cn: 1 }));
                let mut flags = PASS_DW;
                if ti == 0 {
                    flags |= PASS_FIRST;
                }
                if ti + 1 == dw_taps.len() {
                    flags |= PASS_LAST;
                }
                em.push(Cmd::Conv(ConvPass {
                    src_px: sram_in,
                    acc_px: 0,
                    dst_px: sram_stage + (c0 * s_px) as u32,
                    ih: dt.ih as u16,
                    iw: dt.iw as u16,
                    ctot: cn as u16,
                    c0: 0,
                    cn: cn as u16,
                    oh: dt.oh as u16,
                    ow: dt.ow as u16,
                    dy: tp.dy,
                    dx: tp.dx,
                    flags,
                    mn: cn as u16,
                    dpp: pt.iw as u16,
                    dpl: s_px as u16,
                }));
            }
        }

        // ---- phase 2: pointwise mixer straight from staging ----
        em.push(Cmd::SetConv(pw_cfg));
        let mg = pw.cout;
        for mt in 0..pwplan.m_tiles {
            em.push(Cmd::LoadBias(BiasLoad { dram_px: em.bcache[&(ni, 0, mt)] as u32 }));
            let mn = (mg - mt * NUM_CU).min(NUM_CU) as u16;
            for cgi in 0..pwplan.c_groups {
                let c0 = cgi * pwplan.c_per_group;
                let cn = pwplan.c_per_group.min(c_mid - c0);
                let (woff, _) = em.wcache[&(ni, 0, mt, 0, cgi)];
                em.push(Cmd::LoadWeights(WeightLoad { dram_px: woff as u32, cn: cn as u16 }));
                let mut flags = 0u8;
                if cgi == 0 {
                    flags |= PASS_FIRST;
                }
                if cgi + 1 == pwplan.c_groups {
                    flags |= PASS_LAST;
                }
                em.push(Cmd::Conv(ConvPass {
                    src_px: sram_stage + (c0 * s_px) as u32,
                    acc_px: 0,
                    dst_px: sram_out,
                    ih: pt.ih as u16,
                    iw: pt.iw as u16,
                    ctot: cn as u16,
                    c0: 0,
                    cn: cn as u16,
                    oh: pt.oh as u16,
                    ow: pt.ow as u16,
                    dy: 0,
                    dx: 0,
                    flags,
                    mn,
                    dpp: 0,
                    dpl: 0,
                }));
            }
            for f in 0..NUM_CU {
                let m = mt * NUM_CU + f;
                if m >= mg {
                    break;
                }
                em.push(Cmd::Store(DmaDesc {
                    dram_px: dst.px(m, pt.oy0, pt.ox0) as u32,
                    sram_px: sram_out + (f * pt.oh * pt.ow) as u32,
                    row_px: pt.ow as u32,
                    rows: pt.oh as u16,
                    dram_pitch: dst.cw as u32,
                    sram_pitch: pt.ow as u32,
                }));
            }
            em.push(Cmd::Sync);
        }
        em.end_segment(
            ni,
            seg_start,
            Some(dw_cfg),
            vec![Region {
                canvas: src_idx,
                c0: 0,
                c1: dw.cin,
                y0: off + dt.iy0,
                y1: off + dt.iy0 + dt.ih,
                x0: off + dt.ix0,
                x1: off + dt.ix0 + dt.iw,
            }],
            Region {
                canvas: dst_idx,
                c0: 0,
                c1: pw.cout,
                y0: dst.pad + pt.oy0,
                y1: dst.pad + pt.oy0 + pt.oh,
                x0: dst.pad + pt.ox0,
                x1: dst.pad + pt.ox0 + pt.ow,
            },
        );
    }
    Ok(())
}

/// Emit one pool node: channel-chunked SRAM-resident pooling.
fn emit_pool(
    em: &mut Emitter,
    ni: usize,
    p: &PoolSpec,
    src_idx: usize,
    src: &Canvas,
    (dst_idx, dst): (usize, &Canvas),
) -> anyhow::Result<()> {
    let (ih, iw, c) = (src.h, src.w, src.c);
    let oh = (ih - p.k) / p.stride + 1;
    let ow = (iw - p.k) / p.stride + 1;
    // channels per chunk limited by SRAM: (ih*iw + oh*ow) * 2 bytes each
    let per_ch = (ih * iw + oh * ow) * 2;
    anyhow::ensure!(
        per_ch <= SRAM_BYTES,
        "pool {}: plane {ih}x{iw} exceeds SRAM even one channel at a time",
        p.name
    );
    let cc_max = (SRAM_BYTES / per_ch).max(1).min(c);
    let mut ch0 = 0;
    while ch0 < c {
        // One channel chunk = one independently executable segment.
        let seg_start = em.program.len();
        let cc = cc_max.min(c - ch0);
        let sram_in = 0u32;
        let sram_out = (cc * ih * iw) as u32;
        for ci in 0..cc {
            em.push(Cmd::LoadImage(DmaDesc {
                dram_px: src.px(ch0 + ci, 0, 0) as u32,
                sram_px: sram_in + (ci * ih * iw) as u32,
                row_px: iw as u32,
                rows: ih as u16,
                dram_pitch: src.cw as u32,
                sram_pitch: iw as u32,
            }));
        }
        em.push(Cmd::Sync);
        em.push(Cmd::Pool(PoolPass {
            src_px: sram_in,
            dst_px: sram_out,
            ih: ih as u16,
            iw: iw as u16,
            c: cc as u16,
            k: p.k as u8,
            stride: p.stride as u8,
            avg: p.kind == crate::model::PoolKind::Avg,
        }));
        for ci in 0..cc {
            em.push(Cmd::Store(DmaDesc {
                dram_px: dst.px(ch0 + ci, 0, 0) as u32,
                sram_px: sram_out + (ci * oh * ow) as u32,
                row_px: ow as u32,
                rows: oh as u16,
                dram_pitch: dst.cw as u32,
                sram_pitch: ow as u32,
            }));
        }
        em.push(Cmd::Sync);
        em.end_segment(
            ni,
            seg_start,
            None,
            vec![Region {
                canvas: src_idx,
                c0: ch0,
                c1: ch0 + cc,
                y0: src.pad,
                y1: src.pad + ih,
                x0: src.pad,
                x1: src.pad + iw,
            }],
            Region {
                canvas: dst_idx,
                c0: ch0,
                c1: ch0 + cc,
                y0: dst.pad,
                y1: dst.pad + oh,
                x0: dst.pad,
                x1: dst.pad + ow,
            },
        );
        ch0 += cc;
    }
    Ok(())
}

/// Emit one residual-add node: channel-chunked `Add` passes over both
/// operand canvases.
fn emit_add(
    em: &mut Emitter,
    ni: usize,
    spec: &AddSpec,
    srcs: &[(usize, Canvas)],
    (dst_idx, dst): (usize, &Canvas),
) -> anyhow::Result<()> {
    let (a_idx, a) = (srcs[0].0, &srcs[0].1);
    let (b_idx, b) = (srcs[1].0, &srcs[1].1);
    let (h, w, c) = (a.h, a.w, a.c);
    // SRAM: operand A + operand B + output, each cc·h·w px
    let per_ch = 3 * h * w * 2;
    anyhow::ensure!(
        per_ch <= SRAM_BYTES,
        "add {}: plane {h}x{w} exceeds SRAM even one channel at a time",
        spec.name
    );
    let cc_max = (SRAM_BYTES / per_ch).max(1).min(c);
    let mut ch0 = 0;
    while ch0 < c {
        let seg_start = em.program.len();
        let cc = cc_max.min(c - ch0);
        let n_px = cc * h * w;
        let sram_a = 0u32;
        let sram_b = n_px as u32;
        let sram_out = (2 * n_px) as u32;
        for (src, base) in [(a, sram_a), (b, sram_b)] {
            for ci in 0..cc {
                em.push(Cmd::LoadImage(DmaDesc {
                    dram_px: src.px(ch0 + ci, 0, 0) as u32,
                    sram_px: base + (ci * h * w) as u32,
                    row_px: w as u32,
                    rows: h as u16,
                    dram_pitch: src.cw as u32,
                    sram_pitch: w as u32,
                }));
            }
        }
        em.push(Cmd::Sync);
        em.push(Cmd::Add(AddPass {
            src_a_px: sram_a,
            src_b_px: sram_b,
            dst_px: sram_out,
            n_px: n_px as u32,
            shift: spec.shift,
            relu: spec.relu,
        }));
        for ci in 0..cc {
            em.push(Cmd::Store(DmaDesc {
                dram_px: dst.px(ch0 + ci, 0, 0) as u32,
                sram_px: sram_out + (ci * h * w) as u32,
                row_px: w as u32,
                rows: h as u16,
                dram_pitch: dst.cw as u32,
                sram_pitch: w as u32,
            }));
        }
        em.push(Cmd::Sync);
        let read = |canvas: usize, cv: &Canvas| Region {
            canvas,
            c0: ch0,
            c1: ch0 + cc,
            y0: cv.pad,
            y1: cv.pad + h,
            x0: cv.pad,
            x1: cv.pad + w,
        };
        em.end_segment(
            ni,
            seg_start,
            None,
            vec![read(a_idx, a), read(b_idx, b)],
            Region {
                canvas: dst_idx,
                c0: ch0,
                c1: ch0 + cc,
                y0: dst.pad,
                y1: dst.pad + h,
                x0: dst.pad,
                x1: dst.pad + w,
            },
        );
        ch0 += cc;
    }
    Ok(())
}

/// Emit one concat node: per input, channel-chunked DMA copies into the
/// destination canvas at the input's channel offset. Pure data movement
/// (SRAM-staged LoadImage → Store); each copy is its own segment, so a
/// consumer needing only one branch's channels never waits on the other.
fn emit_concat(
    em: &mut Emitter,
    ni: usize,
    spec: &ConcatSpec,
    srcs: &[(usize, Canvas)],
    (dst_idx, dst): (usize, &Canvas),
) -> anyhow::Result<()> {
    let (h, w) = (dst.h, dst.w);
    let per_ch = h * w * 2;
    anyhow::ensure!(
        per_ch <= SRAM_BYTES,
        "concat {}: plane {h}x{w} exceeds SRAM even one channel at a time",
        spec.name
    );
    let cc_max = (SRAM_BYTES / per_ch).max(1);
    let mut coff = 0usize;
    for (src_idx, src) in srcs {
        let c = src.c;
        let mut ch0 = 0;
        while ch0 < c {
            let seg_start = em.program.len();
            let cc = cc_max.min(c - ch0);
            for ci in 0..cc {
                em.push(Cmd::LoadImage(DmaDesc {
                    dram_px: src.px(ch0 + ci, 0, 0) as u32,
                    sram_px: (ci * h * w) as u32,
                    row_px: w as u32,
                    rows: h as u16,
                    dram_pitch: src.cw as u32,
                    sram_pitch: w as u32,
                }));
            }
            em.push(Cmd::Sync);
            for ci in 0..cc {
                em.push(Cmd::Store(DmaDesc {
                    dram_px: dst.px(coff + ch0 + ci, 0, 0) as u32,
                    sram_px: (ci * h * w) as u32,
                    row_px: w as u32,
                    rows: h as u16,
                    dram_pitch: dst.cw as u32,
                    sram_pitch: w as u32,
                }));
            }
            em.push(Cmd::Sync);
            em.end_segment(
                ni,
                seg_start,
                None,
                vec![Region {
                    canvas: *src_idx,
                    c0: ch0,
                    c1: ch0 + cc,
                    y0: src.pad,
                    y1: src.pad + h,
                    x0: src.pad,
                    x1: src.pad + w,
                }],
                Region {
                    canvas: dst_idx,
                    c0: coff + ch0,
                    c1: coff + ch0 + cc,
                    y0: dst.pad,
                    y1: dst.pad + h,
                    x0: dst.pad,
                    x1: dst.pad + w,
                },
            );
            ch0 += cc;
        }
        coff += c;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    /// Segments must exactly cover the program minus the per-conv-node
    /// `SetConv` and the final `Halt`, without overlap, in node order,
    /// and each must end on the `Sync` barrier the parallel runner's
    /// translation-invariance argument depends on.
    #[test]
    fn segments_partition_the_program() {
        // (vgg16 omitted: compiling its full weight image is bench-scale)
        for name in ["quicknet", "facenet", "alexnet", "edgenet", "widenet", "gapnet", "mobilenet"]
        {
            let graph = zoo::graph_by_name(name).unwrap();
            let compiled = compile_graph(&graph).unwrap();
            let mut covered = 0usize;
            let mut at = 0usize;
            let mut last_node = 0usize;
            for s in &compiled.segments {
                assert!(s.start < s.end && s.end <= compiled.program.len(), "{name}: {s:?}");
                assert!(s.start >= at, "{name}: overlapping segments at {s:?}");
                assert!(s.node >= last_node, "{name}: segments out of node order");
                assert_eq!(
                    compiled.program[s.end - 1],
                    Cmd::Sync,
                    "{name}: segment must end on a Sync barrier"
                );
                // commands skipped between segments are node prologues
                for cmd in &compiled.program[at..s.start] {
                    assert!(matches!(cmd, Cmd::SetConv(_)), "{name}: uncovered {cmd:?}");
                }
                covered += s.end - s.start;
                at = s.end;
                last_node = s.node;
            }
            // tail: only the Halt remains
            assert_eq!(&compiled.program[at..], &[Cmd::Halt], "{name}");
            let n_conv = graph
                .nodes
                .iter()
                .filter(|n| matches!(n.op, crate::model::NodeOp::Conv(_)))
                .count();
            assert_eq!(covered + n_conv + 1, compiled.program.len(), "{name}");
        }
    }

    /// Dependency edges must point backwards, only at segments of
    /// producer nodes, and every read of a produced canvas must create
    /// at least one edge.
    #[test]
    fn segment_deps_are_topological_and_complete() {
        for name in ["facenet", "edgenet", "widenet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let compiled = compile_graph(&graph).unwrap();
            for (si, s) in compiled.segments.iter().enumerate() {
                for &d in &s.deps {
                    assert!(d < si, "{name}: forward dep {d} -> {si}");
                    let producer = compiled.segments[d].node;
                    assert!(
                        graph.nodes[s.node]
                            .inputs
                            .iter()
                            .any(|r| matches!(r, crate::model::NodeRef::Node(i) if *i == producer)),
                        "{name}: segment of node {} depends on non-input node {}",
                        s.node,
                        producer
                    );
                }
                // any segment whose node reads a produced tensor needs deps
                let reads_produced = graph.nodes[s.node]
                    .inputs
                    .iter()
                    .any(|r| matches!(r, crate::model::NodeRef::Node(_)));
                assert_eq!(
                    !s.deps.is_empty(),
                    reads_produced,
                    "{name}: segment {si} of node {} dep count",
                    s.node
                );
            }
        }
    }

    /// Parallel weight-image emission must be byte-identical to
    /// sequential emission: same program, same DRAM image, same
    /// segments — offsets are allocated before the parallel fill and
    /// block contents are emission-order-independent.
    #[test]
    fn parallel_weight_emission_is_byte_identical() {
        for name in ["alexnet", "widenet", "gapnet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let seq = compile_graph_threads(&graph, 1).unwrap();
            for threads in [2usize, 8] {
                let par = compile_graph_threads(&graph, threads).unwrap();
                assert_eq!(par.program, seq.program, "{name} t={threads} program");
                assert_eq!(par.dram_init, seq.dram_init, "{name} t={threads} DRAM image");
                assert_eq!(par.segments, seq.segments, "{name} t={threads} segments");
                assert_eq!(par.dram_px, seq.dram_px, "{name} t={threads}");
            }
        }
    }

    /// compile_graph_with_plans must accept planner-chosen plans and
    /// reject plans violating the emitter's resource contracts.
    #[test]
    fn external_plans_are_checked() {
        use crate::compiler::decompose::plan_with_grid;
        let graph = zoo::graph_by_name("quicknet").unwrap();
        let crate::model::NodeOp::Conv(c) = graph.nodes[0].op.clone() else { panic!() };
        let (h, w) = (graph.in_h, graph.in_w);
        // a finer-than-heuristic grid compiles fine
        let fine = plan_with_grid(&c, h, w, 2, 2, c.cin);
        let plans = vec![Some(fine), None];
        let compiled = compile_graph_with_plans(&graph, &plans).unwrap();
        assert!(compiled.segments.iter().filter(|s| s.node == 0).count() >= 4);
        // an ACC-BUF-violating single tile is rejected with a real error
        let mut bad = graph.clone();
        bad.in_h = 64;
        bad.in_w = 64;
        let huge = plan_with_grid(&c, 64, 64, 1, 1, c.cin);
        let err = compile_graph_with_plans(&bad, &[Some(huge), None]).unwrap_err().to_string();
        assert!(err.contains("ACC BUF"), "{err}");
    }

    /// facenet's early layers exceed the 1024-px ACC BUF tile, so the
    /// plan must decompose them into multiple parallel segments.
    #[test]
    fn facenet_has_parallel_width() {
        let compiled = compile_net(&zoo::facenet()).unwrap();
        let first_layer: Vec<_> =
            compiled.segments.iter().filter(|s| s.node == 0).collect();
        assert!(first_layer.len() >= 4, "expected >=4 tiles, got {}", first_layer.len());
    }

    /// widenet's two stem branches both read only the graph input, so
    /// neither may depend on the other — the parallel width the DAG
    /// scheduler exploits. The concat copies depend on exactly one
    /// branch each.
    #[test]
    fn widenet_branches_are_independent() {
        let graph = zoo::widenet();
        let compiled = compile_graph(&graph).unwrap();
        let node = |n: &str| {
            graph.nodes.iter().position(|x| x.name() == n).unwrap()
        };
        let (wa, wb, cat) = (node("wa"), node("wb"), node("cat"));
        for s in &compiled.segments {
            if s.node == wa || s.node == wb {
                assert!(s.deps.is_empty(), "stem branch has deps: {s:?}");
            }
            if s.node == cat {
                assert!(!s.deps.is_empty());
                let dep_nodes: Vec<usize> =
                    s.deps.iter().map(|&d| compiled.segments[d].node).collect();
                assert!(
                    dep_nodes.iter().all(|&n| n == wa) || dep_nodes.iter().all(|&n| n == wb),
                    "concat copy should wait on exactly one branch: {dep_nodes:?}"
                );
            }
        }
    }

    /// A conv consumer tile must depend only on the producer tiles its
    /// halo actually touches — tile-granular, not node-granular, edges.
    /// A 3-way spatial split makes the far tile untouchable: the halo is
    /// 1 px, the middle tile is wider.
    #[test]
    fn conv_deps_are_tile_granular_where_disjoint() {
        use crate::model::{ConvSpec, LayerSpec};
        let conv = |name: &str, cin: usize| {
            LayerSpec::Conv(ConvSpec {
                name: name.into(),
                k: 3,
                stride: 1,
                pad: 1,
                cin,
                cout: 16,
                shift: 9,
                relu: true,
                wseed: 77,
                bseed: 78,
                groups: 1,
            })
        };
        let net = NetSpec {
            name: "tall".into(),
            in_h: 300,
            in_w: 8,
            in_c: 2,
            layers: vec![conv("c1", 2), conv("c2", 16)],
        };
        let compiled = compile_net(&net).unwrap();
        let c1: Vec<usize> = compiled
            .segments
            .iter()
            .enumerate()
            .filter(|(_, s)| s.node == 0)
            .map(|(i, _)| i)
            .collect();
        let c2: Vec<&Segment> = compiled.segments.iter().filter(|s| s.node == 1).collect();
        assert!(c1.len() >= 3, "producer should split >= 3 ways, got {}", c1.len());
        // first-layer tiles read only the input canvas: no deps
        assert!(c1.iter().all(|&i| compiled.segments[i].deps.is_empty()));
        let mut seen: Vec<usize> = Vec::new();
        let mut some_partial = false;
        for s in &c2 {
            assert!(!s.deps.is_empty());
            assert!(s.deps.iter().all(|d| c1.contains(d)), "dep outside producer: {s:?}");
            some_partial |= s.deps.len() < c1.len();
            for &d in &s.deps {
                if !seen.contains(&d) {
                    seen.push(d);
                }
            }
        }
        assert!(some_partial, "every consumer tile waits on every producer tile");
        assert_eq!(seen.len(), c1.len(), "union of deps must cover the producer");
    }
}
