//! Kernel decomposition (paper §1/§5): any K×K filter runs on the fixed
//! 3×3 CU array as a grid of shifted 3×3 sub-kernels ("taps"), padded
//! with zero weights to Kp = 3·⌈K/3⌉.
//!
//! Sub-kernel (p, q) covers filter rows 3p..3p+3 and cols 3q..3q+3 and
//! sees the input shifted by (3p, 3q); all taps accumulate into the same
//! partial plane (wrapping int32 — order-free). `conv_any` in the Python
//! L2 implements the identical schedule, so the two agree bit-for-bit.

/// One decomposition tap.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tap {
    /// Input shift (= 3p, 3q). Bounded by 9 for K ≤ 11 (fits the ISA's
    /// 4-bit tap fields).
    pub dy: u8,
    pub dx: u8,
    /// Filter-row/col origin of the 3×3 sub-kernel.
    pub fy: usize,
    pub fx: usize,
}

/// Enumerate the taps of a K×K kernel.
pub fn taps(k: usize) -> Vec<Tap> {
    assert!(k >= 1 && k <= 15, "kernel size {k} out of range");
    let kp = 3 * k.div_ceil(3);
    let n = kp / 3;
    let mut out = Vec::with_capacity(n * n);
    for p in 0..n {
        for q in 0..n {
            out.push(Tap { dy: (3 * p) as u8, dx: (3 * q) as u8, fy: 3 * p, fx: 3 * q });
        }
    }
    out
}

/// Extract the weights of one tap for one channel range and one
/// 16-feature group, in the CU staging layout `[ch][tap9][feat16]`,
/// zero-padded where the tap exceeds K or the feature exceeds cout.
///
/// `w` is the layer's full weight tensor in (K, K, cg, cout) C-order
/// (cg = cin/groups); `m0` is the *global* output-feature origin of the
/// group (already includes the conv-group offset).
pub fn tap_weights(
    w: &[i16],
    k: usize,
    cg: usize,
    cout: usize,
    tap: Tap,
    c0: usize,
    cn: usize,
    m0: usize,
) -> Vec<i16> {
    let mut out = vec![0i16; cn * 9 * crate::NUM_CU];
    for ci in 0..cn {
        let ch = c0 + ci;
        for ty in 0..3 {
            for tx in 0..3 {
                let (fy, fx) = (tap.fy + ty, tap.fx + tx);
                if fy >= k || fx >= k {
                    continue; // zero padding beyond the real kernel
                }
                for f in 0..crate::NUM_CU {
                    let m = m0 + f;
                    if m >= cout {
                        continue; // zero padding beyond real features
                    }
                    let v = w[((fy * k + fx) * cg + ch) * cout + m];
                    out[(ci * 9 + ty * 3 + tx) * crate::NUM_CU + f] = v;
                }
            }
        }
    }
    out
}

/// Extract one tap's weights for a *depthwise* conv in the packed
/// channel-lane layout: one 9×16 tap-major block where CU column `m`
/// holds the 3×3 sub-kernel of channel `c0 + m`. The engine then scans
/// 16 independent channel planes per pass instead of broadcasting one
/// channel across 16 feature columns.
///
/// `w` is the layer's weight tensor in (K, K, 1, cin) C-order (cg = 1
/// for depthwise); lanes `cn..16` are zero-padded.
pub fn dw_tap_weights(w: &[i16], k: usize, cin: usize, tap: Tap, c0: usize, cn: usize) -> Vec<i16> {
    assert!((1..=crate::NUM_CU).contains(&cn));
    assert_eq!(w.len(), k * k * cin);
    let mut out = vec![0i16; 9 * crate::NUM_CU];
    for ty in 0..3 {
        for tx in 0..3 {
            let (fy, fx) = (tap.fy + ty, tap.fx + tx);
            if fy >= k || fx >= k {
                continue; // zero padding beyond the real kernel
            }
            for m in 0..cn {
                out[(ty * 3 + tx) * crate::NUM_CU + m] = w[(fy * k + fx) * cin + (c0 + m)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tap_counts() {
        assert_eq!(taps(3).len(), 1);
        assert_eq!(taps(5).len(), 4);
        assert_eq!(taps(7).len(), 9);
        assert_eq!(taps(11).len(), 16);
        assert_eq!(taps(1).len(), 1);
    }

    #[test]
    fn tap_shifts_fit_isa_fields() {
        for k in 1..=11 {
            for t in taps(k) {
                assert!(t.dy <= 9 && t.dx <= 9, "k={k} tap {t:?}");
            }
        }
    }

    #[test]
    fn taps_tile_the_padded_kernel_disjointly() {
        for k in [3usize, 5, 7, 11] {
            let kp = 3 * k.div_ceil(3);
            let mut cover = vec![0u8; kp * kp];
            for t in taps(k) {
                for ty in 0..3 {
                    for tx in 0..3 {
                        cover[(t.fy + ty) * kp + (t.fx + tx)] += 1;
                    }
                }
            }
            assert!(cover.iter().all(|&c| c == 1), "k={k}");
        }
    }

    #[test]
    fn dw_tap_weight_block_is_channel_per_lane() {
        // K=5, cin=20: tap (3,3) is partial; lanes beyond cn are zero.
        let k = 5;
        let cin = 20usize;
        let w: Vec<i16> = (0..k * k * cin).map(|i| i as i16 + 1).collect();
        let tp = taps(5)[3];
        let (c0, cn) = (16usize, 4usize);
        let tw = dw_tap_weights(&w, k, cin, tp, c0, cn);
        assert_eq!(tw.len(), 9 * 16);
        for ty in 0..3 {
            for tx in 0..3 {
                for m in 0..16 {
                    let got = tw[(ty * 3 + tx) * 16 + m];
                    let want = if ty < 2 && tx < 2 && m < cn {
                        w[((3 + ty) * k + 3 + tx) * cin + c0 + m]
                    } else {
                        0
                    };
                    assert_eq!(got, want, "ty={ty} tx={tx} m={m}");
                }
            }
        }
    }

    #[test]
    fn tap_weight_extraction_zero_pads() {
        // K=5, cg=2, cout=3: tap (3,3) covers rows 3..6 of a 6x6 padded
        // kernel — only (3..5, 3..5) are real.
        let k = 5;
        let (cg, cout) = (2usize, 3usize);
        let w: Vec<i16> = (0..k * k * cg * cout).map(|i| i as i16 + 1).collect();
        let tp = taps(5)[3];
        assert_eq!((tp.fy, tp.fx), (3, 3));
        let tw = tap_weights(&w, k, cg, cout, tp, 0, cg, 0);
        assert_eq!(tw.len(), cg * 9 * 16);
        for ci in 0..cg {
            for ty in 0..3 {
                for tx in 0..3 {
                    for f in 0..16 {
                        let got = tw[(ci * 9 + ty * 3 + tx) * 16 + f];
                        let want = if ty < 2 && tx < 2 && f < cout {
                            w[(((3 + ty) * k + 3 + tx) * cg + ci) * cout + f]
                        } else {
                            0
                        };
                        assert_eq!(got, want, "ci={ci} ty={ty} tx={tx} f={f}");
                    }
                }
            }
        }
    }
}
