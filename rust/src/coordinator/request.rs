//! Request/response types of the frame-serving API.

use std::time::{Duration, Instant};

use crate::model::Tensor;
use crate::sim::SimStats;

/// `FrameResult::worker` value for results the coordinator front-end
/// synthesizes without dispatching to a worker (unknown net name,
/// admission rejection).
pub const NO_WORKER: usize = usize::MAX;

/// `FrameResult::chip` value for results not served by any chip
/// (front-end synthesized, or failed after exhausting every chip).
pub const NO_CHIP: usize = usize::MAX;

/// One camera frame submitted for inference, tagged with the registered
/// net that should serve it.
#[derive(Clone, Debug)]
pub struct FrameRequest {
    pub id: u64,
    /// Registry name of the net this frame is routed to.
    pub net: String,
    pub frame: Tensor,
    pub submitted: Instant,
    /// Per-*attempt* service deadline, measured from each dispatch to a
    /// chip (not from submission), so a failover retry onto a healthy
    /// chip gets a fresh budget. `None` = no deadline (legacy
    /// behavior). A frame found past-due at dequeue, or stalled past it
    /// by a slow chip, is re-routed and the miss is accounted.
    pub deadline: Option<Duration>,
}

impl FrameRequest {
    pub fn new(id: u64, net: &str, frame: Tensor) -> Self {
        Self { id, net: net.to_string(), frame, submitted: Instant::now(), deadline: None }
    }

    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.deadline = deadline;
        self
    }
}

/// Successful inference payload for one frame.
#[derive(Clone, Debug)]
pub struct FrameOutput {
    pub output: Tensor,
    /// Simulator event counts for this frame.
    pub stats: SimStats,
    /// Wall-clock latency through the coordinator (queue + sim).
    pub wall_latency_s: f64,
    /// Device latency: cycles / f at the operating point of the chip
    /// that served the frame.
    pub device_latency_s: f64,
    /// Time the frame sat in the bounded queue: submit → worker dequeue.
    pub queue_wait_s: f64,
    /// Number of frames in the pipelined window this frame was served
    /// in (1 = unpipelined single-frame execution). A worker running
    /// with `pipeline_depth = N` dequeues up to `N` consecutive
    /// same-net frames and executes them as one rolling window with
    /// cross-frame segment overlap.
    pub window: usize,
}

/// Classification of a delivered frame failure — lets callers and
/// metrics distinguish "your input was bad" from "the fleet degraded
/// under you" without parsing message strings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameErrorKind {
    /// The requested net name is not in the registry.
    UnknownNet,
    /// The admission policy rejected the frame (over budget in Reject
    /// mode, or larger than the degraded fleet can ever hold).
    Admission,
    /// The frame itself failed validation against the net.
    BadFrame,
    /// The frame was dispatched `1 + max_retries` times and every
    /// attempt failed (chip faults, stalls, deadline misses).
    RetriesExhausted,
    /// No live chip remained to serve or retry the frame.
    ChipsUnavailable,
    /// Simulator/scheduler error while executing the frame.
    Internal,
}

impl FrameErrorKind {
    pub fn name(self) -> &'static str {
        match self {
            FrameErrorKind::UnknownNet => "unknown-net",
            FrameErrorKind::Admission => "admission",
            FrameErrorKind::BadFrame => "bad-frame",
            FrameErrorKind::RetriesExhausted => "retries-exhausted",
            FrameErrorKind::ChipsUnavailable => "chips-unavailable",
            FrameErrorKind::Internal => "internal",
        }
    }
}

/// Why a frame failed (kept `Clone`-able for fan-out consumers, hence a
/// message rather than the source `anyhow::Error`).
#[derive(Clone, Debug, thiserror::Error)]
#[error("{message}")]
pub struct FrameError {
    pub kind: FrameErrorKind,
    pub message: String,
}

impl FrameError {
    pub fn new(kind: FrameErrorKind, message: impl Into<String>) -> Self {
        Self { kind, message: message.into() }
    }
}

/// Why a submission could not be accepted at all. Unlike [`FrameError`]
/// (which is *delivered* on the result channel and accounted per
/// frame), a `SubmitError` means no frame entered the system — the old
/// code path panicked here (`expect("coordinator stopped")`).
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum SubmitError {
    /// `stop()` has already run; the worker pool is shut down.
    #[error("coordinator is stopped")]
    Stopped,
    /// Every chip is dead (or every worker thread has exited), so the
    /// job queue has no consumer left.
    #[error("worker pool disconnected")]
    Disconnected,
}

/// Attempt accounting for one frame, carried on the result envelope so
/// both successes and delivered errors feed the retry/failover/deadline
/// counters in `RunMetrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Attempts {
    /// Dispatches to a chip (1 = served first try; 0 = never
    /// dispatched, i.e. a front-end synthesized result).
    pub attempts: u32,
    /// Re-dispatches that landed on a *different* chip than the one
    /// that failed.
    pub failovers: u32,
    /// Attempts abandoned because the per-attempt deadline had passed.
    pub deadline_misses: u32,
}

/// The result for one frame. A failed frame is *delivered* with its
/// error — callers never see a bare `RecvError` for an accepted frame,
/// and `run_stream` accounts the failure instead of silently
/// undercounting.
#[derive(Clone, Debug)]
pub struct FrameResult {
    pub id: u64,
    /// Net name the frame was routed to (as requested, even if unknown).
    pub net: String,
    /// Worker that served the frame (chip-local index), or
    /// [`NO_WORKER`] for results the front-end synthesized (unknown
    /// net, admission rejection) or that failed off-chip.
    pub worker: usize,
    /// Chip that delivered the frame, or [`NO_CHIP`] when no chip did.
    pub chip: usize,
    /// Retry/failover/deadline accounting for this frame.
    pub attempts: Attempts,
    pub result: Result<FrameOutput, FrameError>,
}

impl FrameResult {
    /// Unwrap the success payload, converting a frame failure into an
    /// `anyhow::Error` with the frame id attached.
    pub fn ok(self) -> anyhow::Result<FrameOutput> {
        let id = self.id;
        self.result.map_err(|e| anyhow::anyhow!("frame {id}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_timestamps() {
        let r = FrameRequest::new(1, "quicknet", Tensor::zeros(2, 2, 1));
        assert!(r.submitted.elapsed().as_secs() < 1);
        assert_eq!(r.id, 1);
        assert_eq!(r.net, "quicknet");
        assert_eq!(r.deadline, None);
        let d = Duration::from_millis(50);
        assert_eq!(r.with_deadline(Some(d)).deadline, Some(d));
    }

    #[test]
    fn frame_error_carries_id_through_ok() {
        let r = FrameResult {
            id: 7,
            net: "quicknet".into(),
            worker: 0,
            chip: 0,
            attempts: Attempts { attempts: 1, ..Default::default() },
            result: Err(FrameError::new(FrameErrorKind::Internal, "boom")),
        };
        let err = r.ok().unwrap_err().to_string();
        assert!(err.contains("frame 7") && err.contains("boom"), "{err}");
    }

    #[test]
    fn error_kind_names_are_stable() {
        assert_eq!(FrameErrorKind::RetriesExhausted.name(), "retries-exhausted");
        assert_eq!(FrameErrorKind::Admission.name(), "admission");
        let e = FrameError::new(FrameErrorKind::BadFrame, "h != 8");
        assert_eq!(e.kind, FrameErrorKind::BadFrame);
        assert_eq!(e.to_string(), "h != 8");
    }

    #[test]
    fn submit_error_messages() {
        assert_eq!(SubmitError::Stopped.to_string(), "coordinator is stopped");
        assert!(SubmitError::Disconnected.to_string().contains("disconnected"));
    }
}
