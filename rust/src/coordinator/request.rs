//! Request/response types of the frame-serving API.

use std::time::Instant;

use crate::model::Tensor;
use crate::sim::SimStats;

/// One camera frame submitted for inference.
#[derive(Clone, Debug)]
pub struct FrameRequest {
    pub id: u64,
    pub frame: Tensor,
    pub submitted: Instant,
}

impl FrameRequest {
    pub fn new(id: u64, frame: Tensor) -> Self {
        Self { id, frame, submitted: Instant::now() }
    }
}

/// Successful inference payload for one frame.
#[derive(Clone, Debug)]
pub struct FrameOutput {
    pub output: Tensor,
    /// Simulator event counts for this frame.
    pub stats: SimStats,
    /// Wall-clock latency through the coordinator (queue + sim).
    pub wall_latency_s: f64,
    /// Device latency: cycles / f at the configured operating point.
    pub device_latency_s: f64,
}

/// Why a frame failed (kept `Clone`-able for fan-out consumers, hence a
/// message rather than the source `anyhow::Error`).
#[derive(Clone, Debug, thiserror::Error)]
#[error("{message}")]
pub struct FrameError {
    pub message: String,
}

/// The result for one frame. A failed frame is *delivered* with its
/// error — callers never see a bare `RecvError`, and `run_stream`
/// accounts the failure instead of silently undercounting.
#[derive(Clone, Debug)]
pub struct FrameResult {
    pub id: u64,
    /// Worker that served the frame.
    pub worker: usize,
    pub result: Result<FrameOutput, FrameError>,
}

impl FrameResult {
    /// Unwrap the success payload, converting a frame failure into an
    /// `anyhow::Error` with the frame id attached.
    pub fn ok(self) -> anyhow::Result<FrameOutput> {
        let id = self.id;
        self.result.map_err(|e| anyhow::anyhow!("frame {id}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_timestamps() {
        let r = FrameRequest::new(1, Tensor::zeros(2, 2, 1));
        assert!(r.submitted.elapsed().as_secs() < 1);
        assert_eq!(r.id, 1);
    }

    #[test]
    fn frame_error_carries_id_through_ok() {
        let r = FrameResult {
            id: 7,
            worker: 0,
            result: Err(FrameError { message: "boom".into() }),
        };
        let err = r.ok().unwrap_err().to_string();
        assert!(err.contains("frame 7") && err.contains("boom"), "{err}");
    }
}
