//! Request/response types of the frame-serving API.

use std::time::Instant;

use crate::model::Tensor;
use crate::sim::SimStats;

/// `FrameResult::worker` value for results the coordinator front-end
/// synthesizes without dispatching to a worker (unknown net name,
/// admission rejection).
pub const NO_WORKER: usize = usize::MAX;

/// One camera frame submitted for inference, tagged with the registered
/// net that should serve it.
#[derive(Clone, Debug)]
pub struct FrameRequest {
    pub id: u64,
    /// Registry name of the net this frame is routed to.
    pub net: String,
    pub frame: Tensor,
    pub submitted: Instant,
}

impl FrameRequest {
    pub fn new(id: u64, net: &str, frame: Tensor) -> Self {
        Self { id, net: net.to_string(), frame, submitted: Instant::now() }
    }
}

/// Successful inference payload for one frame.
#[derive(Clone, Debug)]
pub struct FrameOutput {
    pub output: Tensor,
    /// Simulator event counts for this frame.
    pub stats: SimStats,
    /// Wall-clock latency through the coordinator (queue + sim).
    pub wall_latency_s: f64,
    /// Device latency: cycles / f at the configured operating point.
    pub device_latency_s: f64,
    /// Time the frame sat in the bounded queue: submit → worker dequeue.
    pub queue_wait_s: f64,
    /// Number of frames in the pipelined window this frame was served
    /// in (1 = unpipelined single-frame execution). A worker running
    /// with `pipeline_depth = N` dequeues up to `N` consecutive
    /// same-net frames and executes them as one rolling window with
    /// cross-frame segment overlap.
    pub window: usize,
}

/// Why a frame failed (kept `Clone`-able for fan-out consumers, hence a
/// message rather than the source `anyhow::Error`).
#[derive(Clone, Debug, thiserror::Error)]
#[error("{message}")]
pub struct FrameError {
    pub message: String,
}

/// Why a submission could not be accepted at all. Unlike [`FrameError`]
/// (which is *delivered* on the result channel and accounted per
/// frame), a `SubmitError` means no frame entered the system — the old
/// code path panicked here (`expect("coordinator stopped")`).
#[derive(Clone, Debug, PartialEq, Eq, thiserror::Error)]
pub enum SubmitError {
    /// `stop()` has already run; the worker pool is shut down.
    #[error("coordinator is stopped")]
    Stopped,
    /// Every worker thread has exited (e.g. after a panic), so the job
    /// queue has no consumer left.
    #[error("worker pool disconnected")]
    Disconnected,
}

/// The result for one frame. A failed frame is *delivered* with its
/// error — callers never see a bare `RecvError` for an accepted frame,
/// and `run_stream` accounts the failure instead of silently
/// undercounting.
#[derive(Clone, Debug)]
pub struct FrameResult {
    pub id: u64,
    /// Net name the frame was routed to (as requested, even if unknown).
    pub net: String,
    /// Worker that served the frame, or [`NO_WORKER`] for results the
    /// front-end synthesized (unknown net, admission rejection).
    pub worker: usize,
    pub result: Result<FrameOutput, FrameError>,
}

impl FrameResult {
    /// Unwrap the success payload, converting a frame failure into an
    /// `anyhow::Error` with the frame id attached.
    pub fn ok(self) -> anyhow::Result<FrameOutput> {
        let id = self.id;
        self.result.map_err(|e| anyhow::anyhow!("frame {id}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_timestamps() {
        let r = FrameRequest::new(1, "quicknet", Tensor::zeros(2, 2, 1));
        assert!(r.submitted.elapsed().as_secs() < 1);
        assert_eq!(r.id, 1);
        assert_eq!(r.net, "quicknet");
    }

    #[test]
    fn frame_error_carries_id_through_ok() {
        let r = FrameResult {
            id: 7,
            net: "quicknet".into(),
            worker: 0,
            result: Err(FrameError { message: "boom".into() }),
        };
        let err = r.ok().unwrap_err().to_string();
        assert!(err.contains("frame 7") && err.contains("boom"), "{err}");
    }

    #[test]
    fn submit_error_messages() {
        assert_eq!(SubmitError::Stopped.to_string(), "coordinator is stopped");
        assert!(SubmitError::Disconnected.to_string().contains("disconnected"));
    }
}
