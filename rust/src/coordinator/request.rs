//! Request/response types of the frame-serving API.

use std::time::Instant;

use crate::model::Tensor;
use crate::sim::SimStats;

/// One camera frame submitted for inference.
#[derive(Clone, Debug)]
pub struct FrameRequest {
    pub id: u64,
    pub frame: Tensor,
    pub submitted: Instant,
}

impl FrameRequest {
    pub fn new(id: u64, frame: Tensor) -> Self {
        Self { id, frame, submitted: Instant::now() }
    }
}

/// The inference result for one frame.
#[derive(Clone, Debug)]
pub struct FrameResult {
    pub id: u64,
    pub output: Tensor,
    /// Simulator event counts for this frame.
    pub stats: SimStats,
    /// Wall-clock latency through the coordinator (queue + sim).
    pub wall_latency_s: f64,
    /// Device latency: cycles / f at the configured operating point.
    pub device_latency_s: f64,
    /// Worker that served the frame.
    pub worker: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_timestamps() {
        let r = FrameRequest::new(1, Tensor::zeros(2, 2, 1));
        assert!(r.submitted.elapsed().as_secs() < 1);
        assert_eq!(r.id, 1);
    }
}
