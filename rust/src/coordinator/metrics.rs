//! Serving metrics: latency histograms + throughput + energy rollup.

use crate::energy::{EnergyModel, OperatingPoint};
use crate::sim::SimStats;
use crate::util::stats::{eng, Histogram, Running};

/// Aggregated metrics of a serving run.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    pub frames: u64,
    pub wall_s: f64,
    /// Wall-clock latency histogram (µs buckets).
    pub wall_lat_us: Histogram,
    /// Device latency histogram (µs at the DVFS point).
    pub dev_lat_us: Histogram,
    pub queue_wait_us: Running,
    pub totals: SimStats,
    pub op: OperatingPoint,
}

impl RunMetrics {
    pub fn new(op: OperatingPoint) -> Self {
        Self {
            frames: 0,
            wall_s: 0.0,
            wall_lat_us: Histogram::new(),
            dev_lat_us: Histogram::new(),
            queue_wait_us: Running::new(),
            totals: SimStats::default(),
            op,
        }
    }

    pub fn record(&mut self, stats: &SimStats, wall_latency_s: f64, device_latency_s: f64) {
        self.frames += 1;
        self.wall_lat_us.record(wall_latency_s * 1e6);
        self.dev_lat_us.record(device_latency_s * 1e6);
        self.totals.add(stats);
    }

    /// Device-side throughput: frames per *simulated* second.
    pub fn device_fps(&self) -> f64 {
        let total_dev_s = self.totals.cycles as f64 * self.op.cycle_s();
        if total_dev_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / total_dev_s
    }

    /// Effective device throughput in ops/s (2×MACs / device time).
    pub fn device_ops_per_s(&self) -> f64 {
        let total_dev_s = self.totals.cycles as f64 * self.op.cycle_s();
        if total_dev_s == 0.0 {
            return 0.0;
        }
        self.totals.ops() as f64 / total_dev_s
    }

    /// Host-side sim throughput (frames / wall second).
    pub fn wall_fps(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.wall_s
    }

    pub fn report(&self, energy: &EnergyModel) -> String {
        let e = energy.energy(&self.totals, self.op);
        format!(
            "frames={} | device: {:.1} fps, {}OPS eff, util {:.2} | dev-lat p50/p95/p99 = \
             {:.1}/{:.1}/{:.1} ms | energy/frame {:.2} mJ (on-chip {:.2} mJ) | host {:.1} fps",
            self.frames,
            self.device_fps(),
            eng(self.device_ops_per_s()),
            self.totals.utilization(),
            self.dev_lat_us.quantile(0.50) / 1e3,
            self.dev_lat_us.quantile(0.95) / 1e3,
            self.dev_lat_us.quantile(0.99) / 1e3,
            e.total_j() / self.frames.max(1) as f64 * 1e3,
            e.onchip_j() / self.frames.max(1) as f64 * 1e3,
            self.wall_fps(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::dvfs::PEAK;

    #[test]
    fn record_and_rates() {
        let mut m = RunMetrics::new(PEAK);
        let stats = SimStats { cycles: 500_000, macs: 50_000_000, ..Default::default() };
        for _ in 0..10 {
            m.record(&stats, 0.01, 0.001);
        }
        m.wall_s = 0.1;
        assert_eq!(m.frames, 10);
        // 10 frames / (5M cycles / 500MHz = 10ms) = 1000 fps
        assert!((m.device_fps() - 1000.0).abs() < 1.0, "{}", m.device_fps());
        assert!((m.wall_fps() - 100.0).abs() < 1.0);
        assert!(m.device_ops_per_s() > 0.0);
        let rep = m.report(&EnergyModel::default());
        assert!(rep.contains("frames=10"));
    }
}
