//! Serving metrics: latency histograms + throughput + energy rollup.

use super::request::FrameResult;
use crate::energy::{EnergyModel, OperatingPoint};
use crate::sim::SimStats;
use crate::util::stats::{eng, Histogram, Running};

/// Aggregated metrics of a serving run. Failed frames are first-class:
/// they count in `errors` (with the last message kept for reporting)
/// instead of silently vanishing from the stream accounting.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    /// Successfully served frames.
    pub frames: u64,
    /// Frames that failed (delivered as `Err` results).
    pub errors: u64,
    /// Most recent failure message, if any.
    pub last_error: Option<String>,
    pub wall_s: f64,
    /// Wall-clock latency histogram (µs buckets).
    pub wall_lat_us: Histogram,
    /// Device latency histogram (µs at the DVFS point).
    pub dev_lat_us: Histogram,
    pub queue_wait_us: Running,
    pub totals: SimStats,
    pub op: OperatingPoint,
}

impl RunMetrics {
    pub fn new(op: OperatingPoint) -> Self {
        Self {
            frames: 0,
            errors: 0,
            last_error: None,
            wall_s: 0.0,
            wall_lat_us: Histogram::new(),
            dev_lat_us: Histogram::new(),
            queue_wait_us: Running::new(),
            totals: SimStats::default(),
            op,
        }
    }

    pub fn record(&mut self, stats: &SimStats, wall_latency_s: f64, device_latency_s: f64) {
        self.frames += 1;
        self.wall_lat_us.record(wall_latency_s * 1e6);
        self.dev_lat_us.record(device_latency_s * 1e6);
        self.totals.add(stats);
    }

    pub fn record_error(&mut self, message: &str) {
        self.errors += 1;
        self.last_error = Some(message.to_string());
    }

    /// Fold one delivered [`FrameResult`] into the rollup.
    pub fn record_result(&mut self, r: &FrameResult) {
        match &r.result {
            Ok(o) => self.record(&o.stats, o.wall_latency_s, o.device_latency_s),
            Err(e) => self.record_error(&e.message),
        }
    }

    /// Device-side throughput: frames per *simulated* second.
    pub fn device_fps(&self) -> f64 {
        let total_dev_s = self.totals.cycles as f64 * self.op.cycle_s();
        if total_dev_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / total_dev_s
    }

    /// Effective device throughput in ops/s (2×MACs / device time).
    pub fn device_ops_per_s(&self) -> f64 {
        let total_dev_s = self.totals.cycles as f64 * self.op.cycle_s();
        if total_dev_s == 0.0 {
            return 0.0;
        }
        self.totals.ops() as f64 / total_dev_s
    }

    /// Host-side sim throughput (frames / wall second).
    pub fn wall_fps(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.wall_s
    }

    pub fn report(&self, energy: &EnergyModel) -> String {
        let e = energy.energy(&self.totals, self.op);
        let errs = match (&self.last_error, self.errors) {
            (Some(msg), n) if n > 0 => format!(" | ERRORS {n} (last: {msg})"),
            _ => String::new(),
        };
        format!(
            "frames={}{errs} | device: {:.1} fps, {}OPS eff, util {:.2} | dev-lat p50/p95/p99 = \
             {:.1}/{:.1}/{:.1} ms | energy/frame {:.2} mJ (on-chip {:.2} mJ) | host {:.1} fps",
            self.frames,
            self.device_fps(),
            eng(self.device_ops_per_s()),
            self.totals.utilization(),
            self.dev_lat_us.quantile(0.50) / 1e3,
            self.dev_lat_us.quantile(0.95) / 1e3,
            self.dev_lat_us.quantile(0.99) / 1e3,
            e.total_j() / self.frames.max(1) as f64 * 1e3,
            e.onchip_j() / self.frames.max(1) as f64 * 1e3,
            self.wall_fps(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::dvfs::PEAK;

    #[test]
    fn record_and_rates() {
        let mut m = RunMetrics::new(PEAK);
        let stats = SimStats { cycles: 500_000, macs: 50_000_000, ..Default::default() };
        for _ in 0..10 {
            m.record(&stats, 0.01, 0.001);
        }
        m.wall_s = 0.1;
        assert_eq!(m.frames, 10);
        assert_eq!(m.errors, 0);
        // 10 frames / (5M cycles / 500MHz = 10ms) = 1000 fps
        assert!((m.device_fps() - 1000.0).abs() < 1.0, "{}", m.device_fps());
        assert!((m.wall_fps() - 100.0).abs() < 1.0);
        assert!(m.device_ops_per_s() > 0.0);
        let rep = m.report(&EnergyModel::default());
        assert!(rep.contains("frames=10"));
        assert!(!rep.contains("ERRORS"));
        m.record_error("shape mismatch");
        m.record_error("sim fault");
        assert_eq!(m.errors, 2);
        let rep = m.report(&EnergyModel::default());
        assert!(rep.contains("ERRORS 2") && rep.contains("sim fault"), "{rep}");
    }
}
