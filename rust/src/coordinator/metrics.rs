//! Serving metrics: latency histograms + throughput + energy rollup,
//! aggregate, per registered net, and per chip.

use super::fault::ChipHealth;
use super::request::FrameResult;
use crate::energy::{EnergyModel, OperatingPoint};
use crate::sim::SimStats;
use crate::util::stats::{eng, Histogram, Running};

/// Aggregated metrics of a serving run. Failed frames are first-class:
/// they count in `errors` (with the last message kept for reporting)
/// instead of silently vanishing from the stream accounting.
#[derive(Clone, Debug)]
pub struct RunMetrics {
    /// Successfully served frames.
    pub frames: u64,
    /// Frames that failed (delivered as `Err` results), plus frames
    /// lost to a dead worker or a failed submission — every frame that
    /// entered `run_stream` lands in exactly one of `frames`/`errors`.
    pub errors: u64,
    /// Most recent failure message, if any.
    pub last_error: Option<String>,
    /// Re-dispatches: dispatch attempts beyond each frame's first
    /// (served-first-try frames contribute 0).
    pub retries: u64,
    /// Re-dispatches that moved a frame to a *different* chip than the
    /// one that failed it.
    pub failovers: u64,
    /// Attempts abandoned because the per-attempt deadline had passed.
    pub deadline_misses: u64,
    /// Submissions bounced by admission control (delivered as
    /// `FrameErrorKind::Admission` errors; a subset of `errors`).
    pub rejects: u64,
    pub wall_s: f64,
    /// Wall-clock latency histogram (µs buckets).
    pub wall_lat_us: Histogram,
    /// Device latency histogram (µs at the DVFS point).
    pub dev_lat_us: Histogram,
    /// Queue wait (submit → worker dequeue) per served frame, in µs —
    /// log-bucketed so the tail (p95/p99) is reportable, with exact
    /// mean/max.
    pub queue_wait_us: Histogram,
    /// Pipelined-window size each served frame ran in (1 =
    /// unpipelined). Mean > 1 means cross-frame windows actually
    /// formed; the latency/throughput split of a depth sweep reads as:
    /// per-frame latency from `wall_lat_us` (grows with depth — a
    /// frame shares its tile workers with its window), throughput from
    /// `wall_fps` (grows with depth — the frame-boundary idle gap is
    /// gone).
    pub window: Running,
    pub totals: SimStats,
    pub op: OperatingPoint,
}

impl RunMetrics {
    pub fn new(op: OperatingPoint) -> Self {
        Self {
            frames: 0,
            errors: 0,
            last_error: None,
            retries: 0,
            failovers: 0,
            deadline_misses: 0,
            rejects: 0,
            wall_s: 0.0,
            wall_lat_us: Histogram::new(),
            dev_lat_us: Histogram::new(),
            queue_wait_us: Histogram::new(),
            window: Running::new(),
            totals: SimStats::default(),
            op,
        }
    }

    pub fn record(
        &mut self,
        stats: &SimStats,
        wall_latency_s: f64,
        device_latency_s: f64,
        queue_wait_s: f64,
        window: usize,
    ) {
        self.frames += 1;
        self.wall_lat_us.record(wall_latency_s * 1e6);
        self.dev_lat_us.record(device_latency_s * 1e6);
        self.queue_wait_us.record(queue_wait_s * 1e6);
        self.window.push(window as f64);
        self.totals.add(stats);
    }

    pub fn record_error(&mut self, message: &str) {
        self.errors += 1;
        self.last_error = Some(message.to_string());
    }

    /// Fold one delivered [`FrameResult`] into the rollup. Attempt
    /// accounting rides the envelope, so retries spent on a frame count
    /// whether it ultimately served or errored.
    pub fn record_result(&mut self, r: &FrameResult) {
        self.retries += u64::from(r.attempts.attempts.saturating_sub(1));
        self.failovers += u64::from(r.attempts.failovers);
        self.deadline_misses += u64::from(r.attempts.deadline_misses);
        match &r.result {
            Ok(o) => self.record(
                &o.stats,
                o.wall_latency_s,
                o.device_latency_s,
                o.queue_wait_s,
                o.window,
            ),
            Err(e) => {
                if e.kind == super::request::FrameErrorKind::Admission {
                    self.rejects += 1;
                }
                self.record_error(&e.message)
            }
        }
    }

    /// Device-side throughput: frames per *simulated* second.
    pub fn device_fps(&self) -> f64 {
        let total_dev_s = self.totals.cycles as f64 * self.op.cycle_s();
        if total_dev_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / total_dev_s
    }

    /// Effective device throughput in ops/s (2×MACs / device time).
    pub fn device_ops_per_s(&self) -> f64 {
        let total_dev_s = self.totals.cycles as f64 * self.op.cycle_s();
        if total_dev_s == 0.0 {
            return 0.0;
        }
        self.totals.ops() as f64 / total_dev_s
    }

    /// Host-side sim throughput (frames / wall second).
    pub fn wall_fps(&self) -> f64 {
        if self.wall_s == 0.0 {
            return 0.0;
        }
        self.frames as f64 / self.wall_s
    }

    pub fn report(&self, energy: &EnergyModel) -> String {
        let e = energy.energy(&self.totals, self.op);
        let errs = match (&self.last_error, self.errors) {
            (Some(msg), n) if n > 0 => format!(" | ERRORS {n} (last: {msg})"),
            _ => String::new(),
        };
        let pipe = if self.window.max() > 1.0 {
            format!(" | pipe window mean/max {:.1}/{:.0}", self.window.mean(), self.window.max())
        } else {
            String::new()
        };
        let robust = if self.retries + self.failovers + self.deadline_misses > 0 {
            format!(
                " | retries {} / failovers {} / deadline-miss {}",
                self.retries, self.failovers, self.deadline_misses
            )
        } else {
            String::new()
        };
        format!(
            "frames={}{errs} | device: {:.1} fps, {}OPS eff, util {:.2} | dev-lat p50/p95/p99 = \
             {:.1}/{:.1}/{:.1} ms | q-wait p50/p95/p99 {:.0}/{:.0}/{:.0} µs{pipe}{robust} | \
             energy/frame {:.2} mJ (on-chip {:.2} mJ) | host {:.1} fps",
            self.frames,
            self.device_fps(),
            eng(self.device_ops_per_s()),
            self.totals.utilization(),
            self.dev_lat_us.quantile(0.50) / 1e3,
            self.dev_lat_us.quantile(0.95) / 1e3,
            self.dev_lat_us.quantile(0.99) / 1e3,
            self.queue_wait_us.quantile(0.50),
            self.queue_wait_us.quantile(0.95),
            self.queue_wait_us.quantile(0.99),
            e.total_j() / self.frames.max(1) as f64 * 1e3,
            e.onchip_j() / self.frames.max(1) as f64 * 1e3,
            self.wall_fps(),
        )
    }
}

/// Rollup of a mixed-traffic serving run: the aggregate [`RunMetrics`]
/// plus one per registered net (registry order) and — when the
/// coordinator runs chip-sharded — one per chip, at that chip's own
/// DVFS point. Results for net names that were never registered (a
/// delivered "unknown net" error) count in the aggregate only.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub aggregate: RunMetrics,
    pub per_net: Vec<(String, RunMetrics)>,
    /// Per-chip rows, indexed by chip id. Empty when the report was
    /// built without chip topology ([`ServeReport::new`]). A frame's
    /// row is the chip that *delivered* it; front-end synthesized
    /// results and frames that died off-chip land in the aggregate
    /// only.
    pub per_chip: Vec<RunMetrics>,
    /// Final health of each chip at the end of the run (parallel to
    /// `per_chip`; empty for non-sharded reports).
    pub chip_health: Vec<ChipHealth>,
}

impl ServeReport {
    pub fn new(op: OperatingPoint, nets: &[String]) -> Self {
        Self {
            aggregate: RunMetrics::new(op),
            per_net: nets.iter().map(|n| (n.clone(), RunMetrics::new(op))).collect(),
            per_chip: Vec::new(),
            chip_health: Vec::new(),
        }
    }

    /// Like [`ServeReport::new`], plus a per-chip row at each chip's
    /// operating point.
    pub fn with_chips(op: OperatingPoint, nets: &[String], chip_ops: &[OperatingPoint]) -> Self {
        let mut rep = Self::new(op, nets);
        rep.per_chip = chip_ops.iter().map(|&c| RunMetrics::new(c)).collect();
        rep.chip_health = vec![ChipHealth::Healthy; chip_ops.len()];
        rep
    }

    /// Metrics for one registered net.
    pub fn net(&self, name: &str) -> Option<&RunMetrics> {
        self.per_net.iter().find(|(n, _)| n == name).map(|(_, m)| m)
    }

    fn net_mut(&mut self, name: &str) -> Option<&mut RunMetrics> {
        self.per_net.iter_mut().find(|(n, _)| n == name).map(|(_, m)| m)
    }

    /// Fold one delivered result into the aggregate, its net's row, and
    /// (when chip topology is known) the delivering chip's row.
    pub fn record_result(&mut self, r: &FrameResult) {
        self.aggregate.record_result(r);
        if let Some(m) = self.net_mut(&r.net) {
            m.record_result(r);
        }
        if let Some(m) = self.per_chip.get_mut(r.chip) {
            m.record_result(r);
        }
    }

    /// Account a frame that produced no delivered result (dead worker,
    /// failed submission) as an error on the aggregate and its net.
    pub fn record_error_for(&mut self, net: &str, message: &str) {
        self.aggregate.record_error(message);
        if let Some(m) = self.net_mut(net) {
            m.record_error(message);
        }
    }

    /// Stamp the run's wall-clock on the aggregate and every per-net /
    /// per-chip row (the rows share the run's wall, so each row's
    /// `wall_fps` is its share of throughput over the whole run).
    pub fn set_wall(&mut self, wall_s: f64) {
        self.aggregate.wall_s = wall_s;
        for (_, m) in &mut self.per_net {
            m.wall_s = wall_s;
        }
        for m in &mut self.per_chip {
            m.wall_s = wall_s;
        }
    }

    /// Every frame accounted: served + errored, across the aggregate.
    pub fn accounted(&self) -> u64 {
        self.aggregate.frames + self.aggregate.errors
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{
        Attempts, FrameError, FrameErrorKind, FrameOutput, NO_CHIP, NO_WORKER,
    };
    use crate::energy::dvfs::PEAK;

    fn ok_result(id: u64, net: &str, chip: usize, attempts: Attempts) -> FrameResult {
        FrameResult {
            id,
            net: net.into(),
            worker: 0,
            chip,
            attempts,
            result: Ok(FrameOutput {
                output: crate::model::Tensor::zeros(1, 1, 1),
                stats: SimStats { cycles: 1000, ..Default::default() },
                wall_latency_s: 0.001,
                device_latency_s: 0.0005,
                queue_wait_s: 0.0001,
                window: 1,
            }),
        }
    }

    #[test]
    fn record_and_rates() {
        let mut m = RunMetrics::new(PEAK);
        let stats = SimStats { cycles: 500_000, macs: 50_000_000, ..Default::default() };
        for i in 0..10 {
            m.record(&stats, 0.01, 0.001, 0.0005, if i < 5 { 1 } else { 3 });
        }
        m.wall_s = 0.1;
        assert_eq!(m.frames, 10);
        assert_eq!(m.errors, 0);
        // 10 frames / (5M cycles / 500MHz = 10ms) = 1000 fps
        assert!((m.device_fps() - 1000.0).abs() < 1.0, "{}", m.device_fps());
        assert!((m.wall_fps() - 100.0).abs() < 1.0);
        assert!(m.device_ops_per_s() > 0.0);
        assert_eq!(m.queue_wait_us.count(), 10);
        assert!((m.queue_wait_us.mean() - 500.0).abs() < 1e-6);
        assert_eq!(m.window.count(), 10);
        assert!((m.window.mean() - 2.0).abs() < 1e-9);
        let rep = m.report(&EnergyModel::default());
        assert!(rep.contains("frames=10"));
        assert!(rep.contains("q-wait"));
        assert!(rep.contains("pipe window"), "windows > 1 must surface: {rep}");
        assert!(!rep.contains("ERRORS"));
        assert!(!rep.contains("retries"), "clean run must not print robustness counters: {rep}");
        m.record_error("shape mismatch");
        m.record_error("sim fault");
        assert_eq!(m.errors, 2);
        let rep = m.report(&EnergyModel::default());
        assert!(rep.contains("ERRORS 2") && rep.contains("sim fault"), "{rep}");
    }

    #[test]
    fn attempts_fold_into_retry_counters() {
        let mut m = RunMetrics::new(PEAK);
        // served on the 3rd attempt, 2 failovers, 1 deadline miss
        m.record_result(&ok_result(
            0,
            "a",
            2,
            Attempts { attempts: 3, failovers: 2, deadline_misses: 1 },
        ));
        // retry-exhausted error still contributes its spent attempts
        m.record_result(&FrameResult {
            id: 1,
            net: "a".into(),
            worker: NO_WORKER,
            chip: 1,
            attempts: Attempts { attempts: 2, failovers: 1, deadline_misses: 0 },
            result: Err(FrameError::new(FrameErrorKind::RetriesExhausted, "gone")),
        });
        assert_eq!(m.frames, 1);
        assert_eq!(m.errors, 1);
        assert_eq!(m.retries, 3, "(3-1) + (2-1)");
        assert_eq!(m.failovers, 3);
        assert_eq!(m.deadline_misses, 1);
        let rep = m.report(&EnergyModel::default());
        assert!(rep.contains("retries 3 / failovers 3 / deadline-miss 1"), "{rep}");
    }

    #[test]
    fn admission_rejects_counted_and_qwait_percentiles_reported() {
        let mut m = RunMetrics::new(PEAK);
        m.record_result(&FrameResult {
            id: 0,
            net: "a".into(),
            worker: NO_WORKER,
            chip: NO_CHIP,
            attempts: Attempts::default(),
            result: Err(FrameError::new(FrameErrorKind::Admission, "queue full")),
        });
        m.record_result(&FrameResult {
            id: 1,
            net: "a".into(),
            worker: NO_WORKER,
            chip: NO_CHIP,
            attempts: Attempts::default(),
            result: Err(FrameError::new(FrameErrorKind::Internal, "boom")),
        });
        assert_eq!(m.errors, 2);
        assert_eq!(m.rejects, 1, "only Admission errors count as rejects");
        // queue-wait percentiles surface in the report line
        let stats = SimStats { cycles: 1000, ..Default::default() };
        m.record(&stats, 0.01, 0.001, 0.0005, 1);
        let rep = m.report(&EnergyModel::default());
        assert!(rep.contains("q-wait p50/p95/p99"), "{rep}");
    }

    #[test]
    fn serve_report_routes_per_net() {
        let nets = vec!["a".to_string(), "b".to_string()];
        let mut rep = ServeReport::new(PEAK, &nets);
        rep.record_result(&ok_result(0, "a", 0, Attempts { attempts: 1, ..Default::default() }));
        let bad = FrameResult {
            id: 1,
            net: "b".into(),
            worker: NO_WORKER,
            chip: NO_CHIP,
            attempts: Attempts::default(),
            result: Err(FrameError::new(FrameErrorKind::Internal, "nope")),
        };
        rep.record_result(&bad);
        rep.record_error_for("b", "worker died: frame 2 undelivered");
        // unknown net lands in the aggregate only
        let unk = FrameResult {
            id: 3,
            net: "ghost".into(),
            worker: NO_WORKER,
            chip: NO_CHIP,
            attempts: Attempts::default(),
            result: Err(FrameError::new(FrameErrorKind::UnknownNet, "unknown net 'ghost'")),
        };
        rep.record_result(&unk);
        assert_eq!(rep.aggregate.frames, 1);
        assert_eq!(rep.aggregate.errors, 3);
        assert_eq!(rep.net("a").unwrap().frames, 1);
        assert_eq!(rep.net("a").unwrap().errors, 0);
        assert_eq!(rep.net("b").unwrap().errors, 2);
        assert!(rep.net("ghost").is_none());
        assert_eq!(rep.accounted(), 4);
        assert!(rep.per_chip.is_empty(), "plain reports carry no chip rows");
    }

    #[test]
    fn serve_report_routes_per_chip() {
        let nets = vec!["a".to_string()];
        let mut rep = ServeReport::with_chips(PEAK, &nets, &[PEAK, PEAK]);
        rep.record_result(&ok_result(0, "a", 0, Attempts { attempts: 1, ..Default::default() }));
        let retried = Attempts { attempts: 2, failovers: 1, deadline_misses: 0 };
        rep.record_result(&ok_result(1, "a", 1, retried));
        // NO_CHIP results must not panic or land on a chip row
        rep.record_result(&FrameResult {
            id: 2,
            net: "a".into(),
            worker: NO_WORKER,
            chip: NO_CHIP,
            attempts: Attempts::default(),
            result: Err(FrameError::new(FrameErrorKind::ChipsUnavailable, "no chips")),
        });
        rep.set_wall(0.5);
        assert_eq!(rep.per_chip.len(), 2);
        assert_eq!(rep.per_chip[0].frames, 1);
        assert_eq!(rep.per_chip[1].frames, 1);
        assert_eq!(rep.per_chip[1].failovers, 1);
        assert_eq!(rep.aggregate.frames, 2);
        assert_eq!(rep.aggregate.errors, 1);
        assert!((rep.per_chip[0].wall_s - 0.5).abs() < 1e-12);
        assert_eq!(rep.chip_health, vec![ChipHealth::Healthy, ChipHealth::Healthy]);
        assert_eq!(rep.accounted(), 3);
    }
}
