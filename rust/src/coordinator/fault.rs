//! Deterministic fault injection for chip-sharded serving.
//!
//! A [`FaultPlan`] is a seeded, fully reproducible schedule of faults:
//! each [`FaultEvent`] names a chip, a chip-local dequeue index, and a
//! [`FaultKind`]. Workers consult the plan at every frame dequeue, so a
//! given `(seed, chips)` pair replays the exact same failure trajectory
//! on every run — the chaos tests assert lossless accounting without
//! racing on thread scheduling. This generalizes the old ad-hoc
//! `inject_worker_panic` hook (still available, now targetable) into
//! the four failure modes a resource-limited multi-chip deployment
//! actually sees: a worker thread dying, a whole chip dying, a
//! transient per-frame fault, and a compute stall (slow chip).

use crate::util::rng::XorShift32;
use std::collections::VecDeque;

/// Health of one chip-level fault domain.
///
/// Transitions: `Healthy → Degraded` on a fault, `Degraded →
/// Quarantined` after `quarantine_after` consecutive failures,
/// `Quarantined → Degraded` lazily once the cooldown expires (recovery
/// re-admits the chip to routing and grows the admission budget back),
/// any state `→ Dead` on chip death (terminal). Successes walk
/// `Degraded → Healthy`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChipHealth {
    Healthy,
    Degraded,
    Quarantined,
    Dead,
}

impl ChipHealth {
    pub fn name(self) -> &'static str {
        match self {
            ChipHealth::Healthy => "healthy",
            ChipHealth::Degraded => "degraded",
            ChipHealth::Quarantined => "quarantined",
            ChipHealth::Dead => "dead",
        }
    }
    /// Dead chips never come back; everything else can serve again.
    pub fn is_dead(self) -> bool {
        self == ChipHealth::Dead
    }
}

/// What goes wrong when a fault event fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The dequeuing worker thread panics. The in-hand frame fails over
    /// to another chip first, so the panic costs a thread, not a frame.
    WorkerPanic,
    /// The whole chip dies: its queue is closed and drained (every
    /// queued frame fails over), its workers exit, health goes `Dead`.
    ChipDeath,
    /// The attempt fails without executing — a retryable per-frame
    /// fault (ECC hit, bus error, watchdog reset).
    TransientFail,
    /// The chip stalls for `ms` milliseconds before serving. With a
    /// deadline configured, a stalled-past-deadline frame fails over.
    Stall { ms: u64 },
}

impl FaultKind {
    pub fn describe(self) -> &'static str {
        match self {
            FaultKind::WorkerPanic => "worker panic",
            FaultKind::ChipDeath => "chip death",
            FaultKind::TransientFail => "transient fault",
            FaultKind::Stall { .. } => "compute stall",
        }
    }
}

/// One scheduled fault: fires on `chip` when its cumulative frame
/// dequeue counter reaches `frame` (0 = the first frame that chip ever
/// dequeues). Chip-local indices keep the plan deterministic no matter
/// how routing interleaves nets and submitters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    pub chip: usize,
    pub frame: u64,
    pub kind: FaultKind,
}

/// A reproducible schedule of [`FaultEvent`]s.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: no injected faults (production default).
    pub fn none() -> Self {
        Self::default()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Builder for hand-written plans (tests, benches). Later events at
    /// the same `(chip, frame)` slot are dropped — one fault per
    /// dequeue, first writer wins — so plans compose predictably.
    pub fn with(mut self, chip: usize, frame: u64, kind: FaultKind) -> Self {
        if !self.events.iter().any(|e| e.chip == chip && e.frame == frame) {
            self.events.push(FaultEvent { chip, frame, kind });
        }
        self
    }

    /// Deterministic pseudo-random plan for `chips` chips over a run of
    /// roughly `frames` frames. Same `(seed, chips, frames)` → same
    /// plan, always. Shape choices keep the fleet serviceable:
    /// - at most one `ChipDeath`, and none at all when `chips == 1`
    ///   (a dead only-chip would turn every case into "all frames
    ///   error", which tests nothing about failover);
    /// - event frame indices are spread over the first `frames`
    ///   chip-local dequeues so they actually fire;
    /// - stalls are 5–50 ms — long enough to blow a tight deadline,
    ///   short enough for tests.
    pub fn seeded(seed: u32, chips: usize, frames: usize) -> Self {
        let chips = chips.max(1);
        let horizon = frames.max(1) as u32;
        let mut rng = XorShift32::new(seed ^ 0xFA17_0000);
        let n_events = 2 + rng.next_usize(2 + chips);
        let mut plan = FaultPlan::none();
        let mut death_used = false;
        for _ in 0..n_events {
            let chip = rng.next_usize(chips);
            let frame = u64::from(rng.next_u32() % horizon);
            let roll = rng.next_u32() % 100;
            let kind = if roll < 40 {
                FaultKind::TransientFail
            } else if roll < 70 {
                FaultKind::Stall { ms: 5 + u64::from(rng.next_u32() % 46) }
            } else if roll < 85 || chips == 1 || death_used {
                FaultKind::WorkerPanic
            } else {
                death_used = true;
                FaultKind::ChipDeath
            };
            plan = plan.with(chip, frame, kind);
        }
        plan
    }

    /// The events scheduled for one chip, sorted by frame index —
    /// handed to that chip's fault ledger at startup.
    pub(crate) fn events_for(&self, chip: usize) -> VecDeque<FaultEvent> {
        let mut evs: Vec<FaultEvent> =
            self.events.iter().copied().filter(|e| e.chip == chip).collect();
        evs.sort_by_key(|e| e.frame);
        evs.into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_reproducible_and_bounded() {
        for seed in [0u32, 1, 7, 0xDEAD_BEEF] {
            let a = FaultPlan::seeded(seed, 4, 32);
            let b = FaultPlan::seeded(seed, 4, 32);
            assert_eq!(a, b, "seed {seed} not reproducible");
            assert!(!a.is_empty());
            let deaths = a.events().iter().filter(|e| e.kind == FaultKind::ChipDeath).count();
            assert!(deaths <= 1, "seed {seed}: {deaths} chip deaths");
            for e in a.events() {
                assert!(e.chip < 4);
                assert!(e.frame < 32);
                if let FaultKind::Stall { ms } = e.kind {
                    assert!((5..=50).contains(&ms));
                }
            }
        }
    }

    #[test]
    fn single_chip_plans_never_kill_the_only_chip() {
        for seed in 0..64u32 {
            let p = FaultPlan::seeded(seed, 1, 16);
            assert!(
                p.events().iter().all(|e| e.kind != FaultKind::ChipDeath),
                "seed {seed} kills the only chip"
            );
        }
    }

    #[test]
    fn builder_dedups_same_slot_first_wins() {
        let p = FaultPlan::none()
            .with(0, 3, FaultKind::TransientFail)
            .with(0, 3, FaultKind::ChipDeath)
            .with(1, 3, FaultKind::WorkerPanic);
        assert_eq!(p.events().len(), 2);
        assert_eq!(p.events()[0].kind, FaultKind::TransientFail);
    }

    #[test]
    fn events_for_filters_and_sorts() {
        let p = FaultPlan::none()
            .with(1, 9, FaultKind::TransientFail)
            .with(0, 5, FaultKind::WorkerPanic)
            .with(1, 2, FaultKind::Stall { ms: 10 });
        let c1 = p.events_for(1);
        assert_eq!(c1.len(), 2);
        assert_eq!(c1[0].frame, 2);
        assert_eq!(c1[1].frame, 9);
        assert!(p.events_for(2).is_empty());
    }

    #[test]
    fn health_names_and_terminality() {
        assert_eq!(ChipHealth::Quarantined.name(), "quarantined");
        assert!(ChipHealth::Dead.is_dead());
        assert!(!ChipHealth::Degraded.is_dead());
    }
}
