//! L3 coordinator: the streaming frame server in front of the
//! (simulated) accelerator — the system the paper's FPGA demo (Fig. 8)
//! sketches, built out as a deployable component.
//!
//! A smart-vision device streams camera frames; the coordinator owns the
//! request queue, dispatches frames to accelerator workers (one chip =
//! one worker; multi-chip setups just add workers), applies
//! backpressure when the queue fills, and reports latency/throughput
//! both in wall time and in *simulated device time* (cycles at the
//! configured DVFS point).
//!
//! Threads + bounded channels (tokio is not vendorable offline — see
//! DESIGN.md §Deviations); the dataflow is the same reactor shape.

pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::RunMetrics;
pub use request::{FrameError, FrameOutput, FrameRequest, FrameResult};
pub use server::{Coordinator, CoordinatorConfig};
