//! L3 coordinator: the streaming frame server in front of the
//! (simulated) accelerator — the system the paper's FPGA demo (Fig. 8)
//! sketches, built out as a deployable component.
//!
//! A smart-vision device streams camera frames; the coordinator owns a
//! **multi-net serving registry** (`name → Arc<NetRunner>`) in front of
//! a fleet of **chip-level fault domains**: each chip has a private
//! accelerator pool, queue, workers, DVFS point, and health state, and
//! frames route data-parallel (least-loaded) across the healthy chips.
//! Backpressure applies when a chip's bounded queue fills, and an
//! admission policy budgets the DRAM-image bytes of in-flight frames —
//! scaled down pro rata when chips die or are quarantined, so
//! degradation sheds load instead of deadlocking. Metrics are kept per
//! net, per chip, and in aggregate, in wall time and in *simulated
//! device time* (cycles at each chip's DVFS point) — and every frame
//! is accounted: failures are delivered results or counted errors,
//! never silent drops.
//!
//! The `fault` module adds deterministic seeded fault injection
//! (worker panics, chip deaths, transient faults, compute stalls),
//! per-attempt deadlines, and bounded retry/failover — the lossless
//! accounting invariant holds under every seeded fault plan.
//!
//! Observability rides on `CoordinatorConfig::obs` ([`crate::obs`]):
//! per-segment span tracing through the serving path, a structured
//! fleet event log with monotonic sequence numbers (faults, retries,
//! failovers, health transitions), and Prometheus exposition over the
//! serve report. Disabled (the default) it is a pair of `Option`
//! checks per site and leaves outputs/stats bit-identical.
//!
//! With `CoordinatorConfig::pipeline_depth > 1`, workers dequeue
//! contiguous same-net *windows* of frames and run them through the
//! cross-frame pipelined scheduler: frame N+1's early segments overlap
//! frame N's tail on the tile workers, per-frame results and stats
//! staying bit-identical to unpipelined serving.
//!
//! Threads + bounded channels (tokio is not vendorable offline — see
//! DESIGN.md §Deviations); the dataflow is the same reactor shape.

pub mod fault;
pub mod metrics;
pub mod request;
pub mod server;

pub use fault::{ChipHealth, FaultEvent, FaultKind, FaultPlan};
pub use metrics::{RunMetrics, ServeReport};
pub use request::{
    Attempts, FrameError, FrameErrorKind, FrameOutput, FrameRequest, FrameResult, SubmitError,
    NO_CHIP, NO_WORKER,
};
pub use server::{
    AdmissionMode, AdmissionPolicy, AutoOp, Coordinator, CoordinatorConfig, Pending,
    DVFS_LADDER_MHZ,
};
