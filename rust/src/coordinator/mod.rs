//! L3 coordinator: the streaming frame server in front of the
//! (simulated) accelerator — the system the paper's FPGA demo (Fig. 8)
//! sketches, built out as a deployable component.
//!
//! A smart-vision device streams camera frames; the coordinator owns a
//! **multi-net serving registry** (`name → Arc<NetRunner>`) and one
//! shared worker pool: any worker serves any registered net, frames are
//! tagged with the net they target, backpressure applies when the
//! bounded queue fills, and an admission policy budgets the DRAM-image
//! bytes of in-flight frames across the heterogeneous runners. Metrics
//! are kept per net and in aggregate, in wall time and in *simulated
//! device time* (cycles at the configured DVFS point) — and every
//! frame is accounted: failures are delivered results or counted
//! errors, never silent drops.
//!
//! With `CoordinatorConfig::pipeline_depth > 1`, workers dequeue
//! contiguous same-net *windows* of frames and run them through the
//! cross-frame pipelined scheduler: frame N+1's early segments overlap
//! frame N's tail on the tile workers, per-frame results and stats
//! staying bit-identical to unpipelined serving.
//!
//! Threads + bounded channels (tokio is not vendorable offline — see
//! DESIGN.md §Deviations); the dataflow is the same reactor shape.

pub mod metrics;
pub mod request;
pub mod server;

pub use metrics::{RunMetrics, ServeReport};
pub use request::{FrameError, FrameOutput, FrameRequest, FrameResult, SubmitError, NO_WORKER};
pub use server::{AdmissionMode, AdmissionPolicy, Coordinator, CoordinatorConfig, Pending};
