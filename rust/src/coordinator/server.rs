//! The streaming frame server: bounded queue → worker pool → results.
//!
//! Each worker owns one simulated accelerator (compile-once, run-many);
//! the dispatcher is a bounded mpsc channel, so a saturated device
//! back-pressures the camera source instead of buffering unboundedly —
//! the same control law a real smart-vision pipeline needs. A frame
//! that fails still produces a delivered [`FrameResult`] (with the
//! error inside), so `submit()` callers never see a bare `RecvError`
//! and `run_stream` accounts every frame.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::metrics::RunMetrics;
use super::request::{FrameError, FrameOutput, FrameRequest, FrameResult};
use crate::compiler::NetRunner;
use crate::energy::OperatingPoint;
use crate::model::{Graph, NetSpec, Tensor};

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Accelerator instances (chips).
    pub workers: usize,
    /// Bounded queue depth (frames) — backpressure beyond this.
    pub queue_depth: usize,
    /// Host-side parallelism *inside* each frame: the compiled segment
    /// DAG executes over this many threads
    /// (`NetRunner::run_frame_parallel`). 1 = sequential. Results and
    /// stats are bit-identical either way; only wall latency changes.
    pub tile_workers: usize,
    /// DVFS point the devices run at.
    pub op: OperatingPoint,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self { workers: 1, queue_depth: 4, tile_workers: 1, op: crate::energy::dvfs::PEAK }
    }
}

enum Job {
    Frame(FrameRequest, SyncSender<FrameResult>),
    Stop,
}

/// The serving front-end.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    tx: SyncSender<Job>,
    handles: Vec<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Compile a linear net once and start the worker pool.
    pub fn start(net: &NetSpec, cfg: CoordinatorConfig) -> anyhow::Result<Self> {
        Self::start_graph(&Graph::from_net(net), cfg)
    }

    /// Compile a graph (branch/residual topologies included) once and
    /// start the worker pool.
    pub fn start_graph(graph: &Graph, cfg: CoordinatorConfig) -> anyhow::Result<Self> {
        let runner = Arc::new(NetRunner::from_graph(graph)?);
        let (tx, rx) = sync_channel::<Job>(cfg.queue_depth);
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let mut handles = Vec::new();
        for w in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let runner = Arc::clone(&runner);
            let op = cfg.op;
            let tile_workers = cfg.tile_workers.max(1);
            handles.push(std::thread::spawn(move || loop {
                let job = { rx.lock().unwrap().recv() };
                match job {
                    Ok(Job::Frame(req, out)) => {
                        let result = match runner.run_frame_parallel(&req.frame, tile_workers) {
                            Ok((output, stats)) => {
                                Ok(FrameOutput {
                                    output,
                                    device_latency_s: stats.cycles as f64 * op.cycle_s(),
                                    wall_latency_s: req.submitted.elapsed().as_secs_f64(),
                                    stats,
                                })
                            }
                            Err(e) => Err(FrameError { message: format!("{e:#}") }),
                        };
                        let _ = out.send(FrameResult { id: req.id, worker: w, result });
                    }
                    Ok(Job::Stop) | Err(_) => break,
                }
            }));
        }
        Ok(Self { cfg, tx, handles, next_id: AtomicU64::new(0) })
    }

    /// Submit one frame; blocks when the queue is full (backpressure).
    /// Returns the receiver for this frame's result.
    pub fn submit(&self, frame: Tensor) -> Receiver<FrameResult> {
        let (otx, orx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Job::Frame(FrameRequest::new(id, frame), otx))
            .expect("coordinator stopped");
        orx
    }

    /// Convenience: push a batch of frames through and gather metrics —
    /// failures included (`RunMetrics::errors`).
    pub fn run_stream(&self, frames: Vec<Tensor>) -> RunMetrics {
        let mut metrics = RunMetrics::new(self.cfg.op);
        let t0 = Instant::now();
        let mut pending = std::collections::VecDeque::new();
        for f in frames {
            pending.push_back(self.submit(f));
            // drain opportunistically to keep the pipe moving
            while let Some(front) = pending.front() {
                match front.try_recv() {
                    Ok(r) => {
                        metrics.record_result(&r);
                        pending.pop_front();
                    }
                    Err(_) => break,
                }
            }
        }
        for rx in pending {
            if let Ok(r) = rx.recv() {
                metrics.record_result(&r);
            }
        }
        metrics.wall_s = t0.elapsed().as_secs_f64();
        metrics
    }

    pub fn stop(mut self) {
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Job::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::{run_graph_ref, run_net_ref};
    use crate::model::zoo;

    #[test]
    fn serves_frames_correctly_in_order_of_ids() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let frames: Vec<Tensor> =
            (0..6).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect();
        let rxs: Vec<_> = frames.iter().map(|f| coord.submit(f.clone())).collect();
        for (i, (rx, f)) in rxs.into_iter().zip(&frames).enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, i as u64);
            let out = r.ok().unwrap();
            assert_eq!(out.output, run_net_ref(&net, f), "frame {i} wrong result");
            assert!(out.device_latency_s > 0.0);
        }
        coord.stop();
    }

    #[test]
    fn multi_worker_stream_has_all_frames() {
        let net = zoo::quicknet();
        let cfg = CoordinatorConfig { workers: 3, queue_depth: 2, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        let frames: Vec<Tensor> =
            (0..20).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect();
        let m = coord.run_stream(frames);
        assert_eq!(m.frames, 20);
        assert_eq!(m.errors, 0);
        assert!(m.device_fps() > 0.0);
        coord.stop();
    }

    #[test]
    fn tile_parallel_serving_is_bit_exact() {
        let net = zoo::facenet();
        let cfg = CoordinatorConfig { tile_workers: 3, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        for s in 0..3 {
            let f = Tensor::random_image(s, net.in_h, net.in_w, net.in_c);
            let out = coord.submit(f.clone()).recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_net_ref(&net, &f), "frame {s}");
        }
        coord.stop();
    }

    #[test]
    fn graph_net_serving_is_bit_exact() {
        let graph = zoo::edgenet();
        let cfg = CoordinatorConfig { tile_workers: 2, ..Default::default() };
        let coord = Coordinator::start_graph(&graph, cfg).unwrap();
        for s in 0..2 {
            let f = Tensor::random_image(s, graph.in_h, graph.in_w, graph.in_c);
            let out = coord.submit(f.clone()).recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_graph_ref(&graph, &f), "frame {s}");
        }
        coord.stop();
    }

    /// A failing frame must be *delivered* as an error, not dropped:
    /// the submitter sees the message, and run_stream accounts it.
    #[test]
    fn failed_frames_are_delivered_and_accounted() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let bad = Tensor::zeros(3, 3, 1); // wrong shape for quicknet
        let r = coord.submit(bad.clone()).recv().expect("result must arrive");
        assert!(r.result.is_err());
        let msg = r.ok().unwrap_err().to_string();
        assert!(msg.contains("frame") && msg.contains("shape"), "{msg}");

        let mut frames: Vec<Tensor> = (0..4)
            .map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c))
            .collect();
        frames.insert(2, bad);
        let m = coord.run_stream(frames);
        assert_eq!(m.frames, 4, "good frames still served");
        assert_eq!(m.errors, 1, "bad frame accounted as an error");
        assert!(m.last_error.as_deref().unwrap_or("").contains("shape"));
        coord.stop();
    }
}
