//! The streaming frame server: a multi-net serving registry in front
//! of one shared worker pool.
//!
//! `Coordinator::start_registry` compiles each named graph once into
//! `name → Arc<NetRunner>`; every worker can serve every net, so a
//! burst on one workload soaks up whatever capacity the others leave
//! idle — the "one accelerator, many smart-vision apps" deployment the
//! paper targets. The dispatcher is a bounded FIFO job queue, so a
//! saturated device back-pressures the camera sources instead of
//! buffering unboundedly, and an [`AdmissionPolicy`] bounds the total
//! DRAM-image bytes of in-flight frames across the heterogeneous
//! runners (the pooled simulators share one [`AccelPool`]).
//!
//! With `pipeline_depth > 1` a worker dequeues a contiguous same-net
//! *window* of frames and executes it through the cross-frame
//! pipelined scheduler (`NetRunner::run_frames_pipelined`): frame
//! N+1's early segments run on tile workers that would otherwise idle
//! at the frame boundary. Windows are opportunistic (never waited
//! for), FIFO order is preserved, and per-frame results/stats remain
//! bit-identical to unpipelined serving.
//!
//! **Every frame is accounted.** A frame that fails produces a
//! *delivered* [`FrameResult`] with the error inside (bad input,
//! unknown net name, admission rejection); a frame lost to a dead
//! worker is folded into [`RunMetrics`] as an error by `run_stream` /
//! `run_mix`; and submitting to a stopped coordinator is a clean
//! [`SubmitError`], not a panic.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvError, SyncSender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::metrics::{RunMetrics, ServeReport};
use super::request::{FrameError, FrameOutput, FrameRequest, FrameResult, SubmitError, NO_WORKER};
use crate::compiler::{AccelPool, NetRunner};
use crate::energy::OperatingPoint;
use crate::model::{Graph, NetSpec, Tensor};
use crate::planner::PlanPolicy;

/// What to do when admitting a frame would exceed the DRAM budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Block the submitter until in-flight frames release enough bytes
    /// (backpressure — the default).
    Block,
    /// Deliver the frame immediately as a [`FrameError`] (load
    /// shedding); the rejection is accounted like any other error.
    Reject,
}

/// Bounds the total DRAM-image bytes of in-flight frames across all
/// registered nets: a frame is admitted only when its runner's
/// footprint ([`NetRunner::dram_frame_bytes`]) fits in the remaining
/// budget. Heterogeneous nets compete for the same budget, so a few
/// big-canvas frames can't starve the pool unnoticed.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Total in-flight DRAM-image budget in bytes (`usize::MAX` =
    /// unbounded, the default).
    pub max_dram_bytes: usize,
    pub mode: AdmissionMode,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self { max_dram_bytes: usize::MAX, mode: AdmissionMode::Block }
    }
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Accelerator instances (chips).
    pub workers: usize,
    /// Bounded queue depth (frames) — backpressure beyond this.
    pub queue_depth: usize,
    /// Host-side parallelism *inside* each frame: the compiled segment
    /// DAG executes over this many threads
    /// (`NetRunner::run_frame_parallel`). 1 = sequential. Results and
    /// stats are bit-identical either way; only wall latency changes.
    pub tile_workers: usize,
    /// Cross-frame pipelining: a worker dequeues up to this many
    /// consecutive same-net frames in one go and runs them as a
    /// rolling window (`NetRunner::run_frames_pipelined`), so frame
    /// N+1's early segments start on tile workers that would otherwise
    /// idle while frame N's tail drains. 1 (the default) = one frame
    /// per dequeue, the pre-pipelining behaviour. Batching is
    /// opportunistic — a worker never *waits* for a window to fill, so
    /// depth > 1 cannot deadlock a trickling source — and engages only
    /// when `tile_workers ≥ 2` (with one tile thread a window would
    /// just serialize frames on one pool worker). Note each in-flight
    /// frame still holds its own admission reservation: a Block-mode
    /// budget below `depth × dram_frame_bytes` simply caps the
    /// achievable window, it does not wedge.
    pub pipeline_depth: usize,
    /// DVFS point the devices run at.
    pub op: OperatingPoint,
    /// DRAM-image budget for in-flight frames.
    pub admission: AdmissionPolicy,
    /// Decomposition planner every registered net compiles with
    /// (`planner::PlanPolicy`): `Heuristic` is the historical solver,
    /// `MinTraffic`/`DagAware` run the optimization planner. Frame
    /// outputs are bit-identical under every policy; only DRAM traffic
    /// and tile-level parallelism change.
    pub plan_policy: PlanPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            queue_depth: 4,
            tile_workers: 1,
            pipeline_depth: 1,
            op: crate::energy::dvfs::PEAK,
            admission: AdmissionPolicy::default(),
            plan_policy: PlanPolicy::Heuristic,
        }
    }
}

/// In-flight DRAM-byte ledger behind the admission policy.
struct Admission {
    policy: AdmissionPolicy,
    in_flight: Mutex<usize>,
    freed: Condvar,
}

impl Admission {
    /// Reserve `bytes` for one frame, or explain why it can't run.
    fn admit(&self, bytes: usize) -> Result<(), String> {
        if bytes > self.policy.max_dram_bytes {
            return Err(format!(
                "admission: frame needs {bytes} B of DRAM image, budget is {} B",
                self.policy.max_dram_bytes
            ));
        }
        let mut used = self.in_flight.lock().unwrap();
        match self.policy.mode {
            AdmissionMode::Block => {
                while *used + bytes > self.policy.max_dram_bytes {
                    used = self.freed.wait(used).unwrap();
                }
            }
            AdmissionMode::Reject => {
                if *used + bytes > self.policy.max_dram_bytes {
                    return Err(format!(
                        "admission: rejected — {bytes} B needed, {} B of {} B already in flight",
                        *used, self.policy.max_dram_bytes
                    ));
                }
            }
        }
        *used += bytes;
        Ok(())
    }

    fn release(&self, bytes: usize) {
        let mut used = self.in_flight.lock().unwrap();
        *used -= bytes;
        drop(used);
        self.freed.notify_all();
    }
}

/// An owned admission reservation, released exactly once — on drop.
/// It rides inside the [`Job`], so the bytes come back whether the
/// frame was served, its worker panicked mid-run, the send to a dead
/// pool failed, or the job was dropped *unserved inside the queue*
/// (all workers gone, or enqueued behind `Stop` at shutdown). Without
/// that last case a blocked submitter would wait forever on bytes no
/// one can ever release.
struct Reservation {
    admission: Arc<Admission>,
    bytes: usize,
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.admission.release(self.bytes);
    }
}

/// One accepted frame riding the dispatcher queue.
struct FrameJob {
    req: FrameRequest,
    runner: Arc<NetRunner>,
    /// Admission hold for this frame; dropping the job releases it.
    reservation: Reservation,
    out: SyncSender<FrameResult>,
}

enum Job {
    Frame(Box<FrameJob>),
    Stop,
    /// Test/chaos hook: panic the receiving worker (see
    /// [`Coordinator::inject_worker_panic`]).
    #[doc(hidden)]
    Poison,
}

/// What one dequeue hands a worker.
enum Dequeued {
    /// Up to `pipeline_depth` *consecutive same-net* frames, popped as
    /// one window. FIFO order is preserved: the window is a contiguous
    /// prefix of the queue, never a reordering.
    Window(Vec<FrameJob>),
    Stop,
    Poison,
}

/// Bounded MPMC dispatcher replacing the old mpsc `sync_channel`: the
/// pipelined workers need to *peek and batch* — pop a contiguous
/// same-net run of frames in one dequeue — which an opaque channel
/// cannot express. Channel semantics are preserved: bounded `push`
/// blocks (backpressure), pops are FIFO, `Stop`/`Poison` reach exactly
/// one consumer each, and when the last consumer dies the queue closes
/// — pending jobs are dropped (delivering `Disconnected` to their
/// submitters and releasing their admission reservations) and blocked
/// pushers get their job handed back instead of waiting forever.
struct JobQueue {
    state: Mutex<JobQueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct JobQueueState {
    jobs: VecDeque<Job>,
    cap: usize,
    /// Live consumer (worker) threads; 0 = closed.
    consumers: usize,
    /// Consumers currently parked in `pop_window` waiting for work —
    /// while any sibling is idle, window formation stops at 1 frame so
    /// a burst spreads across the pool instead of piling onto one
    /// worker's pipeline.
    idle: usize,
}

impl JobQueue {
    fn new(cap: usize, consumers: usize) -> Self {
        Self {
            state: Mutex::new(JobQueueState {
                jobs: VecDeque::new(),
                cap: cap.max(1),
                consumers,
                idle: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking bounded push. `Err` hands the job back: every consumer
    /// is gone, so nothing could ever serve it.
    fn push(&self, job: Job) -> Result<(), Job> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.consumers == 0 {
                return Err(job);
            }
            if st.jobs.len() < st.cap {
                break;
            }
            st = self.not_full.wait(st).unwrap();
        }
        st.jobs.push_back(job);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop of the queue head; a `Frame` head extends into a
    /// window of consecutive same-net frames, up to `depth`, but only
    /// while (a) no sibling consumer sits idle (an idle sibling should
    /// take the next frame itself — batching it away halves the pool's
    /// parallelism on a burst) and (b) the net's DAG is actually
    /// pipelinable (more than one segment; otherwise the window would
    /// serialize frame-by-frame on this worker while claiming overlap).
    /// `Stop`/`Poison` never ride inside a window — they stay queued
    /// for the next dequeue.
    fn pop_window(&self, depth: usize) -> Dequeued {
        let mut st = self.state.lock().unwrap();
        let first = loop {
            if let Some(j) = st.jobs.pop_front() {
                break j;
            }
            st.idle += 1;
            st = self.not_empty.wait(st).unwrap();
            st.idle -= 1;
        };
        let out = match first {
            Job::Stop => Dequeued::Stop,
            Job::Poison => Dequeued::Poison,
            Job::Frame(f) => {
                let net = f.req.net.clone();
                let pipelinable = f.runner.compiled.segments.len() > 1;
                let mut window = vec![*f];
                while pipelinable
                    && st.idle == 0
                    && window.len() < depth
                    && matches!(st.jobs.front(), Some(Job::Frame(n)) if n.req.net == net)
                {
                    match st.jobs.pop_front() {
                        Some(Job::Frame(n)) => window.push(*n),
                        _ => unreachable!("front was checked to be a same-net frame"),
                    }
                }
                Dequeued::Window(window)
            }
        };
        drop(st);
        self.not_full.notify_all();
        out
    }
}

/// Registers a worker thread's death — panic or clean exit alike. The
/// last consumer out closes the queue: pending jobs are dropped (their
/// submitters see `Disconnected`, their reservations release) and
/// blocked pushers/admission waiters are woken instead of deadlocking.
struct ConsumerGuard {
    queue: Arc<JobQueue>,
}

impl Drop for ConsumerGuard {
    fn drop(&mut self) {
        // Avoid unwrap inside Drop: a poisoned mutex means a pusher
        // panicked mid-push, and its own unwind already propagates.
        if let Ok(mut st) = self.queue.state.lock() {
            st.consumers -= 1;
            if st.consumers == 0 {
                st.jobs.clear();
            }
        }
        self.queue.not_full.notify_all();
        self.queue.not_empty.notify_all();
    }
}

/// Handle to one in-flight frame: the id the coordinator assigned and
/// the channel its delivered [`FrameResult`] arrives on. A `recv` error
/// means the serving worker died before delivering — `run_stream` /
/// `run_mix` fold that into the metrics instead of dropping the frame.
#[derive(Debug)]
pub struct Pending {
    pub id: u64,
    pub net: String,
    rx: Receiver<FrameResult>,
}

impl Pending {
    pub fn recv(&self) -> Result<FrameResult, RecvError> {
        self.rx.recv()
    }

    pub fn try_recv(&self) -> Result<FrameResult, TryRecvError> {
        self.rx.try_recv()
    }
}

/// The serving front-end.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    /// Registry order; the first entry is the default net for untagged
    /// [`Coordinator::submit`].
    nets: Vec<(String, Arc<NetRunner>)>,
    by_name: HashMap<String, usize>,
    queue: Arc<JobQueue>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    stopped: AtomicBool,
    next_id: AtomicU64,
    admission: Arc<Admission>,
}

impl Coordinator {
    /// Compile a linear net once and start the worker pool.
    pub fn start(net: &NetSpec, cfg: CoordinatorConfig) -> anyhow::Result<Self> {
        Self::start_graph(&Graph::from_net(net), cfg)
    }

    /// Compile a graph (branch/residual topologies included) once and
    /// start the worker pool.
    pub fn start_graph(graph: &Graph, cfg: CoordinatorConfig) -> anyhow::Result<Self> {
        Self::start_registry(vec![(graph.name.clone(), graph.clone())], cfg)
    }

    /// Compile every named graph once and start one worker pool that
    /// serves them all: any worker runs any net, the pooled simulator
    /// instances are shared across runners, and the admission policy
    /// bounds the total in-flight DRAM-image bytes.
    pub fn start_registry(
        nets: Vec<(String, Graph)>,
        cfg: CoordinatorConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(!nets.is_empty(), "serving registry needs at least one net");
        let pool = Arc::new(AccelPool::default());
        let mut registry: Vec<(String, Arc<NetRunner>)> = Vec::with_capacity(nets.len());
        let mut by_name = HashMap::new();
        for (name, graph) in &nets {
            anyhow::ensure!(
                by_name.insert(name.clone(), registry.len()).is_none(),
                "duplicate net name '{name}' in registry"
            );
            let mut runner = NetRunner::from_graph_with_policy(graph, cfg.plan_policy)
                .map_err(|e| anyhow::anyhow!("compiling net '{name}': {e:#}"))?;
            runner.share_pool(Arc::clone(&pool));
            registry.push((name.clone(), Arc::new(runner)));
        }
        let admission = Arc::new(Admission {
            policy: cfg.admission,
            in_flight: Mutex::new(0),
            freed: Condvar::new(),
        });
        let nworkers = cfg.workers.max(1);
        let queue = Arc::new(JobQueue::new(cfg.queue_depth, nworkers));
        let mut handles = Vec::new();
        for w in 0..nworkers {
            let queue = Arc::clone(&queue);
            let op = cfg.op;
            let tile_workers = cfg.tile_workers.max(1);
            // Cross-frame overlap happens *among tile workers*; with one
            // tile thread a window would serialize whole frames on this
            // pool worker while its siblings idle — strictly worse than
            // depth 1. So pipelining engages only with tile_workers ≥ 2.
            let depth = if tile_workers > 1 { cfg.pipeline_depth.max(1) } else { 1 };
            handles.push(std::thread::spawn(move || {
                let _consumer = ConsumerGuard { queue: Arc::clone(&queue) };
                loop {
                    match queue.pop_window(depth) {
                        Dequeued::Stop => break,
                        Dequeued::Poison => panic!("injected worker panic (chaos hook)"),
                        Dequeued::Window(jobs) => serve_window(jobs, w, op, tile_workers),
                    }
                }
            }));
        }
        Ok(Self {
            cfg,
            nets: registry,
            by_name,
            queue,
            handles: Mutex::new(handles),
            stopped: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
            admission,
        })
    }

    /// Names of the registered nets, registry order.
    pub fn net_names(&self) -> Vec<String> {
        self.nets.iter().map(|(n, _)| n.clone()).collect()
    }

    /// DRAM-image footprint of one in-flight frame of `net`.
    pub fn dram_frame_bytes(&self, net: &str) -> Option<usize> {
        self.by_name.get(net).map(|&i| self.nets[i].1.dram_frame_bytes())
    }

    /// Synthesize a result the front-end delivers without dispatching
    /// (unknown net, admission rejection) — the frame is still
    /// *delivered and accounted*, never silently dropped.
    fn deliver_front_end_error(id: u64, net: &str, message: String) -> Pending {
        let (otx, orx) = sync_channel(1);
        let _ = otx.send(FrameResult {
            id,
            net: net.to_string(),
            worker: NO_WORKER,
            result: Err(FrameError { message }),
        });
        Pending { id, net: net.to_string(), rx: orx }
    }

    /// Submit one frame to the default (first-registered) net; blocks
    /// when the queue is full (backpressure).
    pub fn submit(&self, frame: Tensor) -> Result<Pending, SubmitError> {
        let net = self.nets[0].0.clone();
        self.submit_to(&net, frame)
    }

    /// Submit one frame to a named net. Unknown names and admission
    /// rejections come back as *delivered* [`FrameError`] results on
    /// the returned [`Pending`]; only a stopped coordinator or a dead
    /// worker pool is a [`SubmitError`].
    pub fn submit_to(&self, net: &str, frame: Tensor) -> Result<Pending, SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let Some(&idx) = self.by_name.get(net) else {
            let have = self.net_names().join(", ");
            return Ok(Self::deliver_front_end_error(
                id,
                net,
                format!("unknown net '{net}' (registered: {have})"),
            ));
        };
        let runner = Arc::clone(&self.nets[idx].1);
        let reserved = runner.dram_frame_bytes();
        if let Err(why) = self.admission.admit(reserved) {
            return Ok(Self::deliver_front_end_error(id, net, why));
        }
        let reservation = Reservation { admission: Arc::clone(&self.admission), bytes: reserved };
        let (otx, orx) = sync_channel(1);
        let job = Job::Frame(Box::new(FrameJob {
            req: FrameRequest::new(id, net, frame),
            runner,
            reservation,
            out: otx,
        }));
        if self.queue.push(job).is_err() {
            // Every worker is gone; the failed push hands the job back
            // and dropping it releases the reservation.
            return Err(SubmitError::Disconnected);
        }
        Ok(Pending { id, net: net.to_string(), rx: orx })
    }

    /// Convenience: push a batch of frames through the default net and
    /// gather metrics — failures included (`RunMetrics::errors`).
    pub fn run_stream(&self, frames: Vec<Tensor>) -> Result<RunMetrics, SubmitError> {
        let net = self.nets[0].0.clone();
        let tagged = frames.into_iter().map(|f| (net.clone(), f)).collect();
        Ok(self.run_mix(tagged)?.aggregate)
    }

    /// Push a mixed-traffic batch (`(net, frame)` pairs) through the
    /// registry and gather aggregate + per-net metrics. Every frame is
    /// accounted exactly once: served frames in `frames`, everything
    /// else — bad input, unknown net, admission rejection, a worker
    /// that died mid-frame, a submission the dead pool refused — in
    /// `errors`. Returns `Err` only when the coordinator was stopped
    /// before any frame entered.
    pub fn run_mix(&self, frames: Vec<(String, Tensor)>) -> Result<ServeReport, SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        let names = self.net_names();
        let mut report = ServeReport::new(self.cfg.op, &names);
        let t0 = Instant::now();
        let mut pending: VecDeque<Pending> = VecDeque::new();
        for (net, f) in frames {
            match self.submit_to(&net, f) {
                Ok(p) => pending.push_back(p),
                Err(e) => report.record_error_for(&net, &format!("submit failed: {e}")),
            }
            // Drain opportunistically to keep the pipe moving. `Empty`
            // just means the front frame is still in flight;
            // `Disconnected` means its worker died before delivering —
            // an accounted error, not a silent drop.
            while let Some(front) = pending.front() {
                match front.try_recv() {
                    Ok(r) => {
                        report.record_result(&r);
                        pending.pop_front();
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        let p = pending.pop_front().expect("front exists");
                        report.record_error_for(
                            &p.net,
                            &format!("worker died: frame {} undelivered", p.id),
                        );
                    }
                }
            }
        }
        for p in pending {
            match p.recv() {
                Ok(r) => report.record_result(&r),
                Err(RecvError) => report.record_error_for(
                    &p.net,
                    &format!("worker died: frame {} undelivered", p.id),
                ),
            }
        }
        report.set_wall(t0.elapsed().as_secs_f64());
        Ok(report)
    }

    /// Shut the worker pool down and join it. Idempotent; afterwards
    /// `submit` returns [`SubmitError::Stopped`] instead of panicking.
    pub fn stop(&self) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        let n = self.handles.lock().unwrap().len();
        for _ in 0..n {
            if self.queue.push(Job::Stop).is_err() {
                break; // workers already gone
            }
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }

    /// Chaos/test hook: panic one worker thread (it dies without
    /// delivering anything, like a real crashed process). Used to prove
    /// the lossy paths are gone: frames queued behind the poison come
    /// back as accounted "worker died" errors, never silent drops.
    #[doc(hidden)]
    pub fn inject_worker_panic(&self) -> Result<(), SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        self.queue.push(Job::Poison).map_err(|_| SubmitError::Disconnected)
    }
}

/// Serve one dequeued same-net window through the runner's cross-frame
/// pipelined scheduler. Every job is answered exactly once and its
/// admission reservation is released only after its result is sent (or
/// during unwind, if this worker panics mid-window): a malformed frame
/// gets its own delivered error up front and leaves the window, and a
/// window-level failure is delivered to every remaining frame — no
/// silent drops on any path.
fn serve_window(jobs: Vec<FrameJob>, worker: usize, op: OperatingPoint, tile_workers: usize) {
    let runner = Arc::clone(&jobs[0].runner);
    // queue wait = submit → this dequeue, measured per frame
    let mut window: Vec<(FrameJob, f64)> = Vec::with_capacity(jobs.len());
    for job in jobs {
        let queue_wait_s = job.req.submitted.elapsed().as_secs_f64();
        match runner.check_frame(&job.req.frame) {
            Ok(()) => window.push((job, queue_wait_s)),
            Err(e) => {
                let msg = format!("{e:#}");
                let _ = job.out.send(FrameResult {
                    id: job.req.id,
                    net: job.req.net.clone(),
                    worker,
                    result: Err(FrameError { message: msg }),
                });
                // `job` drops here → its reservation releases.
            }
        }
    }
    if window.is_empty() {
        return;
    }
    let depth = window.len();
    let outs = {
        // borrow the frames in place — no per-window image copies
        let frames: Vec<&Tensor> = window.iter().map(|(j, _)| &j.req.frame).collect();
        runner.run_frames_pipelined_ref(&frames, tile_workers, depth)
    };
    match outs {
        Ok(outs) => {
            for ((job, queue_wait_s), (output, stats)) in window.into_iter().zip(outs) {
                let result = Ok(FrameOutput {
                    output,
                    device_latency_s: stats.cycles as f64 * op.cycle_s(),
                    wall_latency_s: job.req.submitted.elapsed().as_secs_f64(),
                    queue_wait_s,
                    window: depth,
                    stats,
                });
                let _ = job.out.send(FrameResult {
                    id: job.req.id,
                    net: job.req.net.clone(),
                    worker,
                    result,
                });
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for (job, _) in window {
                let _ = job.out.send(FrameResult {
                    id: job.req.id,
                    net: job.req.net.clone(),
                    worker,
                    result: Err(FrameError { message: msg.clone() }),
                });
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::{run_graph_ref, run_net_ref};
    use crate::model::zoo;

    #[test]
    fn serves_frames_correctly_in_order_of_ids() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let frames: Vec<Tensor> =
            (0..6).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect();
        let rxs: Vec<_> = frames.iter().map(|f| coord.submit(f.clone()).unwrap()).collect();
        for (i, (rx, f)) in rxs.into_iter().zip(&frames).enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, i as u64);
            assert_eq!(r.net, "quicknet");
            let out = r.ok().unwrap();
            assert_eq!(out.output, run_net_ref(&net, f), "frame {i} wrong result");
            assert!(out.device_latency_s > 0.0);
            assert!(out.queue_wait_s >= 0.0);
        }
        coord.stop();
    }

    #[test]
    fn multi_worker_stream_has_all_frames() {
        let net = zoo::quicknet();
        let cfg = CoordinatorConfig { workers: 3, queue_depth: 2, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        let frames: Vec<Tensor> =
            (0..20).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect();
        let m = coord.run_stream(frames).unwrap();
        assert_eq!(m.frames, 20);
        assert_eq!(m.errors, 0);
        assert!(m.device_fps() > 0.0);
        assert_eq!(m.queue_wait_us.count(), 20, "queue wait recorded per served frame");
        coord.stop();
    }

    #[test]
    fn tile_parallel_serving_is_bit_exact() {
        let net = zoo::facenet();
        let cfg = CoordinatorConfig { tile_workers: 3, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        for s in 0..3 {
            let f = Tensor::random_image(s, net.in_h, net.in_w, net.in_c);
            let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_net_ref(&net, &f), "frame {s}");
        }
        coord.stop();
    }

    #[test]
    fn graph_net_serving_is_bit_exact() {
        let graph = zoo::edgenet();
        let cfg = CoordinatorConfig { tile_workers: 2, ..Default::default() };
        let coord = Coordinator::start_graph(&graph, cfg).unwrap();
        for s in 0..2 {
            let f = Tensor::random_image(s, graph.in_h, graph.in_w, graph.in_c);
            let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_graph_ref(&graph, &f), "frame {s}");
        }
        coord.stop();
    }

    /// Serving through the optimization planner must stay bit-exact
    /// with the oracle — the planner only changes decomposition, never
    /// results.
    #[test]
    fn optimized_plan_serving_is_bit_exact() {
        let graph = zoo::edgenet();
        let cfg = CoordinatorConfig {
            tile_workers: 2,
            plan_policy: PlanPolicy::DagAware,
            ..Default::default()
        };
        let coord = Coordinator::start_graph(&graph, cfg).unwrap();
        for s in 0..2 {
            let f = Tensor::random_image(s, graph.in_h, graph.in_w, graph.in_c);
            let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_graph_ref(&graph, &f), "frame {s}");
        }
        coord.stop();
    }

    /// A failing frame must be *delivered* as an error, not dropped:
    /// the submitter sees the message, and run_stream accounts it.
    #[test]
    fn failed_frames_are_delivered_and_accounted() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let bad = Tensor::zeros(3, 3, 1); // wrong shape for quicknet
        let r = coord.submit(bad.clone()).unwrap().recv().expect("result must arrive");
        assert!(r.result.is_err());
        let msg = r.ok().unwrap_err().to_string();
        assert!(msg.contains("frame") && msg.contains("shape"), "{msg}");

        let mut frames: Vec<Tensor> = (0..4)
            .map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c))
            .collect();
        frames.insert(2, bad);
        let m = coord.run_stream(frames).unwrap();
        assert_eq!(m.frames, 4, "good frames still served");
        assert_eq!(m.errors, 1, "bad frame accounted as an error");
        assert!(m.last_error.as_deref().unwrap_or("").contains("shape"));
        coord.stop();
    }

    /// The old `submit` panicked with `expect("coordinator stopped")`;
    /// now it is a typed, matchable error — and `stop` is idempotent.
    #[test]
    fn submit_after_stop_is_clean_error() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let f = Tensor::random_image(0, net.in_h, net.in_w, net.in_c);
        assert!(coord.submit(f.clone()).is_ok());
        coord.stop();
        coord.stop(); // idempotent
        assert_eq!(coord.submit(f.clone()).unwrap_err(), SubmitError::Stopped);
        assert_eq!(coord.run_stream(vec![f]).unwrap_err(), SubmitError::Stopped);
    }

    /// Unknown net names come back as delivered, accounted errors.
    #[test]
    fn unknown_net_is_delivered_error() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let f = Tensor::random_image(0, net.in_h, net.in_w, net.in_c);
        let r = coord.submit_to("nope", f).unwrap().recv().expect("delivered");
        assert_eq!(r.worker, NO_WORKER);
        let msg = r.result.unwrap_err().to_string();
        assert!(msg.contains("unknown net 'nope'") && msg.contains("quicknet"), "{msg}");
        coord.stop();
    }
}
