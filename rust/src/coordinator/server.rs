//! The streaming frame server: a multi-net serving registry in front
//! of a fleet of simulated accelerator **chips**, each an independent
//! fault domain.
//!
//! `Coordinator::start_registry` compiles each named graph once into
//! `name → Arc<NetRunner>`; the runners are shared read-only across
//! `CoordinatorConfig::chips` chips. Each chip owns a private
//! [`AccelPool`], a private bounded job queue with its own worker
//! threads, its own DVFS point, and a health state
//! ([`ChipHealth`]): one chip dying, stalling, or misbehaving never
//! corrupts another. Frames are routed **data-parallel,
//! least-loaded** across routable (healthy/degraded) chips, so a burst
//! on one workload soaks up whatever capacity the others leave idle —
//! the "many small chips behind one host" deployment the paper's
//! resource-limited targets imply.
//!
//! Robustness layer on top of the sharding:
//! - **Deterministic fault injection** ([`FaultPlan`]): seeded worker
//!   panics, whole-chip deaths, transient frame faults, and compute
//!   stalls fire at chosen chip-local frame indices, reproducibly.
//! - **Deadlines + bounded retry**: a frame whose chip dies, faults,
//!   or stalls past its per-attempt deadline is re-dispatched (with
//!   exponential backoff) to another chip up to `max_retries` times;
//!   every attempt is accounted (`retries`, `failovers`,
//!   `deadline_misses` in [`RunMetrics`]) and retry exhaustion is a
//!   *delivered* typed [`FrameError`], never a hang.
//! - **Graceful degradation**: repeated failures quarantine a chip
//!   (cooldown, then lazy re-admission); quarantined/dead chips shrink
//!   the effective admission budget pro rata, so Block-mode
//!   backpressures and Reject-mode sheds accountably instead of
//!   deadlocking on capacity that no longer exists.
//!
//! With `pipeline_depth > 1` a worker dequeues a contiguous same-net
//! *window* of frames and executes it through the cross-frame
//! pipelined scheduler (`NetRunner::run_frames_pipelined`). Windows
//! are opportunistic (never waited for), FIFO order is preserved, and
//! per-frame results/stats remain bit-identical to unpipelined
//! serving — on whichever chip they land.
//!
//! **Every frame is accounted.** A frame that fails produces a
//! *delivered* [`FrameResult`] with the error inside; a frame lost to
//! a dead worker is folded into [`RunMetrics`] as an error by
//! `run_stream` / `run_mix`; and submitting to a stopped coordinator
//! is a clean [`SubmitError`], not a panic. This invariant holds under
//! every seeded fault plan — the chaos battery in
//! `tests/integration_fault.rs` proves it.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvError, SyncSender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::fault::{ChipHealth, FaultEvent, FaultKind, FaultPlan};
use super::metrics::{RunMetrics, ServeReport};
use super::request::{
    Attempts, FrameError, FrameErrorKind, FrameOutput, FrameRequest, FrameResult, SubmitError,
    NO_CHIP, NO_WORKER,
};
use crate::compiler::{AccelPool, NetRunner};
use crate::energy::{EnergyModel, OperatingPoint};
use crate::model::{Graph, NetSpec, Tensor};
use crate::obs::{EventKind, Obs};
use crate::planner::{PlanObjective, PlanPolicy};
use crate::util::sync::lock_recover;

/// What to do when admitting a frame would exceed the DRAM budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmissionMode {
    /// Block the submitter until in-flight frames release enough bytes
    /// (backpressure — the default).
    Block,
    /// Deliver the frame immediately as a [`FrameError`] (load
    /// shedding); the rejection is accounted like any other error.
    Reject,
}

/// Bounds the total DRAM-image bytes of in-flight frames across all
/// registered nets: a frame is admitted only when its runner's
/// footprint ([`NetRunner::dram_frame_bytes`]) fits in the remaining
/// budget. Heterogeneous nets compete for the same budget, so a few
/// big-canvas frames can't starve the pool unnoticed. With multiple
/// chips the budget degrades gracefully: the *effective* budget is
/// `max_dram_bytes × routable_chips / total_chips`, so a dead or
/// quarantined chip sheds its share of admissions instead of letting
/// Block-mode submitters pile onto capacity that no longer exists.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionPolicy {
    /// Total in-flight DRAM-image budget in bytes (`usize::MAX` =
    /// unbounded, the default).
    pub max_dram_bytes: usize,
    pub mode: AdmissionMode,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        Self { max_dram_bytes: usize::MAX, mode: AdmissionMode::Block }
    }
}

#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Worker threads **per chip**.
    pub workers: usize,
    /// Independent chip-level fault domains. Each chip gets a private
    /// [`AccelPool`], queue, worker threads, DVFS point and health
    /// state; frames route least-loaded across routable chips.
    pub chips: usize,
    /// Bounded queue depth (frames) **per chip** — backpressure beyond
    /// this.
    pub queue_depth: usize,
    /// Host-side parallelism *inside* each frame: the compiled segment
    /// DAG executes over this many threads
    /// (`NetRunner::run_frame_parallel`). 1 = sequential. Results and
    /// stats are bit-identical either way; only wall latency changes.
    pub tile_workers: usize,
    /// Cross-frame pipelining: a worker dequeues up to this many
    /// consecutive same-net frames in one go and runs them as a
    /// rolling window (`NetRunner::run_frames_pipelined`), so frame
    /// N+1's early segments start on tile workers that would otherwise
    /// idle while frame N's tail drains. 1 (the default) = one frame
    /// per dequeue, the pre-pipelining behaviour. Batching is
    /// opportunistic — a worker never *waits* for a window to fill, so
    /// depth > 1 cannot deadlock a trickling source — and engages only
    /// when `tile_workers ≥ 2` (with one tile thread a window would
    /// just serialize frames on one pool worker). Note each in-flight
    /// frame still holds its own admission reservation: a Block-mode
    /// budget below `depth × dram_frame_bytes` simply caps the
    /// achievable window, it does not wedge.
    pub pipeline_depth: usize,
    /// DVFS point the devices run at (chips without a `chip_ops`
    /// override use this).
    pub op: OperatingPoint,
    /// Per-chip DVFS overrides, indexed by chip id; chips beyond the
    /// vector's length fall back to `op`. Heterogeneous points model a
    /// big.LITTLE-style fleet.
    pub chip_ops: Vec<OperatingPoint>,
    /// DRAM-image budget for in-flight frames.
    pub admission: AdmissionPolicy,
    /// Decomposition planner every registered net compiles with
    /// (`planner::PlanPolicy`): `Heuristic` is the historical solver,
    /// `MinTraffic`/`DagAware` run the optimization planner. Frame
    /// outputs are bit-identical under every policy; only DRAM traffic
    /// and tile-level parallelism change.
    pub plan_policy: PlanPolicy,
    /// What a searching `plan_policy` minimizes ([`PlanObjective`]):
    /// DRAM traffic (the default), exact latency, energy under an SLO,
    /// or EDP at an operating point. `Heuristic` ignores it.
    pub objective: PlanObjective,
    /// Per-*attempt* service deadline (measured from each dispatch to
    /// a chip). `None` = no deadline. A frame past-due at dequeue, or
    /// stalled past it by a slow chip, is re-routed and the miss
    /// accounted in `RunMetrics::deadline_misses`.
    pub deadline: Option<Duration>,
    /// Re-dispatches allowed per frame after a failed/expired attempt
    /// (chip death, transient fault, deadline miss). Attempt
    /// `1 + max_retries` failing delivers a typed
    /// [`FrameErrorKind::RetriesExhausted`].
    pub max_retries: u32,
    /// Base backoff before a re-dispatch; doubles per attempt (capped
    /// at ×64). Zero disables the sleep.
    pub retry_backoff: Duration,
    /// Consecutive failures on one chip before it is quarantined.
    pub quarantine_after: u32,
    /// How long a quarantined chip sits out before being lazily
    /// re-admitted to routing (as `Degraded`, healing on success).
    pub quarantine_cooldown: Duration,
    /// Deterministic fault injection schedule (empty = no faults).
    pub fault_plan: FaultPlan,
    /// Observability sinks ([`Obs`]): span tracing and/or the fleet
    /// event log. Defaults to [`Obs::none`] — disabled observability is
    /// a pair of `Option` checks per emission site and leaves outputs
    /// and stats bit-identical.
    pub obs: Arc<Obs>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            chips: 1,
            queue_depth: 4,
            tile_workers: 1,
            pipeline_depth: 1,
            op: crate::energy::dvfs::PEAK,
            chip_ops: Vec::new(),
            admission: AdmissionPolicy::default(),
            plan_policy: PlanPolicy::Heuristic,
            objective: PlanObjective::MinTraffic,
            deadline: None,
            max_retries: 2,
            retry_backoff: Duration::from_micros(200),
            quarantine_after: 3,
            quarantine_cooldown: Duration::from_millis(250),
            fault_plan: FaultPlan::none(),
            obs: Obs::none(),
        }
    }
}

// ---------------------------------------------------------------------
// Poison-tolerant locking.
//
// The old code had eleven `lock().unwrap()` sites: one injected worker
// panic could poison a shared mutex and cascade into secondary panics
// in every submitter that touched it afterwards. The two helpers below
// are the only ways this module takes a lock now:
//
// - `util::sync::lock_recover` for ledger/queue/health state whose
//   invariants are update-atomic (plain arithmetic and VecDeque ops
//   that cannot unwind mid-update): poison is survivable, so recover
//   the guard and keep serving. Mandatory on every path reachable from
//   `Drop` during unwind, where a second panic would abort the process.
// - `lock_or_accounted_err` for request paths that can hand the caller
//   a typed error instead: poison surfaces as a *delivered*
//   `FrameError`, accounted like any other failure.

fn lock_or_accounted_err<'a, T>(
    m: &'a Mutex<T>,
    what: &str,
) -> Result<MutexGuard<'a, T>, FrameError> {
    m.lock().map_err(|_| {
        FrameError::new(
            FrameErrorKind::Internal,
            format!("{what} lock poisoned by a worker panic; frame not accepted"),
        )
    })
}

/// In-flight DRAM-byte ledger behind the admission policy. Pure
/// accounting — the degradation-aware budget math lives in
/// [`Router::admit`], which owns the chip topology.
struct Admission {
    policy: AdmissionPolicy,
    in_flight: Mutex<usize>,
    freed: Condvar,
}

impl Admission {
    fn release(&self, bytes: usize) {
        let mut used = lock_recover(&self.in_flight);
        *used = used.saturating_sub(bytes);
        drop(used);
        self.freed.notify_all();
    }
}

/// An owned admission reservation, released exactly once — on drop.
/// It rides inside the [`Job`], so the bytes come back whether the
/// frame was served, its worker panicked mid-run, the send to a dead
/// pool failed, the job failed over between chips, or the job was
/// dropped *unserved inside the queue* (all workers gone, or enqueued
/// behind `Stop` at shutdown). Without that last case a blocked
/// submitter would wait forever on bytes no one can ever release.
struct Reservation {
    admission: Arc<Admission>,
    bytes: usize,
}

impl Drop for Reservation {
    fn drop(&mut self) {
        self.admission.release(self.bytes);
    }
}

/// One accepted frame riding a chip's dispatcher queue, with its
/// attempt ledger: `attempts` counts dispatches, `failovers` counts
/// re-dispatches that changed chips, `deadline_misses` counts attempts
/// abandoned past-due. The ledger travels with the frame across
/// failovers and is delivered on the result envelope either way.
struct FrameJob {
    req: FrameRequest,
    runner: Arc<NetRunner>,
    /// Admission hold for this frame; dropping the job releases it.
    reservation: Reservation,
    out: SyncSender<FrameResult>,
    attempts: u32,
    failovers: u32,
    deadline_misses: u32,
    /// When the current attempt was dispatched — deadlines are
    /// per-attempt, so a failover onto a healthy chip gets a fresh
    /// budget.
    dispatched: Instant,
}

impl FrameJob {
    fn attempt_ledger(&self) -> Attempts {
        Attempts {
            attempts: self.attempts,
            failovers: self.failovers,
            deadline_misses: self.deadline_misses,
        }
    }

    fn past_deadline(&self) -> bool {
        self.req.deadline.is_some_and(|d| self.dispatched.elapsed() > d)
    }
}

enum Job {
    Frame(Box<FrameJob>),
    Stop,
    /// Test/chaos hook: panic whichever worker dequeues this (see
    /// [`Coordinator::inject_worker_panic`]; the targeted variant
    /// [`Coordinator::inject_worker_panic_at`] doesn't ride the queue).
    #[doc(hidden)]
    Poison,
}

/// What one dequeue hands a worker.
enum Dequeued {
    /// Up to `pipeline_depth` *consecutive same-net* frames, popped as
    /// one window. FIFO order is preserved: the window is a contiguous
    /// prefix of the queue, never a reordering.
    Window(Vec<FrameJob>),
    Stop,
    /// This worker was poisoned (queue-riding or targeted): panic.
    Poison,
    /// The chip was killed; the queue is closed and drained. Exit
    /// cleanly.
    Down,
}

/// Bounded MPMC dispatcher (one per chip): the pipelined workers need
/// to *peek and batch* — pop a contiguous same-net run of frames in
/// one dequeue — which an opaque channel cannot express. Channel
/// semantics are preserved: bounded `push` blocks (backpressure), pops
/// are FIFO, `Stop`/`Poison` reach exactly one consumer each. A closed
/// queue (chip killed, or last consumer dead) rejects pushes by
/// handing the job back, and parked consumers wake to `Down`.
struct JobQueue {
    state: Mutex<JobQueueState>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct JobQueueState {
    jobs: VecDeque<Job>,
    cap: usize,
    /// Live consumer (worker) threads.
    consumers: usize,
    /// Consumers currently parked in `pop_window` waiting for work —
    /// while any sibling is idle, window formation stops at 1 frame so
    /// a burst spreads across the pool instead of piling onto one
    /// worker's pipeline.
    idle: usize,
    /// Chip killed: no new pushes; pops report `Down` once drained.
    closed: bool,
    /// Targeted chaos: worker ids that must panic at their next
    /// dequeue ([`Coordinator::inject_worker_panic_at`]).
    poisoned: HashSet<usize>,
}

impl JobQueue {
    fn new(cap: usize, consumers: usize) -> Self {
        Self {
            state: Mutex::new(JobQueueState {
                jobs: VecDeque::new(),
                cap: cap.max(1),
                consumers,
                idle: 0,
                closed: false,
                poisoned: HashSet::new(),
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocking bounded push. `Err` hands the job back: the chip is
    /// closed or every consumer is gone, so nothing here could ever
    /// serve it — the router picks another chip or delivers an error.
    fn push(&self, job: Job) -> Result<(), Job> {
        let mut st = lock_recover(&self.state);
        loop {
            if st.closed || st.consumers == 0 {
                return Err(job);
            }
            if st.jobs.len() < st.cap {
                break;
            }
            st = self.not_full.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        st.jobs.push_back(job);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push that ignores the capacity bound — used only
    /// for failover re-dispatch, which runs on worker threads and must
    /// never block on a bounded queue (a worker waiting on a sibling's
    /// backpressure is a deadlock waiting to happen). The overshoot is
    /// bounded by the frames already admitted.
    fn push_unbounded(&self, job: Job) -> Result<(), Job> {
        let mut st = lock_recover(&self.state);
        if st.closed || st.consumers == 0 {
            return Err(job);
        }
        st.jobs.push_back(job);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop of the queue head by worker `worker`; a `Frame`
    /// head extends into a window of consecutive same-net frames, up
    /// to `depth`, but only while (a) no sibling consumer sits idle
    /// (an idle sibling should take the next frame itself — batching
    /// it away halves the pool's parallelism on a burst) and (b) the
    /// net's DAG is actually pipelinable (more than one segment;
    /// otherwise the window would serialize frame-by-frame on this
    /// worker while claiming overlap). `Stop`/`Poison` never ride
    /// inside a window — they stay queued for the next dequeue. A
    /// pending targeted poison for this worker outranks everything.
    fn pop_window(&self, depth: usize, worker: usize) -> Dequeued {
        let mut st = lock_recover(&self.state);
        let first = loop {
            if st.poisoned.remove(&worker) {
                return Dequeued::Poison;
            }
            if let Some(j) = st.jobs.pop_front() {
                break j;
            }
            if st.closed {
                return Dequeued::Down;
            }
            st.idle += 1;
            st = self.not_empty.wait(st).unwrap_or_else(PoisonError::into_inner);
            st.idle -= 1;
        };
        let out = match first {
            Job::Stop => Dequeued::Stop,
            Job::Poison => Dequeued::Poison,
            Job::Frame(f) => {
                let net = f.req.net.clone();
                let pipelinable = f.runner.compiled.segments.len() > 1;
                let mut window = vec![*f];
                while pipelinable
                    && st.idle == 0
                    && window.len() < depth
                    && matches!(st.jobs.front(), Some(Job::Frame(n)) if n.req.net == net)
                {
                    match st.jobs.pop_front() {
                        Some(Job::Frame(n)) => window.push(*n),
                        _ => unreachable!("front was checked to be a same-net frame"),
                    }
                }
                Dequeued::Window(window)
            }
        };
        drop(st);
        self.not_full.notify_all();
        out
    }

    /// Kill switch: refuse all future pushes and hand back whatever
    /// was queued so the router can fail it over. Idempotent — a
    /// second close returns nothing.
    fn close_and_drain(&self) -> Vec<Job> {
        let mut st = lock_recover(&self.state);
        st.closed = true;
        let drained: Vec<Job> = st.jobs.drain(..).collect();
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
        drained
    }

    fn is_closed(&self) -> bool {
        lock_recover(&self.state).closed
    }

    /// Can this queue accept work right now (open + has consumers)?
    fn accepting(&self) -> bool {
        let st = lock_recover(&self.state);
        !st.closed && st.consumers > 0
    }

    /// Mark `worker` for a panic at its next dequeue. `false` if the
    /// chip is already closed/dead.
    fn poison_worker(&self, worker: usize) -> bool {
        let mut st = lock_recover(&self.state);
        if st.closed || st.consumers == 0 {
            return false;
        }
        st.poisoned.insert(worker);
        drop(st);
        self.not_empty.notify_all();
        true
    }

    /// A consumer left (panic or clean exit). Returns how many remain.
    fn consumer_exit(&self) -> usize {
        let remaining = {
            let mut st = lock_recover(&self.state);
            st.consumers = st.consumers.saturating_sub(1);
            st.consumers
        };
        self.not_full.notify_all();
        self.not_empty.notify_all();
        remaining
    }
}

/// Mutable health bookkeeping of one chip.
struct ChipState {
    health: ChipHealth,
    /// Consecutive failures since the last success.
    consec_failures: u32,
    /// When a quarantined chip may rejoin routing (lazily applied).
    quarantine_until: Option<Instant>,
}

/// One simulated accelerator chip: an independent fault domain with a
/// private [`AccelPool`], its own bounded queue + workers, its own
/// DVFS point, health state, and fault ledger.
struct Chip {
    id: usize,
    op: OperatingPoint,
    pool: Arc<AccelPool>,
    queue: JobQueue,
    state: Mutex<ChipState>,
    /// Frames currently dispatched to (queued on or executing on) this
    /// chip — the least-loaded routing key.
    load: AtomicUsize,
    /// Cumulative frames dequeued by this chip's workers — the
    /// chip-local index [`FaultEvent::frame`] keys on.
    dequeued: AtomicU64,
    /// Pending fault events for this chip, sorted by frame index.
    faults: Mutex<VecDeque<FaultEvent>>,
    /// Shared observability sinks (event log + trace); disabled sinks
    /// cost two `Option` checks per emission site.
    obs: Arc<Obs>,
}

impl Chip {
    fn health(&self) -> ChipHealth {
        lock_recover(&self.state).health
    }

    /// May this chip take new frames right now? Lazily re-admits a
    /// quarantined chip whose cooldown has expired (as `Degraded`; a
    /// success then heals it to `Healthy`).
    ///
    /// Health-transition events are emitted while the state lock is
    /// held (here and in the other transitions below), so event-log
    /// sequence numbers observe transitions in the order they happen.
    fn routable(&self, now: Instant) -> bool {
        let mut st = lock_recover(&self.state);
        match st.health {
            ChipHealth::Healthy | ChipHealth::Degraded => true,
            ChipHealth::Dead => false,
            ChipHealth::Quarantined => match st.quarantine_until {
                Some(until) if now >= until => {
                    st.health = ChipHealth::Degraded;
                    st.consec_failures = 0;
                    st.quarantine_until = None;
                    self.obs.event(EventKind::ChipReadmitted, Some(self.id), None, || {
                        format!("chip {} cooldown expired; re-admitted as degraded", self.id)
                    });
                    true
                }
                _ => false,
            },
        }
    }

    /// Returns `true` on the actual transition into `Dead` (so the
    /// caller emits exactly one `chip-dead` event even when kill paths
    /// race).
    fn mark_dead(&self) -> bool {
        let mut st = lock_recover(&self.state);
        let was_dead = st.health == ChipHealth::Dead;
        st.health = ChipHealth::Dead;
        st.quarantine_until = None;
        !was_dead
    }

    fn note_failure(&self, quarantine_after: u32, cooldown: Duration) {
        let mut st = lock_recover(&self.state);
        if st.health == ChipHealth::Dead {
            return;
        }
        let old = st.health;
        st.consec_failures += 1;
        if st.consec_failures >= quarantine_after {
            st.health = ChipHealth::Quarantined;
            st.quarantine_until = Some(Instant::now() + cooldown);
            if old != ChipHealth::Quarantined {
                let n = st.consec_failures;
                self.obs.event(EventKind::ChipQuarantined, Some(self.id), None, || {
                    format!("chip {} quarantined after {n} consecutive failure(s)", self.id)
                });
            }
        } else {
            st.health = ChipHealth::Degraded;
            if old != ChipHealth::Degraded {
                self.obs.event(EventKind::ChipDegraded, Some(self.id), None, || {
                    format!("chip {} degraded by a failure", self.id)
                });
            }
        }
    }

    fn note_success(&self) {
        let mut st = lock_recover(&self.state);
        if st.health == ChipHealth::Dead {
            return;
        }
        let healed = st.health != ChipHealth::Healthy;
        st.health = ChipHealth::Healthy;
        st.consec_failures = 0;
        st.quarantine_until = None;
        if healed {
            self.obs.event(EventKind::ChipHealed, Some(self.id), None, || {
                format!("chip {} healed by a successful window", self.id)
            });
        }
    }

    /// Consume the fault scheduled for chip-local dequeue index `n`,
    /// if any.
    fn take_fault(&self, n: u64) -> Option<FaultKind> {
        let mut evs = lock_recover(&self.faults);
        let idx = evs.iter().position(|e| e.frame == n)?;
        evs.remove(idx).map(|e| e.kind)
    }

    fn faults_pending(&self) -> bool {
        !lock_recover(&self.faults).is_empty()
    }
}

/// Why `Router::admit` turned a frame away.
enum AdmitFail {
    /// Delivered to the submitter as an accounted [`FrameError`].
    Rejected(FrameError),
    /// Every chip is dead — the submission itself fails
    /// ([`SubmitError::Disconnected`]), like the old dead-pool path.
    NoChips,
}

/// The data-parallel frame router: owns the chip fleet, the admission
/// ledger, and the retry/failover policy. Everything here must be
/// callable from unwinding worker threads without panicking.
struct Router {
    chips: Vec<Arc<Chip>>,
    admission: Arc<Admission>,
    max_retries: u32,
    backoff: Duration,
    quarantine_after: u32,
    quarantine_cooldown: Duration,
    /// Set by `stop()` before `Stop` jobs go out, so consumer guards
    /// don't mistake an orderly shutdown for an organic chip death.
    stopping: AtomicBool,
    /// Shared observability sinks (same handle the chips carry).
    obs: Arc<Obs>,
}

impl Router {
    /// (routable, alive) chip counts. `routable` lazily re-admits
    /// expired quarantines; `alive` is everything not `Dead`.
    fn counts(&self) -> (usize, usize) {
        let now = Instant::now();
        let mut routable = 0;
        let mut alive = 0;
        for c in &self.chips {
            if c.routable(now) {
                routable += 1;
            }
            if !c.health().is_dead() {
                alive += 1;
            }
        }
        (routable, alive)
    }

    /// The admission budget scaled to the serving fraction of the
    /// fleet: `max × n / total` (u128 math — no overflow for byte
    /// budgets near `usize::MAX`). Unbounded stays unbounded.
    fn effective_budget(&self, n: usize) -> usize {
        let max = self.admission.policy.max_dram_bytes;
        if max == usize::MAX {
            return usize::MAX;
        }
        ((max as u128 * n as u128) / self.chips.len().max(1) as u128) as usize
    }

    /// Reserve `bytes` for one frame against the *effective* (health-
    /// scaled) budget, or explain why it can't run. Block mode waits
    /// on a timeout loop so it observes both byte releases and lazy
    /// quarantine expiry; a frame that could never fit even with every
    /// alive chip serving is rejected instead of wedging.
    fn admit(&self, bytes: usize) -> Result<(), AdmitFail> {
        let policy = self.admission.policy;
        if bytes > policy.max_dram_bytes {
            let err = FrameError::new(
                FrameErrorKind::Admission,
                format!(
                    "admission: frame needs {bytes} B of DRAM image, budget is {} B",
                    policy.max_dram_bytes
                ),
            );
            self.obs.event(EventKind::AdmissionReject, None, None, || err.message.clone());
            return Err(AdmitFail::Rejected(err));
        }
        let mut used = lock_or_accounted_err(&self.admission.in_flight, "admission ledger")
            .map_err(AdmitFail::Rejected)?;
        loop {
            let (routable, alive) = self.counts();
            if alive == 0 {
                return Err(AdmitFail::NoChips);
            }
            let eff = self.effective_budget(routable);
            if bytes <= eff.saturating_sub(*used) {
                break;
            }
            match policy.mode {
                AdmissionMode::Reject => {
                    let err = FrameError::new(
                        FrameErrorKind::Admission,
                        format!(
                            "admission: rejected — {bytes} B needed, {} B of {eff} B effective \
                             budget in flight ({routable}/{} chips serving)",
                            *used,
                            self.chips.len()
                        ),
                    );
                    self.obs.event(EventKind::AdmissionReject, None, None, || err.message.clone());
                    return Err(AdmitFail::Rejected(err));
                }
                AdmissionMode::Block => {
                    let ceiling = self.effective_budget(alive);
                    if bytes > ceiling {
                        let err = FrameError::new(
                            FrameErrorKind::Admission,
                            format!(
                                "admission: degraded fleet — frame needs {bytes} B but only \
                                 {alive}/{} chips are alive ({ceiling} B budget ceiling)",
                                self.chips.len()
                            ),
                        );
                        self.obs.event(EventKind::AdmissionReject, None, None, || {
                            err.message.clone()
                        });
                        return Err(AdmitFail::Rejected(err));
                    }
                    let (g, _) = self
                        .admission
                        .freed
                        .wait_timeout(used, Duration::from_millis(20))
                        .unwrap_or_else(PoisonError::into_inner);
                    used = g;
                }
            }
        }
        *used += bytes;
        Ok(())
    }

    /// Least-loaded routable chip, preferring `Healthy` over
    /// `Degraded` and skipping `exclude` (the chip that just failed
    /// the frame) unless it is the only one left.
    fn pick(&self, exclude: Option<usize>) -> Option<Arc<Chip>> {
        let now = Instant::now();
        let best = |skip: Option<usize>| {
            self.chips
                .iter()
                .filter(|c| Some(c.id) != skip && c.queue.accepting() && c.routable(now))
                .min_by_key(|c| {
                    let rank = if c.health() == ChipHealth::Healthy { 0 } else { 1 };
                    (rank, c.load.load(Ordering::SeqCst), c.id)
                })
                .cloned()
        };
        best(exclude).or_else(|| if exclude.is_some() { best(None) } else { None })
    }

    /// Like [`Router::pick`], but rides out *transient* unroutability
    /// (every chip quarantined): sleeps until a cooldown expires, a
    /// chip heals, or the fleet is actually dead/stopping. Returns
    /// `None` only when no chip can ever take the frame.
    fn pick_waiting(&self, exclude: Option<usize>) -> Option<Arc<Chip>> {
        loop {
            if let Some(c) = self.pick(exclude) {
                return Some(c);
            }
            if self.stopping.load(Ordering::SeqCst) {
                return None;
            }
            let (_, alive) = self.counts();
            if alive == 0 {
                return None;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Initial dispatch of an admitted frame (bounded, blocking push —
    /// submitter-side backpressure). `Err` hands the job back: no live
    /// chip could take it.
    fn dispatch(&self, mut job: FrameJob) -> Result<(), FrameJob> {
        loop {
            let Some(chip) = self.pick_waiting(None) else {
                return Err(job);
            };
            job.attempts += 1;
            job.dispatched = Instant::now();
            chip.load.fetch_add(1, Ordering::SeqCst);
            match chip.queue.push(Job::Frame(Box::new(job))) {
                Ok(()) => return Ok(()),
                Err(j) => {
                    // the chip died between pick and push — undo and
                    // re-route
                    chip.load.fetch_sub(1, Ordering::SeqCst);
                    match j {
                        Job::Frame(f) => {
                            job = *f;
                            job.attempts -= 1;
                        }
                        _ => unreachable!("pushed a Frame"),
                    }
                }
            }
        }
    }

    /// Failover path: re-dispatch a failed attempt to another chip
    /// (exponential backoff, unbounded push — never blocks a worker),
    /// or deliver a typed error once the retry budget is spent or no
    /// live chip remains. Call with the job already off the failing
    /// chip's load books. Never panics, never drops the frame.
    fn redispatch(&self, mut job: FrameJob, from: usize, why: &str) {
        if job.attempts > self.max_retries {
            let err = FrameError::new(
                FrameErrorKind::RetriesExhausted,
                format!(
                    "{why}: frame {} failed after {} attempt(s) ({} failover(s), {} deadline \
                     miss(es))",
                    job.req.id, job.attempts, job.failovers, job.deadline_misses
                ),
            );
            self.obs.event(EventKind::RetriesExhausted, Some(from), Some(job.req.id), || {
                err.message.clone()
            });
            Self::deliver_error(job, from, err);
            return;
        }
        if !self.backoff.is_zero() {
            let exp = job.attempts.saturating_sub(1).min(6);
            std::thread::sleep(self.backoff * 2u32.pow(exp));
        }
        loop {
            let Some(chip) = self.pick_waiting(Some(from)) else {
                let err = FrameError::new(
                    FrameErrorKind::ChipsUnavailable,
                    format!(
                        "{why}; worker died and no live chip remains to fail over frame {} \
                         (after {} attempt(s))",
                        job.req.id, job.attempts
                    ),
                );
                self.obs.event(EventKind::ChipsUnavailable, Some(from), Some(job.req.id), || {
                    err.message.clone()
                });
                Self::deliver_error(job, from, err);
                return;
            };
            let moved = chip.id != from;
            job.attempts += 1;
            if moved {
                job.failovers += 1;
            }
            job.dispatched = Instant::now();
            let (frame_id, attempt) = (job.req.id, job.attempts);
            chip.load.fetch_add(1, Ordering::SeqCst);
            match chip.queue.push_unbounded(Job::Frame(Box::new(job))) {
                Ok(()) => {
                    self.obs.event(EventKind::Retry, Some(chip.id), Some(frame_id), || {
                        format!("{why}; attempt {attempt} re-dispatched to chip {}", chip.id)
                    });
                    if moved {
                        self.obs.event(EventKind::Failover, Some(chip.id), Some(frame_id), || {
                            format!("frame {frame_id} failed over chip {from} → {}", chip.id)
                        });
                    }
                    return;
                }
                Err(j) => {
                    chip.load.fetch_sub(1, Ordering::SeqCst);
                    match j {
                        Job::Frame(f) => {
                            job = *f;
                            job.attempts -= 1;
                            if moved {
                                job.failovers -= 1;
                            }
                        }
                        _ => unreachable!("pushed a Frame"),
                    }
                }
            }
        }
    }

    /// Deliver a terminal failure for a frame that died off-chip. The
    /// job drop releases its admission reservation.
    fn deliver_error(job: FrameJob, chip: usize, err: FrameError) {
        let attempts = job.attempt_ledger();
        let _ = job.out.send(FrameResult {
            id: job.req.id,
            net: job.req.net.clone(),
            worker: NO_WORKER,
            chip,
            attempts,
            result: Err(err),
        });
    }

    /// Kill one chip: mark it `Dead`, close its queue, and fail every
    /// queued frame over to the survivors (or deliver typed errors if
    /// none remain). Idempotent; safe to call from an unwinding worker.
    fn kill_chip(&self, id: usize, why: &str) {
        let chip = &self.chips[id];
        if chip.mark_dead() {
            self.obs.event(EventKind::ChipDead, Some(id), None, || format!("chip {id}: {why}"));
        }
        for j in chip.queue.close_and_drain() {
            if let Job::Frame(f) = j {
                chip.load.fetch_sub(1, Ordering::SeqCst);
                self.redispatch(*f, id, why);
            }
            // Stop/Poison drain with the queue: the workers they were
            // meant for are exiting anyway.
        }
        // budget shrank — Block-mode waiters must recheck their ceiling
        self.admission.freed.notify_all();
    }

    fn note_failure(&self, chip: &Chip) {
        chip.note_failure(self.quarantine_after, self.quarantine_cooldown);
        // routable count may have dropped — admission waiters recheck
        self.admission.freed.notify_all();
    }
}

/// Registers a worker thread's death — panic or clean exit alike. The
/// last consumer out of a chip that wasn't already killed or stopped
/// declares the chip organically dead: its queue is closed and every
/// pending frame fails over (delivered as a typed error if no chip
/// survives), its admission share is shed, and blocked pushers are
/// woken instead of deadlocking. Runs during unwind, so everything it
/// touches uses poison-tolerant locks.
struct ConsumerGuard {
    router: Arc<Router>,
    chip: Arc<Chip>,
}

impl Drop for ConsumerGuard {
    fn drop(&mut self) {
        let remaining = self.chip.queue.consumer_exit();
        if remaining == 0
            && !self.chip.queue.is_closed()
            && !self.router.stopping.load(Ordering::SeqCst)
        {
            let why = format!("chip {} worker died", self.chip.id);
            self.router.kill_chip(self.chip.id, &why);
        }
    }
}

/// Handle to one in-flight frame: the id the coordinator assigned and
/// the channel its delivered [`FrameResult`] arrives on. A `recv` error
/// means the serving worker died before delivering — `run_stream` /
/// `run_mix` fold that into the metrics instead of dropping the frame.
#[derive(Debug)]
pub struct Pending {
    pub id: u64,
    pub net: String,
    rx: Receiver<FrameResult>,
}

impl Pending {
    pub fn recv(&self) -> Result<FrameResult, RecvError> {
        self.rx.recv()
    }

    pub fn try_recv(&self) -> Result<FrameResult, TryRecvError> {
        self.rx.try_recv()
    }
}

/// DVFS frequencies (MHz) [`Coordinator::auto_pick_ops`] sweeps: the
/// paper's Table 2 corners (20, 500) plus evenly spaced points between.
pub const DVFS_LADDER_MHZ: [f64; 11] =
    [20.0, 50.0, 100.0, 150.0, 200.0, 250.0, 300.0, 350.0, 400.0, 450.0, 500.0];

/// One net's auto-picked operating point: the minimum-energy
/// [`DVFS_LADDER_MHZ`] point whose *measured* single-frame latency
/// meets the SLO (PEAK fallback when no ladder point can).
#[derive(Clone, Debug)]
pub struct AutoOp {
    pub net: String,
    /// Measured device cycles of the probe frame.
    pub cycles: u64,
    /// The chosen operating point.
    pub op: OperatingPoint,
    /// Probe-frame latency at `op`, milliseconds.
    pub latency_ms: f64,
    /// Probe-frame energy at `op`, joules.
    pub energy_j: f64,
    /// The same frame's energy at PEAK — the baseline the pick beats.
    pub peak_energy_j: f64,
    /// Whether the SLO holds at `op` (`false` only on PEAK fallback,
    /// when even the fastest point misses the deadline).
    pub slo_met: bool,
}

/// Probe one net (one seeded frame on the simulator) and pick its
/// minimum-energy ladder point within the SLO.
fn auto_pick_for(name: &str, runner: &NetRunner, slo_ms: f64) -> anyhow::Result<AutoOp> {
    let em = EnergyModel::default();
    let (h, w, c) = runner.compiled.graph.in_shape();
    let frame = Tensor::random_image(0, h, w, c);
    let (_, stats) = runner
        .run_frame(&frame)
        .map_err(|e| anyhow::anyhow!("auto-pick probe frame for '{name}': {e:#}"))?;
    let peak_energy_j = em.energy(&stats, crate::energy::dvfs::PEAK).total_j();
    let mut best: Option<AutoOp> = None;
    for f in DVFS_LADDER_MHZ {
        let op = OperatingPoint::for_freq(f);
        let latency_ms = stats.cycles as f64 * op.cycle_s() * 1e3;
        if latency_ms > slo_ms {
            continue;
        }
        let energy_j = em.energy(&stats, op).total_j();
        let better = match &best {
            None => true,
            Some(b) => energy_j < b.energy_j,
        };
        if better {
            best = Some(AutoOp {
                net: name.to_string(),
                cycles: stats.cycles,
                op,
                latency_ms,
                energy_j,
                peak_energy_j,
                slo_met: true,
            });
        }
    }
    Ok(best.unwrap_or_else(|| {
        let op = crate::energy::dvfs::PEAK;
        AutoOp {
            net: name.to_string(),
            cycles: stats.cycles,
            op,
            latency_ms: stats.cycles as f64 * op.cycle_s() * 1e3,
            energy_j: peak_energy_j,
            peak_energy_j,
            slo_met: false,
        }
    }))
}

/// The fleet operating point: the *fastest* per-net pick, so every
/// net's SLO still holds on a chip that adopts it.
fn fleet_op(picks: &[AutoOp]) -> OperatingPoint {
    picks
        .iter()
        .map(|p| p.op)
        .reduce(|a, b| if b.freq_mhz > a.freq_mhz { b } else { a })
        .unwrap_or(crate::energy::dvfs::PEAK)
}

/// The serving front-end.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    /// Registry order; the first entry is the default net for untagged
    /// [`Coordinator::submit`].
    nets: Vec<(String, Arc<NetRunner>)>,
    by_name: HashMap<String, usize>,
    router: Arc<Router>,
    handles: Mutex<Vec<JoinHandle<()>>>,
    stopped: AtomicBool,
    next_id: AtomicU64,
}

impl Coordinator {
    /// Compile a linear net once and start the chip fleet.
    pub fn start(net: &NetSpec, cfg: CoordinatorConfig) -> anyhow::Result<Self> {
        Self::start_graph(&Graph::from_net(net), cfg)
    }

    /// Compile a graph (branch/residual topologies included) once and
    /// start the chip fleet.
    pub fn start_graph(graph: &Graph, cfg: CoordinatorConfig) -> anyhow::Result<Self> {
        Self::start_registry(vec![(graph.name.clone(), graph.clone())], cfg)
    }

    /// Compile every named graph once and start `cfg.chips`
    /// independent chips that all serve them: any worker on any chip
    /// runs any net (the compiled runners are shared read-only; the
    /// pooled simulator instances are per-chip), frames route
    /// least-loaded across healthy chips, and the admission policy
    /// bounds the total in-flight DRAM-image bytes fleet-wide.
    pub fn start_registry(
        nets: Vec<(String, Graph)>,
        cfg: CoordinatorConfig,
    ) -> anyhow::Result<Self> {
        let (registry, by_name) = Self::compile_registry(&nets, &cfg)?;
        Self::start_compiled(registry, by_name, cfg)
    }

    /// [`Coordinator::start_registry`], with the fleet operating point
    /// chosen by the DVFS auto-pick instead of `cfg.op`: each net is
    /// probed once on its compiled runner (before any chip exists),
    /// the per-net minimum-energy point within `slo_ms` is computed,
    /// and every chip starts at the fastest per-net pick — the lowest
    /// fleet frequency at which all registered nets meet the SLO.
    /// Returns the per-net pick table alongside the coordinator
    /// ([`Coordinator::op`] reports the fleet point in force).
    pub fn start_registry_auto_op(
        nets: Vec<(String, Graph)>,
        mut cfg: CoordinatorConfig,
        slo_ms: f64,
    ) -> anyhow::Result<(Self, Vec<AutoOp>)> {
        let (registry, by_name) = Self::compile_registry(&nets, &cfg)?;
        let mut picks: Vec<AutoOp> = Vec::with_capacity(registry.len());
        for (name, runner) in &registry {
            let pick = auto_pick_for(name, runner, slo_ms)?;
            cfg.obs.event(EventKind::AutoPick, None, None, || {
                format!(
                    "{}: {:.0} MHz, {:.3} ms, {:.4} J (slo {slo_ms} ms {})",
                    pick.net,
                    pick.op.freq_mhz,
                    pick.latency_ms,
                    pick.energy_j,
                    if pick.slo_met { "met" } else { "MISSED — PEAK fallback" }
                )
            });
            picks.push(pick);
        }
        cfg.op = fleet_op(&picks);
        Ok((Self::start_compiled(registry, by_name, cfg)?, picks))
    }

    /// Compile every named graph once into the shared registry.
    fn compile_registry(
        nets: &[(String, Graph)],
        cfg: &CoordinatorConfig,
    ) -> anyhow::Result<(Vec<(String, Arc<NetRunner>)>, HashMap<String, usize>)> {
        anyhow::ensure!(!nets.is_empty(), "serving registry needs at least one net");
        let mut registry: Vec<(String, Arc<NetRunner>)> = Vec::with_capacity(nets.len());
        let mut by_name = HashMap::new();
        for (name, graph) in nets {
            anyhow::ensure!(
                by_name.insert(name.clone(), registry.len()).is_none(),
                "duplicate net name '{name}' in registry"
            );
            let runner =
                NetRunner::from_graph_with_policy_objective(graph, cfg.plan_policy, cfg.objective)
                    .map_err(|e| anyhow::anyhow!("compiling net '{name}': {e:#}"))?;
            registry.push((name.clone(), Arc::new(runner)));
        }
        Ok((registry, by_name))
    }

    /// Start the chip fleet over an already-compiled registry.
    fn start_compiled(
        registry: Vec<(String, Arc<NetRunner>)>,
        by_name: HashMap<String, usize>,
        cfg: CoordinatorConfig,
    ) -> anyhow::Result<Self> {
        let admission = Arc::new(Admission {
            policy: cfg.admission,
            in_flight: Mutex::new(0),
            freed: Condvar::new(),
        });
        let nchips = cfg.chips.max(1);
        let nworkers = cfg.workers.max(1);
        let chips: Vec<Arc<Chip>> = (0..nchips)
            .map(|c| {
                Arc::new(Chip {
                    id: c,
                    op: cfg.chip_ops.get(c).copied().unwrap_or(cfg.op),
                    pool: Arc::new(AccelPool::default()),
                    queue: JobQueue::new(cfg.queue_depth, nworkers),
                    state: Mutex::new(ChipState {
                        health: ChipHealth::Healthy,
                        consec_failures: 0,
                        quarantine_until: None,
                    }),
                    load: AtomicUsize::new(0),
                    dequeued: AtomicU64::new(0),
                    faults: Mutex::new(cfg.fault_plan.events_for(c)),
                    obs: Arc::clone(&cfg.obs),
                })
            })
            .collect();
        let router = Arc::new(Router {
            chips,
            admission,
            max_retries: cfg.max_retries,
            backoff: cfg.retry_backoff,
            quarantine_after: cfg.quarantine_after.max(1),
            quarantine_cooldown: cfg.quarantine_cooldown,
            stopping: AtomicBool::new(false),
            obs: Arc::clone(&cfg.obs),
        });
        let tile_workers = cfg.tile_workers.max(1);
        // Cross-frame overlap happens *among tile workers*; with one
        // tile thread a window would serialize whole frames on this
        // pool worker while its siblings idle — strictly worse than
        // depth 1. So pipelining engages only with tile_workers ≥ 2.
        let depth = if tile_workers > 1 { cfg.pipeline_depth.max(1) } else { 1 };
        let mut handles = Vec::new();
        for c in 0..nchips {
            for w in 0..nworkers {
                let router = Arc::clone(&router);
                let chip = Arc::clone(&router.chips[c]);
                handles.push(std::thread::spawn(move || {
                    chip_worker(&router, &chip, w, tile_workers, depth);
                }));
            }
        }
        Ok(Self {
            cfg,
            nets: registry,
            by_name,
            router,
            handles: Mutex::new(handles),
            stopped: AtomicBool::new(false),
            next_id: AtomicU64::new(0),
        })
    }

    /// Names of the registered nets, registry order.
    pub fn net_names(&self) -> Vec<String> {
        self.nets.iter().map(|(n, _)| n.clone()).collect()
    }

    /// DRAM-image footprint of one in-flight frame of `net`.
    pub fn dram_frame_bytes(&self, net: &str) -> Option<usize> {
        self.by_name.get(net).map(|&i| self.nets[i].1.dram_frame_bytes())
    }

    /// Current health of every chip, indexed by chip id.
    pub fn chip_health(&self) -> Vec<ChipHealth> {
        self.router.chips.iter().map(|c| c.health()).collect()
    }

    /// Frames currently dispatched to (queued on or executing on) each
    /// chip — the queue-depth gauge `obs::prom::render` exposes.
    pub fn chip_loads(&self) -> Vec<usize> {
        self.router.chips.iter().map(|c| c.load.load(Ordering::SeqCst)).collect()
    }

    /// The admission budget currently in force, scaled by the fleet's
    /// routable fraction (see [`AdmissionPolicy`]).
    pub fn effective_admission_budget(&self) -> usize {
        let (routable, _) = self.router.counts();
        self.router.effective_budget(routable)
    }

    /// Test hook: bytes currently held by in-flight admissions. Zero
    /// once every submitted frame has been delivered — the lossless-
    /// accounting battery asserts this after every chaos run.
    #[doc(hidden)]
    pub fn in_flight_bytes(&self) -> usize {
        *lock_recover(&self.router.admission.in_flight)
    }

    /// Synthesize a result the front-end delivers without dispatching
    /// (unknown net, admission rejection) — the frame is still
    /// *delivered and accounted*, never silently dropped.
    fn deliver_front_end_error(id: u64, net: &str, err: FrameError) -> Pending {
        let (otx, orx) = sync_channel(1);
        let _ = otx.send(FrameResult {
            id,
            net: net.to_string(),
            worker: NO_WORKER,
            chip: NO_CHIP,
            attempts: Attempts::default(),
            result: Err(err),
        });
        Pending { id, net: net.to_string(), rx: orx }
    }

    /// Submit one frame to the default (first-registered) net; blocks
    /// when the target chip's queue is full (backpressure).
    pub fn submit(&self, frame: Tensor) -> Result<Pending, SubmitError> {
        let net = self.nets[0].0.clone();
        self.submit_to(&net, frame)
    }

    /// Submit one frame to a named net. Unknown names and admission
    /// rejections come back as *delivered* [`FrameError`] results on
    /// the returned [`Pending`]; only a stopped coordinator or a fully
    /// dead fleet is a [`SubmitError`].
    pub fn submit_to(&self, net: &str, frame: Tensor) -> Result<Pending, SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let Some(&idx) = self.by_name.get(net) else {
            let have = self.net_names().join(", ");
            return Ok(Self::deliver_front_end_error(
                id,
                net,
                FrameError::new(
                    FrameErrorKind::UnknownNet,
                    format!("unknown net '{net}' (registered: {have})"),
                ),
            ));
        };
        let runner = Arc::clone(&self.nets[idx].1);
        let reserved = runner.dram_frame_bytes();
        match self.router.admit(reserved) {
            Ok(()) => {}
            Err(AdmitFail::Rejected(err)) => {
                return Ok(Self::deliver_front_end_error(id, net, err));
            }
            Err(AdmitFail::NoChips) => return Err(SubmitError::Disconnected),
        }
        let reservation =
            Reservation { admission: Arc::clone(&self.router.admission), bytes: reserved };
        let (otx, orx) = sync_channel(1);
        let job = FrameJob {
            req: FrameRequest::new(id, net, frame).with_deadline(self.cfg.deadline),
            runner,
            reservation,
            out: otx,
            attempts: 0,
            failovers: 0,
            deadline_misses: 0,
            dispatched: Instant::now(),
        };
        if self.router.dispatch(job).is_err() {
            // No live chip could take it; the failed dispatch hands the
            // job back and dropping it releases the reservation.
            return Err(SubmitError::Disconnected);
        }
        Ok(Pending { id, net: net.to_string(), rx: orx })
    }

    /// Convenience: push a batch of frames through the default net and
    /// gather metrics — failures included (`RunMetrics::errors`).
    pub fn run_stream(&self, frames: Vec<Tensor>) -> Result<RunMetrics, SubmitError> {
        let net = self.nets[0].0.clone();
        let tagged = frames.into_iter().map(|f| (net.clone(), f)).collect();
        Ok(self.run_mix(tagged)?.aggregate)
    }

    /// Push a mixed-traffic batch (`(net, frame)` pairs) through the
    /// registry and gather aggregate + per-net + per-chip metrics.
    /// Every frame is accounted exactly once: served frames in
    /// `frames`, everything else — bad input, unknown net, admission
    /// rejection, retry exhaustion, a worker that died mid-frame, a
    /// submission the dead fleet refused — in `errors`. Returns `Err`
    /// only when the coordinator was stopped before any frame entered.
    pub fn run_mix(&self, frames: Vec<(String, Tensor)>) -> Result<ServeReport, SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        let names = self.net_names();
        let chip_ops: Vec<OperatingPoint> = self.router.chips.iter().map(|c| c.op).collect();
        let mut report = ServeReport::with_chips(self.cfg.op, &names, &chip_ops);
        let t0 = Instant::now();
        let mut pending: VecDeque<Pending> = VecDeque::new();
        for (net, f) in frames {
            match self.submit_to(&net, f) {
                Ok(p) => pending.push_back(p),
                Err(e) => report.record_error_for(&net, &format!("submit failed: {e}")),
            }
            // Drain opportunistically to keep the pipe moving. `Empty`
            // just means the front frame is still in flight;
            // `Disconnected` means its worker died before delivering —
            // an accounted error, not a silent drop.
            while let Some(front) = pending.front() {
                match front.try_recv() {
                    Ok(r) => {
                        report.record_result(&r);
                        pending.pop_front();
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        let p = pending.pop_front().expect("front exists");
                        report.record_error_for(
                            &p.net,
                            &format!("worker died: frame {} undelivered", p.id),
                        );
                    }
                }
            }
        }
        for p in pending {
            match p.recv() {
                Ok(r) => report.record_result(&r),
                Err(RecvError) => report.record_error_for(
                    &p.net,
                    &format!("worker died: frame {} undelivered", p.id),
                ),
            }
        }
        report.set_wall(t0.elapsed().as_secs_f64());
        report.chip_health = self.chip_health();
        Ok(report)
    }

    /// Shut the whole fleet down and join it. Idempotent; afterwards
    /// `submit` returns [`SubmitError::Stopped`] instead of panicking.
    pub fn stop(&self) {
        if self.stopped.swap(true, Ordering::SeqCst) {
            return;
        }
        self.router.stopping.store(true, Ordering::SeqCst);
        let per_chip = self.cfg.workers.max(1);
        for chip in &self.router.chips {
            for _ in 0..per_chip {
                if chip.queue.push(Job::Stop).is_err() {
                    break; // chip already closed/dead
                }
            }
        }
        for h in lock_recover(&self.handles).drain(..) {
            let _ = h.join();
        }
    }

    /// Serve-side DVFS auto-pick (the paper's Table 2 trade, closed
    /// into a control loop): run one probe frame per registered net on
    /// the simulator, then choose per net the minimum-energy
    /// [`DVFS_LADDER_MHZ`] point whose *measured* latency meets
    /// `slo_ms` milliseconds (PEAK fallback when none does, flagged
    /// `slo_met: false`). Returns the per-net table plus the fleet
    /// operating point — the fastest per-net pick, so every net's SLO
    /// still holds on every chip that adopts it. Deterministic: the
    /// probe frame is seeded and the simulator is cycle-exact.
    pub fn auto_pick_ops(&self, slo_ms: f64) -> anyhow::Result<(OperatingPoint, Vec<AutoOp>)> {
        let mut picks: Vec<AutoOp> = Vec::with_capacity(self.nets.len());
        for (name, runner) in &self.nets {
            picks.push(auto_pick_for(name, runner, slo_ms)?);
        }
        Ok((fleet_op(&picks), picks))
    }

    /// The fleet-default operating point ([`CoordinatorConfig::op`]);
    /// chips without a per-chip override run at this point.
    pub fn op(&self) -> OperatingPoint {
        self.cfg.op
    }

    /// Chaos/test hook (legacy, untargeted): panic whichever worker on
    /// chip 0 dequeues next. The poison rides the FIFO queue, so
    /// frames ahead of it still serve. Prefer
    /// [`Coordinator::inject_worker_panic_at`] for deterministic
    /// victims.
    #[doc(hidden)]
    pub fn inject_worker_panic(&self) -> Result<(), SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        self.router.chips[0].queue.push(Job::Poison).map_err(|_| SubmitError::Disconnected)
    }

    /// Chaos/test hook: panic a *specific* worker (`worker` on `chip`)
    /// at its next dequeue — deterministic victim selection, no racing
    /// on dequeue order. The worker panics before taking any frame, so
    /// nothing in-hand is lost.
    #[doc(hidden)]
    pub fn inject_worker_panic_at(&self, chip: usize, worker: usize) -> Result<(), SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        let c = self.router.chips.get(chip).ok_or(SubmitError::Disconnected)?;
        if worker >= self.cfg.workers.max(1) || !c.queue.poison_worker(worker) {
            return Err(SubmitError::Disconnected);
        }
        Ok(())
    }

    /// Chaos/test hook: kill one chip outright — health `Dead`, queue
    /// closed, queued frames failed over to the survivors. The fleet
    /// keeps serving on the remaining chips.
    #[doc(hidden)]
    pub fn kill_chip(&self, chip: usize) -> Result<(), SubmitError> {
        if self.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        if chip >= self.router.chips.len() {
            return Err(SubmitError::Disconnected);
        }
        self.router.kill_chip(chip, &format!("chip {chip} killed"));
        Ok(())
    }
}

/// What the worker loop should do after a window's triage.
enum Fate {
    Continue,
    /// Plan-driven chip death: the chip is already killed; exit clean.
    Exit,
    /// Plan-driven worker panic: the in-hand frame already failed
    /// over; now die loudly.
    Panic,
}

/// One chip worker: pop windows, triage each frame against the fault
/// plan and its deadline, serve what survives on this chip's private
/// pool. While the chip still has pending fault events the window
/// depth is forced to 1, so chip-local frame indices line up with the
/// plan deterministically; full windows resume once the plan is spent.
fn chip_worker(
    router: &Arc<Router>,
    chip: &Arc<Chip>,
    wid: usize,
    tile_workers: usize,
    depth: usize,
) {
    let _guard = ConsumerGuard { router: Arc::clone(router), chip: Arc::clone(chip) };
    loop {
        let d = if chip.faults_pending() { 1 } else { depth };
        match chip.queue.pop_window(d, wid) {
            Dequeued::Stop | Dequeued::Down => break,
            Dequeued::Poison => {
                panic!("injected worker panic (chaos hook, chip {} worker {wid})", chip.id)
            }
            Dequeued::Window(jobs) => match triage_and_serve(router, chip, wid, tile_workers, jobs)
            {
                Fate::Continue => {}
                Fate::Exit => break,
                Fate::Panic => {
                    panic!("fault plan: worker panic (chip {} worker {wid})", chip.id)
                }
            },
        }
    }
}

/// Apply the fault plan and deadline checks to a dequeued window, then
/// serve the surviving frames. Every job leaves exactly one way:
/// pushed to `run` and served, re-dispatched to another chip, or
/// delivered as a typed error — never dropped.
fn triage_and_serve(
    router: &Arc<Router>,
    chip: &Arc<Chip>,
    wid: usize,
    tile_workers: usize,
    jobs: Vec<FrameJob>,
) -> Fate {
    let mut fate = Fate::Continue;
    let mut run: Vec<FrameJob> = Vec::with_capacity(jobs.len());
    let mut queue = VecDeque::from(jobs);
    while let Some(mut job) = queue.pop_front() {
        if !matches!(fate, Fate::Continue) {
            // The chip is going down mid-window. Depth-forcing makes
            // fault windows single-frame, so this is a safety net, not
            // a hot path: fail the remainder over rather than drop it.
            chip.load.fetch_sub(1, Ordering::SeqCst);
            router.redispatch(job, chip.id, "chip died mid-window");
            continue;
        }
        let n = chip.dequeued.fetch_add(1, Ordering::SeqCst);
        let fault = chip.take_fault(n);
        if let Some(kind) = &fault {
            let (cid, fid) = (chip.id, job.req.id);
            chip.obs.event(EventKind::FaultInjected, Some(cid), Some(fid), || {
                format!("{kind:?} at chip {cid} local frame {n} (frame {fid})")
            });
        }
        match fault {
            Some(FaultKind::TransientFail) => {
                router.note_failure(chip);
                chip.load.fetch_sub(1, Ordering::SeqCst);
                router.redispatch(job, chip.id, "transient chip fault");
            }
            Some(FaultKind::Stall { ms }) => {
                std::thread::sleep(Duration::from_millis(ms));
                router.note_failure(chip);
                if job.past_deadline() {
                    job.deadline_misses += 1;
                    chip.obs.event(EventKind::DeadlineMiss, Some(chip.id), Some(job.req.id), || {
                        format!("frame {} stalled {ms} ms past its deadline", job.req.id)
                    });
                    chip.load.fetch_sub(1, Ordering::SeqCst);
                    router.redispatch(job, chip.id, "compute stall blew the deadline");
                } else {
                    run.push(job);
                }
            }
            Some(FaultKind::WorkerPanic) => {
                router.note_failure(chip);
                chip.load.fetch_sub(1, Ordering::SeqCst);
                router.redispatch(job, chip.id, "worker panicked");
                fate = Fate::Panic;
            }
            Some(FaultKind::ChipDeath) => {
                chip.load.fetch_sub(1, Ordering::SeqCst);
                // Kill first so the redispatch below can't pick this
                // chip again; the drain inside fails over everything
                // still queued behind this frame.
                router.kill_chip(chip.id, "chip died");
                router.redispatch(job, chip.id, "chip died");
                fate = Fate::Exit;
            }
            None => {
                if job.past_deadline() {
                    // Sat in the queue past its budget — don't burn
                    // sim time on a frame that already missed; no
                    // health penalty (queueing, not a chip fault).
                    job.deadline_misses += 1;
                    chip.obs.event(EventKind::DeadlineMiss, Some(chip.id), Some(job.req.id), || {
                        format!("frame {} sat in the queue past its deadline", job.req.id)
                    });
                    chip.load.fetch_sub(1, Ordering::SeqCst);
                    router.redispatch(job, chip.id, "deadline exceeded before service");
                } else {
                    run.push(job);
                }
            }
        }
    }
    serve_window(router, chip, wid, tile_workers, run);
    fate
}

/// Serve one triaged same-net window through the runner's cross-frame
/// pipelined scheduler on this chip's private pool. Every job is
/// answered exactly once and its admission reservation is released
/// only after its result is sent (or during unwind, if this worker
/// panics mid-window): a malformed frame gets its own delivered error
/// up front and leaves the window, and a window-level failure is
/// delivered to every remaining frame — no silent drops on any path.
fn serve_window(
    router: &Arc<Router>,
    chip: &Arc<Chip>,
    worker: usize,
    tile_workers: usize,
    jobs: Vec<FrameJob>,
) {
    if jobs.is_empty() {
        return;
    }
    let runner = Arc::clone(&jobs[0].runner);
    // queue wait = submit → this dequeue, measured per frame
    let mut window: Vec<(FrameJob, f64)> = Vec::with_capacity(jobs.len());
    for job in jobs {
        let queue_wait_s = job.req.submitted.elapsed().as_secs_f64();
        match runner.check_frame(&job.req.frame) {
            Ok(()) => window.push((job, queue_wait_s)),
            Err(e) => {
                // Malformed input is the frame's fault, not the
                // chip's: no health penalty, no retry.
                chip.load.fetch_sub(1, Ordering::SeqCst);
                let err = FrameError::new(FrameErrorKind::BadFrame, format!("{e:#}"));
                let _ = job.out.send(FrameResult {
                    id: job.req.id,
                    net: job.req.net.clone(),
                    worker,
                    chip: chip.id,
                    attempts: job.attempt_ledger(),
                    result: Err(err),
                });
                // `job` drops here → its reservation releases.
            }
        }
    }
    if window.is_empty() {
        return;
    }
    let depth = window.len();
    let outs = {
        // borrow the frames in place — no per-window image copies
        let frames: Vec<&Tensor> = window.iter().map(|(j, _)| &j.req.frame).collect();
        match chip.obs.trace.as_deref() {
            None => runner.run_frames_pipelined_ref_on(&chip.pool, &frames, tile_workers, depth),
            Some(sink) => {
                // Traced serve: collect the scheduler's enter/exit
                // events on the sink's epoch, pair them into spans
                // keyed by the coordinator frame ids, and record the
                // window on this queue worker's track. The traced
                // scheduler is the same code path — outputs and stats
                // stay bit-identical.
                let ids: Vec<u64> = window.iter().map(|(j, _)| j.req.id).collect();
                let target = sink.target();
                let t0 = sink.now_ns();
                let r = runner.run_frames_pipelined_ref_traced_on(
                    &chip.pool,
                    &frames,
                    tile_workers,
                    depth,
                    &target,
                );
                let t1 = sink.now_ns();
                sink.ingest(&window[0].0.req.net, &runner.compiled, chip.id, &ids, &target.take());
                let cycles = r.as_ref().map_or(0, |o| o.iter().map(|(_, s)| s.cycles).sum());
                sink.window(&window[0].0.req.net, chip.id, worker, ids, t0, t1, cycles);
                r
            }
        }
    };
    match outs {
        Ok(outs) => {
            chip.note_success();
            for ((job, queue_wait_s), (output, stats)) in window.into_iter().zip(outs) {
                let result = Ok(FrameOutput {
                    output,
                    device_latency_s: stats.cycles as f64 * chip.op.cycle_s(),
                    wall_latency_s: job.req.submitted.elapsed().as_secs_f64(),
                    queue_wait_s,
                    window: depth,
                    stats,
                });
                chip.load.fetch_sub(1, Ordering::SeqCst);
                let _ = job.out.send(FrameResult {
                    id: job.req.id,
                    net: job.req.net.clone(),
                    worker,
                    chip: chip.id,
                    attempts: job.attempt_ledger(),
                    result,
                });
            }
        }
        Err(e) => {
            router.note_failure(chip);
            let msg = format!("{e:#}");
            for (job, _) in window {
                chip.load.fetch_sub(1, Ordering::SeqCst);
                let _ = job.out.send(FrameResult {
                    id: job.req.id,
                    net: job.req.net.clone(),
                    worker,
                    chip: chip.id,
                    attempts: job.attempt_ledger(),
                    result: Err(FrameError::new(FrameErrorKind::Internal, msg.clone())),
                });
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::{run_graph_ref, run_net_ref};
    use crate::model::zoo;

    #[test]
    fn serves_frames_correctly_in_order_of_ids() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let frames: Vec<Tensor> =
            (0..6).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect();
        let rxs: Vec<_> = frames.iter().map(|f| coord.submit(f.clone()).unwrap()).collect();
        for (i, (rx, f)) in rxs.into_iter().zip(&frames).enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.id, i as u64);
            assert_eq!(r.net, "quicknet");
            assert_eq!(r.chip, 0);
            assert_eq!(r.attempts.attempts, 1, "clean serve is a single attempt");
            let out = r.ok().unwrap();
            assert_eq!(out.output, run_net_ref(&net, f), "frame {i} wrong result");
            assert!(out.device_latency_s > 0.0);
            assert!(out.queue_wait_s >= 0.0);
        }
        coord.stop();
    }

    #[test]
    fn multi_worker_stream_has_all_frames() {
        let net = zoo::quicknet();
        let cfg = CoordinatorConfig { workers: 3, queue_depth: 2, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        let frames: Vec<Tensor> =
            (0..20).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect();
        let m = coord.run_stream(frames).unwrap();
        assert_eq!(m.frames, 20);
        assert_eq!(m.errors, 0);
        assert!(m.device_fps() > 0.0);
        assert_eq!(m.queue_wait_us.count(), 20, "queue wait recorded per served frame");
        coord.stop();
    }

    #[test]
    fn tile_parallel_serving_is_bit_exact() {
        let net = zoo::facenet();
        let cfg = CoordinatorConfig { tile_workers: 3, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        for s in 0..3 {
            let f = Tensor::random_image(s, net.in_h, net.in_w, net.in_c);
            let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_net_ref(&net, &f), "frame {s}");
        }
        coord.stop();
    }

    #[test]
    fn graph_net_serving_is_bit_exact() {
        let graph = zoo::edgenet();
        let cfg = CoordinatorConfig { tile_workers: 2, ..Default::default() };
        let coord = Coordinator::start_graph(&graph, cfg).unwrap();
        for s in 0..2 {
            let f = Tensor::random_image(s, graph.in_h, graph.in_w, graph.in_c);
            let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_graph_ref(&graph, &f), "frame {s}");
        }
        coord.stop();
    }

    /// Serving through the optimization planner must stay bit-exact
    /// with the oracle — the planner only changes decomposition, never
    /// results.
    #[test]
    fn optimized_plan_serving_is_bit_exact() {
        let graph = zoo::edgenet();
        let cfg = CoordinatorConfig {
            tile_workers: 2,
            plan_policy: PlanPolicy::DagAware,
            ..Default::default()
        };
        let coord = Coordinator::start_graph(&graph, cfg).unwrap();
        for s in 0..2 {
            let f = Tensor::random_image(s, graph.in_h, graph.in_w, graph.in_c);
            let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
            assert_eq!(out.output, run_graph_ref(&graph, &f), "frame {s}");
        }
        coord.stop();
    }

    /// Serving through a latency-objective plan must also stay
    /// bit-exact — the objective only changes decomposition choices.
    #[test]
    fn objective_plan_serving_is_bit_exact() {
        let graph = zoo::edgenet();
        let cfg = CoordinatorConfig {
            plan_policy: PlanPolicy::MinTraffic,
            objective: PlanObjective::MinLatency { op: crate::energy::dvfs::PEAK },
            ..Default::default()
        };
        let coord = Coordinator::start_graph(&graph, cfg).unwrap();
        let f = Tensor::random_image(0, graph.in_h, graph.in_w, graph.in_c);
        let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
        assert_eq!(out.output, run_graph_ref(&graph, &f));
        coord.stop();
    }

    /// The acceptance criterion for energy-aware serving: under a
    /// 50 ms SLO the auto-pick must land on a *lower-energy, slower*
    /// operating point than PEAK for quicknet — and the fleet point is
    /// the fastest per-net pick.
    #[test]
    fn auto_pick_finds_sub_peak_point_within_slo() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let (fleet, picks) = coord.auto_pick_ops(50.0).unwrap();
        assert_eq!(picks.len(), 1);
        let p = &picks[0];
        assert_eq!(p.net, "quicknet");
        assert!(p.slo_met, "quicknet must fit a 50 ms SLO at some ladder point");
        assert!(p.latency_ms <= 50.0, "picked latency {} ms", p.latency_ms);
        assert!(
            p.op.freq_mhz < crate::energy::dvfs::PEAK.freq_mhz,
            "auto-pick stayed at PEAK ({} MHz) — no energy won",
            p.op.freq_mhz
        );
        assert!(
            p.energy_j < p.peak_energy_j,
            "picked energy {} J must beat PEAK {} J",
            p.energy_j,
            p.peak_energy_j
        );
        assert_eq!(fleet.freq_mhz, p.op.freq_mhz, "one net: fleet point is its pick");

        // An impossible SLO falls back to PEAK, flagged.
        let (_, picks) = coord.auto_pick_ops(0.0).unwrap();
        assert!(!picks[0].slo_met);
        assert_eq!(picks[0].op, crate::energy::dvfs::PEAK);
        coord.stop();

        // The auto-op constructor applies the fleet pick to the chips
        // and serving stays bit-exact at the slower point.
        let graph = Graph::from_net(&net);
        let (coord, picks) = Coordinator::start_registry_auto_op(
            vec![("quicknet".into(), graph)],
            CoordinatorConfig::default(),
            50.0,
        )
        .unwrap();
        assert_eq!(coord.op().freq_mhz, picks[0].op.freq_mhz);
        assert!(coord.op().freq_mhz < crate::energy::dvfs::PEAK.freq_mhz);
        let f = Tensor::random_image(7, net.in_h, net.in_w, net.in_c);
        let out = coord.submit(f.clone()).unwrap().recv().unwrap().ok().unwrap();
        assert_eq!(out.output, run_net_ref(&net, &f));
        coord.stop();
    }

    /// A failing frame must be *delivered* as an error, not dropped:
    /// the submitter sees the message, and run_stream accounts it.
    #[test]
    fn failed_frames_are_delivered_and_accounted() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let bad = Tensor::zeros(3, 3, 1); // wrong shape for quicknet
        let r = coord.submit(bad.clone()).unwrap().recv().expect("result must arrive");
        assert!(r.result.is_err());
        let msg = r.ok().unwrap_err().to_string();
        assert!(msg.contains("frame") && msg.contains("shape"), "{msg}");

        let mut frames: Vec<Tensor> = (0..4)
            .map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c))
            .collect();
        frames.insert(2, bad);
        let m = coord.run_stream(frames).unwrap();
        assert_eq!(m.frames, 4, "good frames still served");
        assert_eq!(m.errors, 1, "bad frame accounted as an error");
        assert!(m.last_error.as_deref().unwrap_or("").contains("shape"));
        coord.stop();
    }

    /// The old `submit` panicked with `expect("coordinator stopped")`;
    /// now it is a typed, matchable error — and `stop` is idempotent.
    #[test]
    fn submit_after_stop_is_clean_error() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let f = Tensor::random_image(0, net.in_h, net.in_w, net.in_c);
        assert!(coord.submit(f.clone()).is_ok());
        coord.stop();
        coord.stop(); // idempotent
        assert_eq!(coord.submit(f.clone()).unwrap_err(), SubmitError::Stopped);
        assert_eq!(coord.run_stream(vec![f]).unwrap_err(), SubmitError::Stopped);
    }

    /// Unknown net names come back as delivered, accounted errors.
    #[test]
    fn unknown_net_is_delivered_error() {
        let net = zoo::quicknet();
        let coord = Coordinator::start(&net, CoordinatorConfig::default()).unwrap();
        let f = Tensor::random_image(0, net.in_h, net.in_w, net.in_c);
        let r = coord.submit_to("nope", f).unwrap().recv().expect("delivered");
        assert_eq!(r.worker, NO_WORKER);
        assert_eq!(r.chip, NO_CHIP);
        assert_eq!(r.result.unwrap_err().kind, FrameErrorKind::UnknownNet);
        coord.stop();
    }

    /// Sharded serving stays bit-exact: frames spread across chips
    /// (each with a private pool) and every result matches the oracle.
    #[test]
    fn chips_route_and_stay_bit_exact() {
        let net = zoo::quicknet();
        let cfg = CoordinatorConfig { chips: 3, queue_depth: 2, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        let frames: Vec<Tensor> =
            (0..12).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect();
        let rxs: Vec<_> = frames.iter().map(|f| coord.submit(f.clone()).unwrap()).collect();
        let mut seen = std::collections::HashSet::new();
        for (rx, f) in rxs.into_iter().zip(&frames) {
            let r = rx.recv().unwrap();
            assert!(r.chip < 3, "chip id on the envelope");
            seen.insert(r.chip);
            assert_eq!(r.ok().unwrap().output, run_net_ref(&net, f));
        }
        assert!(seen.len() > 1, "least-loaded routing must use more than one chip: {seen:?}");
        assert!(coord.chip_health().iter().all(|h| *h == ChipHealth::Healthy));
        assert_eq!(coord.in_flight_bytes(), 0);
        coord.stop();
    }

    /// Killing a chip mid-service: queued frames fail over, the fleet
    /// keeps serving, the dead chip stays dead, and the effective
    /// admission budget shrinks pro rata.
    #[test]
    fn kill_chip_fails_over_and_shrinks_budget() {
        let net = zoo::quicknet();
        let cfg = CoordinatorConfig {
            chips: 2,
            queue_depth: 4,
            admission: AdmissionPolicy { max_dram_bytes: 1_000_000, mode: AdmissionMode::Block },
            ..Default::default()
        };
        let coord = Coordinator::start(&net, cfg).unwrap();
        assert_eq!(coord.effective_admission_budget(), 1_000_000);
        let m = coord
            .run_stream(
                (0..4).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect(),
            )
            .unwrap();
        assert_eq!(m.frames, 4);
        coord.kill_chip(1).unwrap();
        assert_eq!(coord.chip_health()[1], ChipHealth::Dead);
        assert_eq!(coord.effective_admission_budget(), 500_000, "budget sheds the dead share");
        let m = coord
            .run_stream(
                (0..6).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect(),
            )
            .unwrap();
        assert_eq!(m.frames, 6, "survivor serves everything");
        assert_eq!(m.errors, 0);
        assert_eq!(coord.in_flight_bytes(), 0);
        coord.stop();
    }

    /// Targeted poison kills exactly the named worker; with one worker
    /// per chip that chip goes down and routing avoids it.
    #[test]
    fn targeted_poison_is_deterministic() {
        let net = zoo::quicknet();
        let cfg = CoordinatorConfig { chips: 2, workers: 1, ..Default::default() };
        let coord = Coordinator::start(&net, cfg).unwrap();
        coord.inject_worker_panic_at(1, 0).unwrap();
        // the poisoned worker dies at its next dequeue (it is parked,
        // so "next" is now); wait for the organic chip death to land
        let t0 = Instant::now();
        while coord.chip_health()[1] != ChipHealth::Dead {
            assert!(t0.elapsed() < Duration::from_secs(5), "chip 1 never died");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(coord.chip_health()[0], ChipHealth::Healthy);
        let m = coord
            .run_stream(
                (0..5).map(|s| Tensor::random_image(s, net.in_h, net.in_w, net.in_c)).collect(),
            )
            .unwrap();
        assert_eq!(m.frames, 5, "chip 0 serves everything");
        assert_eq!(m.errors, 0);
        // out-of-range targets are clean errors
        assert!(coord.inject_worker_panic_at(7, 0).is_err());
        assert!(coord.inject_worker_panic_at(0, 7).is_err());
        coord.stop();
    }
}
