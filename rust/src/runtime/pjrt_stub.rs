//! Stub `Golden` used when the crate is built without the `pjrt`
//! feature: the `xla` (PJRT) bindings are not vendorable in the offline
//! build environment — see Cargo.toml. The API surface matches
//! `pjrt.rs` so every caller compiles; `load_default` reports the
//! runtime as unavailable and golden tests / benches self-skip.

use crate::model::Tensor;

use super::artifacts::{Artifact, Manifest};

/// Placeholder for the PJRT golden-model registry.
pub struct Golden {
    manifest: Manifest,
}

impl Golden {
    /// Always fails: there is no PJRT client in this build.
    pub fn load_default() -> anyhow::Result<Self> {
        anyhow::bail!(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (see rust/Cargo.toml for how to enable the xla bindings)"
        )
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Always fails: artifacts cannot execute without a PJRT client.
    pub fn run(&mut self, name: &str, _input: &Tensor) -> anyhow::Result<Tensor> {
        anyhow::bail!("PJRT runtime unavailable: cannot execute artifact '{name}'")
    }

    /// Artifact kind="net" names present.
    pub fn net_artifacts(&self) -> Vec<&Artifact> {
        self.manifest.artifacts.iter().filter(|a| a.kind == "net").collect()
    }
}
