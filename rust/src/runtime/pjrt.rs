//! PJRT executor: HLO text → compiled executable → int16 tensor I/O.
//!
//! Interchange is HLO **text** (xla_extension 0.5.1 rejects jax ≥ 0.5
//! serialized protos — 64-bit instruction ids; the text parser reassigns
//! them). Artifacts are lowered with `return_tuple=True`, so results
//! unwrap with `to_tuple1()`.

use std::collections::HashMap;

use crate::model::Tensor;

use super::artifacts::{Artifact, Manifest};

/// A compiled golden-model registry over one PJRT CPU client.
pub struct Golden {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Golden {
    /// Create the CPU client and load the manifest (compiles lazily).
    pub fn load_default() -> anyhow::Result<Self> {
        let manifest = Manifest::load_default()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT: {e}"))?;
        Ok(Self { client, manifest, compiled: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn compile(&mut self, name: &str) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(name) {
            let art = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?;
            let path = art.file.to_string_lossy().to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parse {path}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {name}: {e}"))?;
            self.compiled.insert(name.to_string(), exe);
        }
        Ok(&self.compiled[name])
    }

    /// Execute artifact `name` on an HWC int16 tensor.
    pub fn run(&mut self, name: &str, input: &Tensor) -> anyhow::Result<Tensor> {
        let art = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        anyhow::ensure!(
            art.in_shape == vec![input.h, input.w, input.c],
            "{name}: input {:?} != artifact {:?}",
            input.shape(),
            art.in_shape
        );
        let exe = self.compile(&name.to_string())?;
        // i16 lacks a NativeType impl in the crate; build the literal
        // from raw bytes with an explicit S16 shape instead.
        let bytes: Vec<u8> = input.data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S16,
            &[input.h, input.w, input.c],
            &bytes,
        )
        .map_err(|e| anyhow::anyhow!("literal: {e}"))?;
        let result = exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow::anyhow!("execute {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch: {e}"))?;
        let out = result.to_tuple1().map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        let data = out.to_vec::<i16>().map_err(|e| anyhow::anyhow!("to_vec: {e}"))?;
        let (h, w, c) = (art.out_shape[0], art.out_shape[1], art.out_shape[2]);
        anyhow::ensure!(data.len() == h * w * c, "{name}: output size mismatch");
        Ok(Tensor::from_vec(h, w, c, data))
    }

    /// Artifact kind="net" names present.
    pub fn net_artifacts(&self) -> Vec<&Artifact> {
        self.manifest.artifacts.iter().filter(|a| a.kind == "net").collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    /// The PJRT-executed conv tile must equal the in-crate scalar oracle
    /// — this closes the Python-kernel ↔ Rust-contract loop at runtime.
    #[test]
    fn conv_tile_matches_rust_oracle() {
        if !have_artifacts() {
            eprintln!("skipped: run `make artifacts`");
            return;
        }
        let mut g = Golden::load_default().unwrap();
        let art = g.manifest().find("conv3x3_s1_tile").unwrap().clone();
        let input = Tensor::random_image(42, art.in_shape[0], art.in_shape[1], art.in_shape[2]);
        let got = g.run("conv3x3_s1_tile", &input).unwrap();

        use crate::model::layer::{ConvSpec, B_HI, B_LO, W_HI, W_LO};
        let spec = ConvSpec {
            name: art.name.clone(),
            k: art.k,
            stride: art.stride,
            pad: 0,
            cin: art.cin,
            cout: art.cout,
            shift: art.shift as u8,
            relu: art.relu,
            wseed: art.wseed,
            bseed: art.bseed,
            groups: 1,
        };
        let _ = (W_LO, W_HI, B_LO, B_HI);
        let want = crate::model::reference::conv_ref(&input, &spec);
        assert_eq!(got, want, "PJRT artifact != rust oracle (contract broken)");
    }

    #[test]
    fn facenet_artifact_runs() {
        if !have_artifacts() {
            eprintln!("skipped: run `make artifacts`");
            return;
        }
        let mut g = Golden::load_default().unwrap();
        let input = Tensor::random_image(7, 64, 64, 1);
        let out = g.run("facenet_fwd", &input).unwrap();
        assert_eq!(out.shape(), (4, 4, 16));
    }

    #[test]
    fn shape_mismatch_rejected() {
        if !have_artifacts() {
            eprintln!("skipped: run `make artifacts`");
            return;
        }
        let mut g = Golden::load_default().unwrap();
        assert!(g.run("facenet_fwd", &Tensor::zeros(3, 3, 1)).is_err());
    }
}
