//! PJRT runtime: load the AOT HLO artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client —
//! Python never runs on this path.
//!
//! Used for (a) **golden verification**: the cycle simulator's output
//! must match the PJRT-executed artifact bit-for-bit, and (b) as the
//! "reference CPU" baseline in the end-to-end benches.

pub mod artifacts;
/// Real PJRT executor — needs the `xla` bindings (feature `pjrt`).
#[cfg(feature = "pjrt")]
pub mod pjrt;
/// Offline stub with the same API (see Cargo.toml `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use artifacts::{Artifact, Manifest};
pub use pjrt::Golden;
