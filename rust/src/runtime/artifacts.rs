//! `artifacts/manifest.json` — the contract written by `aot.py`.

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// One artifact entry.
#[derive(Clone, Debug)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    /// "conv" | "pool" | "net"
    pub kind: String,
    /// conv-kind params (0 when not applicable)
    pub k: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
    pub shift: usize,
    pub relu: bool,
    pub wseed: u32,
    pub bseed: u32,
    /// net-kind: zoo name
    pub net: String,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

fn shape_of(j: &Json, key: &str) -> Vec<usize> {
    j.get(key)
        .and_then(|io| io.get("shape"))
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_usize).collect())
        .unwrap_or_default()
}

impl Manifest {
    /// Default artifact dir: `$KN_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("KN_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn load_default() -> anyhow::Result<Manifest> {
        Self::load(&Self::default_dir())
    }

    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("{}: {e} (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        anyhow::ensure!(j.usize_or("version", 0) == 1, "unsupported manifest version");
        let mut artifacts = Vec::new();
        for a in j.get("artifacts").and_then(Json::as_arr).unwrap_or(&[]) {
            artifacts.push(Artifact {
                name: a.str_or("name", "").to_string(),
                file: dir.join(a.str_or("file", "")),
                in_shape: shape_of(a, "input"),
                out_shape: shape_of(a, "output"),
                kind: a.str_or("kind", "").to_string(),
                k: a.usize_or("k", 0),
                stride: a.usize_or("stride", 0),
                cin: a.usize_or("cin", 0),
                cout: a.usize_or("cout", 0),
                shift: a.usize_or("shift", 0),
                relu: a.bool_or("relu", false),
                wseed: a.usize_or("wseed", 0) as u32,
                bseed: a.usize_or("bseed", 0) as u32,
                net: a.str_or("net", "").to_string(),
            });
        }
        anyhow::ensure!(!artifacts.is_empty(), "empty manifest");
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if !have_artifacts() {
            eprintln!("skipped: run `make artifacts`");
            return;
        }
        let m = Manifest::load_default().unwrap();
        for required in ["conv3x3_s1_tile", "facenet_fwd", "alexnet_fwd", "quicknet_fwd"] {
            let a = m.find(required).unwrap_or_else(|| panic!("missing {required}"));
            assert!(a.file.exists(), "{:?}", a.file);
            assert_eq!(a.in_shape.len(), 3);
            assert_eq!(a.out_shape.len(), 3);
        }
        let conv = m.find("conv3x3_s1_tile").unwrap();
        assert_eq!(conv.kind, "conv");
        assert_eq!((conv.k, conv.stride, conv.cin, conv.cout), (3, 1, 8, 16));
    }

    #[test]
    fn missing_dir_errors_with_hint() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
