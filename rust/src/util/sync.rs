//! Poison-tolerant locking shared by the coordinator, the compiler's
//! trace plumbing and the observability sinks.
//!
//! A mutex is poisoned when a holder panics. For the state guarded here
//! (metric registries, trace event vectors, report tables) the data is
//! plain values that stay internally consistent at every await point, so
//! the right response is to keep going with whatever was recorded — a
//! panicked worker must not cascade into every other thread that merely
//! wants to *observe* what happened. PR 6 established this policy inside
//! `coordinator/server.rs`; this module lifts it to a shared utility.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Consume `m`, recovering its value if a previous holder panicked.
pub fn into_inner_recover<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn recovers_from_poison() {
        let m = Mutex::new(vec![1u32]);
        // poison it
        let _ = std::thread::scope(|s| {
            s.spawn(|| {
                let _g = m.lock().unwrap();
                panic!("poison");
            })
            .join()
        });
        assert!(m.is_poisoned());
        lock_recover(&m).push(2);
        assert_eq!(into_inner_recover(m), vec![1, 2]);
    }
}
