//! Minimal JSON parser + writer (serde is not vendorable offline).
//!
//! Full JSON value model with the subset of features the repo needs:
//! objects, arrays, strings with escapes, integers/floats, bool, null.
//! Used for `artifacts/manifest.json`, run configs and metric dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// `obj.str_or(key, default)` convenience for config parsing.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Json::as_f64).unwrap_or(default)
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Json::as_bool).unwrap_or(default)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.into() }
    }
    fn ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected eof"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected '{}'", c as char))),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("eof in string"))? {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad utf8 in \\u"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad hex in \\u"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                _ => {
                    // copy one UTF-8 scalar
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.b.len() && (self.b[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// --- writer ----------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Builder helpers for metric dumps.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":"x"}],"c":null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":true,"n":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn manifest_shape() {
        // mirrors what aot.py emits
        let src = r#"{"version":1,"artifacts":[{"name":"t","file":"t.hlo.txt",
            "input":{"shape":[66,66,8],"dtype":"int16"}}]}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.usize_or("version", 0), 1);
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = a
            .get("input").unwrap()
            .get("shape").unwrap()
            .as_arr().unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![66, 66, 8]);
    }
}
