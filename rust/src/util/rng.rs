//! xorshift32 PRNG — bit-for-bit mirror of `python/compile/prng.py`.
//!
//! Both sides regenerate identical synthetic weights/images from the same
//! seeds; that is what makes the cycle simulator's output comparable
//! **bit-exactly** against the PJRT-executed HLO artifacts (whose weights
//! were baked at AOT time from the Python twin of this generator).

/// Marsaglia xorshift32. Seed 0 is remapped to the golden-ratio constant
/// (state must never be zero).
#[derive(Clone, Debug)]
pub struct XorShift32 {
    state: u32,
}

impl XorShift32 {
    pub fn new(seed: u32) -> Self {
        Self { state: if seed == 0 { 0x9E37_79B9 } else { seed } }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 17;
        x ^= x << 5;
        self.state = x;
        x
    }

    /// Uniform integer in `[lo, hi]` via modulo (mirrors the Python side;
    /// modulo bias is irrelevant for synthetic weights).
    #[inline]
    pub fn next_in(&mut self, lo: i32, hi: i32) -> i32 {
        let span = (hi - lo + 1) as u32;
        lo + (self.next_u32() % span) as i32
    }

    /// Uniform float in [0, 1) — used by workload generators (not shared
    /// with Python, so no cross-language contract).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        f64::from(self.next_u32()) / f64::from(u32::MAX)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn next_usize(&mut self, n: usize) -> usize {
        (self.next_u32() as usize) % n.max(1)
    }
}

/// Deterministic int16 weight tensor, C-contiguous generation order
/// (mirror of `prng.weight_tensor`).
pub fn weight_tensor(seed: u32, len: usize, lo: i32, hi: i32) -> Vec<i16> {
    let mut rng = XorShift32::new(seed);
    (0..len).map(|_| rng.next_in(lo, hi) as i16).collect()
}

/// Deterministic int32 bias tensor (mirror of `prng.bias_tensor`).
pub fn bias_tensor(seed: u32, len: usize, lo: i32, hi: i32) -> Vec<i32> {
    let mut rng = XorShift32::new(seed);
    (0..len).map(|_| rng.next_in(lo, hi)).collect()
}

/// Deterministic int16 image tensor (mirror of `prng.image_tensor`),
/// default pixel range 0..=255.
pub fn image_tensor(seed: u32, len: usize, lo: i32, hi: i32) -> Vec<i16> {
    let mut rng = XorShift32::new(seed);
    (0..len).map(|_| rng.next_in(lo, hi) as i16).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pinned vectors — the SAME values are pinned in
    /// `python/tests/test_prng.py`. If this test fails the cross-language
    /// weight contract is broken.
    #[test]
    fn pinned_vectors_match_python() {
        let mut r = XorShift32::new(1);
        let got: Vec<u32> = (0..5).map(|_| r.next_u32()).collect();
        assert_eq!(got, vec![270_369, 67_634_689, 2_647_435_461, 307_599_695, 2_398_689_233]);
        assert_eq!(XorShift32::new(0).next_u32(), 1_359_758_873);
    }

    #[test]
    fn ranges_respected() {
        let mut r = XorShift32::new(99);
        let vals: Vec<i32> = (0..1000).map(|_| r.next_in(-128, 127)).collect();
        assert!(vals.iter().all(|&v| (-128..=127).contains(&v)));
        assert!(vals.iter().any(|&v| v < -100));
        assert!(vals.iter().any(|&v| v > 100));
    }

    #[test]
    fn deterministic_tensors() {
        assert_eq!(weight_tensor(7, 64, -128, 127), weight_tensor(7, 64, -128, 127));
        assert_ne!(weight_tensor(7, 64, -128, 127), weight_tensor(8, 64, -128, 127));
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = XorShift32::new(5);
        for _ in 0..100 {
            let v = r.next_f64();
            assert!((0.0..=1.0).contains(&v));
        }
    }
}
