//! Micro-bench harness (criterion is not vendorable offline).
//!
//! Auto-calibrating: warms up, picks an iteration count targeting a fixed
//! measurement window, reports mean/σ/min and throughput. Every
//! `rust/benches/bench_*.rs` builds on this plus table printers that
//! regenerate the paper's tables/figures row-for-row, and a
//! [`JsonReport`] writer that emits machine-readable `BENCH_*.json`
//! artifacts so the perf trajectory is tracked across PRs (CI uploads
//! them).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Running;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean: Duration,
    pub std: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

/// Run `f` repeatedly and measure. `f` must return something observable
/// to prevent the optimizer from deleting the work (use `std::hint::black_box`
/// in the closure for extra safety).
pub fn bench<F: FnMut() -> R, R>(name: &str, mut f: F) -> BenchResult {
    bench_cfg(name, Duration::from_millis(300), Duration::from_millis(700), &mut f)
}

/// Short variant for heavyweight cases (full-network simulations).
pub fn bench_once<F: FnMut() -> R, R>(name: &str, mut f: F) -> BenchResult {
    // single timed run, no calibration
    let t0 = Instant::now();
    std::hint::black_box(f());
    let dt = t0.elapsed();
    BenchResult { name: name.into(), iters: 1, mean: dt, std: Duration::ZERO, min: dt }
}

pub fn bench_cfg<F: FnMut() -> R, R>(
    name: &str,
    warmup: Duration,
    measure: Duration,
    f: &mut F,
) -> BenchResult {
    // Warm-up and single-iteration estimate.
    let mut one = Duration::from_nanos(u64::MAX);
    let t0 = Instant::now();
    let mut warm_iters = 0u64;
    while t0.elapsed() < warmup || warm_iters < 3 {
        let t = Instant::now();
        std::hint::black_box(f());
        one = one.min(t.elapsed().max(Duration::from_nanos(1)));
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    // Batch size so that one sample ~ measure/16.
    let target_sample = measure / 16;
    let batch = (target_sample.as_nanos() / one.as_nanos().max(1)).clamp(1, 1 << 24) as u64;
    let mut stats = Running::new();
    let mut total_iters = 0u64;
    let t1 = Instant::now();
    while t1.elapsed() < measure {
        let t = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f());
        }
        stats.push(t.elapsed().as_secs_f64() / batch as f64);
        total_iters += batch;
    }
    BenchResult {
        name: name.into(),
        iters: total_iters,
        mean: Duration::from_secs_f64(stats.mean().max(1e-12)),
        std: Duration::from_secs_f64(stats.std()),
        min: Duration::from_secs_f64(if stats.count() == 0 { 0.0 } else { stats.min() }),
    }
}

/// Pretty table printer used by all bench binaries.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }
    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n== {} ==", self.title);
        let sep: String = "-".repeat(line);
        println!("{sep}");
        let hdr: Vec<String> = self
            .headers
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("{h:>w$}"))
            .collect();
        println!("| {} |", hdr.join(" | "));
        println!("{sep}");
        for r in &self.rows {
            let cells: Vec<String> =
                r.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}")).collect();
            println!("| {} |", cells.join(" | "));
        }
        println!("{sep}");
    }
}

/// Machine-readable benchmark artifact: accumulates scalar fields and
/// row arrays, then writes `BENCH_<name>.json` into `$KN_BENCH_DIR`
/// (default: the working directory). All benches emit one so CI can
/// upload and diff the perf trajectory PR over PR.
pub struct JsonReport {
    name: String,
    fields: BTreeMap<String, Json>,
}

impl JsonReport {
    pub fn new(name: &str) -> Self {
        Self { name: name.into(), fields: BTreeMap::new() }
    }

    /// Set a scalar numeric field.
    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        self.fields.insert(key.into(), Json::Num(v));
        self
    }

    /// Set a string field.
    pub fn text(&mut self, key: &str, v: &str) -> &mut Self {
        self.fields.insert(key.into(), Json::Str(v.into()));
        self
    }

    /// Set an arbitrary JSON field.
    pub fn set(&mut self, key: &str, v: Json) -> &mut Self {
        self.fields.insert(key.into(), v);
        self
    }

    /// Append one row object to the array field `key`.
    pub fn push_row(&mut self, key: &str, row: Json) -> &mut Self {
        match self.fields.entry(key.into()).or_insert_with(|| Json::Arr(Vec::new())) {
            Json::Arr(a) => a.push(row),
            other => *other = Json::Arr(vec![row]),
        }
        self
    }

    /// Target path: `$KN_BENCH_DIR/BENCH_<name>.json`.
    pub fn path(&self) -> PathBuf {
        let dir = std::env::var_os("KN_BENCH_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        dir.join(format!("BENCH_{}.json", self.name))
    }

    /// Write the artifact into an explicit directory (testable without
    /// touching process-global state).
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, format!("{}\n", Json::Obj(self.fields.clone())))?;
        println!("wrote {}", path.display());
        Ok(path)
    }

    /// Write the artifact to [`Self::path`]; prints the path on success
    /// so bench logs record where the machine-readable copy went.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = std::env::var_os("KN_BENCH_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("."));
        self.write_to(&dir)
    }
}

/// Format a Duration human-readably.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns >= 1_000_000_000 {
        format!("{:.2}s", d.as_secs_f64())
    } else if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_cfg(
            "spin",
            Duration::from_millis(5),
            Duration::from_millis(20),
            &mut || {
                let mut acc = 0u64;
                for i in 0..100 {
                    // black_box defeats const-folding in release builds
                    acc = acc.wrapping_add(std::hint::black_box(i) * i);
                }
                acc
            },
        );
        assert!(r.iters > 10);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn table_arity_enforced() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(12)), "12ns");
        assert_eq!(fmt_dur(Duration::from_micros(12)), "12.00us");
        assert_eq!(fmt_dur(Duration::from_millis(12)), "12.00ms");
        assert_eq!(fmt_dur(Duration::from_secs(2)), "2.00s");
    }

    #[test]
    fn json_report_roundtrips() {
        use crate::util::json::{obj, s, Json};
        let dir = std::env::temp_dir().join(format!("kn_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut r = JsonReport::new("unit_test");
        r.num("gops", 5.76).text("bench", "unit").push_row(
            "layers",
            obj(vec![("name", s("conv1")), ("wall_ns", Json::Num(123.0))]),
        );
        let path = r.write_to(&dir).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(text.trim()).unwrap();
        assert_eq!(back.get("gops").and_then(Json::as_f64), Some(5.76));
        assert_eq!(back.get("bench").and_then(Json::as_str), Some("unit"));
        assert_eq!(back.get("layers").and_then(Json::as_arr).map(|a| a.len()), Some(1));
        std::fs::remove_dir_all(&dir).ok();
    }
}
