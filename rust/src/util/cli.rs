//! Tiny declarative CLI argument parser (clap is not vendorable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals, defaults
//! and auto-generated `--help`.

use std::collections::BTreeMap;

/// One declared argument.
#[derive(Clone)]
struct ArgSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative CLI parser.
///
/// ```no_run
/// // (no_run: doctest binaries don't inherit the rpath to the parked
/// // libstdc++ — see .cargo/config.toml; the same code is exercised in
/// // the unit tests below)
/// use kn_stream::util::cli::Cli;
/// let mut cli = Cli::new("demo", "demo tool");
/// cli.opt("frames", "64", "number of frames");
/// cli.flag("verbose", "chatty output");
/// let m = cli.parse_from(vec!["--frames".into(), "8".into(), "--verbose".into()]).unwrap();
/// assert_eq!(m.get_usize("frames"), 8);
/// assert!(m.get_flag("verbose"));
/// ```
pub struct Cli {
    name: String,
    about: String,
    specs: Vec<ArgSpec>,
}

/// Parsed matches.
pub struct Matches {
    vals: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Cli {
    pub fn new(name: &str, about: &str) -> Self {
        Self { name: name.into(), about: about.into(), specs: Vec::new() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(&mut self, name: &str, default: &str, help: &str) -> &mut Self {
        self.specs.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(&mut self, name: &str, help: &str) -> &mut Self {
        self.specs.push(ArgSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} [OPTIONS] [ARGS..]\n\nOPTIONS:\n",
            self.name, self.about, self.name);
        for s in &self.specs {
            if s.is_flag {
                out.push_str(&format!("  --{:<24} {}\n", s.name, s.help));
            } else {
                out.push_str(&format!(
                    "  --{:<24} {} (default: {})\n",
                    format!("{} <v>", s.name),
                    s.help,
                    s.default.as_deref().unwrap_or("")
                ));
            }
        }
        out.push_str("  --help                     print this help\n");
        out
    }

    /// Parse `std::env::args().skip(1)`.
    pub fn parse(&self) -> anyhow::Result<Matches> {
        self.parse_from(std::env::args().skip(1).collect())
    }

    pub fn parse_from(&self, args: Vec<String>) -> anyhow::Result<Matches> {
        let mut m = Matches {
            vals: BTreeMap::new(),
            flags: BTreeMap::new(),
            positionals: Vec::new(),
        };
        for s in &self.specs {
            if let Some(d) = &s.default {
                m.vals.insert(s.name.clone(), d.clone());
            }
            if s.is_flag {
                m.flags.insert(s.name.clone(), false);
            }
        }
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| anyhow::anyhow!("unknown option --{key}\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        anyhow::bail!("flag --{key} takes no value");
                    }
                    m.flags.insert(key, true);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?,
                    };
                    m.vals.insert(key, v);
                }
            } else {
                m.positionals.push(a);
            }
        }
        Ok(m)
    }
}

impl Matches {
    pub fn get(&self, key: &str) -> &str {
        self.vals.get(key).map(String::as_str).unwrap_or("")
    }
    pub fn get_usize(&self, key: &str) -> usize {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }
    pub fn get_u64(&self, key: &str) -> u64 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be an integer"))
    }
    pub fn get_f64(&self, key: &str) -> f64 {
        self.get(key).parse().unwrap_or_else(|_| panic!("--{key} must be a number"))
    }
    pub fn get_flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }
    /// Comma-separated list of numbers (`--chip-freqs 500,250`); an
    /// empty or absent value parses to an empty list.
    pub fn get_f64_list(&self, key: &str) -> Vec<f64> {
        let raw = self.get(key);
        if raw.trim().is_empty() {
            return Vec::new();
        }
        raw.split(',')
            .map(|v| {
                v.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("--{key} must be comma-separated numbers"))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        let mut c = Cli::new("t", "test");
        c.opt("n", "4", "count").opt("name", "x", "a name").flag("fast", "go fast");
        c
    }

    #[test]
    fn defaults() {
        let m = cli().parse_from(vec![]).unwrap();
        assert_eq!(m.get_usize("n"), 4);
        assert_eq!(m.get("name"), "x");
        assert!(!m.get_flag("fast"));
    }

    #[test]
    fn space_and_equals_forms() {
        let m = cli()
            .parse_from(vec!["--n".into(), "9".into(), "--name=foo".into(), "--fast".into()])
            .unwrap();
        assert_eq!(m.get_usize("n"), 9);
        assert_eq!(m.get("name"), "foo");
        assert!(m.get_flag("fast"));
    }

    #[test]
    fn positionals_collected() {
        let m = cli().parse_from(vec!["a".into(), "--n".into(), "2".into(), "b".into()]).unwrap();
        assert_eq!(m.positionals, vec!["a", "b"]);
    }

    #[test]
    fn f64_lists_parse_with_spaces_and_default_empty() {
        let mut c = Cli::new("t", "test");
        c.opt("chip-freqs", "", "per-chip MHz");
        let m = c.parse_from(vec!["--chip-freqs".into(), "500, 250,125".into()]).unwrap();
        assert_eq!(m.get_f64_list("chip-freqs"), vec![500.0, 250.0, 125.0]);
        let m = c.parse_from(vec![]).unwrap();
        assert!(m.get_f64_list("chip-freqs").is_empty());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cli().parse_from(vec!["--bogus".into()]).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cli().parse_from(vec!["--n".into()]).is_err());
    }
}
