//! Streaming statistics + fixed-bucket latency histogram (for the
//! coordinator's metrics and the bench harness).

/// Running mean / min / max / stddev (Welford).
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }
    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// Log-bucketed histogram with exact quantile estimation good enough for
/// latency reporting (p50/p95/p99). Buckets are powers of `2^(1/8)` —
/// <9 % relative error per bucket. Min/max/sum are tracked exactly so
/// mean and extrema carry no bucketing error.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const BUCKETS: usize = 512;

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            counts: vec![0; BUCKETS],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket(x: f64) -> usize {
        if x <= 1.0 {
            return 0;
        }
        // index = log_{2^(1/8)}(x) = 8*log2(x)
        ((8.0 * x.log2()) as usize).min(BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> f64 {
        2f64.powf(i as f64 / 8.0)
    }

    pub fn record(&mut self, x: f64) {
        self.counts[Self::bucket(x)] += 1;
        self.total += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    /// Exact minimum of recorded samples (0.0 when empty, like `Running`).
    pub fn min(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.min }
    }

    /// Exact maximum of recorded samples (0.0 when empty, like `Running`).
    pub fn max(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.max }
    }

    /// Quantile in [0,1] -> approximate value.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        Self::bucket_value(BUCKETS - 1)
    }
}

/// Pretty-print a f64 with engineering suffix (K/M/G/T).
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn running_moments() {
        let mut r = Running::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            r.push(x);
        }
        assert_eq!(r.count(), 8);
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.std() - 2.138).abs() < 0.01);
        assert_eq!(r.min(), 2.0);
        assert_eq!(r.max(), 9.0);
    }

    #[test]
    fn histogram_quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        for i in 1..=10_000 {
            h.record(i as f64);
        }
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.10, "p50={p50}");
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.10, "p99={p99}");
        assert_eq!(h.count(), 10_000);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn histogram_exact_extrema_and_sum() {
        let mut h = Histogram::new();
        for x in [12.5, 700.0, 3.0, 41.0] {
            h.record(x);
        }
        // extrema and sum are exact even though quantiles are bucketed
        assert_eq!(h.min(), 3.0);
        assert_eq!(h.max(), 700.0);
        assert!((h.sum() - 756.5).abs() < 1e-12);
        assert!((h.mean() - 189.125).abs() < 1e-12);
    }

    #[test]
    fn eng_suffixes() {
        assert_eq!(eng(144e9), "144.00G");
        assert_eq!(eng(5.76e9), "5.76G");
        assert_eq!(eng(0.8e12), "800.00G");
        assert_eq!(eng(42.0), "42.00");
    }
}
