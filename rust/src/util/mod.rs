//! Substrates built from scratch for the offline environment (no serde /
//! clap / criterion / proptest vendorable): PRNG, JSON, CLI, statistics,
//! a micro-bench harness and a property-test engine.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod sync;
