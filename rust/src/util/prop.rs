//! Hand-rolled property-test engine (proptest is not vendorable offline).
//!
//! A `Gen` wraps the shared xorshift32 and produces random cases; `check`
//! runs N cases and, on failure, re-runs a simple halving **shrink** over
//! the failing case's size parameters before panicking with the minimal
//! reproduction seed. Coordinator invariants (routing, batching, state),
//! decomposition legality and numerics contracts are all property-tested
//! with this.

use super::rng::XorShift32;

/// Random-case generator handed to properties.
pub struct Gen {
    pub rng: XorShift32,
    /// Current size budget — shrinking lowers this.
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u32, size: usize) -> Self {
        Self { rng: XorShift32::new(seed), size }
    }
    /// Integer in [lo, hi] (inclusive), clamped by the size budget above lo.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        let hi_eff = lo + ((hi - lo) as u64).min(self.size as u64) as i64;
        lo + (self.rng.next_u32() as i64).rem_euclid(hi_eff - lo + 1)
    }
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }
    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_usize(xs.len())]
    }
    pub fn vec_i16(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i16> {
        (0..len).map(|_| self.rng.next_in(lo, hi) as i16).collect()
    }
}

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `prop`. On failure, shrink the size budget
/// (halving) to find a smaller failing case, then panic with diagnostics.
pub fn check(name: &str, cases: u32, prop: impl Fn(&mut Gen) -> CaseResult) {
    check_seeded(name, 0xC0FFEE, cases, prop)
}

pub fn check_seeded(name: &str, base_seed: u32, cases: u32, prop: impl Fn(&mut Gen) -> CaseResult) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case).wrapping_mul(0x9E37_79B9) | 1;
        let mut g = Gen::new(seed, 64);
        if let Err(msg) = prop(&mut g) {
            // shrink: halve the size budget while it still fails
            let mut best = (64usize, msg);
            let mut size = 32usize;
            while size >= 1 {
                let mut g = Gen::new(seed, size);
                match prop(&mut g) {
                    Err(m) => {
                        best = (size, m);
                        size /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, shrunk size {}):\n  {}",
                best.0, best.1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("add commutes", 100, |g| {
            let a = g.int(-1000, 1000);
            let b = g.int(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a}+{b}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |g| {
            let v = g.int(0, 10);
            Err(format!("v={v}"))
        });
    }

    #[test]
    fn shrink_reduces_size() {
        // property failing only for size >= 2 — the shrinker must find
        // that size 1 passes and report a small failing budget.
        let result = std::panic::catch_unwind(|| {
            check("fails when big", 1, |g| {
                let v = g.usize_in(0, 60);
                if v >= 2 {
                    Err(format!("v={v}"))
                } else {
                    Ok(())
                }
            });
        });
        assert!(result.is_err());
    }

    #[test]
    fn gen_respects_bounds() {
        let mut g = Gen::new(1, 1000);
        for _ in 0..1000 {
            let v = g.int(-5, 5);
            assert!((-5..=5).contains(&v));
        }
    }
}
