//! Scalar reference implementation (the in-crate oracle).
//!
//! Straight nested loops over the layer math, written for auditability,
//! not speed. The cycle simulator (`sim/`), the compiler's decomposed
//! schedules, and the PJRT-executed artifacts are all tested against
//! this — and this, in turn, matches the Python numpy oracle through the
//! shared PRNG + fixed-point contract.

use super::graph::{AddSpec, Graph, NodeOp, NodeRef};
use super::layer::{ConvSpec, LayerSpec, NetSpec, PoolSpec};
use super::tensor::Tensor;
use crate::fixed;

/// Full KxK conv (valid padding — pad the input first), fused requantize.
pub fn conv_ref(x: &Tensor, spec: &ConvSpec) -> Tensor {
    let w = spec.weights();
    let b = spec.biases();
    conv_ref_with(x, spec, &w, &b)
}

/// Like [`conv_ref`] but with caller-provided parameters (used by tests
/// that inject special weights).
pub fn conv_ref_with(x: &Tensor, spec: &ConvSpec, w: &[i16], b: &[i32]) -> Tensor {
    assert_eq!(x.c, spec.cin);
    let cg = spec.cin / spec.groups; // channels per group
    let mg = spec.cout / spec.groups; // output features per group
    assert_eq!(w.len(), spec.k * spec.k * cg * spec.cout);
    assert_eq!(b.len(), spec.cout);
    let ho = (x.h - spec.k) / spec.stride + 1;
    let wo = (x.w - spec.k) / spec.stride + 1;
    let mut out = Tensor::zeros(ho, wo, spec.cout);
    for oy in 0..ho {
        for ox in 0..wo {
            for m in 0..spec.cout {
                let g = m / mg; // which group this output feature is in
                let mut acc = b[m];
                for i in 0..spec.k {
                    for j in 0..spec.k {
                        for ch in 0..cg {
                            let xv =
                                x.at(oy * spec.stride + i, ox * spec.stride + j, g * cg + ch);
                            // weight layout (K, K, cg, cout) C-order: the
                            // group's features live at columns [g*mg, (g+1)*mg)
                            let wv = w[((i * spec.k + j) * cg + ch) * spec.cout + m];
                            acc = fixed::acc_add(acc, fixed::pe_mul(xv, wv));
                        }
                    }
                }
                out.set(oy, ox, m, fixed::requantize(acc, spec.shift, spec.relu));
            }
        }
    }
    out
}

/// Depthwise conv oracle (`groups == cin == cout`): each output channel
/// is its own input channel filtered by its own K×K kernel. Pure
/// delegation to the grouped [`conv_ref`] math — this exists so the
/// depthwise fast path has a named, shape-checked reference to be
/// bit-exact against.
pub fn depthwise_ref(x: &Tensor, spec: &ConvSpec) -> Tensor {
    assert_eq!(spec.groups, spec.cin, "depthwise: groups == cin");
    assert_eq!(spec.cout, spec.cin, "depthwise: cout == cin");
    conv_ref(x, spec)
}

/// Average pooling oracle: int32 window sum, then round-half-up
/// division by the window area — the same rounding convention as the
/// conv requantizer (`fixed::requantize`), so `k = 2` (÷4) is exactly a
/// shift and odd areas round to nearest. Covers the global-average-pool
/// head (`k` = plane size, one output pixel per channel).
pub fn avgpool_ref(x: &Tensor, spec: &PoolSpec) -> Tensor {
    let ho = (x.h - spec.k) / spec.stride + 1;
    let wo = (x.w - spec.k) / spec.stride + 1;
    let area = (spec.k * spec.k) as i32;
    let mut out = Tensor::zeros(ho, wo, x.c);
    for oy in 0..ho {
        for ox in 0..wo {
            for ch in 0..x.c {
                let mut sum = 0i32;
                for i in 0..spec.k {
                    for j in 0..spec.k {
                        sum += x.at(oy * spec.stride + i, ox * spec.stride + j, ch) as i32;
                    }
                }
                // round-half-up mean; always representable in i16
                out.set(oy, ox, ch, (sum + area / 2).div_euclid(area) as i16);
            }
        }
    }
    out
}

/// Pooling oracle dispatching on the window kind.
pub fn pool_kind_ref(x: &Tensor, spec: &PoolSpec) -> Tensor {
    match spec.kind {
        crate::model::PoolKind::Max => pool_ref(x, spec),
        crate::model::PoolKind::Avg => avgpool_ref(x, spec),
    }
}

/// Max pooling oracle.
pub fn pool_ref(x: &Tensor, spec: &PoolSpec) -> Tensor {
    let ho = (x.h - spec.k) / spec.stride + 1;
    let wo = (x.w - spec.k) / spec.stride + 1;
    let mut out = Tensor::zeros(ho, wo, x.c);
    for oy in 0..ho {
        for ox in 0..wo {
            for ch in 0..x.c {
                let mut m = i16::MIN;
                for i in 0..spec.k {
                    for j in 0..spec.k {
                        m = m.max(x.at(oy * spec.stride + i, ox * spec.stride + j, ch));
                    }
                }
                out.set(oy, ox, ch, m);
            }
        }
    }
    out
}

/// Element-wise residual add oracle: `requantize(a + b, shift, relu)`
/// per pixel — the same output stage as a conv, applied to the int32
/// sum (matches the `Add` ISA command bit-for-bit).
pub fn add_ref(a: &Tensor, b: &Tensor, spec: &AddSpec) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "add {}: operand shapes", spec.name);
    let mut out = Tensor::zeros(a.h, a.w, a.c);
    for (o, (&x, &y)) in out.data.iter_mut().zip(a.data.iter().zip(&b.data)) {
        *o = fixed::requantize(fixed::acc_add(x as i32, y as i32), spec.shift, spec.relu);
    }
    out
}

/// One layer (applies conv padding).
pub fn run_layer_ref(x: &Tensor, layer: &LayerSpec) -> Tensor {
    match layer {
        LayerSpec::Conv(c) => conv_ref(&x.pad_hw(c.pad), c),
        LayerSpec::Pool(p) => pool_kind_ref(x, p),
    }
}

/// Whole net.
pub fn run_net_ref(net: &NetSpec, input: &Tensor) -> Tensor {
    assert_eq!(input.shape(), net.in_shape(), "net {} input shape", net.name);
    let mut x = input.clone();
    for l in &net.layers {
        x = run_layer_ref(&x, l);
    }
    x
}

/// Whole graph: evaluate nodes in (construction-guaranteed) topological
/// order, memoizing every node's tensor — branch fan-out reads the same
/// producer tensor, exactly like consumers reading one DRAM canvas.
pub fn run_graph_ref(graph: &Graph, input: &Tensor) -> Tensor {
    assert_eq!(input.shape(), graph.in_shape(), "graph {} input shape", graph.name);
    let mut outs: Vec<Tensor> = Vec::with_capacity(graph.nodes.len());
    for node in &graph.nodes {
        let mut ins: Vec<&Tensor> = Vec::with_capacity(node.inputs.len());
        for r in &node.inputs {
            ins.push(match r {
                NodeRef::Input => input,
                NodeRef::Node(i) => &outs[*i],
            });
        }
        let out = match &node.op {
            NodeOp::Conv(c) => conv_ref(&ins[0].pad_hw(c.pad), c),
            NodeOp::Pool(p) => pool_kind_ref(ins[0], p),
            NodeOp::Add(a) => add_ref(ins[0], ins[1], a),
            NodeOp::Concat(_) => Tensor::concat_c(&ins),
        };
        outs.push(out);
    }
    match graph.output {
        NodeRef::Input => input.clone(),
        NodeRef::Node(i) => outs.swap_remove(i),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn identity_kernel_reproduces_input() {
        let x = Tensor::random_image(1, 10, 10, 1);
        let spec = ConvSpec {
            name: "id".into(),
            k: 3,
            stride: 1,
            pad: 0,
            cin: 1,
            cout: 1,
            shift: 0,
            relu: false,
            wseed: 0,
            bseed: 0,
            groups: 1,
        };
        let mut w = vec![0i16; 9];
        w[4] = 1; // center tap
        let out = conv_ref_with(&x, &spec, &w, &[0]);
        assert_eq!(out.shape(), (8, 8, 1));
        for y in 0..8 {
            for xx in 0..8 {
                assert_eq!(out.at(y, xx, 0), x.at(y + 1, xx + 1, 0));
            }
        }
    }

    #[test]
    fn pool_known_values() {
        let x = Tensor::from_vec(4, 4, 1, (0..16).map(|v| v as i16).collect());
        let out = pool_ref(&x, &PoolSpec::max("p", 2, 2));
        assert_eq!(out.data, vec![5, 7, 13, 15]);
    }

    #[test]
    fn avgpool_known_values_round_half_up() {
        let x = Tensor::from_vec(4, 4, 1, (0..16).map(|v| v as i16).collect());
        // windows sum to 10, 18, 42, 50; (sum + 2) / 4
        let out = avgpool_ref(&x, &PoolSpec::avg("a", 2, 2));
        assert_eq!(out.data, vec![3, 5, 11, 13]);
        // negative values: (-10 + 2).div_euclid(4) = -2 (round half up)
        let n = Tensor::from_vec(2, 2, 1, vec![-1, -2, -3, -4]);
        let out = avgpool_ref(&n, &PoolSpec::avg("n", 2, 1));
        assert_eq!(out.data, vec![-2]);
    }

    #[test]
    fn global_avg_pool_is_plane_mean() {
        let x = Tensor::from_vec(3, 3, 2, (0..18).map(|v| v as i16).collect());
        let out = avgpool_ref(&x, &PoolSpec::global_avg("g", 3));
        assert_eq!(out.shape(), (1, 1, 2));
        // channel 0 holds evens 0..=16 (mean 8), channel 1 odds (mean 9)
        assert_eq!(out.data, vec![8, 9]);
    }

    #[test]
    fn facenet_runs_and_keeps_signal() {
        let net = zoo::facenet();
        let x = Tensor::random_image(7, 64, 64, 1);
        let out = run_net_ref(&net, &x);
        assert_eq!(out.shape(), (4, 4, 16));
        let nonzero = out.data.iter().filter(|&&v| v != 0).count();
        assert!(nonzero > 8, "signal died: {nonzero} nonzero of {}", out.data.len());
    }

    #[test]
    fn add_ref_requantizes_like_the_conv_output_stage() {
        let a = Tensor::from_vec(1, 2, 1, vec![100, -100]);
        let b = Tensor::from_vec(1, 2, 1, vec![3, -3]);
        let spec = AddSpec { name: "a".into(), shift: 1, relu: false };
        // round-half-up: (103+1)>>1 = 52, (-103+1)>>1 = -51
        assert_eq!(add_ref(&a, &b, &spec).data, vec![52, -51]);
        let relu = AddSpec { name: "r".into(), shift: 0, relu: true };
        assert_eq!(add_ref(&a, &b, &relu).data, vec![103, 0]);
    }

    #[test]
    fn graph_ref_matches_linear_net_ref() {
        let net = zoo::facenet();
        let g = crate::model::Graph::from_net(&net);
        let x = Tensor::random_image(11, 64, 64, 1);
        assert_eq!(run_graph_ref(&g, &x), run_net_ref(&net, &x));
    }

    #[test]
    fn residual_identity_branch() {
        // add(x, x) with shift 1 and no relu is the identity (round-half-
        // up of 2v is exactly v): a zero-weight conv branch + shortcut.
        let mut g = crate::model::Graph::new("idres", 6, 6, 2);
        g.add_node(
            crate::model::NodeOp::Add(AddSpec { name: "add".into(), shift: 1, relu: false }),
            &["input", "input"],
        )
        .unwrap();
        let x = Tensor::random_image(3, 6, 6, 2);
        assert_eq!(run_graph_ref(&g, &x), x);
    }

    #[test]
    fn stride2_shapes() {
        let x = Tensor::random_image(2, 11, 11, 2);
        let spec = ConvSpec {
            name: "s2".into(),
            k: 3,
            stride: 2,
            pad: 0,
            cin: 2,
            cout: 4,
            shift: 8,
            relu: true,
            wseed: 3,
            bseed: 4,
            groups: 1,
        };
        assert_eq!(conv_ref(&x, &spec).shape(), (5, 5, 4));
    }
}
