//! Minimal HWC int16 tensor (and int32 accumulator plane) — the only
//! tensor type the accelerator moves around. Row-major HWC matches the
//! DRAM layout the DMA streams (channel-interleaved pixels).

use crate::util::rng;

/// (H, W, C) int16 tensor, row-major HWC.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<i16>,
}

impl Tensor {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Self { h, w, c, data: vec![0; h * w * c] }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<i16>) -> Self {
        assert_eq!(data.len(), h * w * c, "tensor shape/data mismatch");
        Self { h, w, c, data }
    }

    /// Deterministic synthetic image (mirrors `prng.image_tensor`).
    pub fn random_image(seed: u32, h: usize, w: usize, c: usize) -> Self {
        Self::from_vec(h, w, c, rng::image_tensor(seed, h * w * c, 0, 255))
    }

    #[inline(always)]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> i16 {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline(always)]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: i16) {
        debug_assert!(y < self.h && x < self.w && ch < self.c);
        self.data[(y * self.w + x) * self.c + ch] = v;
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.h, self.w, self.c)
    }

    pub fn bytes(&self) -> usize {
        self.data.len() * 2
    }

    /// Zero-pad H and W by `pad` on every side (the DMA writes a zero
    /// apron around each tile for 'same' convolutions).
    pub fn pad_hw(&self, pad: usize) -> Tensor {
        if pad == 0 {
            return self.clone();
        }
        let mut out = Tensor::zeros(self.h + 2 * pad, self.w + 2 * pad, self.c);
        for y in 0..self.h {
            let src = &self.data[y * self.w * self.c..(y + 1) * self.w * self.c];
            let off = ((y + pad) * out.w + pad) * out.c;
            out.data[off..off + src.len()].copy_from_slice(src);
        }
        out
    }

    /// Crop a (y0..y0+h, x0..x0+w) window, all channels.
    pub fn crop(&self, y0: usize, x0: usize, h: usize, w: usize) -> Tensor {
        assert!(y0 + h <= self.h && x0 + w <= self.w, "crop out of bounds");
        let mut out = Tensor::zeros(h, w, self.c);
        for y in 0..h {
            let src = ((y0 + y) * self.w + x0) * self.c;
            let dst = y * w * self.c;
            out.data[dst..dst + w * self.c]
                .copy_from_slice(&self.data[src..src + w * self.c]);
        }
        out
    }

    /// Channel slice [c0, c0+n).
    pub fn channels(&self, c0: usize, n: usize) -> Tensor {
        assert!(c0 + n <= self.c);
        let mut out = Tensor::zeros(self.h, self.w, n);
        for y in 0..self.h {
            for x in 0..self.w {
                for ch in 0..n {
                    out.set(y, x, ch, self.at(y, x, c0 + ch));
                }
            }
        }
        out
    }

    /// Write `src` into self at channel offset `c0` (feature-decomposition
    /// re-assembly).
    pub fn write_channels(&mut self, c0: usize, src: &Tensor) {
        assert_eq!((self.h, self.w), (src.h, src.w));
        assert!(c0 + src.c <= self.c);
        for y in 0..self.h {
            for x in 0..self.w {
                for ch in 0..src.c {
                    self.set(y, x, c0 + ch, src.at(y, x, ch));
                }
            }
        }
    }

    /// Concatenate tensors along the channel axis (graph `Concat` op).
    /// All inputs must share H and W.
    pub fn concat_c(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty(), "concat of nothing");
        let (h, w) = (parts[0].h, parts[0].w);
        assert!(
            parts.iter().all(|p| p.h == h && p.w == w),
            "concat plane mismatch"
        );
        let mut out = Tensor::zeros(h, w, parts.iter().map(|p| p.c).sum());
        let mut c0 = 0;
        for p in parts {
            out.write_channels(c0, p);
            c0 += p.c;
        }
        out
    }

    /// Write `src` into self at spatial offset (y0, x0) (image-
    /// decomposition re-assembly).
    pub fn write_window(&mut self, y0: usize, x0: usize, src: &Tensor) {
        assert_eq!(self.c, src.c);
        assert!(y0 + src.h <= self.h && x0 + src.w <= self.w);
        for y in 0..src.h {
            let dst = ((y0 + y) * self.w + x0) * self.c;
            let s = y * src.w * src.c;
            self.data[dst..dst + src.w * src.c]
                .copy_from_slice(&src.data[s..s + src.w * src.c]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_roundtrip() {
        let mut t = Tensor::zeros(4, 5, 3);
        t.set(2, 3, 1, -77);
        assert_eq!(t.at(2, 3, 1), -77);
        assert_eq!(t.at(2, 3, 0), 0);
    }

    #[test]
    fn pad_places_image_centered() {
        let t = Tensor::from_vec(1, 1, 1, vec![9]);
        let p = t.pad_hw(2);
        assert_eq!(p.shape(), (5, 5, 1));
        assert_eq!(p.at(2, 2, 0), 9);
        assert_eq!(p.data.iter().filter(|&&v| v != 0).count(), 1);
    }

    #[test]
    fn crop_window() {
        let t = Tensor::random_image(1, 6, 6, 2);
        let c = t.crop(1, 2, 3, 3);
        assert_eq!(c.shape(), (3, 3, 2));
        assert_eq!(c.at(0, 0, 0), t.at(1, 2, 0));
        assert_eq!(c.at(2, 2, 1), t.at(3, 4, 1));
    }

    #[test]
    fn channel_split_and_reassemble() {
        let t = Tensor::random_image(2, 4, 4, 6);
        let a = t.channels(0, 3);
        let b = t.channels(3, 3);
        let mut r = Tensor::zeros(4, 4, 6);
        r.write_channels(0, &a);
        r.write_channels(3, &b);
        assert_eq!(r, t);
    }

    #[test]
    fn concat_c_stacks_channels() {
        let a = Tensor::random_image(1, 4, 4, 2);
        let b = Tensor::random_image(2, 4, 4, 3);
        let cat = Tensor::concat_c(&[&a, &b]);
        assert_eq!(cat.shape(), (4, 4, 5));
        assert_eq!(cat.channels(0, 2), a);
        assert_eq!(cat.channels(2, 3), b);
    }

    #[test]
    fn window_reassemble() {
        let t = Tensor::random_image(3, 8, 8, 2);
        let mut r = Tensor::zeros(8, 8, 2);
        for (y0, x0) in [(0, 0), (0, 4), (4, 0), (4, 4)] {
            r.write_window(y0, x0, &t.crop(y0, x0, 4, 4));
        }
        assert_eq!(r, t);
    }

    #[test]
    #[should_panic(expected = "crop out of bounds")]
    fn crop_bounds_checked() {
        Tensor::zeros(4, 4, 1).crop(2, 2, 3, 3);
    }
}
