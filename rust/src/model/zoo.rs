//! The deterministic synthetic model zoo — EXACT mirror of
//! `python/compile/nets.py`. Seeds, shifts and shapes are the
//! cross-language contract; integration tests compare the simulator
//! against the AOT artifacts bit-for-bit and catch any drift.

use super::graph::{AddSpec, ConcatSpec, Graph, NodeOp};
use super::layer::{ConvSpec, LayerSpec, NetSpec, PoolSpec};
use super::tensor::Tensor;

fn conv(
    name: &str,
    k: usize,
    stride: usize,
    pad: usize,
    cin: usize,
    cout: usize,
    shift: u8,
    relu: bool,
    wseed: u32,
    bseed: u32,
) -> LayerSpec {
    LayerSpec::Conv(ConvSpec {
        name: name.into(),
        k,
        stride,
        pad,
        cin,
        cout,
        shift,
        relu,
        wseed,
        bseed,
        groups: 1,
    })
}

#[allow(clippy::too_many_arguments)]
fn gconv(
    name: &str,
    k: usize,
    stride: usize,
    pad: usize,
    cin: usize,
    cout: usize,
    shift: u8,
    relu: bool,
    wseed: u32,
    bseed: u32,
    groups: usize,
) -> LayerSpec {
    LayerSpec::Conv(ConvSpec {
        name: name.into(),
        k,
        stride,
        pad,
        cin,
        cout,
        shift,
        relu,
        wseed,
        bseed,
        groups,
    })
}

fn pool(name: &str, k: usize, stride: usize) -> LayerSpec {
    LayerSpec::Pool(PoolSpec::max(name, k, stride))
}

/// Tiny net for the quickstart example: one conv + one pool.
pub fn quicknet() -> NetSpec {
    let base = 5000;
    NetSpec {
        name: "quicknet".into(),
        in_h: 18,
        in_w: 18,
        in_c: 4,
        layers: vec![
            conv("conv1", 3, 1, 0, 4, 16, 9, true, base, base + 1),
            pool("pool1", 2, 2),
        ],
    }
}

/// Small face-detection CNN (the paper's Fig. 8 FPGA demo workload).
pub fn facenet() -> NetSpec {
    let base = 7000;
    NetSpec {
        name: "facenet".into(),
        in_h: 64,
        in_w: 64,
        in_c: 1,
        layers: vec![
            conv("conv1", 3, 1, 1, 1, 8, 8, true, base, base + 1),
            pool("pool1", 2, 2),
            conv("conv2", 3, 1, 1, 8, 16, 9, true, base + 2, base + 3),
            pool("pool2", 2, 2),
            conv("conv3", 3, 1, 1, 16, 32, 10, true, base + 4, base + 5),
            pool("pool3", 2, 2),
            conv("conv4", 3, 1, 0, 32, 16, 10, true, base + 6, base + 7),
            conv("score", 3, 1, 0, 16, 16, 10, false, base + 8, base + 9),
        ],
    }
}

/// AlexNet CONV+POOL stack (paper Table 1; FC layers out of scope).
pub fn alexnet() -> NetSpec {
    let base = 9000;
    NetSpec {
        name: "alexnet".into(),
        in_h: 227,
        in_w: 227,
        in_c: 3,
        layers: vec![
            conv("conv1", 11, 4, 0, 3, 96, 11, true, base, base + 1),
            pool("pool1", 3, 2),
            gconv("conv2", 5, 1, 2, 96, 256, 12, true, base + 2, base + 3, 2),
            pool("pool2", 3, 2),
            conv("conv3", 3, 1, 1, 256, 384, 12, true, base + 4, base + 5),
            gconv("conv4", 3, 1, 1, 384, 384, 12, true, base + 6, base + 7, 2),
            gconv("conv5", 3, 1, 1, 384, 256, 12, true, base + 8, base + 9, 2),
            pool("pool5", 3, 2),
        ],
    }
}

/// VGG-16 conv stack — all 3×3, the native shape of the CU array.
pub fn vgg16() -> NetSpec {
    let base = 11000u32;
    let cfg: &[(usize, usize)] = &[(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut layers = Vec::new();
    let mut cin = 3usize;
    let mut seed = base;
    for (bi, &(cout, reps)) in cfg.iter().enumerate() {
        let bi = bi + 1;
        for ri in 1..=reps {
            let shift = if cin == 3 { 8 } else { 11 };
            layers.push(conv(
                &format!("conv{bi}_{ri}"),
                3,
                1,
                1,
                cin,
                cout,
                shift,
                true,
                seed,
                seed + 1,
            ));
            seed += 2;
            cin = cout;
        }
        layers.push(pool(&format!("pool{bi}"), 2, 2));
    }
    NetSpec { name: "vgg16".into(), in_h: 224, in_w: 224, in_c: 3, layers }
}

/// Conv node helper for the graph nets (groups = 1).
#[allow(clippy::too_many_arguments)]
fn gnode(
    name: &str,
    k: usize,
    pad: usize,
    cin: usize,
    cout: usize,
    shift: u8,
    relu: bool,
    seed: u32,
) -> NodeOp {
    NodeOp::Conv(ConvSpec {
        name: name.into(),
        k,
        stride: 1,
        pad,
        cin,
        cout,
        shift,
        relu,
        wseed: seed,
        bseed: seed + 1,
        groups: 1,
    })
}

/// Residual edge net: two shortcut-add blocks around a pooled stem —
/// the ResNet-style topology the graph IR exists for. Each block's
/// second conv runs without ReLU; the Add requantizes the sum (shift 1,
/// ReLU), so the shortcut carries signal the conv path modulates.
pub fn edgenet() -> Graph {
    let base = 13000;
    let mut g = Graph::new("edgenet", 32, 32, 4);
    let n = |g: &mut Graph, op, ins: &[&str]| {
        g.add_node(op, ins).expect("edgenet is well-formed");
    };
    n(&mut g, gnode("stem", 3, 1, 4, 16, 9, true, base), &["input"]);
    n(&mut g, gnode("b1a", 3, 1, 16, 16, 10, true, base + 2), &["stem"]);
    n(&mut g, gnode("b1b", 3, 1, 16, 16, 10, false, base + 4), &["b1a"]);
    n(
        &mut g,
        NodeOp::Add(AddSpec { name: "add1".into(), shift: 1, relu: true }),
        &["b1b", "stem"],
    );
    n(&mut g, NodeOp::Pool(PoolSpec::max("pool1", 2, 2)), &["add1"]);
    n(&mut g, gnode("b2a", 3, 1, 16, 16, 10, true, base + 6), &["pool1"]);
    n(&mut g, gnode("b2b", 3, 1, 16, 16, 10, false, base + 8), &["b2a"]);
    n(
        &mut g,
        NodeOp::Add(AddSpec { name: "add2".into(), shift: 1, relu: true }),
        &["b2b", "pool1"],
    );
    n(&mut g, gnode("head", 3, 0, 16, 16, 10, false, base + 10), &["add2"]);
    g
}

/// Branch+concat stem (Inception-style): parallel 3×3 and 5×5 paths
/// over the input, channel-concatenated, then a pooled trunk. The 5×5
/// branch exercises kernel decomposition inside a branch.
pub fn widenet() -> Graph {
    let base = 15000;
    let mut g = Graph::new("widenet", 32, 32, 4);
    let n = |g: &mut Graph, op, ins: &[&str]| {
        g.add_node(op, ins).expect("widenet is well-formed");
    };
    n(&mut g, gnode("wa", 3, 1, 4, 16, 9, true, base), &["input"]);
    n(&mut g, gnode("wb", 5, 2, 4, 16, 11, true, base + 2), &["input"]);
    n(&mut g, NodeOp::Concat(ConcatSpec { name: "cat".into() }), &["wa", "wb"]);
    n(&mut g, NodeOp::Pool(PoolSpec::max("pool1", 2, 2)), &["cat"]);
    n(&mut g, gnode("mid", 3, 1, 32, 32, 11, true, base + 4), &["pool1"]);
    n(&mut g, gnode("head", 3, 0, 32, 16, 11, false, base + 6), &["mid"]);
    g
}

/// MobileNet-style head exerciser: conv trunk downsampled by *average*
/// pooling, finished by a global-average-pool head and a 1×1 scorer —
/// the avg/GAP coverage the decomposition planner benches need.
pub fn gapnet() -> Graph {
    let base = 17000;
    let mut g = Graph::new("gapnet", 32, 32, 4);
    let n = |g: &mut Graph, op, ins: &[&str]| {
        g.add_node(op, ins).expect("gapnet is well-formed");
    };
    n(&mut g, gnode("stem", 3, 1, 4, 16, 9, true, base), &["input"]);
    n(&mut g, NodeOp::Pool(PoolSpec::avg("apool1", 2, 2)), &["stem"]);
    n(&mut g, gnode("mid", 3, 1, 16, 32, 10, true, base + 2), &["apool1"]);
    n(&mut g, NodeOp::Pool(PoolSpec::avg("apool2", 2, 2)), &["mid"]);
    n(&mut g, gnode("deep", 3, 1, 32, 32, 11, true, base + 4), &["apool2"]);
    n(&mut g, NodeOp::Pool(PoolSpec::global_avg("gap", 8)), &["deep"]);
    n(&mut g, gnode("score", 1, 0, 32, 16, 11, false, base + 6), &["gap"]);
    g
}

/// Depthwise conv node: `c` independent 3×3 filters (groups = cin =
/// cout), the layer shape the packed dw fast path exists for.
fn dwnode(name: &str, stride: usize, c: usize, shift: u8, seed: u32) -> NodeOp {
    NodeOp::Conv(ConvSpec {
        name: name.into(),
        k: 3,
        stride,
        pad: 1,
        cin: c,
        cout: c,
        shift,
        relu: true,
        wseed: seed,
        bseed: seed + 1,
        groups: c,
    })
}

/// Pointwise 1×1 mixer node — the fusion partner of [`dwnode`].
fn pwnode(name: &str, cin: usize, cout: usize, shift: u8, relu: bool, seed: u32) -> NodeOp {
    NodeOp::Conv(ConvSpec {
        name: name.into(),
        k: 1,
        stride: 1,
        pad: 0,
        cin,
        cout,
        shift,
        relu,
        wseed: seed,
        bseed: seed + 1,
        groups: 1,
    })
}

/// MobileNet-class stack: a dense stem, two depthwise-separable blocks
/// (3×3 depthwise → 1×1 pointwise, the second depthwise strided), a
/// global-average-pool head and a 1×1 scorer. The primary workload of
/// the depthwise fast path and the fused DwPw lowering: channel widths
/// 16 and 32 exercise both the single-group (cn = 16) and two-group
/// packings, and every dw→pw pair is a legal fusion site.
pub fn mobilenet() -> Graph {
    let base = 19000;
    let mut g = Graph::new("mobilenet", 24, 24, 3);
    let n = |g: &mut Graph, op, ins: &[&str]| {
        g.add_node(op, ins).expect("mobilenet is well-formed");
    };
    n(&mut g, gnode("stem", 3, 1, 3, 16, 9, true, base), &["input"]);
    n(&mut g, dwnode("dw1", 1, 16, 7, base + 2), &["stem"]);
    n(&mut g, pwnode("pw1", 16, 32, 9, true, base + 4), &["dw1"]);
    n(&mut g, dwnode("dw2", 2, 32, 7, base + 6), &["pw1"]);
    n(&mut g, pwnode("pw2", 32, 32, 10, true, base + 8), &["dw2"]);
    n(&mut g, NodeOp::Pool(PoolSpec::global_avg("gap", 12)), &["pw2"]);
    n(&mut g, pwnode("score", 32, 16, 10, false, base + 10), &["gap"]);
    g
}

/// Look up a net by name.
pub fn by_name(name: &str) -> Option<NetSpec> {
    match name {
        "quicknet" => Some(quicknet()),
        "facenet" => Some(facenet()),
        "alexnet" => Some(alexnet()),
        "vgg16" => Some(vgg16()),
        _ => None,
    }
}

/// Look up any zoo net as a graph — linear nets convert via
/// [`Graph::from_net`], `edgenet`/`widenet` are graph-native.
pub fn graph_by_name(name: &str) -> Option<Graph> {
    match name {
        "edgenet" => Some(edgenet()),
        "widenet" => Some(widenet()),
        "gapnet" => Some(gapnet()),
        "mobilenet" => Some(mobilenet()),
        _ => by_name(name).map(|n| Graph::from_net(&n)),
    }
}

/// Resolve a comma-separated list of zoo net names (e.g.
/// `"edgenet,widenet,facenet"`) into named graphs — the input format of
/// the serving registry (`kn-stream serve --nets …`).
pub fn graphs_by_names(names: &str) -> anyhow::Result<Vec<(String, Graph)>> {
    let nets: Vec<(String, Graph)> = names
        .split(',')
        .map(|s| s.trim())
        .filter(|s| !s.is_empty())
        .map(|n| {
            graph_by_name(n).map(|g| (n.to_string(), g)).ok_or_else(|| {
                anyhow::anyhow!("unknown net '{n}' (have: {})", GRAPH_ALL.join(", "))
            })
        })
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(!nets.is_empty(), "no net names in '{names}'");
    Ok(nets)
}

/// Deterministic weighted round-robin traffic over named graphs: the
/// weights expand into a repeating slot pattern (`4:2:1` → AAAABBC…),
/// frame `i` takes slot `i % Σw` with a seed-`i` random image of that
/// net's input shape. The synthetic "mixed camera sources" stream
/// behind `kn-stream serve --mix` and the mixed-traffic serving bench —
/// one definition so the two can't drift apart.
pub fn mix_stream(
    nets: &[(String, Graph)],
    weights: &[usize],
    frames: usize,
) -> Vec<(String, Tensor)> {
    assert_eq!(nets.len(), weights.len(), "one mix weight per net");
    let mut pattern = Vec::new();
    for (i, &w) in weights.iter().enumerate() {
        for _ in 0..w {
            pattern.push(i);
        }
    }
    assert!(!pattern.is_empty(), "mix weights sum to zero");
    (0..frames)
        .map(|i| {
            let (name, g) = &nets[pattern[i % pattern.len()]];
            (name.clone(), Tensor::random_image(i as u32, g.in_h, g.in_w, g.in_c))
        })
        .collect()
}

pub const ALL: &[&str] = &["quicknet", "facenet", "alexnet", "vgg16"];

/// Every zoo net, including the graph-native topologies.
pub const GRAPH_ALL: &[&str] =
    &["quicknet", "facenet", "alexnet", "vgg16", "edgenet", "widenet", "gapnet", "mobilenet"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alexnet_matches_paper_table1_shapes() {
        let shapes = alexnet().shapes();
        let find = |n: &str| shapes.iter().find(|s| s.0 == n).map(|s| (s.1, s.2, s.3)).unwrap();
        assert_eq!(find("input"), (227, 227, 3));
        assert_eq!(find("conv1"), (55, 55, 96));
        assert_eq!(find("conv2"), (27, 27, 256));
        assert_eq!(find("conv3"), (13, 13, 384));
        assert_eq!(find("conv4"), (13, 13, 384));
        assert_eq!(find("conv5"), (13, 13, 256));
        assert_eq!(find("pool5"), (6, 6, 256));
    }

    #[test]
    fn alexnet_total_ops_about_1p3g() {
        // Table 1 total: 1.3 GOPs (sum of the five conv rows).
        let total = alexnet().total_ops() as f64;
        assert!((total - 1.33e9).abs() / 1.33e9 < 0.02, "total={total}");
    }

    #[test]
    fn facenet_output_shape() {
        assert_eq!(facenet().out_shape(), (4, 4, 16));
    }

    #[test]
    fn quicknet_output_shape() {
        assert_eq!(quicknet().out_shape(), (8, 8, 16));
    }

    #[test]
    fn vgg16_has_13_convs_and_ends_7x7() {
        let net = vgg16();
        let convs = net.layers.iter().filter(|l| matches!(l, LayerSpec::Conv(_))).count();
        assert_eq!(convs, 13);
        assert_eq!(net.out_shape(), (7, 7, 512));
    }

    #[test]
    fn zoo_lookup() {
        for n in ALL {
            assert!(by_name(n).is_some());
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn graphs_by_names_parses_lists() {
        let nets = graphs_by_names("edgenet, widenet,facenet").unwrap();
        let names: Vec<&str> = nets.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["edgenet", "widenet", "facenet"]);
        assert!(graphs_by_names("edgenet,nope").is_err());
        assert!(graphs_by_names("").is_err());
    }

    #[test]
    fn mix_stream_is_weighted_round_robin() {
        let nets = graphs_by_names("quicknet,edgenet").unwrap();
        let tagged = mix_stream(&nets, &[2, 1], 7);
        let names: Vec<&str> = tagged.iter().map(|(n, _)| n.as_str()).collect();
        let want =
            ["quicknet", "quicknet", "edgenet", "quicknet", "quicknet", "edgenet", "quicknet"];
        assert_eq!(names, want);
        for (n, f) in &tagged {
            let g = graph_by_name(n).unwrap();
            assert_eq!(f.shape(), g.in_shape(), "{n} frame shape");
        }
    }

    #[test]
    fn graph_zoo_lookup_and_shapes() {
        for n in GRAPH_ALL {
            let g = graph_by_name(n).unwrap_or_else(|| panic!("missing {n}"));
            g.validate().unwrap_or_else(|e| panic!("{n}: {e}"));
        }
        assert!(graph_by_name("nope").is_none());
        assert_eq!(edgenet().out_shape().unwrap(), (14, 14, 16));
        assert_eq!(widenet().out_shape().unwrap(), (14, 14, 16));
        assert_eq!(gapnet().out_shape().unwrap(), (1, 1, 16));
    }

    #[test]
    fn mobilenet_shapes_and_dw_structure() {
        let g = mobilenet();
        let shapes = g.validate().unwrap();
        let by = |n: &str| {
            g.nodes
                .iter()
                .position(|nd| nd.op.name() == n)
                .unwrap_or_else(|| panic!("missing {n}"))
        };
        assert_eq!(shapes[by("stem")], (24, 24, 16));
        assert_eq!(shapes[by("dw1")], (24, 24, 16));
        assert_eq!(shapes[by("pw1")], (24, 24, 32));
        assert_eq!(shapes[by("dw2")], (12, 12, 32));
        assert_eq!(shapes[by("pw2")], (12, 12, 32));
        assert_eq!(g.out_shape().unwrap(), (1, 1, 16));
        for dwn in ["dw1", "dw2"] {
            let NodeOp::Conv(c) = &g.nodes[by(dwn)].op else { panic!("{dwn} is a conv") };
            assert!(crate::compiler::decompose::dw_eligible(c), "{dwn}");
        }
    }
}
