//! Graph IR — the network representation the compiler and runtime
//! actually consume.
//!
//! `NetSpec`'s linear layer stack cannot express the branch/residual
//! topologies (shortcut adds, multi-path stems, channel concat) that
//! modern edge workloads need, and it forces the executor into a
//! layer-at-a-time view. The graph IR replaces it underneath everything:
//! named nodes with explicit input edges, evaluated/lowered in
//! topological order (enforced by construction — a node may only
//! reference earlier nodes or the graph input). Linear nets convert
//! losslessly via [`Graph::from_net`], so the whole `NetSpec` surface
//! keeps working.
//!
//! Two ops exist only at the graph level:
//!
//! * [`AddSpec`] — element-wise residual add with the same
//!   requantization output stage as a conv (round-half-up shift,
//!   saturate, optional ReLU); executed on-device by the `Add` ISA
//!   command through the SRAM adder path.
//! * [`ConcatSpec`] — channel concatenation; pure data movement, lowered
//!   to DMA copies into the consumer's canvas.
//!
//! [`Graph::validate`] is the single legality gate: it checks arity,
//! shape agreement and resource-representable configurations up front
//! and returns real `anyhow` errors — the compiler refuses to lower an
//! invalid graph instead of panicking mid-emission.

use super::layer::{ConvSpec, LayerSpec, NetSpec, PoolSpec};

/// Element-wise residual add: `out = requantize(a + b, shift, relu)`.
#[derive(Clone, Debug, PartialEq)]
pub struct AddSpec {
    pub name: String,
    /// Requantization right-shift applied to the int32 sum.
    pub shift: u8,
    pub relu: bool,
}

/// Channel concatenation of all inputs (H and W must agree).
#[derive(Clone, Debug, PartialEq)]
pub struct ConcatSpec {
    pub name: String,
}

/// One graph node's operation.
#[derive(Clone, Debug, PartialEq)]
pub enum NodeOp {
    Conv(ConvSpec),
    Pool(PoolSpec),
    Add(AddSpec),
    Concat(ConcatSpec),
}

impl NodeOp {
    pub fn name(&self) -> &str {
        match self {
            NodeOp::Conv(c) => &c.name,
            NodeOp::Pool(p) => &p.name,
            NodeOp::Add(a) => &a.name,
            NodeOp::Concat(c) => &c.name,
        }
    }

    /// Number of inputs this op requires (`None` = variadic, ≥ 2).
    fn arity(&self) -> Option<usize> {
        match self {
            NodeOp::Conv(_) | NodeOp::Pool(_) => Some(1),
            NodeOp::Add(_) => Some(2),
            NodeOp::Concat(_) => None,
        }
    }
}

/// Where a node's input comes from: the graph input or an earlier node.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeRef {
    Input,
    Node(usize),
}

/// A named operation with explicit input edges.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: NodeOp,
    pub inputs: Vec<NodeRef>,
}

impl Node {
    pub fn name(&self) -> &str {
        self.op.name()
    }
}

/// A whole network as a DAG. Nodes are stored in topological order
/// (guaranteed by the builder: edges may only point at earlier nodes or
/// the input); the graph output is `output`'s tensor.
#[derive(Clone, Debug)]
pub struct Graph {
    pub name: String,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub nodes: Vec<Node>,
    pub output: NodeRef,
}

impl Graph {
    pub fn new(name: &str, in_h: usize, in_w: usize, in_c: usize) -> Self {
        Self { name: name.into(), in_h, in_w, in_c, nodes: Vec::new(), output: NodeRef::Input }
    }

    pub fn in_shape(&self) -> (usize, usize, usize) {
        (self.in_h, self.in_w, self.in_c)
    }

    /// Resolve a node name to a reference. `"input"` is the graph input.
    pub fn resolve(&self, name: &str) -> anyhow::Result<NodeRef> {
        if name == "input" {
            return Ok(NodeRef::Input);
        }
        self.nodes
            .iter()
            .position(|n| n.name() == name)
            .map(NodeRef::Node)
            .ok_or_else(|| anyhow::anyhow!("graph {}: unknown node '{name}'", self.name))
    }

    /// Append a node fed by the named producers (`"input"` = the graph
    /// input). The new node becomes the graph output. Edges can only
    /// reach already-added nodes, so the node list stays topologically
    /// ordered by construction.
    pub fn add_node(&mut self, op: NodeOp, inputs: &[&str]) -> anyhow::Result<usize> {
        let resolved: Vec<NodeRef> =
            inputs.iter().map(|n| self.resolve(n)).collect::<anyhow::Result<_>>()?;
        anyhow::ensure!(
            op.name() != "input" && self.resolve(op.name()).is_err(),
            "graph {}: duplicate node name '{}'",
            self.name,
            op.name()
        );
        let idx = self.nodes.len();
        self.nodes.push(Node { op, inputs: resolved });
        self.output = NodeRef::Node(idx);
        Ok(idx)
    }

    /// Lossless conversion of a linear layer stack: layer *i* feeds
    /// layer *i+1*, the last layer is the output.
    pub fn from_net(net: &NetSpec) -> Graph {
        let mut g = Graph::new(&net.name, net.in_h, net.in_w, net.in_c);
        let mut prev = NodeRef::Input;
        for l in &net.layers {
            let op = match l {
                LayerSpec::Conv(c) => NodeOp::Conv(c.clone()),
                LayerSpec::Pool(p) => NodeOp::Pool(p.clone()),
            };
            let idx = g.nodes.len();
            g.nodes.push(Node { op, inputs: vec![prev] });
            prev = NodeRef::Node(idx);
        }
        g.output = prev;
        g
    }

    /// Shape of a reference, given the per-node shapes (as returned by
    /// [`Graph::validate`]).
    pub(crate) fn shape_of(
        &self,
        r: NodeRef,
        shapes: &[(usize, usize, usize)],
    ) -> (usize, usize, usize) {
        match r {
            NodeRef::Input => self.in_shape(),
            NodeRef::Node(i) => shapes[i],
        }
    }

    /// Validate the whole graph and return every node's output shape
    /// (indexed like `nodes`). This is the single legality gate the
    /// compiler and the reference evaluator rely on: after it passes,
    /// shape math cannot underflow and channel counts line up.
    pub fn validate(&self) -> anyhow::Result<Vec<(usize, usize, usize)>> {
        anyhow::ensure!(!self.nodes.is_empty(), "graph {}: no nodes", self.name);
        anyhow::ensure!(
            self.in_h > 0 && self.in_w > 0 && self.in_c > 0,
            "graph {}: degenerate input shape {}x{}x{}",
            self.name,
            self.in_h,
            self.in_w,
            self.in_c
        );
        let mut shapes: Vec<(usize, usize, usize)> = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let name = node.name();
            anyhow::ensure!(!name.is_empty() && name != "input", "node {i}: reserved/empty name");
            anyhow::ensure!(
                !self.nodes[..i].iter().any(|n| n.name() == name),
                "graph {}: duplicate node name '{name}'",
                self.name
            );
            for r in &node.inputs {
                if let NodeRef::Node(j) = r {
                    anyhow::ensure!(
                        *j < i,
                        "node {name}: input edge to node {j} is not topological"
                    );
                }
            }
            if let Some(want) = node.op.arity() {
                anyhow::ensure!(
                    node.inputs.len() == want,
                    "node {name}: needs {want} input(s), has {}",
                    node.inputs.len()
                );
            } else {
                anyhow::ensure!(
                    node.inputs.len() >= 2,
                    "concat {name}: needs >= 2 inputs, has {}",
                    node.inputs.len()
                );
            }
            let ins: Vec<(usize, usize, usize)> =
                node.inputs.iter().map(|r| self.shape_of(*r, &shapes)).collect();
            shapes.push(node_out_shape(&node.op, &ins)?);
        }
        if let NodeRef::Node(i) = self.output {
            anyhow::ensure!(
                i < self.nodes.len(),
                "graph {}: output node {i} out of range",
                self.name
            );
        }
        Ok(shapes)
    }

    /// Output shape of the whole graph (validated graphs only).
    pub fn out_shape(&self) -> anyhow::Result<(usize, usize, usize)> {
        let shapes = self.validate()?;
        Ok(self.shape_of(self.output, &shapes))
    }
}

/// Checked shape inference for one op — real error messages instead of
/// the historical `assert!`/underflow behaviour.
pub fn node_out_shape(
    op: &NodeOp,
    ins: &[(usize, usize, usize)],
) -> anyhow::Result<(usize, usize, usize)> {
    match op {
        NodeOp::Conv(c) => {
            let (h, w, cin) = ins[0];
            anyhow::ensure!(c.k >= 1 && c.stride >= 1, "conv {}: k/stride must be >= 1", c.name);
            anyhow::ensure!(
                cin == c.cin,
                "conv {}: cin {} != producer channels {}",
                c.name,
                c.cin,
                cin
            );
            anyhow::ensure!(
                c.groups >= 1 && c.cin % c.groups == 0 && c.cout % c.groups == 0,
                "conv {}: groups {} must divide cin {} and cout {}",
                c.name,
                c.groups,
                c.cin,
                c.cout
            );
            anyhow::ensure!(
                h + 2 * c.pad >= c.k && w + 2 * c.pad >= c.k,
                "conv {}: kernel {} exceeds padded input {}x{} (pad {})",
                c.name,
                c.k,
                h,
                w,
                c.pad
            );
            Ok((
                (h + 2 * c.pad - c.k) / c.stride + 1,
                (w + 2 * c.pad - c.k) / c.stride + 1,
                c.cout,
            ))
        }
        NodeOp::Pool(p) => {
            let (h, w, ch) = ins[0];
            match p.kind {
                crate::model::PoolKind::Max => anyhow::ensure!(
                    p.k == 2 || p.k == 3,
                    "pool {}: max window {} unsupported (the comparator does 2 or 3)",
                    p.name,
                    p.k
                ),
                crate::model::PoolKind::Avg => anyhow::ensure!(
                    (2..=63).contains(&p.k),
                    "pool {}: avg window {} outside 2..=63 (ISA 6-bit field)",
                    p.name,
                    p.k
                ),
            }
            anyhow::ensure!(
                (1..=63).contains(&p.stride),
                "pool {}: stride {} outside 1..=63 (ISA 6-bit field)",
                p.name,
                p.stride
            );
            anyhow::ensure!(
                h >= p.k && w >= p.k,
                "pool {}: window {} exceeds input {}x{}",
                p.name,
                p.k,
                h,
                w
            );
            Ok(((h - p.k) / p.stride + 1, (w - p.k) / p.stride + 1, ch))
        }
        NodeOp::Add(a) => {
            anyhow::ensure!(
                ins[0] == ins[1],
                "add {}: operand shapes differ: {:?} vs {:?}",
                a.name,
                ins[0],
                ins[1]
            );
            anyhow::ensure!(a.shift < 31, "add {}: shift {} out of range", a.name, a.shift);
            Ok(ins[0])
        }
        NodeOp::Concat(c) => {
            let (h, w, _) = ins[0];
            for (i, s) in ins.iter().enumerate() {
                anyhow::ensure!(
                    (s.0, s.1) == (h, w),
                    "concat {}: input {i} plane {}x{} != {}x{}",
                    c.name,
                    s.0,
                    s.1,
                    h,
                    w
                );
            }
            Ok((h, w, ins.iter().map(|s| s.2).sum()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(name: &str, k: usize, pad: usize, cin: usize, cout: usize) -> NodeOp {
        NodeOp::Conv(ConvSpec {
            name: name.into(),
            k,
            stride: 1,
            pad,
            cin,
            cout,
            shift: 9,
            relu: true,
            wseed: 1,
            bseed: 2,
            groups: 1,
        })
    }

    fn residual_graph() -> Graph {
        let mut g = Graph::new("res", 16, 16, 4);
        g.add_node(conv("stem", 3, 1, 4, 8), &["input"]).unwrap();
        g.add_node(conv("b1", 3, 1, 8, 8), &["stem"]).unwrap();
        g.add_node(
            NodeOp::Add(AddSpec { name: "add1".into(), shift: 1, relu: true }),
            &["b1", "stem"],
        )
        .unwrap();
        g
    }

    #[test]
    fn residual_graph_validates_and_shapes() {
        let g = residual_graph();
        let shapes = g.validate().unwrap();
        assert_eq!(shapes, vec![(16, 16, 8); 3]);
        assert_eq!(g.out_shape().unwrap(), (16, 16, 8));
    }

    #[test]
    fn concat_sums_channels() {
        let mut g = Graph::new("cat", 16, 16, 4);
        g.add_node(conv("a", 3, 1, 4, 8), &["input"]).unwrap();
        g.add_node(conv("b", 5, 2, 4, 16), &["input"]).unwrap();
        g.add_node(NodeOp::Concat(ConcatSpec { name: "cat".into() }), &["a", "b"]).unwrap();
        assert_eq!(g.out_shape().unwrap(), (16, 16, 24));
    }

    #[test]
    fn from_net_is_a_chain() {
        let net = crate::model::zoo::facenet();
        let g = Graph::from_net(&net);
        assert_eq!(g.nodes.len(), net.layers.len());
        assert_eq!(g.nodes[0].inputs, vec![NodeRef::Input]);
        for (i, n) in g.nodes.iter().enumerate().skip(1) {
            assert_eq!(n.inputs, vec![NodeRef::Node(i - 1)]);
        }
        let shapes = g.validate().unwrap();
        assert_eq!(*shapes.last().unwrap(), net.out_shape());
    }

    #[test]
    fn cin_mismatch_is_a_real_error() {
        let mut g = Graph::new("bad", 16, 16, 4);
        g.add_node(conv("c1", 3, 1, 8, 8), &["input"]).unwrap();
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("cin 8 != producer channels 4"), "{err}");
    }

    #[test]
    fn pool_window_underflow_is_a_real_error() {
        let mut g = Graph::new("bad", 2, 2, 1);
        g.add_node(NodeOp::Pool(PoolSpec::max("p", 3, 2)), &["input"]).unwrap();
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains("window 3 exceeds input 2x2"), "{err}");
    }

    #[test]
    fn avg_pool_windows_validate() {
        // global average pool over the whole 8x8 plane is legal...
        let mut g = Graph::new("gap", 8, 8, 4);
        g.add_node(NodeOp::Pool(PoolSpec::global_avg("gap", 8)), &["input"]).unwrap();
        assert_eq!(g.out_shape().unwrap(), (1, 1, 4));
        // ...a max pool of the same window is not (comparator does 2/3)
        let mut bad = Graph::new("bad", 8, 8, 4);
        bad.add_node(NodeOp::Pool(PoolSpec::max("p", 8, 8)), &["input"]).unwrap();
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("max window 8"), "{err}");
        // and an avg window beyond the 6-bit ISA field is rejected
        let mut wide = Graph::new("wide", 80, 80, 1);
        wide.add_node(NodeOp::Pool(PoolSpec::avg("p", 64, 64)), &["input"]).unwrap();
        let err = wide.validate().unwrap_err().to_string();
        assert!(err.contains("outside 2..=63"), "{err}");
    }

    #[test]
    fn add_shape_mismatch_rejected() {
        let mut g = Graph::new("bad", 16, 16, 4);
        g.add_node(conv("a", 3, 1, 4, 8), &["input"]).unwrap();
        g.add_node(conv("b", 3, 0, 4, 8), &["input"]).unwrap();
        g.add_node(
            NodeOp::Add(AddSpec { name: "add".into(), shift: 0, relu: false }),
            &["a", "b"],
        )
        .unwrap();
        assert!(g.validate().is_err());
    }

    #[test]
    fn duplicate_names_and_unknown_edges_rejected() {
        let mut g = Graph::new("bad", 16, 16, 4);
        g.add_node(conv("a", 3, 1, 4, 8), &["input"]).unwrap();
        assert!(g.add_node(conv("a", 3, 1, 8, 8), &["a"]).is_err());
        assert!(g.add_node(conv("b", 3, 1, 8, 8), &["nope"]).is_err());
    }

    #[test]
    fn arity_enforced() {
        let mut g = Graph::new("bad", 8, 8, 2);
        g.add_node(conv("a", 3, 1, 2, 4), &["input"]).unwrap();
        g.add_node(
            NodeOp::Concat(ConcatSpec { name: "cat".into() }),
            &["a"],
        )
        .unwrap();
        let err = g.validate().unwrap_err().to_string();
        assert!(err.contains(">= 2 inputs"), "{err}");
    }
}
