//! Network descriptions, tensors, the deterministic synthetic model zoo
//! (shared with `python/compile/nets.py`), and a straightforward scalar
//! reference implementation used as the in-crate oracle.
//!
//! Networks have two surfaces: the historical linear [`NetSpec`] layer
//! stack, and the [`graph`] IR (named nodes, explicit edges, residual
//! Add / channel Concat) that the compiler and runtime consume. Linear
//! nets convert losslessly via [`Graph::from_net`].

pub mod graph;
pub mod layer;
pub mod reference;
pub mod tensor;
pub mod zoo;

pub use graph::{AddSpec, ConcatSpec, Graph, Node, NodeOp, NodeRef};
pub use layer::{ConvSpec, LayerSpec, NetSpec, PoolKind, PoolSpec};
pub use tensor::Tensor;
