//! Network descriptions, tensors, the deterministic synthetic model zoo
//! (shared with `python/compile/nets.py`), and a straightforward scalar
//! reference implementation used as the in-crate oracle.

pub mod layer;
pub mod reference;
pub mod tensor;
pub mod zoo;

pub use layer::{ConvSpec, LayerSpec, NetSpec, PoolSpec};
pub use tensor::Tensor;
