//! Layer and network descriptions + the static cost model behind the
//! paper's Table 1 (ops & storage per layer).

use crate::util::rng;

/// Convolution layer spec — mirror of `python/compile/nets.py::ConvSpec`.
#[derive(Clone, Debug, PartialEq)]
pub struct ConvSpec {
    pub name: String,
    /// Kernel size K (K×K). K>3 runs via kernel decomposition on the 3×3 CU.
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub cin: usize,
    pub cout: usize,
    /// Requantization right-shift (power-of-two output scale).
    pub shift: u8,
    pub relu: bool,
    pub wseed: u32,
    pub bseed: u32,
    /// Grouped convolution (original AlexNet conv2/4/5). Each group is an
    /// independent conv over cin/groups -> cout/groups channels.
    pub groups: usize,
}

/// Which reduction the pooling module performs over each window.
///
/// * `Max` — the paper's §4.3 comparator path (window 2 or 3).
/// * `Avg` — accumulate-and-divide: the comparator is swapped for an
///   adder with the same feedback register, and the emit stage divides
///   by the window area with round-half-up (the same rounding
///   convention as the conv requantizer). Because the adder serializes
///   arbitrary window sizes, `Avg` also covers the global-average-pool
///   head (`k == plane size`, one output pixel per channel).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Pooling layer spec (max window 2/3, avg window up to the ISA's
/// 6-bit field — including a whole-plane global average pool).
#[derive(Clone, Debug, PartialEq)]
pub struct PoolSpec {
    pub name: String,
    pub k: usize,
    pub stride: usize,
    pub kind: PoolKind,
}

impl PoolSpec {
    pub fn max(name: &str, k: usize, stride: usize) -> Self {
        Self { name: name.into(), k, stride, kind: PoolKind::Max }
    }

    pub fn avg(name: &str, k: usize, stride: usize) -> Self {
        Self { name: name.into(), k, stride, kind: PoolKind::Avg }
    }

    /// Global average pool over an `n × n` plane: one output pixel per
    /// channel (MobileNet-style classification heads).
    pub fn global_avg(name: &str, n: usize) -> Self {
        Self::avg(name, n, n)
    }
}

#[derive(Clone, Debug, PartialEq)]
pub enum LayerSpec {
    Conv(ConvSpec),
    Pool(PoolSpec),
}

impl LayerSpec {
    pub fn name(&self) -> &str {
        match self {
            LayerSpec::Conv(c) => &c.name,
            LayerSpec::Pool(p) => &p.name,
        }
    }

    /// Output (H, W, C) for an input (H, W, C).
    pub fn out_shape(&self, (h, w, c): (usize, usize, usize)) -> (usize, usize, usize) {
        match self {
            LayerSpec::Conv(s) => {
                assert_eq!(c, s.cin, "layer {}: cin mismatch", s.name);
                (
                    (h + 2 * s.pad - s.k) / s.stride + 1,
                    (w + 2 * s.pad - s.k) / s.stride + 1,
                    s.cout,
                )
            }
            LayerSpec::Pool(s) => ((h - s.k) / s.stride + 1, (w - s.k) / s.stride + 1, c),
        }
    }
}

impl ConvSpec {
    /// Deterministic weights in (K, K, Cin, Cout) C-order — identical
    /// bytes to `python/compile/model.py::layer_params`.
    pub fn weights(&self) -> Vec<i16> {
        rng::weight_tensor(
            self.wseed,
            self.k * self.k * (self.cin / self.groups) * self.cout,
            W_LO,
            W_HI,
        )
    }
    pub fn biases(&self) -> Vec<i32> {
        rng::bias_tensor(self.bseed, self.cout, B_LO, B_HI)
    }
    /// MAC count for an output of (ho, wo).
    pub fn macs(&self, ho: usize, wo: usize) -> u64 {
        (ho * wo * self.cout) as u64 * (self.k * self.k * self.cin / self.groups) as u64
    }
    /// Paper-style op count (1 MAC = 2 ops: multiply + add).
    pub fn ops(&self, ho: usize, wo: usize) -> u64 {
        2 * self.macs(ho, wo)
    }
    pub fn weight_bytes(&self) -> usize {
        self.k * self.k * (self.cin / self.groups) * self.cout * 2
    }
}

/// Shared weight value ranges (contract with `python/compile/nets.py`).
pub const W_LO: i32 = -128;
pub const W_HI: i32 = 127;
pub const B_LO: i32 = -1024;
pub const B_HI: i32 = 1023;

/// A whole network: input shape + layer stack.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub name: String,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub layers: Vec<LayerSpec>,
}

/// Per-layer static costs — the rows of the paper's Table 1.
#[derive(Clone, Debug)]
pub struct LayerCost {
    pub name: String,
    pub in_shape: (usize, usize, usize),
    pub out_shape: (usize, usize, usize),
    /// Paper counts ops only for CONV layers (Table 1 sums to 1.3 G).
    pub ops: u64,
    pub in_bytes: usize,
    pub out_bytes: usize,
    pub weight_bytes: usize,
}

impl NetSpec {
    pub fn in_shape(&self) -> (usize, usize, usize) {
        (self.in_h, self.in_w, self.in_c)
    }

    /// Validate the layer stack (shape agreement, pool-window bounds,
    /// group divisibility) with real error messages — the graph IR's
    /// checker applied to the linear chain. `compile_net` runs this
    /// before lowering, so an ill-formed spec errors instead of
    /// panicking (or underflowing `(h - k)`) mid-emission.
    pub fn validate(&self) -> anyhow::Result<()> {
        crate::model::graph::Graph::from_net(self).validate()?;
        Ok(())
    }

    /// Shapes of every layer output, input first (mirror of
    /// `nets.net_shapes`).
    pub fn shapes(&self) -> Vec<(String, usize, usize, usize)> {
        let mut out = vec![("input".to_string(), self.in_h, self.in_w, self.in_c)];
        let mut s = self.in_shape();
        for l in &self.layers {
            s = l.out_shape(s);
            out.push((l.name().to_string(), s.0, s.1, s.2));
        }
        out
    }

    pub fn out_shape(&self) -> (usize, usize, usize) {
        let mut s = self.in_shape();
        for l in &self.layers {
            s = l.out_shape(s);
        }
        s
    }

    /// Table-1 style cost rows for every layer.
    pub fn costs(&self) -> Vec<LayerCost> {
        let mut rows = Vec::new();
        let mut shape = self.in_shape();
        for l in &self.layers {
            let out = l.out_shape(shape);
            let (ops, wbytes) = match l {
                LayerSpec::Conv(c) => (c.ops(out.0, out.1), c.weight_bytes()),
                LayerSpec::Pool(_) => (0, 0),
            };
            rows.push(LayerCost {
                name: l.name().to_string(),
                in_shape: shape,
                out_shape: out,
                ops,
                in_bytes: shape.0 * shape.1 * shape.2 * 2,
                out_bytes: out.0 * out.1 * out.2 * 2,
                weight_bytes: wbytes,
            });
            shape = out;
        }
        rows
    }

    /// Total CONV ops (the paper's "1.3 G" for AlexNet).
    pub fn total_ops(&self) -> u64 {
        self.costs().iter().map(|c| c.ops).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(k: usize, stride: usize, pad: usize, cin: usize, cout: usize) -> LayerSpec {
        LayerSpec::Conv(ConvSpec {
            name: "c".into(),
            k,
            stride,
            pad,
            cin,
            cout,
            shift: 8,
            relu: true,
            wseed: 1,
            bseed: 2,
            groups: 1,
        })
    }

    #[test]
    fn conv_shapes() {
        assert_eq!(conv(11, 4, 0, 3, 96).out_shape((227, 227, 3)), (55, 55, 96));
        assert_eq!(conv(5, 1, 2, 96, 256).out_shape((27, 27, 96)), (27, 27, 256));
        assert_eq!(conv(3, 1, 1, 256, 384).out_shape((13, 13, 256)), (13, 13, 384));
    }

    #[test]
    fn pool_shapes() {
        let p = LayerSpec::Pool(PoolSpec::max("p", 3, 2));
        assert_eq!(p.out_shape((55, 55, 96)), (27, 27, 96));
        assert_eq!(p.out_shape((13, 13, 256)), (6, 6, 256));
        // avg pooling has the same shape law, incl. the global head
        let a = LayerSpec::Pool(PoolSpec::avg("a", 2, 2));
        assert_eq!(a.out_shape((8, 8, 16)), (4, 4, 16));
        let g = LayerSpec::Pool(PoolSpec::global_avg("g", 7));
        assert_eq!(g.out_shape((7, 7, 512)), (1, 1, 512));
    }

    #[test]
    fn alexnet_conv1_ops_match_table1() {
        // Table 1 row 1: 211 M ops
        if let LayerSpec::Conv(c) = conv(11, 4, 0, 3, 96) {
            let ops = c.ops(55, 55);
            assert_eq!(ops, 2 * 55 * 55 * 96 * 11 * 11 * 3);
            assert!((ops as f64 - 211e6).abs() / 211e6 < 0.01, "ops={ops}");
        }
    }

    #[test]
    fn netspec_validate_catches_bad_stacks() {
        let ok = NetSpec {
            name: "ok".into(),
            in_h: 8,
            in_w: 8,
            in_c: 3,
            layers: vec![conv(3, 1, 1, 3, 8)],
        };
        assert!(ok.validate().is_ok());
        let cin_mismatch = NetSpec { layers: vec![conv(3, 1, 1, 4, 8)], ..ok.clone() };
        let err = cin_mismatch.validate().unwrap_err().to_string();
        assert!(err.contains("cin 4"), "{err}");
        let pool_underflow = NetSpec {
            layers: vec![LayerSpec::Pool(PoolSpec::max("p", 3, 2))],
            in_h: 2,
            in_w: 2,
            ..ok
        };
        assert!(pool_underflow.validate().is_err());
    }

    #[test]
    fn weights_deterministic_and_sized() {
        if let LayerSpec::Conv(c) = conv(3, 1, 1, 4, 8) {
            let w = c.weights();
            assert_eq!(w.len(), 3 * 3 * 4 * 8);
            assert_eq!(w, c.weights());
            assert_eq!(c.weight_bytes(), w.len() * 2);
        }
    }
}
