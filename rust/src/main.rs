//! `kn-stream` — CLI for the streaming-CNN-accelerator reproduction.
//!
//! Subcommands:
//!   run      run a zoo net on the simulated accelerator, report
//!            cycles / utilization / energy at a DVFS point
//!   serve    streaming frame server (coordinator) over synthetic camera
//!   verify   golden check: simulator output vs PJRT-executed artifact
//!   plan     print the decomposition plan of every conv layer
//!   lint     static schedule analyzer: ISA lint + segment-DAG race
//!            detection over the compiled command stream
//!   info     chip configuration, area and DVFS summary

use kn_stream::analysis::{analyze, lint_timing};
use kn_stream::compiler::{compile_graph_with_options, CompileOptions, NetRunner};
use kn_stream::coordinator::{
    AdmissionMode, AdmissionPolicy, Coordinator, CoordinatorConfig, FaultPlan,
};
use kn_stream::energy::{AreaModel, EnergyModel, OperatingPoint};
use kn_stream::model::{zoo, Tensor};
use kn_stream::obs::{prom, Obs, TraceSink};
use kn_stream::planner::{plan_graph, plan_graph_objective, PlanObjective, PlanPolicy};
use kn_stream::runtime::Golden;
use kn_stream::util::bench::Table;
use kn_stream::util::cli::Cli;
use kn_stream::util::stats::eng;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (sub, rest) = match args.split_first() {
        Some((s, r)) if !s.starts_with("--") => (s.clone(), r.to_vec()),
        _ => {
            print_usage();
            return Ok(());
        }
    };
    match sub.as_str() {
        "run" => cmd_run(rest),
        "serve" => cmd_serve(rest),
        "verify" => cmd_verify(rest),
        "plan" => cmd_plan(rest),
        "lint" => cmd_lint(rest),
        "info" => cmd_info(),
        other => {
            print_usage();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_usage() {
    println!(
        "kn-stream — streaming CNN accelerator (Du et al. 2017) reproduction\n\n\
         USAGE: kn-stream <run|serve|verify|plan|lint|info> [options]\n\
         Try `kn-stream run --help`."
    );
}

fn net_arg(name: &str) -> anyhow::Result<kn_stream::model::NetSpec> {
    zoo::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown net '{name}' (have: {})", zoo::ALL.join(", ")))
}

fn graph_arg(name: &str) -> anyhow::Result<kn_stream::model::Graph> {
    zoo::graph_by_name(name).ok_or_else(|| {
        anyhow::anyhow!("unknown net '{name}' (have: {})", zoo::GRAPH_ALL.join(", "))
    })
}

fn cmd_run(args: Vec<String>) -> anyhow::Result<()> {
    let mut cli = Cli::new("kn-stream run", "run a net on the simulated accelerator");
    cli.opt("net", "facenet", "zoo net (quicknet|facenet|alexnet|vgg16|edgenet|widenet|gapnet)")
        .opt("frames", "1", "number of frames")
        .opt("freq", "500", "clock in MHz (20..500, sets VDD by DVFS law)")
        .opt("seed", "1", "input frame seed")
        .opt("plan-policy", "heuristic", "decomposition planner (heuristic|min-traffic|dag-aware)")
        .opt("objective", "min-traffic", "objective (min-traffic|min-latency|min-energy|min-edp)")
        .opt("slo-ms", "0", "latency SLO for --objective min-energy (0 = none)")
        .opt("trace-out", "", "write a Perfetto-loadable Chrome trace of the run to this path");
    let m = cli.parse_from(args)?;
    let net = graph_arg(m.get("net"))?;
    let op = OperatingPoint::for_freq(m.get_f64("freq"));
    let policy = PlanPolicy::parse(m.get("plan-policy"))?;
    let objective =
        PlanObjective::parse(m.get("objective"), m.get_f64("freq"), m.get_f64("slo-ms"))?;
    let runner = NetRunner::from_graph_with_policy_objective(&net, policy, objective)?;
    let trace_out = m.get("trace-out").to_string();
    let sink = (!trace_out.is_empty()).then(TraceSink::new);
    let energy = EnergyModel::default();
    let ov = &runner.compiled.output;
    println!("net={} in={:?} out={:?}  @ {:.0} MHz / {:.2} V", net.name, net.in_shape(),
             (ov.h, ov.w, ov.c), op.freq_mhz, op.vdd);
    for i in 0..m.get_u64("frames") {
        let seed = m.get_u64("seed") as u32 + i as u32;
        let frame = Tensor::random_image(seed, net.in_h, net.in_w, net.in_c);
        let t0 = std::time::Instant::now();
        let (out, stats) = match &sink {
            None => runner.run_frame(&frame)?,
            Some(sink) => {
                // Traced runs go through the parallel segment-DAG
                // scheduler (2 tile workers) — the sequential path has
                // no trace points. Outputs and stats are bit-identical.
                let target = sink.target();
                let mut outs = runner.run_frames_pipelined_ref_traced(&[&frame], 2, 1, &target)?;
                sink.ingest(&net.name, &runner.compiled, 0, &[i], &target.take());
                outs.pop().expect("one frame in, one result out")
            }
        };
        let dev_ms = stats.cycles as f64 * op.cycle_s() * 1e3;
        let e = energy.energy(&stats, op);
        println!(
            "frame {i}: out{:?} | {} cycles = {:.2} ms on-device ({:.1} fps) | util {:.2} \
             (lane {:.2}) | {}OPS eff | {:.2} mJ | sim wall {:.0} ms",
            out.shape(),
            stats.cycles,
            dev_ms,
            1e3 / dev_ms,
            stats.utilization(),
            stats.lane_utilization(),
            eng(stats.ops() as f64 / (stats.cycles as f64 * op.cycle_s())),
            e.total_j() * 1e3,
            t0.elapsed().as_secs_f64() * 1e3,
        );
    }
    if let Some(sink) = &sink {
        sink.write(&trace_out)?;
        println!("trace: {} segment span(s) → {trace_out} (load in https://ui.perfetto.dev)",
                 sink.spans().len());
    }
    Ok(())
}

/// Parse a `--mix` ratio string like `4:2:1` into per-net weights.
fn parse_mix(mix: &str, nets: usize) -> anyhow::Result<Vec<usize>> {
    if mix.is_empty() {
        return Ok(vec![1; nets]);
    }
    let weights: Vec<usize> = mix
        .split(':')
        .map(|w| w.trim().parse::<usize>().map_err(|_| anyhow::anyhow!("bad mix weight '{w}'")))
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(
        weights.len() == nets,
        "--mix has {} weights but --nets has {} nets",
        weights.len(),
        nets
    );
    anyhow::ensure!(weights.iter().sum::<usize>() > 0, "--mix weights sum to zero");
    Ok(weights)
}

fn cmd_serve(args: Vec<String>) -> anyhow::Result<()> {
    let mut cli = Cli::new("kn-stream serve", "streaming frame server over synthetic camera");
    cli.opt("net", "facenet", "zoo net (incl. graph nets edgenet|widenet)")
        .opt("nets", "", "serving registry: comma-separated nets (overrides --net)")
        .opt("mix", "", "traffic mix over --nets as ratios, e.g. 4:2:1 (default uniform)")
        .opt("frames", "64", "frames to stream")
        .opt("workers", "1", "accelerator instances")
        .opt("queue", "4", "bounded queue depth")
        .opt("tile-workers", "1", "parallel segment-DAG threads per frame")
        .opt("pipeline-depth", "1", "same-net frames per worker window (cross-frame pipelining)")
        .opt("admit-mb", "0", "in-flight DRAM-image budget in MB (0 = unbounded)")
        .opt("admit-mode", "block", "over-budget behavior: block|reject")
        .opt("plan-policy", "heuristic", "decomposition planner (heuristic|min-traffic|dag-aware)")
        .opt("objective", "min-traffic", "objective (min-traffic|min-latency|min-energy|min-edp)")
        .opt("freq", "500", "clock in MHz")
        .opt("chips", "1", "independent chip fault domains (frames route least-loaded)")
        .opt("chip-freqs", "", "per-chip MHz overrides, comma-separated (default: --freq)")
        .opt("deadline-ms", "0", "per-attempt service deadline in ms (0 = none)")
        .opt("max-retries", "2", "re-dispatches per frame before retries-exhausted")
        .opt("chaos-seed", "", "deterministic fault-injection seed (empty = no faults)")
        .opt("trace-out", "", "write a Perfetto-loadable Chrome trace of the serve to this path")
        .opt("metrics-out", "", "write Prometheus text exposition of the run to this path")
        .opt("event-log", "", "write the structured fleet event log (JSONL) to this path");
    let m = cli.parse_from(args)?;
    let list = if m.get("nets").is_empty() { m.get("net") } else { m.get("nets") };
    let nets = zoo::graphs_by_names(list)?;
    let weights = parse_mix(m.get("mix"), nets.len())?;
    let admit_mb = m.get_f64("admit-mb");
    let admission = AdmissionPolicy {
        max_dram_bytes: if admit_mb > 0.0 { (admit_mb * 1e6) as usize } else { usize::MAX },
        mode: match m.get("admit-mode") {
            "block" => AdmissionMode::Block,
            "reject" => AdmissionMode::Reject,
            other => anyhow::bail!("unknown --admit-mode '{other}' (block|reject)"),
        },
    };
    let op = OperatingPoint::for_freq(m.get_f64("freq"));
    let chips = m.get_usize("chips").max(1);
    let chip_ops: Vec<OperatingPoint> =
        m.get_f64_list("chip-freqs").iter().map(|&f| OperatingPoint::for_freq(f)).collect();
    anyhow::ensure!(
        chip_ops.len() <= chips,
        "--chip-freqs lists {} points for {chips} chip(s)",
        chip_ops.len()
    );
    let frames = m.get_usize("frames");
    let fault_plan = match m.get("chaos-seed") {
        "" => FaultPlan::none(),
        s => {
            let seed: u32 = s.parse().map_err(|_| anyhow::anyhow!("bad --chaos-seed '{s}'"))?;
            let plan = FaultPlan::seeded(seed, chips, frames);
            for e in plan.events() {
                println!("chaos: chip {} frame {} — {}", e.chip, e.frame, e.kind.describe());
            }
            plan
        }
    };
    let deadline_ms = m.get_f64("deadline-ms");
    let objective = PlanObjective::parse(m.get("objective"), m.get_f64("freq"), deadline_ms)?;
    let trace_out = m.get("trace-out").to_string();
    let metrics_out = m.get("metrics-out").to_string();
    let event_log = m.get("event-log").to_string();
    // The event log also feeds the exposition's event counters, so
    // --metrics-out implies collecting it.
    let obs = Obs::with(!trace_out.is_empty(), !event_log.is_empty() || !metrics_out.is_empty());
    let cfg = CoordinatorConfig {
        workers: m.get_usize("workers"),
        chips,
        queue_depth: m.get_usize("queue"),
        tile_workers: m.get_usize("tile-workers"),
        pipeline_depth: m.get_usize("pipeline-depth"),
        op,
        chip_ops,
        admission,
        plan_policy: PlanPolicy::parse(m.get("plan-policy"))?,
        objective,
        deadline: (deadline_ms > 0.0)
            .then(|| std::time::Duration::from_micros((deadline_ms * 1e3) as u64)),
        max_retries: m.get_usize("max-retries") as u32,
        fault_plan,
        obs: obs.clone(),
        ..CoordinatorConfig::default()
    };

    let tagged = zoo::mix_stream(&nets, &weights, frames);
    // min-energy serving with an SLO picks its own fleet DVFS point
    // from measured probe frames (unless per-chip points were forced).
    let auto_op = matches!(objective, PlanObjective::MinEnergy { .. })
        && deadline_ms > 0.0
        && m.get("chip-freqs").is_empty();
    let (coord, op) = if auto_op {
        let (coord, picks) = Coordinator::start_registry_auto_op(nets, cfg, deadline_ms)?;
        let mut t = Table::new(
            "DVFS auto-pick (min energy within SLO, per net)",
            &["net", "cycles", "MHz", "VDD", "lat ms", "mJ", "PEAK mJ", "SLO met"],
        );
        for p in &picks {
            t.row(&[
                p.net.clone(),
                format!("{}", p.cycles),
                format!("{:.0}", p.op.freq_mhz),
                format!("{:.2}", p.op.vdd),
                format!("{:.2}", p.latency_ms),
                format!("{:.3}", p.energy_j * 1e3),
                format!("{:.3}", p.peak_energy_j * 1e3),
                if p.slo_met { "yes".into() } else { "NO (PEAK fallback)".into() },
            ]);
        }
        t.print();
        let op = coord.op();
        println!("fleet operating point: {:.0} MHz / {:.2} V", op.freq_mhz, op.vdd);
        (coord, op)
    } else {
        (Coordinator::start_registry(nets, cfg)?, op)
    };
    let rep = coord.run_mix(tagged)?;
    let chip_loads = coord.chip_loads();
    let energy = EnergyModel::default();
    let q3 = |h: &kn_stream::util::stats::Histogram, scale: f64, prec: usize| {
        format!(
            "{:.prec$}/{:.prec$}/{:.prec$}",
            h.quantile(0.5) * scale,
            h.quantile(0.95) * scale,
            h.quantile(0.99) * scale,
        )
    };
    let mut t = Table::new(
        "per-net serving report",
        &["net", "frames", "errors", "device fps", "lat p50/p95/p99 ms",
          "q-wait p50/p95/p99 µs", "mJ/frame"],
    );
    for (name, nm) in &rep.per_net {
        let e = energy.energy(&nm.totals, op);
        t.row(&[
            name.clone(),
            format!("{}", nm.frames),
            format!("{}", nm.errors),
            format!("{:.1}", nm.device_fps()),
            q3(&nm.dev_lat_us, 1e-3, 2),
            q3(&nm.queue_wait_us, 1.0, 0),
            format!("{:.3}", e.total_j() / nm.frames.max(1) as f64 * 1e3),
        ]);
    }
    t.print();
    if !rep.per_chip.is_empty() {
        let mut t = Table::new(
            "per-chip fault-domain report",
            &["chip", "health", "MHz", "frames", "errors", "retries", "failovers", "ddl-miss",
              "lat p50/p95/p99 ms", "q-wait p50/p95/p99 µs"],
        );
        for (c, cm) in rep.per_chip.iter().enumerate() {
            let health =
                rep.chip_health.get(c).map_or("?", |h| h.name());
            t.row(&[
                format!("{c}"),
                health.to_string(),
                format!("{:.0}", cm.op.freq_mhz),
                format!("{}", cm.frames),
                format!("{}", cm.errors),
                format!("{}", cm.retries),
                format!("{}", cm.failovers),
                format!("{}", cm.deadline_misses),
                q3(&cm.dev_lat_us, 1e-3, 2),
                q3(&cm.queue_wait_us, 1.0, 0),
            ]);
        }
        t.print();
    }
    println!("aggregate: {}", rep.aggregate.report(&energy));
    coord.stop();
    if let Some(sink) = &obs.trace {
        sink.write(&trace_out)?;
        println!(
            "trace: {} span(s), {} window(s), {} instant(s) → {trace_out}",
            sink.spans().len(),
            sink.windows().len(),
            sink.instants().len()
        );
    }
    if !metrics_out.is_empty() {
        std::fs::write(&metrics_out, prom::render(&rep, obs.log.as_deref(), &chip_loads))?;
        println!("metrics: Prometheus exposition → {metrics_out}");
    }
    if let Some(log) = &obs.log {
        if !event_log.is_empty() {
            log.write(&event_log)?;
            println!("events: {} fleet event(s) → {event_log}", log.len());
        }
    }
    Ok(())
}

fn cmd_verify(args: Vec<String>) -> anyhow::Result<()> {
    let mut cli = Cli::new("kn-stream verify", "simulator vs PJRT golden artifacts (bit-exact)");
    cli.opt("net", "all", "net to verify (or 'all')").opt("seed", "123", "frame seed");
    let m = cli.parse_from(args)?;
    let mut golden = Golden::load_default()?;
    let nets: Vec<String> = if m.get("net") == "all" {
        golden.net_artifacts().iter().map(|a| a.net.clone()).collect()
    } else {
        vec![m.get("net").to_string()]
    };
    let mut failed = 0;
    for name in nets {
        let net = net_arg(&name)?;
        let art = format!("{name}_fwd");
        let frame = Tensor::random_image(m.get_u64("seed") as u32, net.in_h, net.in_w, net.in_c);
        let want = golden.run(&art, &frame)?;
        let runner = NetRunner::new(&net)?;
        let (got, stats) = runner.run_frame(&frame)?;
        if got == want {
            println!("{name}: OK — simulator == PJRT artifact bit-for-bit \
                      ({} px, {} cycles, util {:.2})", got.data.len(), stats.cycles,
                     stats.utilization());
        } else {
            let diff = got.data.iter().zip(&want.data).filter(|(a, b)| a != b).count();
            println!("{name}: FAIL — {diff}/{} px differ", got.data.len());
            failed += 1;
        }
    }
    anyhow::ensure!(failed == 0, "{failed} net(s) failed golden verification");
    Ok(())
}

fn cmd_plan(args: Vec<String>) -> anyhow::Result<()> {
    let mut cli = Cli::new("kn-stream plan", "print decomposition plans");
    cli.opt("net", "alexnet", "zoo net (incl. graph nets edgenet|widenet|gapnet)")
        .opt("policy", "dag-aware", "planner for --optimize (heuristic|min-traffic|dag-aware)")
        .opt("objective", "min-traffic", "objective (min-traffic|min-latency|min-energy|min-edp)")
        .opt("freq", "500", "operating point for latency/energy objectives, MHz")
        .opt("slo-ms", "0", "latency SLO for --objective min-energy (0 = none)")
        .opt("seed", "1", "frame seed for the --optimize measurement run");
    cli.flag("dump-graph", "print the compiled segment DAG as Graphviz DOT and exit");
    cli.flag(
        "optimize",
        "run the decomposition planner: per-node predicted vs measured DRAM bytes + policy diff",
    );
    let m = cli.parse_from(args)?;
    let net = graph_arg(m.get("net"))?;
    if m.get_flag("optimize") {
        let policy = PlanPolicy::parse(m.get("policy"))?;
        let objective =
            PlanObjective::parse(m.get("objective"), m.get_f64("freq"), m.get_f64("slo-ms"))?;
        let op = OperatingPoint::for_freq(m.get_f64("freq"));
        return cmd_plan_optimize(&net, policy, objective, op, m.get_u64("seed") as u32);
    }
    let runner = NetRunner::from_graph(&net)?;
    if m.get_flag("dump-graph") {
        print!("{}", runner.compiled.segments_dot());
        return Ok(());
    }
    println!("{}: {} commands, {} segments, DRAM image {:.1} MB", net.name,
             runner.compiled.program.len(), runner.compiled.segments.len(),
             runner.compiled.dram_px as f64 * 2.0 / 1e6);
    println!("{:<10} {:>6} {:>8} {:>8} {:>8} {:>10} {:>10}",
             "layer", "grid", "c-grps", "m-tiles", "tiles", "in-tile", "sram");
    for (name, p) in &runner.compiled.plans {
        println!(
            "{:<10} {:>6} {:>8} {:>8} {:>8} {:>9.1}K {:>9.1}K",
            name,
            format!("{}x{}", p.gy, p.gx),
            p.c_groups,
            p.m_tiles,
            p.tiles.len(),
            p.in_tile_bytes as f64 / 1000.0,
            p.sram_bytes as f64 / 1000.0,
        );
    }
    Ok(())
}

/// `plan --optimize`: per-node plan table with predicted vs measured
/// DRAM bytes *and cycles* under the chosen policy and objective, then
/// a whole-graph policy diff. Exits nonzero on any model drift —
/// bytes, cycles, or the decoded-stream timing replay.
fn cmd_plan_optimize(
    net: &kn_stream::model::Graph,
    policy: PlanPolicy,
    objective: PlanObjective,
    op: OperatingPoint,
    seed: u32,
) -> anyhow::Result<()> {
    let gp = plan_graph_objective(net, policy, objective)?;
    // reuse the computed plans — don't run the planner again inside
    // NetRunner::from_graph_with_policy
    let compiled = kn_stream::compiler::compile_graph_with_plans(net, &gp.plans)?;
    let runner = NetRunner::from_compiled(compiled, kn_stream::sim::SimConfig::default())?;
    let frame = Tensor::random_image(seed, net.in_h, net.in_w, net.in_c);
    let (_, measured) = runner.run_frame_node_stats(&frame)?;

    let kb = |b: u64| format!("{:.1}", b as f64 / 1e3);
    let mut t = Table::new(
        &format!(
            "{} decomposition plan — policy {}, objective {}",
            net.name,
            policy.name(),
            gp.objective.name()
        ),
        &[
            "node", "grid", "c-grps", "tiles", "sram KB", "prd rd", "mea rd", "prd wr",
            "mea wr", "prd cyc", "mea cyc", "lane util",
        ],
    );
    for (i, node) in net.nodes.iter().enumerate() {
        let pred = &gp.node_traffic[i];
        // a fused-away depthwise producer runs inside its pointwise
        // consumer's segments; a fused pointwise node is tagged "+dw"
        let fused = gp.plans[i].as_ref().is_some_and(|p| p.fuse_dw);
        let (grid, cgrps, tiles, sram) = match gp.reports.iter().find(|r| r.node == i) {
            Some(r) => (
                format!("{}x{}{}", r.grid.0, r.grid.1, if fused { "+dw" } else { "" }),
                format!("{}", r.c_groups),
                format!("{}", r.ntiles),
                format!("{:.1}", r.sram_bytes as f64 / 1e3),
            ),
            None => ("-".into(), "-".into(), "-".into(), "-".into()),
        };
        let util = if measured[i].active_cycles == 0 {
            "-".into()
        } else {
            format!("{:.3}", measured[i].lane_utilization())
        };
        t.row(&[
            node.name().to_string(),
            grid,
            cgrps,
            tiles,
            sram,
            kb(pred.read_bytes),
            kb(measured[i].dram_read_bytes),
            kb(pred.write_bytes),
            kb(measured[i].dram_write_bytes),
            format!("{}", gp.node_cycles[i]),
            format!("{}", measured[i].cycles),
            util,
        ]);
    }
    t.print();

    let mut t = Table::new(
        "policy comparison (predicted)",
        &[
            "policy", "DRAM rd MB", "DRAM wr MB", "dep edges", "crit.path Mcy", "lat ms @op",
            "est mJ/frame",
        ],
    );
    for p in PlanPolicy::ALL {
        // the chosen policy's plan is already computed; plan the others
        let fresh;
        let g = if p == policy {
            &gp
        } else {
            fresh = plan_graph_objective(net, p, objective)?;
            &fresh
        };
        let tt = g.total_traffic();
        t.row(&[
            p.name().to_string(),
            format!("{:.3}", tt.read_bytes as f64 / 1e6),
            format!("{:.3}", tt.write_bytes as f64 / 1e6),
            format!("{}", g.dep_edges),
            format!("{:.3}", g.est_critical_path_cycles as f64 / 1e6),
            format!("{:.3}", g.latency_ms(op)),
            format!("{:.3}", g.energy_j(op) * 1e3),
        ]);
    }
    t.print();
    let mism = net
        .nodes
        .iter()
        .enumerate()
        .filter(|(i, _)| {
            gp.node_traffic[*i].read_bytes != measured[*i].dram_read_bytes
                || gp.node_traffic[*i].write_bytes != measured[*i].dram_write_bytes
                || gp.node_cycles[*i] != measured[*i].cycles
        })
        .count();
    anyhow::ensure!(
        mism == 0,
        "cost model drifted from the emitter on {mism} node(s) — see table above"
    );
    // Second, independent gate: replay the decoded command stream
    // through the analysis timing lint against the planner's table.
    let drift = lint_timing(&runner.compiled, &gp.node_cycles);
    for d in &drift {
        println!("{d}");
    }
    anyhow::ensure!(drift.is_empty(), "timing lint: {} drift diagnostic(s)", drift.len());
    println!(
        "cost model check: predicted DRAM bytes and cycles == measured for all {} nodes",
        net.nodes.len()
    );
    Ok(())
}

/// `lint`: compile every requested net × policy, run the static
/// schedule analyzer on the artifact, and fail on any diagnostic.
/// `--chips N` re-compiles N times and requires byte-identical output
/// first — the determinism a sharded multi-chip deployment assumes.
fn cmd_lint(args: Vec<String>) -> anyhow::Result<()> {
    let mut cli = Cli::new("kn-stream lint", "static schedule analyzer over compiled programs");
    cli.opt("net", "all", "zoo net to lint, or 'all' (incl. graph nets)")
        .opt("policy", "all", "plan policy (heuristic|min-traffic|dag-aware|all)")
        .opt("chips", "1", "independent compiles that must be byte-identical before analysis");
    let m = cli.parse_from(args)?;
    let nets: Vec<String> = if m.get("net") == "all" {
        zoo::GRAPH_ALL.iter().map(|s| s.to_string()).collect()
    } else {
        vec![m.get("net").to_string()]
    };
    let policies: Vec<PlanPolicy> = if m.get("policy") == "all" {
        PlanPolicy::ALL.to_vec()
    } else {
        vec![PlanPolicy::parse(m.get("policy"))?]
    };
    let chips = m.get_usize("chips").max(1);
    // The analyzer runs explicitly below, so the in-compile verify gate
    // would only duplicate work.
    let opts = CompileOptions { verify: false, ..Default::default() };
    let mut t = Table::new(
        "static schedule lint",
        &["net", "policy", "segments", "cmds", "hazards", "lint ms", "verdict"],
    );
    let (mut dirty, mut rows) = (0usize, 0usize);
    for name in &nets {
        let graph = graph_arg(name)?;
        for &policy in &policies {
            let compile = || -> anyhow::Result<kn_stream::compiler::CompiledNet> {
                match policy {
                    PlanPolicy::Heuristic => compile_graph_with_options(&graph, None, &opts),
                    _ => {
                        let gp = plan_graph(&graph, policy)?;
                        compile_graph_with_options(&graph, Some(&gp.plans), &opts)
                    }
                }
            };
            let compiled = compile()?;
            for c in 1..chips {
                let again = compile()?;
                anyhow::ensure!(
                    again.program == compiled.program && again.dram_init == compiled.dram_init,
                    "{name}/{}: chip {c} compile is not byte-identical",
                    policy.name()
                );
            }
            let t0 = std::time::Instant::now();
            let analysis = analyze(&compiled)?;
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            for d in &analysis.diagnostics {
                println!("{name}/{}: {d}", policy.name());
            }
            dirty += usize::from(!analysis.is_clean());
            rows += 1;
            t.row(&[
                name.clone(),
                policy.name().to_string(),
                format!("{}", analysis.segments),
                format!("{}", compiled.program.len()),
                format!("{}", analysis.hazards_checked),
                format!("{ms:.1}"),
                if analysis.is_clean() { "clean".into() } else { "DIRTY".into() },
            ]);
        }
    }
    t.print();
    anyhow::ensure!(dirty == 0, "{dirty} of {rows} schedule(s) failed lint");
    println!("lint: {rows} net x policy schedule(s) clean");
    Ok(())
}

fn cmd_info() -> anyhow::Result<()> {
    let area = AreaModel::default();
    let rpt = area.paper_config();
    let (s, c, b) = rpt.shares();
    let energy = EnergyModel::default();
    println!("kn-stream accelerator model (Du et al. 2017, TSMC 65 nm)");
    println!("  CU engine array : {} CUs x {} PEs = {} MACs/cycle",
             kn_stream::NUM_CU, kn_stream::PES_PER_CU, kn_stream::NUM_CU * kn_stream::PES_PER_CU);
    println!("  buffer bank     : {} KB single-port, {} B word", kn_stream::SRAM_BYTES / 1024,
             kn_stream::SRAM_WIDTH_BYTES);
    println!("  command FIFO    : {} deep, 16-bit AXI", kn_stream::CMD_FIFO_DEPTH);
    println!("  core area       : {:.2} mm²  (SRAM {:.0}% / CU {:.0}% / COL BUF {:.0}%), {:.2} M gates",
             rpt.total_mm2(), s * 100.0, c * 100.0, b * 100.0,
             area.gate_count(&rpt) / 1e6);
    for f in [20.0, 100.0, 250.0, 500.0] {
        let op = OperatingPoint::for_freq(f);
        println!(
            "  @ {:>3.0} MHz / {:.2} V : {:>7} peak, {:>6.1} mW, {:.2} TOPS/W",
            f,
            op.vdd,
            format!("{}OPS", eng(energy.peak_ops(op))),
            energy.peak_power_w(op) * 1e3,
            energy.peak_tops_per_w(op)
        );
    }
    Ok(())
}
