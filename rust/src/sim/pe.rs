//! Processing engine (paper §4.2, Fig. 4).
//!
//! One PE = one int16×int16 multiplier + a D flip-flop that passes its
//! input pixel to the next PE in the systolic chain. `EN_Ctrl` gates the
//! multiplier off on stride-skipped positions to save power (the energy
//! model charges only enabled multiplies).

use crate::fixed;

/// One processing engine. The D-FF chain is modeled by the `pass` value
/// returned from [`Pe::step`]; the CU wires nine of these in series.
#[derive(Clone, Debug, Default)]
pub struct Pe {
    /// Weight register (written on filter-update requests).
    pub weight: i16,
    /// D flip-flop holding the pixel being passed downstream.
    dff: i16,
    /// Multiplies actually performed (EN_Ctrl-gated).
    pub mul_count: u64,
}

impl Pe {
    /// One cycle: latch `x_in`, emit the previous pixel downstream, and
    /// (if enabled) produce the product of the *incoming* pixel with the
    /// stored weight.
    #[inline]
    pub fn step(&mut self, x_in: i16, en: bool) -> (i16, i32) {
        let downstream = self.dff;
        self.dff = x_in;
        let product = if en {
            self.mul_count += 1;
            fixed::pe_mul(x_in, self.weight)
        } else {
            0
        };
        (downstream, product)
    }

    pub fn load_weight(&mut self, w: i16) {
        self.weight = w;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiply_and_pass() {
        let mut pe = Pe::default();
        pe.load_weight(3);
        let (down0, p0) = pe.step(5, true);
        assert_eq!(down0, 0); // DFF was empty
        assert_eq!(p0, 15);
        let (down1, p1) = pe.step(-7, true);
        assert_eq!(down1, 5); // previous pixel emerges one cycle later
        assert_eq!(p1, -21);
        assert_eq!(pe.mul_count, 2);
    }

    #[test]
    fn en_ctrl_gates_power() {
        let mut pe = Pe::default();
        pe.load_weight(100);
        let (_, p) = pe.step(50, false);
        assert_eq!(p, 0);
        assert_eq!(pe.mul_count, 0); // gated multiply not counted
    }

    #[test]
    fn extreme_products_fit_i32() {
        let mut pe = Pe::default();
        pe.load_weight(i16::MIN);
        let (_, p) = pe.step(i16::MIN, true);
        assert_eq!(p, (i16::MIN as i32) * (i16::MIN as i32));
    }
}
