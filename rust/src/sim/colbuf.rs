//! Streaming column buffer (paper §3, Fig. 2).
//!
//! A 2×N row buffer pair in front of the CU array: as pixel rows of the
//! current channel stream out of SRAM 8-per-cycle, the two row buffers
//! hold the previous two rows, so every incoming pixel completes a 3×3
//! window column and the convolution never pauses ("no need to wait for
//! the incomplete convolution calculation"). This module models the
//! state machine exactly — fill level, row wrap, boundary behaviour —
//! and exposes the windows the CU array consumes.

/// Fill words before the first valid window of a width-`w` scan: two
/// full rows at 8 px/word. Shared with the analytic timing model in
/// `sim/fastconv.rs` so state machine and cycle model cannot drift.
pub fn fill_words(w: usize) -> usize {
    (2 * w).div_ceil(super::sram::WORD_PX)
}

/// Column buffer for one channel scan of a (h × w) tile.
pub struct ColumnBuffer {
    w: usize,
    /// The 2×N row buffers (N = tile width).
    rows: [Vec<i16>; 2],
    /// Incoming row index (0-based); rows 0 and 1 only fill.
    next_row: usize,
    /// Shift registers holding the left two columns of the window.
    cols: [[i16; 3]; 2],
    /// Current x position within the streaming row.
    x: usize,
}

impl ColumnBuffer {
    pub fn new(w: usize) -> Self {
        assert!(w >= 3, "column buffer needs width >= 3");
        Self {
            w,
            rows: [vec![0; w], vec![0; w]],
            next_row: 0,
            cols: [[0; 3]; 2],
            x: 0,
        }
    }

    /// Number of fill cycles (SRAM words) before the first valid window:
    /// two full rows at 8 px/word.
    pub fn fill_words(&self) -> usize {
        fill_words(self.w)
    }

    /// Stream one pixel of the current input row. Returns a complete 3×3
    /// window (centered on the column just completed) once both the row
    /// fill and the 3-column fill are satisfied.
    ///
    /// The window rows are (row-2, row-1, row) = the two row buffers plus
    /// the live pixel; window columns are the last three streamed.
    pub fn push_px(&mut self, px: i16) -> Option<[i16; 9]> {
        debug_assert!(self.x < self.w);
        let x = self.x;
        // Column vector for this x: two buffered rows + live pixel.
        let col = [self.rows[0][x], self.rows[1][x], px];
        // Row buffers shift down: row-1 becomes row-2, live becomes row-1.
        self.rows[0][x] = self.rows[1][x];
        self.rows[1][x] = px;
        // Column shift registers.
        let out = if self.next_row >= 2 && x >= 2 {
            Some([
                self.cols[0][0], self.cols[1][0], col[0],
                self.cols[0][1], self.cols[1][1], col[1],
                self.cols[0][2], self.cols[1][2], col[2],
            ])
        } else {
            None
        };
        self.cols[0] = self.cols[1];
        self.cols[1] = col;
        self.x += 1;
        if self.x == self.w {
            self.x = 0;
            self.next_row += 1;
            // new row: the column shift registers restart at the boundary
            self.cols = [[0; 3]; 2];
        }
        out
    }

    /// Rows streamed so far.
    pub fn rows_streamed(&self) -> usize {
        self.next_row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Tensor;

    /// Stream a whole single-channel tile and collect windows; they must
    /// equal the naive 3×3 window extraction — and there must be exactly
    /// (h-2)*(w-2) of them, one per cycle after the fill (streaming
    /// continuity, Fig. 2b).
    #[test]
    fn windows_match_naive_extraction() {
        let t = Tensor::random_image(11, 9, 7, 1);
        let mut cb = ColumnBuffer::new(t.w);
        let mut got = Vec::new();
        for y in 0..t.h {
            for x in 0..t.w {
                if let Some(win) = cb.push_px(t.at(y, x, 0)) {
                    got.push(((y, x), win));
                }
            }
        }
        assert_eq!(got.len(), (t.h - 2) * (t.w - 2));
        let mut i = 0;
        for oy in 0..t.h - 2 {
            for ox in 0..t.w - 2 {
                let ((y, x), win) = got[i];
                // window completes when its bottom-right pixel streams in
                assert_eq!((y, x), (oy + 2, ox + 2));
                let mut want = [0i16; 9];
                for dy in 0..3 {
                    for dx in 0..3 {
                        want[dy * 3 + dx] = t.at(oy + dy, ox + dx, 0);
                    }
                }
                assert_eq!(win, want, "window at ({oy},{ox})");
                i += 1;
            }
        }
    }

    #[test]
    fn no_windows_during_fill() {
        let mut cb = ColumnBuffer::new(5);
        // first two rows: no output at all
        for _ in 0..2 {
            for x in 0..5 {
                assert!(cb.push_px(x as i16).is_none());
            }
        }
        // third row: first two pixels still fill columns, then valid
        assert!(cb.push_px(1).is_none());
        assert!(cb.push_px(2).is_none());
        assert!(cb.push_px(3).is_some());
    }

    #[test]
    fn fill_words_accounting() {
        let cb = ColumnBuffer::new(55);
        assert_eq!(cb.fill_words(), (2 * 55usize).div_ceil(8));
    }

    #[test]
    fn row_boundary_resets_columns() {
        // windows must never mix pixels from the end of one row with the
        // start of the next (the Fig. 2a "boundary issue")
        let t = Tensor::from_vec(3, 4, 1, (1..=12).collect());
        let mut cb = ColumnBuffer::new(4);
        let mut wins = Vec::new();
        for y in 0..3 {
            for x in 0..4 {
                if let Some(w) = cb.push_px(t.at(y, x, 0)) {
                    wins.push(w);
                }
            }
        }
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[0], [1, 2, 3, 5, 6, 7, 9, 10, 11]);
        assert_eq!(wins[1], [2, 3, 4, 6, 7, 8, 10, 11, 12]);
    }
}
