//! The 128 KB single-port SRAM buffer bank (paper §4.1, Fig. 3).
//!
//! 16-byte words (8 int16 pixels per access). Single-ported: every read
//! or write occupies the port for one cycle — the accelerator charges
//! the port-conflict cycles, this module counts the accesses and
//! enforces capacity. A bump allocator hands out tile regions (the
//! compiler plans them; the simulator validates).

use crate::{SRAM_BYTES, SRAM_WIDTH_BYTES};

/// Pixels (int16) per SRAM word.
pub const WORD_PX: usize = SRAM_WIDTH_BYTES / 2;
/// Total capacity in pixels.
pub const CAP_PX: usize = SRAM_BYTES / 2;

/// The buffer bank. Data is held in pixel (int16) granularity; access
/// counters are in words (one word = one port cycle).
pub struct BufferBank {
    data: Vec<i16>,
    pub reads: u64,
    pub writes: u64,
    alloc_top: usize,
}

impl Default for BufferBank {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferBank {
    pub fn new() -> Self {
        Self { data: vec![0; CAP_PX], reads: 0, writes: 0, alloc_top: 0 }
    }

    pub fn capacity_px(&self) -> usize {
        CAP_PX
    }

    /// Allocate a region of `len_px` pixels (compiler-planned layout).
    /// Panics if the bank is over-committed — the decomposition solver is
    /// supposed to make that impossible; tests assert it.
    pub fn alloc(&mut self, len_px: usize) -> u32 {
        let base = self.alloc_top;
        assert!(
            base + len_px <= CAP_PX,
            "SRAM over-committed: {} + {} > {} px",
            base,
            len_px,
            CAP_PX
        );
        self.alloc_top += len_px;
        base as u32
    }

    /// Release everything (between layers / tiles).
    pub fn reset_alloc(&mut self) {
        self.alloc_top = 0;
    }

    pub fn allocated_px(&self) -> usize {
        self.alloc_top
    }

    /// Raw view of the whole bank (fast-path window reads; traffic is
    /// charged separately at streaming granularity).
    #[inline(always)]
    pub fn raw(&self) -> &[i16] {
        &self.data
    }

    // -- pixel access (counts port words) -----------------------------------

    #[inline(always)]
    pub fn read_px(&mut self, addr: usize) -> i16 {
        debug_assert!(addr < CAP_PX, "SRAM read OOB: {addr}");
        self.data[addr]
    }

    #[inline(always)]
    pub fn write_px(&mut self, addr: usize, v: i16) {
        debug_assert!(addr < CAP_PX, "SRAM write OOB: {addr}");
        self.data[addr] = v;
    }

    /// Charge `n` pixels of read traffic (rounded up to words).
    #[inline(always)]
    pub fn charge_read_px(&mut self, n: usize) {
        self.reads += n.div_ceil(WORD_PX) as u64;
    }

    #[inline(always)]
    pub fn charge_write_px(&mut self, n: usize) {
        self.writes += n.div_ceil(WORD_PX) as u64;
    }

    /// Bulk copy helpers used by the DMA engine (charging included).
    pub fn write_slice(&mut self, addr: usize, src: &[i16]) {
        assert!(addr + src.len() <= CAP_PX, "SRAM write_slice OOB");
        self.data[addr..addr + src.len()].copy_from_slice(src);
        self.charge_write_px(src.len());
    }

    pub fn read_slice(&mut self, addr: usize, len: usize) -> Vec<i16> {
        assert!(addr + len <= CAP_PX, "SRAM read_slice OOB");
        self.charge_read_px(len);
        self.data[addr..addr + len].to_vec()
    }

    /// int32 partial-plane access: one int32 = 2 pixels, little-endian
    /// halves (the ACC BUF's view of the bank).
    #[inline(always)]
    pub fn read_i32(&mut self, addr_px: usize) -> i32 {
        let lo = self.read_px(addr_px) as u16 as u32;
        let hi = self.read_px(addr_px + 1) as u16 as u32;
        (lo | (hi << 16)) as i32
    }

    #[inline(always)]
    pub fn write_i32(&mut self, addr_px: usize, v: i32) {
        self.write_px(addr_px, (v as u32 & 0xFFFF) as u16 as i16);
        self.write_px(addr_px + 1, ((v as u32) >> 16) as u16 as i16);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_is_128kb() {
        assert_eq!(CAP_PX * 2, 128 * 1024);
        assert_eq!(WORD_PX, 8);
    }

    #[test]
    fn alloc_and_overcommit() {
        let mut b = BufferBank::new();
        let a = b.alloc(1000);
        let c = b.alloc(2000);
        assert_eq!(a, 0);
        assert_eq!(c, 1000);
        assert_eq!(b.allocated_px(), 3000);
        b.reset_alloc();
        assert_eq!(b.allocated_px(), 0);
    }

    #[test]
    #[should_panic(expected = "SRAM over-committed")]
    fn overcommit_panics() {
        let mut b = BufferBank::new();
        b.alloc(CAP_PX);
        b.alloc(1);
    }

    #[test]
    fn word_charging_rounds_up() {
        let mut b = BufferBank::new();
        b.charge_read_px(1); // 1 px -> 1 word
        b.charge_read_px(8); // 8 px -> 1 word
        b.charge_read_px(9); // 9 px -> 2 words
        assert_eq!(b.reads, 4);
        b.charge_write_px(17);
        assert_eq!(b.writes, 3);
    }

    #[test]
    fn i32_roundtrip() {
        let mut b = BufferBank::new();
        for v in [0, 1, -1, i32::MAX, i32::MIN, 123_456_789, -987_654_321] {
            b.write_i32(100, v);
            assert_eq!(b.read_i32(100), v);
        }
    }

    #[test]
    fn slices_roundtrip_and_charge() {
        let mut b = BufferBank::new();
        let data: Vec<i16> = (0..100).collect();
        b.write_slice(50, &data);
        assert_eq!(b.read_slice(50, 100), data);
        assert_eq!(b.writes, 13); // ceil(100/8)
        assert_eq!(b.reads, 13);
    }
}
