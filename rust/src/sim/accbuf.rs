//! Accumulation buffer (paper §4.1, Fig. 3): a dedicated int32 partial-
//! sum memory between the CU engine array and the buffer bank, with the
//! fused bias / requantize / ReLU output stage.
//!
//! Partial planes persist across conv passes (channel groups and
//! kernel-decomposition taps) for the current output tile; the LAST pass
//! requantizes to int16 and drains to SRAM. Capacity bounds the output
//! tile (`oh*ow <= 1024` pixels × 16 features) — a constraint the
//! decomposition solver enforces.

use crate::fixed;
use crate::NUM_CU;

/// Output-tile pixels the ACC BUF can hold (× 16 features × int32 = 64 KB).
pub const ACC_TILE_PX: usize = 1024;
/// Total int32 entries.
pub const ACC_ENTRIES: usize = ACC_TILE_PX * NUM_CU;

pub struct AccBuf {
    data: Vec<i32>,
    /// Bias registers for the active 16-feature group.
    bias: [i32; NUM_CU],
    /// Accumulate operations performed (energy model input).
    pub acc_ops: u64,
}

impl Default for AccBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl AccBuf {
    pub fn new() -> Self {
        Self { data: vec![0; ACC_ENTRIES], bias: [0; NUM_CU], acc_ops: 0 }
    }

    pub fn load_bias(&mut self, b: &[i32; NUM_CU]) {
        self.bias = *b;
    }

    /// FIRST pass: initialise `n_px` pixels of the plane at `base` with
    /// the bias registers.
    pub fn init_plane(&mut self, base: usize, n_px: usize) {
        assert!(base + n_px <= ACC_TILE_PX, "ACC BUF overflow: {base}+{n_px}");
        for p in 0..n_px {
            let off = (base + p) * NUM_CU;
            self.data[off..off + NUM_CU].copy_from_slice(&self.bias);
        }
    }

    /// Accumulate one cycle's 16 partial sums into pixel `px` of the plane.
    #[inline(always)]
    pub fn accumulate(&mut self, base: usize, px: usize, partials: &[i32; NUM_CU]) {
        debug_assert!(base + px < ACC_TILE_PX, "ACC BUF overflow");
        let off = (base + px) * NUM_CU;
        for m in 0..NUM_CU {
            self.data[off + m] = self.data[off + m].wrapping_add(partials[m]);
        }
        self.acc_ops += NUM_CU as u64;
    }

    /// LAST pass: requantize pixel `px` to 16 int16 lanes.
    #[inline(always)]
    pub fn requant_px(&self, base: usize, px: usize, shift: u8, relu: bool) -> [i16; NUM_CU] {
        let off = (base + px) * NUM_CU;
        core::array::from_fn(|m| fixed::requantize(self.data[off + m], shift, relu))
    }

    /// Mutable 16-lane row of pixel `px` (fused engine accumulation).
    #[inline(always)]
    pub fn row_mut(&mut self, base: usize, px: usize) -> &mut [i32] {
        debug_assert!(base + px < ACC_TILE_PX, "ACC BUF overflow");
        let off = (base + px) * NUM_CU;
        self.acc_ops += NUM_CU as u64;
        &mut self.data[off..off + NUM_CU]
    }

    /// Mutable int32 plane of `n_px` pixels × 16 lanes at `base`: the
    /// tap-major fast path accumulates whole channel scans at once.
    /// Same `acc_ops` charge as `n_px` calls of the per-pixel path.
    #[inline]
    pub fn plane_mut(&mut self, base: usize, n_px: usize) -> &mut [i32] {
        assert!(base + n_px <= ACC_TILE_PX, "ACC BUF overflow: {base}+{n_px}");
        self.acc_ops += (n_px * NUM_CU) as u64;
        &mut self.data[base * NUM_CU..(base + n_px) * NUM_CU]
    }

    /// Raw plane readback (tests).
    pub fn peek(&self, base: usize, px: usize, m: usize) -> i32 {
        self.data[(base + px) * NUM_CU + m]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_init_then_accumulate_then_requant() {
        let mut ab = AccBuf::new();
        let bias: [i32; NUM_CU] = core::array::from_fn(|m| m as i32 * 10);
        ab.load_bias(&bias);
        ab.init_plane(0, 4);
        let partial: [i32; NUM_CU] = core::array::from_fn(|m| m as i32);
        ab.accumulate(0, 2, &partial);
        ab.accumulate(0, 2, &partial);
        assert_eq!(ab.peek(0, 2, 3), 30 + 3 + 3);
        assert_eq!(ab.peek(0, 1, 3), 30);
        let q = ab.requant_px(0, 2, 1, false);
        assert_eq!(q[3], fixed::requantize(36, 1, false));
        assert_eq!(ab.acc_ops, 32);
    }

    #[test]
    fn wrapping_accumulation() {
        let mut ab = AccBuf::new();
        ab.load_bias(&[i32::MAX; NUM_CU]);
        ab.init_plane(0, 1);
        ab.accumulate(0, 0, &[1; NUM_CU]);
        assert_eq!(ab.peek(0, 0, 0), i32::MIN); // wrapped, by contract
    }

    #[test]
    #[should_panic(expected = "ACC BUF overflow")]
    fn capacity_enforced() {
        let mut ab = AccBuf::new();
        ab.init_plane(0, ACC_TILE_PX + 1);
    }
}
