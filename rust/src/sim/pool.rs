//! Reconfigurable streaming pooling module (paper §4.3, Fig. 5).
//!
//! The scratchpad presents rows of one output feature in parallel; a
//! multiplexer selects the rows valid for the configured conv stride,
//! and the max-pool unit — a four-input comparator with a feedback
//! register — reduces the k×k window as the columns stream by. The
//! pooled output feeds back to the scratchpad (here: the buffer bank).
//!
//! Functional model: per output pixel the comparator performs k cycles
//! (one per window column), comparing up to 3 row inputs + the feedback
//! register — exactly the §4.3 procedure. Cycle cost: `oh*ow*k` per
//! channel plane, overlappable with the next conv's streaming (the
//! scheduler decides; the accelerator charges it serially by default).

use super::sram::BufferBank;

/// One max-pool unit: 4-input comparator + feedback register.
#[derive(Default)]
pub struct MaxPoolUnit {
    feedback: i16,
    valid: bool,
    pub compare_ops: u64,
}

impl MaxPoolUnit {
    /// One cycle: compare up to three incoming row values with the
    /// feedback register.
    #[inline]
    pub fn step(&mut self, inputs: &[i16]) -> i16 {
        debug_assert!(inputs.len() <= 3, "comparator has 4 inputs incl. feedback");
        let mut m = if self.valid { self.feedback } else { i16::MIN };
        for &v in inputs {
            m = m.max(v);
        }
        self.compare_ops += inputs.len() as u64 + self.valid as u64;
        self.feedback = m;
        self.valid = true;
        m
    }

    /// Window boundary: emit and clear the feedback register.
    #[inline]
    pub fn emit(&mut self) -> i16 {
        let m = self.feedback;
        self.valid = false;
        self.feedback = i16::MIN;
        m
    }
}

/// Pooling pass over a planar (C, H, W) int16 region in the buffer bank.
/// Returns cycles consumed.
///
/// Functional fast path: row-sliced max over the raw plane — max is
/// associative and commutative, so the result is bit-identical to the
/// streaming comparator procedure ([`MaxPoolUnit`], kept validated by
/// the unit tests below). Counters are charged analytically, matching
/// the comparator exactly: `k` columns per window → `oh·ow·k` cycles
/// per channel plane, and the 4-input comparator performs
/// `k + (k−1)·(k+1) = k² + k − 1` compares per window.
#[allow(clippy::too_many_arguments)]
pub fn pool_pass(
    sram: &mut BufferBank,
    src_px: usize,
    dst_px: usize,
    ih: usize,
    iw: usize,
    c: usize,
    k: usize,
    stride: usize,
    compare_ops: &mut u64,
) -> u64 {
    assert!(k == 2 || k == 3, "pool window must be 2 or 3 (paper §4.3)");
    assert!(stride >= 1);
    let oh = (ih - k) / stride + 1;
    let ow = (iw - k) / stride + 1;
    let mut out_plane = vec![i16::MIN; oh * ow];
    let mut cycles = 0u64;
    for ch in 0..c {
        let splane = src_px + ch * ih * iw;
        let dplane = dst_px + ch * oh * ow;
        {
            let data = sram.raw();
            for oy in 0..oh {
                let orow = &mut out_plane[oy * ow..(oy + 1) * ow];
                orow.fill(i16::MIN);
                for i in 0..k {
                    let row = &data[splane + (oy * stride + i) * iw..][..iw];
                    for (ox, o) in orow.iter_mut().enumerate() {
                        for &v in &row[ox * stride..ox * stride + k] {
                            *o = (*o).max(v);
                        }
                    }
                }
            }
        }
        for (px, &v) in out_plane.iter().enumerate() {
            sram.write_px(dplane + px, v);
        }
        // port traffic: the scratchpad serves row-parallel reads, the
        // bank sees one word stream per row (one pass of the plane) +
        // the pooled output writes.
        cycles += (oh * ow * k) as u64;
        sram.charge_read_px(ih * iw);
        sram.charge_write_px(oh * ow);
    }
    *compare_ops += (c * oh * ow * (k * k + k - 1)) as u64;
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::pool_ref;
    use crate::model::{PoolSpec, Tensor};
    use crate::util::prop::check;

    /// Load a HWC tensor into SRAM planar (C,H,W) at `base`.
    fn load_planar(sram: &mut BufferBank, base: usize, t: &Tensor) {
        for ch in 0..t.c {
            for y in 0..t.h {
                for x in 0..t.w {
                    sram.write_px(base + ch * t.h * t.w + y * t.w + x, t.at(y, x, ch));
                }
            }
        }
    }

    fn read_planar(sram: &mut BufferBank, base: usize, h: usize, w: usize, c: usize) -> Tensor {
        let mut t = Tensor::zeros(h, w, c);
        for ch in 0..c {
            for y in 0..h {
                for x in 0..w {
                    t.set(y, x, ch, sram.read_px(base + ch * h * w + y * w + x));
                }
            }
        }
        t
    }

    #[test]
    fn comparator_feedback_procedure() {
        let mut u = MaxPoolUnit::default();
        // 3x3 window scanned as 3 columns of 3
        u.step(&[1, 5, 2]);
        u.step(&[4, 3, 0]);
        u.step(&[-1, -2, 7]);
        assert_eq!(u.emit(), 7);
        // feedback cleared for the next window
        u.step(&[-5, -6]);
        assert_eq!(u.emit(), -5);
    }

    #[test]
    fn pool_pass_matches_oracle_property() {
        check("pool_pass == pool_ref", 40, |g| {
            let k = if g.bool() { 2 } else { 3 };
            let stride = g.usize_in(1, 3);
            let ih = g.usize_in(k, 24);
            let iw = g.usize_in(k, 24);
            let c = g.usize_in(1, 5);
            let data = g.vec_i16(ih * iw * c, -3000, 3000);
            let t = Tensor::from_vec(ih, iw, c, data);
            let want = pool_ref(&t, &PoolSpec { name: "p".into(), k, stride });
            let mut sram = BufferBank::new();
            load_planar(&mut sram, 0, &t);
            let mut ops = 0;
            let dst = (ih * iw * c).next_multiple_of(8);
            pool_pass(&mut sram, 0, dst, ih, iw, c, k, stride, &mut ops);
            let got = read_planar(&mut sram, dst, want.h, want.w, c);
            if got == want {
                Ok(())
            } else {
                Err(format!("pool {k}x{k}/s{stride} {ih}x{iw}x{c} mismatch"))
            }
        });
    }

    #[test]
    fn cycle_count_is_k_per_output() {
        let mut sram = BufferBank::new();
        let t = Tensor::random_image(5, 8, 8, 2);
        load_planar(&mut sram, 0, &t);
        let mut ops = 0;
        let cy = pool_pass(&mut sram, 0, 256, 8, 8, 2, 2, 2, &mut ops);
        assert_eq!(cy, (4 * 4 * 2 * 2) as u64); // oh*ow*k per channel
        assert!(ops > 0);
    }
}
