//! Reconfigurable streaming pooling module (paper §4.3, Fig. 5).
//!
//! The scratchpad presents rows of one output feature in parallel; a
//! multiplexer selects the rows valid for the configured conv stride,
//! and the max-pool unit — a four-input comparator with a feedback
//! register — reduces the k×k window as the columns stream by. The
//! pooled output feeds back to the scratchpad (here: the buffer bank).
//!
//! Functional model: per output pixel the comparator performs k cycles
//! (one per window column), comparing up to 3 row inputs + the feedback
//! register — exactly the §4.3 procedure. Cycle cost: `oh*ow*k` per
//! channel plane, overlappable with the next conv's streaming (the
//! scheduler decides; the accelerator charges it serially by default).
//!
//! **Average pooling** reuses the same streaming datapath with the
//! comparator swapped for a 4-input *adder* feeding an int32 feedback
//! accumulator; the emit stage divides by the window area with
//! round-half-up (the conv requantizer's rounding convention). Because
//! the adder serializes columns, windows are not limited to 2/3 — a
//! whole-plane window implements the global-average-pool head.

use super::sram::BufferBank;

/// One max-pool unit: 4-input comparator + feedback register.
#[derive(Default)]
pub struct MaxPoolUnit {
    feedback: i16,
    valid: bool,
    pub compare_ops: u64,
}

impl MaxPoolUnit {
    /// One cycle: compare up to three incoming row values with the
    /// feedback register.
    #[inline]
    pub fn step(&mut self, inputs: &[i16]) -> i16 {
        debug_assert!(inputs.len() <= 3, "comparator has 4 inputs incl. feedback");
        let mut m = if self.valid { self.feedback } else { i16::MIN };
        for &v in inputs {
            m = m.max(v);
        }
        self.compare_ops += inputs.len() as u64 + self.valid as u64;
        self.feedback = m;
        self.valid = true;
        m
    }

    /// Window boundary: emit and clear the feedback register.
    #[inline]
    pub fn emit(&mut self) -> i16 {
        let m = self.feedback;
        self.valid = false;
        self.feedback = i16::MIN;
        m
    }
}

/// One average-pool unit: 4-input adder + int32 feedback accumulator.
/// The emit stage performs the round-half-up division by the window
/// area (`k²`), mirroring the conv requantizer's rounding.
#[derive(Default)]
pub struct AvgPoolUnit {
    acc: i32,
    pub add_ops: u64,
}

impl AvgPoolUnit {
    /// One cycle: accumulate up to three incoming row values.
    #[inline]
    pub fn step(&mut self, inputs: &[i16]) -> i32 {
        debug_assert!(inputs.len() <= 3, "adder has 4 inputs incl. feedback");
        for &v in inputs {
            self.acc += v as i32;
        }
        self.add_ops += inputs.len() as u64;
        self.acc
    }

    /// Window boundary: divide by the window area (round half up),
    /// emit, and clear the accumulator.
    #[inline]
    pub fn emit(&mut self, area: i32) -> i16 {
        let mean = (self.acc + area / 2).div_euclid(area) as i16;
        self.acc = 0;
        self.add_ops += 1; // the rounding add of the divide stage
        mean
    }
}

/// Pooling pass over a planar (C, H, W) int16 region in the buffer bank.
/// Returns cycles consumed.
///
/// Functional fast path: row-sliced reduction over the raw plane — max
/// is associative/commutative and the avg accumulation is exact int32,
/// so the results are bit-identical to the streaming unit procedures
/// ([`MaxPoolUnit`] / [`AvgPoolUnit`], kept validated by the unit tests
/// below). Counters are charged analytically, matching the streaming
/// units exactly: `k` columns per window → `oh·ow·k` cycles per channel
/// plane. Per window the 4-input comparator performs
/// `k + (k−1)·(k+1) = k² + k − 1` compares; the avg path performs `k²`
/// adds (window accumulation) plus the divide stage's rounding add.
#[allow(clippy::too_many_arguments)]
pub fn pool_pass(
    sram: &mut BufferBank,
    src_px: usize,
    dst_px: usize,
    ih: usize,
    iw: usize,
    c: usize,
    k: usize,
    stride: usize,
    avg: bool,
    compare_ops: &mut u64,
) -> u64 {
    if avg {
        assert!(k >= 2 && k <= ih.min(iw), "avg window must fit the plane");
    } else {
        assert!(k == 2 || k == 3, "max window must be 2 or 3 (paper §4.3)");
    }
    assert!(stride >= 1);
    let oh = (ih - k) / stride + 1;
    let ow = (iw - k) / stride + 1;
    let area = (k * k) as i32;
    let mut max_plane = vec![i16::MIN; oh * ow];
    let mut sum_plane = vec![0i32; oh * ow];
    let mut cycles = 0u64;
    for ch in 0..c {
        let splane = src_px + ch * ih * iw;
        let dplane = dst_px + ch * oh * ow;
        {
            let data = sram.raw();
            for oy in 0..oh {
                let mrow = &mut max_plane[oy * ow..(oy + 1) * ow];
                let srow = &mut sum_plane[oy * ow..(oy + 1) * ow];
                mrow.fill(i16::MIN);
                srow.fill(0);
                for i in 0..k {
                    let row = &data[splane + (oy * stride + i) * iw..][..iw];
                    if avg {
                        for (ox, o) in srow.iter_mut().enumerate() {
                            for &v in &row[ox * stride..ox * stride + k] {
                                *o += v as i32;
                            }
                        }
                    } else {
                        for (ox, o) in mrow.iter_mut().enumerate() {
                            for &v in &row[ox * stride..ox * stride + k] {
                                *o = (*o).max(v);
                            }
                        }
                    }
                }
            }
        }
        for px in 0..oh * ow {
            let v = if avg {
                ((sum_plane[px] + area / 2).div_euclid(area)) as i16
            } else {
                max_plane[px]
            };
            sram.write_px(dplane + px, v);
        }
        // port traffic: the scratchpad serves row-parallel reads, the
        // bank sees one word stream per row (one pass of the plane) +
        // the pooled output writes.
        cycles += (oh * ow * k) as u64;
        sram.charge_read_px(ih * iw);
        sram.charge_write_px(oh * ow);
    }
    let ops_per_window = if avg { k * k + 1 } else { k * k + k - 1 };
    *compare_ops += (c * oh * ow * ops_per_window) as u64;
    cycles
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::reference::{avgpool_ref, pool_ref};
    use crate::model::{PoolSpec, Tensor};
    use crate::util::prop::check;

    /// Load a HWC tensor into SRAM planar (C,H,W) at `base`.
    fn load_planar(sram: &mut BufferBank, base: usize, t: &Tensor) {
        for ch in 0..t.c {
            for y in 0..t.h {
                for x in 0..t.w {
                    sram.write_px(base + ch * t.h * t.w + y * t.w + x, t.at(y, x, ch));
                }
            }
        }
    }

    fn read_planar(sram: &mut BufferBank, base: usize, h: usize, w: usize, c: usize) -> Tensor {
        let mut t = Tensor::zeros(h, w, c);
        for ch in 0..c {
            for y in 0..h {
                for x in 0..w {
                    t.set(y, x, ch, sram.read_px(base + ch * h * w + y * w + x));
                }
            }
        }
        t
    }

    #[test]
    fn comparator_feedback_procedure() {
        let mut u = MaxPoolUnit::default();
        // 3x3 window scanned as 3 columns of 3
        u.step(&[1, 5, 2]);
        u.step(&[4, 3, 0]);
        u.step(&[-1, -2, 7]);
        assert_eq!(u.emit(), 7);
        // feedback cleared for the next window
        u.step(&[-5, -6]);
        assert_eq!(u.emit(), -5);
    }

    #[test]
    fn adder_feedback_procedure() {
        let mut u = AvgPoolUnit::default();
        // 2x2 window as 2 columns of 2: (1 + 2 + 3 + 4 + 2) / 4 = 3 (half up)
        u.step(&[1, 2]);
        u.step(&[3, 4]);
        assert_eq!(u.emit(4), 3);
        // accumulator cleared; negative mean rounds half up too
        u.step(&[-1, -2]);
        u.step(&[-3, -4]);
        assert_eq!(u.emit(4), -2);
        assert!(u.add_ops > 0);
    }

    #[test]
    fn pool_pass_matches_oracle_property() {
        check("pool_pass == pool_ref", 40, |g| {
            let k = if g.bool() { 2 } else { 3 };
            let stride = g.usize_in(1, 3);
            let ih = g.usize_in(k, 24);
            let iw = g.usize_in(k, 24);
            let c = g.usize_in(1, 5);
            let data = g.vec_i16(ih * iw * c, -3000, 3000);
            let t = Tensor::from_vec(ih, iw, c, data);
            let want = pool_ref(&t, &PoolSpec::max("p", k, stride));
            let mut sram = BufferBank::new();
            load_planar(&mut sram, 0, &t);
            let mut ops = 0;
            let dst = (ih * iw * c).next_multiple_of(8);
            pool_pass(&mut sram, 0, dst, ih, iw, c, k, stride, false, &mut ops);
            let got = read_planar(&mut sram, dst, want.h, want.w, c);
            if got == want {
                Ok(())
            } else {
                Err(format!("pool {k}x{k}/s{stride} {ih}x{iw}x{c} mismatch"))
            }
        });
    }

    #[test]
    fn avg_pool_pass_matches_oracle_property() {
        check("pool_pass(avg) == avgpool_ref", 40, |g| {
            let k = g.usize_in(2, 8);
            let stride = g.usize_in(1, 3);
            let ih = g.usize_in(k, 24);
            let iw = g.usize_in(k, 24);
            let c = g.usize_in(1, 5);
            let data = g.vec_i16(ih * iw * c, -3000, 3000);
            let t = Tensor::from_vec(ih, iw, c, data);
            let want = avgpool_ref(&t, &PoolSpec::avg("a", k, stride));
            let mut sram = BufferBank::new();
            load_planar(&mut sram, 0, &t);
            let mut ops = 0;
            let dst = (ih * iw * c).next_multiple_of(8);
            pool_pass(&mut sram, 0, dst, ih, iw, c, k, stride, true, &mut ops);
            let got = read_planar(&mut sram, dst, want.h, want.w, c);
            if got == want {
                Ok(())
            } else {
                Err(format!("avg pool {k}x{k}/s{stride} {ih}x{iw}x{c} mismatch"))
            }
        });
    }

    #[test]
    fn global_avg_pool_pass_is_plane_mean() {
        let t = Tensor::from_vec(3, 3, 2, (0..18).map(|v| v as i16).collect());
        let want = avgpool_ref(&t, &PoolSpec::global_avg("g", 3));
        let mut sram = BufferBank::new();
        load_planar(&mut sram, 0, &t);
        let mut ops = 0;
        pool_pass(&mut sram, 0, 64, 3, 3, 2, 3, 3, true, &mut ops);
        assert_eq!(read_planar(&mut sram, 64, 1, 1, 2), want);
    }

    #[test]
    fn cycle_count_is_k_per_output() {
        let mut sram = BufferBank::new();
        let t = Tensor::random_image(5, 8, 8, 2);
        load_planar(&mut sram, 0, &t);
        let mut ops = 0;
        let cy = pool_pass(&mut sram, 0, 256, 8, 8, 2, 2, 2, false, &mut ops);
        assert_eq!(cy, (4 * 4 * 2 * 2) as u64); // oh*ow*k per channel
        assert!(ops > 0);
        // avg charges the same streaming cycles for the same window
        let mut ops_a = 0;
        let cy_a = pool_pass(&mut sram, 0, 256, 8, 8, 2, 2, 2, true, &mut ops_a);
        assert_eq!(cy_a, cy);
        assert_eq!(ops_a, (2 * 4 * 4 * 5) as u64); // k² + 1 per window
    }
}
