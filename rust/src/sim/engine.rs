//! CU engine array (paper §4.1–4.2): sixteen CUs sharing one input
//! window (input-stationary broadcast) and producing 16 output features
//! per cycle, plus the weight prefetch controller.

use super::cu::Cu;
use crate::NUM_CU;

/// The 16-CU array + prefetch controller state.
pub struct CuEngine {
    cus: Vec<Cu>,
    /// Weight prefetch staging: per CU, the next channel's 3×3 block.
    staged: Vec<[i16; 9]>,
    staged_valid: bool,
    /// Stall cycles caused by swap-before-prefetch.
    pub weight_stalls: u64,
    /// Active weights, feature-major [m*9 + tap] — the fast-path mirror
    /// of the PE weight registers (see `step_fast`).
    active_flat: Vec<i16>,
    /// Pre-widened i32 mirror [m*9 + tap] — saves 144 sign-extensions
    /// per simulated cycle in the fused fast path.
    active_wide: Vec<i32>,
    /// Multiplies performed through the fast path.
    fast_muls: u64,
}

impl Default for CuEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl CuEngine {
    pub fn new() -> Self {
        Self {
            cus: (0..NUM_CU).map(|_| Cu::default()).collect(),
            staged: vec![[0; 9]; NUM_CU],
            staged_valid: false,
            weight_stalls: 0,
            active_flat: vec![0; NUM_CU * 9],
            active_wide: vec![0; NUM_CU * 9],
            fast_muls: 0,
        }
    }

    /// Prefetch controller: stage the weights for one channel — layout
    /// `w[tap][feature]` flattened as 9×16 (tap-major), matching the
    /// (K, K, C, M) DRAM layout sliced at one (tap-row, tap-col, channel).
    pub fn prefetch_channel(&mut self, w: &[i16]) {
        assert_eq!(w.len(), 9 * NUM_CU, "one channel = 9 taps x 16 features");
        for (m, s) in self.staged.iter_mut().enumerate() {
            for tap in 0..9 {
                s[tap] = w[tap * NUM_CU + m];
            }
        }
        self.staged_valid = true;
    }

    /// Channel boundary: synchronized filter update across all CUs.
    /// Returns stall cycles incurred (0 if the prefetch was ready —
    /// double-buffering hid the load).
    pub fn update_weights(&mut self) -> u64 {
        if !self.staged_valid {
            // Model: a blocking reload costs one cycle per weight word
            // (9×16 px / 8 px-per-word).
            let stall = (9 * NUM_CU).div_ceil(super::sram::WORD_PX) as u64;
            self.weight_stalls += stall;
            return stall;
        }
        for (m, (cu, s)) in self.cus.iter_mut().zip(self.staged.iter()).enumerate() {
            cu.prefetch(s);
            let ok = cu.swap_weights();
            debug_assert!(ok);
            self.active_flat[m * 9..m * 9 + 9].copy_from_slice(s);
            for (tap, &w) in s.iter().enumerate() {
                self.active_wide[m * 9 + tap] = w as i32;
            }
        }
        self.staged_valid = false;
        0
    }

    /// Fast path of [`CuEngine::step`]: identical arithmetic (wrapping
    /// int32 dot-9 per CU over the active weight bank) without mutating
    /// the per-PE D-FF chain — the chain's observable effect on the
    /// conv pass is only the pipeline *timing*, which the pass-level
    /// cycle accounting already charges. Bit-exactness is enforced by
    /// the `fast_path_matches_slow_path` test below.
    #[inline]
    pub fn step_fast(&mut self, window: &[i16; 9]) -> [i32; NUM_CU] {
        self.fast_muls += (NUM_CU * super::super::PES_PER_CU as usize) as u64;
        // Feature-major dot-9 per CU lane. (A *per-window* tap-major
        // broadcast was tried and was ~15% slower than this dot; the
        // plane-level tap-major sweeps in `sim/fastconv.rs` are the
        // variant that wins — see EXPERIMENTS.md §Perf.)
        let mut out = [0i32; NUM_CU];
        for (m, o) in out.iter_mut().enumerate() {
            let w = &self.active_flat[m * 9..m * 9 + 9];
            let mut acc = 0i32;
            for t in 0..9 {
                acc = acc.wrapping_add(window[t] as i32 * w[t] as i32);
            }
            *o = acc;
        }
        out
    }

    /// Fused variant: one engine cycle accumulated straight into the
    /// ACC BUF row (saves a 16-lane round trip per cycle on the sim's
    /// hottest loop). Arithmetic identical to `step_fast` + wrapping add.
    #[inline]
    pub fn step_accumulate(&mut self, window: &[i16; 9], acc_row: &mut [i32]) {
        debug_assert_eq!(acc_row.len(), NUM_CU);
        self.fast_muls += (NUM_CU * super::super::PES_PER_CU as usize) as u64;
        let mut win = [0i32; 9];
        for t in 0..9 {
            win[t] = window[t] as i32;
        }
        for (m, o) in acc_row.iter_mut().enumerate() {
            let w = &self.active_wide[m * 9..m * 9 + 9];
            let mut acc = 0i32;
            for t in 0..9 {
                acc = acc.wrapping_add(win[t].wrapping_mul(w[t]));
            }
            *o = o.wrapping_add(acc);
        }
    }

    /// One engine cycle: broadcast the window to all 16 CUs.
    /// Returns the 16 int32 partial sums. `en` = EN_Ctrl stride gate.
    #[inline]
    pub fn step(&mut self, window: &[i16; 9], en: bool) -> [i32; NUM_CU] {
        let mut out = [0i32; NUM_CU];
        for (o, cu) in out.iter_mut().zip(self.cus.iter_mut()) {
            *o = cu.step(window, en);
        }
        out
    }

    /// Charge `n` multiplies performed on the engine's behalf by the
    /// tap-major fast path (`sim/fastconv.rs`) — keeps [`Self::mul_count`]
    /// consistent when the PE chain is bypassed.
    #[inline]
    pub fn charge_muls(&mut self, n: u64) {
        self.fast_muls += n;
    }

    /// Reset the perf counters and staging flag for pooled-accelerator
    /// reuse. Weight registers are left as-is: every conv pass re-stages
    /// its weights before computing.
    pub fn reset_counters(&mut self) {
        self.fast_muls = 0;
        self.weight_stalls = 0;
        self.staged_valid = false;
    }

    /// Total multiplies performed across all PEs (energy model input).
    pub fn mul_count(&self) -> u64 {
        self.fast_muls + self.cus.iter().map(|c| c.mul_count()).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed;
    use crate::util::rng::XorShift32;

    #[test]
    fn sixteen_features_parallel() {
        let mut eng = CuEngine::new();
        let mut rng = XorShift32::new(3);
        // one channel of weights: 9 taps x 16 features
        let w: Vec<i16> = (0..9 * NUM_CU).map(|_| rng.next_in(-128, 127) as i16).collect();
        eng.prefetch_channel(&w);
        assert_eq!(eng.update_weights(), 0);
        let win: [i16; 9] = core::array::from_fn(|i| (i as i16 + 1) * 3);
        let out = eng.step(&win, true);
        for (m, &o) in out.iter().enumerate() {
            let wt: [i16; 9] = core::array::from_fn(|tap| w[tap * NUM_CU + m]);
            assert_eq!(o, fixed::cu_dot9(&win, &wt), "feature {m}");
        }
        assert_eq!(eng.mul_count(), 9 * 16);
    }

    #[test]
    fn fast_path_matches_slow_path() {
        let mut rng = XorShift32::new(77);
        for trial in 0..50 {
            let mut eng = CuEngine::new();
            let w: Vec<i16> =
                (0..9 * NUM_CU).map(|_| rng.next_in(-32768, 32767) as i16).collect();
            eng.prefetch_channel(&w);
            eng.update_weights();
            let win: [i16; 9] = core::array::from_fn(|_| rng.next_in(-32768, 32767) as i16);
            let slow = eng.step(&win, true);
            let fast = eng.step_fast(&win);
            assert_eq!(slow, fast, "trial {trial}");
        }
    }

    #[test]
    fn missing_prefetch_stalls() {
        let mut eng = CuEngine::new();
        let stall = eng.update_weights();
        assert_eq!(stall, (9 * 16usize).div_ceil(8) as u64);
        assert_eq!(eng.weight_stalls, stall);
    }

    #[test]
    fn double_buffering_hides_load() {
        let mut eng = CuEngine::new();
        let w = vec![1i16; 9 * NUM_CU];
        eng.prefetch_channel(&w);
        assert_eq!(eng.update_weights(), 0);
        eng.prefetch_channel(&w);
        assert_eq!(eng.update_weights(), 0);
        assert_eq!(eng.weight_stalls, 0);
    }
}
