//! AXI command front-end (paper §4.1): 16-bit bus → 128-deep command
//! FIFO → command decoder.
//!
//! The host (here: the coordinator) pushes encoded command words; the
//! decoder pulls complete commands. FIFO-full is backpressure the host
//! must respect — `push_word` returns false and the word must be
//! re-offered (tested).

use std::collections::VecDeque;

use crate::isa::{Cmd, Opcode};
use crate::CMD_FIFO_DEPTH;

#[derive(Default)]
pub struct CmdFifo {
    words: VecDeque<u16>,
    /// Words accepted over the bus (16 bits per cycle at bus clock).
    pub words_in: u64,
    /// Decoded commands.
    pub cmds_out: u64,
}

impl CmdFifo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Offer one word over the AXI bus. Returns false on backpressure
    /// (FIFO full) — the host retries.
    pub fn push_word(&mut self, w: u16) -> bool {
        if self.words.len() >= CMD_FIFO_DEPTH {
            return false;
        }
        self.words.push_back(w);
        self.words_in += 1;
        true
    }

    /// Decoder: pull one complete command if the FIFO holds one.
    /// Returns `Ok(None)` when more words are needed, `Err` on an
    /// invalid opcode (a real decoder would raise an error IRQ).
    pub fn pop_cmd(&mut self) -> Result<Option<Cmd>, u16> {
        let Some(&op_word) = self.words.front() else {
            return Ok(None);
        };
        let Some(op) = Opcode::from_u16(op_word) else {
            return Err(op_word);
        };
        let need = op.words_needed();
        if self.words.len() < need {
            return Ok(None);
        }
        let buf: Vec<u16> = self.words.iter().take(need).copied().collect();
        let mut i = 0;
        let cmd = Cmd::decode(&buf, &mut i).expect("length-checked decode");
        debug_assert_eq!(i, need);
        for _ in 0..need {
            self.words.pop_front();
        }
        self.cmds_out += 1;
        Ok(Some(cmd))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{ConvCfg, DmaDesc};

    #[test]
    fn fifo_depth_backpressure() {
        let mut f = CmdFifo::new();
        for i in 0..CMD_FIFO_DEPTH {
            assert!(f.push_word(i as u16));
        }
        assert!(!f.push_word(0xFFFF), "word 129 must be refused");
        assert_eq!(f.len(), 128);
    }

    #[test]
    fn partial_command_waits() {
        let mut f = CmdFifo::new();
        let mut words = Vec::new();
        Cmd::LoadImage(DmaDesc::flat(7, 9, 11)).encode(&mut words);
        // push all but the last word: decoder must hold off
        for &w in &words[..words.len() - 1] {
            f.push_word(w);
        }
        assert_eq!(f.pop_cmd(), Ok(None));
        f.push_word(words[words.len() - 1]);
        assert_eq!(
            f.pop_cmd(),
            Ok(Some(Cmd::LoadImage(DmaDesc::flat(7, 9, 11))))
        );
        assert!(f.is_empty());
    }

    #[test]
    fn invalid_opcode_raises() {
        let mut f = CmdFifo::new();
        f.push_word(0x00EE);
        assert_eq!(f.pop_cmd(), Err(0x00EE));
    }

    #[test]
    fn streams_multiple_commands() {
        let mut f = CmdFifo::new();
        let cmds = vec![
            Cmd::SetConv(ConvCfg { stride: 2, shift: 9, relu: true }),
            Cmd::Sync,
            Cmd::Halt,
        ];
        for w in Cmd::encode_program(&cmds) {
            assert!(f.push_word(w));
        }
        let mut got = Vec::new();
        while let Ok(Some(c)) = f.pop_cmd() {
            got.push(c);
            if c == Cmd::Halt {
                break;
            }
        }
        assert_eq!(got, cmds);
        assert_eq!(f.cmds_out, 3);
    }
}
