//! Top-level accelerator (paper Fig. 3): command decoder + DMA + buffer
//! bank + column buffer + CU engine array + accumulation buffer +
//! pooling module, glued exactly as the block diagram wires them.
//!
//! `run_program` consumes an ISA stream through the AXI FIFO and returns
//! when `Halt` retires. All compute is **functionally bit-exact** with
//! the fixed-point contract; all cycle/event accounting follows the
//! model documented in `sim/mod.rs`.

use super::accbuf::{AccBuf, ACC_TILE_PX};
use super::axi::CmdFifo;
use super::dma::{Dma, DramModel};
use super::engine::CuEngine;
use super::fastconv;
use super::sram::{BufferBank, WORD_PX};
use super::SimStats;
use crate::isa::{AddPass, Cmd, ConvCfg, ConvPass, PoolPass, PASS_DW, PASS_FIRST, PASS_LAST};
use crate::{NUM_CU, PES_PER_CU};

/// Deferred DRAM writes produced by [`Accelerator::exec_shared`]:
/// `(dram_px, row)` pairs the parallel runner publishes when the
/// segment completes.
pub type StoreLog = Vec<(usize, Vec<i16>)>;

/// Shared per-frame DRAM handle for [`Accelerator::exec_shared`].
///
/// Every access goes through the raw pointer — no `&[i16]` over the
/// backing store is ever materialized — so one DAG worker can read
/// producer canvases while another publishes its completed segment's
/// stores into a *different* pixel range of the same allocation
/// without violating Rust's aliasing rules. Data-race freedom is the
/// caller's contract: conflicting same-pixel accesses must be ordered
/// externally (the segment DAG's dependency edges, whose completion
/// counters are updated under the scheduler mutex — its release/
/// acquire pairs provide the happens-before edge); unordered accesses
/// must touch disjoint pixels (segments of one node write disjoint
/// canvas regions; weight/bias blocks are written only at compile
/// time).
pub struct SharedDram<'a> {
    ptr: *mut i16,
    len: usize,
    _backing: std::marker::PhantomData<&'a mut [i16]>,
}

// SAFETY: see the type-level contract — all cross-thread element
// accesses are either externally ordered or disjoint.
unsafe impl Sync for SharedDram<'_> {}
// SAFETY: same contract — the handle carries no thread-affine state.
unsafe impl Send for SharedDram<'_> {}

impl<'a> SharedDram<'a> {
    pub fn new(dram: &'a mut [i16]) -> Self {
        Self { ptr: dram.as_mut_ptr(), len: dram.len(), _backing: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Read `dst.len()` pixels starting at `at` into `dst`.
    pub fn read_into(&self, at: usize, dst: &mut [i16]) {
        assert!(at + dst.len() <= self.len, "DRAM read OOB");
        // SAFETY: in-bounds; raw-pointer read, and the caller orders
        // any conflicting write before/after this segment (see above).
        unsafe { std::ptr::copy_nonoverlapping(self.ptr.add(at), dst.as_mut_ptr(), dst.len()) };
    }

    /// Read `n` pixels at `at` into a fresh buffer.
    pub fn read_vec(&self, at: usize, n: usize) -> Vec<i16> {
        let mut out = vec![0i16; n];
        self.read_into(at, &mut out);
        out
    }

    /// Publish `row` at pixel `at`.
    pub fn write(&self, at: usize, row: &[i16]) {
        assert!(at + row.len() <= self.len, "DRAM write OOB");
        // SAFETY: in-bounds; raw-pointer write to pixels no unordered
        // concurrent access touches (disjoint-store contract).
        unsafe { std::ptr::copy_nonoverlapping(row.as_ptr(), self.ptr.add(at), row.len()) };
    }
}

/// Simulator knobs (microarchitecture is fixed; timing params vary).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// DRAM capacity in pixels.
    pub dram_px: usize,
    /// DRAM burst latency (cycles).
    pub dram_latency: u64,
    /// DRAM bandwidth (bytes / accelerator cycle).
    pub dram_bytes_per_cycle: f64,
    /// Model DMA/compute overlap (double buffering). When false every
    /// DMA serializes with the datapath — the "naive" baseline of the
    /// Fig. 2 / Fig. 6 comparisons.
    pub overlap_dma: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self { dram_px: 64 << 20, dram_latency: 32, dram_bytes_per_cycle: 3.2, overlap_dma: true }
    }
}

pub struct Accelerator {
    pub cfg: SimConfig,
    pub sram: BufferBank,
    pub dram: DramModel,
    pub engine: CuEngine,
    pub accbuf: AccBuf,
    pub fifo: CmdFifo,
    dma: Dma,
    conv_cfg: ConvCfg,
    /// Weight staging FIFO filled by `LoadWeights` (each entry: one
    /// pass's cn channels × 9 taps × 16 features + its DMA-ready time).
    /// Depth 2 — the shadow bank that lets the prefetch controller load
    /// the next pass's weights while the current pass computes (§4.2).
    wstage: std::collections::VecDeque<(Vec<i16>, u64)>,
    /// Total pooling comparator operations.
    pool_ops_total: u64,
    /// Reusable DMA row scratch for shared-DRAM loads (capacity only;
    /// contents never outlive one row copy).
    row_buf: Vec<i16>,
    pub stats: SimStats,
}

impl Accelerator {
    pub fn new(cfg: SimConfig) -> Self {
        let mut dram = DramModel::new(cfg.dram_px);
        dram.burst_latency = cfg.dram_latency;
        dram.bytes_per_cycle = cfg.dram_bytes_per_cycle;
        Self {
            cfg,
            sram: BufferBank::new(),
            dram,
            engine: CuEngine::new(),
            accbuf: AccBuf::new(),
            fifo: CmdFifo::new(),
            dma: Dma::default(),
            conv_cfg: ConvCfg { stride: 1, shift: 0, relu: false },
            wstage: std::collections::VecDeque::new(),
            pool_ops_total: 0,
            row_buf: Vec::new(),
            stats: SimStats::default(),
        }
    }

    /// Execute a full command program. The host-side view: stream words
    /// in, let the decoder drain. A stream that exhausts without `Halt`
    /// is a hard error — a real command decoder would hang waiting for
    /// more words, so letting it pass silently hid compiler bugs.
    pub fn run_program(&mut self, cmds: &[Cmd]) -> anyhow::Result<()> {
        let words = Cmd::encode_program(cmds);
        let mut next = 0usize;
        loop {
            // Host streams words until the FIFO pushes back.
            while next < words.len() && self.fifo.push_word(words[next]) {
                next += 1;
            }
            match self.fifo.pop_cmd() {
                Err(bad) => anyhow::bail!("invalid opcode word {bad:#06x}"),
                Ok(None) => {
                    if next >= words.len() {
                        anyhow::bail!(
                            "command stream exhausted without Halt after {} command(s) \
                             ({} word(s) left undecoded)",
                            self.stats.commands,
                            self.fifo.len()
                        );
                    }
                }
                Ok(Some(cmd)) => {
                    let halt = cmd == Cmd::Halt;
                    self.exec(cmd);
                    if halt {
                        self.sync_stats();
                        return Ok(());
                    }
                }
            }
        }
    }

    /// Execute one decoded command.
    pub fn exec(&mut self, cmd: Cmd) {
        self.stats.commands += 1;
        match cmd {
            Cmd::Nop | Cmd::Halt => {}
            Cmd::Sync => {
                // Barrier: wait for the DMA channel.
                if self.dma.busy_until > self.stats.cycles {
                    self.stats.dma_stall_cycles += self.dma.busy_until - self.stats.cycles;
                    self.stats.cycles = self.dma.busy_until;
                }
            }
            Cmd::SetConv(c) => self.conv_cfg = c,
            Cmd::LoadImage(d) => {
                // data movement (functional) + one pipelined-burst charge
                for r in 0..d.rows as usize {
                    let src = d.dram_px as usize + r * d.dram_pitch as usize;
                    let dst = d.sram_px as usize + r * d.sram_pitch as usize;
                    let n = d.row_px as usize;
                    assert!(src + n <= self.dram.data.len(), "DRAM read OOB");
                    let row = self.dram.data[src..src + n].to_vec();
                    self.sram.write_slice(dst, &row);
                }
                self.charge_dma_read(d.total_px() as u64 * 2);
            }
            Cmd::Store(d) => {
                for r in 0..d.rows as usize {
                    let src = d.sram_px as usize + r * d.sram_pitch as usize;
                    let dst = d.dram_px as usize + r * d.dram_pitch as usize;
                    let n = d.row_px as usize;
                    let row = self.sram.read_slice(src, n);
                    assert!(dst + n <= self.dram.data.len(), "DRAM write OOB");
                    self.dram.data[dst..dst + n].copy_from_slice(&row);
                }
                self.charge_dma_write(d.total_px() as u64 * 2);
            }
            Cmd::LoadWeights(w) => {
                let len = w.cn as usize * PES_PER_CU * NUM_CU;
                let (data, done) =
                    self.dma.read(&mut self.dram, w.dram_px as usize, len, self.stats.cycles);
                assert!(self.wstage.len() < 2, "weight shadow bank depth is 2 (compiler bug)");
                self.wstage.push_back((data, done));
                self.stats.weight_loads += len as u64;
                self.stats.dram_read_bytes += len as u64 * 2;
                if !self.cfg.overlap_dma {
                    self.stats.cycles = self.stats.cycles.max(done);
                }
            }
            Cmd::LoadBias(b) => {
                // 16 int32 = 32 px, little-endian halves.
                let at = b.dram_px as usize;
                let (data, done) = self.dma.read(&mut self.dram, at, 2 * NUM_CU, self.stats.cycles);
                let mut bias = [0i32; NUM_CU];
                for (m, bv) in bias.iter_mut().enumerate() {
                    let lo = data[2 * m] as u16 as u32;
                    let hi = data[2 * m + 1] as u16 as u32;
                    *bv = (lo | (hi << 16)) as i32;
                }
                self.accbuf.load_bias(&bias);
                self.stats.dram_read_bytes += (2 * NUM_CU) as u64 * 2;
                if !self.cfg.overlap_dma {
                    self.stats.cycles = self.stats.cycles.max(done);
                }
            }
            Cmd::Conv(p) => self.exec_conv(p),
            Cmd::Pool(p) => self.exec_pool(p),
            Cmd::Add(p) => self.exec_add(p),
        }
    }

    /// Element-wise residual add over SRAM-resident operands — the
    /// graph `Add` op. Functionally `requantize(a + b, shift, relu)`
    /// per pixel (bit-exact with `model::reference::add_ref`). Timing:
    /// the adder streams a word per port access, and the single-ported
    /// bank serializes the two operand reads and the write-back, so the
    /// pass costs 3 port accesses per 8-pixel word.
    fn exec_add(&mut self, p: AddPass) {
        let n = p.n_px as usize;
        let (a0, b0, d0) = (p.src_a_px as usize, p.src_b_px as usize, p.dst_px as usize);
        let (shift, relu) = (p.shift, p.relu);
        for i in 0..n {
            let a = self.sram.raw()[a0 + i];
            let b = self.sram.raw()[b0 + i];
            let v = crate::fixed::requantize(
                crate::fixed::acc_add(a as i32, b as i32),
                shift,
                relu,
            );
            self.sram.write_px(d0 + i, v);
        }
        self.sram.charge_read_px(n);
        self.sram.charge_read_px(n);
        self.sram.charge_write_px(n);
        self.stats.cycles += 3 * n.div_ceil(WORD_PX) as u64;
        self.stats.sram_reads = self.sram.reads;
        self.stats.sram_writes = self.sram.writes;
    }

    /// One convolution pass — see `ConvPass` for semantics.
    ///
    /// Channel loop outer (§4.2 filter-update-per-channel), pixels
    /// streamed inner through the column-buffer schedule. The SRAM tile
    /// is planar (channel-major): `src_px + (ch*ih + y)*iw + x`.
    fn exec_conv(&mut self, p: ConvPass) {
        if p.flags & PASS_DW != 0 {
            return self.exec_conv_dw(p);
        }
        let st = self.conv_cfg.stride as usize;
        assert!(st >= 1);
        let (ih, iw) = (p.ih as usize, p.iw as usize);
        let (oh, ow) = (p.oh as usize, p.ow as usize);
        let (dy, dx) = (p.dy as usize, p.dx as usize);
        assert!(oh * ow <= ACC_TILE_PX, "output tile exceeds ACC BUF (compiler bug)");
        // bounds: the tap's window range must stay inside the tile
        assert!(dy + (oh - 1) * st + 3 <= ih, "tap row range exceeds tile");
        assert!(dx + (ow - 1) * st + 3 <= iw, "tap col range exceeds tile");

        if p.flags & PASS_FIRST != 0 {
            self.accbuf.init_plane(0, oh * ow);
            self.stats.cycles += (oh * ow) as u64 / WORD_PX as u64 + 1;
        }

        let cn = p.cn as usize;
        // Pop this pass's weights from the shadow bank; stall until the
        // prefetch DMA has landed (0 in steady state — the previous
        // pass's compute hides it).
        let (wstage, ready) = self.wstage.pop_front().expect("Conv without LoadWeights");
        assert_eq!(
            wstage.len(),
            cn * PES_PER_CU * NUM_CU,
            "LoadWeights/Conv mismatch (compiler bug)"
        );
        if ready > self.stats.cycles {
            self.stats.dma_stall_cycles += ready - self.stats.cycles;
            self.stats.cycles = ready;
        }

        let src = p.src_px as usize;
        // Analytic per-scan timing — same numbers the historical
        // per-pixel loop charged; the functional kernel below never
        // touches it, so host-side speed cannot perturb reported cycles.
        let t = fastconv::scan_timing(ih, iw, oh, ow, st);
        let chan_w = PES_PER_CU * NUM_CU; // one channel: 9 taps × 16 features
        let scan_macs = (oh * ow * chan_w) as u64;
        // occupied lanes: mn real output features out of the 16 issued
        let mn = (p.mn as usize).clamp(1, NUM_CU);
        let scan_lane_macs = (oh * ow * PES_PER_CU * mn) as u64;
        let mut macs = 0u64;
        for ci in 0..cn {
            // §4.2: synchronized filter update at the channel boundary;
            // the prefetch controller staged this channel during the
            // previous scan (double-buffered => usually 0 stall).
            let wtap = &wstage[ci * chan_w..(ci + 1) * chan_w];
            self.engine.prefetch_channel(wtap);
            self.stats.cycles += self.engine.update_weights();

            // Plane-streaming tap-major scan: contiguous SRAM row slices
            // fused-multiply-accumulated straight into the ACC BUF plane,
            // bit-exact with the PE chain (see sim/fastconv.rs).
            let plane = src + ci * ih * iw;
            fastconv::conv_scan_tap_major(
                self.sram.raw(),
                plane,
                iw,
                st,
                (dy, dx),
                (oh, ow),
                wtap,
                self.accbuf.plane_mut(0, oh * ow),
            );
            self.engine.charge_muls(scan_macs);
            macs += scan_macs;
            self.stats.lane_macs += scan_lane_macs;

            // Column-buffer fill + streaming traffic + scan cycles
            // (compute- or stream-bound), per the analytic model.
            self.stats.cycles += t.fill_cycles;
            self.sram.charge_read_px(t.stream_px);
            self.stats.cycles += t.scan_cycles;
            self.stats.active_cycles += t.active_cycles;
        }
        self.stats.macs += macs;

        if p.flags & PASS_LAST != 0 {
            // Output stage: requantize the plane and write int16 planar
            // (16 features) to SRAM at dst_px.
            let (shift, relu) = (self.conv_cfg.shift, self.conv_cfg.relu);
            let dst = p.dst_px as usize;
            for px in 0..oh * ow {
                let q = self.accbuf.requant_px(0, px, shift, relu);
                for (m, &v) in q.iter().enumerate() {
                    // planar per-feature planes: dst + (m*oh*ow + px)
                    self.sram.write_px(dst + m * oh * ow + px, v);
                }
            }
            self.sram.charge_write_px(oh * ow * NUM_CU);
            self.stats.cycles += (oh * ow * NUM_CU).div_ceil(WORD_PX) as u64;
        }

        self.stats.sram_reads = self.sram.reads;
        self.stats.sram_writes = self.sram.writes;
        self.stats.pool_ops = self.pool_ops_total;
    }

    /// One **depthwise** convolution pass (`PASS_DW`): the 16 CU columns
    /// hold 16 *independent* 3×3 filters and lane `m` scans its own
    /// input plane, so one pass covers `cn` channels per tap instead of
    /// broadcasting one channel across 16 feature lanes. The pass loop
    /// is tap-outer (one `LoadWeights`+`Conv` per decomposed tap);
    /// `PASS_LAST` requantizes and writes `cn` channel planes at
    /// `dst + m·dpl`, row pitch `dpp` (SRAM staging for the fused
    /// DwPw path, plain planar tiles otherwise).
    fn exec_conv_dw(&mut self, p: ConvPass) {
        let st = self.conv_cfg.stride as usize;
        assert!(st >= 1);
        let (ih, iw) = (p.ih as usize, p.iw as usize);
        let (oh, ow) = (p.oh as usize, p.ow as usize);
        let (dy, dx) = (p.dy as usize, p.dx as usize);
        let cn = p.cn as usize;
        assert!((1..=NUM_CU).contains(&cn), "dw pass packs 1..=16 channel lanes");
        assert!(oh * ow <= ACC_TILE_PX, "output tile exceeds ACC BUF (compiler bug)");
        assert!(dy + (oh - 1) * st + 3 <= ih, "tap row range exceeds tile");
        assert!(dx + (ow - 1) * st + 3 <= iw, "tap col range exceeds tile");

        if p.flags & PASS_FIRST != 0 {
            self.accbuf.init_plane(0, oh * ow);
            self.stats.cycles += (oh * ow) as u64 / WORD_PX as u64 + 1;
        }

        let (wstage, ready) = self.wstage.pop_front().expect("Conv without LoadWeights");
        assert_eq!(
            wstage.len(),
            PES_PER_CU * NUM_CU,
            "dw weight block is one 9x16 tap-major block (compiler bug)"
        );
        if ready > self.stats.cycles {
            self.stats.dma_stall_cycles += ready - self.stats.cycles;
            self.stats.cycles = ready;
        }
        self.engine.prefetch_channel(&wstage);
        self.stats.cycles += self.engine.update_weights();

        let t = fastconv::dw_scan_timing(ih, iw, oh, ow, st, cn);
        fastconv::dwconv_scan_tap_major(
            self.sram.raw(),
            p.src_px as usize,
            ih * iw,
            iw,
            st,
            (dy, dx),
            (oh, ow),
            cn,
            &wstage,
            self.accbuf.plane_mut(0, oh * ow),
        );
        // the array still *issues* all 144 MACs per output pixel; only
        // cn·9 of them land on occupied lanes
        let scan_macs = (oh * ow * PES_PER_CU * NUM_CU) as u64;
        self.engine.charge_muls(scan_macs);
        self.stats.macs += scan_macs;
        self.stats.lane_macs += (oh * ow * PES_PER_CU * cn) as u64;
        self.stats.cycles += t.fill_cycles;
        self.sram.charge_read_px(t.stream_px);
        self.stats.cycles += t.scan_cycles;
        self.stats.active_cycles += t.active_cycles;

        if p.flags & PASS_LAST != 0 {
            let (shift, relu) = (self.conv_cfg.shift, self.conv_cfg.relu);
            let dst = p.dst_px as usize;
            let dpp = if p.dpp == 0 { ow } else { p.dpp as usize };
            let dpl = if p.dpl == 0 { oh * ow } else { p.dpl as usize };
            for px in 0..oh * ow {
                let q = self.accbuf.requant_px(0, px, shift, relu);
                let (y, x) = (px / ow, px % ow);
                for (m, &v) in q.iter().take(cn).enumerate() {
                    self.sram.write_px(dst + m * dpl + y * dpp + x, v);
                }
            }
            self.sram.charge_write_px(oh * ow * cn);
            self.stats.cycles += (oh * ow * cn).div_ceil(WORD_PX) as u64;
        }

        self.stats.sram_reads = self.sram.reads;
        self.stats.sram_writes = self.sram.writes;
        self.stats.pool_ops = self.pool_ops_total;
    }

    fn exec_pool(&mut self, p: PoolPass) {
        let cy = super::pool::pool_pass(
            &mut self.sram,
            p.src_px as usize,
            p.dst_px as usize,
            p.ih as usize,
            p.iw as usize,
            p.c as usize,
            p.k as usize,
            p.stride as usize,
            p.avg,
            &mut self.pool_ops_total,
        );
        self.stats.cycles += cy;
        self.stats.sram_reads = self.sram.reads;
        self.stats.sram_writes = self.sram.writes;
        self.stats.pool_ops = self.pool_ops_total;
    }
}

impl Accelerator {
    /// One pipelined-burst DMA read charge: traffic counters + channel
    /// scheduling (+ serialization when double buffering is off).
    fn charge_dma_read(&mut self, bytes: u64) {
        self.dram.read_bytes += bytes;
        self.stats.dram_read_bytes += bytes;
        let done = self.dma.schedule(&self.dram, bytes, self.stats.cycles);
        if !self.cfg.overlap_dma {
            self.stats.cycles = self.stats.cycles.max(done);
        }
    }

    fn charge_dma_write(&mut self, bytes: u64) {
        self.dram.write_bytes += bytes;
        self.stats.dram_write_bytes += bytes;
        let done = self.dma.schedule(&self.dram, bytes, self.stats.cycles);
        if !self.cfg.overlap_dma {
            self.stats.cycles = self.stats.cycles.max(done);
        }
    }

    /// Fold the cumulative SRAM/pool counters into the stats snapshot.
    /// Done at frame end — mid-run they lag until the next Conv/Pool,
    /// and the trailing Store of the last block would otherwise be
    /// dropped from the reported traffic.
    pub fn sync_stats(&mut self) {
        self.stats.sram_reads = self.sram.reads;
        self.stats.sram_writes = self.sram.writes;
        self.stats.pool_ops = self.pool_ops_total;
    }

    /// Set the conv datapath config directly. The parallel runner uses
    /// this to apply a layer's `SetConv` to every worker without
    /// re-executing (and re-counting) the command per worker.
    pub fn set_conv_cfg(&mut self, cfg: ConvCfg) {
        self.conv_cfg = cfg;
    }

    /// Reset every event/cycle counter and all transient state so a
    /// pooled instance can serve a new frame without reallocating its
    /// SRAM/DRAM backing stores. Memory *contents* are left as-is:
    /// every compiled program loads a region before reading it.
    pub fn reset_counters(&mut self) {
        self.stats = SimStats::default();
        self.sram.reads = 0;
        self.sram.writes = 0;
        self.sram.reset_alloc();
        self.dram.read_bytes = 0;
        self.dram.write_bytes = 0;
        self.dma = Dma::default();
        self.fifo = CmdFifo::new();
        self.wstage.clear();
        self.pool_ops_total = 0;
        self.accbuf.acc_ops = 0;
        self.engine.reset_counters();
        self.conv_cfg = ConvCfg { stride: 1, shift: 0, relu: false };
    }

    /// Execute one decoded command in **shared-DRAM** mode: DRAM reads
    /// come from the caller's [`SharedDram`] image, and `Store` rows
    /// are appended to `wlog` instead of written (the DAG runner
    /// publishes them when the segment completes — the decomposed work
    /// units of one node write disjoint canvas regions, and consumers
    /// are ordered behind the publish by their dependency edges).
    /// Event and cycle accounting is identical to
    /// [`Accelerator::exec`]; since every decomposed work unit ends on
    /// a `Sync` barrier, per-segment stat deltas are
    /// translation-invariant and parallel totals match a sequential run
    /// bit-for-bit (tested in `compiler::tests`).
    pub fn exec_shared(&mut self, cmd: Cmd, dram: &SharedDram, wlog: &mut StoreLog) {
        self.stats.commands += 1;
        match cmd {
            Cmd::Nop | Cmd::Halt => {}
            Cmd::Sync => {
                if self.dma.busy_until > self.stats.cycles {
                    self.stats.dma_stall_cycles += self.dma.busy_until - self.stats.cycles;
                    self.stats.cycles = self.dma.busy_until;
                }
            }
            Cmd::SetConv(c) => self.conv_cfg = c,
            Cmd::LoadImage(d) => {
                // reusable row scratch: no per-row allocation on the
                // DMA hot path (row_buf keeps its capacity across rows,
                // segments and frames)
                let n = d.row_px as usize;
                let mut row = std::mem::take(&mut self.row_buf);
                row.resize(n, 0);
                for r in 0..d.rows as usize {
                    let src = d.dram_px as usize + r * d.dram_pitch as usize;
                    let dst = d.sram_px as usize + r * d.sram_pitch as usize;
                    dram.read_into(src, &mut row);
                    self.sram.write_slice(dst, &row);
                }
                self.row_buf = row;
                self.charge_dma_read(d.total_px() as u64 * 2);
            }
            Cmd::Store(d) => {
                for r in 0..d.rows as usize {
                    let src = d.sram_px as usize + r * d.sram_pitch as usize;
                    let dst = d.dram_px as usize + r * d.dram_pitch as usize;
                    let n = d.row_px as usize;
                    let row = self.sram.read_slice(src, n);
                    assert!(dst + n <= dram.len(), "DRAM write OOB");
                    wlog.push((dst, row));
                }
                self.charge_dma_write(d.total_px() as u64 * 2);
            }
            Cmd::LoadWeights(w) => {
                let len = w.cn as usize * PES_PER_CU * NUM_CU;
                let data = dram.read_vec(w.dram_px as usize, len);
                let bytes = len as u64 * 2;
                self.dram.read_bytes += bytes;
                let done = self.dma.schedule(&self.dram, bytes, self.stats.cycles);
                assert!(self.wstage.len() < 2, "weight shadow bank depth is 2 (compiler bug)");
                self.wstage.push_back((data, done));
                self.stats.weight_loads += len as u64;
                self.stats.dram_read_bytes += bytes;
                if !self.cfg.overlap_dma {
                    self.stats.cycles = self.stats.cycles.max(done);
                }
            }
            Cmd::LoadBias(b) => {
                let len = 2 * NUM_CU;
                let data = dram.read_vec(b.dram_px as usize, len);
                let mut bias = [0i32; NUM_CU];
                for (m, bv) in bias.iter_mut().enumerate() {
                    let lo = data[2 * m] as u16 as u32;
                    let hi = data[2 * m + 1] as u16 as u32;
                    *bv = (lo | (hi << 16)) as i32;
                }
                self.accbuf.load_bias(&bias);
                let bytes = len as u64 * 2;
                self.dram.read_bytes += bytes;
                let done = self.dma.schedule(&self.dram, bytes, self.stats.cycles);
                self.stats.dram_read_bytes += bytes;
                if !self.cfg.overlap_dma {
                    self.stats.cycles = self.stats.cycles.max(done);
                }
            }
            Cmd::Conv(p) => self.exec_conv(p),
            Cmd::Pool(p) => self.exec_pool(p),
            Cmd::Add(p) => self.exec_add(p),
        }
    }

    /// DMA busy cycles (utilization reporting).
    pub fn dma_busy_cycles(&self) -> u64 {
        self.dma.busy_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_waits_for_dma() {
        let mut acc = Accelerator::new(SimConfig::default());
        acc.exec(Cmd::LoadImage(crate::isa::DmaDesc::flat(0, 0, 4096)));
        let before = acc.stats.cycles;
        acc.exec(Cmd::Sync);
        assert!(acc.stats.cycles > before, "Sync must advance to DMA completion");
        assert!(acc.stats.dma_stall_cycles > 0);
    }

    #[test]
    fn no_overlap_config_serializes() {
        let mut cfg = SimConfig::default();
        cfg.overlap_dma = false;
        let mut acc = Accelerator::new(cfg);
        acc.exec(Cmd::LoadImage(crate::isa::DmaDesc::flat(0, 0, 4096)));
        assert!(acc.stats.cycles > 0);
    }

    #[test]
    fn stream_without_halt_is_a_hard_error() {
        let mut acc = Accelerator::new(SimConfig::default());
        let err = acc.run_program(&[Cmd::Nop, Cmd::Sync]).unwrap_err();
        assert!(err.to_string().contains("without Halt"), "{err}");
        // the same stream with a Halt retires cleanly
        let mut acc = Accelerator::new(SimConfig::default());
        acc.run_program(&[Cmd::Nop, Cmd::Sync, Cmd::Halt]).unwrap();
        assert_eq!(acc.stats.commands, 3);
    }

    #[test]
    fn empty_stream_is_a_hard_error() {
        let mut acc = Accelerator::new(SimConfig::default());
        assert!(acc.run_program(&[]).is_err());
    }

    /// Shared-DRAM mode must charge identically to owned mode and defer
    /// the Store writes to the log.
    #[test]
    fn exec_shared_matches_exec_accounting() {
        let desc = crate::isa::DmaDesc::flat(0, 0, 1024);
        let store = crate::isa::DmaDesc::flat(4096, 0, 1024);

        let mut own = Accelerator::new(SimConfig { dram_px: 8192, ..SimConfig::default() });
        for c in [Cmd::LoadImage(desc), Cmd::Store(store), Cmd::Sync] {
            own.exec(c);
        }
        own.sync_stats();

        let mut shared = Accelerator::new(SimConfig { dram_px: 0, ..SimConfig::default() });
        let mut backing = vec![7i16; 8192];
        let dram = SharedDram::new(&mut backing);
        let mut wlog = StoreLog::new();
        for c in [Cmd::LoadImage(desc), Cmd::Store(store), Cmd::Sync] {
            shared.exec_shared(c, &dram, &mut wlog);
        }
        shared.sync_stats();

        assert_eq!(own.stats, shared.stats);
        assert_eq!(wlog.len(), 1);
        assert_eq!(wlog[0].0, 4096);
        assert_eq!(wlog[0].1, vec![7i16; 1024]);
    }

    /// The Add command must match the reference requantized sum and
    /// charge the single port for 2 reads + 1 write per word.
    #[test]
    fn add_command_requantizes_and_charges() {
        let mut acc = Accelerator::new(SimConfig::default());
        let vals_a: Vec<i16> = (0..16).map(|v| (v * 100 - 800) as i16).collect();
        let vals_b: Vec<i16> = (0..16).map(|v| (v * 7 + 3) as i16).collect();
        for i in 0..16 {
            acc.sram.write_px(i, vals_a[i]);
            acc.sram.write_px(100 + i, vals_b[i]);
        }
        acc.reset_counters();
        acc.exec(Cmd::Add(AddPass {
            src_a_px: 0,
            src_b_px: 100,
            dst_px: 200,
            n_px: 16,
            shift: 1,
            relu: true,
        }));
        for i in 0..16 {
            let want = crate::fixed::requantize(
                crate::fixed::acc_add(vals_a[i] as i32, vals_b[i] as i32),
                1,
                true,
            );
            assert_eq!(acc.sram.raw()[200 + i], want, "px {i}");
        }
        // 16 px = 2 words: 2+2 read words, 2 write words, 6 port cycles
        assert_eq!(acc.stats.cycles, 6);
        assert_eq!(acc.stats.sram_reads, 4);
        assert_eq!(acc.stats.sram_writes, 2);
        assert_eq!(acc.stats.commands, 1);
    }

    #[test]
    fn reset_counters_clears_a_used_instance() {
        let mut acc = Accelerator::new(SimConfig::default());
        acc.exec(Cmd::LoadImage(crate::isa::DmaDesc::flat(0, 0, 4096)));
        acc.exec(Cmd::Sync);
        acc.sync_stats();
        assert_ne!(acc.stats, SimStats::default());
        acc.reset_counters();
        acc.sync_stats();
        assert_eq!(acc.stats, SimStats::default());
        assert_eq!(acc.dma_busy_cycles(), 0);
    }
}
