//! Convolution unit (paper §4.2, Fig. 4): nine PEs + an adder tree.
//!
//! Per cycle a CU consumes one 3×3 window (presented by the column
//! buffer) and produces one int32 partial sum for its output feature.
//! Weights are double-banked: the prefetch controller fills the shadow
//! bank while the active bank computes; `swap_weights` is the §4.2
//! "synchronized filter update request" at each channel boundary.

use super::pe::Pe;

#[derive(Clone, Debug, Default)]
pub struct Cu {
    pes: [Pe; 9],
    shadow: [i16; 9],
    shadow_valid: bool,
}

impl Cu {
    /// Prefetch the next channel's 3×3 weights into the shadow bank.
    pub fn prefetch(&mut self, w: &[i16; 9]) {
        self.shadow = *w;
        self.shadow_valid = true;
    }

    /// Filter-update request: activate the shadow bank. Returns false
    /// (a stall) if the prefetch hasn't arrived.
    pub fn swap_weights(&mut self) -> bool {
        if !self.shadow_valid {
            return false;
        }
        for (pe, &w) in self.pes.iter_mut().zip(self.shadow.iter()) {
            pe.load_weight(w);
        }
        self.shadow_valid = false;
        true
    }

    /// Directly load the active bank (reset / test path).
    pub fn load_weights(&mut self, w: &[i16; 9]) {
        for (pe, &w) in self.pes.iter_mut().zip(w.iter()) {
            pe.load_weight(w);
        }
    }

    /// One cycle: 9 parallel PE multiplies + adder tree. `en` is the
    /// EN_Ctrl stride gate.
    #[inline]
    pub fn step(&mut self, window: &[i16; 9], en: bool) -> i32 {
        let mut acc = 0i32;
        for (pe, &x) in self.pes.iter_mut().zip(window.iter()) {
            let (_down, p) = pe.step(x, en);
            acc = acc.wrapping_add(p);
        }
        acc
    }

    pub fn mul_count(&self) -> u64 {
        self.pes.iter().map(|p| p.mul_count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fixed;

    #[test]
    fn dot9_matches_fixed() {
        let mut cu = Cu::default();
        let w: [i16; 9] = [1, -2, 3, -4, 5, -6, 7, -8, 9];
        let x: [i16; 9] = [9, 8, 7, 6, 5, 4, 3, 2, 1];
        cu.load_weights(&w);
        assert_eq!(cu.step(&x, true), fixed::cu_dot9(&x, &w));
        assert_eq!(cu.mul_count(), 9);
    }

    #[test]
    fn gated_step_is_zero_and_free() {
        let mut cu = Cu::default();
        cu.load_weights(&[1; 9]);
        assert_eq!(cu.step(&[100; 9], false), 0);
        assert_eq!(cu.mul_count(), 0);
    }

    #[test]
    fn swap_requires_prefetch() {
        let mut cu = Cu::default();
        assert!(!cu.swap_weights(), "swap without prefetch must stall");
        cu.prefetch(&[2; 9]);
        assert!(cu.swap_weights());
        assert_eq!(cu.step(&[1; 9], true), 18);
        // shadow consumed: a second swap stalls again
        assert!(!cu.swap_weights());
    }
}
