//! DMA controller + off-chip DRAM model (paper §4.1).
//!
//! The DRAM model charges per-burst latency and per-byte bandwidth — the
//! quantities the decomposition scheme exists to economise. Address
//! space is pixel-granular (int16). The DMA can run ahead of the
//! datapath (double buffering): `busy_until` tracks when the channel
//! frees; `Sync` commands make the datapath wait and record the
//! non-hidden stall.

/// Off-chip DRAM: backing store + timing/energy parameters.
pub struct DramModel {
    pub data: Vec<i16>,
    /// Fixed latency per DMA burst (cycles at the accelerator clock).
    pub burst_latency: u64,
    /// Sustained bandwidth: bytes per accelerator cycle.
    pub bytes_per_cycle: f64,
    pub read_bytes: u64,
    pub write_bytes: u64,
}

impl DramModel {
    /// `capacity_px` pixels of DRAM. Default timing: 32-cycle burst
    /// latency, 3.2 B/cycle (≈1.6 GB/s at 500 MHz — one 16-bit LPDDR
    /// channel, the class of part a resource-limited system carries).
    pub fn new(capacity_px: usize) -> Self {
        Self {
            data: vec![0; capacity_px],
            burst_latency: 32,
            bytes_per_cycle: 3.2,
            read_bytes: 0,
            write_bytes: 0,
        }
    }

    /// Cycles to transfer `bytes`.
    pub fn xfer_cycles(&self, bytes: u64) -> u64 {
        self.burst_latency + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// Timing-only replay of one segment's command stream: the datapath
/// clock, the serialized DMA channel, and the two-deep weight stage,
/// advanced by exactly the charge rules `Accel::exec` applies. Both the
/// planner's analytic cycle model (`planner::cost`) and the analyzer's
/// decoded-stream timing lint (`analysis`) drive this struct, so a
/// drift between them and the simulator is a drift in *one* place.
///
/// Uses the default DRAM timing (32-cycle burst, 3.2 B/cycle) — the
/// configuration every exactness gate and test runs under.
pub struct SegClock {
    /// Datapath clock (cycles since segment start).
    pub cyc: u64,
    /// Timestamp when the DMA channel frees.
    dma_free: u64,
    /// Completion timestamps of staged weight blocks (FIFO).
    wfifo: std::collections::VecDeque<u64>,
    burst_latency: u64,
    bytes_per_cycle: f64,
    /// Cycles `cyc` advanced by datapath compute.
    pub compute_cycles: u64,
    /// Cycles `cyc` stalled waiting on inbound DMA (weights/image/bias).
    pub load_stall_cycles: u64,
    /// Cycles `cyc` stalled draining outbound stores at a `Sync`.
    pub store_stall_cycles: u64,
    /// The most recent DMA queued on the channel was a store, so a
    /// subsequent `Sync` stall is charged to store drain.
    store_pending: bool,
}

impl Default for SegClock {
    fn default() -> Self {
        Self {
            cyc: 0,
            dma_free: 0,
            wfifo: std::collections::VecDeque::new(),
            burst_latency: 32,
            bytes_per_cycle: 3.2,
            compute_cycles: 0,
            load_stall_cycles: 0,
            store_stall_cycles: 0,
            store_pending: false,
        }
    }
}

impl SegClock {
    pub fn new() -> Self {
        Self::default()
    }

    fn xfer(&self, bytes: u64) -> u64 {
        self.burst_latency + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }

    /// Schedule an overlappable DMA transfer (LoadImage / LoadBias):
    /// the channel serializes, the datapath does not wait.
    pub fn dma(&mut self, bytes: u64) {
        self.dma_free = self.dma_free.max(self.cyc) + self.xfer(bytes);
        self.store_pending = false;
    }

    /// Schedule an outbound SRAM→DRAM store. Identical channel timing to
    /// `dma` — only the phase attribution of a later `Sync` stall differs.
    pub fn store(&mut self, bytes: u64) {
        self.dma_free = self.dma_free.max(self.cyc) + self.xfer(bytes);
        self.store_pending = true;
    }

    /// Schedule a weight-block fetch and stage its completion time.
    pub fn load_weights(&mut self, px: u64) {
        self.dma(px * 2);
        self.wfifo.push_back(self.dma_free);
    }

    /// A conv pass consumes the oldest staged weight block, stalling
    /// until its fetch completes.
    pub fn pop_weights(&mut self) {
        if let Some(ready) = self.wfifo.pop_front() {
            self.load_stall_cycles += ready.saturating_sub(self.cyc);
            self.cyc = self.cyc.max(ready);
        }
    }

    /// Datapath compute: advance the clock unconditionally.
    pub fn compute(&mut self, cycles: u64) {
        self.cyc += cycles;
        self.compute_cycles += cycles;
    }

    /// `Sync`: wait for the DMA channel to drain. The stall is charged
    /// to store drain when the channel tail is an outbound store, to
    /// inbound load latency otherwise — so by construction
    /// `cyc == compute_cycles + load_stall_cycles + store_stall_cycles`.
    pub fn sync(&mut self) {
        let stall = self.dma_free.saturating_sub(self.cyc);
        if self.store_pending {
            self.store_stall_cycles += stall;
        } else {
            self.load_stall_cycles += stall;
        }
        self.cyc = self.cyc.max(self.dma_free);
    }
}

/// The DMA engine: one channel, tracked by completion time.
#[derive(Default)]
pub struct Dma {
    /// Accelerator-cycle timestamp when the DMA channel becomes free.
    pub busy_until: u64,
    /// Total DMA busy cycles (for utilization reporting).
    pub busy_cycles: u64,
}

impl Dma {
    /// Timing-only scheduling of a transfer of `bytes` issued at `now`
    /// (2-D descriptors pay one burst latency, then stream). Returns the
    /// completion timestamp.
    pub fn schedule(&mut self, dram: &DramModel, bytes: u64, now: u64) -> u64 {
        let dur = dram.xfer_cycles(bytes);
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.busy_cycles += dur;
        self.busy_until
    }

    /// Schedule a DRAM→SRAM copy issued at time `now`. Returns the
    /// completion timestamp; the caller decides whether it is hidden.
    pub fn read(
        &mut self,
        dram: &mut DramModel,
        dram_px: usize,
        len_px: usize,
        now: u64,
    ) -> (Vec<i16>, u64) {
        assert!(dram_px + len_px <= dram.data.len(), "DRAM read OOB");
        let bytes = (len_px * 2) as u64;
        dram.read_bytes += bytes;
        let dur = dram.xfer_cycles(bytes);
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.busy_cycles += dur;
        (dram.data[dram_px..dram_px + len_px].to_vec(), self.busy_until)
    }

    /// Schedule an SRAM→DRAM copy issued at time `now`.
    pub fn write(
        &mut self,
        dram: &mut DramModel,
        dram_px: usize,
        src: &[i16],
        now: u64,
    ) -> u64 {
        assert!(dram_px + src.len() <= dram.data.len(), "DRAM write OOB");
        let bytes = (src.len() * 2) as u64;
        dram.write_bytes += bytes;
        dram.data[dram_px..dram_px + src.len()].copy_from_slice(src);
        let dur = dram.xfer_cycles(bytes);
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.busy_cycles += dur;
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_model() {
        let d = DramModel::new(1024);
        assert_eq!(d.xfer_cycles(0), 32);
        assert_eq!(d.xfer_cycles(32), 32 + 10);
    }

    #[test]
    fn read_write_roundtrip_and_traffic() {
        let mut dram = DramModel::new(1024);
        let mut dma = Dma::default();
        let done = dma.write(&mut dram, 100, &[1, 2, 3, 4], 0);
        assert!(done > 0);
        let (back, _) = dma.read(&mut dram, 100, 4, done);
        assert_eq!(back, vec![1, 2, 3, 4]);
        assert_eq!(dram.write_bytes, 8);
        assert_eq!(dram.read_bytes, 8);
    }

    #[test]
    fn channel_serializes() {
        let mut dram = DramModel::new(4096);
        let mut dma = Dma::default();
        let t1 = dma.write(&mut dram, 0, &[0; 1000], 0);
        // second transfer issued at time 0 must queue behind the first
        let t2 = dma.write(&mut dram, 2000, &[0; 1000], 0);
        assert!(t2 >= t1 + dram.xfer_cycles(2000));
    }

    #[test]
    #[should_panic(expected = "DRAM read OOB")]
    fn oob_checked() {
        let mut dram = DramModel::new(16);
        Dma::default().read(&mut dram, 10, 10, 0);
    }

    #[test]
    fn seg_clock_mirrors_the_charge_rules() {
        let mut c = SegClock::new();
        // bias fetch: 32 + ceil(64/3.2) = 52 channel-cycles, hidden
        c.dma(64);
        assert_eq!(c.cyc, 0);
        // weight block: 144 px = 288 B → 32 + 90 = 122, queued behind
        c.load_weights(144);
        c.sync();
        assert_eq!(c.cyc, 52 + 122);
        c.pop_weights(); // already staged — no stall
        assert_eq!(c.cyc, 174);
        c.load_weights(144); // issues at 174, ready 296
        c.compute(10);
        c.pop_weights(); // stalls the datapath to the fetch
        assert_eq!(c.cyc, 296);
    }

    #[test]
    fn seg_clock_phases_partition_the_clock() {
        let mut c = SegClock::new();
        c.load_weights(144);
        c.sync(); // inbound stall: 122 cycles
        assert_eq!(c.load_stall_cycles, 122);
        c.pop_weights(); // already staged — no further stall
        c.compute(40);
        c.store(64); // outbound: 32 + 20 = 52, queued at cyc 162
        c.sync(); // store drain stall
        assert_eq!(c.store_stall_cycles, 52);
        assert_eq!(c.compute_cycles, 40);
        // exhaustive invariant: the three phases partition the clock
        assert_eq!(c.cyc, c.compute_cycles + c.load_stall_cycles + c.store_stall_cycles);
        // and a store followed by a load re-classifies the next sync
        c.dma(64);
        c.sync();
        assert_eq!(c.cyc, c.compute_cycles + c.load_stall_cycles + c.store_stall_cycles);
    }
}
