//! DMA controller + off-chip DRAM model (paper §4.1).
//!
//! The DRAM model charges per-burst latency and per-byte bandwidth — the
//! quantities the decomposition scheme exists to economise. Address
//! space is pixel-granular (int16). The DMA can run ahead of the
//! datapath (double buffering): `busy_until` tracks when the channel
//! frees; `Sync` commands make the datapath wait and record the
//! non-hidden stall.

/// Off-chip DRAM: backing store + timing/energy parameters.
pub struct DramModel {
    pub data: Vec<i16>,
    /// Fixed latency per DMA burst (cycles at the accelerator clock).
    pub burst_latency: u64,
    /// Sustained bandwidth: bytes per accelerator cycle.
    pub bytes_per_cycle: f64,
    pub read_bytes: u64,
    pub write_bytes: u64,
}

impl DramModel {
    /// `capacity_px` pixels of DRAM. Default timing: 32-cycle burst
    /// latency, 3.2 B/cycle (≈1.6 GB/s at 500 MHz — one 16-bit LPDDR
    /// channel, the class of part a resource-limited system carries).
    pub fn new(capacity_px: usize) -> Self {
        Self {
            data: vec![0; capacity_px],
            burst_latency: 32,
            bytes_per_cycle: 3.2,
            read_bytes: 0,
            write_bytes: 0,
        }
    }

    /// Cycles to transfer `bytes`.
    pub fn xfer_cycles(&self, bytes: u64) -> u64 {
        self.burst_latency + (bytes as f64 / self.bytes_per_cycle).ceil() as u64
    }
}

/// The DMA engine: one channel, tracked by completion time.
#[derive(Default)]
pub struct Dma {
    /// Accelerator-cycle timestamp when the DMA channel becomes free.
    pub busy_until: u64,
    /// Total DMA busy cycles (for utilization reporting).
    pub busy_cycles: u64,
}

impl Dma {
    /// Timing-only scheduling of a transfer of `bytes` issued at `now`
    /// (2-D descriptors pay one burst latency, then stream). Returns the
    /// completion timestamp.
    pub fn schedule(&mut self, dram: &DramModel, bytes: u64, now: u64) -> u64 {
        let dur = dram.xfer_cycles(bytes);
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.busy_cycles += dur;
        self.busy_until
    }

    /// Schedule a DRAM→SRAM copy issued at time `now`. Returns the
    /// completion timestamp; the caller decides whether it is hidden.
    pub fn read(
        &mut self,
        dram: &mut DramModel,
        dram_px: usize,
        len_px: usize,
        now: u64,
    ) -> (Vec<i16>, u64) {
        assert!(dram_px + len_px <= dram.data.len(), "DRAM read OOB");
        let bytes = (len_px * 2) as u64;
        dram.read_bytes += bytes;
        let dur = dram.xfer_cycles(bytes);
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.busy_cycles += dur;
        (dram.data[dram_px..dram_px + len_px].to_vec(), self.busy_until)
    }

    /// Schedule an SRAM→DRAM copy issued at time `now`.
    pub fn write(
        &mut self,
        dram: &mut DramModel,
        dram_px: usize,
        src: &[i16],
        now: u64,
    ) -> u64 {
        assert!(dram_px + src.len() <= dram.data.len(), "DRAM write OOB");
        let bytes = (src.len() * 2) as u64;
        dram.write_bytes += bytes;
        dram.data[dram_px..dram_px + src.len()].copy_from_slice(src);
        let dur = dram.xfer_cycles(bytes);
        let start = self.busy_until.max(now);
        self.busy_until = start + dur;
        self.busy_cycles += dur;
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_model() {
        let d = DramModel::new(1024);
        assert_eq!(d.xfer_cycles(0), 32);
        assert_eq!(d.xfer_cycles(32), 32 + 10);
    }

    #[test]
    fn read_write_roundtrip_and_traffic() {
        let mut dram = DramModel::new(1024);
        let mut dma = Dma::default();
        let done = dma.write(&mut dram, 100, &[1, 2, 3, 4], 0);
        assert!(done > 0);
        let (back, _) = dma.read(&mut dram, 100, 4, done);
        assert_eq!(back, vec![1, 2, 3, 4]);
        assert_eq!(dram.write_bytes, 8);
        assert_eq!(dram.read_bytes, 8);
    }

    #[test]
    fn channel_serializes() {
        let mut dram = DramModel::new(4096);
        let mut dma = Dma::default();
        let t1 = dma.write(&mut dram, 0, &[0; 1000], 0);
        // second transfer issued at time 0 must queue behind the first
        let t2 = dma.write(&mut dram, 2000, &[0; 1000], 0);
        assert!(t2 >= t1 + dram.xfer_cycles(2000));
    }

    #[test]
    #[should_panic(expected = "DRAM read OOB")]
    fn oob_checked() {
        let mut dram = DramModel::new(16);
        Dma::default().read(&mut dram, 10, 10, 0);
    }
}
