//! Cycle-level, functionally bit-exact simulator of the streaming
//! accelerator (paper Figs. 2–5).
//!
//! ## Microarchitectural model
//!
//! One simulated **cycle** is one step of the CU engine array: 16 CUs ×
//! 9 PEs = 144 multiplies (the paper's peak 144 GOPS at 500 MHz = 144
//! MACs × 2 ops × f). Channels are the outer streaming loop — "when one
//! channel is scanned, a synchronized filter update request updates the
//! weights for the upcoming channel" (§4.2) — and int32 partial planes
//! accumulate in the SRAM-backed accumulation buffer across channel
//! scans and kernel-decomposition taps.
//!
//! Cycle accounting per conv pass (one 3×3 tap × `cn` channels × one
//! 16-feature group):
//!
//! ```text
//! compute cycles   = oh*ow*cn                  (1 output px / cycle / CU)
//! stream  cycles   = rows_used*iw*cn / 8       (8 px per SRAM word)
//! rmw     cycles   = oh*ow*2/8 * (multi-pass)  (int32 partial RMW)
//! pass    cycles   = max(compute, stream) + rmw + fill
//! ```
//!
//! plus DMA cycles from the DRAM model (overlappable with compute via
//! double buffering — the scheduler decides). All event counts (MACs,
//! SRAM words, DRAM bytes, weight loads) feed the energy model.

pub mod accbuf;
pub mod accel;
pub mod axi;
pub mod colbuf;
pub mod cu;
pub mod dma;
pub mod engine;
pub mod fastconv;
pub mod pe;
pub mod pool;
pub mod sram;

pub use accel::{Accelerator, SimConfig};

/// Event/cycle counters — the interface between simulation and the
/// energy/performance models.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimStats {
    /// Total cycles consumed (datapath + non-hidden DMA stalls).
    pub cycles: u64,
    /// Cycles where the CU array did useful work.
    pub active_cycles: u64,
    /// Multiply-accumulate operations actually performed.
    pub macs: u64,
    /// MACs on *occupied* lanes only: a conv pass issues 144 multiplies
    /// per cycle regardless, but only `9 × mn` of them (mn = active CU
    /// columns) feed real outputs. `macs` keeps the issued count (the
    /// energy/cost models depend on it); this counter is the numerator
    /// of the engine-width utilization the depthwise fast path improves.
    pub lane_macs: u64,
    /// SRAM word accesses (16 B words; single-port — reads + writes).
    pub sram_reads: u64,
    pub sram_writes: u64,
    /// DRAM traffic in bytes.
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    /// DMA cycles that could not be hidden behind compute.
    pub dma_stall_cycles: u64,
    /// Weight words loaded into the CU register banks.
    pub weight_loads: u64,
    /// Pooling comparator operations.
    pub pool_ops: u64,
    /// Commands executed.
    pub commands: u64,
}

impl SimStats {
    pub fn add(&mut self, o: &SimStats) {
        self.cycles += o.cycles;
        self.active_cycles += o.active_cycles;
        self.macs += o.macs;
        self.lane_macs += o.lane_macs;
        self.sram_reads += o.sram_reads;
        self.sram_writes += o.sram_writes;
        self.dram_read_bytes += o.dram_read_bytes;
        self.dram_write_bytes += o.dram_write_bytes;
        self.dma_stall_cycles += o.dma_stall_cycles;
        self.weight_loads += o.weight_loads;
        self.pool_ops += o.pool_ops;
        self.commands += o.commands;
    }

    /// CU array utilization: achieved MACs / (144 × cycles).
    pub fn utilization(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.macs as f64 / (crate::NUM_CU * crate::PES_PER_CU) as f64 / self.cycles as f64
    }

    /// Engine-width utilization: occupied-lane MACs / (144 × active
    /// cycles). A grouped depthwise lowering runs one real channel per
    /// 16-wide round (≈ 9/144 = 0.0625); the packed depthwise schedule
    /// fills all 16 lanes (→ 1.0). Active cycles, not total: this is a
    /// datapath-occupancy number, DMA stalls are accounted elsewhere.
    pub fn lane_utilization(&self) -> f64 {
        if self.active_cycles == 0 {
            return 0.0;
        }
        self.lane_macs as f64
            / (crate::NUM_CU * crate::PES_PER_CU) as f64
            / self.active_cycles as f64
    }

    /// Paper-style ops (1 MAC = 2 ops).
    pub fn ops(&self) -> u64 {
        2 * self.macs
    }
}
