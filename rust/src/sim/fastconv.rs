//! Plane-streaming, tap-major convolution fast path — the simulator's
//! hottest loop, rebuilt around the access pattern the hardware streams.
//!
//! The PE-chain model (`engine::step` / `step_accumulate`) consumes one
//! *gathered* 3×3 window per output pixel: a 9-element scalar gather
//! followed by a 9×16 scalar dot — the exact anti-pattern the streaming
//! column buffer exists to avoid, and one LLVM cannot vectorize. This
//! module computes the same channel scan as nine **tap sweeps over
//! contiguous SRAM row slices**: for tap (ty, tx), the input pixels
//! feeding output row `oy` are the row slice starting at
//! `plane + (oy·s + dy + ty)·iw + dx + tx`, and each pixel broadcasts
//! into the 16 accumulator lanes of its output pixel — a
//! splat-multiply-accumulate LLVM auto-vectorizes (no deps, no
//! intrinsics).
//!
//! **Bit-exactness.** Products are exact (i16×i16 → i32) and the ACC
//! BUF contract is *wrapping* i32 addition (`fixed::acc_add`), which is
//! associative and commutative — reordering the tap/pixel accumulation
//! cannot change any output bit. `tap_major_matches_pe_chain` below and
//! the `integration_fastpath` property suite enforce this against the
//! PE-chain engine and the scalar oracle.
//!
//! **Timing.** Not modeled here: [`ScanTiming`] is the analytic cycle
//! model of one channel scan (identical numbers to the historical
//! per-pixel loop), so the functional kernel's host speed never
//! perturbs reported cycles or traffic.

use super::sram::WORD_PX;
use crate::NUM_CU;

/// Analytic timing of one channel scan of a conv pass, decoupled from
/// the functional kernel. See `sim/mod.rs` for the cycle model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScanTiming {
    /// Column-buffer fill: two rows at 8 px/word.
    pub fill_cycles: u64,
    /// Scan cycles: max(compute, stream) — compute- or stream-bound.
    pub scan_cycles: u64,
    /// Cycles the CU array does useful work (= output pixels).
    pub active_cycles: u64,
    /// SRAM pixels streamed (used rows × tile width), for the traffic
    /// charge.
    pub stream_px: usize,
}

/// Cycle/traffic model of one channel scan over an (ih × iw) tile
/// producing (oh × ow) outputs at `stride`.
pub fn scan_timing(ih: usize, iw: usize, oh: usize, ow: usize, stride: usize) -> ScanTiming {
    let rows = ((oh - 1) * stride + 3).min(ih);
    let compute = (oh * ow) as u64;
    let stream = (rows * iw).div_ceil(WORD_PX) as u64;
    ScanTiming {
        fill_cycles: super::colbuf::fill_words(iw) as u64,
        scan_cycles: compute.max(stream),
        active_cycles: compute,
        stream_px: rows * iw,
    }
}

/// Cycle/traffic model of one *depthwise* scan: `cn` channel planes
/// stream through the single-ported bank (one word budget per plane,
/// like the per-channel scans they replace) while all `cn` lanes
/// compute in parallel — so compute is `oh·ow` once, not per channel.
pub fn dw_scan_timing(
    ih: usize,
    iw: usize,
    oh: usize,
    ow: usize,
    stride: usize,
    cn: usize,
) -> ScanTiming {
    let rows = ((oh - 1) * stride + 3).min(ih);
    let compute = (oh * ow) as u64;
    let stream = (cn * (rows * iw).div_ceil(WORD_PX)) as u64;
    ScanTiming {
        fill_cycles: super::colbuf::fill_words(iw) as u64,
        scan_cycles: compute.max(stream),
        active_cycles: compute,
        stream_px: cn * rows * iw,
    }
}

/// Accumulate one *depthwise* scan — one 3×3 tap offset at `stride` —
/// into the int32 ACC plane. Unlike [`conv_scan_tap_major`], the 16 CU
/// columns hold 16 *independent* filters (`wtap[tap·16 + m]` = channel
/// `m`'s tap) and lane `m` scans its own input plane at
/// `plane + m·plane_stride`: one pass covers `cn` channels instead of
/// broadcasting one channel to 16 feature lanes. Lanes `cn..16` are
/// left untouched (their weights are zero-padded anyway).
#[allow(clippy::too_many_arguments)]
pub fn dwconv_scan_tap_major(
    sram: &[i16],
    plane: usize,
    plane_stride: usize,
    iw: usize,
    stride: usize,
    (dy, dx): (usize, usize),
    (oh, ow): (usize, usize),
    cn: usize,
    wtap: &[i16],
    acc: &mut [i32],
) {
    assert_eq!(wtap.len(), 9 * NUM_CU, "one dw block = 9 taps x 16 channel lanes");
    assert_eq!(acc.len(), oh * ow * NUM_CU, "ACC plane shape mismatch");
    assert!((1..=NUM_CU).contains(&cn));
    assert!(stride >= 1);
    let span = (ow - 1) * stride + 1;
    for m in 0..cn {
        // lane m: a scalar 9-tap sweep over its private channel plane
        let mut w = [0i32; 9];
        for (t, wd) in w.iter_mut().enumerate() {
            *wd = wtap[t * NUM_CU + m] as i32;
        }
        let pbase = plane + m * plane_stride;
        for oy in 0..oh {
            let row0 = pbase + (oy * stride + dy) * iw + dx;
            let arow = &mut acc[oy * ow * NUM_CU..(oy + 1) * ow * NUM_CU];
            for ty in 0..3 {
                for tx in 0..3 {
                    let wm = w[ty * 3 + tx];
                    let base = row0 + ty * iw + tx;
                    let src = &sram[base..base + span];
                    for (a, &px) in
                        arow.chunks_exact_mut(NUM_CU).zip(src.iter().step_by(stride))
                    {
                        a[m] = a[m].wrapping_add((px as i32).wrapping_mul(wm));
                    }
                }
            }
        }
    }
}

/// Accumulate one channel scan — one 3×3 tap offset (`dy`, `dx`) at
/// `stride` — into the int32 ACC plane `acc` (`oh·ow` pixels × 16
/// feature lanes, pixel-major).
///
/// `wtap` is the channel's weight block in the tap-major staging layout
/// `[tap·16 + feature]` — exactly the order `LoadWeights` delivers from
/// DRAM, so no transpose happens on the hot path.
#[allow(clippy::too_many_arguments)]
pub fn conv_scan_tap_major(
    sram: &[i16],
    plane: usize,
    iw: usize,
    stride: usize,
    (dy, dx): (usize, usize),
    (oh, ow): (usize, usize),
    wtap: &[i16],
    acc: &mut [i32],
) {
    assert_eq!(wtap.len(), 9 * NUM_CU, "one channel = 9 taps x 16 features");
    assert_eq!(acc.len(), oh * ow * NUM_CU, "ACC plane shape mismatch");
    assert!(stride >= 1);
    // Pre-widen the 9×16 weights once per scan (amortized over
    // oh·ow·144 MACs).
    let mut w = [0i32; 9 * NUM_CU];
    for (wd, &ws) in w.iter_mut().zip(wtap) {
        *wd = ws as i32;
    }
    // Input columns touched by one output row of one tap column.
    let span = (ow - 1) * stride + 1;
    for oy in 0..oh {
        let row0 = plane + (oy * stride + dy) * iw + dx;
        let arow = &mut acc[oy * ow * NUM_CU..(oy + 1) * ow * NUM_CU];
        for ty in 0..3 {
            for tx in 0..3 {
                let wt = &w[(ty * 3 + tx) * NUM_CU..(ty * 3 + tx + 1) * NUM_CU];
                let base = row0 + ty * iw + tx;
                let src = &sram[base..base + span];
                // One fused multiply-accumulate sweep: contiguous row
                // pixels broadcast into 16 contiguous ACC lanes each.
                for (a, &px) in arow.chunks_exact_mut(NUM_CU).zip(src.iter().step_by(stride)) {
                    let x = px as i32;
                    for (ai, &wm) in a.iter_mut().zip(wt) {
                        *ai = ai.wrapping_add(x.wrapping_mul(wm));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::CuEngine;
    use crate::util::prop::check;

    /// The tap-major plane kernel must be bit-identical to the PE-chain
    /// engine fed gathered windows, across shapes, strides and offsets,
    /// over the full i16 value range (wrapping territory included).
    #[test]
    fn tap_major_matches_pe_chain() {
        check("fastconv == PE chain", 40, |g| {
            let stride = if g.bool() { 1 } else { 2 };
            let oh = g.usize_in(1, 10);
            let ow = g.usize_in(1, 10);
            let (dy, dx) = (g.usize_in(0, 3), g.usize_in(0, 3));
            let ih = dy + (oh - 1) * stride + 3 + g.usize_in(0, 2);
            let iw = dx + (ow - 1) * stride + 3 + g.usize_in(0, 2);
            let sram = g.vec_i16(ih * iw, -32768, 32767);
            let wtap = g.vec_i16(9 * NUM_CU, -32768, 32767);

            let mut acc = vec![0i32; oh * ow * NUM_CU];
            conv_scan_tap_major(&sram, 0, iw, stride, (dy, dx), (oh, ow), &wtap, &mut acc);

            // prefetch_channel takes the same tap-major layout as wtap
            let mut eng = CuEngine::new();
            eng.prefetch_channel(&wtap);
            eng.update_weights();
            for oy in 0..oh {
                for ox in 0..ow {
                    let (y0, x0) = (oy * stride + dy, ox * stride + dx);
                    let win: [i16; 9] =
                        core::array::from_fn(|t| sram[(y0 + t / 3) * iw + x0 + t % 3]);
                    let want = eng.step(&win, true);
                    for (m, &wv) in want.iter().enumerate() {
                        let got = acc[(oy * ow + ox) * NUM_CU + m];
                        if got != wv {
                            return Err(format!(
                                "({oy},{ox}) m={m}: fast {got} != chain {wv} \
                                 (s={stride} {oh}x{ow} dy={dy} dx={dx})"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Accumulation across scans is order-free (wrapping i32): two scans
    /// into the same plane equal the pixel-wise wrapping sum of the
    /// individual scans.
    #[test]
    fn scans_accumulate_wrapping() {
        let mut g = crate::util::prop::Gen::new(0xFA57, 64);
        let (oh, ow, iw, ih) = (4usize, 5usize, 9usize, 8usize);
        let sram = g.vec_i16(ih * iw, -32768, 32767);
        let w1 = g.vec_i16(9 * NUM_CU, -32768, 32767);
        let w2 = g.vec_i16(9 * NUM_CU, -32768, 32767);
        let mut both = vec![0i32; oh * ow * NUM_CU];
        conv_scan_tap_major(&sram, 0, iw, 1, (0, 0), (oh, ow), &w1, &mut both);
        conv_scan_tap_major(&sram, 0, iw, 1, (1, 1), (oh, ow), &w2, &mut both);
        let mut a = vec![0i32; oh * ow * NUM_CU];
        let mut b = vec![0i32; oh * ow * NUM_CU];
        conv_scan_tap_major(&sram, 0, iw, 1, (0, 0), (oh, ow), &w1, &mut a);
        conv_scan_tap_major(&sram, 0, iw, 1, (1, 1), (oh, ow), &w2, &mut b);
        for i in 0..both.len() {
            assert_eq!(both[i], a[i].wrapping_add(b[i]), "lane {i}");
        }
    }

    /// The depthwise scan must equal 16 independent single-lane scans:
    /// lane m of the dw kernel == lane m of a broadcast scan whose
    /// weight block is zero except in column m, run over plane m.
    #[test]
    fn dw_scan_matches_per_lane_broadcast_scans() {
        check("dw scan == per-lane scans", 30, |g| {
            let stride = if g.bool() { 1 } else { 2 };
            let oh = g.usize_in(1, 6);
            let ow = g.usize_in(1, 6);
            let (dy, dx) = (g.usize_in(0, 2), g.usize_in(0, 2));
            let ih = dy + (oh - 1) * stride + 3;
            let iw = dx + (ow - 1) * stride + 3;
            let cn = g.usize_in(1, NUM_CU);
            let ps = ih * iw;
            let sram = g.vec_i16(cn * ps, -32768, 32767);
            let wtap = g.vec_i16(9 * NUM_CU, -32768, 32767);

            let mut got = vec![0i32; oh * ow * NUM_CU];
            dwconv_scan_tap_major(
                &sram, 0, ps, iw, stride, (dy, dx), (oh, ow), cn, &wtap, &mut got,
            );
            for m in 0..cn {
                let mut wm = vec![0i16; 9 * NUM_CU];
                for t in 0..9 {
                    wm[t * NUM_CU + m] = wtap[t * NUM_CU + m];
                }
                let mut want = vec![0i32; oh * ow * NUM_CU];
                conv_scan_tap_major(
                    &sram, m * ps, iw, stride, (dy, dx), (oh, ow), &wm, &mut want,
                );
                for px in 0..oh * ow {
                    let (a, b) = (got[px * NUM_CU + m], want[px * NUM_CU + m]);
                    if a != b {
                        return Err(format!("lane {m} px {px}: dw {a} != broadcast {b}"));
                    }
                }
            }
            // untouched lanes stay zero
            for m in cn..NUM_CU {
                if (0..oh * ow).any(|px| got[px * NUM_CU + m] != 0) {
                    return Err(format!("lane {m} >= cn={cn} was written"));
                }
            }
            Ok(())
        });
    }

    /// Depthwise timing: compute charged once for all 16 lanes, stream
    /// charged per plane.
    #[test]
    fn dw_timing_model() {
        let t = dw_scan_timing(10, 8, 8, 6, 1, 16);
        assert_eq!(t.active_cycles, 48); // one tile scan, not 16
        assert_eq!(t.stream_px, 16 * 10 * 8);
        assert_eq!(t.scan_cycles, 16 * 10); // stream-bound: 16 planes x 80/8
        let t1 = dw_scan_timing(35, 35, 32, 32, 1, 2);
        assert_eq!(t1.active_cycles, 1024);
        assert_eq!(t1.scan_cycles, 1024.max(2 * (34 * 35usize).div_ceil(8) as u64));
    }

    /// The analytic scan timing reproduces the documented cycle model:
    /// compute-bound when oh·ow dominates, stream-bound otherwise.
    #[test]
    fn analytic_timing_model() {
        // compute-bound: 8x6 outputs from a 10x8 tile, stride 1
        let t = scan_timing(10, 8, 8, 6, 1);
        assert_eq!(t.fill_cycles, 2); // 16 px / 8 per word
        assert_eq!(t.active_cycles, 48);
        assert_eq!(t.stream_px, 10 * 8); // rows used = (8-1)+3 = 10
        assert_eq!(t.scan_cycles, 48); // max(48, 80/8=10)
        // stream-bound: 4x4 outputs from a wide 40x40 tile
        let t2 = scan_timing(40, 40, 4, 4, 1);
        assert_eq!(t2.stream_px, 6 * 40); // rows used = (4-1)+3 = 6
        assert_eq!(t2.scan_cycles, 30); // max(16, 240/8=30)
        // stride 2 rows-used clamp
        let t3 = scan_timing(9, 12, 4, 4, 2);
        assert_eq!(t3.stream_px, 9 * 12); // (4-1)*2+3 = 9 = ih
    }

    /// Partial-lane depthwise groups (trailing `cn < 16`) at stride 2:
    /// the likeliest predicted/measured drift sources in the planner's
    /// cycle model. Stream cost scales with the *actual* lane count,
    /// and a small trailing group can flip a pass from stream- to
    /// compute-bound.
    #[test]
    fn dw_partial_lane_timing_edges() {
        // stride 2, rows clamped to ih: rows = (4-1)*2+3 = 9 = ih,
        // words per plane = ceil(9*12/8) = 14.
        let t5 = dw_scan_timing(9, 12, 4, 4, 2, 5);
        assert_eq!(t5.fill_cycles, 3); // 24 px / 8 per word
        assert_eq!(t5.active_cycles, 16);
        assert_eq!(t5.stream_px, 5 * 9 * 12);
        assert_eq!(t5.scan_cycles, 5 * 14); // stream-bound at 5 lanes

        // the same pass with a single trailing lane is compute-bound:
        // one plane streams in 14 words < 16 output pixels.
        let t1 = dw_scan_timing(9, 12, 4, 4, 2, 1);
        assert_eq!(t1.scan_cycles, 16);
        assert_eq!(t1.stream_px, 9 * 12);

        // crossover sits exactly at cn = 2 (28 words > 16 px)
        assert_eq!(dw_scan_timing(9, 12, 4, 4, 2, 2).scan_cycles, 28);

        // scan cycles are monotone nondecreasing in the lane count and
        // match the documented max(compute, cn·words) at every cn —
        // full group (16), trailing groups, and the stride-2 clamp.
        for (ih, iw, oh, ow, st) in [(9, 12, 4, 4, 2), (11, 11, 5, 5, 2), (10, 8, 8, 6, 1)] {
            let rows = ((oh - 1) * st + 3).min(ih);
            let words = (rows * iw).div_ceil(WORD_PX) as u64;
            let mut prev = 0;
            for cn in 1..=NUM_CU {
                let t = dw_scan_timing(ih, iw, oh, ow, st, cn);
                assert_eq!(t.scan_cycles, ((oh * ow) as u64).max(cn as u64 * words));
                assert!(t.scan_cycles >= prev, "scan not monotone at cn={cn}");
                prev = t.scan_cycles;
            }
        }
    }
}
