//! The accelerator command set (paper §4.1).
//!
//! Commands are streamed over the 16-bit AXI bus into a 128-deep command
//! FIFO; the on-chip command decoder pulls words and drives the blocks.
//! Each command is an opcode word followed by fixed-length operand words
//! (16-bit each, little-endian packing of wider fields).
//!
//! The compiler (`compiler/codegen.rs`) emits exactly this stream; the
//! simulator's AXI front-end (`sim/axi.rs`) decodes it back. Encode →
//! decode round-trips are property-tested.

/// Opcode values (the first 16-bit word of every command).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u16)]
pub enum Opcode {
    Nop = 0x0000,
    /// Configure the conv datapath for the following `Conv` passes.
    SetConv = 0x0001,
    /// DMA: DRAM → SRAM (input tile / apron).
    LoadImage = 0x0002,
    /// DMA: weight block DRAM → CU prefetch buffer.
    LoadWeights = 0x0003,
    /// Run one convolution pass (one 3×3 tap × channel range × 16-feature
    /// tile) over the configured tile.
    Conv = 0x0004,
    /// Run the streaming pooling module over an SRAM region.
    Pool = 0x0005,
    /// DMA: SRAM → DRAM (output tile).
    Store = 0x0006,
    /// Barrier: wait until DMA + datapath are idle.
    Sync = 0x0007,
    /// DMA: 16 int32 bias words DRAM → ACC BUF bias registers.
    LoadBias = 0x0008,
    /// Element-wise residual add over two SRAM regions (graph `Add` op).
    Add = 0x0009,
    /// End of command stream.
    Halt = 0x000F,
}

impl Opcode {
    pub fn from_u16(v: u16) -> Option<Opcode> {
        Some(match v {
            0x0000 => Opcode::Nop,
            0x0001 => Opcode::SetConv,
            0x0002 => Opcode::LoadImage,
            0x0003 => Opcode::LoadWeights,
            0x0004 => Opcode::Conv,
            0x0005 => Opcode::Pool,
            0x0006 => Opcode::Store,
            0x0007 => Opcode::Sync,
            0x0008 => Opcode::LoadBias,
            0x0009 => Opcode::Add,
            0x000F => Opcode::Halt,
            _ => return None,
        })
    }
}

impl Opcode {
    /// Total 16-bit words of a command with this opcode (incl. opcode).
    pub fn words_needed(self) -> usize {
        match self {
            Opcode::Nop | Opcode::Halt | Opcode::Sync => 1,
            Opcode::SetConv => 2,
            Opcode::LoadImage | Opcode::Store => 12,
            Opcode::LoadWeights => 4,
            Opcode::LoadBias => 3,
            Opcode::Conv => 18,
            Opcode::Pool => 9,
            Opcode::Add => 10,
        }
    }
}

/// Conv datapath configuration (persists until the next `SetConv`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvCfg {
    /// Convolution stride (EN_Ctrl gating for stride > 1).
    pub stride: u8,
    /// Requantization shift of the ACC BUF output stage.
    pub shift: u8,
    /// ReLU at the output stage.
    pub relu: bool,
}

/// One convolution pass.
///
/// The pass streams input channels `c0..c0+cn` of an SRAM-resident tile
/// of shape (`ih`, `iw`, `ctot`) located at `src_px` (pixel units),
/// computes a 3×3 conv tap offset by (`dy`, `dx`) with stride from the
/// active [`ConvCfg`], and accumulates int32 partials for a 16-feature
/// group into the partial plane at `acc_px`. `FIRST` initialises the
/// plane with the bias, `LAST` requantizes to int16 at `dst_px`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvPass {
    pub src_px: u32,
    pub acc_px: u32,
    pub dst_px: u32,
    pub ih: u16,
    pub iw: u16,
    /// Total channels of the SRAM tile (addressing pitch).
    pub ctot: u16,
    /// First channel and channel count of this pass.
    pub c0: u16,
    pub cn: u16,
    /// Output tile shape.
    pub oh: u16,
    pub ow: u16,
    /// Kernel-decomposition tap offset.
    pub dy: u8,
    pub dx: u8,
    pub flags: u8, // bit0 FIRST, bit1 LAST, bit2 DW
    /// Active output lanes of this pass (1..=16): the CU columns whose
    /// features (or, under `PASS_DW`, channels) are real rather than
    /// zero-padded. Pure accounting — the datapath always runs 16 wide.
    pub mn: u16,
    /// `PASS_DW` LAST-pass destination layout: row pitch of each output
    /// plane (0 ⇒ `ow`, contiguous) ...
    pub dpp: u16,
    /// ... and plane stride in pixels (0 ⇒ `oh*ow`). A fused DwPw
    /// schedule points these at a margined SRAM staging canvas the
    /// following pointwise pass reads as its input tile.
    pub dpl: u16,
}

pub const PASS_FIRST: u8 = 1 << 0;
pub const PASS_LAST: u8 = 1 << 1;
/// Depthwise pass: the 144-px weight block holds 16 *independent* 3×3
/// filters (CU column m = channel `c0+m`'s taps) and lane m scans its
/// own input plane `src_px + m·ih·iw` — 16 channel planes per round
/// instead of one channel broadcast to all 16 feature lanes.
pub const PASS_DW: u8 = 1 << 2;

/// 2-D DMA descriptor (pixel-granular; 1 px = 2 bytes): `rows` rows of
/// `row_px` pixels, with independent DRAM/SRAM row pitches — the shape
/// every tile/canvas transfer needs. A flat copy is `rows == 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DmaDesc {
    pub dram_px: u32,
    pub sram_px: u32,
    pub row_px: u32,
    pub rows: u16,
    pub dram_pitch: u32,
    pub sram_pitch: u32,
}

impl DmaDesc {
    /// Flat 1-D copy.
    pub fn flat(dram_px: u32, sram_px: u32, len_px: u32) -> Self {
        Self { dram_px, sram_px, row_px: len_px, rows: 1, dram_pitch: len_px, sram_pitch: len_px }
    }

    pub fn total_px(&self) -> u32 {
        self.row_px * self.rows as u32
    }
}

/// Weight-block prefetch: 9 taps × `cn` channels × 16 features starting
/// at DRAM address `dram_px`, into the CU weight-register shadow bank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WeightLoad {
    pub dram_px: u32,
    pub cn: u16,
}

/// Bias prefetch: 16 int32 values (32 px) at `dram_px` into the ACC BUF
/// bias registers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BiasLoad {
    pub dram_px: u32,
}

/// Element-wise residual add (graph `Add` op): reads `n_px` int16
/// pixels at `src_a_px` and `src_b_px`, writes
/// `requantize(a + b, shift, relu)` at `dst_px` — the same round-half-
/// up/saturate/ReLU output stage a conv pass ends with, applied to the
/// int32 sum. All three regions are SRAM and must be disjoint (the
/// compiler plans them).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AddPass {
    pub src_a_px: u32,
    pub src_b_px: u32,
    pub dst_px: u32,
    pub n_px: u32,
    pub shift: u8,
    pub relu: bool,
}

/// Pooling pass over an SRAM region (int16 plane, C-interleaved).
///
/// `k` and `stride` are 6-bit fields (≤ 63) packed with the `avg` mode
/// bit into one word: max pooling drives the §4.3 comparator (window 2
/// or 3), average pooling swaps it for the accumulate-and-divide path,
/// whose serial adder also covers global-average-pool windows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PoolPass {
    pub src_px: u32,
    pub dst_px: u32,
    pub ih: u16,
    pub iw: u16,
    pub c: u16,
    pub k: u8,
    pub stride: u8,
    pub avg: bool,
}

/// Decoded command.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmd {
    Nop,
    SetConv(ConvCfg),
    LoadImage(DmaDesc),
    LoadWeights(WeightLoad),
    LoadBias(BiasLoad),
    Conv(ConvPass),
    Pool(PoolPass),
    Add(AddPass),
    Store(DmaDesc),
    Sync,
    Halt,
}

fn push32(words: &mut Vec<u16>, v: u32) {
    words.push((v & 0xFFFF) as u16);
    words.push((v >> 16) as u16);
}

fn read32(words: &[u16], i: &mut usize) -> Option<u32> {
    let lo = *words.get(*i)? as u32;
    let hi = *words.get(*i + 1)? as u32;
    *i += 2;
    Some(lo | (hi << 16))
}

fn read16(words: &[u16], i: &mut usize) -> Option<u16> {
    let v = *words.get(*i)?;
    *i += 1;
    Some(v)
}

impl Cmd {
    /// Encode to the 16-bit AXI word stream.
    pub fn encode(&self, out: &mut Vec<u16>) {
        match self {
            Cmd::Nop => out.push(Opcode::Nop as u16),
            Cmd::Halt => out.push(Opcode::Halt as u16),
            Cmd::Sync => out.push(Opcode::Sync as u16),
            Cmd::SetConv(c) => {
                out.push(Opcode::SetConv as u16);
                out.push((c.stride as u16) | ((c.shift as u16) << 4) | ((c.relu as u16) << 12));
            }
            Cmd::LoadImage(d) | Cmd::Store(d) => {
                out.push(if matches!(self, Cmd::LoadImage(_)) {
                    Opcode::LoadImage as u16
                } else {
                    Opcode::Store as u16
                });
                push32(out, d.dram_px);
                push32(out, d.sram_px);
                push32(out, d.row_px);
                out.push(d.rows);
                push32(out, d.dram_pitch);
                push32(out, d.sram_pitch);
            }
            Cmd::LoadWeights(w) => {
                out.push(Opcode::LoadWeights as u16);
                push32(out, w.dram_px);
                out.push(w.cn);
            }
            Cmd::LoadBias(b) => {
                out.push(Opcode::LoadBias as u16);
                push32(out, b.dram_px);
            }
            Cmd::Conv(p) => {
                out.push(Opcode::Conv as u16);
                push32(out, p.src_px);
                push32(out, p.acc_px);
                push32(out, p.dst_px);
                out.extend_from_slice(&[
                    p.ih,
                    p.iw,
                    p.ctot,
                    p.c0,
                    p.cn,
                    p.oh,
                    p.ow,
                    (p.dy as u16) | ((p.dx as u16) << 4) | ((p.flags as u16) << 8),
                    p.mn,
                    p.dpp,
                    p.dpl,
                ]);
            }
            Cmd::Pool(p) => {
                out.push(Opcode::Pool as u16);
                push32(out, p.src_px);
                push32(out, p.dst_px);
                let packed =
                    (p.k as u16 & 0x3F) | ((p.stride as u16 & 0x3F) << 6) | ((p.avg as u16) << 12);
                out.extend_from_slice(&[p.ih, p.iw, p.c, packed]);
            }
            Cmd::Add(p) => {
                out.push(Opcode::Add as u16);
                push32(out, p.src_a_px);
                push32(out, p.src_b_px);
                push32(out, p.dst_px);
                push32(out, p.n_px);
                out.push((p.shift as u16) | ((p.relu as u16) << 8));
            }
        }
    }

    /// Decode one command starting at `*i`; advances `*i`.
    pub fn decode(words: &[u16], i: &mut usize) -> Option<Cmd> {
        let op = Opcode::from_u16(read16(words, i)?)?;
        Some(match op {
            Opcode::Nop => Cmd::Nop,
            Opcode::Halt => Cmd::Halt,
            Opcode::Sync => Cmd::Sync,
            Opcode::SetConv => {
                let v = read16(words, i)?;
                Cmd::SetConv(ConvCfg {
                    stride: (v & 0xF) as u8,
                    shift: ((v >> 4) & 0xFF) as u8,
                    relu: (v >> 12) & 1 == 1,
                })
            }
            Opcode::LoadImage | Opcode::Store => {
                let d = DmaDesc {
                    dram_px: read32(words, i)?,
                    sram_px: read32(words, i)?,
                    row_px: read32(words, i)?,
                    rows: read16(words, i)?,
                    dram_pitch: read32(words, i)?,
                    sram_pitch: read32(words, i)?,
                };
                if op == Opcode::LoadImage {
                    Cmd::LoadImage(d)
                } else {
                    Cmd::Store(d)
                }
            }
            Opcode::LoadWeights => Cmd::LoadWeights(WeightLoad {
                dram_px: read32(words, i)?,
                cn: read16(words, i)?,
            }),
            Opcode::LoadBias => Cmd::LoadBias(BiasLoad { dram_px: read32(words, i)? }),
            Opcode::Conv => {
                let src_px = read32(words, i)?;
                let acc_px = read32(words, i)?;
                let dst_px = read32(words, i)?;
                let ih = read16(words, i)?;
                let iw = read16(words, i)?;
                let ctot = read16(words, i)?;
                let c0 = read16(words, i)?;
                let cn = read16(words, i)?;
                let oh = read16(words, i)?;
                let ow = read16(words, i)?;
                let packed = read16(words, i)?;
                let mn = read16(words, i)?;
                let dpp = read16(words, i)?;
                let dpl = read16(words, i)?;
                Cmd::Conv(ConvPass {
                    src_px,
                    acc_px,
                    dst_px,
                    ih,
                    iw,
                    ctot,
                    c0,
                    cn,
                    oh,
                    ow,
                    dy: (packed & 0xF) as u8,
                    dx: ((packed >> 4) & 0xF) as u8,
                    flags: ((packed >> 8) & 0xFF) as u8,
                    mn,
                    dpp,
                    dpl,
                })
            }
            Opcode::Pool => {
                let src_px = read32(words, i)?;
                let dst_px = read32(words, i)?;
                let ih = read16(words, i)?;
                let iw = read16(words, i)?;
                let c = read16(words, i)?;
                let packed = read16(words, i)?;
                Cmd::Pool(PoolPass {
                    src_px,
                    dst_px,
                    ih,
                    iw,
                    c,
                    k: (packed & 0x3F) as u8,
                    stride: ((packed >> 6) & 0x3F) as u8,
                    avg: (packed >> 12) & 1 == 1,
                })
            }
            Opcode::Add => {
                let src_a_px = read32(words, i)?;
                let src_b_px = read32(words, i)?;
                let dst_px = read32(words, i)?;
                let n_px = read32(words, i)?;
                let packed = read16(words, i)?;
                Cmd::Add(AddPass {
                    src_a_px,
                    src_b_px,
                    dst_px,
                    n_px,
                    shift: (packed & 0xFF) as u8,
                    relu: (packed >> 8) & 1 == 1,
                })
            }
        })
    }

    /// Encode a whole program.
    pub fn encode_program(cmds: &[Cmd]) -> Vec<u16> {
        let mut out = Vec::new();
        for c in cmds {
            c.encode(&mut out);
        }
        out
    }

    /// Decode a whole program (stops at Halt or end of stream). A
    /// malformed stream reports *where* and *why* it failed — the word
    /// offset, the command index, and the opcode context — so the
    /// static analyzer and any other consumer of raw command streams
    /// can point at the offending word.
    pub fn decode_program(words: &[u16]) -> Result<Vec<Cmd>, DecodeError> {
        let mut i = 0;
        let mut cmds = Vec::new();
        while i < words.len() {
            let at = i;
            let op = Opcode::from_u16(words[at]).ok_or(DecodeError {
                word: at,
                cmd: cmds.len(),
                kind: DecodeErrorKind::BadOpcode(words[at]),
            })?;
            let need = op.words_needed();
            if at + need > words.len() {
                return Err(DecodeError {
                    word: at,
                    cmd: cmds.len(),
                    kind: DecodeErrorKind::Truncated { opcode: op, have: words.len() - at, need },
                });
            }
            let c = Cmd::decode(words, &mut i).expect("length-checked decode");
            let is_halt = c == Cmd::Halt;
            cmds.push(c);
            if is_halt {
                break;
            }
        }
        Ok(cmds)
    }
}

/// Why one command of a word stream failed to decode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeErrorKind {
    /// The opcode word holds no known opcode.
    BadOpcode(u16),
    /// The stream ends before the command's operand words do.
    Truncated { opcode: Opcode, have: usize, need: usize },
}

/// Decode failure with full context: the 16-bit word offset of the
/// failing command's opcode word, the index of that command in the
/// stream, and the failure kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeError {
    pub word: usize,
    pub cmd: usize,
    pub kind: DecodeErrorKind,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.kind {
            DecodeErrorKind::BadOpcode(w) => write!(
                f,
                "bad opcode word {w:#06x} at word {} (command {})",
                self.word, self.cmd
            ),
            DecodeErrorKind::Truncated { opcode, have, need } => write!(
                f,
                "truncated {opcode:?} at word {} (command {}): {have} of {need} words",
                self.word, self.cmd
            ),
        }
    }
}

impl std::error::Error for DecodeError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Gen};

    fn arb_cmd(g: &mut Gen) -> Cmd {
        match g.usize_in(0, 9) {
            0 => Cmd::Nop,
            8 => Cmd::LoadBias(BiasLoad { dram_px: g.int(0, i64::from(u32::MAX)) as u32 }),
            9 => Cmd::Add(AddPass {
                src_a_px: g.int(0, 65535) as u32,
                src_b_px: g.int(0, 65535) as u32,
                dst_px: g.int(0, 65535) as u32,
                n_px: g.int(1, 65535) as u32,
                shift: g.usize_in(0, 24) as u8,
                relu: g.bool(),
            }),
            1 => Cmd::SetConv(ConvCfg {
                stride: g.usize_in(1, 4) as u8,
                shift: g.usize_in(0, 24) as u8,
                relu: g.bool(),
            }),
            2 => Cmd::LoadImage(DmaDesc {
                dram_px: g.int(0, i64::from(u32::MAX)) as u32,
                sram_px: g.int(0, 65535) as u32,
                row_px: g.int(1, 65535) as u32,
                rows: g.usize_in(1, 512) as u16,
                dram_pitch: g.int(0, 65535) as u32,
                sram_pitch: g.int(0, 65535) as u32,
            }),
            3 => Cmd::LoadWeights(WeightLoad {
                dram_px: g.int(0, i64::from(u32::MAX)) as u32,
                cn: g.usize_in(1, 512) as u16,
            }),
            4 => Cmd::Conv(ConvPass {
                src_px: g.int(0, 65535) as u32,
                acc_px: g.int(0, 65535) as u32,
                dst_px: g.int(0, 65535) as u32,
                ih: g.usize_in(3, 256) as u16,
                iw: g.usize_in(3, 256) as u16,
                ctot: g.usize_in(1, 512) as u16,
                c0: g.usize_in(0, 256) as u16,
                cn: g.usize_in(1, 256) as u16,
                oh: g.usize_in(1, 256) as u16,
                ow: g.usize_in(1, 256) as u16,
                dy: g.usize_in(0, 9) as u8,
                dx: g.usize_in(0, 9) as u8,
                flags: g.usize_in(0, 7) as u8,
                mn: g.usize_in(1, 16) as u16,
                dpp: g.usize_in(0, 4096) as u16,
                dpl: g.usize_in(0, 4096) as u16,
            }),
            5 => {
                let avg = g.bool();
                Cmd::Pool(PoolPass {
                    src_px: g.int(0, 65535) as u32,
                    dst_px: g.int(0, 65535) as u32,
                    ih: g.usize_in(2, 256) as u16,
                    iw: g.usize_in(2, 256) as u16,
                    c: g.usize_in(1, 64) as u16,
                    k: if avg { g.usize_in(2, 63) as u8 } else { *g.choose(&[2u8, 3]) },
                    stride: g.usize_in(1, 63) as u8,
                    avg,
                })
            }
            6 => Cmd::Store(DmaDesc {
                dram_px: g.int(0, i64::from(u32::MAX)) as u32,
                sram_px: g.int(0, 65535) as u32,
                row_px: g.int(1, 65535) as u32,
                rows: g.usize_in(1, 512) as u16,
                dram_pitch: g.int(0, 65535) as u32,
                sram_pitch: g.int(0, 65535) as u32,
            }),
            _ => Cmd::Sync,
        }
    }

    #[test]
    fn roundtrip_property() {
        check("isa encode/decode roundtrip", 500, |g| {
            let cmd = arb_cmd(g);
            let mut words = Vec::new();
            cmd.encode(&mut words);
            let mut i = 0;
            match Cmd::decode(&words, &mut i) {
                Some(back) if back == cmd && i == words.len() => Ok(()),
                Some(back) => Err(format!("{cmd:?} -> {back:?} (i={i}/{})", words.len())),
                None => Err(format!("{cmd:?} failed to decode")),
            }
        });
    }

    #[test]
    fn program_roundtrip_with_halt() {
        check("program roundtrip", 100, |g| {
            let n = g.usize_in(0, 20);
            let mut cmds: Vec<Cmd> = (0..n).map(|_| arb_cmd(g)).collect();
            cmds.push(Cmd::Halt);
            let words = Cmd::encode_program(&cmds);
            match Cmd::decode_program(&words) {
                Ok(back) if back == cmds => Ok(()),
                other => Err(format!("{} cmds -> {other:?}", cmds.len())),
            }
        });
    }

    #[test]
    fn decode_program_reports_offset_and_opcode() {
        // A junk opcode word mid-stream names the word and command index.
        let mut words = Cmd::encode_program(&[Cmd::Sync, Cmd::Nop]);
        words.push(0x00fe);
        let err = Cmd::decode_program(&words).unwrap_err();
        assert_eq!(err.word, 2);
        assert_eq!(err.cmd, 2);
        assert_eq!(err.kind, DecodeErrorKind::BadOpcode(0x00fe));

        // A stream cut mid-command names the opcode and the shortfall.
        let mut words = Vec::new();
        Cmd::LoadBias(BiasLoad { dram_px: 9 }).encode(&mut words);
        words.truncate(2);
        let err = Cmd::decode_program(&words).unwrap_err();
        assert_eq!(err.word, 0);
        assert_eq!(err.cmd, 0);
        assert_eq!(
            err.kind,
            DecodeErrorKind::Truncated { opcode: Opcode::LoadBias, have: 2, need: 3 }
        );
    }

    #[test]
    fn bad_opcode_rejected() {
        assert!(Cmd::decode(&[0x00FE], &mut 0).is_none());
    }

    #[test]
    fn truncated_command_rejected() {
        let mut words = Vec::new();
        Cmd::LoadImage(DmaDesc::flat(1, 2, 3)).encode(&mut words);
        words.truncate(3);
        assert!(Cmd::decode(&words, &mut 0).is_none());
    }
}
