//! Structured fleet event log: coordinator lifecycle events (faults,
//! retries, failovers, health transitions, admission rejects, DVFS
//! auto-picks) with monotonic sequence numbers, so fault-handling
//! *ordering* is testable instead of inferred from log text.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::json::{num, obj, s, Json};
use crate::util::sync::lock_recover;

/// What happened. `name()` is the stable kebab-case identifier used in
/// the JSONL stream, trace instant names and Prometheus label values.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A seeded fault fired on a chip (detail says which kind).
    FaultInjected,
    /// A failed attempt was re-queued on the same chip.
    Retry,
    /// A failed attempt was re-routed to a different chip.
    Failover,
    /// A frame exhausted its retry budget and was delivered as an error.
    RetriesExhausted,
    /// A frame had no routable chip left and was delivered as an error.
    ChipsUnavailable,
    /// An attempt exceeded the per-attempt deadline.
    DeadlineMiss,
    /// Admission control rejected a submission.
    AdmissionReject,
    /// A chip was marked dead (fault injection or organic worker death).
    ChipDead,
    /// Consecutive failures quarantined a chip for its cooldown.
    ChipQuarantined,
    /// A failure degraded a chip (sheds admission weight).
    ChipDegraded,
    /// A success restored a degraded chip to healthy.
    ChipHealed,
    /// A quarantined chip's cooldown expired; re-admitted degraded.
    ChipReadmitted,
    /// The DVFS auto-picker selected an operating point.
    AutoPick,
}

/// Every kind, for exhaustive exposition/reporting sweeps.
pub const EVENT_KINDS: [EventKind; 13] = [
    EventKind::FaultInjected,
    EventKind::Retry,
    EventKind::Failover,
    EventKind::RetriesExhausted,
    EventKind::ChipsUnavailable,
    EventKind::DeadlineMiss,
    EventKind::AdmissionReject,
    EventKind::ChipDead,
    EventKind::ChipQuarantined,
    EventKind::ChipDegraded,
    EventKind::ChipHealed,
    EventKind::ChipReadmitted,
    EventKind::AutoPick,
];

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::FaultInjected => "fault-injected",
            EventKind::Retry => "retry",
            EventKind::Failover => "failover",
            EventKind::RetriesExhausted => "retries-exhausted",
            EventKind::ChipsUnavailable => "chips-unavailable",
            EventKind::DeadlineMiss => "deadline-miss",
            EventKind::AdmissionReject => "admission-reject",
            EventKind::ChipDead => "chip-dead",
            EventKind::ChipQuarantined => "chip-quarantined",
            EventKind::ChipDegraded => "chip-degraded",
            EventKind::ChipHealed => "chip-healed",
            EventKind::ChipReadmitted => "chip-readmitted",
            EventKind::AutoPick => "dvfs-auto-pick",
        }
    }

    /// Chip health state machine transitions (for the
    /// `kn_chip_health_transitions_total` counter).
    pub fn is_health_transition(&self) -> bool {
        matches!(
            self,
            EventKind::ChipDead
                | EventKind::ChipQuarantined
                | EventKind::ChipDegraded
                | EventKind::ChipHealed
                | EventKind::ChipReadmitted
        )
    }
}

/// One logged lifecycle event. `seq` is assigned under the log's lock,
/// so it is a total order over the whole fleet: if quarantine's `seq`
/// is below re-admission's, quarantine *happened first*.
#[derive(Clone, Debug)]
pub struct FleetEvent {
    /// Monotonic, gapless sequence number (0-based).
    pub seq: u64,
    /// Microseconds since the log epoch.
    pub t_us: u64,
    pub kind: EventKind,
    /// Chip the event concerns, if any.
    pub chip: Option<usize>,
    /// Frame id the event concerns, if any.
    pub frame: Option<u64>,
    /// Human-readable specifics ("transient fault", "cooldown over", …).
    pub detail: String,
}

impl FleetEvent {
    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(num).unwrap_or(Json::Null);
        obj(vec![
            ("seq", num(self.seq as f64)),
            ("t_us", num(self.t_us as f64)),
            ("kind", s(self.kind.name())),
            ("chip", opt(self.chip.map(|c| c as f64))),
            ("frame", opt(self.frame.map(|f| f as f64))),
            ("detail", s(&self.detail)),
        ])
    }
}

/// The fleet event log. Sequence numbers are assigned while holding the
/// event vector's lock, so `events()[i].seq == i` always — monotonic and
/// gapless by construction. Locking is poison-tolerant: the log must
/// survive the very crashes it exists to describe.
pub struct EventLog {
    epoch: Instant,
    events: Mutex<Vec<FleetEvent>>,
}

impl Default for EventLog {
    fn default() -> Self {
        Self::new()
    }
}

impl EventLog {
    pub fn new() -> Self {
        Self::with_epoch(Instant::now())
    }

    pub fn with_epoch(epoch: Instant) -> Self {
        Self { epoch, events: Mutex::new(Vec::new()) }
    }

    /// Record an event; returns its sequence number.
    pub fn emit(
        &self,
        kind: EventKind,
        chip: Option<usize>,
        frame: Option<u64>,
        detail: String,
    ) -> u64 {
        let t_us = self.epoch.elapsed().as_micros() as u64;
        let mut ev = lock_recover(&self.events);
        let seq = ev.len() as u64;
        ev.push(FleetEvent { seq, t_us, kind, chip, frame, detail });
        seq
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.events).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of all events in sequence order.
    pub fn events(&self) -> Vec<FleetEvent> {
        lock_recover(&self.events).clone()
    }

    /// How many events of `kind` have been logged.
    pub fn count(&self, kind: EventKind) -> u64 {
        lock_recover(&self.events).iter().filter(|e| e.kind == kind).count() as u64
    }

    /// The whole log as JSON Lines (one object per event, seq order).
    pub fn to_jsonl(&self) -> String {
        let ev = lock_recover(&self.events);
        let mut out = String::new();
        for e in ev.iter() {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
        out
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_jsonl())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_is_monotonic_and_gapless() {
        let log = EventLog::new();
        assert_eq!(log.emit(EventKind::FaultInjected, Some(1), Some(7), "x".into()), 0);
        assert_eq!(log.emit(EventKind::Retry, Some(1), Some(7), "y".into()), 1);
        assert_eq!(log.emit(EventKind::ChipDead, Some(1), None, "z".into()), 2);
        for (i, e) in log.events().iter().enumerate() {
            assert_eq!(e.seq, i as u64);
        }
        assert_eq!(log.count(EventKind::Retry), 1);
        assert_eq!(log.len(), 3);
    }

    #[test]
    fn jsonl_parses_line_by_line() {
        let log = EventLog::new();
        log.emit(EventKind::ChipQuarantined, Some(2), None, "3 consecutive failures".into());
        log.emit(EventKind::ChipReadmitted, Some(2), None, "cooldown over".into());
        let text = log.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = Json::parse(lines[0]).unwrap();
        assert_eq!(v.get("kind").unwrap().as_str(), Some("chip-quarantined"));
        assert_eq!(v.get("chip").unwrap().as_usize(), Some(2));
        assert_eq!(v.get("seq").unwrap().as_usize(), Some(0));
        let v1 = Json::parse(lines[1]).unwrap();
        assert_eq!(v1.get("seq").unwrap().as_usize(), Some(1));
        assert_eq!(v1.get("frame").unwrap(), &Json::Null);
    }

    #[test]
    fn kind_names_are_stable_and_unique() {
        let mut names: Vec<&str> = EVENT_KINDS.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), EVENT_KINDS.len());
    }
}
