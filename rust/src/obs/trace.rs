//! Span tracing: pairs the compiler's `SegTrace` events into structured
//! per-segment spans `{net, frame, node, segment, chip, worker, tile
//! class}`, splits each span into DMA-load / compute / store sub-spans
//! using the exact `SegClock` phase replay (`analysis::segment_phases` —
//! the same replay the planner's cycle model is built on), and emits the
//! whole timeline as Chrome Trace Event JSON loadable in Perfetto
//! (chrome://tracing and https://ui.perfetto.dev).
//!
//! Track layout: one Perfetto *process* per chip (`pid == chip id`), one
//! *thread* per tile worker (`tid == worker`), one thread per chip queue
//! worker (`tid == 100 + worker`) carrying window spans, and an `events`
//! thread (`tid == 999`) carrying instant events; fleet-scoped instants
//! (no chip) live on a synthetic `fleet` process.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::analysis::{net_phases, SegPhases};
use crate::compiler::{CompiledNet, SegTrace, TraceTarget};
use crate::model::NodeOp;
use crate::util::json::{num, obj, s, Json};
use crate::util::sync::lock_recover;

use super::events::EventKind;

/// Synthetic Perfetto process id for fleet-scoped (chip-less) instants.
const FLEET_PID: u64 = 9999;
/// Thread id offset for chip queue-worker (window) tracks.
const QUEUE_TID: u64 = 100;
/// Thread id of each chip's instant-event track.
const EVENTS_TID: u64 = 999;

/// One traced segment execution, fully attributed.
#[derive(Clone, Debug)]
pub struct SegSpan {
    pub net: String,
    pub chip: usize,
    /// Tile worker (DAG executor) — one Perfetto track per chip×worker.
    pub worker: usize,
    /// Frame id (coordinator-global when serving, window index in `run`).
    pub frame: u64,
    pub node: usize,
    /// Graph node name (e.g. `conv1`, `dw3`).
    pub node_name: String,
    /// Tile class: `conv` / `pw` / `dw` / `grouped` / `pool` / `add` /
    /// `concat`.
    pub class: String,
    pub seg: usize,
    /// Wall-clock span bounds, nanoseconds since the sink epoch.
    pub t0_ns: u64,
    pub t1_ns: u64,
    /// Measured segment cycles (the `SimStats` delta this execution
    /// charged to its frame).
    pub cycles: u64,
    /// Measured non-hidden DMA stall cycles of the segment.
    pub dma_stall_cycles: u64,
    /// Exact phase split replayed from the command stream. By PR 9's
    /// exactness gate `phases.cycles == cycles`, and the three phases
    /// partition it — this is what the sub-spans render.
    pub phases: SegPhases,
}

/// One serving window executed by a chip queue worker.
#[derive(Clone, Debug)]
pub struct WindowSpan {
    pub net: String,
    pub chip: usize,
    /// Chip queue worker that served the window.
    pub worker: usize,
    /// Frame ids of the window, submission order.
    pub frames: Vec<u64>,
    pub t0_ns: u64,
    pub t1_ns: u64,
    /// Summed measured cycles of the window's frames.
    pub cycles: u64,
}

/// An instant event mirrored from the fleet event log (fault, retry,
/// failover, health transition, DVFS auto-pick).
#[derive(Clone, Debug)]
pub struct InstantEvent {
    pub t_ns: u64,
    pub kind: EventKind,
    pub chip: Option<usize>,
    /// Sequence number in the fleet event log (0 when no log is wired).
    pub seq: u64,
    pub detail: String,
}

/// Per-net span labels + phase splits, computed once per net and shared
/// by every ingest of that net's windows.
struct NetMeta {
    /// Exact per-segment phase split (`analysis::net_phases`).
    phases: Vec<SegPhases>,
    /// Per-node name and tile class.
    node_names: Vec<String>,
    node_classes: Vec<String>,
}

fn tile_class(op: &NodeOp) -> &'static str {
    match op {
        NodeOp::Conv(c) => {
            if c.groups > 1 && c.groups == c.cin {
                "dw"
            } else if c.groups > 1 {
                "grouped"
            } else if c.k == 1 {
                "pw"
            } else {
                "conv"
            }
        }
        NodeOp::Pool(_) => "pool",
        NodeOp::Add(_) => "add",
        NodeOp::Concat(_) => "concat",
    }
}

#[derive(Default)]
struct SinkState {
    spans: Vec<SegSpan>,
    windows: Vec<WindowSpan>,
    instants: Vec<InstantEvent>,
    meta: HashMap<String, Arc<NetMeta>>,
}

/// The trace collector: one epoch, one timeline, all chips. Locking is
/// poison-tolerant — the trace of a crashed run is the one you most
/// want to read.
pub struct TraceSink {
    epoch: Instant,
    state: Mutex<SinkState>,
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new()
    }
}

impl TraceSink {
    pub fn new() -> Self {
        Self::with_epoch(Instant::now())
    }

    pub fn with_epoch(epoch: Instant) -> Self {
        Self { epoch, state: Mutex::new(SinkState::default()) }
    }

    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Nanoseconds since the sink epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// A compiler trace target sharing this sink's epoch, so events from
    /// every run land on one coherent timeline.
    pub fn target(&self) -> TraceTarget {
        TraceTarget::with_epoch(self.epoch)
    }

    fn meta_for(&self, net: &str, compiled: &CompiledNet) -> Arc<NetMeta> {
        let mut st = lock_recover(&self.state);
        if let Some(m) = st.meta.get(net) {
            return m.clone();
        }
        let m = Arc::new(NetMeta {
            phases: net_phases(compiled),
            node_names: compiled.graph.nodes.iter().map(|n| n.name().to_string()).collect(),
            node_classes: compiled.graph.nodes.iter().map(|n| tile_class(&n.op).into()).collect(),
        });
        st.meta.insert(net.to_string(), m.clone());
        m
    }

    /// Pair the enter/exit events of one traced window into spans. The
    /// exit timestamp is clamped to at least 1 ns past the enter so
    /// `enter < exit` holds even under a coarse platform clock.
    /// `frame_ids[w]` maps the window-local frame index `w` of the trace
    /// events to the id recorded on the span.
    pub fn ingest(
        &self,
        net: &str,
        compiled: &CompiledNet,
        chip: usize,
        frame_ids: &[u64],
        events: &[SegTrace],
    ) {
        let meta = self.meta_for(net, compiled);
        let mut open: HashMap<(usize, usize), (u64, usize)> = HashMap::new();
        let mut spans = Vec::new();
        for e in events {
            if e.enter {
                open.insert((e.frame, e.seg), (e.t_ns, e.worker));
                continue;
            }
            let Some((t0, worker)) = open.remove(&(e.frame, e.seg)) else {
                continue;
            };
            spans.push(SegSpan {
                net: net.to_string(),
                chip,
                worker,
                frame: frame_ids.get(e.frame).copied().unwrap_or(e.frame as u64),
                node: e.node,
                node_name: meta.node_names.get(e.node).cloned().unwrap_or_default(),
                class: meta.node_classes.get(e.node).cloned().unwrap_or_default(),
                seg: e.seg,
                t0_ns: t0,
                t1_ns: e.t_ns.max(t0 + 1),
                cycles: e.cycles,
                dma_stall_cycles: e.dma_stall_cycles,
                phases: meta.phases.get(e.seg).copied().unwrap_or_default(),
            });
        }
        lock_recover(&self.state).spans.append(&mut spans);
    }

    /// Record one serving-window span on a chip queue-worker track.
    #[allow(clippy::too_many_arguments)]
    pub fn window(
        &self,
        net: &str,
        chip: usize,
        worker: usize,
        frames: Vec<u64>,
        t0_ns: u64,
        t1_ns: u64,
        cycles: u64,
    ) {
        lock_recover(&self.state).windows.push(WindowSpan {
            net: net.to_string(),
            chip,
            worker,
            frames,
            t0_ns,
            t1_ns: t1_ns.max(t0_ns + 1),
            cycles,
        });
    }

    /// Record an instant event (mirrored from the fleet event log).
    pub fn instant(&self, kind: EventKind, chip: Option<usize>, seq: u64, detail: String) {
        let t_ns = self.now_ns();
        lock_recover(&self.state).instants.push(InstantEvent { t_ns, kind, chip, seq, detail });
    }

    pub fn spans(&self) -> Vec<SegSpan> {
        lock_recover(&self.state).spans.clone()
    }

    pub fn windows(&self) -> Vec<WindowSpan> {
        lock_recover(&self.state).windows.clone()
    }

    pub fn instants(&self) -> Vec<InstantEvent> {
        lock_recover(&self.state).instants.clone()
    }

    /// The whole timeline as a Chrome Trace Event JSON document.
    pub fn to_chrome_json(&self) -> Json {
        let st = lock_recover(&self.state);
        let us = |ns: u64| ns as f64 / 1000.0;
        let mut events: Vec<Json> = Vec::new();

        // Track metadata: process per chip, thread per worker role.
        let mut chips: Vec<u64> = Vec::new();
        let mut threads: Vec<(u64, u64, String)> = Vec::new();
        let seen_chip = |chips: &mut Vec<u64>, c: u64| {
            if !chips.contains(&c) {
                chips.push(c);
            }
        };
        let seen_thread = |threads: &mut Vec<(u64, u64, String)>, p: u64, t: u64, n: String| {
            if !threads.iter().any(|(a, b, _)| (*a, *b) == (p, t)) {
                threads.push((p, t, n));
            }
        };
        for sp in &st.spans {
            seen_chip(&mut chips, sp.chip as u64);
            let tid = sp.worker as u64;
            seen_thread(&mut threads, sp.chip as u64, tid, format!("tile-worker {}", sp.worker));
        }
        for w in &st.windows {
            seen_chip(&mut chips, w.chip as u64);
            let tid = QUEUE_TID + w.worker as u64;
            seen_thread(&mut threads, w.chip as u64, tid, format!("queue-worker {}", w.worker));
        }
        for i in &st.instants {
            match i.chip {
                Some(c) => {
                    seen_chip(&mut chips, c as u64);
                    seen_thread(&mut threads, c as u64, EVENTS_TID, "events".into());
                }
                None => seen_thread(&mut threads, FLEET_PID, 0, "events".into()),
            }
        }
        for &c in &chips {
            events.push(obj(vec![
                ("ph", s("M")),
                ("name", s("process_name")),
                ("pid", num(c as f64)),
                ("tid", num(0.0)),
                ("args", obj(vec![("name", s(&format!("chip {c}")))])),
            ]));
        }
        if st.instants.iter().any(|i| i.chip.is_none()) {
            events.push(obj(vec![
                ("ph", s("M")),
                ("name", s("process_name")),
                ("pid", num(FLEET_PID as f64)),
                ("tid", num(0.0)),
                ("args", obj(vec![("name", s("fleet"))])),
            ]));
        }
        for (p, t, n) in &threads {
            events.push(obj(vec![
                ("ph", s("M")),
                ("name", s("thread_name")),
                ("pid", num(*p as f64)),
                ("tid", num(*t as f64)),
                ("args", obj(vec![("name", s(n))])),
            ]));
        }

        // Segment spans + phase sub-spans.
        for sp in &st.spans {
            let (t0, t1) = (us(sp.t0_ns), us(sp.t1_ns));
            let args = obj(vec![
                ("net", s(&sp.net)),
                ("frame", num(sp.frame as f64)),
                ("node", num(sp.node as f64)),
                ("seg", num(sp.seg as f64)),
                ("class", s(&sp.class)),
                ("cycles", num(sp.cycles as f64)),
                ("dma_stall_cycles", num(sp.dma_stall_cycles as f64)),
                ("load_stall_cycles", num(sp.phases.load_stall as f64)),
                ("compute_cycles", num(sp.phases.compute as f64)),
                ("store_stall_cycles", num(sp.phases.store_stall as f64)),
            ]);
            events.push(obj(vec![
                ("ph", s("X")),
                ("name", s(&format!("{} s{} f{}", sp.node_name, sp.seg, sp.frame))),
                ("cat", s("segment")),
                ("pid", num(sp.chip as f64)),
                ("tid", num(sp.worker as f64)),
                ("ts", num(t0)),
                ("dur", num(t1 - t0)),
                ("args", args),
            ]));
            // Sub-spans: the wall span scaled by the exact cycle phases.
            // Wall positions are proportional (cycles are simulated time,
            // the span is host time); the args carry the exact counts.
            let total = sp.phases.cycles;
            if total > 0 {
                let wall = t1 - t0;
                let mut cursor = 0u64;
                for (label, cyc) in [
                    ("dma-load", sp.phases.load_stall),
                    ("compute", sp.phases.compute),
                    ("store", sp.phases.store_stall),
                ] {
                    if cyc == 0 {
                        continue;
                    }
                    let p0 = t0 + wall * (cursor as f64 / total as f64);
                    let pd = wall * (cyc as f64 / total as f64);
                    cursor += cyc;
                    events.push(obj(vec![
                        ("ph", s("X")),
                        ("name", s(label)),
                        ("cat", s("phase")),
                        ("pid", num(sp.chip as f64)),
                        ("tid", num(sp.worker as f64)),
                        ("ts", num(p0)),
                        ("dur", num(pd)),
                        ("args", obj(vec![("cycles", num(cyc as f64))])),
                    ]));
                }
            }
        }

        // Window spans on the queue-worker tracks.
        for w in &st.windows {
            let (t0, t1) = (us(w.t0_ns), us(w.t1_ns));
            let frames = Json::Arr(w.frames.iter().map(|&f| num(f as f64)).collect());
            events.push(obj(vec![
                ("ph", s("X")),
                ("name", s(&format!("window[{}] {}", w.frames.len(), w.net))),
                ("cat", s("window")),
                ("pid", num(w.chip as f64)),
                ("tid", num(QUEUE_TID as f64 + w.worker as f64)),
                ("ts", num(t0)),
                ("dur", num(t1 - t0)),
                ("args", obj(vec![("frames", frames), ("cycles", num(w.cycles as f64))])),
            ]));
        }

        // Instants: faults, retries, failovers, health transitions.
        for i in &st.instants {
            let (pid, tid, scope) = match i.chip {
                Some(c) => (c as f64, EVENTS_TID as f64, "p"),
                None => (FLEET_PID as f64, 0.0, "g"),
            };
            events.push(obj(vec![
                ("ph", s("i")),
                ("name", s(i.kind.name())),
                ("cat", s("event")),
                ("pid", num(pid)),
                ("tid", num(tid)),
                ("ts", num(us(i.t_ns))),
                ("s", s(scope)),
                ("args", obj(vec![("seq", num(i.seq as f64)), ("detail", s(&i.detail))])),
            ]));
        }

        obj(vec![("traceEvents", Json::Arr(events)), ("displayTimeUnit", s("ms"))])
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_chrome_json().to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::NetRunner;
    use crate::model::{zoo, Tensor};

    #[test]
    fn ingest_pairs_events_into_spans_with_phases() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let frames: Vec<Tensor> =
            (0..2).map(|i| Tensor::random_image(i, net.in_h, net.in_w, net.in_c)).collect();
        let refs: Vec<&Tensor> = frames.iter().collect();
        let sink = TraceSink::new();
        let target = sink.target();
        let outs = runner.run_frames_pipelined_ref_traced(&refs, 2, 2, &target).unwrap();
        sink.ingest(&net.name, &runner.compiled, 0, &[10, 11], &target.take());
        let spans = sink.spans();
        let nseg = runner.compiled.segments.len();
        assert_eq!(spans.len(), 2 * nseg, "one span per frame × segment");
        for sp in &spans {
            assert!(sp.t0_ns < sp.t1_ns, "enter < exit");
            assert!(sp.frame == 10 || sp.frame == 11, "window ids mapped");
            assert_eq!(
                sp.phases.cycles,
                sp.phases.load_stall + sp.phases.compute + sp.phases.store_stall,
                "phases partition the segment clock"
            );
            assert_eq!(sp.phases.cycles, sp.cycles, "replayed == measured per segment");
            assert!(!sp.node_name.is_empty());
        }
        // per-frame span cycles reconcile with the measured frame stats
        for (w, (_, stats)) in outs.iter().enumerate() {
            let total: u64 =
                spans.iter().filter(|sp| sp.frame == 10 + w as u64).map(|sp| sp.cycles).sum();
            assert_eq!(total, stats.cycles, "frame {w} span total == SimStats.cycles");
        }
    }

    #[test]
    fn chrome_json_is_wellformed_and_carries_tracks() {
        let net = zoo::quicknet();
        let runner = NetRunner::new(&net).unwrap();
        let frame = Tensor::random_image(3, net.in_h, net.in_w, net.in_c);
        let sink = TraceSink::new();
        let target = sink.target();
        runner.run_frames_pipelined_ref_traced(&[&frame], 2, 1, &target).unwrap();
        sink.ingest(&net.name, &runner.compiled, 1, &[0], &target.take());
        sink.instant(EventKind::FaultInjected, Some(1), 0, "transient fault".into());
        sink.instant(EventKind::AutoPick, None, 1, "quicknet@250MHz".into());
        let doc = sink.to_chrome_json().to_string();
        let v = Json::parse(&doc).unwrap();
        let evs = v.get("traceEvents").unwrap().as_arr().unwrap();
        let xs = evs.iter().filter(|e| e.str_or("ph", "") == "X").count();
        let is = evs.iter().filter(|e| e.str_or("ph", "") == "i").count();
        let ms = evs.iter().filter(|e| e.str_or("ph", "") == "M").count();
        assert!(xs > 0, "has spans");
        assert_eq!(is, 2, "has both instants");
        assert!(ms >= 3, "process + thread metadata present");
    }
}
