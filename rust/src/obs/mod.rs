//! Observability: span tracing, metric exposition, and the fleet event
//! log — the simulator's internal timeline as first-class artifacts.
//!
//! * [`trace`] — pairs the compiler's `SegTrace` events into structured
//!   per-segment spans with DMA-load / compute / store sub-spans derived
//!   from the exact `SegClock` phase replay (`analysis::segment_phases`),
//!   plus serving-window spans and instant events, emitted as Chrome
//!   Trace Event JSON loadable in Perfetto (`--trace-out trace.json`).
//! * [`events`] — the structured fleet event log: lifecycle events
//!   (faults, retries, failovers, health transitions, DVFS auto-picks)
//!   with monotonic sequence numbers, exportable as JSONL
//!   (`--event-log events.jsonl`).
//! * [`prom`] — Prometheus text exposition over a `ServeReport` + the
//!   event log (`--metrics-out metrics.prom`).
//!
//! [`Obs`] bundles the two sinks behind the coordinator config. Both
//! default to disabled (`Obs::none`), in which case every emission site
//! is a pair of `Option` checks — no locks, no clocks, no allocation —
//! and outputs/stats are bit-identical to an untraced run.

pub mod events;
pub mod prom;
pub mod trace;

use std::fmt;
use std::sync::Arc;
use std::time::Instant;

pub use events::{EventKind, EventLog, FleetEvent, EVENT_KINDS};
pub use trace::{InstantEvent, SegSpan, TraceSink, WindowSpan};

/// The observability handle carried by `CoordinatorConfig`: an optional
/// trace sink and an optional event log sharing one epoch, so spans,
/// instants and logged events land on a single coherent timeline.
#[derive(Clone, Default)]
pub struct Obs {
    pub trace: Option<Arc<TraceSink>>,
    pub log: Option<Arc<EventLog>>,
}

impl fmt::Debug for Obs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Obs")
            .field("trace", &self.trace.is_some())
            .field("log", &self.log.is_some())
            .finish()
    }
}

impl Obs {
    /// Everything disabled — the default, near-zero-cost configuration.
    pub fn none() -> Arc<Self> {
        Arc::new(Self::default())
    }

    /// Enable the selected sinks on one shared epoch.
    pub fn with(trace: bool, log: bool) -> Arc<Self> {
        let epoch = Instant::now();
        Arc::new(Self {
            trace: trace.then(|| Arc::new(TraceSink::with_epoch(epoch))),
            log: log.then(|| Arc::new(EventLog::with_epoch(epoch))),
        })
    }

    pub fn enabled(&self) -> bool {
        self.trace.is_some() || self.log.is_some()
    }

    /// Record a lifecycle event: logged (with a fleet-wide sequence
    /// number) when the event log is enabled, mirrored as a trace
    /// instant when the trace sink is enabled. `detail` is lazy so
    /// disabled observability never formats a string.
    pub fn event<F: FnOnce() -> String>(
        &self,
        kind: EventKind,
        chip: Option<usize>,
        frame: Option<u64>,
        detail: F,
    ) {
        if self.trace.is_none() && self.log.is_none() {
            return;
        }
        let d = detail();
        let seq = self.log.as_ref().map_or(0, |l| l.emit(kind, chip, frame, d.clone()));
        if let Some(t) = &self.trace {
            t.instant(kind, chip, seq, d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_obs_is_inert_and_cheap() {
        let obs = Obs::none();
        assert!(!obs.enabled());
        // the detail closure must not run when both sinks are off
        obs.event(EventKind::Retry, Some(0), Some(1), || {
            panic!("detail formatted on a disabled Obs")
        });
    }

    #[test]
    fn event_tees_to_log_and_trace_with_shared_seq() {
        let obs = Obs::with(true, true);
        obs.event(EventKind::FaultInjected, Some(2), Some(9), || "compute stall".into());
        obs.event(EventKind::Retry, Some(2), Some(9), || "attempt 2 on chip 2".into());
        let log = obs.log.as_ref().unwrap();
        let trace = obs.trace.as_ref().unwrap();
        assert_eq!(log.len(), 2);
        let instants = trace.instants();
        assert_eq!(instants.len(), 2);
        assert_eq!(instants[0].seq, 0);
        assert_eq!(instants[1].seq, 1);
        assert_eq!(instants[1].kind, EventKind::Retry);
        assert_eq!(log.events()[0].detail, "compute stall");
    }
}
