//! Prometheus text exposition (version 0.0.4) over a [`ServeReport`]
//! and the fleet event log. Hand-rolled like the rest of `util` — the
//! format is line-oriented and trivial to emit without a client crate.
//!
//! Family reference (all prefixed `kn_`):
//!
//! | family | type | labels |
//! |---|---|---|
//! | `kn_frames_total` | counter | `net` (`_all` = aggregate) |
//! | `kn_errors_total` | counter | `net` |
//! | `kn_admission_rejects_total` | counter | — |
//! | `kn_retries_total` | counter | — |
//! | `kn_failovers_total` | counter | — |
//! | `kn_deadline_misses_total` | counter | — |
//! | `kn_dram_read_bytes_total` | counter | — |
//! | `kn_dram_write_bytes_total` | counter | — |
//! | `kn_frame_latency_us` | summary | `net`, `quantile` |
//! | `kn_device_latency_us` | summary | `net`, `quantile` |
//! | `kn_queue_wait_us` | summary | `net`, `quantile` |
//! | `kn_utilization` | gauge | — |
//! | `kn_lane_utilization` | gauge | — |
//! | `kn_wall_seconds` | gauge | — |
//! | `kn_chip_health` | gauge | `chip` (0 healthy … 3 dead) |
//! | `kn_chip_frames_total` | counter | `chip` |
//! | `kn_chip_errors_total` | counter | `chip` |
//! | `kn_chip_queue_depth` | gauge | `chip` |
//! | `kn_chip_health_transitions_total` | counter | `chip` |
//! | `kn_fleet_events_total` | counter | `kind` |

use std::fmt::Write as _;

use crate::coordinator::{ChipHealth, RunMetrics, ServeReport};
use crate::util::stats::Histogram;

use super::events::{EventLog, EVENT_KINDS};

/// Escape a label value per the exposition format.
fn esc(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn head(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn summary(out: &mut String, name: &str, net: &str, h: &Histogram) {
    let net = esc(net);
    for q in [0.5, 0.95, 0.99] {
        let _ =
            writeln!(out, "{name}{{net=\"{net}\",quantile=\"{q}\"}} {}", h.quantile(q));
    }
    let _ = writeln!(out, "{name}_sum{{net=\"{net}\"}} {}", h.sum());
    let _ = writeln!(out, "{name}_count{{net=\"{net}\"}} {}", h.count());
}

fn health_value(h: ChipHealth) -> u64 {
    match h {
        ChipHealth::Healthy => 0,
        ChipHealth::Degraded => 1,
        ChipHealth::Quarantined => 2,
        ChipHealth::Dead => 3,
    }
}

/// Render the exposition document. `log` supplies the event counters
/// (`kn_fleet_events_total`, health transitions); `chip_loads` the
/// current per-chip queue depth gauge (pass `&[]` when unknown).
pub fn render(report: &ServeReport, log: Option<&EventLog>, chip_loads: &[usize]) -> String {
    let mut out = String::new();
    let rows: Vec<(&str, &RunMetrics)> = std::iter::once(("_all", &report.aggregate))
        .chain(report.per_net.iter().map(|(n, m)| (n.as_str(), m)))
        .collect();

    head(&mut out, "kn_frames_total", "counter", "Frames served successfully.");
    for (net, m) in &rows {
        let _ = writeln!(out, "kn_frames_total{{net=\"{}\"}} {}", esc(net), m.frames);
    }
    head(&mut out, "kn_errors_total", "counter", "Frames delivered as errors.");
    for (net, m) in &rows {
        let _ = writeln!(out, "kn_errors_total{{net=\"{}\"}} {}", esc(net), m.errors);
    }

    let agg = &report.aggregate;
    head(&mut out, "kn_admission_rejects_total", "counter", "Submissions bounced by admission.");
    let _ = writeln!(out, "kn_admission_rejects_total {}", agg.rejects);
    head(&mut out, "kn_retries_total", "counter", "Dispatch attempts beyond each frame's first.");
    let _ = writeln!(out, "kn_retries_total {}", agg.retries);
    head(&mut out, "kn_failovers_total", "counter", "Re-dispatches that moved chips.");
    let _ = writeln!(out, "kn_failovers_total {}", agg.failovers);
    head(&mut out, "kn_deadline_misses_total", "counter", "Attempts past their deadline.");
    let _ = writeln!(out, "kn_deadline_misses_total {}", agg.deadline_misses);
    head(&mut out, "kn_dram_read_bytes_total", "counter", "DRAM bytes read (all chips).");
    let _ = writeln!(out, "kn_dram_read_bytes_total {}", agg.totals.dram_read_bytes);
    head(&mut out, "kn_dram_write_bytes_total", "counter", "DRAM bytes written (all chips).");
    let _ = writeln!(out, "kn_dram_write_bytes_total {}", agg.totals.dram_write_bytes);

    head(&mut out, "kn_frame_latency_us", "summary", "Wall-clock frame latency (µs).");
    for (net, m) in &rows {
        summary(&mut out, "kn_frame_latency_us", net, &m.wall_lat_us);
    }
    head(&mut out, "kn_device_latency_us", "summary", "Device frame latency at the DVFS point.");
    for (net, m) in &rows {
        summary(&mut out, "kn_device_latency_us", net, &m.dev_lat_us);
    }
    head(&mut out, "kn_queue_wait_us", "summary", "Submit-to-dequeue queue wait (µs).");
    for (net, m) in &rows {
        summary(&mut out, "kn_queue_wait_us", net, &m.queue_wait_us);
    }

    head(&mut out, "kn_utilization", "gauge", "MAC array utilization (0..1).");
    let _ = writeln!(out, "kn_utilization {}", agg.totals.utilization());
    head(&mut out, "kn_lane_utilization", "gauge", "CU lane occupancy (0..1).");
    let _ = writeln!(out, "kn_lane_utilization {}", agg.totals.lane_utilization());
    head(&mut out, "kn_wall_seconds", "gauge", "Wall-clock duration of the run.");
    let _ = writeln!(out, "kn_wall_seconds {}", agg.wall_s);

    if !report.per_chip.is_empty() {
        head(&mut out, "kn_chip_health", "gauge", "0 healthy, 1 degraded, 2 quarantined, 3 dead.");
        for (c, h) in report.chip_health.iter().enumerate() {
            let _ = writeln!(out, "kn_chip_health{{chip=\"{c}\"}} {}", health_value(*h));
        }
        head(&mut out, "kn_chip_frames_total", "counter", "Frames delivered per chip.");
        for (c, m) in report.per_chip.iter().enumerate() {
            let _ = writeln!(out, "kn_chip_frames_total{{chip=\"{c}\"}} {}", m.frames);
        }
        head(&mut out, "kn_chip_errors_total", "counter", "Errors delivered per chip.");
        for (c, m) in report.per_chip.iter().enumerate() {
            let _ = writeln!(out, "kn_chip_errors_total{{chip=\"{c}\"}} {}", m.errors);
        }
    }
    if !chip_loads.is_empty() {
        head(&mut out, "kn_chip_queue_depth", "gauge", "In-flight jobs queued per chip.");
        for (c, d) in chip_loads.iter().enumerate() {
            let _ = writeln!(out, "kn_chip_queue_depth{{chip=\"{c}\"}} {d}");
        }
    }

    if let Some(log) = log {
        head(&mut out, "kn_fleet_events_total", "counter", "Fleet lifecycle events by kind.");
        for k in EVENT_KINDS {
            let _ =
                writeln!(out, "kn_fleet_events_total{{kind=\"{}\"}} {}", k.name(), log.count(k));
        }
        if !report.per_chip.is_empty() {
            head(
                &mut out,
                "kn_chip_health_transitions_total",
                "counter",
                "Chip health state-machine transitions.",
            );
            let events = log.events();
            for c in 0..report.per_chip.len() {
                let n = events
                    .iter()
                    .filter(|e| e.kind.is_health_transition() && e.chip == Some(c))
                    .count();
                let _ = writeln!(out, "kn_chip_health_transitions_total{{chip=\"{c}\"}} {n}");
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServeReport;
    use crate::energy::dvfs::PEAK;
    use crate::obs::events::EventKind;

    #[test]
    fn exposition_is_wellformed() {
        let mut rep = ServeReport::with_chips(PEAK, &["a".to_string()], &[PEAK, PEAK]);
        rep.chip_health[1] = ChipHealth::Dead;
        rep.aggregate.retries = 3;
        let log = EventLog::new();
        log.emit(EventKind::FaultInjected, Some(1), Some(0), "transient fault".into());
        log.emit(EventKind::ChipDead, Some(1), None, "chip death".into());
        let text = render(&rep, Some(&log), &[2, 0]);
        assert!(text.contains("# TYPE kn_frames_total counter"));
        assert!(text.contains("kn_frames_total{net=\"_all\"} 0"));
        assert!(text.contains("kn_retries_total 3"));
        assert!(text.contains("kn_chip_health{chip=\"1\"} 3"));
        assert!(text.contains("kn_chip_queue_depth{chip=\"0\"} 2"));
        assert!(text.contains("kn_fleet_events_total{kind=\"fault-injected\"} 1"));
        assert!(text.contains("kn_fleet_events_total{kind=\"chip-dead\"} 1"));
        assert!(text.contains("kn_fleet_events_total{kind=\"retry\"} 0"));
        assert!(text.contains("kn_chip_health_transitions_total{chip=\"1\"} 1"));
        assert!(text.contains("kn_queue_wait_us{net=\"_all\",quantile=\"0.5\"}"));
        // every non-comment line is "name{labels} value" or "name value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, val) = line.rsplit_once(' ').expect("metric line has a value");
            assert!(val.parse::<f64>().is_ok(), "numeric value in {line:?}");
        }
    }
}
