//! 16-bit fixed-point numerics — the cross-language bit-exactness contract.
//!
//! Mirrors `python/compile/kernels/quant.py` / the Pallas conv kernel:
//!
//! * activations/weights: `i16`; biases and accumulators: **wrapping** `i32`
//! * multiply: `(a as i32) * (w as i32)` (never overflows i32)
//! * accumulate: `i32::wrapping_add` — wrapping makes accumulation
//!   **order-independent**, which is what lets the decomposition compiler
//!   replay partial sums in any schedule and still match bit-for-bit
//! * requantize: round-half-up via wrapping add of `1 << (shift-1)` then
//!   arithmetic right shift, saturate to i16, optional ReLU

/// Saturating bounds of the output precision.
pub const QMAX: i32 = i16::MAX as i32;
pub const QMIN: i32 = i16::MIN as i32;

/// One multiply of the PE: int16 × int16 → int32 (exact).
#[inline(always)]
pub fn pe_mul(a: i16, w: i16) -> i32 {
    a as i32 * w as i32
}

/// Accumulation-buffer add: wrapping int32.
#[inline(always)]
pub fn acc_add(acc: i32, x: i32) -> i32 {
    acc.wrapping_add(x)
}

/// The ACC BUF output stage: round-half-up shift → saturate → ReLU.
///
/// `shift == 0` is a pass-through (still saturating). The rounding add
/// may wrap — that is the hardware register semantics, and the Pallas /
/// numpy twins do the same.
#[inline(always)]
pub fn requantize(acc: i32, shift: u8, relu: bool) -> i16 {
    debug_assert!(shift < 31);
    let mut v = acc;
    if shift > 0 {
        v = v.wrapping_add(1 << (shift - 1));
        v >>= shift; // arithmetic shift (i32)
    }
    v = v.clamp(QMIN, QMAX);
    if relu {
        v = v.max(0);
    }
    v as i16
}

/// 3×3 window dot product — what one CU computes per output pixel
/// (9 PE multiplies + adder tree), fed channel-serially by the caller.
#[inline(always)]
pub fn cu_dot9(window: &[i16; 9], weights: &[i16; 9]) -> i32 {
    let mut acc = 0i32;
    for i in 0..9 {
        acc = acc.wrapping_add(pe_mul(window[i], weights[i]));
    }
    acc
}

/// Reference scalar conv for one output element over all taps/channels —
/// used by tests as a third, trivially-auditable implementation.
pub fn conv_point(
    x: &[i16],
    (h, w, c): (usize, usize, usize),
    wt: &[i16],
    k: usize,
    (oy, ox): (usize, usize),
    stride: usize,
    m_idx: usize,
    m_total: usize,
) -> i32 {
    let _ = h;
    let mut acc = 0i32;
    for i in 0..k {
        for j in 0..k {
            for ch in 0..c {
                let xi = x[((oy * stride + i) * w + (ox * stride + j)) * c + ch];
                let wi = wt[((i * k + j) * c + ch) * m_total + m_idx];
                acc = acc.wrapping_add(pe_mul(xi, wi));
            }
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requant_known_vectors() {
        // pinned against python/tests/test_quant.py::test_round_half_up
        assert_eq!(requantize(3, 1, false), 2);
        assert_eq!(requantize(-3, 1, false), -1);
        assert_eq!(requantize(2, 1, false), 1);
        assert_eq!(requantize(-2, 1, false), -1);
        assert_eq!(requantize(1, 1, false), 1);
        assert_eq!(requantize(-1, 1, false), 0);
    }

    #[test]
    fn requant_saturates() {
        assert_eq!(requantize(1 << 30, 4, false), 32767);
        assert_eq!(requantize(-(1 << 30), 4, false), -32768);
        assert_eq!(requantize(32768 << 4, 4, false), 32767);
    }

    #[test]
    fn requant_passthrough_shift0() {
        assert_eq!(requantize(123, 0, false), 123);
        assert_eq!(requantize(-40000, 0, false), -32768);
        assert_eq!(requantize(40000, 0, false), 32767);
    }

    #[test]
    fn requant_relu() {
        assert_eq!(requantize(-1000, 0, true), 0);
        assert_eq!(requantize(1000, 0, true), 1000);
    }

    #[test]
    fn requant_rounding_add_wraps() {
        // acc near INT32_MAX — pinned against the python kernel's
        // test_rounding_add_can_wrap
        assert_eq!(requantize(i32::MAX, 8, false), -32768);
        assert_eq!(requantize(i32::MAX - 63, 8, false), -32768);
        assert_eq!(requantize(i32::MIN, 8, false), -32768);
    }

    #[test]
    fn wrapping_accumulate_is_order_independent() {
        let vals = [i32::MAX, 123, i32::MAX, -77, i32::MIN, 99];
        let fwd = vals.iter().fold(0i32, |a, &b| acc_add(a, b));
        let rev = vals.iter().rev().fold(0i32, |a, &b| acc_add(a, b));
        assert_eq!(fwd, rev);
    }

    #[test]
    fn cu_dot9_matches_naive() {
        let w: [i16; 9] = [1, -2, 3, -4, 5, -6, 7, -8, 9];
        let x: [i16; 9] = [9, 8, 7, 6, 5, 4, 3, 2, 1];
        let want: i32 = x.iter().zip(w.iter()).map(|(&a, &b)| a as i32 * b as i32).sum();
        assert_eq!(cu_dot9(&x, &w), want);
    }
}
