//! Candidate enumeration: generalizes `decompose::plan_conv`'s single
//! heuristic winner into *all feasible* `(gy, gx, c_per_group)` plans
//! of a conv node, each evaluated by the analytic cost model in O(1).
//!
//! Two observations keep the space small without losing optima:
//!
//! * For a fixed grid, DRAM traffic depends on the channel grouping
//!   only through *whether* the whole channel set stays SRAM-resident
//!   (`c_groups == 1` avoids the per-feature-tile input re-stream);
//!   beyond that, weight/bias/output traffic are grouping-invariant.
//!   So per grid only the **largest feasible** `c_per_group` is kept —
//!   any smaller grouping has equal-or-worse traffic and an equal
//!   dependency structure.
//! * Distinct groupings only arise at the distinct values of
//!   `⌈cg / n⌉`, an O(√cg) set.

use super::cost::{conv_candidate, conv_out_shape, dw_candidate, ConvCandidate};
use crate::compiler::decompose::dw_eligible;
use crate::model::ConvSpec;
use crate::sim::accbuf::ACC_TILE_PX;
use crate::NUM_CU;

/// The distinct values of `⌈cg / n⌉` for `n = 1..=cg`, descending —
/// every channels-per-group count that yields a distinct `c_groups`.
pub fn channel_group_options(cg: usize) -> Vec<usize> {
    let mut out: Vec<usize> = (1..=cg).map(|n| cg.div_ceil(n)).collect();
    out.dedup(); // already descending and grouped
    out
}

/// Enumerate every feasible decomposition of `spec` over a pre-pad
/// `(h, w)` input at `sram_budget`: all output grids `gy × gx` whose
/// largest tile fits the ACC BUF, each with its largest SRAM-feasible
/// channel grouping. Deterministic order (row grids outer).
pub fn enumerate_conv(
    spec: &ConvSpec,
    h: usize,
    w: usize,
    sram_budget: usize,
) -> Vec<ConvCandidate> {
    let (oh, ow) = conv_out_shape(spec, h, w);
    if dw_eligible(spec) {
        // Depthwise-eligible layers always lower through the packed
        // fast path (the materializer `plan_with_grid` does the same),
        // so only dw candidates are emitted: per grid, the widest
        // SRAM-feasible lane packing (fewest channel groups = least
        // weight/bias re-streaming).
        let mut out = Vec::new();
        for gy in 1..=oh {
            if oh.div_ceil(gy) > ACC_TILE_PX {
                continue;
            }
            for gx in 1..=ow {
                let probe = dw_candidate(spec, h, w, gy, gx, 1);
                if probe.max_out_px > ACC_TILE_PX || probe.sram_bytes > sram_budget {
                    continue;
                }
                for cpg in (1..=NUM_CU.min(spec.cin)).rev() {
                    let cand = dw_candidate(spec, h, w, gy, gx, cpg);
                    if cand.feasible(sram_budget) {
                        out.push(cand);
                        break;
                    }
                }
            }
        }
        return out;
    }
    let cg = spec.cin / spec.groups;
    let c_options = channel_group_options(cg);
    let mut out = Vec::new();
    for gy in 1..=oh {
        let max_th = oh.div_ceil(gy);
        // The coarsest column grid that can satisfy the ACC BUF bound
        // for this row grid; anything coarser is infeasible.
        if max_th > ACC_TILE_PX {
            continue;
        }
        for gx in 1..=ow {
            let probe = conv_candidate(spec, h, w, gy, gx, 1);
            if probe.max_out_px > ACC_TILE_PX || probe.sram_bytes > sram_budget {
                continue;
            }
            // Largest feasible channel grouping for this grid.
            let mut chosen = None;
            for &c in &c_options {
                let cand = conv_candidate(spec, h, w, gy, gx, c);
                if cand.feasible(sram_budget) {
                    chosen = Some(cand);
                    break;
                }
            }
            if let Some(cand) = chosen {
                out.push(cand);
            }
        }
    }
    out
}

/// Deterministic candidate ordering: traffic first, then fewer tiles,
/// square-ish grids, fewer row splits — aligned with `plan_conv`'s
/// preferences.
fn cand_key(c: &ConvCandidate) -> (u64, usize, u64, usize) {
    (c.traffic.total_bytes(), c.ntiles, (c.gy as i64 - c.gx as i64).unsigned_abs(), c.gy)
}

/// The traffic-minimal candidate.
pub fn min_traffic(cands: &[ConvCandidate]) -> Option<&ConvCandidate> {
    cands.iter().min_by_key(|c| cand_key(c))
}

/// Prune a candidate list for the DAG-aware search: keep plans within
/// `slack` of the minimal traffic (so the search can trade split-axis
/// alignment without ever losing much traffic), sorted by traffic,
/// capped at `cap`.
pub fn prune_for_search(
    mut cands: Vec<ConvCandidate>,
    slack: f64,
    cap: usize,
) -> Vec<ConvCandidate> {
    let Some(best) = min_traffic(&cands).map(|c| c.traffic.total_bytes()) else {
        return cands;
    };
    let limit = (best as f64 * (1.0 + slack)) as u64;
    cands.retain(|c| c.traffic.total_bytes() <= limit);
    cands.sort_by_key(cand_key);
    cands.truncate(cap);
    cands
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::model::LayerSpec;
    use crate::SRAM_BYTES;

    #[test]
    fn channel_options_are_distinct_ceil_divs() {
        assert_eq!(channel_group_options(1), vec![1]);
        assert_eq!(channel_group_options(4), vec![4, 2, 1]);
        assert_eq!(channel_group_options(6), vec![6, 3, 2, 1]);
        let o = channel_group_options(96);
        assert!(o.windows(2).all(|w| w[0] > w[1]), "descending: {o:?}");
        assert!(o.contains(&96) && o.contains(&48) && o.contains(&1));
    }

    #[test]
    fn every_candidate_is_feasible_and_the_solver_choice_is_among_them() {
        for name in ["alexnet", "facenet"] {
            let net = zoo::by_name(name).unwrap();
            let mut shape = net.in_shape();
            for l in &net.layers {
                if let LayerSpec::Conv(c) = l {
                    let cands = enumerate_conv(c, shape.0, shape.1, SRAM_BYTES);
                    assert!(!cands.is_empty(), "{name}/{}", c.name);
                    for cand in &cands {
                        assert!(cand.feasible(SRAM_BYTES), "{name}/{}: {cand:?}", c.name);
                    }
                    let plan =
                        crate::compiler::decompose::plan_conv(c, shape.0, shape.1).unwrap();
                    assert!(
                        cands.iter().any(|cd| cd.gy == plan.gy
                            && cd.gx == plan.gx
                            && cd.c_per_group >= plan.c_per_group),
                        "{name}/{}: solver grid {}x{} missing",
                        c.name,
                        plan.gy,
                        plan.gx
                    );
                }
                shape = l.out_shape(shape);
            }
        }
    }

    #[test]
    fn min_traffic_beats_or_ties_the_heuristic() {
        // alexnet conv2: 48-channel groups over a 27×27 plane with 8
        // feature tiles — "fewest tiles" forces c_groups = 2, which
        // re-streams the whole input once per 16-feature round. A
        // 2-way image split keeps the channel set resident (one load
        // per tile) and wins even after re-streaming weights per tile.
        // (conv3 is the counter-case: m_tiles = 24 makes weight
        // re-streaming dominate, so its 1-tile heuristic plan IS the
        // optimum — the enumerator must keep it.)
        let net = zoo::alexnet();
        let mut shape = net.in_shape();
        for l in &net.layers {
            if let LayerSpec::Conv(c) = l {
                let plan = crate::compiler::decompose::plan_conv(c, shape.0, shape.1).unwrap();
                let heur =
                    conv_candidate(c, shape.0, shape.1, plan.gy, plan.gx, plan.c_per_group);
                let cands = enumerate_conv(c, shape.0, shape.1, SRAM_BYTES);
                let best = min_traffic(&cands).unwrap();
                assert!(
                    best.traffic.total_bytes() <= heur.traffic.total_bytes(),
                    "{}: {} > {}",
                    c.name,
                    best.traffic.total_bytes(),
                    heur.traffic.total_bytes()
                );
                if c.name == "conv2" {
                    assert!(
                        best.traffic.total_bytes() * 100 <= heur.traffic.total_bytes() * 95,
                        "conv2 should improve >5%: best {} vs heuristic {}",
                        best.traffic.total_bytes(),
                        heur.traffic.total_bytes()
                    );
                }
            }
            shape = l.out_shape(shape);
        }
    }
}
