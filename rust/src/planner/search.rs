//! DAG-aware decomposition search: choose every conv node's
//! `(gy, gx, c_per_group)` plan *jointly* over the graph instead of in
//! isolation, co-optimizing split axes across producer→consumer edges.
//!
//! The score of an assignment is
//!
//! ```text
//! Σ predicted DRAM bytes                      (the paper's §5 objective)
//!   + DEP_EDGE_BYTES · cross-tile dep edges   (scheduling/sync overhead)
//!   + CP_BYTES_PER_CYCLE · critical path      (parallelism term)
//! ```
//!
//! where the dependency-edge count is an exact mirror of the region-
//! intersection pass `compiler::codegen` runs over the emitted
//! segments (verified segment-for-segment by
//! `tests/integration_planner.rs`), and the critical path walks the
//! node DAG with each node's analytic cycle estimate divided by its
//! achievable parallel width. Traffic dominates by construction: the
//! candidate lists are pre-pruned to plans within a fixed slack of the
//! per-node traffic optimum, so the search trades *alignment* (matched
//! producer/consumer split axes → consumer tiles that wait on few
//! producer tiles), never an unbounded amount of DRAM traffic.
//!
//! The search itself is coordinate descent: start from the per-node
//! traffic optimum (`MinTraffic`), then sweep the conv nodes in
//! topological order, re-choosing each node's candidate against its
//! neighbors' current choices until a sweep changes nothing.

use super::cost::{
    add_chunks, concat_chunks, conv_node_cycles, fixed_node_cycles, fixed_node_traffic,
    fused_dwpw_cycles, fused_dwpw_traffic, pool_chunks, predicted_stats, ConvCandidate,
    NodeTraffic,
};
use super::enumerate::{enumerate_conv, min_traffic, prune_for_search};
use super::PlanPolicy;
use crate::compiler::decompose::{dw_eligible, plan_conv_budget, plan_with_grid, split_even, Plan};
use crate::energy::{EnergyModel, OperatingPoint};
use crate::model::graph::{Graph, NodeOp, NodeRef};
use crate::model::ConvSpec;
use crate::sim::SimStats;
use crate::SRAM_BYTES;

/// Score weight of one cross-tile dependency edge, in DRAM-byte
/// equivalents (~ one command-issue + sync round a consumer tile
/// spends waiting on a producer it didn't need). Small against any
/// real tile transfer, so traffic always dominates.
const DEP_EDGE_BYTES: f64 = 128.0;
/// Critical-path weight (byte-equivalents per exact cycle).
/// Deliberately *far below* the DMA bandwidth: at bandwidth scale a
/// compute-bound layer's cycle count dwarfs its DRAM bytes and the
/// search would happily burn real traffic for width. At 0.05 the term
/// acts as intended — among near-equal-traffic assignments it prefers
/// the wider, shorter-critical-path one; it never buys width with more
/// than a few KB of traffic.
const CP_BYTES_PER_CYCLE: f64 = 0.05;
/// Dep-edge weight of the latency objective, in cycles: one edge ≈ the
/// `DEP_EDGE_BYTES` sync round converted at the nominal 3.2 B/cycle.
const DEP_EDGE_CYCLES: f64 = DEP_EDGE_BYTES / 3.2;
/// Critical-path tie-break weight of the latency objective (serial
/// device cycles dominate; width is a scheduler bonus).
const CP_CYCLE_WEIGHT: f64 = 0.05;
/// Candidates may cost at most this fraction more traffic than the
/// per-node optimum (the alignment budget of the DAG-aware search).
const TRAFFIC_SLACK: f64 = 0.25;
/// Candidate-list cap per node after pruning.
const CAND_CAP: usize = 64;
/// Parallel width the critical-path term assumes the runner achieves
/// (the default `tile_workers` ballpark).
const PAR_WIDTH: u64 = 4;
/// Coordinate-descent sweep bound (converges in 1–2 on the zoo).
const MAX_SWEEPS: usize = 4;

/// What the searching policies (`MinTraffic`, `DagAware`) minimize.
/// The legacy byte objective stays the default; the other three rank
/// candidates by the planner's **exact** cycle model at a chosen
/// [`OperatingPoint`] (simulated cycles are frequency-independent, so
/// the `op` matters only where energy or wall-clock enters the score).
/// The `Heuristic` policy ignores the objective — it never scores.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PlanObjective {
    /// Total DRAM bytes — the paper's §5 objective.
    MinTraffic,
    /// Predicted device latency (exact serial cycles) at `op`.
    MinLatency { op: OperatingPoint },
    /// Predicted energy per frame at `op`, subject to a latency SLO:
    /// when the energy-optimal plan would miss `slo_ms` at `op`, the
    /// planner falls back to the latency-optimal plan (`slo_ms <= 0`
    /// disables the SLO).
    MinEnergy { slo_ms: f64, op: OperatingPoint },
    /// Energy×delay product at `op`. Per-node selection is greedy
    /// (the product is not additive across nodes); the DAG-aware
    /// descent scores the true whole-graph product.
    MinEdp { op: OperatingPoint },
}

impl Default for PlanObjective {
    fn default() -> Self {
        Self::MinTraffic
    }
}

impl PlanObjective {
    pub const fn name(&self) -> &'static str {
        match self {
            Self::MinTraffic => "min-traffic",
            Self::MinLatency { .. } => "min-latency",
            Self::MinEnergy { .. } => "min-energy",
            Self::MinEdp { .. } => "min-edp",
        }
    }

    /// Parse a CLI objective name. `freq_mhz` fixes the operating
    /// point; `slo_ms` only matters for `min-energy`.
    pub fn parse(s: &str, freq_mhz: f64, slo_ms: f64) -> anyhow::Result<Self> {
        let op = OperatingPoint::for_freq(freq_mhz);
        Ok(match s {
            "min-traffic" => Self::MinTraffic,
            "min-latency" => Self::MinLatency { op },
            "min-energy" => Self::MinEnergy { slo_ms, op },
            "min-edp" => Self::MinEdp { op },
            _ => anyhow::bail!(
                "unknown objective '{s}' (min-traffic | min-latency | min-energy | min-edp)"
            ),
        })
    }
}

/// Predicted energy of one node or one whole plan from its traffic and
/// exact cycles — SRAM/pool counters at zero, exactly like
/// [`GraphPlan::energy_j`], so per-node metrics sum to the plan total.
fn metric_energy_j(t: &NodeTraffic, cycles: u64, op: OperatingPoint) -> f64 {
    EnergyModel::default().energy(&predicted_stats(t, cycles), op).total_j()
}

/// The scalar one node contributes to the objective — additive across
/// nodes for every objective except EDP (see [`PlanObjective::MinEdp`]).
fn objective_metric(obj: PlanObjective, t: &NodeTraffic, cycles: u64) -> f64 {
    match obj {
        PlanObjective::MinTraffic => t.total_bytes() as f64,
        PlanObjective::MinLatency { .. } => cycles as f64,
        PlanObjective::MinEnergy { op, .. } => metric_energy_j(t, cycles, op),
        PlanObjective::MinEdp { op } => metric_energy_j(t, cycles, op) * cycles as f64,
    }
}

/// Canvas index of a node input (mirror of `codegen::canvas_of`):
/// 0 is the graph input, node *i* writes canvas *i + 1*.
fn canvas_of(r: NodeRef) -> usize {
    match r {
        NodeRef::Input => 0,
        NodeRef::Node(i) => i + 1,
    }
}

/// Per-conv-node static context: the spec and its pre-pad input plane.
struct ConvInfo {
    spec: ConvSpec,
    h: usize,
    w: usize,
}

/// What one node *writes* on its output canvas, per segment.
enum WShape {
    /// Conv image tiles: a partition of the valid output plane, all
    /// channels. `row_bounds`/`col_bounds` are canvas-space partition
    /// boundaries (length `g + 1`).
    Tiles { row_bounds: Vec<usize>, col_bounds: Vec<usize> },
    /// Channel-chunked full-plane writers (pool/add/concat copies).
    Chunks { channels: Vec<(usize, usize)>, y: (usize, usize), x: (usize, usize) },
}

impl WShape {
    fn segments(&self) -> usize {
        match self {
            WShape::Tiles { row_bounds, col_bounds } => {
                (row_bounds.len() - 1) * (col_bounds.len() - 1)
            }
            WShape::Chunks { channels, .. } => channels.len(),
        }
    }
}

/// What one node *reads* from one input canvas, per segment.
enum RShape {
    /// Conv tile input windows (with halo), all channels. Intervals are
    /// canvas-space `(start, end)`, sorted, possibly overlapping.
    Tiles { rows: Vec<(usize, usize)>, cols: Vec<(usize, usize)> },
    /// Channel-chunked full-plane readers.
    Chunks { channels: Vec<(usize, usize)>, y: (usize, usize), x: (usize, usize) },
}

/// Number of partition cells `[B[i], B[i+1])` intersecting `[a, b)`.
fn cells(bounds: &[usize], (a, b): (usize, usize)) -> u64 {
    if b <= a || bounds.len() < 2 {
        return 0;
    }
    let n = bounds.len() - 1;
    let first = bounds[1..].partition_point(|&e| e <= a);
    let last = bounds[..n].partition_point(|&s| s < b);
    last.saturating_sub(first) as u64
}

/// Count overlapping pairs between two sorted, internally-disjoint
/// channel-interval lists.
fn overlap_pairs(aa: &[(usize, usize)], bb: &[(usize, usize)]) -> u64 {
    let mut count = 0u64;
    let mut j0 = 0usize;
    for &(a0, al) in aa {
        let a1 = a0 + al;
        while j0 < bb.len() && bb[j0].0 + bb[j0].1 <= a0 {
            j0 += 1;
        }
        let mut j = j0;
        while j < bb.len() && bb[j].0 < a1 {
            count += 1;
            j += 1;
        }
    }
    count
}

fn span_overlaps((a0, a1): (usize, usize), (b0, b1): (usize, usize)) -> bool {
    a0 < b1 && b0 < a1
}

/// Dependency edges one consumer read shape creates against a producer
/// write shape — the planner's mirror of codegen's region-intersection
/// pass.
fn count_edge(w: &WShape, r: &RShape) -> u64 {
    match (w, r) {
        (WShape::Tiles { row_bounds, col_bounds }, RShape::Tiles { rows, cols }) => {
            let row_pairs: u64 = rows.iter().map(|&iv| cells(row_bounds, iv)).sum();
            let col_pairs: u64 = cols.iter().map(|&iv| cells(col_bounds, iv)).sum();
            row_pairs * col_pairs
        }
        (WShape::Tiles { row_bounds, col_bounds }, RShape::Chunks { channels, y, x }) => {
            // every chunk reads the full plane; conv writes all channels
            cells(row_bounds, *y) * cells(col_bounds, *x) * channels.len() as u64
        }
        (WShape::Chunks { channels, y, x }, RShape::Tiles { rows, cols }) => {
            let row_hits = rows.iter().filter(|&&iv| span_overlaps(iv, *y)).count() as u64;
            let col_hits = cols.iter().filter(|&&iv| span_overlaps(iv, *x)).count() as u64;
            // conv tiles read all channels → every write chunk counts
            row_hits * col_hits * channels.len() as u64
        }
        (
            WShape::Chunks { channels: wc, y: wy, x: wx },
            RShape::Chunks { channels: rc, y: ry, x: rx },
        ) => {
            if span_overlaps(*wy, *ry) && span_overlaps(*wx, *rx) {
                overlap_pairs(rc, wc)
            } else {
                0
            }
        }
    }
}

/// Everything static the dep-edge mirror needs about a graph.
struct DepCtx {
    /// Canvas zero-border pads (mirror of codegen's consumer-pad scan).
    pads: Vec<usize>,
    /// Per-node output shapes.
    shapes: Vec<(usize, usize, usize)>,
}

impl DepCtx {
    fn shape_of(&self, graph: &Graph, r: NodeRef) -> (usize, usize, usize) {
        match r {
            NodeRef::Input => graph.in_shape(),
            NodeRef::Node(i) => self.shapes[i],
        }
    }
}

/// The write shape of node `ni` under grid choice `grid` (conv only).
fn write_shape(graph: &Graph, ctx: &DepCtx, ni: usize, grid: Option<(usize, usize)>) -> WShape {
    let node = &graph.nodes[ni];
    let dst_pad = ctx.pads[ni + 1];
    let (oh, ow, oc) = ctx.shapes[ni];
    match &node.op {
        NodeOp::Conv(_) => {
            let (gy, gx) = grid.expect("conv node needs a grid choice");
            let bounds = |n: usize, parts: usize| {
                let mut b: Vec<usize> =
                    split_even(n, parts).iter().map(|&(at, _)| dst_pad + at).collect();
                b.push(dst_pad + n);
                b
            };
            WShape::Tiles { row_bounds: bounds(oh, gy), col_bounds: bounds(ow, gx) }
        }
        NodeOp::Pool(_) => {
            let (ih, iw, c) = ctx.shape_of(graph, node.inputs[0]);
            debug_assert_eq!(c, oc);
            WShape::Chunks {
                channels: pool_chunks(ih, iw, oh, ow, c),
                y: (dst_pad, dst_pad + oh),
                x: (dst_pad, dst_pad + ow),
            }
        }
        NodeOp::Add(_) => WShape::Chunks {
            channels: add_chunks(oh, ow, oc),
            y: (dst_pad, dst_pad + oh),
            x: (dst_pad, dst_pad + ow),
        },
        NodeOp::Concat(_) => {
            let mut channels = Vec::new();
            let mut coff = 0usize;
            for r in &node.inputs {
                let (_, _, ci) = ctx.shape_of(graph, *r);
                for (c0, cc) in concat_chunks(oh, ow, ci) {
                    channels.push((coff + c0, cc));
                }
                coff += ci;
            }
            WShape::Chunks { channels, y: (dst_pad, dst_pad + oh), x: (dst_pad, dst_pad + ow) }
        }
    }
}

/// The read shape of node `ni`'s input `idx` under grid choice `grid`.
fn read_shape(
    graph: &Graph,
    ctx: &DepCtx,
    ni: usize,
    idx: usize,
    grid: Option<(usize, usize)>,
) -> RShape {
    let node = &graph.nodes[ni];
    let src = node.inputs[idx];
    let src_pad = ctx.pads[canvas_of(src)];
    let (ih, iw, ic) = ctx.shape_of(graph, src);
    match &node.op {
        NodeOp::Conv(c) => {
            let (gy, gx) = grid.expect("conv node needs a grid choice");
            let (oh, ow, _) = ctx.shapes[ni];
            let kp = 3 * c.k.div_ceil(3);
            let off = src_pad - c.pad;
            let ivs = |n: usize, parts: usize| {
                split_even(n, parts)
                    .iter()
                    .map(|&(at, len)| {
                        let start = off + at * c.stride;
                        (start, start + (len - 1) * c.stride + kp)
                    })
                    .collect()
            };
            RShape::Tiles { rows: ivs(oh, gy), cols: ivs(ow, gx) }
        }
        NodeOp::Pool(_) => {
            let (oh, ow, _) = ctx.shapes[ni];
            RShape::Chunks {
                channels: pool_chunks(ih, iw, oh, ow, ic),
                y: (src_pad, src_pad + ih),
                x: (src_pad, src_pad + iw),
            }
        }
        NodeOp::Add(_) => RShape::Chunks {
            channels: add_chunks(ih, iw, ic),
            y: (src_pad, src_pad + ih),
            x: (src_pad, src_pad + iw),
        },
        NodeOp::Concat(_) => RShape::Chunks {
            channels: concat_chunks(ih, iw, ic),
            y: (src_pad, src_pad + ih),
            x: (src_pad, src_pad + iw),
        },
    }
}

/// Total cross-node dependency edges the compiled segment DAG will
/// contain under the given per-conv-node grid choices. `fused_dw_of`
/// mirrors codegen's fusion map (pointwise node → its absorbed
/// depthwise producer): a fused-away producer emits no segments, and
/// the pointwise node's segments read the producer's *input* canvas
/// through the depthwise tile geometry instead.
fn count_dep_edges(
    graph: &Graph,
    ctx: &DepCtx,
    grids: &[Option<(usize, usize)>],
    fused_dw_of: &[Option<usize>],
) -> u64 {
    let n = graph.nodes.len();
    let mut fused_away = vec![false; n];
    for di in fused_dw_of.iter().flatten() {
        fused_away[*di] = true;
    }
    let writes: Vec<WShape> =
        (0..n).map(|ni| write_shape(graph, ctx, ni, grids[ni])).collect();
    let mut total = 0u64;
    for (ni, node) in graph.nodes.iter().enumerate() {
        if fused_away[ni] {
            continue; // emits no segments of its own
        }
        if let Some(di) = fused_dw_of[ni] {
            // the fused segment's only read is the dw input window
            if let NodeRef::Node(p) = graph.nodes[di].inputs[0] {
                total += count_edge(&writes[p], &read_shape(graph, ctx, di, 0, grids[di]));
            }
            continue;
        }
        for (idx, r) in node.inputs.iter().enumerate() {
            // An Add reads both operands inside ONE segment; if both
            // edges point at the same producer the emitter dedupes the
            // dependency, so count it once.
            let dup_add_read = matches!(node.op, NodeOp::Add(_))
                && idx == 1
                && node.inputs[0] == node.inputs[1];
            if dup_add_read {
                continue;
            }
            if let NodeRef::Node(p) = r {
                total += count_edge(&writes[*p], &read_shape(graph, ctx, ni, idx, grids[ni]));
            }
        }
    }
    total
}

/// Per-node parallel width (independently schedulable segments).
fn node_width(graph: &Graph, ctx: &DepCtx, ni: usize, grid: Option<(usize, usize)>) -> u64 {
    write_shape(graph, ctx, ni, grid).segments() as u64
}

/// Critical-path cycles through the node DAG: each node contributes
/// its **exact** cycle count divided by its achievable width.
fn critical_path(
    graph: &Graph,
    ctx: &DepCtx,
    node_cycles: &[u64],
    grids: &[Option<(usize, usize)>],
) -> u64 {
    let mut cp = vec![0u64; graph.nodes.len()];
    let mut best = 0u64;
    for (i, node) in graph.nodes.iter().enumerate() {
        let width = node_width(graph, ctx, i, grids[i]).clamp(1, PAR_WIDTH);
        let own = node_cycles[i] / width;
        let base = node
            .inputs
            .iter()
            .map(|r| match r {
                NodeRef::Input => 0,
                NodeRef::Node(j) => cp[*j],
            })
            .max()
            .unwrap_or(0);
        cp[i] = base + own;
        best = best.max(cp[i]);
    }
    best
}

/// One conv node's chosen plan, with its predicted costs — the rows of
/// `kn-stream plan --optimize`.
#[derive(Clone, Debug)]
pub struct NodePlanReport {
    pub node: usize,
    pub name: String,
    pub grid: (usize, usize),
    pub c_groups: usize,
    pub ntiles: usize,
    pub sram_bytes: usize,
    pub traffic: NodeTraffic,
}

/// A whole-graph decomposition assignment plus its predicted costs.
pub struct GraphPlan {
    pub policy: PlanPolicy,
    pub objective: PlanObjective,
    pub sram_budget: usize,
    /// Per-node executable plan (`Some` for conv nodes) — feed to
    /// `compiler::compile_graph_with_plans`.
    pub plans: Vec<Option<Plan>>,
    /// Predicted per-node DRAM traffic (every node).
    pub node_traffic: Vec<NodeTraffic>,
    /// Predicted per-node device cycles — **exact** against the
    /// measured per-node `SimStats` under the default DRAM timing. A
    /// fused-away depthwise producer carries 0 (its pointwise consumer
    /// carries the fused segment's cycles), mirroring `node_traffic`.
    pub node_cycles: Vec<u64>,
    /// Conv-node summary rows.
    pub reports: Vec<NodePlanReport>,
    /// Cross-tile dependency edges the segment DAG will contain.
    pub dep_edges: u64,
    /// Critical-path cycles (parallelism proxy over exact node cycles).
    pub est_critical_path_cycles: u64,
}

impl GraphPlan {
    pub fn total_traffic(&self) -> NodeTraffic {
        let mut t = NodeTraffic::default();
        for nt in &self.node_traffic {
            t.add(nt);
        }
        t
    }

    /// Predicted frame cycles — exact vs the measured serial device.
    pub fn predicted_cycles(&self) -> u64 {
        self.node_cycles.iter().sum()
    }

    /// Predicted frame latency at an operating point, in milliseconds.
    pub fn latency_ms(&self, op: OperatingPoint) -> f64 {
        self.predicted_cycles() as f64 * op.cycle_s() * 1e3
    }

    /// Predicted frame stats (exact MACs, DRAM bytes **and** cycles)
    /// for the energy model.
    pub fn predicted_stats(&self) -> SimStats {
        predicted_stats(&self.total_traffic(), self.predicted_cycles())
    }

    /// Estimated energy per frame at an operating point (DRAM + MAC +
    /// control terms; SRAM term under-estimated — see `planner::cost`).
    pub fn energy_j(&self, op: OperatingPoint) -> f64 {
        EnergyModel::default().energy(&self.predicted_stats(), op).total_j()
    }
}

/// Plan a graph under the chip's 128 KB budget (traffic objective).
pub fn plan_graph(graph: &Graph, policy: PlanPolicy) -> anyhow::Result<GraphPlan> {
    plan_graph_budget(graph, policy, SRAM_BYTES)
}

/// Plan a graph under the chip's 128 KB budget against an objective.
pub fn plan_graph_objective(
    graph: &Graph,
    policy: PlanPolicy,
    objective: PlanObjective,
) -> anyhow::Result<GraphPlan> {
    plan_graph_budget_objective(graph, policy, SRAM_BYTES, objective)
}

/// Plan a graph under an explicit SRAM budget (what-if sweeps; only
/// budgets ≤ the chip's can execute).
pub fn plan_graph_budget(
    graph: &Graph,
    policy: PlanPolicy,
    sram_budget: usize,
) -> anyhow::Result<GraphPlan> {
    plan_graph_budget_objective(graph, policy, sram_budget, PlanObjective::MinTraffic)
}

/// Plan a graph under an explicit SRAM budget and objective. A
/// `MinEnergy` plan that would miss its SLO at the chosen operating
/// point falls back to the latency-optimal plan — so its energy never
/// exceeds `MinLatency`'s, and the SLO is met whenever any plan in the
/// candidate space can meet it.
pub fn plan_graph_budget_objective(
    graph: &Graph,
    policy: PlanPolicy,
    sram_budget: usize,
    objective: PlanObjective,
) -> anyhow::Result<GraphPlan> {
    let gp = plan_impl(graph, policy, sram_budget, objective)?;
    if let PlanObjective::MinEnergy { slo_ms, op } = objective {
        if slo_ms > 0.0 && gp.latency_ms(op) > slo_ms {
            let mut fb = plan_impl(graph, policy, sram_budget, PlanObjective::MinLatency { op })?;
            fb.objective = objective;
            return Ok(fb);
        }
    }
    Ok(gp)
}

fn plan_impl(
    graph: &Graph,
    policy: PlanPolicy,
    sram_budget: usize,
    objective: PlanObjective,
) -> anyhow::Result<GraphPlan> {
    let shapes = graph.validate()?;
    let n = graph.nodes.len();

    // canvas pads, as codegen assigns them
    let mut pads = vec![0usize; n + 1];
    for node in &graph.nodes {
        if let NodeOp::Conv(c) = &node.op {
            let j = canvas_of(node.inputs[0]);
            pads[j] = pads[j].max(c.pad);
        }
    }
    let ctx = DepCtx { pads, shapes: shapes.clone() };

    let infos: Vec<Option<ConvInfo>> = graph
        .nodes
        .iter()
        .map(|node| match &node.op {
            NodeOp::Conv(c) => {
                let (h, w, _) = ctx.shape_of(graph, node.inputs[0]);
                Some(ConvInfo { spec: c.clone(), h, w })
            }
            _ => None,
        })
        .collect();

    // ---- per-policy candidate selection ---------------------------------
    let mut sel: Vec<Option<ConvCandidate>> = vec![None; n];
    match policy {
        PlanPolicy::Heuristic => {
            for (i, info) in infos.iter().enumerate() {
                let Some(info) = info else { continue };
                let plan = plan_conv_budget(&info.spec, info.h, info.w, sram_budget)
                    .map_err(|e| anyhow::anyhow!("conv {}: {e}", info.spec.name))?;
                sel[i] = Some(if plan.dw {
                    super::cost::dw_candidate(
                        &info.spec,
                        info.h,
                        info.w,
                        plan.gy,
                        plan.gx,
                        plan.c_per_group,
                    )
                } else {
                    super::cost::conv_candidate(
                        &info.spec,
                        info.h,
                        info.w,
                        plan.gy,
                        plan.gx,
                        plan.c_per_group,
                    )
                });
            }
        }
        PlanPolicy::MinTraffic | PlanPolicy::DagAware => {
            let mut lists: Vec<Vec<ConvCandidate>> = vec![Vec::new(); n];
            let mut picks: Vec<Option<usize>> = vec![None; n];
            for (i, info) in infos.iter().enumerate() {
                let Some(info) = info else { continue };
                let cands = enumerate_conv(&info.spec, info.h, info.w, sram_budget);
                anyhow::ensure!(
                    !cands.is_empty(),
                    "conv {}: no feasible decomposition at {} B SRAM",
                    info.spec.name,
                    sram_budget
                );
                lists[i] = if policy == PlanPolicy::DagAware {
                    prune_for_search(cands, TRAFFIC_SLACK, CAND_CAP)
                } else if objective == PlanObjective::MinTraffic {
                    vec![*min_traffic(&cands).expect("non-empty")]
                } else {
                    // latency/energy objectives rank the full list
                    cands
                };
                // Seed: index 0 is the min-traffic head; other
                // objectives take the per-node metric argmin (globally
                // optimal for every additive objective).
                picks[i] = Some(match objective {
                    PlanObjective::MinTraffic => 0,
                    _ => {
                        let mut bi = 0;
                        let mut bm = f64::INFINITY;
                        for (j, c) in lists[i].iter().enumerate() {
                            let cyc = conv_node_cycles(&info.spec, info.h, info.w, c);
                            let m = objective_metric(objective, &c.traffic, cyc);
                            if m < bm {
                                bm = m;
                                bi = j;
                            }
                        }
                        bi
                    }
                });
            }
            if policy == PlanPolicy::DagAware {
                descend(graph, &ctx, &infos, &lists, &mut picks, objective);
            }
            for i in 0..n {
                if let Some(j) = picks[i] {
                    sel[i] = Some(lists[i][j]);
                }
            }
        }
    }

    // ---- depthwise→pointwise fusion post-pass ---------------------------
    // For the searching policies, absorb a 1×1 pointwise conv into its
    // depthwise producer when the fused lowering (dw output staged in
    // SRAM, never round-tripped through DRAM) beats the best *separate*
    // plans on the active objective. `fuse[ni] = Some(di)` mirrors the
    // fusion map codegen derives; the dw node's candidate is re-pinned
    // to the grid that minimizes the fused metric.
    let mut fuse: Vec<Option<usize>> = vec![None; n];
    let mut fused_cost: Vec<Option<(NodeTraffic, usize, u64)>> = vec![None; n];
    if matches!(policy, PlanPolicy::MinTraffic | PlanPolicy::DagAware) {
        for ni in 0..n {
            let NodeOp::Conv(pw) = &graph.nodes[ni].op else { continue };
            if pw.k != 1 || pw.stride != 1 || pw.pad != 0 || pw.groups != 1 {
                continue;
            }
            let Some(&NodeRef::Node(di)) = graph.nodes[ni].inputs.first() else { continue };
            let NodeOp::Conv(dw) = &graph.nodes[di].op else { continue };
            if !dw_eligible(dw) || graph.output == NodeRef::Node(di) || fuse[di].is_some() {
                continue;
            }
            let consumers = graph
                .nodes
                .iter()
                .flat_map(|nd| nd.inputs.iter())
                .filter(|r| matches!(r, NodeRef::Node(j) if *j == di))
                .count();
            if consumers != 1 {
                continue;
            }
            let dinfo = infos[di].as_ref().expect("dw conv info");
            // Best fused grid: the dw node's grid drives both phases,
            // so minimize the *fused* objective metric over its
            // candidates.
            let mut best: Option<(ConvCandidate, NodeTraffic, usize, u64, f64)> = None;
            for dc in enumerate_conv(&dinfo.spec, dinfo.h, dinfo.w, sram_budget) {
                let (t, sram) = fused_dwpw_traffic(&dinfo.spec, pw, dinfo.h, dinfo.w, &dc);
                if sram > sram_budget {
                    continue;
                }
                let cyc = fused_dwpw_cycles(&dinfo.spec, pw, dinfo.h, dinfo.w, &dc);
                let m = objective_metric(objective, &t, cyc);
                let better = match &best {
                    None => true,
                    Some((.., bm)) => m < *bm,
                };
                if better {
                    best = Some((dc, t, sram, cyc, m));
                }
            }
            let Some((dc, ft, fsram, fcyc, fmetric)) = best else { continue };
            let sep_metric = [di, ni]
                .iter()
                .map(|&i| {
                    let c = sel[i].expect("separate candidate");
                    let info = infos[i].as_ref().expect("conv info");
                    let cyc = conv_node_cycles(&info.spec, info.h, info.w, &c);
                    objective_metric(objective, &c.traffic, cyc)
                })
                .sum::<f64>();
            if fmetric < sep_metric {
                sel[di] = Some(dc);
                fuse[ni] = Some(di);
                fused_cost[ni] = Some((ft, fsram, fcyc));
            }
        }
    }
    let mut fused_away = vec![false; n];
    for di in fuse.iter().flatten() {
        fused_away[*di] = true;
    }

    // ---- finalize --------------------------------------------------------
    let mut plans: Vec<Option<Plan>> = vec![None; n];
    let mut node_traffic = vec![NodeTraffic::default(); n];
    let mut node_cycles = vec![0u64; n];
    let mut reports = Vec::new();
    let mut grids: Vec<Option<(usize, usize)>> = vec![None; n];
    for (i, node) in graph.nodes.iter().enumerate() {
        match (&node.op, &sel[i]) {
            (NodeOp::Conv(_), Some(cand)) => {
                let info = infos[i].as_ref().expect("conv info");
                let mut report = NodePlanReport {
                    node: i,
                    name: info.spec.name.clone(),
                    grid: (cand.gy, cand.gx),
                    c_groups: cand.c_groups,
                    ntiles: cand.ntiles,
                    sram_bytes: cand.sram_bytes,
                    traffic: cand.traffic,
                };
                if let Some(di) = fuse[i] {
                    // pointwise absorbed into its depthwise producer:
                    // ride the dw grid, chunk staged channels 16-wide
                    let dc = sel[di].expect("fused dw candidate");
                    let mut plan = plan_with_grid(
                        &info.spec,
                        info.h,
                        info.w,
                        dc.gy,
                        dc.gx,
                        info.spec.cin.min(crate::NUM_CU),
                    );
                    plan.fuse_dw = true;
                    let (ft, fsram, fcyc) = fused_cost[i].expect("fused traffic");
                    report.grid = (dc.gy, dc.gx);
                    report.c_groups = plan.c_groups;
                    report.ntiles = plan.tiles.len();
                    report.sram_bytes = fsram;
                    report.traffic = ft;
                    node_traffic[i] = ft;
                    node_cycles[i] = fcyc;
                    grids[i] = Some((dc.gy, dc.gx));
                    plans[i] = Some(plan);
                } else {
                    plans[i] = Some(plan_with_grid(
                        &info.spec,
                        info.h,
                        info.w,
                        cand.gy,
                        cand.gx,
                        cand.c_per_group,
                    ));
                    // a fused-away dw node's traffic and cycles are
                    // carried by its pointwise consumer
                    if !fused_away[i] {
                        node_traffic[i] = cand.traffic;
                        node_cycles[i] = conv_node_cycles(&info.spec, info.h, info.w, cand);
                    }
                    report.traffic = node_traffic[i];
                    grids[i] = Some((cand.gy, cand.gx));
                }
                reports.push(report);
            }
            (op, _) => {
                let ins: Vec<(usize, usize, usize)> =
                    node.inputs.iter().map(|r| ctx.shape_of(graph, *r)).collect();
                node_traffic[i] = fixed_node_traffic(op, &ins, shapes[i]);
                node_cycles[i] = fixed_node_cycles(op, &ins, shapes[i]);
            }
        }
    }
    lint_fusion(graph, &fuse, &plans)?;
    let dep_edges = count_dep_edges(graph, &ctx, &grids, &fuse);
    let est_critical_path_cycles = critical_path(graph, &ctx, &node_cycles, &grids);
    Ok(GraphPlan {
        policy,
        objective,
        sram_budget,
        plans,
        node_traffic,
        node_cycles,
        reports,
        dep_edges,
        est_critical_path_cycles,
    })
}

/// Lint the fusion post-pass output before it leaves the planner: every
/// `fuse_dw` plan must name a depthwise producer whose plan rides the
/// identical tile grid, and no plan may carry the marker without a
/// fusion entry. Codegen re-checks the same contracts at emission; the
/// planner-side lint attributes a violation to the search instead of
/// letting it surface as a downstream emission error.
fn lint_fusion(
    graph: &Graph,
    fuse: &[Option<usize>],
    plans: &[Option<Plan>],
) -> anyhow::Result<()> {
    for (ni, fused) in fuse.iter().enumerate() {
        let Some(di) = *fused else {
            if let Some(p) = &plans[ni] {
                anyhow::ensure!(
                    !p.fuse_dw,
                    "graph {}: node {ni} carries fuse_dw without a fusion entry",
                    graph.name
                );
            }
            continue;
        };
        let pw = plans[ni]
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("graph {}: fused node {ni} has no plan", graph.name))?;
        let dwp = plans[di].as_ref().ok_or_else(|| {
            anyhow::anyhow!("graph {}: fused dw producer {di} has no plan", graph.name)
        })?;
        anyhow::ensure!(
            pw.fuse_dw && !pw.dw,
            "graph {}: fusion entry {ni} -> {di} but node {ni}'s plan is not a fused pointwise",
            graph.name
        );
        anyhow::ensure!(
            dwp.dw && !dwp.fuse_dw,
            "graph {}: fused producer {di} is not a plain depthwise plan",
            graph.name
        );
        let (NodeOp::Conv(pws), NodeOp::Conv(dws)) = (&graph.nodes[ni].op, &graph.nodes[di].op)
        else {
            anyhow::bail!("graph {}: fusion entry {ni} -> {di} names a non-conv node", graph.name);
        };
        anyhow::ensure!(
            pws.k == 1 && pws.stride == 1 && pws.pad == 0 && pws.groups == 1,
            "graph {}: fused consumer {ni} is not a 1x1/s1/p0 pointwise conv",
            graph.name
        );
        anyhow::ensure!(
            dw_eligible(dws),
            "graph {}: fused producer {di} is not depthwise-eligible",
            graph.name
        );
        anyhow::ensure!(
            (dwp.gy, dwp.gx) == (pw.gy, pw.gx)
                && dwp.tiles.len() == pw.tiles.len()
                && dwp
                    .tiles
                    .iter()
                    .zip(&pw.tiles)
                    .all(|(a, b)| (a.oy0, a.ox0, a.oh, a.ow) == (b.oy0, b.ox0, b.oh, b.ow)),
            "graph {}: fused pair {ni} -> {di} rides mismatched tile grids \
             ({}x{} vs {}x{})",
            graph.name,
            dwp.gy,
            dwp.gx,
            pw.gy,
            pw.gx
        );
    }
    Ok(())
}

/// Coordinate descent over the pruned candidate lists: re-choose one
/// node at a time against the full objective until a sweep converges.
/// `picks[i]` indexes into `lists[i]`; per-candidate cycles are
/// memoized up front so each score evaluation is pure bookkeeping.
fn descend(
    graph: &Graph,
    ctx: &DepCtx,
    infos: &[Option<ConvInfo>],
    lists: &[Vec<ConvCandidate>],
    picks: &mut [Option<usize>],
    objective: PlanObjective,
) {
    let n = graph.nodes.len();
    // fusion is decided in a post-pass; the descent scores unfused plans
    let no_fuse: Vec<Option<usize>> = vec![None; n];
    // memoized exact cycles per (node, candidate)
    let cyc: Vec<Vec<u64>> = infos
        .iter()
        .zip(lists)
        .map(|(info, list)| match info {
            Some(info) => list
                .iter()
                .map(|c| conv_node_cycles(&info.spec, info.h, info.w, c))
                .collect(),
            None => Vec::new(),
        })
        .collect();
    // fixed (non-conv) node costs never change across the descent
    let fixed: Vec<Option<(NodeTraffic, u64)>> = graph
        .nodes
        .iter()
        .enumerate()
        .map(|(i, node)| {
            if infos[i].is_some() {
                return None;
            }
            let ins: Vec<(usize, usize, usize)> =
                node.inputs.iter().map(|r| ctx.shape_of(graph, *r)).collect();
            Some((
                fixed_node_traffic(&node.op, &ins, ctx.shapes[i]),
                fixed_node_cycles(&node.op, &ins, ctx.shapes[i]),
            ))
        })
        .collect();
    let score = |picks: &[Option<usize>]| -> f64 {
        let mut totals = NodeTraffic::default();
        let mut node_cycles = vec![0u64; n];
        let mut grids: Vec<Option<(usize, usize)>> = vec![None; n];
        let mut total_cycles = 0u64;
        for i in 0..n {
            match picks[i] {
                Some(j) => {
                    let c = &lists[i][j];
                    totals.add(&c.traffic);
                    node_cycles[i] = cyc[i][j];
                    grids[i] = Some((c.gy, c.gx));
                }
                None => {
                    let (t, fc) = fixed[i].as_ref().expect("fixed node cost");
                    totals.add(t);
                    node_cycles[i] = *fc;
                }
            }
            total_cycles += node_cycles[i];
        }
        let deps = count_dep_edges(graph, ctx, &grids, &no_fuse) as f64;
        let cp = critical_path(graph, ctx, &node_cycles, &grids) as f64;
        match objective {
            PlanObjective::MinTraffic => {
                totals.total_bytes() as f64 + DEP_EDGE_BYTES * deps + CP_BYTES_PER_CYCLE * cp
            }
            PlanObjective::MinLatency { .. } => {
                total_cycles as f64 + DEP_EDGE_CYCLES * deps + CP_CYCLE_WEIGHT * cp
            }
            PlanObjective::MinEnergy { slo_ms, op } => {
                // 1 J per ms over the SLO: a deadline miss dominates
                // any realistic per-frame energy difference.
                let e = metric_energy_j(&totals, total_cycles, op);
                let lat_ms = total_cycles as f64 * op.cycle_s() * 1e3;
                let penalty = if slo_ms > 0.0 { (lat_ms - slo_ms).max(0.0) } else { 0.0 };
                e + penalty
            }
            PlanObjective::MinEdp { op } => {
                metric_energy_j(&totals, total_cycles, op) * (total_cycles as f64 * op.cycle_s())
            }
        }
    };

    let mut best = score(picks);
    for _ in 0..MAX_SWEEPS {
        let mut changed = false;
        for i in 0..n {
            if infos[i].is_none() || lists[i].len() <= 1 {
                continue;
            }
            // Evaluate every candidate for node i against the current
            // neighbor choices; keep the best found (restoring the
            // incumbent if none improves) so `best == score(picks)`
            // holds at every step.
            let mut best_pick = picks[i];
            for j in 0..lists[i].len() {
                picks[i] = Some(j);
                let s = score(picks);
                if s + 1e-9 < best {
                    best = s;
                    best_pick = Some(j);
                    changed = true;
                }
            }
            picks[i] = best_pick;
        }
        if !changed {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn policies_plan_every_zoo_graph() {
        for name in ["quicknet", "facenet", "edgenet", "widenet", "gapnet", "alexnet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            for policy in PlanPolicy::ALL {
                let gp = plan_graph(&graph, policy).unwrap_or_else(|e| {
                    panic!("{name}/{}: {e}", policy.name());
                });
                assert_eq!(gp.plans.len(), graph.nodes.len(), "{name}");
                for (i, node) in graph.nodes.iter().enumerate() {
                    assert_eq!(
                        gp.plans[i].is_some(),
                        matches!(node.op, NodeOp::Conv(_)),
                        "{name} node {i}"
                    );
                }
                let t = gp.total_traffic();
                assert!(t.read_bytes > 0 && t.write_bytes > 0 && t.macs > 0, "{name}");
                assert!(gp.dep_edges > 0, "{name} has producer->consumer edges");
                assert!(gp.est_critical_path_cycles > 0, "{name}");
                assert!(gp.energy_j(crate::energy::dvfs::PEAK) > 0.0, "{name}");
            }
        }
    }

    #[test]
    fn min_traffic_never_exceeds_heuristic() {
        for name in ["quicknet", "facenet", "edgenet", "widenet", "gapnet", "alexnet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let heur = plan_graph(&graph, PlanPolicy::Heuristic).unwrap();
            let mt = plan_graph(&graph, PlanPolicy::MinTraffic).unwrap();
            assert!(
                mt.total_traffic().total_bytes() <= heur.total_traffic().total_bytes(),
                "{name}: min-traffic {} > heuristic {}",
                mt.total_traffic().total_bytes(),
                heur.total_traffic().total_bytes()
            );
        }
    }

    #[test]
    fn dag_aware_improves_traffic_or_deps_somewhere() {
        let mut improved = false;
        for name in ["quicknet", "facenet", "edgenet", "widenet", "gapnet", "alexnet"] {
            let graph = zoo::graph_by_name(name).unwrap();
            let heur = plan_graph(&graph, PlanPolicy::Heuristic).unwrap();
            let dag = plan_graph(&graph, PlanPolicy::DagAware).unwrap();
            improved |= dag.total_traffic().total_bytes() < heur.total_traffic().total_bytes()
                || dag.dep_edges < heur.dep_edges;
        }
        assert!(improved, "DagAware must beat Heuristic on traffic or deps somewhere");
    }

    #[test]
    fn budget_sweep_is_monotone_in_traffic() {
        // Tighter SRAM → finer decompositions → no less DRAM traffic
        // (the Fig. 6 trade, now produced by the planner).
        let graph = zoo::graph_by_name("alexnet").unwrap();
        let mut last = 0u64;
        for budget in [256 * 1024, 128 * 1024, 64 * 1024] {
            let gp = plan_graph_budget(&graph, PlanPolicy::MinTraffic, budget).unwrap();
            let total = gp.total_traffic().total_bytes();
            assert!(
                last == 0 || total >= last,
                "budget {budget}: traffic {total} fell below the looser budget's {last}"
            );
            last = total;
        }
    }

    #[test]
    fn interval_counting_primitives() {
        // partition [0,4,8,12]; reads clamp into it
        let b = vec![0usize, 4, 8, 12];
        assert_eq!(cells(&b, (0, 12)), 3);
        assert_eq!(cells(&b, (3, 5)), 2);
        assert_eq!(cells(&b, (4, 8)), 1);
        assert_eq!(cells(&b, (11, 30)), 1);
        assert_eq!(cells(&b, (12, 14)), 0);
        assert_eq!(cells(&b, (5, 5)), 0);
        let aa = [(0usize, 4usize), (4, 4)];
        let bb = [(2usize, 4usize), (6, 2)];
        assert_eq!(overlap_pairs(&aa, &bb), 3);
        assert_eq!(overlap_pairs(&bb, &aa), 3);
    }
}
