//! Decomposition planner — the optimization layer between the graph IR
//! and `compiler::codegen`.
//!
//! The paper (§5, Fig. 6) chooses image/feature/channel decomposition
//! to fit the 128 KB buffer bank while minimizing off-chip traffic;
//! `compiler::decompose::plan_conv` hard-codes one point of that trade
//! ("fewest tiles, then fewest channel groups"). This module models
//! the choice instead, in the style related accelerators justify their
//! dataflows (Ahmadi et al. 2020's serial-accumulation traffic model,
//! Origami's energy-per-access analysis):
//!
//! * [`enumerate`] — all feasible `(gy, gx, c_per_group)` plans per
//!   conv node, not one heuristic winner;
//! * [`cost`] — an analytic model predicting per-plan DRAM bytes
//!   (input reload with halo, weight re-streaming, bias, output
//!   writeback), SRAM footprint, MACs and **exact** device cycles —
//!   pinned to measured `SimStats` counters by property test;
//! * [`search`] — graph-level selection: the per-node traffic optimum
//!   ([`PlanPolicy::MinTraffic`]) and a DAG-aware coordinate descent
//!   ([`PlanPolicy::DagAware`]) that co-optimizes split axes across
//!   producer→consumer edges, scored by the chosen [`PlanObjective`]
//!   (DRAM bytes, exact latency, energy under an SLO, or EDP at an
//!   operating point) plus a cross-tile dependency-edge count (an
//!   exact mirror of codegen's region-intersection pass) and a
//!   critical-path/parallelism term in true cycle units.
//!
//! All policies produce plans the unchanged emitter executes; frame
//! outputs are bit-identical across policies (the decomposition only
//! reorders wrapping-int32 accumulation and disjoint DMA traffic),
//! which `tests/integration_planner.rs` enforces against the scalar
//! oracle.

pub mod cost;
pub mod enumerate;
pub mod search;

pub use cost::{ConvCandidate, NodeTraffic};
pub use enumerate::enumerate_conv;
pub use search::{
    plan_graph, plan_graph_budget, plan_graph_budget_objective, plan_graph_objective, GraphPlan,
    NodePlanReport, PlanObjective,
};

/// Which decomposition planner the compiler runs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlanPolicy {
    /// The historical per-node heuristic (`plan_conv`): fewest image
    /// tiles, then fewest channel groups. The compile default.
    #[default]
    Heuristic,
    /// Per-node DRAM-traffic optimum from the candidate enumeration.
    MinTraffic,
    /// Graph-level search: traffic + cross-edge dependency count +
    /// critical-path term, co-optimized across producer→consumer pairs.
    DagAware,
}

impl PlanPolicy {
    pub const ALL: [PlanPolicy; 3] =
        [PlanPolicy::Heuristic, PlanPolicy::MinTraffic, PlanPolicy::DagAware];

    pub fn name(self) -> &'static str {
        match self {
            PlanPolicy::Heuristic => "heuristic",
            PlanPolicy::MinTraffic => "min-traffic",
            PlanPolicy::DagAware => "dag-aware",
        }
    }

    /// Parse a CLI spelling (`--plan-policy heuristic|min-traffic|dag-aware`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "heuristic" => Ok(PlanPolicy::Heuristic),
            "min-traffic" => Ok(PlanPolicy::MinTraffic),
            "dag-aware" => Ok(PlanPolicy::DagAware),
            other => anyhow::bail!(
                "unknown plan policy '{other}' (have: heuristic, min-traffic, dag-aware)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_names_roundtrip() {
        for p in PlanPolicy::ALL {
            assert_eq!(PlanPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(PlanPolicy::parse("optimal").is_err());
        assert_eq!(PlanPolicy::default(), PlanPolicy::Heuristic);
    }
}
