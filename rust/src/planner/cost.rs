//! Analytic cost model of the decomposition compiler — predicts, per
//! candidate plan and per graph node, exactly the DRAM traffic **and
//! device cycles** the emitted command stream will generate, plus the
//! SRAM footprint and MAC count used for scoring.
//!
//! The DRAM numbers are **exact by construction**: each formula mirrors
//! one emission loop of `compiler::codegen` —
//!
//! * *input reload with halo*: `emit_conv` re-loads a tile's input
//!   window once per conv group when the whole channel set fits SRAM
//!   (`c_groups == 1`), and once per **feature tile** per channel group
//!   otherwise (the `loaded` slot tracks only one channel slice, so
//!   every 16-feature round re-streams all `c_groups` slices);
//! * *weight re-streaming*: every tile re-issues the `LoadWeights` of
//!   all `(group, feature-tile, tap, channel-group)` blocks — the cost
//!   of image decomposition the paper's §5 trades against SRAM;
//! * *bias*: one 16×int32 block per `(tile, group, feature-tile)`;
//! * *output writeback*: decomposition-invariant — every output pixel
//!   is stored exactly once.
//!
//! `tests/integration_planner.rs` holds a property test pinning these
//! predictions to measured [`SimStats`] counters bit-for-bit across
//! random specs × random feasible plans; if an emitter changes its
//! streaming order, that test fails before any planner decision drifts.

use crate::model::{ConvSpec, NodeOp};
use crate::sim::accbuf::ACC_TILE_PX;
use crate::sim::dma::SegClock;
use crate::sim::SimStats;
use crate::{NUM_CU, PES_PER_CU, SRAM_BYTES};

/// Predicted DRAM traffic (and MACs) of one graph node for one frame.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NodeTraffic {
    pub read_bytes: u64,
    pub write_bytes: u64,
    pub macs: u64,
}

impl NodeTraffic {
    pub fn total_bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    pub fn add(&mut self, o: &NodeTraffic) {
        self.read_bytes += o.read_bytes;
        self.write_bytes += o.write_bytes;
        self.macs += o.macs;
    }
}

/// One feasible `(gy, gx, c_per_group)` decomposition of a conv node,
/// evaluated analytically in O(1) — tiles are materialized (via
/// `decompose::plan_with_grid`) only for the candidate that wins.
#[derive(Clone, Copy, Debug)]
pub struct ConvCandidate {
    pub gy: usize,
    pub gx: usize,
    pub c_per_group: usize,
    pub c_groups: usize,
    pub m_tiles: usize,
    /// Image tiles (`gy · gx`) — the node's parallel width.
    pub ntiles: usize,
    /// Peak SRAM bytes (worst input tile + output staging + weights).
    pub sram_bytes: usize,
    pub in_tile_bytes: usize,
    pub out_tile_bytes: usize,
    /// Largest output tile in pixels (ACC BUF constraint).
    pub max_out_px: usize,
    /// Depthwise fast-path schedule (`emit_conv_dw` lowering).
    pub dw: bool,
    /// Predicted DRAM traffic of the emitted schedule.
    pub traffic: NodeTraffic,
}

impl ConvCandidate {
    /// Feasible on hardware with `sram_budget` bytes of buffer bank.
    pub fn feasible(&self, sram_budget: usize) -> bool {
        self.max_out_px <= ACC_TILE_PX && self.sram_bytes <= sram_budget
    }
}

/// Split one output axis of length `n` into `parts` spans (as
/// `split_even` does) and return `(Σ input span, max output span,
/// max input span)` for stride `s` and padded kernel `kp` — the
/// separable aggregates the O(1) candidate evaluation needs.
fn axis_aggregates(n: usize, parts: usize, s: usize, kp: usize) -> (usize, usize, usize) {
    debug_assert!(parts >= 1 && parts <= n);
    // Each span of `len` outputs reads `(len-1)·s + kp` input rows, so
    // Σ over the partition telescopes to `parts·kp + s·(n − parts)`.
    let sum_in = parts * kp + s * (n - parts);
    let max_out = n.div_ceil(parts);
    let max_in = (max_out - 1) * s + kp;
    (sum_in, max_out, max_in)
}

/// Output plane of a conv over a pre-pad `(h, w)` input.
pub fn conv_out_shape(spec: &ConvSpec, h: usize, w: usize) -> (usize, usize) {
    (
        (h + 2 * spec.pad - spec.k) / spec.stride + 1,
        (w + 2 * spec.pad - spec.k) / spec.stride + 1,
    )
}

/// Evaluate one `(gy, gx, c_per_group)` candidate for `spec` over a
/// pre-pad `(h, w)` input plane. O(1): no tile list is materialized.
pub fn conv_candidate(
    spec: &ConvSpec,
    h: usize,
    w: usize,
    gy: usize,
    gx: usize,
    c_per_group: usize,
) -> ConvCandidate {
    let (oh, ow) = conv_out_shape(spec, h, w);
    let kp = 3 * spec.k.div_ceil(3);
    let ntaps = (kp / 3) * (kp / 3);
    let cg = spec.cin / spec.groups;
    let mg = spec.cout / spec.groups;
    let m_tiles = mg.div_ceil(NUM_CU);
    let c_groups = cg.div_ceil(c_per_group);
    let ntiles = gy * gx;

    let (row_in_sum, max_th, max_ih) = axis_aggregates(oh, gy, spec.stride, kp);
    let (col_in_sum, max_tw, max_iw) = axis_aggregates(ow, gx, spec.stride, kp);
    // Σ over tiles of (ih · iw) factors into the per-axis sums.
    let sum_in_px = row_in_sum * col_in_sum;

    // SRAM footprint formula shared with `decompose::candidate_sram`.
    let in_tile_bytes = max_ih * max_iw * c_per_group * 2;
    let out_tile_bytes = max_th * max_tw * NUM_CU * 2;
    let w_bytes = c_per_group * PES_PER_CU * NUM_CU * 2;

    // emit_conv re-streams the input per feature tile unless the whole
    // channel set stays resident (`c_groups == 1`).
    let input_rounds = if c_groups == 1 { 1 } else { m_tiles };
    let input_px = (sum_in_px * spec.groups * cg * input_rounds) as u64;
    let weight_px = (ntiles * spec.groups * m_tiles * ntaps * cg * PES_PER_CU * NUM_CU) as u64;
    let bias_px = (ntiles * spec.groups * m_tiles * 2 * NUM_CU) as u64;
    let output_px = (spec.cout * oh * ow) as u64;
    let macs = (oh * ow) as u64
        * (NUM_CU * PES_PER_CU * ntaps * cg * spec.groups * m_tiles) as u64;

    ConvCandidate {
        gy,
        gx,
        c_per_group,
        c_groups,
        m_tiles,
        ntiles,
        sram_bytes: in_tile_bytes + out_tile_bytes + w_bytes,
        in_tile_bytes,
        out_tile_bytes,
        max_out_px: max_th * max_tw,
        dw: false,
        traffic: NodeTraffic {
            read_bytes: 2 * (input_px + weight_px + bias_px),
            write_bytes: 2 * output_px,
            macs,
        },
    }
}

/// Evaluate one `(gy, gx, c_per_group)` *depthwise fast-path* candidate
/// (`emit_conv_dw` lowering): `c_per_group` ≤ 16 channel planes per
/// pass across the engine lanes, one 9×16 weight block per (channel
/// group, tap), every channel's input loaded once per tile.
pub fn dw_candidate(
    spec: &ConvSpec,
    h: usize,
    w: usize,
    gy: usize,
    gx: usize,
    c_per_group: usize,
) -> ConvCandidate {
    debug_assert!(spec.groups == spec.cin && spec.cout == spec.cin);
    debug_assert!((1..=NUM_CU.min(spec.cin)).contains(&c_per_group));
    let (oh, ow) = conv_out_shape(spec, h, w);
    let kp = 3 * spec.k.div_ceil(3);
    let ntaps = (kp / 3) * (kp / 3);
    let c_groups = spec.cin.div_ceil(c_per_group);
    let ntiles = gy * gx;

    let (row_in_sum, max_th, max_ih) = axis_aggregates(oh, gy, spec.stride, kp);
    let (col_in_sum, max_tw, max_iw) = axis_aggregates(ow, gx, spec.stride, kp);
    let sum_in_px = row_in_sum * col_in_sum;

    // SRAM footprint shared with `decompose::candidate_sram_dw`.
    let in_tile_bytes = max_ih * max_iw * c_per_group * 2;
    let out_tile_bytes = max_th * max_tw * NUM_CU * 2;
    let w_bytes = PES_PER_CU * NUM_CU * 2;

    let input_px = (sum_in_px * spec.cin) as u64;
    let weight_px = (ntiles * c_groups * ntaps * PES_PER_CU * NUM_CU) as u64;
    let bias_px = (ntiles * c_groups * 2 * NUM_CU) as u64;
    let output_px = (spec.cout * oh * ow) as u64;
    // the dw pass issues 144 multiplies per output pixel per tap pass
    let macs = (oh * ow) as u64 * (NUM_CU * PES_PER_CU * ntaps * c_groups) as u64;

    ConvCandidate {
        gy,
        gx,
        c_per_group,
        c_groups,
        m_tiles: 1,
        ntiles,
        sram_bytes: in_tile_bytes + out_tile_bytes + w_bytes,
        in_tile_bytes,
        out_tile_bytes,
        max_out_px: max_th * max_tw,
        dw: true,
        traffic: NodeTraffic {
            read_bytes: 2 * (input_px + weight_px + bias_px),
            write_bytes: 2 * output_px,
            macs,
        },
    }
}

/// Predicted traffic of a fused depthwise→pointwise pair emitted by
/// `emit_fused_dwpw` on the depthwise candidate's grid: the dw phase
/// reads its input/weights/biases exactly like `dw_candidate`, the pw
/// phase re-streams its weights per tile, and the dw→pw intermediate
/// never touches DRAM — only the pw output is written back. Also
/// returns the fused pair's peak SRAM bytes (dw input group + `C`
/// staging planes + pw output staging).
pub fn fused_dwpw_traffic(
    dw_spec: &ConvSpec,
    pw_spec: &ConvSpec,
    h: usize,
    w: usize,
    dw_cand: &ConvCandidate,
) -> (NodeTraffic, usize) {
    debug_assert!(pw_spec.k == 1 && pw_spec.stride == 1 && pw_spec.pad == 0);
    debug_assert_eq!(pw_spec.cin, dw_spec.cout);
    let (oh, ow) = conv_out_shape(dw_spec, h, w);
    let kp = 3 * dw_spec.k.div_ceil(3);
    let ntaps_dw = (kp / 3) * (kp / 3);
    let c_mid = dw_spec.cout;
    let m_tiles_pw = pw_spec.cout.div_ceil(NUM_CU);
    let ntiles = dw_cand.ntiles;
    let (gy, gx) = (dw_cand.gy, dw_cand.gx);

    let (row_in_sum, _, max_ih) = axis_aggregates(oh, gy, dw_spec.stride, kp);
    let (col_in_sum, _, max_iw) = axis_aggregates(ow, gx, dw_spec.stride, kp);
    let sum_in_px = row_in_sum * col_in_sum;
    // pw staging planes: the 1×1 pass's (th+2)×(tw+2) input window
    let (_, max_th, max_sh) = axis_aggregates(oh, gy, 1, 3);
    let (_, max_tw, max_sw) = axis_aggregates(ow, gx, 1, 3);

    let input_px = (sum_in_px * dw_spec.cin) as u64;
    let dw_weight_px = (ntiles * dw_cand.c_groups * ntaps_dw * PES_PER_CU * NUM_CU) as u64;
    let dw_bias_px = (ntiles * dw_cand.c_groups * 2 * NUM_CU) as u64;
    let pw_weight_px = (ntiles * m_tiles_pw * c_mid * PES_PER_CU * NUM_CU) as u64;
    let pw_bias_px = (ntiles * m_tiles_pw * 2 * NUM_CU) as u64;
    let output_px = (pw_spec.cout * oh * ow) as u64;
    let macs = (oh * ow) as u64
        * (NUM_CU * PES_PER_CU) as u64
        * (dw_cand.c_groups * ntaps_dw + c_mid * m_tiles_pw) as u64;

    let sram_bytes = (max_ih * max_iw * dw_cand.c_per_group
        + c_mid * max_sh * max_sw
        + max_th * max_tw * NUM_CU)
        * 2;
    (
        NodeTraffic {
            read_bytes: 2
                * (input_px + dw_weight_px + dw_bias_px + pw_weight_px + pw_bias_px),
            write_bytes: 2 * output_px,
            macs,
        },
        sram_bytes,
    )
}

/// Channel chunking `[ (c0, len), … ]` for a per-channel SRAM cost of
/// `per_ch` bytes — the exact loop of the pool/add/concat emitters
/// (their differing `cc_max` caps are all subsumed by the
/// `min(c - ch0)` every iteration takes anyway).
pub fn chunk_spans(c: usize, per_ch: usize) -> Vec<(usize, usize)> {
    let cc_max = (SRAM_BYTES / per_ch.max(1)).max(1);
    let mut out = Vec::new();
    let mut ch0 = 0;
    while ch0 < c {
        let cc = cc_max.min(c - ch0);
        out.push((ch0, cc));
        ch0 += cc;
    }
    out
}

/// Channel chunks of a pool node over an `(ih, iw, c)` input.
pub fn pool_chunks(ih: usize, iw: usize, oh: usize, ow: usize, c: usize) -> Vec<(usize, usize)> {
    chunk_spans(c, (ih * iw + oh * ow) * 2)
}

/// Channel chunks of an add node over an `(h, w, c)` plane.
pub fn add_chunks(h: usize, w: usize, c: usize) -> Vec<(usize, usize)> {
    chunk_spans(c, 3 * h * w * 2)
}

/// Channel chunks of one concat *input* of `ci` channels on an
/// `(h, w)` plane.
pub fn concat_chunks(h: usize, w: usize, ci: usize) -> Vec<(usize, usize)> {
    chunk_spans(ci, h * w * 2)
}

/// Predicted DRAM traffic of a non-conv node — plan-independent, fixed
/// by the shapes (`ins` = input shapes, `out` = output shape).
pub fn fixed_node_traffic(
    op: &NodeOp,
    ins: &[(usize, usize, usize)],
    out: (usize, usize, usize),
) -> NodeTraffic {
    let px = |(h, w, c): (usize, usize, usize)| (h * w * c) as u64;
    match op {
        NodeOp::Conv(_) => unreachable!("conv traffic comes from its candidate"),
        NodeOp::Pool(_) => NodeTraffic {
            read_bytes: 2 * px(ins[0]),
            write_bytes: 2 * px(out),
            macs: 0,
        },
        NodeOp::Add(_) => NodeTraffic {
            read_bytes: 2 * (px(ins[0]) + px(ins[1])),
            write_bytes: 2 * px(out),
            macs: 0,
        },
        NodeOp::Concat(_) => NodeTraffic {
            read_bytes: 2 * ins.iter().map(|&s| px(s)).sum::<u64>(),
            write_bytes: 2 * px(out),
            macs: 0,
        },
    }
}

// ---------------------------------------------------------------------------
// exact cycle model
// ---------------------------------------------------------------------------
//
// Like the DRAM-byte formulas above, the cycle predictions replay each
// emission loop of `compiler::codegen` against the simulator's charge
// rules (`sim::dma::SegClock` + `scan_timing`/`dw_scan_timing`), so
// predicted cycles equal the measured `SimStats::cycles` **exactly**
// under the default DRAM timing. Because a tile's cycle count depends
// only on its `(th, tw)` output span and `split_even` produces at most
// two distinct span lengths per axis, a conv node costs at most four
// tile replays regardless of grid size.

/// Distinct output-span lengths of `split_even(n, parts)` with their
/// multiplicities (zero-length spans are skipped, as `tiles_for_grid`
/// does). At most two classes.
fn axis_classes(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let (q, r) = (n / parts, n % parts);
    let mut out = Vec::new();
    if r > 0 {
        out.push((q + 1, r));
    }
    if q > 0 {
        out.push((q, parts - r));
    }
    out
}

/// Replay one `emit_conv` tile segment: `(groups × m_tiles)` rounds of
/// bias → primed/pipelined weight blocks → per-pass channel scans →
/// feature stores, with the `loaded` slot tracking which channel slice
/// is resident (inputs reload only when it changes).
fn conv_tile_cycles(spec: &ConvSpec, th: usize, tw: usize, cand: &ConvCandidate) -> u64 {
    let kp = 3 * spec.k.div_ceil(3);
    let ntaps = (kp / 3) * (kp / 3);
    let (ih, iw) = ((th - 1) * spec.stride + kp, (tw - 1) * spec.stride + kp);
    let cg = spec.cin / spec.groups;
    let mg = spec.cout / spec.groups;
    let t = crate::sim::fastconv::scan_timing(ih, iw, th, tw, spec.stride);
    let scan = t.fill_cycles + t.scan_cycles;
    let cn_of = |cgi: usize| cand.c_per_group.min(cg - cgi * cand.c_per_group);
    let total_passes = cand.c_groups * ntaps;
    let mut clk = SegClock::new();
    let mut loaded: Option<(usize, usize)> = None;
    for g in 0..spec.groups {
        for mt in 0..cand.m_tiles {
            clk.dma(2 * 2 * NUM_CU as u64); // bias block
            clk.load_weights((cn_of(0) * PES_PER_CU * NUM_CU) as u64); // prime
            for pass in 0..total_passes {
                let cgi = pass / ntaps;
                if loaded != Some((g, cgi)) {
                    for _ in 0..cn_of(cgi) {
                        clk.dma((ih * iw * 2) as u64);
                    }
                    clk.sync();
                    loaded = Some((g, cgi));
                }
                if pass + 1 < total_passes {
                    let next = cn_of((pass + 1) / ntaps);
                    clk.load_weights((next * PES_PER_CU * NUM_CU) as u64);
                }
                if pass == 0 {
                    clk.compute((th * tw / 8 + 1) as u64); // ACC init (PASS_FIRST)
                }
                clk.pop_weights();
                clk.compute(cn_of(cgi) as u64 * scan);
                if pass + 1 == total_passes {
                    // requantize flush drains all 16 lanes (PASS_LAST)
                    clk.compute((th * tw * NUM_CU).div_ceil(8) as u64);
                }
            }
            for _ in 0..NUM_CU.min(mg - mt * NUM_CU) {
                clk.dma((th * tw * 2) as u64);
            }
            clk.sync();
        }
    }
    clk.cyc
}

/// Replay one `emit_conv_dw` tile segment: per channel group, bias +
/// packed plane loads, then one weight block and one multi-lane scan
/// per tap, then the group's stores. The flush drains only the `cn`
/// live lanes.
fn dw_tile_cycles(spec: &ConvSpec, th: usize, tw: usize, cand: &ConvCandidate) -> u64 {
    let kp = 3 * spec.k.div_ceil(3);
    let ntaps = (kp / 3) * (kp / 3);
    let (ih, iw) = ((th - 1) * spec.stride + kp, (tw - 1) * spec.stride + kp);
    let mut clk = SegClock::new();
    for cgi in 0..cand.c_groups {
        let cn = cand.c_per_group.min(spec.cin - cgi * cand.c_per_group);
        clk.dma(2 * 2 * NUM_CU as u64);
        for _ in 0..cn {
            clk.dma((ih * iw * 2) as u64);
        }
        clk.sync();
        for ti in 0..ntaps {
            clk.load_weights((PES_PER_CU * NUM_CU) as u64);
            if ti == 0 {
                clk.compute((th * tw / 8 + 1) as u64);
            }
            clk.pop_weights();
            let t = crate::sim::fastconv::dw_scan_timing(ih, iw, th, tw, spec.stride, cn);
            clk.compute(t.fill_cycles + t.scan_cycles);
            if ti + 1 == ntaps {
                clk.compute((th * tw * cn).div_ceil(8) as u64);
            }
        }
        for _ in 0..cn {
            clk.dma((th * tw * 2) as u64);
        }
        clk.sync();
    }
    clk.cyc
}

/// Exact device cycles of one conv node under `cand` — the sum over
/// tile classes of one segment replay each.
pub fn conv_node_cycles(spec: &ConvSpec, h: usize, w: usize, cand: &ConvCandidate) -> u64 {
    let (oh, ow) = conv_out_shape(spec, h, w);
    let mut total = 0u64;
    for &(th, cy) in &axis_classes(oh, cand.gy) {
        for &(tw, cx) in &axis_classes(ow, cand.gx) {
            let one = if cand.dw {
                dw_tile_cycles(spec, th, tw, cand)
            } else {
                conv_tile_cycles(spec, th, tw, cand)
            };
            total += (cy * cx) as u64 * one;
        }
    }
    total
}

/// Exact device cycles of a fused depthwise→pointwise pair emitted by
/// `emit_fused_dwpw` on the depthwise candidate's grid: the dw phase
/// runs without stores (its output stays staged on chip), then the pw
/// phase consumes the staged planes — one weight block per channel
/// group, popped with no prefetch pipelining — and writes back.
pub fn fused_dwpw_cycles(
    dw_spec: &ConvSpec,
    pw_spec: &ConvSpec,
    h: usize,
    w: usize,
    dw_cand: &ConvCandidate,
) -> u64 {
    debug_assert!(pw_spec.k == 1 && pw_spec.stride == 1 && pw_spec.pad == 0);
    let (oh, ow) = conv_out_shape(dw_spec, h, w);
    let kp = 3 * dw_spec.k.div_ceil(3);
    let ntaps_dw = (kp / 3) * (kp / 3);
    let c_mid = dw_spec.cout;
    let cpg_pw = c_mid.min(NUM_CU);
    let c_groups_pw = c_mid.div_ceil(cpg_pw);
    let m_tiles_pw = pw_spec.cout.div_ceil(NUM_CU);
    let mut total = 0u64;
    for &(th, cy) in &axis_classes(oh, dw_cand.gy) {
        for &(tw, cx) in &axis_classes(ow, dw_cand.gx) {
            let mut clk = SegClock::new();
            // dw phase: like `dw_tile_cycles` but with no writeback
            let (dih, diw) = ((th - 1) * dw_spec.stride + kp, (tw - 1) * dw_spec.stride + kp);
            for cgi in 0..dw_cand.c_groups {
                let cn = dw_cand.c_per_group.min(dw_spec.cin - cgi * dw_cand.c_per_group);
                clk.dma(2 * 2 * NUM_CU as u64);
                for _ in 0..cn {
                    clk.dma((dih * diw * 2) as u64);
                }
                clk.sync();
                for ti in 0..ntaps_dw {
                    clk.load_weights((PES_PER_CU * NUM_CU) as u64);
                    if ti == 0 {
                        clk.compute((th * tw / 8 + 1) as u64);
                    }
                    clk.pop_weights();
                    let t = crate::sim::fastconv::dw_scan_timing(
                        dih,
                        diw,
                        th,
                        tw,
                        dw_spec.stride,
                        cn,
                    );
                    clk.compute(t.fill_cycles + t.scan_cycles);
                    if ti + 1 == ntaps_dw {
                        clk.compute((th * tw * cn).div_ceil(8) as u64);
                    }
                }
            }
            // pw phase over the staged (th+2)×(tw+2) halo windows
            let t = crate::sim::fastconv::scan_timing(th + 2, tw + 2, th, tw, 1);
            let scan = t.fill_cycles + t.scan_cycles;
            for mt in 0..m_tiles_pw {
                clk.dma(2 * 2 * NUM_CU as u64);
                for cgi in 0..c_groups_pw {
                    let cn = cpg_pw.min(c_mid - cgi * cpg_pw);
                    clk.load_weights((cn * PES_PER_CU * NUM_CU) as u64);
                    if cgi == 0 {
                        clk.compute((th * tw / 8 + 1) as u64);
                    }
                    clk.pop_weights();
                    clk.compute(cn as u64 * scan);
                    if cgi + 1 == c_groups_pw {
                        clk.compute((th * tw * NUM_CU).div_ceil(8) as u64);
                    }
                }
                for _ in 0..NUM_CU.min(pw_spec.cout - mt * NUM_CU) {
                    clk.dma((th * tw * 2) as u64);
                }
                clk.sync();
            }
            total += (cy * cx) as u64 * clk.cyc;
        }
    }
    total
}

/// Exact device cycles of a non-conv node — one chunk-segment replay
/// per emitted chunk, mirroring `emit_pool`/`emit_add`/`emit_concat`.
pub fn fixed_node_cycles(
    op: &NodeOp,
    ins: &[(usize, usize, usize)],
    out: (usize, usize, usize),
) -> u64 {
    let mut total = 0u64;
    match op {
        NodeOp::Conv(_) => unreachable!("conv cycles come from its candidate"),
        NodeOp::Pool(p) => {
            let (ih, iw, c) = ins[0];
            let (oh, ow, _) = out;
            for &(_, cc) in &pool_chunks(ih, iw, oh, ow, c) {
                let mut clk = SegClock::new();
                for _ in 0..cc {
                    clk.dma((ih * iw * 2) as u64);
                }
                clk.sync();
                clk.compute((cc * oh * ow * p.k) as u64);
                for _ in 0..cc {
                    clk.dma((oh * ow * 2) as u64);
                }
                clk.sync();
                total += clk.cyc;
            }
        }
        NodeOp::Add(_) => {
            let (h, w, c) = ins[0];
            for &(_, cc) in &add_chunks(h, w, c) {
                let mut clk = SegClock::new();
                for _ in 0..2 * cc {
                    clk.dma((h * w * 2) as u64);
                }
                clk.sync();
                clk.compute(3 * (cc * h * w).div_ceil(8) as u64);
                for _ in 0..cc {
                    clk.dma((h * w * 2) as u64);
                }
                clk.sync();
                total += clk.cyc;
            }
        }
        NodeOp::Concat(_) => {
            for &(h, w, ci) in ins {
                for &(_, cc) in &concat_chunks(h, w, ci) {
                    let mut clk = SegClock::new();
                    for _ in 0..cc {
                        clk.dma((h * w * 2) as u64);
                    }
                    clk.sync();
                    for _ in 0..cc {
                        clk.dma((h * w * 2) as u64);
                    }
                    clk.sync();
                    total += clk.cyc;
                }
            }
        }
    }
    total
}

/// Predicted frame [`SimStats`] from the summed node traffic and the
/// summed exact node cycles: MACs, DRAM bytes **and cycles** are exact
/// under the default DRAM timing. SRAM word counters are left at zero,
/// which under-estimates energy by the on-chip-SRAM term.
pub fn predicted_stats(total: &NodeTraffic, cycles: u64) -> SimStats {
    SimStats {
        cycles,
        macs: total.macs,
        dram_read_bytes: total.read_bytes,
        dram_write_bytes: total.write_bytes,
        ..SimStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::decompose::plan_conv;
    use crate::model::zoo;
    use crate::model::LayerSpec;

    #[test]
    fn axis_aggregates_match_explicit_split() {
        for (n, parts, s, kp) in [(55, 3, 4, 12), (13, 2, 1, 3), (224, 7, 1, 3), (10, 10, 2, 6)] {
            let spans = crate::compiler::decompose::split_even(n, parts);
            let explicit_sum: usize = spans.iter().map(|&(_, l)| (l - 1) * s + kp).sum();
            let explicit_max_out = spans.iter().map(|&(_, l)| l).max().unwrap();
            let (sum, max_out, max_in) = axis_aggregates(n, parts, s, kp);
            assert_eq!(sum, explicit_sum, "n={n} parts={parts}");
            assert_eq!(max_out, explicit_max_out);
            assert_eq!(max_in, (explicit_max_out - 1) * s + kp);
        }
    }

    /// The O(1) candidate evaluation must agree with the solver's
    /// materialized plan on every shared quantity.
    #[test]
    fn candidate_matches_materialized_plan() {
        for name in ["alexnet", "facenet", "vgg16"] {
            let net = zoo::by_name(name).unwrap();
            let mut shape = net.in_shape();
            for l in &net.layers {
                if let LayerSpec::Conv(c) = l {
                    let plan = plan_conv(c, shape.0, shape.1).unwrap();
                    let cand =
                        conv_candidate(c, shape.0, shape.1, plan.gy, plan.gx, plan.c_per_group);
                    assert_eq!(cand.ntiles, plan.tiles.len(), "{name}/{}", c.name);
                    assert_eq!(cand.sram_bytes, plan.sram_bytes, "{name}/{}", c.name);
                    assert_eq!(cand.in_tile_bytes, plan.in_tile_bytes, "{name}/{}", c.name);
                    assert_eq!(cand.out_tile_bytes, plan.out_tile_bytes, "{name}/{}", c.name);
                    assert_eq!(cand.c_groups, plan.c_groups, "{name}/{}", c.name);
                    assert_eq!(cand.m_tiles, plan.m_tiles, "{name}/{}", c.name);
                    let max_px = plan.tiles.iter().map(|t| t.oh * t.ow).max().unwrap();
                    assert_eq!(cand.max_out_px, max_px, "{name}/{}", c.name);
                    let sum_in: usize = plan.tiles.iter().map(|t| t.ih * t.iw).sum();
                    // recover Σ ih·iw from the traffic formula inverse
                    let rounds = if cand.c_groups == 1 { 1 } else { cand.m_tiles };
                    let cgt = c.cin / c.groups * c.groups * rounds;
                    let kp = 3 * c.k.div_ceil(3);
                    let ntaps = (kp / 3) * (kp / 3);
                    let weight_px = (cand.ntiles
                        * c.groups
                        * cand.m_tiles
                        * ntaps
                        * (c.cin / c.groups)
                        * PES_PER_CU
                        * NUM_CU) as u64;
                    let bias_px = (cand.ntiles * c.groups * cand.m_tiles * 2 * NUM_CU) as u64;
                    let input_px = cand.traffic.read_bytes / 2 - weight_px - bias_px;
                    assert_eq!(input_px, (sum_in * cgt) as u64, "{name}/{}", c.name);
                }
                shape = l.out_shape(shape);
            }
        }
    }

    #[test]
    fn axis_classes_match_explicit_split() {
        for (n, parts) in [(55, 3), (13, 2), (224, 7), (10, 10), (7, 9), (16, 16)] {
            let spans = crate::compiler::decompose::split_even(n, parts);
            let mut counts = std::collections::BTreeMap::new();
            for &(_, l) in &spans {
                if l > 0 {
                    *counts.entry(l).or_insert(0usize) += 1;
                }
            }
            let classes = axis_classes(n, parts);
            assert_eq!(classes.len(), counts.len(), "n={n} parts={parts}");
            for &(len, cnt) in &classes {
                assert_eq!(counts[&len], cnt, "n={n} parts={parts} len={len}");
            }
        }
    }

    #[test]
    fn chunk_spans_partition() {
        for (c, per_ch) in [(96, 4000), (3, 200_000), (256, 2 * 27 * 27 * 2)] {
            let chunks = chunk_spans(c, per_ch);
            let total: usize = chunks.iter().map(|&(_, l)| l).sum();
            assert_eq!(total, c);
            let mut at = 0;
            for &(c0, l) in &chunks {
                assert_eq!(c0, at);
                assert!(l >= 1);
                at += l;
            }
        }
    }
}
