//! # kn-stream
//!
//! A production-shaped reproduction of *"A Streaming Accelerator for Deep
//! Convolutional Neural Networks with Image and Feature Decomposition for
//! Resource-limited System Applications"* (Du, Du, Li, Su, Chang — 2017).
//!
//! The paper's 65 nm ASIC is replaced (see `DESIGN.md` §Substitution) by a
//! functionally **bit-exact, cycle-level simulator** plus the full system
//! around it:
//!
//! - [`sim`] — the accelerator microarchitecture: 128 KB single-port SRAM
//!   buffer bank, streaming column buffer, 16×(3×3) CU engine array,
//!   accumulation buffer, reconfigurable pooling module, DMA/DRAM, AXI
//!   command front-end. Functional conv compute runs through the
//!   tap-major plane-streaming kernel (`sim::fastconv`, bit-exact with
//!   the PE chain); cycle/traffic accounting stays in a decoupled
//!   analytic timing model.
//! - [`isa`] — the command set streamed over the 16-bit AXI bus.
//! - [`compiler`] — graph IR → decomposition plan (image / feature /
//!   kernel decomposition, paper §5) → command stream, plus the
//!   dependency-annotated segment DAG that lets `NetRunner` execute
//!   decomposed tiles concurrently — across nodes and branches, with no
//!   layer barriers — with bit-identical output and stats.
//! - [`planner`] — the optimization layer above the emitter: candidate
//!   enumeration over all feasible decompositions, an analytic DRAM/
//!   SRAM/energy cost model validated against measured `SimStats`, and
//!   a DAG-aware search that co-optimizes split axes across
//!   producer→consumer edges (`PlanPolicy`).
//! - [`analysis`] — static schedule analyzer: an abstract interpreter
//!   over the compiled command stream that independently re-derives
//!   every invariant codegen promises (ISA linting, SRAM/DRAM bounds,
//!   uninitialized-read detection, `PASS_DW` field checks) plus a
//!   segment-DAG race detector proving every RAW/WAR/WAW hazard is
//!   covered by a dependency path.
//! - [`model`] — network descriptions (linear `NetSpec` stacks and the
//!   graph IR with residual Add / channel Concat) + the deterministic
//!   synthetic zoo shared with the Python compile path.
//! - [`fixed`] — the 16-bit fixed-point numerics contract (bit-exact with
//!   the Pallas kernels).
//! - [`energy`] — area / power / DVFS models reproducing Table 2 & Fig. 7.
//! - [`runtime`] — PJRT client that loads the AOT HLO artifacts produced
//!   by `python/compile/aot.py` (golden models; never Python at runtime).
//! - [`coordinator`] — the streaming frame server: request queue, layer
//!   scheduling onto the accelerator, metrics.
//! - [`obs`] — observability: Perfetto span tracing (per-segment spans
//!   with exact DMA-load / compute / store sub-spans), Prometheus metric
//!   exposition, and the structured fleet event log with monotonic
//!   sequence numbers.
//! - [`util`] — offline-environment substrates built from scratch: PRNG,
//!   JSON parser, CLI parser, stats, bench harness, property testing.

pub mod analysis;
pub mod compiler;
pub mod coordinator;
pub mod energy;
pub mod fixed;
pub mod isa;
pub mod model;
pub mod obs;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Number of convolution units in the engine array (paper §4.1).
pub const NUM_CU: usize = 16;
/// Processing engines (multipliers) per CU — one 3×3 window (paper §4.2).
pub const PES_PER_CU: usize = 9;
/// On-chip buffer-bank capacity in bytes (paper §4.1).
pub const SRAM_BYTES: usize = 128 * 1024;
/// SRAM word width in bytes — streams 8 int16 pixels per cycle (paper §3).
pub const SRAM_WIDTH_BYTES: usize = 16;
/// Pixels streamed per cycle (16 B word / 2 B pixel).
pub const PIXELS_PER_CYCLE: usize = SRAM_WIDTH_BYTES / 2;
/// Command FIFO depth (paper §4.1).
pub const CMD_FIFO_DEPTH: usize = 128;
