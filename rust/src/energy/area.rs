//! Area model (paper §6, Fig. 7): core 2.3 mm × 0.8 mm = 1.84 mm² in
//! TSMC 65 nm GP, split 57 % SRAM buffer bank / 35 % CU engine array /
//! 8 % column buffer; 0.3 M gates.
//!
//! Built bottom-up from per-resource densities (65 nm-class single-port
//! SRAM macro density, synthesized 16-bit MAC area) and checked against
//! the paper's split — so "what if" configurations (more CUs, bigger
//! SRAM) scale sensibly in the ablation bench.

use crate::{NUM_CU, PES_PER_CU, SRAM_BYTES};

/// Per-resource area parameters (65 nm-class).
#[derive(Clone, Debug)]
pub struct AreaModel {
    /// Single-port SRAM density: mm² per KiB (macro incl. periphery).
    pub sram_mm2_per_kib: f64,
    /// One 16-bit MAC (multiplier + adder + weight regs + DFF): mm².
    pub mac_mm2: f64,
    /// Column buffer: mm² per pixel of row-buffer storage (2×N int16 +
    /// muxing).
    pub colbuf_mm2_per_px: f64,
    /// Fixed overhead: ACC BUF + pooling + AXI/decoder + DMA, mm².
    pub misc_mm2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        Self {
            // calibrated: 128 KiB → 1.049 mm² (57 % of 1.84 mm²)
            sram_mm2_per_kib: 1.049 / 128.0,
            // calibrated: 144 MACs + engine wiring → 0.644 mm² (35 %)
            mac_mm2: 0.644 / (NUM_CU * PES_PER_CU) as f64,
            // calibrated: 2×256-px row buffers + mux → 0.147 mm² (8 %)
            colbuf_mm2_per_px: 0.147 / 512.0,
            misc_mm2: 0.0,
        }
    }
}

/// Area report for one configuration.
#[derive(Clone, Debug)]
pub struct AreaReport {
    pub sram_mm2: f64,
    pub cu_array_mm2: f64,
    pub colbuf_mm2: f64,
    pub misc_mm2: f64,
}

impl AreaReport {
    pub fn total_mm2(&self) -> f64 {
        self.sram_mm2 + self.cu_array_mm2 + self.colbuf_mm2 + self.misc_mm2
    }
    pub fn shares(&self) -> (f64, f64, f64) {
        let t = self.total_mm2();
        (self.sram_mm2 / t, self.cu_array_mm2 / t, self.colbuf_mm2 / t)
    }
}

impl AreaModel {
    /// Area of a configuration: `sram_bytes` of buffer bank, `n_cu` CUs
    /// of 9 PEs, a 2×`row_px` column buffer.
    pub fn report_for(&self, sram_bytes: usize, n_cu: usize, row_px: usize) -> AreaReport {
        AreaReport {
            sram_mm2: sram_bytes as f64 / 1024.0 * self.sram_mm2_per_kib,
            cu_array_mm2: (n_cu * PES_PER_CU) as f64 * self.mac_mm2,
            colbuf_mm2: (2 * row_px) as f64 * self.colbuf_mm2_per_px,
            misc_mm2: self.misc_mm2,
        }
    }

    /// The paper's configuration (Fig. 7).
    pub fn paper_config(&self) -> AreaReport {
        self.report_for(SRAM_BYTES, NUM_CU, 256)
    }

    /// Gate-count estimate: paper reports 0.3 M gates for the logic
    /// (CU array + column buffer + control; SRAM is macro area). A 65 nm
    /// NAND2-equivalent is ≈ 1.44 µm²; logic area / gate density.
    pub fn gate_count(&self, rpt: &AreaReport) -> f64 {
        let logic_mm2 = rpt.cu_array_mm2 + rpt.colbuf_mm2 + rpt.misc_mm2;
        // utilization-corrected density ≈ 0.38 Mgates/mm² for datapath
        logic_mm2 * 0.38e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_core_area_and_split() {
        let m = AreaModel::default();
        let r = m.paper_config();
        let total = r.total_mm2();
        assert!((total - 1.84).abs() / 1.84 < 0.02, "core {total:.3} mm² vs 1.84");
        let (s, c, b) = r.shares();
        assert!((s - 0.57).abs() < 0.02, "sram share {s:.3}");
        assert!((c - 0.35).abs() < 0.02, "cu share {c:.3}");
        assert!((b - 0.08).abs() < 0.02, "colbuf share {b:.3}");
    }

    #[test]
    fn gate_count_near_paper() {
        let m = AreaModel::default();
        let g = m.gate_count(&m.paper_config());
        assert!((g - 0.3e6).abs() / 0.3e6 < 0.15, "gates {g:.0} vs 0.3 M");
    }

    #[test]
    fn scaling_what_ifs() {
        let m = AreaModel::default();
        let double_sram = m.report_for(2 * SRAM_BYTES, NUM_CU, 256);
        assert!(double_sram.total_mm2() > m.paper_config().total_mm2());
        let (s, _, _) = double_sram.shares();
        assert!(s > 0.57);
        let double_cu = m.report_for(SRAM_BYTES, 32, 256);
        let (_, c, _) = double_cu.shares();
        assert!(c > 0.35);
    }
}
