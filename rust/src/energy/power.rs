//! Event-based energy model calibrated to the paper's Table 2.
//!
//! Per-event energies are 65 nm-class values (Horowitz, ISSCC'14 scaled
//! to 16-bit datapaths); `e_ctrl_cycle` (clock tree + control) and
//! `p_leak_nom` are the calibration knobs fitted so that the model's
//! peak-activity power hits the paper's two corners:
//!
//! * 500 MHz / 1.0 V, 144 GOPS → **425 mW**  (0.34 TOPS/W)
//! * 20 MHz / 0.6 V,  5.8 GOPS → **7 mW**    (0.82 TOPS/W)

use super::dvfs::OperatingPoint;
use crate::sim::SimStats;
use crate::{NUM_CU, PES_PER_CU};

/// Per-event energies at the nominal 1.0 V corner (picojoules).
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// One 16-bit MAC incl. weight-register read + local wiring.
    pub e_mac_pj: f64,
    /// One 16 B SRAM word access (single-port bank).
    pub e_sram_word_pj: f64,
    /// One int32 accumulation-buffer op (read-add-write).
    pub e_accbuf_pj: f64,
    /// One pooling comparator op.
    pub e_pool_pj: f64,
    /// Off-chip DRAM energy per byte (does not scale with core VDD).
    pub e_dram_byte_pj: f64,
    /// Control + clock-tree energy per active cycle (calibrated).
    pub e_ctrl_cycle_pj: f64,
    /// Leakage power at 1.0 V (calibrated), watts.
    pub p_leak_nom_w: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            e_mac_pj: 5.0,
            e_sram_word_pj: 12.0,
            e_accbuf_pj: 1.0,
            e_pool_pj: 0.4,
            e_dram_byte_pj: 80.0,
            e_ctrl_cycle_pj: 112.0,
            p_leak_nom_w: 2.0e-3,
        }
    }
}

/// Energy split of a run (joules).
#[derive(Clone, Debug, Default)]
pub struct EnergyBreakdown {
    pub mac_j: f64,
    pub sram_j: f64,
    pub accbuf_j: f64,
    pub pool_j: f64,
    pub dram_j: f64,
    pub ctrl_j: f64,
    pub leak_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.mac_j + self.sram_j + self.accbuf_j + self.pool_j + self.dram_j + self.ctrl_j
            + self.leak_j
    }
    /// On-chip-only total (the paper's TOPS/W excludes DRAM).
    pub fn onchip_j(&self) -> f64 {
        self.total_j() - self.dram_j
    }
}

impl EnergyModel {
    /// Energy of a simulated run at an operating point.
    pub fn energy(&self, stats: &SimStats, op: OperatingPoint) -> EnergyBreakdown {
        let ds = op.dyn_scale();
        let t = stats.cycles as f64 * op.cycle_s();
        let pj = 1e-12;
        EnergyBreakdown {
            mac_j: stats.macs as f64 * self.e_mac_pj * ds * pj,
            sram_j: (stats.sram_reads + stats.sram_writes) as f64 * self.e_sram_word_pj * ds * pj,
            accbuf_j: stats.macs as f64 / PES_PER_CU as f64 * self.e_accbuf_pj * ds * pj,
            pool_j: stats.pool_ops as f64 * self.e_pool_pj * ds * pj,
            dram_j: (stats.dram_read_bytes + stats.dram_write_bytes) as f64
                * self.e_dram_byte_pj
                * pj,
            ctrl_j: stats.cycles as f64 * self.e_ctrl_cycle_pj * ds * pj,
            leak_j: self.p_leak_nom_w * op.leak_scale() * t,
        }
    }

    /// Peak-activity power (W): every cycle does 144 MACs + one SRAM
    /// stream word + 16 ACC ops — the "GOPS plate" the paper's Table 2
    /// power numbers describe.
    pub fn peak_power_w(&self, op: OperatingPoint) -> f64 {
        let per_cycle_pj = (NUM_CU * PES_PER_CU) as f64 * self.e_mac_pj
            + 1.2 * self.e_sram_word_pj
            + NUM_CU as f64 * self.e_accbuf_pj
            + self.e_ctrl_cycle_pj;
        per_cycle_pj * 1e-12 * op.dyn_scale() * op.freq_mhz * 1e6
            + self.p_leak_nom_w * op.leak_scale()
    }

    /// Peak throughput in ops/s at a frequency (144 MACs × 2 per cycle).
    pub fn peak_ops(&self, op: OperatingPoint) -> f64 {
        (2 * NUM_CU * PES_PER_CU) as f64 * op.freq_mhz * 1e6
    }

    /// Peak energy efficiency (TOPS/W) at an operating point.
    pub fn peak_tops_per_w(&self, op: OperatingPoint) -> f64 {
        self.peak_ops(op) / self.peak_power_w(op) / 1e12
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::dvfs::{EFFICIENT, PEAK};

    #[test]
    fn calibration_hits_table2_peak_corner() {
        let m = EnergyModel::default();
        let p = m.peak_power_w(PEAK) * 1e3;
        assert!((p - 425.0).abs() / 425.0 < 0.05, "peak power {p:.1} mW vs 425 mW");
        let ops = m.peak_ops(PEAK) / 1e9;
        assert!((ops - 144.0).abs() < 1e-9, "peak {ops} GOPS");
        let eff = m.peak_tops_per_w(PEAK);
        assert!((eff - 0.3).abs() < 0.08, "peak eff {eff:.3} TOPS/W vs 0.3");
    }

    #[test]
    fn calibration_hits_table2_efficient_corner() {
        let m = EnergyModel::default();
        let p = m.peak_power_w(EFFICIENT) * 1e3;
        assert!((p - 7.0).abs() / 7.0 < 0.12, "low power {p:.2} mW vs 7 mW");
        let ops = m.peak_ops(EFFICIENT) / 1e9;
        assert!((ops - 5.76).abs() < 0.01, "low-f {ops} GOPS vs 5.8");
        let eff = m.peak_tops_per_w(EFFICIENT);
        assert!((eff - 0.8).abs() < 0.1, "eff {eff:.3} TOPS/W vs 0.8");
    }

    #[test]
    fn efficiency_improves_at_low_voltage() {
        let m = EnergyModel::default();
        assert!(m.peak_tops_per_w(EFFICIENT) > 2.0 * m.peak_tops_per_w(PEAK));
    }

    /// Pin the full per-event breakdown at the EFFICIENT corner: every
    /// term is hand-computed from the Table-2-calibrated constants
    /// (ds = 0.6² = 0.36, leak scale 0.6³ = 0.216, t = 10⁶ cy / 20 MHz
    /// = 0.05 s). A drift in any per-event energy or scaling law moves
    /// exactly one of these.
    #[test]
    fn efficient_corner_energy_breakdown_is_pinned() {
        let m = EnergyModel::default();
        let stats = SimStats {
            cycles: 1_000_000,
            macs: 9_000_000,
            sram_reads: 100_000,
            sram_writes: 50_000,
            pool_ops: 10_000,
            dram_read_bytes: 1_000_000,
            dram_write_bytes: 500_000,
            ..Default::default()
        };
        let e = m.energy(&stats, EFFICIENT);
        let close = |got: f64, want: f64| (got - want).abs() < want * 1e-9;
        assert!(close(e.mac_j, 1.62e-5), "mac {:.4e}", e.mac_j);
        assert!(close(e.sram_j, 6.48e-7), "sram {:.4e}", e.sram_j);
        assert!(close(e.accbuf_j, 3.6e-7), "accbuf {:.4e}", e.accbuf_j);
        assert!(close(e.pool_j, 1.44e-9), "pool {:.4e}", e.pool_j);
        assert!(close(e.dram_j, 1.2e-4), "dram {:.4e}", e.dram_j);
        assert!(close(e.ctrl_j, 4.032e-5), "ctrl {:.4e}", e.ctrl_j);
        assert!(close(e.leak_j, 2.16e-5), "leak {:.4e}", e.leak_j);
        assert!(close(e.onchip_j(), e.total_j() - 1.2e-4), "onchip excludes DRAM");
    }

    /// Interpolated `for_freq` points follow the linear V/f law and
    /// its derived scalings exactly: 260 MHz is the V-midpoint
    /// (0.8 V → ds 0.64, leak 0.512) and 100 MHz lands at 2/3 V.
    #[test]
    fn interpolated_points_follow_the_vf_law() {
        let m = EnergyModel::default();
        let op = OperatingPoint::for_freq(260.0);
        assert!((op.vdd - 0.8).abs() < 1e-12);
        assert!((op.dyn_scale() - 0.64).abs() < 1e-12);
        assert!((op.leak_scale() - 0.512).abs() < 1e-12);
        let p260 = m.peak_power_w(op);
        assert!(p260 > m.peak_power_w(EFFICIENT) && p260 < m.peak_power_w(PEAK));
        let op100 = OperatingPoint::for_freq(100.0);
        assert!((op100.vdd - 2.0 / 3.0).abs() < 1e-12);
        // dynamic terms of a fixed-stats workload scale with V²: the
        // 0.8 V midpoint costs exactly 0.64× the PEAK mac/ctrl energy
        let stats = SimStats { cycles: 500_000, macs: 10_000_000, ..Default::default() };
        let (mid, peak) = (m.energy(&stats, op), m.energy(&stats, PEAK));
        assert!((mid.mac_j - 0.64 * peak.mac_j).abs() < peak.mac_j * 1e-12);
        assert!((mid.ctrl_j - 0.64 * peak.ctrl_j).abs() < peak.ctrl_j * 1e-12);
        assert_eq!(mid.dram_j, peak.dram_j, "DRAM energy does not scale with core VDD");
    }

    #[test]
    fn run_energy_scales_with_voltage() {
        let m = EnergyModel::default();
        let stats = SimStats { cycles: 1_000_000, macs: 100_000_000, ..Default::default() };
        let hi = m.energy(&stats, PEAK);
        let lo = m.energy(&stats, EFFICIENT);
        assert!(lo.mac_j < hi.mac_j * 0.4);
        // DRAM term identical (off-chip, no VDD scaling)
        assert_eq!(lo.dram_j, hi.dram_j);
    }
}
