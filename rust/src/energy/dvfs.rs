//! DVFS operating points (paper Table 2: 0.6–1.0 V, 20–500 MHz).

/// One voltage/frequency operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    pub freq_mhz: f64,
    pub vdd: f64,
}

/// The paper's published corners.
pub const PEAK: OperatingPoint = OperatingPoint { freq_mhz: 500.0, vdd: 1.0 };
pub const EFFICIENT: OperatingPoint = OperatingPoint { freq_mhz: 20.0, vdd: 0.6 };

impl OperatingPoint {
    /// Minimum supply for a target frequency: linear V/f law anchored at
    /// the paper's two corners (the usual near-threshold..nominal range
    /// approximation for 65 nm GP).
    pub fn for_freq(freq_mhz: f64) -> Self {
        let f = freq_mhz.clamp(EFFICIENT.freq_mhz, PEAK.freq_mhz);
        let t = (f - EFFICIENT.freq_mhz) / (PEAK.freq_mhz - EFFICIENT.freq_mhz);
        OperatingPoint { freq_mhz: f, vdd: EFFICIENT.vdd + t * (PEAK.vdd - EFFICIENT.vdd) }
    }

    /// Dynamic-energy scale vs the 1.0 V nominal: (V/Vnom)².
    pub fn dyn_scale(&self) -> f64 {
        (self.vdd / PEAK.vdd).powi(2)
    }

    /// Leakage-power scale vs nominal: ≈ (V/Vnom)³ (DIBL-ish).
    pub fn leak_scale(&self) -> f64 {
        (self.vdd / PEAK.vdd).powi(3)
    }

    /// Cycle time in seconds.
    pub fn cycle_s(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corners() {
        assert_eq!(OperatingPoint::for_freq(500.0), PEAK);
        assert_eq!(OperatingPoint::for_freq(20.0), EFFICIENT);
        assert_eq!(OperatingPoint::for_freq(5.0).vdd, 0.6); // clamped
        assert_eq!(OperatingPoint::for_freq(900.0).vdd, 1.0);
    }

    #[test]
    fn monotone_vf_law() {
        let mut last = 0.0;
        for f in [20.0, 100.0, 260.0, 400.0, 500.0] {
            let v = OperatingPoint::for_freq(f).vdd;
            assert!(v >= last);
            last = v;
        }
    }

    #[test]
    fn scales() {
        assert!((EFFICIENT.dyn_scale() - 0.36).abs() < 1e-12);
        assert!((PEAK.dyn_scale() - 1.0).abs() < 1e-12);
        assert!(EFFICIENT.leak_scale() < EFFICIENT.dyn_scale());
    }
}
